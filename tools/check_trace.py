#!/usr/bin/env python3
"""Validate a Chrome-trace JSON file emitted by the step-trace subsystem.

Usage: check_trace.py TRACE.json

Checks the schema contract DESIGN.md §10 documents and CI relies on:

  * top level is an object with a non-empty ``traceEvents`` list and
    ``displayTimeUnit`` set to ``ms``;
  * every complete ("X") event carries name/cat/ph/ts/dur/pid/tid with
    numeric, non-negative ts and dur (microseconds);
  * every tid that appears in an X event is named by an "M"
    (``thread_name``) metadata event — one lane per pool worker plus the
    coordinator lane;
  * within each tid, X events are sorted by start time (the writer's
    contract, and what keeps Perfetto's ingestion linear);
  * "step"-category spans — one per training step, on the coordinator
    lane — do not overlap (small scheduler slack allowed) and carry
    strictly increasing ``args.step`` numbers.

Exit code 0 when the trace passes, 1 with a diagnostic otherwise.
"""

import json
import sys

# allowed overlap between consecutive step spans: max(50 µs, 1% of the
# earlier span) — Instant-based span edges on different threads can
# straddle each other by scheduler latency without the tiling being wrong
SLACK_US = 50.0
SLACK_FRAC = 0.01

REQUIRED_X_FIELDS = ("name", "cat", "ph", "ts", "dur", "pid", "tid")


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def main():
    if len(sys.argv) != 2:
        fail("usage: check_trace.py TRACE.json")
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit is {doc.get('displayTimeUnit')!r}, want 'ms'")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")

    named_tids = set()
    spans_by_tid = {}
    step_spans = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") != "thread_name":
                fail(f"event {i}: metadata event named {ev.get('name')!r}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                fail(f"event {i}: thread_name without args.name")
            named_tids.add(ev.get("tid"))
        elif ph == "X":
            for field in REQUIRED_X_FIELDS:
                if field not in ev:
                    fail(f"event {i}: X event missing {field!r}")
            if not is_num(ev["ts"]) or ev["ts"] < 0:
                fail(f"event {i}: bad ts {ev['ts']!r}")
            if not is_num(ev["dur"]) or ev["dur"] < 0:
                fail(f"event {i}: bad dur {ev['dur']!r}")
            spans_by_tid.setdefault(ev["tid"], []).append(ev)
            if ev["cat"] == "step":
                step_spans.append(ev)
        else:
            fail(f"event {i}: unexpected ph {ph!r}")

    if not spans_by_tid:
        fail("no X (complete) events in the trace")
    for tid, spans in sorted(spans_by_tid.items()):
        if tid not in named_tids:
            fail(f"tid {tid} has spans but no thread_name metadata event")
        for a, b in zip(spans, spans[1:]):
            if b["ts"] < a["ts"]:
                fail(f"tid {tid}: spans not sorted by ts ({b['ts']} after {a['ts']})")

    if not step_spans:
        fail("no 'step'-category spans (the per-step timeline anchor)")
    step_spans.sort(key=lambda ev: ev["ts"])
    prev_step = None
    for ev in step_spans:
        step = ev.get("args", {}).get("step")
        if not is_num(step):
            fail(f"step span at ts={ev['ts']} has no numeric args.step")
        if prev_step is not None and step <= prev_step:
            fail(f"step numbers not increasing: {step} after {prev_step}")
        prev_step = step
    for a, b in zip(step_spans, step_spans[1:]):
        slack = max(SLACK_US, SLACK_FRAC * a["dur"])
        if b["ts"] < a["ts"] + a["dur"] - slack:
            fail(
                f"step spans overlap: step {b['args']['step']} starts at "
                f"{b['ts']:.1f} inside step {a['args']['step']} "
                f"[{a['ts']:.1f}, {a['ts'] + a['dur']:.1f}]"
            )

    n_x = sum(len(s) for s in spans_by_tid.values())
    print(
        f"check_trace: OK: {n_x} spans on {len(spans_by_tid)} lanes, "
        f"{len(step_spans)} steps, schema valid"
    )


if __name__ == "__main__":
    main()
