#!/usr/bin/env python3
"""Guard against "new bench forgot CI" drift.

Every bench registered in rust/Cargo.toml must either be executed by the
bench-quick CI job (a `cargo bench --bench <name>` line in
.github/workflows/ci.yml) or appear in the conscious allowlist below.
The bench-quick job runs this first, so adding a [[bench]] without wiring
it into CI fails the pipeline instead of rotting silently.

Run from anywhere: paths resolve relative to this file.
"""

import pathlib
import re
import sys

# Long-running paper-table benches that regenerate full tables (training
# runs, large sweeps) and are covered by the compile-only bench-smoke job.
# Adding a bench here is a conscious decision — prefer teaching it --quick
# and putting it in bench-quick.
ALLOW_COMPILE_ONLY = {
    "ablation_optimizers",
    "fig1_schedule",
    "table2_convergence",
    "table2_time_model",
}


def bench_quick_runs(ci: str) -> set[str]:
    """Bench names actually executed by the bench-quick job: only
    uncommented lines inside that job's block count (a mention in a YAML
    comment or another job must not satisfy the guard)."""
    runs: set[str] = set()
    in_job = False
    for line in ci.splitlines():
        stripped = line.strip()
        if re.fullmatch(r"bench-quick:", stripped) and line.startswith("  "):
            in_job = True
            continue
        # a new two-space-indented key ends the bench-quick block
        if in_job and re.match(r"  \S", line) and not line.startswith("   "):
            in_job = False
        if not in_job or stripped.startswith("#"):
            continue
        m = re.search(r"cargo bench --bench\s+(\S+)", stripped)
        if m:
            runs.add(m.group(1))
    return runs


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    cargo = (root / "rust" / "Cargo.toml").read_text(encoding="utf-8")
    ci = (root / ".github" / "workflows" / "ci.yml").read_text(encoding="utf-8")

    registered = re.findall(r'\[\[bench\]\]\s*\nname\s*=\s*"([^"]+)"', cargo)
    if not registered:
        print("check_bench_ci: found no [[bench]] entries — parsing broke?")
        return 1
    run_in_ci = bench_quick_runs(ci)
    if not run_in_ci:
        print("check_bench_ci: found no bench runs in the bench-quick job — parsing broke?")
        return 1

    missing = [b for b in registered if b not in run_in_ci and b not in ALLOW_COMPILE_ONLY]
    stale_allow = sorted(ALLOW_COMPILE_ONLY - set(registered))

    ok = True
    if missing:
        ok = False
        print(
            "check_bench_ci: benches registered in rust/Cargo.toml but not "
            "executed by the bench-quick job (add a `cargo bench --bench "
            "<name> -- --quick` line to .github/workflows/ci.yml, or "
            "allowlist consciously in tools/check_bench_ci.py):"
        )
        for b in missing:
            print(f"  - {b}")
    if stale_allow:
        ok = False
        print("check_bench_ci: allowlist entries with no matching [[bench]]:")
        for b in stale_allow:
            print(f"  - {b}")
    if ok:
        executed = [b for b in registered if b in run_in_ci]
        print(
            f"check_bench_ci: ok — {len(executed)}/{len(registered)} benches "
            f"run in bench-quick, {len(ALLOW_COMPILE_ONLY)} allowlisted "
            "compile-only"
        )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
