#!/usr/bin/env python3
"""Guard against "new bench/example forgot CI" drift.

Every bench registered in rust/Cargo.toml must either be executed by the
bench-quick CI job (a `cargo bench --bench <name>` line in
.github/workflows/ci.yml) or appear in the conscious allowlist below.
Likewise every registered [[example]] must either be executed by the
examples-smoke job (a `cargo run --release --example <name>` line) or be
allowlisted as build-only.  Both jobs run this first, so adding a target
without wiring it into CI fails the pipeline instead of rotting silently.

The examples-smoke job must also keep invoking the artifact validators —
tools/check_trace.py against the smoke run's Chrome trace and
tools/check_metrics.py (both `--self-test` and against the smoke run's
JSONL + report) — so the exporters cannot drift away from their checkers.

Run from anywhere: paths resolve relative to this file.
"""

import pathlib
import re
import sys

# Long-running paper-table benches that regenerate full tables (training
# runs, large sweeps) and are covered by the compile-only bench-smoke job.
# Adding a bench here is a conscious decision — prefer teaching it --quick
# and putting it in bench-quick.
ALLOW_COMPILE_ONLY = {
    "ablation_optimizers",
    "fig1_schedule",
    "table2_convergence",
    "table2_time_model",
}

# Examples that are full studies/sweeps (minutes of training) — the build
# job compiles them (bit-rot guard) but examples-smoke does not execute
# them.  Adding one here is a conscious decision — prefer teaching it a
# step budget (LANS_SMOKE_STEPS) and executing it in examples-smoke.
ALLOW_BUILD_ONLY_EXAMPLES = {
    "calibrate_lr",
    "finetune",
    "pretrain_bert",
    "scaling_study",
    "schedule_explorer",
    "variance_study",
}


def job_lines(ci: str, job: str):
    """Uncommented lines inside one top-level job's block (a mention in a
    YAML comment or another job must not satisfy the guards)."""
    in_job = False
    for line in ci.splitlines():
        stripped = line.strip()
        if re.fullmatch(rf"{re.escape(job)}:", stripped) and line.startswith("  "):
            in_job = True
            continue
        # a new two-space-indented key ends the job's block
        if in_job and re.match(r"  \S", line) and not line.startswith("   "):
            in_job = False
        if in_job and not stripped.startswith("#"):
            yield stripped


def bench_quick_runs(ci: str) -> set[str]:
    runs: set[str] = set()
    for line in job_lines(ci, "bench-quick"):
        m = re.search(r"cargo bench --bench\s+(\S+)", line)
        if m:
            runs.add(m.group(1))
    return runs


def example_smoke_runs(ci: str) -> set[str]:
    runs: set[str] = set()
    for line in job_lines(ci, "examples-smoke"):
        m = re.search(r"cargo run (?:--release )?--example\s+(\S+)", line)
        if m:
            runs.add(m.group(1))
    return runs


# every validator the examples-smoke job must invoke, with the substring
# that proves it (checked against uncommented job lines only)
REQUIRED_SMOKE_VALIDATORS = [
    ("tools/check_trace.py", "tools/check_trace.py"),
    ("tools/check_metrics.py --self-test", "check_metrics.py --self-test"),
    ("tools/check_metrics.py (smoke artifacts)", "check_metrics.py target/"),
    ("tools/check_postmortem.py --self-test", "check_postmortem.py --self-test"),
    ("tools/check_postmortem.py (smoke bundle)", "check_postmortem.py target/"),
    ("lans-inspect postmortem render", "--bin lans-inspect"),
]


def missing_smoke_validators(ci: str) -> list[str]:
    lines = list(job_lines(ci, "examples-smoke"))
    return [
        label
        for label, needle in REQUIRED_SMOKE_VALIDATORS
        if not any(needle in line for line in lines)
    ]


def report_missing(kind: str, missing: list, hint: str) -> None:
    print(f"check_bench_ci: {kind} registered in rust/Cargo.toml but not executed by CI ({hint}):")
    for name in missing:
        print(f"  - {name}")


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    cargo = (root / "rust" / "Cargo.toml").read_text(encoding="utf-8")
    ci = (root / ".github" / "workflows" / "ci.yml").read_text(encoding="utf-8")

    registered = re.findall(r'\[\[bench\]\]\s*\nname\s*=\s*"([^"]+)"', cargo)
    examples = re.findall(r'\[\[example\]\]\s*\nname\s*=\s*"([^"]+)"', cargo)
    if not registered:
        print("check_bench_ci: found no [[bench]] entries — parsing broke?")
        return 1
    if not examples:
        print("check_bench_ci: found no [[example]] entries — parsing broke?")
        return 1
    run_in_ci = bench_quick_runs(ci)
    if not run_in_ci:
        print("check_bench_ci: found no bench runs in the bench-quick job — parsing broke?")
        return 1
    examples_run = example_smoke_runs(ci)
    if not examples_run:
        print("check_bench_ci: found no example runs in the examples-smoke job — parsing broke?")
        return 1

    ok = True
    missing = [b for b in registered if b not in run_in_ci and b not in ALLOW_COMPILE_ONLY]
    if missing:
        ok = False
        report_missing(
            "benches",
            missing,
            "add a `cargo bench --bench <name> -- --quick` line to the bench-quick "
            "job, or allowlist consciously in tools/check_bench_ci.py",
        )
    stale_allow = sorted(ALLOW_COMPILE_ONLY - set(registered))
    if stale_allow:
        ok = False
        print("check_bench_ci: bench allowlist entries with no matching [[bench]]:")
        for b in stale_allow:
            print(f"  - {b}")

    ex_missing = [
        e for e in examples if e not in examples_run and e not in ALLOW_BUILD_ONLY_EXAMPLES
    ]
    if ex_missing:
        ok = False
        report_missing(
            "examples",
            ex_missing,
            "add a `cargo run --release --example <name>` line to the "
            "examples-smoke job, or allowlist consciously in tools/check_bench_ci.py",
        )
    ex_stale = sorted(ALLOW_BUILD_ONLY_EXAMPLES - set(examples))
    if ex_stale:
        ok = False
        print("check_bench_ci: example allowlist entries with no matching [[example]]:")
        for e in ex_stale:
            print(f"  - {e}")

    lost_validators = missing_smoke_validators(ci)
    if lost_validators:
        ok = False
        print(
            "check_bench_ci: examples-smoke no longer invokes required artifact "
            "validators:"
        )
        for v in lost_validators:
            print(f"  - {v}")

    if ok:
        executed = [b for b in registered if b in run_in_ci]
        ex_executed = [e for e in examples if e in examples_run]
        print(
            f"check_bench_ci: ok — {len(executed)}/{len(registered)} benches "
            f"run in bench-quick ({len(ALLOW_COMPILE_ONLY)} compile-only), "
            f"{len(ex_executed)}/{len(examples)} examples run in examples-smoke "
            f"({len(ALLOW_BUILD_ONLY_EXAMPLES)} build-only), "
            f"{len(REQUIRED_SMOKE_VALIDATORS)} artifact validators wired"
        )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
