#!/usr/bin/env python3
"""Perf-regression gate: fresh `--quick` Reporter output vs committed baselines.

The bench-quick CI job runs every hot-path bench with `--quick` (each writes
`BENCH_<name>.json` via `util::bench::Reporter`), then runs this tool.  For
every snapshot committed under `BENCH_baseline/` it:

  1. requires the matching fresh `BENCH_<name>.json` to exist (a bench
     silently dropped from CI fails here, not months later),
  2. evaluates the baseline's `gate` entries — hand-set bounds on metrics
     (or `num/den` metric ratios) that are meaningful across machines:
     speedup floors, analytic byte/time invariants — and fails the job on
     any violation,
  3. prints the drift vs the baseline's `observed` snapshot (informational:
     absolute ms vary with the runner, so they inform but never gate).

Gate entry schema, inside `BENCH_baseline/BENCH_<name>.json`:

    "gate": {
      "overlap_speedup_b8": {"min": 1.0, "min_threads": 4, "why": "..."},
      "f16_narrow_speedup": {"min": 2.0, "requires": "simd_active"},
      "model_hier_naive_s/model_flat_s": {"max": 1.0}
    }

`min_threads` skips a bound when the runner has fewer cores than the
contract needs (mirrors the in-bench thread guards).  `requires` names a
feature-flag metric the bench reports (e.g. `simd_active`): when it is
missing or falsy in the fresh output the bound is skipped with a printed
note instead of failing — so a runner without the hardware feature (or a
LANS_FORCE_SCALAR=1 leg) passes the job without diluting the contract on
runners that do have it.  After an intentional
perf change, refresh the `observed` snapshots with:

    python3 tools/compare_bench.py --update

and commit `BENCH_baseline/`.  Gate bounds are deliberate floors — loosen
them by hand, with the reasoning in the commit message.

Run from anywhere: paths resolve relative to this file; fresh JSON is read
from $BENCH_OUT_DIR (or the working directory), matching the Reporter.
"""

import json
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "BENCH_baseline"


def fresh_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get("BENCH_OUT_DIR", "."))


def resolve(expr: str, metrics: dict):
    """A gate key is a metric name or a `num/den` ratio of two metrics.
    Returns (value, None) or (None, error-string)."""
    parts = expr.split("/")
    if len(parts) not in (1, 2):
        return None, f"malformed gate expression {expr!r}"
    vals = []
    for name in parts:
        if name not in metrics:
            return None, f"metric {name!r} missing from fresh output (schema drift?)"
        v = metrics[name]
        if not isinstance(v, (int, float)):
            return None, f"metric {name!r} is not a number: {v!r}"
        vals.append(float(v))
    if len(vals) == 1:
        return vals[0], None
    if vals[1] == 0.0:
        return None, f"gate ratio {expr!r} divides by zero"
    return vals[0] / vals[1], None


def check_one(base_path: pathlib.Path, failures: list) -> None:
    base = json.loads(base_path.read_text(encoding="utf-8"))
    name = base["bench"]
    fp = fresh_dir() / f"BENCH_{name}.json"
    if not fp.exists():
        failures.append(
            f"{name}: no fresh {fp} — bench-quick no longer runs this bench "
            "(restore the run line in .github/workflows/ci.yml or delete the baseline)"
        )
        return
    fresh = json.loads(fp.read_text(encoding="utf-8"))
    metrics = fresh.get("metrics", {})
    threads = int(fresh.get("threads_available", 0))

    if bool(fresh.get("quick")) != bool(base.get("quick", True)):
        print(
            f"{name}: quick={fresh.get('quick')} does not match the baseline's "
            f"quick={base.get('quick', True)} — bounds are calibrated for the "
            "--quick sweep, skipping gates"
        )
        return

    for expr, spec in base.get("gate", {}).items():
        need = int(spec.get("min_threads", 0))
        if threads < need:
            print(f"{name}: [{expr}] skipped ({threads} < {need} threads)")
            continue
        flag = spec.get("requires")
        if flag is not None and not metrics.get(flag):
            print(f"{name}: [{expr}] skipped (requires {flag!r}, runner reports it off)")
            continue
        value, err = resolve(expr, metrics)
        if err:
            failures.append(f"{name}: [{expr}] {err}")
            continue
        lo, hi = spec.get("min"), spec.get("max")
        why = f" — {spec['why']}" if "why" in spec else ""
        if lo is not None and value < float(lo):
            failures.append(f"{name}: [{expr}] = {value:.4g} below min {lo}{why}")
        elif hi is not None and value > float(hi):
            failures.append(f"{name}: [{expr}] = {value:.4g} above max {hi}{why}")
        else:
            print(f"{name}: [{expr}] = {value:.4g} ok")

    observed = base.get("observed", {})
    for k in sorted(set(observed) & set(metrics)):
        old, new = observed[k], metrics[k]
        if isinstance(old, (int, float)) and isinstance(new, (int, float)) and old:
            print(f"{name}: {k}: {old:.4g} -> {new:.4g} ({new / old:+.1%} vs snapshot, info only)")


def update() -> int:
    """Refresh every baseline's `observed` snapshot (and quick flag) from the
    fresh JSON.  Gate bounds are never touched."""
    changed = 0
    for base_path in sorted(BASELINE_DIR.glob("BENCH_*.json")):
        base = json.loads(base_path.read_text(encoding="utf-8"))
        fp = fresh_dir() / base_path.name
        if not fp.exists():
            print(f"update: skipping {base_path.name} (no fresh run found)")
            continue
        fresh = json.loads(fp.read_text(encoding="utf-8"))
        base["quick"] = bool(fresh.get("quick"))
        base["observed"] = fresh.get("metrics", {})
        base_path.write_text(json.dumps(base, indent=2) + "\n", encoding="utf-8")
        changed += 1
        print(f"update: refreshed {base_path.name}")
    print(f"update: {changed} baseline(s) refreshed — review and commit BENCH_baseline/")
    return 0


def main() -> int:
    if "--update" in sys.argv[1:]:
        return update()
    baselines = sorted(BASELINE_DIR.glob("BENCH_*.json"))
    if not baselines:
        print("compare_bench: no baselines under BENCH_baseline/ — nothing to gate?")
        return 1
    failures: list = []
    for b in baselines:
        check_one(b, failures)
    if failures:
        print("\ncompare_bench: PERF REGRESSION GATE TRIPPED:")
        for f in failures:
            print(f"  - {f}")
        print(
            "\nIf the change is intentional: re-run the benches with --quick, then\n"
            "    python3 tools/compare_bench.py --update\n"
            "review the refreshed BENCH_baseline/*.json and commit them.  Gate\n"
            "bounds (min/max) are hand-set contracts — adjust those only with\n"
            "the reasoning in the commit message."
        )
        return 1
    print(f"\ncompare_bench: ok — {len(baselines)} baseline(s), all gates hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
