#!/usr/bin/env python3
"""Validate the run-health telemetry artifacts (DESIGN.md §12).

Usage: check_metrics.py METRICS.jsonl REPORT.json
       check_metrics.py --self-test

Checks the schema contract the metrics exporter
(`rust/src/metrics/export.rs`) guarantees and CI relies on:

JSONL (one JSON object per recorded step):
  * every line is strict JSON with the full StepRecord field set;
  * numeric fields are numbers or null (non-finite f64s serialize as
    null — by design for skipped steps' NaN grad norms);
  * ``step`` is strictly increasing, ``wall_s`` and ``tokens`` are
    non-decreasing (cumulative clocks);
  * non-skipped steps carry numeric grad_norm/trust_ratio;
  * ``overlap_eff`` is in [0, 1], ``loss_scale`` is positive.

Report (single ``lans-metrics-report-v1`` document):
  * run totals are consistent (skipped <= steps);
  * each time summary's percentiles are ordered p50 <= p90 <= p99 <= max;
  * counters are non-negative integers, histogram bucket counts sum to
    the histogram count, bucket indices are in [0, 64);
  * ``health.healthy`` is exactly "no warn-severity verdict";
  * ``model`` is null or carries model/measured/delta numbers.

Cross-checks (when both files are given): line count == report steps,
skipped-line count == report skipped_steps, last tokens == report tokens.

An empty JSONL with a zero-step report passes (a run of zero steps is a
valid run).  Exit code 0 on pass, 1 with a diagnostic otherwise.
"""

import json
import sys

REPORT_SCHEMA = "lans-metrics-report-v1"
HIST_BUCKETS = 64

JSONL_FIELDS = (
    "step", "lr", "loss", "loss_ema", "grad_norm", "trust_ratio", "tokens",
    "wall_s", "loss_scale", "skipped", "comm_s", "compute_s", "overlap_eff",
    "note",
)
TIME_FIELDS = ("samples", "mean_s", "p50_s", "p90_s", "p99_s", "max_s")
VERDICT_FIELDS = ("kind", "severity", "step", "value", "threshold", "message",
                  "detail")


class CheckError(Exception):
    pass


def fail(msg):
    raise CheckError(msg)


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def is_num_or_null(x):
    return x is None or is_num(x)


def is_int(x):
    return isinstance(x, int) and not isinstance(x, bool)


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def check_jsonl_text(text):
    """Validate the per-step JSONL body; returns (steps, skipped, last_tokens)."""
    prev_step, prev_wall, prev_tokens = None, None, None
    n, skipped = 0, 0
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            fail(f"jsonl line {i}: blank line inside the series")
        try:
            r = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"jsonl line {i}: not valid JSON: {e}")
        if not isinstance(r, dict):
            fail(f"jsonl line {i}: not an object")
        for field in JSONL_FIELDS:
            if field not in r:
                fail(f"jsonl line {i}: missing {field!r}")
        if not is_int(r["step"]) or r["step"] < 1:
            fail(f"jsonl line {i}: bad step {r['step']!r}")
        if prev_step is not None and r["step"] <= prev_step:
            fail(f"jsonl line {i}: step {r['step']} not after {prev_step}")
        prev_step = r["step"]
        if not isinstance(r["skipped"], bool):
            fail(f"jsonl line {i}: skipped is {r['skipped']!r}, want bool")
        if not isinstance(r["note"], str):
            fail(f"jsonl line {i}: note is {r['note']!r}, want string")
        for field in ("lr", "loss", "loss_ema", "grad_norm", "trust_ratio",
                      "wall_s", "loss_scale", "comm_s", "compute_s",
                      "overlap_eff"):
            if not is_num_or_null(r[field]):
                fail(f"jsonl line {i}: {field} is {r[field]!r}, want number or null")
        if not r["skipped"]:
            for field in ("grad_norm", "trust_ratio"):
                if not is_num(r[field]):
                    fail(f"jsonl line {i}: applied step with non-numeric {field}")
        else:
            skipped += 1
        if not is_int(r["tokens"]) or r["tokens"] < 0:
            fail(f"jsonl line {i}: bad tokens {r['tokens']!r}")
        if prev_tokens is not None and r["tokens"] < prev_tokens:
            fail(f"jsonl line {i}: tokens {r['tokens']} below previous {prev_tokens}")
        prev_tokens = r["tokens"]
        if is_num(r["wall_s"]):
            if r["wall_s"] < 0:
                fail(f"jsonl line {i}: negative wall_s {r['wall_s']}")
            if prev_wall is not None and r["wall_s"] < prev_wall:
                fail(f"jsonl line {i}: wall_s {r['wall_s']} below previous {prev_wall}")
            prev_wall = r["wall_s"]
        if is_num(r["overlap_eff"]) and not 0.0 <= r["overlap_eff"] <= 1.0:
            fail(f"jsonl line {i}: overlap_eff {r['overlap_eff']} outside [0, 1]")
        if is_num(r["loss_scale"]) and r["loss_scale"] <= 0:
            fail(f"jsonl line {i}: loss_scale {r['loss_scale']} not positive")
        n += 1
    return n, skipped, prev_tokens


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

def check_time_summary(label, t):
    if not isinstance(t, dict):
        fail(f"{label}: not an object")
    for field in TIME_FIELDS:
        if field not in t:
            fail(f"{label}: missing {field!r}")
    if not is_int(t["samples"]) or t["samples"] < 0:
        fail(f"{label}: bad samples {t['samples']!r}")
    for field in TIME_FIELDS[1:]:
        if not is_num_or_null(t[field]):
            fail(f"{label}: {field} is {t[field]!r}, want number or null")
    if t["samples"] > 0:
        p50, p90, p99, mx = t["p50_s"], t["p90_s"], t["p99_s"], t["max_s"]
        if not all(is_num(x) for x in (p50, p90, p99, mx)):
            fail(f"{label}: non-numeric percentile with samples > 0")
        if not (p50 <= p90 <= p99 <= mx):
            fail(f"{label}: percentiles out of order: {p50} {p90} {p99} max {mx}")


def check_report_doc(doc):
    """Validate a parsed report document; returns (steps, skipped, tokens)."""
    if not isinstance(doc, dict):
        fail("report: top level must be an object")
    if doc.get("schema") != REPORT_SCHEMA:
        fail(f"report: schema is {doc.get('schema')!r}, want {REPORT_SCHEMA!r}")
    for field in ("steps", "skipped_steps", "tokens"):
        if not is_int(doc.get(field)) or doc[field] < 0:
            fail(f"report: bad {field} {doc.get(field)!r}")
    if doc["skipped_steps"] > doc["steps"]:
        fail(f"report: skipped_steps {doc['skipped_steps']} > steps {doc['steps']}")
    for field in ("tokens_per_second", "final_loss", "final_loss_ema"):
        if field not in doc or not is_num_or_null(doc[field]):
            fail(f"report: {field} is {doc.get(field)!r}, want number or null")
    if not isinstance(doc.get("diverged"), bool):
        fail(f"report: diverged is {doc.get('diverged')!r}, want bool")

    for field in ("step_time", "comm_time", "compute_time"):
        if field not in doc:
            fail(f"report: missing {field!r}")
        check_time_summary(field, doc[field])
    if doc["step_time"]["samples"] != doc["steps"]:
        fail(
            f"report: step_time.samples {doc['step_time']['samples']} "
            f"!= steps {doc['steps']}"
        )

    counters = doc.get("counters")
    if not isinstance(counters, dict):
        fail("report: counters must be an object")
    for name, v in counters.items():
        if not is_int(v) or v < 0:
            fail(f"report: counter {name!r} is {v!r}, want non-negative int")
    gauges = doc.get("gauges")
    if not isinstance(gauges, dict):
        fail("report: gauges must be an object")
    for name, v in gauges.items():
        if not is_num_or_null(v):
            fail(f"report: gauge {name!r} is {v!r}, want number or null")

    hists = doc.get("histograms")
    if not isinstance(hists, dict):
        fail("report: histograms must be an object")
    for name, h in hists.items():
        label = f"histogram {name!r}"
        if not isinstance(h, dict):
            fail(f"{label}: not an object")
        for field in ("count", "sum", "mean", "p50", "p90", "p99", "buckets"):
            if field not in h:
                fail(f"{label}: missing {field!r}")
        if not is_int(h["count"]) or h["count"] < 0:
            fail(f"{label}: bad count {h['count']!r}")
        if not isinstance(h["buckets"], list):
            fail(f"{label}: buckets must be a list")
        total = 0
        for pair in h["buckets"]:
            if (not isinstance(pair, list) or len(pair) != 2
                    or not is_int(pair[0]) or not is_int(pair[1])):
                fail(f"{label}: bucket entry {pair!r}, want [index, count]")
            idx, cnt = pair
            if not 0 <= idx < HIST_BUCKETS:
                fail(f"{label}: bucket index {idx} outside [0, {HIST_BUCKETS})")
            if cnt <= 0:
                fail(f"{label}: sparse bucket with non-positive count {cnt}")
            total += cnt
        if total != h["count"]:
            fail(f"{label}: bucket counts sum to {total}, count says {h['count']}")
        if h["count"] > 0:
            p50, p90, p99 = h["p50"], h["p90"], h["p99"]
            if not all(is_num(x) for x in (p50, p90, p99)):
                fail(f"{label}: non-numeric percentile with count > 0")
            if not (p50 <= p90 <= p99):
                fail(f"{label}: percentiles out of order: {p50} {p90} {p99}")

    health = doc.get("health")
    if not isinstance(health, dict) or not isinstance(health.get("healthy"), bool):
        fail("report: health must be an object with a bool 'healthy'")
    verdicts = health.get("verdicts")
    if not isinstance(verdicts, list):
        fail("report: health.verdicts must be a list")
    warns = 0
    for i, v in enumerate(verdicts):
        if not isinstance(v, dict):
            fail(f"report: verdict {i} is not an object")
        for field in VERDICT_FIELDS:
            if field not in v:
                fail(f"report: verdict {i} missing {field!r}")
        if v["severity"] not in ("info", "warn"):
            fail(f"report: verdict {i} severity {v['severity']!r}")
        if not is_int(v["step"]) or v["step"] < 0:
            fail(f"report: verdict {i} bad step {v['step']!r}")
        if not isinstance(v["detail"], str) or not v["detail"]:
            fail(
                f"report: verdict {i} detail is {v['detail']!r}, want "
                f"non-empty string (the monitor always attributes at least "
                f"the step index)"
            )
        if v["severity"] == "warn":
            warns += 1
    if health["healthy"] != (warns == 0):
        fail(
            f"report: healthy={health['healthy']} but {warns} warn verdict(s) "
            f"— the verdict list is the source of truth"
        )

    model = doc.get("model", "absent")
    if model == "absent":
        fail("report: missing 'model' (null when no prediction was supplied)")
    if model is not None:
        if not isinstance(model, dict):
            fail("report: model must be null or an object")
        for field in ("model_step_time_s", "measured_step_time_s", "delta_frac"):
            if field not in model or not is_num_or_null(model[field]):
                fail(f"report: model.{field} is {model.get(field)!r}")
    return doc["steps"], doc["skipped_steps"], doc["tokens"]


def check_pair(jsonl_text, report_doc):
    n, skipped, last_tokens = check_jsonl_text(jsonl_text)
    steps, rep_skipped, tokens = check_report_doc(report_doc)
    if n != steps:
        fail(f"cross-check: {n} jsonl lines but report says {steps} steps")
    if skipped != rep_skipped:
        fail(
            f"cross-check: {skipped} skipped jsonl lines but report says "
            f"{rep_skipped}"
        )
    if n > 0 and last_tokens != tokens:
        fail(
            f"cross-check: last jsonl tokens {last_tokens} but report says "
            f"{tokens}"
        )
    return n, skipped


# ---------------------------------------------------------------------------
# Self-test: an in-memory fixture matrix — one valid pair, then corruptions
# that each must be caught.  Keeps the checker honest without artifacts.
# ---------------------------------------------------------------------------

def fixture_line(step, **over):
    r = {
        "step": step, "lr": 1e-3, "loss": 5.0 - 0.1 * step,
        "loss_ema": 5.0 - 0.05 * step, "grad_norm": 1.0, "trust_ratio": 0.9,
        "tokens": 64 * step, "wall_s": 0.01 * step, "loss_scale": 65536.0,
        "skipped": False, "comm_s": 0.002, "compute_s": 0.006,
        "overlap_eff": 0.5, "note": "",
    }
    r.update(over)
    return r


def fixture_pair():
    lines = [fixture_line(t) for t in range(1, 5)]
    lines[2].update(skipped=True, grad_norm=None, trust_ratio=None,
                    note='overflow, scale -> 32768 "half"')
    jsonl = "\n".join(json.dumps(r) for r in lines) + "\n"
    ts = {"samples": 4, "mean_s": 0.01, "p50_s": 0.01, "p90_s": 0.01,
          "p99_s": 0.01, "max_s": 0.01}
    report = {
        "schema": REPORT_SCHEMA, "steps": 4, "skipped_steps": 1,
        "tokens": 256, "tokens_per_second": 6400.0,
        "final_loss": 4.6, "final_loss_ema": 4.8, "diverged": False,
        "step_time": dict(ts), "comm_time": dict(ts), "compute_time": dict(ts),
        "counters": {"wire.intra_bytes": 4096, "scaler.backoffs": 1},
        "gauges": {"scaler.scale": 32768.0},
        "histograms": {
            "optim.trust_ratio": {
                "count": 3, "sum": 2.7, "mean": 0.9, "p50": 0.9,
                "p90": 0.9, "p99": 0.9, "buckets": [[33, 3]],
            },
        },
        "health": {
            "healthy": False,
            "verdicts": [{
                "kind": "loss_scale_thrash", "severity": "warn", "step": 3,
                "value": 1.0, "threshold": 3.0, "message": "1 backoff",
                "detail": "step 3",
            }],
        },
        "model": {"model_step_time_s": 0.009, "measured_step_time_s": 0.01,
                  "delta_frac": 0.111},
    }
    return jsonl, report


def self_test():
    import copy

    jsonl, report = fixture_pair()
    check_pair(jsonl, report)  # the clean fixture must pass

    def corrupt_jsonl(name, mutate):
        lines = [json.loads(x) for x in jsonl.splitlines()]
        mutate(lines)
        return name, "\n".join(json.dumps(r) for r in lines) + "\n", report

    def corrupt_report(name, mutate):
        doc = copy.deepcopy(report)
        mutate(doc)
        return name, jsonl, doc

    def drop(d, k):
        d.pop(k)

    cases = [
        corrupt_jsonl("step not increasing",
                      lambda ls: ls[1].update(step=1)),
        corrupt_jsonl("wall clock runs backwards",
                      lambda ls: ls[3].update(wall_s=0.001)),
        corrupt_jsonl("tokens shrink",
                      lambda ls: ls[3].update(tokens=1)),
        corrupt_jsonl("overlap_eff above 1",
                      lambda ls: ls[0].update(overlap_eff=1.5)),
        corrupt_jsonl("non-positive loss scale",
                      lambda ls: ls[0].update(loss_scale=0.0)),
        corrupt_jsonl("applied step with null grad_norm",
                      lambda ls: ls[0].update(grad_norm=None)),
        corrupt_jsonl("missing field",
                      lambda ls: drop(ls[0], "loss_ema")),
        corrupt_jsonl("string where number expected",
                      lambda ls: ls[0].update(loss="4.5")),
        corrupt_report("wrong schema tag",
                       lambda d: d.update(schema="bogus-v0")),
        corrupt_report("skipped exceeds steps",
                       lambda d: d.update(skipped_steps=9)),
        corrupt_report("percentiles out of order",
                       lambda d: d["step_time"].update(p50_s=0.5)),
        corrupt_report("samples vs steps mismatch",
                       lambda d: d["step_time"].update(samples=3)),
        corrupt_report("negative counter",
                       lambda d: d["counters"].update({"wire.intra_bytes": -1})),
        corrupt_report("histogram count vs buckets",
                       lambda d: d["histograms"]["optim.trust_ratio"].update(count=7)),
        corrupt_report("bucket index out of range",
                       lambda d: d["histograms"]["optim.trust_ratio"].update(
                           buckets=[[64, 3]])),
        corrupt_report("healthy contradicts warn verdict",
                       lambda d: d["health"].update(healthy=True)),
        corrupt_report("verdict with unknown severity",
                       lambda d: d["health"]["verdicts"][0].update(severity="fatal")),
        corrupt_report("verdict with empty detail",
                       lambda d: d["health"]["verdicts"][0].update(detail="")),
        corrupt_report("verdict missing detail",
                       lambda d: drop(d["health"]["verdicts"][0], "detail")),
        corrupt_report("model missing entirely",
                       lambda d: drop(d, "model")),
        ("jsonl/report step count mismatch",
         jsonl + json.dumps(fixture_line(9, tokens=999, wall_s=9.0)) + "\n",
         report),
    ]
    for name, jl, rep in cases:
        try:
            check_pair(jl, rep)
        except CheckError:
            continue
        print(f"check_metrics: SELF-TEST FAIL: {name!r} was not caught",
              file=sys.stderr)
        sys.exit(1)

    # an empty run is valid: no lines, zero-step report
    empty_report = copy.deepcopy(report)
    empty_report.update(steps=0, skipped_steps=0, tokens=0, final_loss=None,
                        final_loss_ema=None, tokens_per_second=None)
    for k in ("step_time", "comm_time", "compute_time"):
        empty_report[k] = {"samples": 0, "mean_s": 0.0, "p50_s": 0.0,
                           "p90_s": 0.0, "p99_s": 0.0, "max_s": 0.0}
    empty_report["histograms"] = {}
    empty_report["health"] = {"healthy": True, "verdicts": []}
    check_pair("", empty_report)

    print(f"check_metrics: self-test OK ({len(cases)} corruptions caught, "
          f"clean + empty fixtures pass)")


def main():
    if sys.argv[1:] == ["--self-test"]:
        try:
            self_test()
        except CheckError as e:
            print(f"check_metrics: SELF-TEST FAIL: clean fixture rejected: {e}",
                  file=sys.stderr)
            sys.exit(1)
        return
    if len(sys.argv) != 3:
        print("usage: check_metrics.py METRICS.jsonl REPORT.json | --self-test",
              file=sys.stderr)
        sys.exit(1)
    jsonl_path, report_path = sys.argv[1], sys.argv[2]
    try:
        with open(jsonl_path, encoding="utf-8") as f:
            jsonl_text = f.read()
        with open(report_path, encoding="utf-8") as f:
            report_doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_metrics: FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    try:
        n, skipped = check_pair(jsonl_text, report_doc)
    except CheckError as e:
        print(f"check_metrics: FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print(
        f"check_metrics: OK: {n} steps ({skipped} skipped), report schema "
        f"{REPORT_SCHEMA} valid, series and report agree"
    )


if __name__ == "__main__":
    main()
