#!/usr/bin/env python3
"""Validate a flight-recorder postmortem bundle (DESIGN.md §13).

Usage: check_postmortem.py BUNDLE.json
       check_postmortem.py --self-test

Checks the schema contract the bundle writer
(`rust/src/obs/postmortem.rs`) guarantees and CI relies on:

Envelope (single ``lans-postmortem-v1`` document):
  * ``trigger`` names one of the four trigger kinds, a step, and a
    non-empty message;
  * ``culprit`` is null or a (lane, stage, dur_s) pre-attribution;
  * ``config`` is a flat string→string echo of the run's knobs;
  * ``registry`` carries non-negative integer counters and numeric (or
    null) gauges; ``scaler`` is null or (loss_scale, overflows).

Frames (the retained last-K window):
  * non-empty, at most ``flight_steps`` entries;
  * steps strictly consecutive (+1 — the ring never gaps);
  * ``partial`` is exactly "no StepRecord" (the failing step's frame);
  * spans, when present, carry the full (lane, cat, label, timing) set.

Trigger↔evidence cross-checks:
  * the trigger step is the last retained frame's step (or one past it,
    for panics sealed before the frame landed);
  * ``worker_failure`` must pre-attribute a ``worker-N`` lane and end on
    a partial frame;
  * ``health_verdict`` must retain a warn-severity verdict at the
    trigger step;
  * ``skip_burst`` must retain at least SKIP_BURST skipped frames;
  * ``pool_poison`` must say what panicked.

Exit code 0 on pass, 1 with a diagnostic otherwise.
"""

import json
import sys

BUNDLE_SCHEMA = "lans-postmortem-v1"
TRIGGER_KINDS = ("health_verdict", "skip_burst", "worker_failure", "pool_poison")
# mirrors rust/src/obs/flight.rs::SKIP_BURST
SKIP_BURST = 3

VERDICT_FIELDS = ("kind", "severity", "step", "value", "threshold", "message",
                  "detail")
FRAME_FIELDS = ("step", "partial", "applied_steps", "loss_scale",
                "scaler_overflows", "record", "counter_deltas", "verdicts",
                "spans")
RECORD_FIELDS = ("lr", "loss", "loss_ema", "grad_norm", "trust_ratio",
                 "tokens", "wall_s", "comm_s", "compute_s", "overlap_eff",
                 "skipped", "note")
SPAN_FIELDS = ("lane", "cat", "label", "start_s", "dur_s", "detail")


class CheckError(Exception):
    pass


def fail(msg):
    raise CheckError(msg)


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def is_num_or_null(x):
    return x is None or is_num(x)


def is_int(x):
    return isinstance(x, int) and not isinstance(x, bool)


def check_verdict(label, v):
    if not isinstance(v, dict):
        fail(f"{label}: not an object")
    for field in VERDICT_FIELDS:
        if field not in v:
            fail(f"{label}: missing {field!r}")
    if v["severity"] not in ("info", "warn"):
        fail(f"{label}: severity {v['severity']!r}")
    if not is_int(v["step"]) or v["step"] < 0:
        fail(f"{label}: bad step {v['step']!r}")
    if not isinstance(v["detail"], str) or not v["detail"]:
        fail(f"{label}: detail is {v['detail']!r}, want non-empty string")


def check_frame(label, f):
    if not isinstance(f, dict):
        fail(f"{label}: not an object")
    for field in FRAME_FIELDS:
        if field not in f:
            fail(f"{label}: missing {field!r}")
    if not is_int(f["step"]) or f["step"] < 0:
        fail(f"{label}: bad step {f['step']!r}")
    if not isinstance(f["partial"], bool):
        fail(f"{label}: partial is {f['partial']!r}, want bool")
    if not is_int(f["applied_steps"]) or f["applied_steps"] < 0:
        fail(f"{label}: bad applied_steps {f['applied_steps']!r}")
    if not is_num_or_null(f["loss_scale"]):
        fail(f"{label}: loss_scale is {f['loss_scale']!r}")
    if not is_int(f["scaler_overflows"]) or f["scaler_overflows"] < 0:
        fail(f"{label}: bad scaler_overflows {f['scaler_overflows']!r}")

    record = f["record"]
    if f["partial"] != (record is None):
        fail(f"{label}: partial={f['partial']} but record is "
             f"{'null' if record is None else 'present'} — partial means "
             f"exactly 'no StepRecord'")
    if record is not None:
        if not isinstance(record, dict):
            fail(f"{label}: record must be null or an object")
        for field in RECORD_FIELDS:
            if field not in record:
                fail(f"{label}: record missing {field!r}")
        if not isinstance(record["skipped"], bool):
            fail(f"{label}: record.skipped is {record['skipped']!r}")
        for field in ("lr", "loss", "loss_ema", "grad_norm", "trust_ratio",
                      "wall_s", "comm_s", "compute_s", "overlap_eff"):
            if not is_num_or_null(record[field]):
                fail(f"{label}: record.{field} is {record[field]!r}")

    if not isinstance(f["counter_deltas"], dict):
        fail(f"{label}: counter_deltas must be an object")
    for name, v in f["counter_deltas"].items():
        if not is_int(v) or v < 0:
            fail(f"{label}: counter delta {name!r} is {v!r}")
    if not isinstance(f["verdicts"], list):
        fail(f"{label}: verdicts must be a list")
    for i, v in enumerate(f["verdicts"]):
        check_verdict(f"{label} verdict {i}", v)

    spans = f["spans"]
    if spans is not None:
        if not isinstance(spans, list):
            fail(f"{label}: spans must be null or a list")
        for i, s in enumerate(spans):
            slabel = f"{label} span {i}"
            if not isinstance(s, dict):
                fail(f"{slabel}: not an object")
            for field in SPAN_FIELDS:
                if field not in s:
                    fail(f"{slabel}: missing {field!r}")
            for field in ("start_s", "dur_s"):
                if not is_num(s[field]) or s[field] < 0:
                    fail(f"{slabel}: {field} is {s[field]!r}")


def check_bundle_doc(doc):
    """Validate a parsed bundle; returns (trigger_kind, trigger_step, frames)."""
    if not isinstance(doc, dict):
        fail("bundle: top level must be an object")
    if doc.get("schema") != BUNDLE_SCHEMA:
        fail(f"bundle: schema is {doc.get('schema')!r}, want {BUNDLE_SCHEMA!r}")

    trig = doc.get("trigger")
    if not isinstance(trig, dict):
        fail("bundle: trigger must be an object")
    for field in ("kind", "step", "message"):
        if field not in trig:
            fail(f"bundle: trigger missing {field!r}")
    kind = trig["kind"]
    if kind not in TRIGGER_KINDS:
        fail(f"bundle: trigger kind {kind!r}, want one of {TRIGGER_KINDS}")
    if not is_int(trig["step"]) or trig["step"] < 0:
        fail(f"bundle: bad trigger step {trig['step']!r}")
    if not isinstance(trig["message"], str) or not trig["message"]:
        fail("bundle: trigger message must be a non-empty string")

    culprit = doc.get("culprit", "absent")
    if culprit == "absent":
        fail("bundle: missing 'culprit' (null when nothing was attributed)")
    if culprit is not None:
        if not isinstance(culprit, dict):
            fail("bundle: culprit must be null or an object")
        for field in ("lane", "stage"):
            if not isinstance(culprit.get(field), str) or not culprit[field]:
                fail(f"bundle: culprit {field} is {culprit.get(field)!r}, "
                     f"want non-empty string")
        if not is_num_or_null(culprit.get("dur_s", "absent")):
            fail(f"bundle: culprit dur_s is {culprit.get('dur_s')!r}")

    config = doc.get("config")
    if not isinstance(config, dict):
        fail("bundle: config must be an object")
    for k, v in config.items():
        if not isinstance(v, str):
            fail(f"bundle: config {k!r} is {v!r}, want string (the echo is "
                 f"rendered, not typed)")

    flight_steps = doc.get("flight_steps")
    if not is_int(flight_steps) or flight_steps < 1:
        fail(f"bundle: bad flight_steps {flight_steps!r}")

    frames = doc.get("frames")
    if not isinstance(frames, list) or not frames:
        fail("bundle: frames must be a non-empty list — a sealed bundle "
             "always retains at least the triggering window")
    if len(frames) > flight_steps:
        fail(f"bundle: {len(frames)} frames exceed flight_steps {flight_steps}")
    for i, f in enumerate(frames):
        check_frame(f"frame {i}", f)
    for prev, cur in zip(frames, frames[1:]):
        if cur["step"] != prev["step"] + 1:
            fail(f"bundle: frame steps gap: {prev['step']} -> {cur['step']} "
                 f"(the ring retains consecutive steps)")

    verdicts = doc.get("verdicts")
    if not isinstance(verdicts, list):
        fail("bundle: verdicts must be a list")
    for i, v in enumerate(verdicts):
        check_verdict(f"bundle verdict {i}", v)
    flattened = [(v["kind"], v["step"]) for f in frames for v in f["verdicts"]]
    if [(v["kind"], v["step"]) for v in verdicts] != flattened:
        fail("bundle: top-level verdicts must flatten the frame verdicts, "
             "in order")

    registry = doc.get("registry")
    if not isinstance(registry, dict):
        fail("bundle: registry must be an object")
    counters = registry.get("counters")
    if not isinstance(counters, dict):
        fail("bundle: registry.counters must be an object")
    for name, v in counters.items():
        if not is_int(v) or v < 0:
            fail(f"bundle: counter {name!r} is {v!r}")
    gauges = registry.get("gauges")
    if not isinstance(gauges, dict):
        fail("bundle: registry.gauges must be an object")
    for name, v in gauges.items():
        if not is_num_or_null(v):
            fail(f"bundle: gauge {name!r} is {v!r}")

    scaler = doc.get("scaler", "absent")
    if scaler == "absent":
        fail("bundle: missing 'scaler' (null when no frame was retained)")
    if scaler is not None:
        if not isinstance(scaler, dict):
            fail("bundle: scaler must be null or an object")
        if not is_num_or_null(scaler.get("loss_scale", "absent")):
            fail(f"bundle: scaler.loss_scale is {scaler.get('loss_scale')!r}")
        if not is_int(scaler.get("overflows")) or scaler["overflows"] < 0:
            fail(f"bundle: scaler.overflows is {scaler.get('overflows')!r}")

    # ---- trigger ↔ evidence cross-checks ---------------------------------
    last_step = frames[-1]["step"]
    if not 0 <= trig["step"] - last_step <= 1:
        fail(f"bundle: trigger step {trig['step']} vs last frame {last_step} "
             f"— the trigger must be at (or one past) the retained window")

    if kind == "worker_failure":
        if culprit is None or not culprit["lane"].startswith("worker-"):
            fail(f"bundle: worker_failure must pre-attribute a worker-N "
                 f"lane, culprit is {culprit!r}")
        if not frames[-1]["partial"]:
            fail("bundle: worker_failure must end on a partial frame (the "
                 "step died before its record existed)")
    elif kind == "health_verdict":
        if not any(v["severity"] == "warn" and v["step"] == trig["step"]
                   for v in verdicts):
            fail(f"bundle: health_verdict trigger at step {trig['step']} "
                 f"but no warn verdict at that step is retained")
    elif kind == "skip_burst":
        skipped = sum(1 for f in frames
                      if f["record"] is not None and f["record"]["skipped"])
        if skipped < SKIP_BURST:
            fail(f"bundle: skip_burst trigger but only {skipped} skipped "
                 f"frame(s) retained (burst threshold {SKIP_BURST})")
    elif kind == "pool_poison":
        if "panicked" not in trig["message"]:
            fail("bundle: pool_poison trigger must say what panicked, "
                 f"message is {trig['message']!r}")
    return kind, trig["step"], frames


# ---------------------------------------------------------------------------
# Self-test: one clean fixture per trigger kind, then a corruption matrix.
# ---------------------------------------------------------------------------

def fixture_frame(step, **over):
    f = {
        "step": step, "partial": False, "applied_steps": step,
        "loss_scale": 65536.0, "scaler_overflows": 0,
        "record": {
            "lr": 1e-3, "loss": 5.0 - 0.1 * step, "loss_ema": 5.0,
            "grad_norm": 1.0, "trust_ratio": 0.9, "tokens": 64 * step,
            "wall_s": 0.01 * step, "comm_s": 0.002, "compute_s": 0.006,
            "overlap_eff": 0.5, "skipped": False, "note": "",
        },
        "counter_deltas": {"wire.intra_bytes": 4096},
        "verdicts": [],
        "spans": [{"lane": "coordinator", "cat": "comm", "label": "allreduce",
                   "start_s": 0.001, "dur_s": 0.002, "detail": 0}],
    }
    f.update(over)
    return f


def fixture_bundle(kind):
    frames = [fixture_frame(t) for t in range(3, 7)]
    trig = {"kind": kind, "step": 6, "message": "fixture trigger"}
    culprit = {"lane": "coordinator", "stage": "allreduce", "dur_s": 0.002}
    if kind == "worker_failure":
        frames[-1] = fixture_frame(6, partial=True, record=None, spans=None)
        trig["message"] = "worker 5 failed at step 6: injected failure"
        culprit = {"lane": "worker-5", "stage": "worker_grads", "dur_s": None}
    elif kind == "health_verdict":
        warn = {"kind": "straggler", "severity": "warn", "step": 6,
                "value": 0.2, "threshold": 0.015, "message": "step 13x median",
                "detail": "lans-pool-3 — slowest stage 'allreduce' (2.0e-3s)"}
        frames[-1]["verdicts"] = [warn]
    elif kind == "skip_burst":
        for f in frames[1:]:
            f["record"]["skipped"] = True
            f["applied_steps"] = frames[0]["step"]
        trig["message"] = "3 consecutive scale backoffs"
        culprit = {"lane": "coordinator", "stage": "loss_scale", "dur_s": None}
    elif kind == "pool_poison":
        trig["message"] = "dag: stage 'bucket-2' panicked and poisoned the region"
        culprit = None
    return {
        "schema": BUNDLE_SCHEMA,
        "trigger": trig,
        "culprit": culprit,
        "config": {"optimizer": "lans", "workers": "8", "seed": "42"},
        "flight_steps": 8,
        "frames": frames,
        "verdicts": [v for f in frames for v in f["verdicts"]],
        "registry": {"counters": {"wire.intra_bytes": 16384},
                     "gauges": {"scaler.scale": 65536.0}},
        "scaler": {"loss_scale": 65536.0, "overflows": 0},
    }


def self_test():
    import copy

    for kind in TRIGGER_KINDS:
        check_bundle_doc(fixture_bundle(kind))  # every clean kind must pass

    def corrupt(name, kind, mutate):
        doc = copy.deepcopy(fixture_bundle(kind))
        mutate(doc)
        return name, doc

    def drop(d, k):
        d.pop(k)

    cases = [
        corrupt("wrong schema tag", "health_verdict",
                lambda d: d.update(schema="bogus-v0")),
        corrupt("unknown trigger kind", "health_verdict",
                lambda d: d["trigger"].update(kind="gremlins")),
        corrupt("empty trigger message", "health_verdict",
                lambda d: d["trigger"].update(message="")),
        corrupt("culprit missing entirely", "health_verdict",
                lambda d: drop(d, "culprit")),
        corrupt("culprit with empty lane", "health_verdict",
                lambda d: d["culprit"].update(lane="")),
        corrupt("typed config value", "health_verdict",
                lambda d: d["config"].update(workers=8)),
        corrupt("frames empty", "health_verdict",
                lambda d: d.update(frames=[], verdicts=[])),
        corrupt("frames exceed flight_steps", "health_verdict",
                lambda d: d.update(flight_steps=2)),
        corrupt("frame step gap", "health_verdict",
                lambda d: d["frames"][2].update(step=9)),
        corrupt("partial frame with a record", "worker_failure",
                lambda d: d["frames"][-1].update(
                    record=fixture_frame(6)["record"])),
        corrupt("full frame without a record", "health_verdict",
                lambda d: d["frames"][0].update(record=None)),
        corrupt("negative counter delta", "health_verdict",
                lambda d: d["frames"][0]["counter_deltas"].update(
                    {"wire.intra_bytes": -4})),
        corrupt("span missing timing", "health_verdict",
                lambda d: drop(d["frames"][0]["spans"][0], "dur_s")),
        corrupt("verdict without detail", "health_verdict",
                lambda d: drop(d["frames"][-1]["verdicts"][0], "detail")),
        corrupt("top-level verdicts out of sync", "health_verdict",
                lambda d: d.update(verdicts=[])),
        corrupt("trigger far past the window", "health_verdict",
                lambda d: d["trigger"].update(step=20)),
        corrupt("trigger before the window", "health_verdict",
                lambda d: d["trigger"].update(step=3)),
        corrupt("worker_failure without worker lane", "worker_failure",
                lambda d: d["culprit"].update(lane="coordinator")),
        corrupt("worker_failure ending on a full frame", "worker_failure",
                lambda d: d["frames"][-1].update(
                    partial=False, record=fixture_frame(6)["record"])),
        corrupt("health_verdict without the warn", "health_verdict",
                lambda d: d["frames"][-1]["verdicts"][0].update(severity="info")),
        corrupt("skip_burst without the skips", "skip_burst",
                lambda d: [f["record"].update(skipped=False)
                           for f in d["frames"]]),
        corrupt("pool_poison without a panic message", "pool_poison",
                lambda d: d["trigger"].update(message="something went wrong")),
        corrupt("negative registry counter", "health_verdict",
                lambda d: d["registry"]["counters"].update(
                    {"wire.intra_bytes": -1})),
        corrupt("scaler missing entirely", "health_verdict",
                lambda d: drop(d, "scaler")),
    ]
    # the health_verdict warn must be *at the trigger step*: move it off
    moved = copy.deepcopy(fixture_bundle("health_verdict"))
    moved["frames"][-1]["verdicts"][0]["step"] = 4
    moved["verdicts"] = [v for f in moved["frames"] for v in f["verdicts"]]
    cases.append(("health_verdict warn at the wrong step", moved))

    for name, doc in cases:
        try:
            check_bundle_doc(doc)
        except CheckError:
            continue
        print(f"check_postmortem: SELF-TEST FAIL: {name!r} was not caught",
              file=sys.stderr)
        sys.exit(1)

    print(f"check_postmortem: self-test OK ({len(TRIGGER_KINDS)} clean "
          f"fixtures pass, {len(cases)} corruptions caught)")


def main():
    if sys.argv[1:] == ["--self-test"]:
        try:
            self_test()
        except CheckError as e:
            print(f"check_postmortem: SELF-TEST FAIL: clean fixture rejected: {e}",
                  file=sys.stderr)
            sys.exit(1)
        return
    if len(sys.argv) != 2:
        print("usage: check_postmortem.py BUNDLE.json | --self-test",
              file=sys.stderr)
        sys.exit(1)
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_postmortem: FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    try:
        kind, step, frames = check_bundle_doc(doc)
    except CheckError as e:
        print(f"check_postmortem: FAIL: {path}: {e}", file=sys.stderr)
        sys.exit(1)
    print(
        f"check_postmortem: OK: {path}: {kind} @ step {step}, "
        f"{len(frames)} retained frame(s), schema {BUNDLE_SCHEMA} valid"
    )


if __name__ == "__main__":
    main()
