"""LR schedules: jax closed forms vs the paper's Fig. 1 numbers.
Rust parity is enforced by the same constants being asserted in
rust/src/optim/schedule.rs tests."""

import numpy as np
import pytest

from compile.schedule import linear_warmup_decay, poly_decay, warmup_const_decay

T, TW, TC = 3519, 1500, 963


def auc(fn, **kw):
    t_total = kw["t_total"]
    return float(sum(float(fn(t, **kw)) for t in range(1, t_total + 1)))


class TestShapes:
    def test_eq8_peak_and_ends(self):
        assert float(linear_warmup_decay(TW, eta=0.01, t_warmup=TW, t_total=T)) \
            == pytest.approx(0.01)
        assert float(linear_warmup_decay(1, eta=0.01, t_warmup=TW, t_total=T)) \
            == pytest.approx(0.01 / TW)
        assert float(linear_warmup_decay(T, eta=0.01, t_warmup=TW, t_total=T)) \
            == pytest.approx(0.0, abs=1e-9)

    def test_eq9_constant_stage(self):
        kw = dict(eta=0.007, t_warmup=TW, t_const=TC, t_total=T)
        for t in (TW, TW + 1, TW + TC // 2, TW + TC):
            assert float(warmup_const_decay(t, **kw)) == pytest.approx(0.007)
        assert float(warmup_const_decay(TW + TC + 50, **kw)) < 0.007
        assert float(warmup_const_decay(T, **kw)) == pytest.approx(0.0, abs=1e-9)

    def test_poly_power1_equals_eq8(self):
        for t in (10, TW, 2000, T):
            a = float(poly_decay(t, eta=0.01, t_warmup=TW, t_total=T, power=1.0))
            b = float(linear_warmup_decay(t, eta=0.01, t_warmup=TW, t_total=T))
            assert a == pytest.approx(b, abs=1e-9)


class TestFig1:
    def test_auc_gaps_match_paper(self):
        a_ideal = auc(linear_warmup_decay, eta=0.01, t_warmup=TW, t_total=T)
        a_small = auc(linear_warmup_decay, eta=0.007, t_warmup=TW, t_total=T)
        a_ours = auc(warmup_const_decay, eta=0.007, t_warmup=TW,
                     t_const=TC, t_total=T)
        assert a_ideal - a_small == pytest.approx(5.28, abs=0.05)
        assert a_ideal - a_ours == pytest.approx(1.91, abs=0.05)

    def test_traced_matches_python(self):
        # schedules are traced into the opt artifacts — jit parity
        import jax
        f = jax.jit(lambda t: warmup_const_decay(
            t, eta=0.007, t_warmup=TW, t_const=TC, t_total=T))
        for t in (1.0, 1500.0, 2000.0, 3000.0, 3519.0):
            assert float(f(t)) == pytest.approx(
                float(warmup_const_decay(t, eta=0.007, t_warmup=TW,
                                         t_const=TC, t_total=T)), rel=1e-6)
