"""Pallas optimizer kernels vs pure-jnp oracles — the core L1 correctness
signal.  Hypothesis sweeps block lengths (including tile-boundary cases) and
hyper-parameter ranges; every property asserts allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.adamw import adamw_update
from compile.kernels.common import pad_to_tile, padded_len, sq_norm
from compile.kernels.lamb import lamb_update
from compile.kernels.lans import lans_update
from compile.kernels.ref import adamw_ref, lamb_ref, lans_ref

TILE = 256  # small tile so hypothesis exercises multi-tile grids cheaply


def make_block(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    m = (0.1 * rng.standard_normal(n)).astype(np.float32)
    v = np.abs(0.1 * rng.standard_normal(n)).astype(np.float32)
    g = rng.standard_normal(n).astype(np.float32)
    return x, m, v, g


def check(kernel, ref, n, seed, hp, kernel_kw=None, ref_kw=None):
    x, m, v, g = make_block(n, seed)
    got = kernel(jnp.array(x), jnp.array(m), jnp.array(v), jnp.array(g),
                 **hp, **(kernel_kw or {}))
    want = ref(x, m, v, g, **hp, **(ref_kw or {}))
    for gi, wi, name in zip(got, want, ("x", "m", "v")):
        np.testing.assert_allclose(
            np.asarray(gi), np.asarray(wi), rtol=3e-5, atol=3e-6,
            err_msg=f"{kernel.__name__} {name} mismatch at n={n}")


HP = st.fixed_dictionaries({
    "lr": st.floats(1e-5, 0.1),
    "beta1": st.floats(0.5, 0.99),
    "beta2": st.floats(0.9, 0.9999),
    "eps": st.sampled_from([1e-8, 1e-6]),
    "wd": st.sampled_from([0.0, 0.01, 0.1]),
    "step": st.integers(1, 1000).map(float),
})

# block sizes around tile boundaries plus odd sizes
NS = st.sampled_from([1, 3, TILE - 1, TILE, TILE + 1, 2 * TILE, 1000, 2500])


class TestLans:
    @settings(max_examples=30, deadline=None)
    @given(n=NS, seed=st.integers(0, 2**31), hp=HP)
    def test_matches_ref(self, n, seed, hp):
        check(lans_update, lans_ref, n, seed, hp, kernel_kw={"tile": TILE})

    def test_zero_gradient_block_is_safe(self):
        # a freshly-initialised bias block can have g = 0 exactly
        x = jnp.ones(8)
        z = jnp.zeros(8)
        hp = dict(lr=0.01, beta1=0.9, beta2=0.999, eps=1e-6, wd=0.0, step=1.0)
        xn, mn, vn = lans_update(x, z, z, z, **hp, tile=TILE)
        assert np.all(np.isfinite(np.asarray(xn)))
        assert np.all(np.isfinite(np.asarray(mn)))

    def test_gradient_scale_invariance(self):
        # eq. (4): scaling g by any positive factor leaves the step unchanged
        x, m, v, g = make_block(500, 0)
        hp = dict(lr=0.01, beta1=0.9, beta2=0.999, eps=1e-6, wd=0.01, step=1.0)
        a = lans_update(jnp.array(x), jnp.array(m), jnp.array(v),
                        jnp.array(g), **hp, tile=TILE)
        b = lans_update(jnp.array(x), jnp.array(m), jnp.array(v),
                        jnp.array(1000.0 * g), **hp, tile=TILE)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=1e-5, atol=1e-6)

    def test_update_norm_bounded_by_lr_xnorm(self):
        # trust-ratio property: ‖Δx‖ ≤ lr·‖x‖ when wd=0
        x, m, v, g = make_block(1000, 1)
        hp = dict(lr=0.01, beta1=0.9, beta2=0.999, eps=1e-6, wd=0.0, step=1.0)
        xn, _, _ = lans_update(jnp.array(x), jnp.array(m), jnp.array(v),
                               jnp.array(g), **hp, tile=TILE)
        dx = np.linalg.norm(np.asarray(xn) - x)
        assert dx <= 0.01 * np.linalg.norm(x) * 1.001


class TestLamb:
    @settings(max_examples=30, deadline=None)
    @given(n=NS, seed=st.integers(0, 2**31), hp=HP)
    def test_matches_ref(self, n, seed, hp):
        check(lamb_update, lamb_ref, n, seed, hp, kernel_kw={"tile": TILE})

    def test_phi_clipping(self):
        x, m, v, g = make_block(300, 2)
        x = x * 100.0  # huge ‖x‖ so clipping binds
        hp = dict(lr=0.01, beta1=0.9, beta2=0.999, eps=1e-6, wd=0.01, step=3.0)
        clip = dict(phi_min=0.1, phi_max=5.0)
        got = lamb_update(jnp.array(x), jnp.array(m), jnp.array(v),
                          jnp.array(g), **hp, **clip, tile=TILE)
        want = lamb_ref(x, m, v, g, **hp, **clip)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=3e-5, atol=3e-6)


class TestAdamW:
    @settings(max_examples=30, deadline=None)
    @given(n=NS, seed=st.integers(0, 2**31), hp=HP,
           bgn=st.booleans())
    def test_matches_ref(self, n, seed, hp, bgn):
        check(adamw_update, adamw_ref, n, seed, hp,
              kernel_kw={"block_grad_norm": bgn, "tile": TILE},
              ref_kw={"block_grad_norm": bgn})


class TestCommon:
    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 3000), seed=st.integers(0, 2**31))
    def test_sq_norm_matches_numpy(self, n, seed):
        rng = np.random.default_rng(seed)
        a = rng.standard_normal(n).astype(np.float32)
        got = float(sq_norm(jnp.array(a), tile=TILE))
        want = float(np.sum(a.astype(np.float64) ** 2))
        assert got == pytest.approx(want, rel=2e-5)

    def test_padding(self):
        assert padded_len(1, 256) == 256
        assert padded_len(256, 256) == 256
        assert padded_len(257, 256) == 512
        a = jnp.arange(5.0)
        p = pad_to_tile(a, 4)
        assert p.shape == (8,)
        assert float(p[7]) == 0.0
