"""L2 model: shapes, loss sanity, gradient correctness (finite differences
on a selected parameter), and learnability on a trivial dataset."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.configs import get_config, param_specs, decay_mask, BertConfig
from compile.model import forward_mlm_loss, init_params, make_fwd_bwd
from compile.optim import make_opt_step

TINY = BertConfig("unit-tiny", num_layers=2, hidden=32, num_heads=2,
                  intermediate=64, vocab_size=64, max_seq_len=16)


def make_batch(cfg, b, s, p, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(5, cfg.vocab_size, size=(b, s)).astype(np.int32)
    pos = np.stack([rng.choice(s, size=p, replace=False) for _ in range(b)]
                   ).astype(np.int32)
    ids = np.take_along_axis(tokens, pos, axis=1)
    w = np.ones((b, p), np.float32)
    return tokens, pos, ids, w


class TestParamSpecs:
    def test_counts_match_known_presets(self):
        # bert-base ~110M with 30522 vocab
        base = get_config("bert-base")
        assert 1.0e8 < base.param_count() < 1.2e8
        large = get_config("bert-large")
        assert 3.3e8 < large.param_count() < 3.6e8

    def test_decay_mask_convention(self):
        assert decay_mask("encoder/layer_0/attn/q_kernel")
        assert not decay_mask("encoder/layer_0/attn/q_bias")
        assert not decay_mask("embeddings/ln_scale")
        assert decay_mask("embeddings/word")

    def test_init_matches_specs(self):
        params = init_params(TINY, 0)
        specs = param_specs(TINY)
        assert len(params) == len(specs)
        for p, (name, shape) in zip(params, specs):
            assert p.shape == shape, name
        # ln scales are ones
        names = [n for n, _ in specs]
        ln = params[names.index("embeddings/ln_scale")]
        assert np.all(ln == 1.0)


class TestForward:
    def test_loss_is_near_uniform_at_init(self):
        params = init_params(TINY, 0)
        tokens, pos, ids, w = make_batch(TINY, 4, 16, 3)
        loss = forward_mlm_loss(tuple(map(jnp.array, params)),
                                jnp.array(tokens), jnp.array(pos),
                                jnp.array(ids), jnp.array(w), TINY)
        # random init => approx log(vocab)
        assert abs(float(loss) - np.log(TINY.vocab_size)) < 0.5

    def test_weights_mask_loss(self):
        params = tuple(map(jnp.array, init_params(TINY, 0)))
        tokens, pos, ids, w = make_batch(TINY, 2, 16, 3)
        full = forward_mlm_loss(params, jnp.array(tokens), jnp.array(pos),
                                jnp.array(ids), jnp.array(w), TINY)
        # corrupt the target at a zero-weight slot: loss must not change
        w2 = w.copy()
        w2[0, 1] = 0.0
        ids2 = ids.copy()
        base = forward_mlm_loss(params, jnp.array(tokens), jnp.array(pos),
                                jnp.array(ids2), jnp.array(w2), TINY)
        ids2[0, 1] = (ids2[0, 1] + 7) % TINY.vocab_size
        changed = forward_mlm_loss(params, jnp.array(tokens), jnp.array(pos),
                                   jnp.array(ids2), jnp.array(w2), TINY)
        assert float(base) == pytest.approx(float(changed), rel=1e-6)
        assert float(full) != pytest.approx(float(base), rel=1e-6)

    def test_fwd_bwd_outputs(self):
        fb = make_fwd_bwd(TINY)
        params = tuple(map(jnp.array, init_params(TINY, 0)))
        tokens, pos, ids, w = make_batch(TINY, 2, 16, 3)
        out = fb(params, jnp.array(tokens), jnp.array(pos), jnp.array(ids),
                 jnp.array(w))
        assert len(out) == 1 + len(params)
        for g, p in zip(out[1:], params):
            assert g.shape == p.shape


class TestGradients:
    def test_finite_difference_on_mlm_bias(self):
        """Central finite differences on a few coordinates of the MLM output
        bias (cheap: it enters the loss linearly through the logits)."""
        specs = param_specs(TINY)
        names = [n for n, _ in specs]
        bias_idx = names.index("mlm/output_bias")
        params = list(map(jnp.array, init_params(TINY, 1)))
        tokens, pos, ids, w = make_batch(TINY, 2, 16, 3, seed=1)
        args = (jnp.array(tokens), jnp.array(pos), jnp.array(ids), jnp.array(w))

        def loss_fn(ps):
            return forward_mlm_loss(tuple(ps), *args, TINY)

        g = jax.grad(lambda ps: loss_fn(ps))(params)[bias_idx]
        eps = 1e-3
        for coord in [0, 7, 33]:
            pp = [p for p in params]
            delta = np.zeros(TINY.vocab_size, np.float32)
            delta[coord] = eps
            pp[bias_idx] = params[bias_idx] + delta
            up = float(loss_fn(pp))
            pp[bias_idx] = params[bias_idx] - delta
            down = float(loss_fn(pp))
            fd = (up - down) / (2 * eps)
            assert float(g[coord]) == pytest.approx(fd, rel=0.05, abs=1e-4)


class TestTraining:
    def test_loss_decreases_with_lans(self):
        """30 LANS steps on a fixed batch must cut the loss (end-to-end L1+L2
        integration in pure python)."""
        cfg = TINY
        fb = jax.jit(make_fwd_bwd(cfg))
        step = jax.jit(make_opt_step(cfg, "lans"))
        params = tuple(map(jnp.array, init_params(cfg, 2)))
        n = len(params)
        ms = tuple(jnp.zeros_like(p) for p in params)
        vs = tuple(jnp.zeros_like(p) for p in params)
        tokens, pos, ids, w = make_batch(cfg, 4, 16, 3, seed=2)
        args = (jnp.array(tokens), jnp.array(pos), jnp.array(ids), jnp.array(w))

        first = None
        last = None
        for t in range(1, 31):
            out = fb(params, *args)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            last = float(loss)
            new = step(params, ms, vs, grads,
                       jnp.array([0.02], jnp.float32),
                       jnp.array([float(t)], jnp.float32))
            params = tuple(new[:n])
            ms = tuple(new[n:2 * n])
            vs = tuple(new[2 * n:3 * n])
        assert last < first * 0.7, f"loss {first} -> {last}"
