"""Whole-model optimizer steps (optim.make_opt_step): per-block equivalence
with ref.py, weight-decay masking, and the flat argument layout that the
AOT artifact (and thus the rust runtime) relies on."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.configs import BertConfig, decay_mask, param_specs
from compile.model import init_params
from compile.optim import OptHyper, make_opt_step
from compile.kernels.ref import adamw_ref, lamb_ref, lans_ref

CFG = BertConfig("unit-opt", num_layers=1, hidden=16, num_heads=2,
                 intermediate=32, vocab_size=32, max_seq_len=8)

REFS = {"lans": lans_ref, "lamb": lamb_ref, "adamw": adamw_ref,
        "adamw_bgn": adamw_ref}


def state(seed):
    rng = np.random.default_rng(seed)
    params = tuple(map(jnp.array, init_params(CFG, seed)))
    ms = tuple(jnp.array(0.1 * rng.standard_normal(p.shape), jnp.float32)
               for p in params)
    vs = tuple(jnp.array(np.abs(0.1 * rng.standard_normal(p.shape)),
                         jnp.float32) for p in params)
    grads = tuple(jnp.array(rng.standard_normal(p.shape), jnp.float32)
                  for p in params)
    return params, ms, vs, grads


@pytest.mark.parametrize("name", ["lans", "lamb", "adamw", "adamw_bgn"])
def test_blockwise_equivalence(name):
    hyper = OptHyper()
    step = make_opt_step(CFG, name, hyper)
    params, ms, vs, grads = state(0)
    n = len(params)
    out = step(params, ms, vs, grads,
               jnp.array([0.01], jnp.float32), jnp.array([4.0], jnp.float32))
    assert len(out) == 3 * n

    ref = REFS[name]
    for i, (pname, _) in enumerate(param_specs(CFG)):
        wd = hyper.weight_decay if decay_mask(pname) else 0.0
        kw = dict(lr=0.01, beta1=hyper.beta1, beta2=hyper.beta2,
                  eps=hyper.eps, wd=wd, step=4.0)
        if name == "adamw_bgn":
            kw["block_grad_norm"] = True
        want = ref(params[i].reshape(-1), ms[i].reshape(-1),
                   vs[i].reshape(-1), grads[i].reshape(-1), **kw)
        np.testing.assert_allclose(
            np.asarray(out[i]).reshape(-1), np.asarray(want[0]),
            rtol=3e-5, atol=3e-6, err_msg=f"{name}: {pname} params")
        np.testing.assert_allclose(
            np.asarray(out[n + i]).reshape(-1), np.asarray(want[1]),
            rtol=3e-5, atol=3e-6, err_msg=f"{name}: {pname} m")


def test_weight_decay_masked_blocks_unaffected_by_wd():
    """Bias/LN blocks must see wd=0: changing weight_decay must not change
    their update."""
    params, ms, vs, grads = state(1)
    s1 = make_opt_step(CFG, "lans", OptHyper(weight_decay=0.0))
    s2 = make_opt_step(CFG, "lans", OptHyper(weight_decay=0.5))
    o1 = s1(params, ms, vs, grads, jnp.array([0.01]), jnp.array([1.0]))
    o2 = s2(params, ms, vs, grads, jnp.array([0.01]), jnp.array([1.0]))
    for i, (pname, _) in enumerate(param_specs(CFG)):
        a, b = np.asarray(o1[i]), np.asarray(o2[i])
        if decay_mask(pname):
            continue
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7,
                                   err_msg=f"{pname} affected by wd")
    # but decayed blocks ARE affected
    kernels = [i for i, (n, _) in enumerate(param_specs(CFG)) if decay_mask(n)]
    diffs = sum(float(np.abs(np.asarray(o1[i]) - np.asarray(o2[i])).sum())
                for i in kernels)
    assert diffs > 1e-4


def test_unknown_optimizer_raises():
    with pytest.raises(KeyError):
        make_opt_step(CFG, "sgdzilla")
