"""Pallas LayerNorm kernel: forward vs oracle, and the custom VJP vs
jax-autodiff of the oracle, across row/width sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.layernorm import layernorm, ROW_BLOCK
from compile.kernels.ref import layernorm_ref


def make(rows, h, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, h)).astype(np.float32)
    s = (1.0 + 0.1 * rng.standard_normal(h)).astype(np.float32)
    b = (0.1 * rng.standard_normal(h)).astype(np.float32)
    return x, s, b


@settings(max_examples=20, deadline=None)
@given(rows=st.sampled_from([1, ROW_BLOCK - 1, ROW_BLOCK, ROW_BLOCK + 1, 33]),
       h=st.sampled_from([8, 64, 128]),
       seed=st.integers(0, 2**31))
def test_forward_matches_ref(rows, h, seed):
    x, s, b = make(rows, h, seed)
    got = layernorm(jnp.array(x), jnp.array(s), jnp.array(b))
    want = layernorm_ref(x, s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(rows=st.sampled_from([3, ROW_BLOCK, 19]),
       h=st.sampled_from([16, 64]),
       seed=st.integers(0, 2**31))
def test_vjp_matches_autodiff_of_ref(rows, h, seed):
    x, s, b = make(rows, h, seed)

    def f_kernel(x, s, b):
        return jnp.sum(jnp.cos(layernorm(x, s, b)) * 1.5)

    def f_ref(x, s, b):
        return jnp.sum(jnp.cos(layernorm_ref(x, s, b)) * 1.5)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(
        jnp.array(x), jnp.array(s), jnp.array(b))
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(
        jnp.array(x), jnp.array(s), jnp.array(b))
    for a, bb, name in zip(g1, g2, ("dx", "dscale", "dbias")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=2e-4, atol=2e-5, err_msg=name)


def test_normalizes_rows():
    x, s, b = make(16, 32, 0)
    y = np.asarray(layernorm(jnp.array(x), jnp.ones(32), jnp.zeros(32)))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-3)


def test_eps_is_respected():
    # constant rows: variance 0, output must be finite and equal bias
    x = jnp.ones((4, 16)) * 3.0
    y = layernorm(x, jnp.ones(16), jnp.full((16,), 0.5))
    assert np.all(np.isfinite(np.asarray(y)))
    np.testing.assert_allclose(np.asarray(y), 0.5, atol=1e-3)
