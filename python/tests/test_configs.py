"""Dependency-free checks of the config/preset layer (runs without jax).

These pin the contract the rust side relies on: the canonical parameter
order, the size bookkeeping, and the weight-decay mask convention — and they
keep the CI python job meaningful even on runners without jax installed.
"""

from compile.configs import (
    PRESETS,
    decay_mask,
    get_config,
    int_prod,
    param_specs,
)


def test_presets_cover_the_family():
    for name in ["bert-tiny", "bert-mini", "bert-small", "bert-base", "bert-large"]:
        cfg = get_config(name)
        assert cfg.name == name
        assert cfg.hidden % cfg.num_heads == 0


def test_unknown_preset_raises():
    try:
        get_config("bert-colossal")
    except KeyError as e:
        assert "bert-colossal" in str(e)
    else:
        raise AssertionError("expected KeyError")


def test_param_count_matches_specs():
    for cfg in PRESETS.values():
        total = sum(int_prod(shape) for _, shape in param_specs(cfg))
        assert cfg.param_count() == total


def test_bert_large_param_count_magnitude():
    # published BERT-Large: ~334M trainable params without pooler/NSP head
    p = get_config("bert-large").param_count()
    assert 3.3e8 < p < 3.6e8, p


def test_canonical_order_starts_with_embeddings_ends_with_mlm():
    specs = param_specs(get_config("bert-tiny"))
    names = [n for n, _ in specs]
    assert names[0] == "embeddings/word"
    assert names[-1] == "mlm/output_bias"
    # one q_kernel per layer, in layer order
    q = [n for n in names if n.endswith("attn/q_kernel")]
    assert q == [f"encoder/layer_{i}/attn/q_kernel" for i in range(2)]


def test_decay_mask_convention():
    # kernels and embeddings decay; biases and LayerNorm params do not
    assert decay_mask("encoder/layer_0/attn/q_kernel")
    assert decay_mask("embeddings/word")
    assert not decay_mask("encoder/layer_0/attn/q_bias")
    assert not decay_mask("embeddings/ln_scale")
    assert not decay_mask("mlm/ln_bias")


def test_every_spec_shape_is_positive():
    for cfg in PRESETS.values():
        for name, shape in param_specs(cfg):
            assert all(int(d) > 0 for d in shape), (cfg.name, name, shape)
