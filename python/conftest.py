"""Make `compile.*` importable when pytest runs from the repo root
(`pytest python/tests/`) as well as from `python/`, and keep collection
hermetic: test modules that need optional heavyweight dependencies (jax,
hypothesis) are auto-skipped when those packages are not installed, so the
CI python job runs on plain pytest+numpy."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _has(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is not None
    except (ImportError, ValueError):
        return False


# test module -> hard requirements beyond pytest/numpy; modules not listed
# here (e.g. tests/test_configs.py) collect unconditionally
_REQUIRES = {
    "tests/test_kernels.py": ("jax", "hypothesis"),
    "tests/test_layernorm.py": ("jax", "hypothesis"),
    "tests/test_model.py": ("jax",),
    "tests/test_optim.py": ("jax",),
    "tests/test_schedule.py": ("jax",),
}

collect_ignore = [
    path for path, deps in _REQUIRES.items() if not all(_has(d) for d in deps)
]
