"""Pallas fused AdamW kernel, with optional blockwise gradient normalization
(eq. 4) — the paper's §4 finetuning optimizer ("we use AdamW optimizer with
per-block gradient normalization").

AdamW needs no trust-ratio reductions, so the whole update is a single grid
pass (plus the eq. 4 norm pass when enabled):

  x' = x - lr * ( m'/(1-b1^t) / (sqrt(v'/(1-b2^t)) + eps) + wd x )

HBM traffic: 4n reads + 3n writes (7n), +1n read with block_grad_norm.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (DEFAULT_TILE, NORM_EPS, pad_to_tile, scalar_spec,
                     sq_norm, tile_spec)


def _adamw_kernel(x_ref, m_ref, v_ref, g_ref, s_ref, x_out, m_out, v_out):
    """s_ref: [inv_gnorm, beta1, beta2, inv_bc1, inv_bc2, eps, wd, lr]."""
    inv_gnorm = s_ref[0]
    beta1, beta2 = s_ref[1], s_ref[2]
    inv_bc1, inv_bc2 = s_ref[3], s_ref[4]
    eps, wd, lr = s_ref[5], s_ref[6], s_ref[7]

    x = x_ref[...]
    g = g_ref[...] * inv_gnorm
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    m_out[...] = m_new
    v_out[...] = v_new
    upd = (m_new * inv_bc1) / (jnp.sqrt(v_new * inv_bc2) + eps) + wd * x
    x_out[...] = x - lr * upd


def adamw_update(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
                 block_grad_norm=False, tile: int = DEFAULT_TILE):
    """One fused AdamW step on a flattened block.  Returns (x', m', v')."""
    n = x.shape[0]
    xp, mp, vp, gp = (pad_to_tile(a, tile) for a in (x, m, v, g))
    grid = xp.shape[0] // tile

    t = jnp.asarray(step, jnp.float32)
    inv_bc1 = 1.0 / (1.0 - beta1 ** t)
    inv_bc2 = 1.0 / (1.0 - beta2 ** t)

    if block_grad_norm:
        gnorm = jnp.sqrt(sq_norm(g, tile))
        inv_gnorm = 1.0 / jnp.maximum(gnorm, NORM_EPS)
    else:
        inv_gnorm = jnp.float32(1.0)

    s = jnp.stack([inv_gnorm, jnp.float32(beta1), jnp.float32(beta2),
                   inv_bc1, inv_bc2, jnp.float32(eps), jnp.float32(wd),
                   jnp.asarray(lr, jnp.float32)])
    x_new, m_new, v_new = pl.pallas_call(
        _adamw_kernel,
        grid=(grid,),
        in_specs=[tile_spec(tile)] * 4 + [scalar_spec(8)],
        out_specs=[tile_spec(tile)] * 3,
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.float32)] * 3,
        interpret=True,
    )(xp, mp, vp, gp, s)

    return x_new[:n], m_new[:n], v_new[:n]
