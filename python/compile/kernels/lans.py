"""Pallas fused LANS kernel (Algorithm 2 of the paper).

The update for one parameter block x with moments (m, v) and gradient g:

    g~ = g / ||g||                                      (eq. 4)
    m' = b1 m + (1-b1) g~ ;  v' = b2 v + (1-b2) g~^2
    r  = (m'/(1-b1^t)) / (sqrt(v'/(1-b2^t)) + eps)
    c  =  g~            / (sqrt(v'/(1-b2^t)) + eps)
    d  = phi(||x||) [ b1 (r+wd x)/||r+wd x||  +  (1-b1)(c+wd x)/||c+wd x|| ]
    x' = x - lr d                                       (eq. 7)

Three grid passes over the block (DESIGN.md §Hardware-Adaptation):

  pass A  reduce ||g||^2                       (reads g:      1n)
  pass B  write m', v'; reduce ||x||^2,
          ||r+wd x||^2, ||c+wd x||^2           (reads x,m,v,g: 4n, writes 2n)
  pass C  apply x' = x - coef_r*(r+wd x)
                     - coef_c*(c+wd x)         (reads x,m',v',g: 4n, writes 1n)

Total HBM traffic 9n reads + 3n writes = 12n words vs ~31n for the unfused
elementwise-op sequence (see rust `perf::traffic`); the fusion factor is the
TPU translation of apex's fused_lans claim.

Scalar plumbing: pass B and C receive a small f32 parameter vector broadcast
to every grid step (``scalar_spec``); norms flow between passes as jnp
scalars computed from the pass outputs, i.e. the inter-pass reductions stay
inside the same lowered HLO module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (DEFAULT_TILE, NORM_EPS, _masked, pad_to_tile,
                     scalar_spec, sq_norm, tile_spec)


def _moments_kernel(x_ref, m_ref, v_ref, g_ref, s_ref,
                    m_out, v_out, sums_out, *, tile, n):
    """Pass B: update moments from the normalized gradient and accumulate the
    three squared norms needed for the trust ratios.

    s_ref layout: [inv_gnorm, beta1, beta2, inv_bc1, inv_bc2, eps, wd]
    sums_out layout: [sum_x2, sum_rfull2, sum_cfull2]
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_out[...] = jnp.zeros_like(sums_out)

    inv_gnorm = s_ref[0]
    beta1, beta2 = s_ref[1], s_ref[2]
    inv_bc1, inv_bc2 = s_ref[3], s_ref[4]
    eps, wd = s_ref[5], s_ref[6]

    x = x_ref[...]
    g_t = g_ref[...] * inv_gnorm
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g_t
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g_t * g_t
    m_out[...] = m_new
    v_out[...] = v_new

    denom = jnp.sqrt(v_new * inv_bc2) + eps
    r_full = (m_new * inv_bc1) / denom + wd * x
    c_full = g_t / denom + wd * x

    xm = _masked(x, i, tile, n)
    rm = _masked(r_full, i, tile, n)
    cm = _masked(c_full, i, tile, n)
    sums_out[0] += jnp.sum(xm * xm)
    sums_out[1] += jnp.sum(rm * rm)
    sums_out[2] += jnp.sum(cm * cm)


def _apply_kernel(x_ref, m_ref, v_ref, g_ref, s_ref, x_out):
    """Pass C: x' = x - coef_r (r + wd x) - coef_c (c + wd x).

    s_ref layout: [inv_gnorm, inv_bc1, inv_bc2, eps, wd, coef_r, coef_c]
    where coef_r = lr*phi(||x||)*b1/||r+wd x|| and
          coef_c = lr*phi(||x||)*(1-b1)/||c+wd x||.
    """
    inv_gnorm = s_ref[0]
    inv_bc1, inv_bc2 = s_ref[1], s_ref[2]
    eps, wd = s_ref[3], s_ref[4]
    coef_r, coef_c = s_ref[5], s_ref[6]

    x = x_ref[...]
    g_t = g_ref[...] * inv_gnorm
    denom = jnp.sqrt(v_ref[...] * inv_bc2) + eps
    r_full = (m_ref[...] * inv_bc1) / denom + wd * x
    c_full = g_t / denom + wd * x
    x_out[...] = x - coef_r * r_full - coef_c * c_full


def _phi(norm, phi_min, phi_max):
    if phi_min is None and phi_max is None:
        return norm
    return jnp.clip(norm, phi_min, phi_max)


def lans_update(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
                phi_min=None, phi_max=None, tile: int = DEFAULT_TILE):
    """One fused LANS step on a flattened block.  Returns (x', m', v').

    ``lr`` / ``step`` may be traced scalars (they enter through the scalar
    parameter vector), so a single lowering serves the whole schedule.
    """
    n = x.shape[0]
    xp, mp, vp, gp = (pad_to_tile(a, tile) for a in (x, m, v, g))
    grid = xp.shape[0] // tile

    t = jnp.asarray(step, jnp.float32)
    inv_bc1 = 1.0 / (1.0 - beta1 ** t)
    inv_bc2 = 1.0 / (1.0 - beta2 ** t)

    # pass A — ||g||
    gnorm = jnp.sqrt(sq_norm(g, tile))
    inv_gnorm = 1.0 / jnp.maximum(gnorm, NORM_EPS)

    # pass B — moments + norm accumulators
    s_b = jnp.stack([inv_gnorm,
                     jnp.float32(beta1), jnp.float32(beta2),
                     inv_bc1, inv_bc2,
                     jnp.float32(eps), jnp.float32(wd)])
    m_new, v_new, sums = pl.pallas_call(
        functools.partial(_moments_kernel, tile=tile, n=n),
        grid=(grid,),
        in_specs=[tile_spec(tile)] * 4 + [scalar_spec(7)],
        out_specs=[tile_spec(tile), tile_spec(tile),
                   pl.BlockSpec((3,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.float32),
                   jax.ShapeDtypeStruct(xp.shape, jnp.float32),
                   jax.ShapeDtypeStruct((3,), jnp.float32)],
        interpret=True,
    )(xp, mp, vp, gp, s_b)

    x_norm = jnp.sqrt(sums[0])
    r_norm = jnp.maximum(jnp.sqrt(sums[1]), NORM_EPS)
    c_norm = jnp.maximum(jnp.sqrt(sums[2]), NORM_EPS)
    scale = jnp.asarray(lr, jnp.float32) * _phi(x_norm, phi_min, phi_max)
    coef_r = scale * beta1 / r_norm
    coef_c = scale * (1.0 - beta1) / c_norm

    # pass C — apply
    s_c = jnp.stack([inv_gnorm, inv_bc1, inv_bc2,
                     jnp.float32(eps), jnp.float32(wd), coef_r, coef_c])
    x_new = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[tile_spec(tile)] * 4 + [scalar_spec(7)],
        out_specs=tile_spec(tile),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, m_new, v_new, gp, s_c)

    return x_new[:n], m_new[:n], v_new[:n]
