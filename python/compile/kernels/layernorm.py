"""Pallas fused LayerNorm with a hand-written VJP.

BERT is LayerNorm-heavy (2 per encoder layer + embeddings + MLM head), and
LayerNorm is the model-side fusion opportunity the XLA CPU pipeline misses
most often, so it is the L1 kernel on the *model* path (the optimizer kernels
are L1 on the update path).  Forward normalizes rows of an (R, H) matrix;
backward produces dx per row plus grid-accumulated dscale/dbias.

Pallas kernels are not auto-differentiated, so the pair is wired up with
``jax.custom_vjp`` — this is what lets the fwd_bwd HLO artifact contain
Pallas-lowered ops on both the forward and backward pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _pad_rows(a, rb):
    r = a.shape[0]
    p = ((r + rb - 1) // rb) * rb
    if p == r:
        return a
    return jnp.pad(a, ((0, p - r), (0, 0)))


def _fwd_kernel(x_ref, s_ref, b_ref, y_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x_hat = (x - mu) * jax.lax.rsqrt(var + eps)
    y_ref[...] = x_hat * s_ref[...] + b_ref[...]


def _bwd_kernel(x_ref, s_ref, dy_ref, dx_ref, ds_ref, db_ref,
                *, eps, rows, rb):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...]
    dy = dy_ref[...]
    scale = s_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rsig = jax.lax.rsqrt(var + eps)
    x_hat = (x - mu) * rsig

    wdy = dy * scale
    c1 = jnp.mean(wdy, axis=-1, keepdims=True)
    c2 = jnp.mean(wdy * x_hat, axis=-1, keepdims=True)
    dx_ref[...] = (wdy - c1 - x_hat * c2) * rsig

    # mask padded rows out of the parameter gradients
    ridx = i * rb + jax.lax.iota(jnp.int32, rb)
    live = (ridx < rows)[:, None]
    ds_ref[...] += jnp.sum(jnp.where(live, dy * x_hat, 0.0), axis=0)
    db_ref[...] += jnp.sum(jnp.where(live, dy, 0.0), axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm(x, scale, bias, eps=1e-12):
    """Row-wise LayerNorm over the last axis of a 2-D array via Pallas."""
    y, _ = _layernorm_fwd(x, scale, bias, eps)
    return y


def _layernorm_fwd(x, scale, bias, eps):
    rows, h = x.shape
    xp = _pad_rows(x, ROW_BLOCK)
    grid = xp.shape[0] // ROW_BLOCK
    y = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(grid,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, h), lambda i: (i, 0)),
                  pl.BlockSpec((h,), lambda i: (0,)),
                  pl.BlockSpec((h,), lambda i: (0,))],
        out_specs=pl.BlockSpec((ROW_BLOCK, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, scale, bias)
    return y[:rows], (x, scale)


def _layernorm_bwd(eps, res, dy):
    x, scale = res
    rows, h = x.shape
    xp = _pad_rows(x, ROW_BLOCK)
    dyp = _pad_rows(dy, ROW_BLOCK)
    grid = xp.shape[0] // ROW_BLOCK
    dx, dscale, dbias = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps, rows=rows, rb=ROW_BLOCK),
        grid=(grid,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, h), lambda i: (i, 0)),
                  pl.BlockSpec((h,), lambda i: (0,)),
                  pl.BlockSpec((ROW_BLOCK, h), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_BLOCK, h), lambda i: (i, 0)),
                   pl.BlockSpec((h,), lambda i: (0,)),
                   pl.BlockSpec((h,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, x.dtype),
                   jax.ShapeDtypeStruct((h,), x.dtype),
                   jax.ShapeDtypeStruct((h,), x.dtype)],
        interpret=True,
    )(xp, scale, dyp)
    return dx[:rows], dscale, dbias


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
