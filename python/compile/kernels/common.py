"""Shared Pallas plumbing for the fused-optimizer kernels.

Layout convention (the TPU adaptation of apex's multi-tensor-apply, see
DESIGN.md §Hardware-Adaptation): every parameter block is flattened to 1-D,
padded to a multiple of ``tile`` (default 1024 = 8 sublanes × 128 lanes),
and the grid walks tiles.  Full-block reductions (the trust-ratio norms) are
computed by accumulator kernels whose output block maps every grid step to
the same (1,) slot — the canonical Pallas grid-reduction pattern.

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret-mode lowers to plain HLO that both jax-CPU
and the rust PJRT client run bit-identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes of f32 — one native TPU vreg tile.
DEFAULT_TILE = 1024

# Matches ref.py and the rust implementation.
NORM_EPS = 1e-16


def padded_len(n: int, tile: int) -> int:
    return ((n + tile - 1) // tile) * tile


def pad_to_tile(a, tile: int):
    """Pad a 1-D array with zeros to a multiple of ``tile``."""
    n = a.shape[0]
    p = padded_len(n, tile)
    if p == n:
        return a
    return jnp.pad(a, (0, p - n))


def _masked(vals, i, tile, n):
    """Zero out lanes past the true block length ``n`` in grid step ``i``."""
    idx = i * tile + jax.lax.iota(jnp.int32, tile)
    return jnp.where(idx < n, vals, 0.0)


def _sq_norm_kernel(a_ref, o_ref, *, tile, n):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _masked(a_ref[...], i, tile, n)
    o_ref[0] += jnp.sum(a * a)


def sq_norm(a, tile: int = DEFAULT_TILE):
    """Sum of squares of a 1-D (unpadded) array via a grid-accumulating
    Pallas kernel.  Returns a () f32 scalar."""
    n = a.shape[0]
    ap = pad_to_tile(a, tile)
    grid = ap.shape[0] // tile
    out = pl.pallas_call(
        functools.partial(_sq_norm_kernel, tile=tile, n=n),
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(ap)
    return out[0]


def tile_spec(tile: int) -> pl.BlockSpec:
    """BlockSpec walking a padded 1-D array tile by tile."""
    return pl.BlockSpec((tile,), lambda i: (i,))


def scalar_spec(k: int) -> pl.BlockSpec:
    """BlockSpec broadcasting a small (k,) scalar-parameter array to every
    grid step."""
    return pl.BlockSpec((k,), lambda i: (0,))
