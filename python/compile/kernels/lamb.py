"""Pallas fused LAMB kernel (Algorithm 1 — the paper's baseline).

Two grid passes per block (no gradient-normalization pass — LAMB feeds the
raw gradient into the moments):

  pass B  write m', v'; reduce ||x||^2 and ||r + wd x||^2
  pass C  apply x' = x - coef * (r + wd x)
          with coef = lr * phi(||x||) / ||r + wd x||

HBM traffic: 8n reads + 3n writes = 11n words.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (DEFAULT_TILE, NORM_EPS, _masked, pad_to_tile,
                     scalar_spec, tile_spec)


def _moments_kernel(x_ref, m_ref, v_ref, g_ref, s_ref,
                    m_out, v_out, sums_out, *, tile, n):
    """s_ref: [beta1, beta2, inv_bc1, inv_bc2, eps, wd];
    sums_out: [sum_x2, sum_u2]."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_out[...] = jnp.zeros_like(sums_out)

    beta1, beta2 = s_ref[0], s_ref[1]
    inv_bc1, inv_bc2 = s_ref[2], s_ref[3]
    eps, wd = s_ref[4], s_ref[5]

    x = x_ref[...]
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    m_out[...] = m_new
    v_out[...] = v_new

    r = (m_new * inv_bc1) / (jnp.sqrt(v_new * inv_bc2) + eps)
    u = r + wd * x
    xm = _masked(x, i, tile, n)
    um = _masked(u, i, tile, n)
    sums_out[0] += jnp.sum(xm * xm)
    sums_out[1] += jnp.sum(um * um)


def _apply_kernel(x_ref, m_ref, v_ref, s_ref, x_out):
    """s_ref: [inv_bc1, inv_bc2, eps, wd, coef]."""
    inv_bc1, inv_bc2 = s_ref[0], s_ref[1]
    eps, wd = s_ref[2], s_ref[3]
    coef = s_ref[4]
    x = x_ref[...]
    r = (m_ref[...] * inv_bc1) / (jnp.sqrt(v_ref[...] * inv_bc2) + eps)
    x_out[...] = x - coef * (r + wd * x)


def _phi(norm, phi_min, phi_max):
    if phi_min is None and phi_max is None:
        return norm
    return jnp.clip(norm, phi_min, phi_max)


def lamb_update(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
                phi_min=None, phi_max=None, tile: int = DEFAULT_TILE):
    """One fused LAMB step on a flattened block.  Returns (x', m', v')."""
    n = x.shape[0]
    xp, mp, vp, gp = (pad_to_tile(a, tile) for a in (x, m, v, g))
    grid = xp.shape[0] // tile

    t = jnp.asarray(step, jnp.float32)
    inv_bc1 = 1.0 / (1.0 - beta1 ** t)
    inv_bc2 = 1.0 / (1.0 - beta2 ** t)

    s_b = jnp.stack([jnp.float32(beta1), jnp.float32(beta2),
                     inv_bc1, inv_bc2, jnp.float32(eps), jnp.float32(wd)])
    m_new, v_new, sums = pl.pallas_call(
        functools.partial(_moments_kernel, tile=tile, n=n),
        grid=(grid,),
        in_specs=[tile_spec(tile)] * 4 + [scalar_spec(6)],
        out_specs=[tile_spec(tile), tile_spec(tile),
                   pl.BlockSpec((2,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.float32),
                   jax.ShapeDtypeStruct(xp.shape, jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        interpret=True,
    )(xp, mp, vp, gp, s_b)

    x_norm = jnp.sqrt(sums[0])
    u_norm = jnp.maximum(jnp.sqrt(sums[1]), NORM_EPS)
    coef = jnp.asarray(lr, jnp.float32) * _phi(x_norm, phi_min, phi_max) / u_norm

    s_c = jnp.stack([inv_bc1, inv_bc2, jnp.float32(eps), jnp.float32(wd), coef])
    x_new = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[tile_spec(tile)] * 3 + [scalar_spec(5)],
        out_specs=tile_spec(tile),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, m_new, v_new, s_c)

    return x_new[:n], m_new[:n], v_new[:n]
