"""Pure-jnp correctness oracles for the Pallas optimizer kernels.

Every function operates on one parameter *block* (the paper's x_{t,G_b}),
flattened to 1-D, and implements the algorithm exactly as printed:

* ``lamb_ref``  — Algorithm 1 of the paper (You et al.'s LAMB).
* ``lans_ref``  — Algorithm 2 (LANS): per-block gradient normalization
  (eq. 4) + the Nesterov-style convex combination of the momentum direction
  ``r`` and the instantaneous direction ``c`` (eq. 7).
* ``adamw_ref`` — AdamW (Loshchilov & Hutter), optionally with the paper's
  blockwise gradient normalization (§4: the finetuning optimizer).

The trust-ratio scaling function phi is the identity (the paper: "it is
generally set to an identity mapping"), optionally clipped to
[phi_min, phi_max] as in NVIDIA's reference implementations.
"""

import jax.numpy as jnp

# Guard against 0/0 when a block norm vanishes (e.g. a freshly-initialised
# bias block with zero gradient).  Matches the rust implementation.
_NORM_EPS = 1e-16


def _phi(norm, phi_min=None, phi_max=None):
    if phi_min is None and phi_max is None:
        return norm
    return jnp.clip(norm, phi_min, phi_max)


def _safe_div(num, den):
    return num / jnp.maximum(den, _NORM_EPS)


def lans_ref(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
             phi_min=None, phi_max=None):
    """One LANS step on a single block.  Returns (x_new, m_new, v_new).

    ``step`` is the 1-based iteration counter t used for bias correction.
    """
    x = jnp.asarray(x, jnp.float32)
    g = jnp.asarray(g, jnp.float32)
    # eq. (4): per-block gradient normalization.
    g_norm = jnp.sqrt(jnp.sum(g * g))
    g_tilde = _safe_div(g, g_norm)

    m_new = beta1 * m + (1.0 - beta1) * g_tilde
    v_new = beta2 * v + (1.0 - beta2) * g_tilde * g_tilde

    t = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    denom = jnp.sqrt(v_hat) + eps

    r = m_hat / denom
    # Algorithm 2 line 11: c uses the *unbias-corrected* normalized gradient
    # (the paper removes the 1/(1-beta1^t) factor from the c-direction).
    c = g_tilde / denom

    r_full = r + wd * x
    c_full = c + wd * x
    x_norm = jnp.sqrt(jnp.sum(x * x))
    r_norm = jnp.sqrt(jnp.sum(r_full * r_full))
    c_norm = jnp.sqrt(jnp.sum(c_full * c_full))

    scale = _phi(x_norm, phi_min, phi_max)
    d = scale * (beta1 * _safe_div(r_full, r_norm)
                 + (1.0 - beta1) * _safe_div(c_full, c_norm))
    return x - lr * d, m_new, v_new


def lamb_ref(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
             phi_min=None, phi_max=None):
    """One LAMB step on a single block (Algorithm 1)."""
    x = jnp.asarray(x, jnp.float32)
    g = jnp.asarray(g, jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g

    t = jnp.asarray(step, jnp.float32)
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    r = m_hat / (jnp.sqrt(v_hat) + eps)

    u = r + wd * x
    x_norm = jnp.sqrt(jnp.sum(x * x))
    u_norm = jnp.sqrt(jnp.sum(u * u))
    scale = _phi(x_norm, phi_min, phi_max)
    return x - lr * scale * _safe_div(u, u_norm), m_new, v_new


def adamw_ref(x, m, v, g, *, lr, beta1, beta2, eps, wd, step,
              block_grad_norm=False):
    """One AdamW step on a single block; ``block_grad_norm=True`` applies the
    paper's eq. (4) normalization first (the finetuning optimizer of §4)."""
    x = jnp.asarray(x, jnp.float32)
    g = jnp.asarray(g, jnp.float32)
    if block_grad_norm:
        g = _safe_div(g, jnp.sqrt(jnp.sum(g * g)))
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    t = jnp.asarray(step, jnp.float32)
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    upd = m_hat / (jnp.sqrt(v_hat) + eps) + wd * x
    return x - lr * upd, m_new, v_new


def layernorm_ref(x, scale, bias, eps=1e-12):
    """Row-wise LayerNorm oracle over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias
