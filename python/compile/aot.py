"""AOT lowering driver: jax → HLO *text* artifacts + meta.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the rust ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per (config, seq, micro-batch) this emits:

    fwd_bwd_<tag>.hlo.txt    (params…, tokens, pos, ids, w) → (loss, grads…)
    eval_<tag>.hlo.txt       (params…, tokens, pos, ids, w) → (loss,)
    opt_<opt>_<cfg>.hlo.txt  (params…, m…, v…, grads…, lr[1], step[1])
                             → (params'…, m'…, v'…)
    <tag>.meta.json          canonical param table + artifact signatures

Usage:  python -m compile.aot --config bert-tiny --seq 64 --batch 4 \
            --out-dir ../artifacts
"""

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import decay_mask, get_config, int_prod, param_specs
from .model import make_eval_loss, make_fwd_bwd
from .optim import OptHyper, make_opt_step

OPTIMIZERS = ("lans", "lamb", "adamw", "adamw_bgn")


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def mlm_slots_for(seq: int) -> int:
    """BERT masks 15% of tokens; slot count is the padded prediction budget."""
    return max(1, math.ceil(0.15 * seq))


def _param_structs(cfg):
    return tuple(jax.ShapeDtypeStruct(s, jnp.float32)
                 for _, s in param_specs(cfg))


def _batch_structs(batch: int, seq: int, slots: int):
    return (jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            jax.ShapeDtypeStruct((batch, slots), jnp.int32),
            jax.ShapeDtypeStruct((batch, slots), jnp.int32),
            jax.ShapeDtypeStruct((batch, slots), jnp.float32))


def lower_fwd_bwd(cfg, batch: int, seq: int) -> str:
    slots = mlm_slots_for(seq)
    n = len(param_specs(cfg))
    fwd_bwd = make_fwd_bwd(cfg)

    def flat(*args):
        return fwd_bwd(tuple(args[:n]), *args[n:])

    structs = _param_structs(cfg) + _batch_structs(batch, seq, slots)
    return to_hlo_text(jax.jit(flat).lower(*structs))


def lower_eval(cfg, batch: int, seq: int) -> str:
    slots = mlm_slots_for(seq)
    n = len(param_specs(cfg))
    ev = make_eval_loss(cfg)

    def flat(*args):
        return ev(tuple(args[:n]), *args[n:])

    structs = _param_structs(cfg) + _batch_structs(batch, seq, slots)
    return to_hlo_text(jax.jit(flat).lower(*structs))


def lower_opt(cfg, opt_name: str, hyper: OptHyper) -> str:
    n = len(param_specs(cfg))
    step_fn = make_opt_step(cfg, opt_name, hyper)

    def flat(*args):
        params = tuple(args[:n])
        ms = tuple(args[n:2 * n])
        vs = tuple(args[2 * n:3 * n])
        grads = tuple(args[3 * n:4 * n])
        lr, step = args[4 * n], args[4 * n + 1]
        return step_fn(params, ms, vs, grads, lr, step)

    ps = _param_structs(cfg)
    scal = (jax.ShapeDtypeStruct((1,), jnp.float32),) * 2
    return to_hlo_text(jax.jit(flat).lower(*(ps * 4 + scal)))


def emit(cfg_name: str, batch: int, seq: int, out_dir: str,
         optimizers=OPTIMIZERS, hyper: OptHyper = OptHyper(),
         with_eval: bool = True) -> dict:
    """Emit the full artifact set; returns the meta dict."""
    cfg = get_config(cfg_name)
    assert seq <= cfg.max_seq_len
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{cfg_name}_s{seq}_b{batch}"
    slots = mlm_slots_for(seq)

    def write(name, text):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {name} ({len(text)} chars)")
        return name

    artifacts = {}
    artifacts["fwd_bwd"] = write(f"fwd_bwd_{tag}.hlo.txt",
                                 lower_fwd_bwd(cfg, batch, seq))
    if with_eval:
        artifacts["eval"] = write(f"eval_{tag}.hlo.txt",
                                  lower_eval(cfg, batch, seq))
    for opt in optimizers:
        artifacts[f"opt_{opt}"] = write(f"opt_{opt}_{cfg_name}.hlo.txt",
                                        lower_opt(cfg, opt, hyper))

    meta = {
        "tag": tag,
        "config": cfg.to_dict(),
        "batch": batch,
        "seq": seq,
        "mlm_slots": slots,
        "params": [{"name": n, "shape": list(s), "size": int_prod(s),
                    "decay": decay_mask(n)}
                   for n, s in param_specs(cfg)],
        "param_count": cfg.param_count(),
        "hyper": {"beta1": hyper.beta1, "beta2": hyper.beta2,
                  "eps": hyper.eps, "weight_decay": hyper.weight_decay},
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, f"{tag}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote {tag}.meta.json")
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="bert-tiny")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--optimizers", default=",".join(OPTIMIZERS))
    ap.add_argument("--no-eval", action="store_true")
    ap.add_argument("--phase2", action="store_true",
                    help="also emit a phase-2 artifact at max_seq_len "
                         "(the paper's two-phase pretraining)")
    args = ap.parse_args()

    opts = tuple(o for o in args.optimizers.split(",") if o)
    print(f"emitting {args.config} seq={args.seq} batch={args.batch} "
          f"-> {args.out_dir}")
    emit(args.config, args.batch, args.seq, args.out_dir, opts,
         with_eval=not args.no_eval)
    if args.phase2:
        cfg = get_config(args.config)
        b2 = max(1, args.batch // 4)  # paper: phase-2 batch ≈ phase-1 / 3
        print(f"emitting phase-2 {args.config} seq={cfg.max_seq_len} "
              f"batch={b2}")
        emit(args.config, b2, cfg.max_seq_len, args.out_dir,
             optimizers=(), with_eval=not args.no_eval)


if __name__ == "__main__":
    main()
