"""L2: whole-model optimizer steps over the canonical parameter tuple.

``make_opt_step(cfg, name, hyper)`` builds the function that ``aot.py``
lowers to ``opt_<name>_<cfg>.hlo.txt``:

    (params…, m…, v…, grads…, lr[1], step[1]) → (params'…, m'…, v'…)

Each parameter tensor is one LAMB/LANS block (the paper's G_b): it is
flattened, run through the fused Pallas kernel, and reshaped back.  Weight
decay follows the BERT convention (λ=0 on biases and LayerNorm parameters,
``configs.decay_mask``), matching the authors' apex implementation.

``lr`` and ``step`` are shape-(1,) f32 runtime inputs so one lowering serves
the entire LR schedule; the schedule itself runs in rust.
"""

from dataclasses import dataclass

from .configs import BertConfig, decay_mask, param_specs
from .kernels.adamw import adamw_update
from .kernels.lamb import lamb_update
from .kernels.lans import lans_update


@dataclass(frozen=True)
class OptHyper:
    """Optimizer hyper-parameters baked into the artifact (Table 1 has the
    schedule-level knobs; these are the Adam-family constants)."""
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-6
    weight_decay: float = 0.01
    # phi clipping; None,None = identity (the paper's choice)
    phi_min: float | None = None
    phi_max: float | None = None


KERNELS = {
    "lans": lans_update,
    "lamb": lamb_update,
    "adamw": adamw_update,
    "adamw_bgn": adamw_update,  # + blockwise gradient normalization (§4)
}


def make_opt_step(cfg: BertConfig, name: str, hyper: OptHyper = OptHyper()):
    """Returns step(params, ms, vs, grads, lr, step) -> params' + ms' + vs'
    (a flat tuple, canonical order)."""
    if name not in KERNELS:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(KERNELS)}")
    kernel = KERNELS[name]
    specs = param_specs(cfg)

    def step_fn(params, ms, vs, grads, lr, step):
        lr_s = lr.reshape(())
        t_s = step.reshape(())
        new_p, new_m, new_v = [], [], []
        for (pname, shape), x, m, v, g in zip(specs, params, ms, vs, grads):
            wd = hyper.weight_decay if decay_mask(pname) else 0.0
            kw = dict(lr=lr_s, beta1=hyper.beta1, beta2=hyper.beta2,
                      eps=hyper.eps, wd=wd, step=t_s)
            if name in ("lans", "lamb"):
                kw.update(phi_min=hyper.phi_min, phi_max=hyper.phi_max)
            if name == "adamw_bgn":
                kw.update(block_grad_norm=True)
            xf, mf, vf, gf = (a.reshape(-1) for a in (x, m, v, g))
            xn, mn, vn = kernel(xf, mf, vf, gf, **kw)
            new_p.append(xn.reshape(shape))
            new_m.append(mn.reshape(shape))
            new_v.append(vn.reshape(shape))
        return tuple(new_p) + tuple(new_m) + tuple(new_v)

    return step_fn
