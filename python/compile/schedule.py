"""Learning-rate schedules (paper eq. 8 and eq. 9) as traced jax scalars.

These are baked into the optimizer HLO artifacts so the rust hot path only
feeds the step counter; a bit-identical rust implementation lives in
``rust/src/optim/schedule.rs`` (it drives scheduling decisions and Fig. 1)
and parity is asserted in ``python/tests/test_schedule.py`` against the same
closed forms.
"""

import jax.numpy as jnp


def linear_warmup_decay(t, *, eta, t_warmup, t_total):
    """eq. (8): linear warmup to ``eta`` over ``t_warmup`` steps, then linear
    decay to 0 at ``t_total``.  ``t`` is the 1-based step, traced or static."""
    t = jnp.asarray(t, jnp.float32)
    warm = eta * t / t_warmup
    decay = eta * (t_total - t) / (t_total - t_warmup)
    return jnp.where(t <= t_warmup, warm, jnp.maximum(decay, 0.0))


def warmup_const_decay(t, *, eta, t_warmup, t_const, t_total):
    """eq. (9): warmup, then a constant-LR transient of ``t_const`` steps,
    then linear decay — the paper's scheduler for batch sizes past the
    linear-scaling limit."""
    t = jnp.asarray(t, jnp.float32)
    warm = eta * t / t_warmup
    decay = eta * (t_total - t) / (t_total - t_warmup - t_const)
    out = jnp.where(t <= t_warmup, warm,
                    jnp.where(t <= t_warmup + t_const, eta,
                              jnp.maximum(decay, 0.0)))
    return out


def poly_decay(t, *, eta, t_warmup, t_total, power=1.0):
    """Polynomial-decay generalisation (power=1 reduces to eq. 8); included
    because the BERT reference implementations use poly decay."""
    t = jnp.asarray(t, jnp.float32)
    warm = eta * t / t_warmup
    frac = jnp.clip((t_total - t) / (t_total - t_warmup), 0.0, 1.0)
    return jnp.where(t <= t_warmup, warm, eta * frac ** power)
