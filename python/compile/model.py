"""L2: BERT encoder forward/backward with masked-LM loss, in jax.

This is the compute graph the rust workers execute: ``make_fwd_bwd(cfg)``
returns a function (params, batch) -> (loss, grads) that ``aot.py`` lowers to
one HLO text artifact per (config, seq_len, micro_batch).  LayerNorm goes
through the Pallas kernel (``kernels/layernorm.py``) so L1 code is on both
the forward and the backward path of the artifact.

Parameters travel as a *tuple in canonical order* (``configs.param_specs``) —
the same order the rust runtime marshals literals in.  No pytree surprises:
tuple in, tuple of grads out.

Architecture = BERT post-LN as in Devlin et al.: word+position embeddings,
N×(self-attention + FFN with GELU), MLM head with a GELU transform and the
output projection *tied* to the word-embedding matrix.  NSP is omitted (as in
RoBERTa and most reproductions; the paper's target metric is MLM-driven
SQuAD quality, and NSP contributes <1% of FLOPs).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from .configs import BertConfig, param_specs
from .kernels.layernorm import layernorm


def gelu(x):
    """tanh-approximation GELU (Hendrycks & Gimpel; the Megatron/GPT form).

    The exact-erf form lowers to the `erf` HLO opcode, which the runtime's
    XLA 0.5.1 text parser predates — the tanh approximation lowers to
    parser-supported primitives and differs by <1e-3 everywhere.
    """
    c = np.sqrt(2.0 / np.pi).astype(np.float32)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def init_params(cfg: BertConfig, seed: int = 0):
    """Initialise parameters in canonical order.

    BERT init: truncated-normal(0.02) for kernels and embeddings, zeros for
    biases, ones for LayerNorm scales.
    """
    rng = np.random.default_rng(seed)

    def trunc_normal(shape, std=0.02):
        a = rng.standard_normal(size=shape).astype(np.float32)
        return np.clip(a, -2.0, 2.0) * std

    out = []
    for name, shape in param_specs(cfg):
        if name.endswith("ln_scale"):
            out.append(np.ones(shape, np.float32))
        elif name.endswith("_bias") or name.endswith("ln_bias"):
            out.append(np.zeros(shape, np.float32))
        else:
            out.append(trunc_normal(shape))
    return tuple(out)


def _ln(x2d, scale, bias, eps):
    return layernorm(x2d, scale, bias, eps)


def _attention(h, p, cfg: BertConfig):
    """Multi-head self-attention block (no padding mask: the data pipeline
    always packs full-length sequences, matching the BERT pretraining
    pipeline where documents are concatenated and split)."""
    b, s, hd = h.shape
    nh, dh = cfg.num_heads, cfg.head_dim

    def proj(x, kernel, bias):
        return (x.reshape(b * s, hd) @ kernel + bias).reshape(b, s, nh, dh)

    q = proj(h, p["attn/q_kernel"], p["attn/q_bias"])
    k = proj(h, p["attn/k_kernel"], p["attn/k_bias"])
    v = proj(h, p["attn/v_kernel"], p["attn/v_bias"])

    # (b, nh, s, s)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, hd)
    out = ctx @ p["attn/out_kernel"] + p["attn/out_bias"]

    res = h.reshape(b * s, hd) + out
    return _ln(res, p["attn/ln_scale"], p["attn/ln_bias"],
               cfg.layernorm_eps).reshape(b, s, hd)


def _ffn(h, p, cfg: BertConfig):
    b, s, hd = h.shape
    x = h.reshape(b * s, hd)
    inner = gelu(x @ p["ffn/in_kernel"] + p["ffn/in_bias"])
    out = inner @ p["ffn/out_kernel"] + p["ffn/out_bias"]
    return _ln(x + out, p["ffn/ln_scale"], p["ffn/ln_bias"],
               cfg.layernorm_eps).reshape(b, s, hd)


def _layer_view(params_by_name: dict, layer: int) -> dict:
    pref = f"encoder/layer_{layer}/"
    return {k[len(pref):]: v for k, v in params_by_name.items()
            if k.startswith(pref)}


def forward_mlm_loss(params: tuple, tokens, mlm_pos, mlm_ids, mlm_weights,
                     cfg: BertConfig):
    """Masked-LM loss.

    tokens      (b, s)  int32 — input ids with [MASK] substitutions applied
    mlm_pos     (b, p)  int32 — positions of prediction slots
    mlm_ids     (b, p)  int32 — original token ids at those slots
    mlm_weights (b, p)  f32   — 1.0 for live slots, 0.0 for padding slots
    """
    names = [n for n, _ in param_specs(cfg)]
    p = dict(zip(names, params))
    b, s = tokens.shape

    emb = p["embeddings/word"][tokens] + p["embeddings/position"][:s][None]
    h = _ln(emb.reshape(b * s, cfg.hidden), p["embeddings/ln_scale"],
            p["embeddings/ln_bias"], cfg.layernorm_eps).reshape(b, s, cfg.hidden)

    for i in range(cfg.num_layers):
        lp = _layer_view(p, i)
        h = _attention(h, lp, cfg)
        h = _ffn(h, lp, cfg)

    # gather prediction slots: (b, p, hidden)
    sel = jnp.take_along_axis(h, mlm_pos[..., None], axis=1)
    np_ = sel.shape[1]
    x = sel.reshape(b * np_, cfg.hidden)
    x = gelu(x @ p["mlm/transform_kernel"] + p["mlm/transform_bias"])
    x = _ln(x, p["mlm/ln_scale"], p["mlm/ln_bias"], cfg.layernorm_eps)
    # tied output embedding
    logits = x @ p["embeddings/word"].T + p["mlm/output_bias"]

    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = mlm_ids.reshape(b * np_)
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    w = mlm_weights.reshape(b * np_)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def make_fwd_bwd(cfg: BertConfig):
    """(params…, tokens, mlm_pos, mlm_ids, mlm_weights) → (loss, grads…)."""

    def fwd_bwd(params, tokens, mlm_pos, mlm_ids, mlm_weights):
        loss, grads = jax.value_and_grad(forward_mlm_loss)(
            params, tokens, mlm_pos, mlm_ids, mlm_weights, cfg)
        return (loss,) + tuple(grads)

    return fwd_bwd


def make_eval_loss(cfg: BertConfig):
    """(params…, batch) → (loss,) — forward only, for held-out eval."""

    def eval_loss(params, tokens, mlm_pos, mlm_ids, mlm_weights):
        return (forward_mlm_loss(params, tokens, mlm_pos, mlm_ids,
                                 mlm_weights, cfg),)

    return eval_loss
