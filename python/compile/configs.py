"""Model-size presets shared between the python compile path and the rust
runtime (via ``<config>.meta.json``).

The paper pretrains BERT-Large (L=24, H=1024).  We expose the whole family so
that laptop-scale experiments (tiny/mini/small) and the cluster time model
(base/large) read the same dimension table.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class BertConfig:
    name: str
    num_layers: int
    hidden: int
    num_heads: int
    intermediate: int
    vocab_size: int
    max_seq_len: int
    type_vocab: int = 2
    layernorm_eps: float = 1e-12

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.num_heads == 0
        return self.hidden // self.num_heads

    def param_count(self) -> int:
        """Total parameter count (matches ``param_specs``)."""
        return sum(int_prod(shape) for _, shape in param_specs(self))

    def to_dict(self) -> dict:
        return asdict(self)


def int_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# Vocab sizes for tiny/mini/small are synthetic-corpus vocabularies; base and
# large use the true BERT WordPiece vocab size so FLOP/byte counts used by the
# rust cluster time model are faithful to the paper's workload.
PRESETS = {
    "bert-tiny": BertConfig("bert-tiny", 2, 128, 2, 512, 2048, 128),
    "bert-mini": BertConfig("bert-mini", 4, 256, 4, 1024, 8192, 128),
    "bert-small": BertConfig("bert-small", 6, 512, 8, 2048, 8192, 128),
    "bert-base": BertConfig("bert-base", 12, 768, 12, 3072, 30522, 512),
    "bert-large": BertConfig("bert-large", 24, 1024, 16, 4096, 30522, 512),
}


def get_config(name: str) -> BertConfig:
    if name not in PRESETS:
        raise KeyError(f"unknown config {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]


def param_specs(cfg: BertConfig):
    """Ordered list of (name, shape) for every parameter tensor.

    The order defined here is THE canonical parameter order: jax flattens the
    model params in this order when lowering, meta.json records it, and the
    rust runtime marshals literals in the same order.  Each tensor is one
    LAMB/LANS *block* (the paper's G_b).
    """
    specs = [
        ("embeddings/word", (cfg.vocab_size, cfg.hidden)),
        ("embeddings/position", (cfg.max_seq_len, cfg.hidden)),
        ("embeddings/ln_scale", (cfg.hidden,)),
        ("embeddings/ln_bias", (cfg.hidden,)),
    ]
    for i in range(cfg.num_layers):
        p = f"encoder/layer_{i}"
        specs += [
            (f"{p}/attn/q_kernel", (cfg.hidden, cfg.hidden)),
            (f"{p}/attn/q_bias", (cfg.hidden,)),
            (f"{p}/attn/k_kernel", (cfg.hidden, cfg.hidden)),
            (f"{p}/attn/k_bias", (cfg.hidden,)),
            (f"{p}/attn/v_kernel", (cfg.hidden, cfg.hidden)),
            (f"{p}/attn/v_bias", (cfg.hidden,)),
            (f"{p}/attn/out_kernel", (cfg.hidden, cfg.hidden)),
            (f"{p}/attn/out_bias", (cfg.hidden,)),
            (f"{p}/attn/ln_scale", (cfg.hidden,)),
            (f"{p}/attn/ln_bias", (cfg.hidden,)),
            (f"{p}/ffn/in_kernel", (cfg.hidden, cfg.intermediate)),
            (f"{p}/ffn/in_bias", (cfg.intermediate,)),
            (f"{p}/ffn/out_kernel", (cfg.intermediate, cfg.hidden)),
            (f"{p}/ffn/out_bias", (cfg.hidden,)),
            (f"{p}/ffn/ln_scale", (cfg.hidden,)),
            (f"{p}/ffn/ln_bias", (cfg.hidden,)),
        ]
    specs += [
        ("mlm/transform_kernel", (cfg.hidden, cfg.hidden)),
        ("mlm/transform_bias", (cfg.hidden,)),
        ("mlm/ln_scale", (cfg.hidden,)),
        ("mlm/ln_bias", (cfg.hidden,)),
        ("mlm/output_bias", (cfg.vocab_size,)),
    ]
    return specs


# Blocks that are excluded from weight decay (λ=0) in BERT convention:
# biases and LayerNorm parameters.  The paper's apex implementation follows
# the same convention.
def decay_mask(name: str) -> bool:
    return not (name.endswith("_bias") or "ln_scale" in name or "ln_bias" in name)
