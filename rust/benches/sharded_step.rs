//! Sharded-optimizer bench: per-worker LANS update time vs worker count at
//! bert-base scale (≈110M params), next to the replicated serial baseline,
//! the modeled reduce-scatter/all-gather communication cost on the paper's
//! EFA testbed, and the pipelined step (reduce-scatter buffers handed
//! straight to the optimizer, stitch fused with the grad² phase) against
//! the two-stage scatter-then-step path it replaces.
//!
//! The point of the subsystem (ZeRO-1, Lin et al. 2020): per-worker update
//! compute and moment memory both shrink by W× at *identical arithmetic* —
//! the sharded trajectory is bit-identical to the replicated one
//! (property-tested; spot-checked again here).
//!
//! `--quick` (CI smoke): fewer reps, trimmed W sweep, same assertions.
//! Numbers land in `BENCH_sharded_step.json`.

use lans::collective::cost::{all_gather_time_s, reduce_scatter_time_s, CommSpec};
use lans::collective::ring_reduce_scatter;
use lans::optim::{
    make_optimizer, scatter_to_plan, BlockTable, Hyper, Optimizer, ShardedOptimizer,
};
use lans::util::bench::{bench, quick_mode, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("sharded_step");
    let table = BlockTable::bert_base();
    let n = table.total;
    let mut rng = Rng::new(1);
    let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.02).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let bytes = n as f64 * 4.0;

    println!(
        "=== sharded LANS step, bert-base scale ({:.1}M params{}) ===\n",
        n as f64 / 1e6,
        if quick { ", --quick" } else { "" }
    );

    // replicated serial baseline (scoped so its 4n of state frees early)
    let (warmup, reps) = if quick { (1, 2) } else { (1, 5) };
    let r_rep = {
        let mut rep_opt = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut xr = x0.clone();
        bench("replicated serial", warmup, reps, || {
            rep_opt.step(std::hint::black_box(&mut xr), &g, 0.001);
        })
    };
    println!("replicated serial LANS step: {:.2} ms\n", r_rep.mean_ms());
    rep.result(&r_rep);

    // correctness spot-check: one sharded step must reproduce the
    // replicated bits exactly
    {
        let mut a = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut so = ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), 4)
            .unwrap();
        let mut xa = x0.clone();
        let mut xb = x0.clone();
        a.step(&mut xa, &g, 0.001);
        let sg = so.plan().split(&g);
        so.step(&mut xb, &sg, 0.001);
        assert_eq!(xa, xb, "sharded step is not bit-identical to replicated");
    }

    let mut t = Table::new(&[
        "W",
        "per-worker ms",
        "vs replicated",
        "moments MB/worker",
        "modeled RS+AG (EFA)",
    ]);
    let w_sweep: &[usize] = if quick { &[1, 2, 4, 8] } else { &[1, 2, 4, 8, 16] };
    let mut per_worker = Vec::new();
    for &w in w_sweep {
        let mut so =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), w).unwrap();
        let shard_grads = so.plan().split(&g);
        let mut x = x0.clone();
        // warm-up, then average the slowest shard's wall time over reps —
        // what one worker of a W-wide deployment would spend updating
        so.step_timed(&mut x, &shard_grads, 0.001);
        let mut worst_sum = 0.0f64;
        for _ in 0..reps {
            let (_, secs) = so.step_timed(std::hint::black_box(&mut x), &shard_grads, 0.001);
            worst_sum += secs.iter().copied().fold(0.0f64, f64::max);
        }
        let ms = worst_sum / reps as f64 * 1e3;
        per_worker.push((w, ms));
        let max_shard = (0..w).map(|s| so.plan().len_of(s)).max().unwrap_or(0);
        let comm_ms = (reduce_scatter_time_s(w, bytes, CommSpec::efa())
            + all_gather_time_s(w, bytes, CommSpec::efa()))
            * 1e3;
        t.row(&[
            w.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", r_rep.mean_ms() / ms),
            format!("{:.1}", 2.0 * max_shard as f64 * 4.0 / 1e6),
            format!("{comm_ms:.1} ms"),
        ]);
        rep.metric(&format!("per_worker_ms_w{w}"), ms);
    }
    t.print();
    println!(
        "\n(per-worker ms = slowest shard's update wall time; moments = m+v \
         for the largest shard.  The modeled RS+AG column is the α-β cost \
         of the gradient reduce-scatter + parameter all-gather on 100 Gb/s \
         EFA — what replaces the allreduce on the wire.)"
    );

    // ---- pipelined step: fused stitch + phase A vs scatter-then-step ----
    // both paths start from the same reduce-scattered buffers; the fused
    // path parallelizes the owned-range stitch across the pool and folds
    // the grad² partials while the stitched chunks are cache-hot, instead
    // of a serial full-vector scatter_to_plan on the caller followed by a
    // separate phase-A region.
    let avail = ThreadPool::available();
    let w = 4usize;
    let pool = ThreadPool::new(avail);
    println!(
        "\n=== pipelined sharded step (W={w}, pool={avail} threads): \
         scatter-then-step vs fused step_scattered ===\n"
    );
    let bufs: Vec<Vec<f32>> = {
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        ring_reduce_scatter(&mut bufs);
        bufs
    };
    let scale = 1.0 / w as f32;

    let (r_old, x_old) = {
        let mut so_old =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), w).unwrap();
        let mut x_old = x0.clone();
        let r = bench("scatter_to_plan + step_pooled", warmup, reps, || {
            let sg = scatter_to_plan(&bufs, so_old.plan(), scale);
            so_old.step_pooled(&pool, std::hint::black_box(&mut x_old), &sg, 0.001);
        });
        (r, x_old)
    };
    let (r_new, x_new) = {
        let mut so_new =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), w).unwrap();
        let mut x_new = x0.clone();
        let r = bench("fused step_scattered", warmup, reps, || {
            so_new.step_scattered(&pool, std::hint::black_box(&mut x_new), &bufs, scale, 0.001);
        });
        (r, x_new)
    };
    rep.result(&r_old);
    rep.result(&r_new);
    rep.metric("pipelined_old_ms", r_old.mean_ms());
    rep.metric("pipelined_new_ms", r_new.mean_ms());
    println!(
        "scatter-then-step: {:.2} ms   fused step_scattered: {:.2} ms   ({:.2}x)",
        r_old.mean_ms(),
        r_new.mean_ms(),
        r_old.mean_ns / r_new.mean_ns
    );
    // and the bits must agree — the two paths drove identical updates
    assert_eq!(x_old, x_new, "pipelined step diverged from scatter-then-step");

    // persist numbers before the acceptance assertions
    rep.write().expect("writing BENCH_sharded_step.json");

    // acceptance: per-worker update time decreases monotonically in W
    for pair in per_worker.windows(2) {
        let ((w0, t0), (w1, t1)) = (pair[0], pair[1]);
        assert!(
            t1 <= t0 * 1.10,
            "per-worker time must not grow: W={w0} -> {t0:.2} ms, W={w1} -> {t1:.2} ms"
        );
    }
    let (first, last) = (per_worker[0].1, per_worker.last().unwrap().1);
    assert!(
        last < first * 0.5,
        "W={} per-worker time ({last:.2} ms) should be well under half of W=1 ({first:.2} ms)",
        per_worker.last().unwrap().0
    );
    println!(
        "\nper-worker update time W=1 -> W={}: {first:.2} ms -> {last:.2} ms \
         ({:.1}x) — the W-fold optimizer-compute cut the sharded subsystem buys",
        per_worker.last().unwrap().0,
        first / last
    );

    // acceptance: the fused path must not lose to the two-stage path it
    // replaces (it strictly removes a serial stitch pass and a region)
    if avail >= 2 {
        assert!(
            r_new.mean_ns < r_old.mean_ns * 1.05,
            "fused step_scattered ({:.2} ms) must not lose to scatter-then-step ({:.2} ms)",
            r_new.mean_ms(),
            r_old.mean_ms()
        );
    }
}
