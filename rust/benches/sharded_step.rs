//! Sharded-optimizer bench: per-worker LANS update time vs worker count at
//! bert-base scale (≈110M params), next to the replicated serial baseline,
//! plus the modeled reduce-scatter/all-gather communication cost on the
//! paper's EFA testbed.
//!
//! The point of the subsystem (ZeRO-1, Lin et al. 2020): per-worker update
//! compute and moment memory both shrink by W× at *identical arithmetic* —
//! the sharded trajectory is bit-identical to the replicated one
//! (property-tested; spot-checked again here).

use lans::collective::cost::{all_gather_time_s, reduce_scatter_time_s, CommSpec};
use lans::optim::{make_optimizer, BlockTable, Hyper, Optimizer, ShardedOptimizer};
use lans::util::bench::{bench, Table};
use lans::util::rng::Rng;

fn main() {
    let table = BlockTable::bert_base();
    let n = table.total;
    let mut rng = Rng::new(1);
    let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.02).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let bytes = n as f64 * 4.0;

    println!(
        "=== sharded LANS step, bert-base scale ({:.1}M params) ===\n",
        n as f64 / 1e6
    );

    // replicated serial baseline
    let mut rep = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
    let mut xr = x0.clone();
    let r_rep = bench("replicated serial", 1, 5, || {
        rep.step(std::hint::black_box(&mut xr), &g, 0.001);
    });
    println!("replicated serial LANS step: {:.2} ms\n", r_rep.mean_ms());

    // correctness spot-check: one sharded step must reproduce the
    // replicated bits exactly
    {
        let mut a = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut so = ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), 4)
            .unwrap();
        let mut xa = x0.clone();
        let mut xb = x0.clone();
        a.step(&mut xa, &g, 0.001);
        let sg = so.plan().split(&g);
        so.step(&mut xb, &sg, 0.001);
        assert_eq!(xa, xb, "sharded step is not bit-identical to replicated");
    }

    let mut t = Table::new(&[
        "W",
        "per-worker ms",
        "vs replicated",
        "moments MB/worker",
        "modeled RS+AG (EFA)",
    ]);
    let mut per_worker = Vec::new();
    for w in [1usize, 2, 4, 8, 16] {
        let mut so =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), w).unwrap();
        let shard_grads = so.plan().split(&g);
        let mut x = x0.clone();
        // warm-up, then average the slowest shard's wall time over reps —
        // what one worker of a W-wide deployment would spend updating
        so.step_timed(&mut x, &shard_grads, 0.001);
        let reps = 5;
        let mut worst_sum = 0.0f64;
        for _ in 0..reps {
            let (_, secs) = so.step_timed(std::hint::black_box(&mut x), &shard_grads, 0.001);
            worst_sum += secs.iter().copied().fold(0.0f64, f64::max);
        }
        let ms = worst_sum / reps as f64 * 1e3;
        per_worker.push((w, ms));
        let max_shard = (0..w).map(|s| so.plan().len_of(s)).max().unwrap_or(0);
        let comm_ms = (reduce_scatter_time_s(w, bytes, CommSpec::efa())
            + all_gather_time_s(w, bytes, CommSpec::efa()))
            * 1e3;
        t.row(&[
            w.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", r_rep.mean_ms() / ms),
            format!("{:.1}", 2.0 * max_shard as f64 * 4.0 / 1e6),
            format!("{comm_ms:.1} ms"),
        ]);
    }
    t.print();
    println!(
        "\n(per-worker ms = slowest shard's update wall time; moments = m+v \
         for the largest shard.  The modeled RS+AG column is the α-β cost \
         of the gradient reduce-scatter + parameter all-gather on 100 Gb/s \
         EFA — what replaces the allreduce on the wire.)"
    );

    // acceptance: per-worker update time decreases monotonically in W
    for pair in per_worker.windows(2) {
        let ((w0, t0), (w1, t1)) = (pair[0], pair[1]);
        assert!(
            t1 <= t0 * 1.10,
            "per-worker time must not grow: W={w0} -> {t0:.2} ms, W={w1} -> {t1:.2} ms"
        );
    }
    let (first, last) = (per_worker[0].1, per_worker.last().unwrap().1);
    assert!(
        last < first * 0.5,
        "W=16 per-worker time ({last:.2} ms) should be well under half of W=1 ({first:.2} ms)"
    );
    println!(
        "\nper-worker update time W=1 -> W=16: {first:.2} ms -> {last:.2} ms \
         ({:.1}x) — the W-fold optimizer-compute cut the sharded subsystem buys",
        first / last
    );
}
