//! Optimizer-step bench: native (rust) update throughput per algorithm at
//! BERT sizes, the persistent-pool-vs-per-call-spawn comparison, the
//! plan-granularity-vs-block-granularity executor sweep, the HLO (Pallas)
//! step for bert-tiny, and the fused-vs-unfused HBM-traffic model that
//! translates apex fused_lans's claim to TPU terms (DESIGN.md
//! §Hardware-Adaptation).
//!
//! `--quick` (CI smoke): fewer iterations and a trimmed thread sweep, but
//! the same acceptance assertions.  Numbers land in
//! `BENCH_optimizer_step.json` via the shared `util::bench::Reporter`.

use std::path::PathBuf;

use lans::optim::{
    lans_step_on_plan, make_optimizer, BlockTable, Hyper, Lans, Optimizer, ParallelExecutor,
    ShardPlan,
};
use lans::runtime::{Engine, ModelRuntime};
use lans::simd::{self, AdamK};
use lans::util::bench::{bench, quick_mode, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 10) };
    let mut rep = Reporter::new("optimizer_step");

    // bert-base-shaped block table (≈110M params) without needing artifacts
    let table = BlockTable::bert_base();
    let n = table.total;
    println!(
        "=== native optimizer step, bert-base scale ({:.1}M params{}) ===\n",
        n as f64 / 1e6,
        if quick { ", --quick" } else { "" }
    );
    let mut rng = Rng::new(1);
    let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.02).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

    let mut t = Table::new(&["optimizer", "ms/step", "Mparam/s", "GB/s (7 arrays)"]);
    for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd", "nag"] {
        let mut opt = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
        let mut x = x0.clone();
        let r = bench(&format!("serial/{name}"), warmup, iters, || {
            opt.step(std::hint::black_box(&mut x), &g, 0.001);
        });
        // LANS/LAMB/AdamW touch x,m,v,g reads + x,m,v writes = 7 arrays
        let bytes = 7.0 * n as f64 * 4.0;
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.mean_ms()),
            format!("{:.1}", n as f64 / (r.mean_ns * 1e-9) / 1e6),
            format!("{:.2}", bytes / (r.mean_ns * 1e-9) / 1e9),
        ]);
        rep.result(&r);
    }
    t.print();

    // ---- SIMD vs portable-scalar segment sweeps --------------------------
    // Direct kernel calls at production segment granularity (NORM_SEG=4096
    // chunks), dispatched backend vs the canonical portable module in the
    // same process.  Speedup floors for these ratios are gated in
    // BENCH_baseline/BENCH_optimizer_step.json, guarded by `simd_active`
    // (same convention as the conversion kernels in
    // BENCH_baseline/BENCH_mixed_precision.json): on a scalar-dispatch
    // machine the floors are skipped instead of failing vacuously.
    let backend = simd::backend();
    rep.metric(
        "simd_active",
        if backend == simd::Backend::Scalar { 0.0 } else { 1.0 },
    );
    println!(
        "\n=== SIMD vs scalar segment sweeps (dispatch backend: {}) ===\n",
        backend.name()
    );
    let n_sweep = if quick { 1 << 18 } else { 1 << 22 };
    const SEG: usize = 4096;
    let gs: Vec<f32> = (0..n_sweep).map(|_| rng.normal_f32()).collect();
    let mut ts = Table::new(&["kernel", "simd GB/s", "scalar GB/s", "speedup"]);
    let mut sweep = |rep: &mut Reporter,
                     ts: &mut Table,
                     name: &str,
                     key: &str,
                     bytes_per_elem: f64,
                     run: &mut dyn FnMut(bool)| {
        let rs = bench(&format!("{name} (simd)"), 1, iters, || run(true));
        let rp = bench(&format!("{name} (scalar)"), 1, iters, || run(false));
        let gbs = |r: &lans::util::bench::BenchResult| {
            bytes_per_elem * n_sweep as f64 / (r.mean_ns * 1e-9) / 1e9
        };
        let ratio = rp.mean_ns / rs.mean_ns;
        ts.row(&[
            name.into(),
            format!("{:.2}", gbs(&rs)),
            format!("{:.2}", gbs(&rp)),
            format!("{ratio:.2}x"),
        ]);
        rep.metric(key, ratio);
        rep.result(&rs);
        rep.result(&rp);
    };
    sweep(&mut rep, &mut ts, "grad_sq (per-seg)", "grad_sq_speedup", 4.0, &mut |s| {
        let f: fn(&[f32]) -> f64 = if s { simd::sum_sq } else { simd::portable::sum_sq };
        let mut acc = 0.0f64;
        for c in std::hint::black_box(&gs[..]).chunks(SEG) {
            acc += f(c);
        }
        std::hint::black_box(acc);
    });
    let mut gu = gs.clone();
    sweep(&mut rep, &mut ts, "unscale+grad_sq", "unscale_grad_sq_speedup", 8.0, &mut |s| {
        let f: fn(&mut [f32], f32) -> f64 =
            if s { simd::unscale_sum_sq } else { simd::portable::unscale_sum_sq };
        let mut acc = 0.0f64;
        for c in std::hint::black_box(&mut gu[..]).chunks_mut(SEG) {
            acc += f(c, 1.0); // inv_scale = 1 keeps the buffer fixed across iters
        }
        std::hint::black_box(acc);
    });
    let k = AdamK {
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-6,
        inv_bc1: 1.0,
        inv_bc2: 1.0,
        lr: 1e-3,
        wd: 0.01,
        inv_gnorm: 1.0,
    };
    let xb = &x0[..n_sweep];
    let (mut m, mut v) = (vec![0.0f32; n_sweep], vec![0.0f32; n_sweep]);
    let (mut rf, mut cf) = (vec![0.0f32; n_sweep], vec![0.0f32; n_sweep]);
    // x,g,m,v read + m,v,rf,cf written = 8 arrays
    sweep(&mut rep, &mut ts, "lans moment sweep", "lans_sweep_speedup", 32.0, &mut |s| {
        type LansFn = fn(
            &AdamK,
            &[f32],
            &[f32],
            &mut [f32],
            &mut [f32],
            &mut [f32],
            &mut [f32],
        ) -> (f64, f64, f64);
        let f: LansFn = if s { simd::lans_segment } else { simd::portable::lans_segment };
        let mut acc = (0.0f64, 0.0f64, 0.0f64);
        let mut lo = 0usize;
        while lo < n_sweep {
            let hi = (lo + SEG).min(n_sweep);
            let (a, b, c) = f(
                &k,
                std::hint::black_box(&xb[lo..hi]),
                &gs[lo..hi],
                &mut m[lo..hi],
                &mut v[lo..hi],
                &mut rf[lo..hi],
                &mut cf[lo..hi],
            );
            acc.0 += a;
            acc.1 += b;
            acc.2 += c;
            lo = hi;
        }
        std::hint::black_box(acc);
    });
    ts.print();

    // thread sweep shared by the sections below
    let avail = ThreadPool::available();
    let mut thread_counts = if quick {
        // trimmed sweep, but keep 8 whenever the machine has it so the
        // plan-vs-block ceiling assertion (which needs >= 8 threads)
        // actually executes in CI smoke mode
        let mut v = vec![1usize, 2, avail.min(4)];
        if avail >= 8 {
            v.push(8);
        }
        v
    } else {
        let mut v = vec![1usize, 2, 4, 8];
        if !v.contains(&avail) {
            v.push(avail);
        }
        v
    };
    thread_counts.sort_unstable();
    thread_counts.dedup();
    rep.metric("threads_max_swept", *thread_counts.last().unwrap() as f64);

    // ---- serial vs plan-parallel (ParallelExecutor) sweep ----
    println!(
        "\n=== serial vs plan-parallel step (ParallelExecutor, {avail} cores available) ===\n"
    );
    let mut t_par = Table::new(&["optimizer", "threads", "ms/step", "speedup vs serial"]);
    for name in ["lans", "lamb", "adamw"] {
        let mut serial_ms = f64::NAN;
        for &nt in &thread_counts {
            let exec = ParallelExecutor::new(nt);
            let mut opt = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut x = x0.clone();
            let r = bench(&format!("plan/{name}/t{nt}"), warmup, iters, || {
                exec.step(opt.as_mut(), std::hint::black_box(&mut x), &g, 0.001);
            });
            if nt == 1 {
                serial_ms = r.mean_ms();
            }
            t_par.row(&[
                name.to_string(),
                nt.to_string(),
                format!("{:.2}", r.mean_ms()),
                format!("{:.2}x", serial_ms / r.mean_ms()),
            ]);
            rep.result(&r);
        }
    }
    t_par.print();
    println!(
        "\n(threads=1 is the exact serial path; the parallel path cuts the \
         flat vector on the balanced NORM_SEG plan grid and must win from \
         4 threads up at bert-base scale — asserted below)"
    );

    // ---- persistent pool vs per-call spawn ----
    // (a) region-overhead microbench: many small regions, the shape of the
    // ring collective's 2(W-1) steps and of small-model optimizer phases.
    // This is where per-call thread spawn burns its time, and what the
    // persistent pool (two sync points per region) removes.
    println!("\n=== persistent pool vs per-call spawn ===\n");
    let mut t_pool = Table::new(&[
        "threads",
        "µs/region (persistent)",
        "µs/region (spawn)",
        "spawn/persistent",
        "lans ms/step (persistent)",
        "lans ms/step (spawn)",
    ]);
    let mut region_pairs: Vec<(usize, f64, f64)> = Vec::new();
    let regions_per_iter = if quick { 20 } else { 100 };
    for &nt in thread_counts.iter().filter(|&&nt| nt >= 2) {
        let chunk = 4096usize; // POOLED_MIN_ELEMS-sized work items
        let mut data = vec![1.0f32; chunk * 16];
        let persistent = ThreadPool::new(nt);
        let spawning = ThreadPool::new_spawning(nt);
        let mut measure = |pool: &ThreadPool, tag: &str| {
            let r = bench(&format!("region/{tag}/t{nt}"), 1, if quick { 3 } else { 5 }, || {
                for _ in 0..regions_per_iter {
                    let mut chunks: Vec<&mut [f32]> = data.chunks_mut(chunk).collect();
                    let sums = pool.map_mut(&mut chunks, |c| {
                        c.iter().map(|&x| x as f64).sum::<f64>()
                    });
                    std::hint::black_box(sums);
                }
            });
            rep.result(&r);
            r.mean_ns / 1e3 / regions_per_iter as f64 // µs per region
        };
        let us_persistent = measure(&persistent, "persistent");
        let us_spawn = measure(&spawning, "spawn");
        region_pairs.push((nt, us_persistent, us_spawn));

        // (b) the full LANS step end-to-end on both pools (informational:
        // at 110M params the compute dwarfs region overhead; the margin
        // shows up at laptop scale and in the collectives)
        let step_ms = |pool: &ThreadPool, tag: &str, rep: &mut Reporter| {
            let mut opt = Lans::new(table.clone(), Hyper::default());
            let mut x = x0.clone();
            let plan = ShardPlan::build(&table, lans::util::pool::policy::plan_chunks(nt));
            let r = bench(&format!("lans_step/{tag}/t{nt}"), warmup, iters, || {
                lans_step_on_plan(
                    &mut opt,
                    pool,
                    &plan,
                    std::hint::black_box(&mut x),
                    &g,
                    0.001,
                );
            });
            rep.result(&r);
            r.mean_ms()
        };
        let ms_persistent = step_ms(&persistent, "persistent", &mut rep);
        let ms_spawn = step_ms(&spawning, "spawn", &mut rep);
        t_pool.row(&[
            nt.to_string(),
            format!("{us_persistent:.1}"),
            format!("{us_spawn:.1}"),
            format!("{:.1}x", us_spawn / us_persistent),
            format!("{ms_persistent:.2}"),
            format!("{ms_spawn:.2}"),
        ]);
        rep.metric(&format!("region_us_persistent_t{nt}"), us_persistent);
        rep.metric(&format!("region_us_spawn_t{nt}"), us_spawn);
        rep.metric(&format!("lans_step_ms_persistent_t{nt}"), ms_persistent);
        rep.metric(&format!("lans_step_ms_spawn_t{nt}"), ms_spawn);
    }
    t_pool.print();

    // ---- plan granularity vs the old block granularity ----
    // block granularity is capped by the largest block (the word
    // embedding, ~20% of params ⇒ ≈5x no matter the thread count); the
    // balanced plan has no such ceiling.
    let largest = table.blocks.iter().map(|b| b.len).max().unwrap();
    let ceiling = n as f64 / largest as f64;
    println!(
        "\n=== plan vs block granularity (largest block {:.1}M ⇒ block-path ceiling {:.2}x) ===\n",
        largest as f64 / 1e6,
        ceiling
    );
    let mut t_gran = Table::new(&[
        "threads",
        "ms/step (block grid)",
        "ms/step (plan grid)",
        "plan speedup vs block",
    ]);
    let mut gran_pairs: Vec<(usize, f64, f64)> = Vec::new();
    for &nt in thread_counts.iter().filter(|&&nt| nt >= 2) {
        let pool = ThreadPool::new(nt);
        let run = |plan: &ShardPlan, tag: &str, rep: &mut Reporter| {
            let mut opt = Lans::new(table.clone(), Hyper::default());
            let mut x = x0.clone();
            let r = bench(&format!("grid/{tag}/t{nt}"), warmup, iters, || {
                lans_step_on_plan(&mut opt, &pool, plan, std::hint::black_box(&mut x), &g, 0.001);
            });
            rep.result(&r);
            r.mean_ms()
        };
        let block_plan = ShardPlan::per_block(&table);
        let balanced = ShardPlan::build(&table, lans::util::pool::policy::plan_chunks(nt));
        let ms_block = run(&block_plan, "block", &mut rep);
        let ms_plan = run(&balanced, "plan", &mut rep);
        gran_pairs.push((nt, ms_block, ms_plan));
        t_gran.row(&[
            nt.to_string(),
            format!("{ms_block:.2}"),
            format!("{ms_plan:.2}"),
            format!("{:.2}x", ms_block / ms_plan),
        ]);
        rep.metric(&format!("grid_ms_block_t{nt}"), ms_block);
        rep.metric(&format!("grid_ms_plan_t{nt}"), ms_plan);
    }
    t_gran.print();

    if !quick {
        hbm_traffic_model();
        hlo_section(&table);
    }

    // persist numbers before any acceptance assertion can abort the run
    rep.write().expect("writing BENCH_optimizer_step.json");

    // ---- acceptance assertions ----
    // 1. the persistent pool beats the per-call-spawn baseline at every
    //    thread count >= 2 (region overhead is the thing it exists to kill)
    for &(nt, us_persistent, us_spawn) in &region_pairs {
        if nt > avail {
            println!(
                "[persistent-vs-spawn assertion skipped at {nt} threads: only {avail} cores]"
            );
            continue;
        }
        assert!(
            us_persistent < us_spawn,
            "persistent pool ({us_persistent:.1} µs/region) must beat per-call spawn \
             ({us_spawn:.1} µs/region) at {nt} threads"
        );
        println!(
            "persistent pool beats per-call spawn at {nt} threads: \
             {us_persistent:.1} vs {us_spawn:.1} µs/region ({:.1}x)",
            us_spawn / us_persistent
        );
    }

    // 2. serial vs parallel: the plan path must win from 4 threads up
    if avail >= 4 {
        let mut opt_s = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut xs = x0.clone();
        let r_s = bench("lans serial (accept)", warmup, iters, || {
            opt_s.step(std::hint::black_box(&mut xs), &g, 0.001);
        });
        let exec4 = ParallelExecutor::new(4);
        let mut opt_p = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut xp = x0.clone();
        let r_p = bench("lans parallel (accept)", warmup, iters, || {
            exec4.step(opt_p.as_mut(), std::hint::black_box(&mut xp), &g, 0.001);
        });
        println!(
            "\nLANS bert-base step: serial {:.2} ms -> parallel(4 threads) {:.2} ms ({:.2}x)",
            r_s.mean_ms(),
            r_p.mean_ms(),
            r_s.mean_ns / r_p.mean_ns
        );
        assert!(
            r_p.mean_ns < r_s.mean_ns,
            "parallel LANS step ({:.2} ms) must beat serial ({:.2} ms) at >= 4 threads",
            r_p.mean_ms(),
            r_s.mean_ms()
        );
    } else {
        println!("\n[serial-vs-parallel assertion skipped: only {avail} cores available]");
    }

    // 3. the balanced plan grid breaks the block-granularity ceiling: at
    //    >= 8 threads the plan path must beat the block path (whose
    //    speedup is capped at ~{ceiling:.1}x by the embedding block)
    for &(nt, ms_block, ms_plan) in &gran_pairs {
        if nt < 8 || nt > avail {
            continue;
        }
        assert!(
            ms_plan < ms_block,
            "plan grid ({ms_plan:.2} ms) must beat the block grid ({ms_block:.2} ms) \
             at {nt} threads — the embedding block must no longer be the critical path"
        );
        println!(
            "plan grid beats block grid at {nt} threads: {ms_plan:.2} vs {ms_block:.2} ms"
        );
    }
    if avail < 8 {
        println!("[plan-vs-block >=8-thread assertion skipped: only {avail} cores]");
    }
}

fn hbm_traffic_model() {
    println!("\n=== fused-vs-unfused HBM traffic (the apex fused_lans claim, TPU terms) ===\n");
    // words moved per parameter per step (reads + writes):
    //   fused pallas LANS (3 passes, DESIGN.md): 9 reads + 3 writes = 12
    //   unfused elementwise graph: each of the ~14 intermediate ops
    //   reads ~2 and writes 1 full-size array ≈ 31 words (counted below)
    let fused = 12.0;
    let unfused_ops: &[(&str, f64, f64)] = &[
        ("g~ = g/||g||", 1.0, 1.0), // + reduce pass over g
        ("||g|| reduce", 1.0, 0.0),
        ("m' = b1 m + (1-b1) g~", 2.0, 1.0),
        ("v' = b2 v + (1-b2) g~^2", 2.0, 1.0),
        ("m^ = m'/(1-b1^t)", 1.0, 1.0),
        ("v^ = v'/(1-b2^t)", 1.0, 1.0),
        ("r = m^/(sqrt(v^)+eps)", 2.0, 1.0),
        ("c = g~/(sqrt(v^)+eps)", 2.0, 1.0),
        ("r+wd x / c+wd x", 4.0, 2.0),
        ("||x||,||r..||,||c..|| reduces", 3.0, 0.0),
        ("x' = x - a(r..) - b(c..)", 3.0, 1.0),
    ];
    let unfused: f64 = unfused_ops.iter().map(|(_, r, w)| r + w).sum();
    let mut t2 = Table::new(&["variant", "words/param/step", "traffic ratio"]);
    t2.row(&["unfused elementwise".into(), format!("{unfused:.0}"), "1.00".into()]);
    t2.row(&[
        "fused pallas (3-pass)".into(),
        format!("{fused:.0}"),
        format!("{:.2}", fused / unfused),
    ]);
    t2.print();
    println!(
        "\nfusion cuts optimizer HBM traffic {:.1}x — on a bandwidth-bound \
         VPU pass this is the speedup apex's fused_lans gets from \
         multi-tensor-apply on V100.",
        unfused / fused
    );
}

fn hlo_section(_table: &BlockTable) {
    // HLO (Pallas) optimizer step on the real artifact, if built
    let meta = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        println!("\n[skipped HLO step bench — run `make artifacts`]");
        return;
    }
    println!("\n=== AOT Pallas optimizer step (bert-tiny artifact, PJRT CPU) ===\n");
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let tiny_table = BlockTable::from_meta(&rt.meta);
    let mut t3 = Table::new(&["optimizer", "ms/step (HLO)", "ms/step (native)"]);
    for name in ["lans", "lamb", "adamw"] {
        rt.load_optimizer(name).unwrap();
        let mut params = rt.init_params(3);
        let mut state = rt.zero_opt_state();
        let grads: Vec<_> = rt
            .meta
            .params
            .iter()
            .map(|p| {
                let mut rr = Rng::new(p.size as u64);
                lans::runtime::TensorF32::new(
                    p.shape.clone(),
                    (0..p.size).map(|_| rr.normal_f32()).collect(),
                )
            })
            .collect();
        let r_hlo = bench(name, 1, 5, || {
            rt.opt_step(name, &mut params, &mut state, &grads, 0.001).unwrap();
        });
        let mut opt = make_optimizer(name, tiny_table.clone(), Hyper::default()).unwrap();
        let mut flat = tiny_table.flatten(&params);
        let gflat = tiny_table.flatten(&grads);
        let r_nat = bench(name, 1, 5, || {
            opt.step(std::hint::black_box(&mut flat), &gflat, 0.001);
        });
        t3.row(&[
            name.to_string(),
            format!("{:.2}", r_hlo.mean_ms()),
            format!("{:.2}", r_nat.mean_ms()),
        ]);
    }
    t3.print();
    println!(
        "\n(the HLO column includes literal marshalling through the device \
         thread; interpret-mode Pallas on CPU is a correctness vehicle, \
         not a TPU perf proxy — see DESIGN.md §Perf)"
    );
}
