//! Optimizer-step bench: native (rust) update throughput per algorithm at
//! BERT sizes, the HLO (Pallas) step for bert-tiny, and the fused-vs-unfused
//! HBM-traffic model that translates apex fused_lans's claim to TPU terms
//! (DESIGN.md §Hardware-Adaptation).

use std::path::PathBuf;

use lans::optim::{make_optimizer, BlockTable, Hyper, Optimizer, ParallelExecutor};
use lans::runtime::{Engine, ModelRuntime};
use lans::util::bench::{bench, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    // bert-base-shaped block table (≈110M params) without needing artifacts
    let table = BlockTable::bert_base();
    let n = table.total;
    println!(
        "=== native optimizer step, bert-base scale ({:.1}M params) ===\n",
        n as f64 / 1e6
    );
    let mut rng = Rng::new(1);
    let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.02).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

    let mut t = Table::new(&["optimizer", "ms/step", "Mparam/s", "GB/s (7 arrays)"]);
    for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd", "nag"] {
        let mut opt = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
        let mut x = x0.clone();
        let r = bench(name, 2, 10, || {
            opt.step(std::hint::black_box(&mut x), &g, 0.001);
        });
        // LANS/LAMB/AdamW touch x,m,v,g reads + x,m,v writes = 7 arrays
        let bytes = 7.0 * n as f64 * 4.0;
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.mean_ms()),
            format!("{:.1}", n as f64 / (r.mean_ns * 1e-9) / 1e6),
            format!("{:.2}", bytes / (r.mean_ns * 1e-9) / 1e9),
        ]);
    }
    t.print();

    // ---- serial vs block-parallel (ParallelExecutor) sweep ----
    let avail = ThreadPool::available();
    let mut thread_counts = vec![1usize, 2, 4, 8];
    if !thread_counts.contains(&avail) {
        thread_counts.push(avail);
    }
    thread_counts.sort_unstable();
    thread_counts.dedup();
    println!(
        "\n=== serial vs block-parallel step (ParallelExecutor, {avail} cores available) ===\n"
    );
    let mut t_par = Table::new(&["optimizer", "threads", "ms/step", "speedup vs serial"]);
    for name in ["lans", "lamb", "adamw"] {
        let mut serial_ms = f64::NAN;
        for &nt in &thread_counts {
            let exec = ParallelExecutor::new(nt);
            let mut opt = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut x = x0.clone();
            let r = bench(&format!("{name} threads={nt}"), 2, 10, || {
                exec.step(opt.as_mut(), std::hint::black_box(&mut x), &g, 0.001);
            });
            if nt == 1 {
                serial_ms = r.mean_ms();
            }
            t_par.row(&[
                name.to_string(),
                nt.to_string(),
                format!("{:.2}", r.mean_ms()),
                format!("{:.2}x", serial_ms / r.mean_ms()),
            ]);
        }
    }
    t_par.print();
    println!(
        "\n(threads=1 is the exact serial path; the parallel path shards the \
         flat vector on BlockTable boundaries and must win from 4 threads up \
         at bert-base scale — asserted as an acceptance check below)"
    );
    {
        // acceptance check: parallel LANS beats serial at >= 4 threads
        let mut opt_s = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut xs = x0.clone();
        let r_s = bench("lans serial", 2, 10, || {
            opt_s.step(std::hint::black_box(&mut xs), &g, 0.001);
        });
        let exec4 = ParallelExecutor::new(4);
        let mut opt_p = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut xp = x0.clone();
        let r_p = bench("lans parallel", 2, 10, || {
            exec4.step(opt_p.as_mut(), std::hint::black_box(&mut xp), &g, 0.001);
        });
        println!(
            "\nLANS bert-base step: serial {:.2} ms -> parallel({} threads) {:.2} ms \
             ({:.2}x)",
            r_s.mean_ms(),
            exec4.threads(),
            r_p.mean_ms(),
            r_s.mean_ns / r_p.mean_ns
        );
        if avail >= 4 {
            assert!(
                r_p.mean_ns < r_s.mean_ns,
                "parallel LANS step ({:.2} ms) must beat serial ({:.2} ms) at >= 4 threads",
                r_p.mean_ms(),
                r_s.mean_ms()
            );
        } else {
            println!(
                "[speedup assertion skipped: only {avail} cores available, \
                 4 threads would oversubscribe]"
            );
        }
    }

    println!("\n=== fused-vs-unfused HBM traffic (the apex fused_lans claim, TPU terms) ===\n");
    // words moved per parameter per step (reads + writes):
    //   fused pallas LANS (3 passes, DESIGN.md): 9 reads + 3 writes = 12
    //   unfused elementwise graph: each of the ~14 intermediate ops
    //   reads ~2 and writes 1 full-size array ≈ 31 words (counted below)
    let fused = 12.0;
    let unfused_ops: &[(&str, f64, f64)] = &[
        ("g~ = g/||g||", 1.0, 1.0),       // + reduce pass over g
        ("||g|| reduce", 1.0, 0.0),
        ("m' = b1 m + (1-b1) g~", 2.0, 1.0),
        ("v' = b2 v + (1-b2) g~^2", 2.0, 1.0),
        ("m^ = m'/(1-b1^t)", 1.0, 1.0),
        ("v^ = v'/(1-b2^t)", 1.0, 1.0),
        ("r = m^/(sqrt(v^)+eps)", 2.0, 1.0),
        ("c = g~/(sqrt(v^)+eps)", 2.0, 1.0),
        ("r+wd x / c+wd x", 4.0, 2.0),
        ("||x||,||r..||,||c..|| reduces", 3.0, 0.0),
        ("x' = x - a(r..) - b(c..)", 3.0, 1.0),
    ];
    let unfused: f64 = unfused_ops.iter().map(|(_, r, w)| r + w).sum();
    let mut t2 = Table::new(&["variant", "words/param/step", "traffic ratio"]);
    t2.row(&["unfused elementwise".into(), format!("{unfused:.0}"), "1.00".into()]);
    t2.row(&[
        "fused pallas (3-pass)".into(),
        format!("{fused:.0}"),
        format!("{:.2}", fused / unfused),
    ]);
    t2.print();
    println!(
        "\nfusion cuts optimizer HBM traffic {:.1}x — on a bandwidth-bound \
         VPU pass this is the speedup apex's fused_lans gets from \
         multi-tensor-apply on V100.",
        unfused / fused
    );

    // HLO (Pallas) optimizer step on the real artifact, if built
    let meta = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/bert-tiny_s64_b4.meta.json");
    if meta.exists() {
        println!("\n=== AOT Pallas optimizer step (bert-tiny artifact, PJRT CPU) ===\n");
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(engine, &meta).unwrap();
        let tiny_table = BlockTable::from_meta(&rt.meta);
        let mut t3 = Table::new(&["optimizer", "ms/step (HLO)", "ms/step (native)"]);
        for name in ["lans", "lamb", "adamw"] {
            rt.load_optimizer(name).unwrap();
            let mut params = rt.init_params(3);
            let mut state = rt.zero_opt_state();
            let grads: Vec<_> = rt
                .meta
                .params
                .iter()
                .map(|p| {
                    let mut rr = Rng::new(p.size as u64);
                    lans::runtime::TensorF32::new(
                        p.shape.clone(),
                        (0..p.size).map(|_| rr.normal_f32()).collect(),
                    )
                })
                .collect();
            let r_hlo = bench(name, 1, 5, || {
                rt.opt_step(name, &mut params, &mut state, &grads, 0.001).unwrap();
            });
            let mut opt =
                make_optimizer(name, tiny_table.clone(), Hyper::default()).unwrap();
            let mut flat = tiny_table.flatten(&params);
            let gflat = tiny_table.flatten(&grads);
            let r_nat = bench(name, 1, 5, || {
                opt.step(std::hint::black_box(&mut flat), &gflat, 0.001);
            });
            t3.row(&[
                name.to_string(),
                format!("{:.2}", r_hlo.mean_ms()),
                format!("{:.2}", r_nat.mean_ms()),
            ]);
        }
        t3.print();
        println!(
            "\n(the HLO column includes literal marshalling through the device \
             thread; interpret-mode Pallas on CPU is a correctness vehicle, \
             not a TPU perf proxy — see DESIGN.md §Perf)"
        );
    } else {
        println!("\n[skipped HLO step bench — run `make artifacts`]");
    }
}
