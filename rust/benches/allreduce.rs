//! Collective bench: in-process ring-allreduce throughput across worker
//! counts and message sizes — serial, chunk-parallel on the persistent
//! pool, and chunk-parallel on the per-call-spawn baseline (every ring
//! step used to pay a spawn+join per worker; a W-worker allreduce issues
//! `2(W-1)` such regions) — against the α-β cost model's predictions for
//! the paper's real testbeds.
//!
//! `--quick` (CI smoke): fewer iterations and a trimmed sweep.  Numbers
//! land in `BENCH_allreduce.json`.

use lans::collective::cost::{
    allreduce_time_s, flat_gpu_ring_time_s, hierarchical_allreduce_time_s, CommSpec,
};
use lans::collective::{ring_allreduce, ring_allreduce_pooled};
use lans::util::bench::{bench, quick_mode, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("allreduce");
    let iters = if quick { 3 } else { 10 };
    let avail = ThreadPool::available();
    let pool = ThreadPool::new(avail);
    let spawn_pool = ThreadPool::new_spawning(avail);

    println!(
        "=== in-process ring allreduce (sum), pool width {avail}{} ===\n",
        if quick { ", --quick" } else { "" }
    );
    let mut t = Table::new(&[
        "workers",
        "floats",
        "serial ms",
        "pooled ms",
        "pooled (spawn) ms",
        "pool speedup",
        "GB/s (algo, pooled)",
    ]);
    let workers: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let sizes: &[usize] = if quick {
        &[1 << 16, 1 << 20]
    } else {
        &[1 << 16, 1 << 20, 1 << 22]
    };
    let mut pairs: Vec<(usize, usize, f64, f64)> = Vec::new();
    for &w in workers {
        for &n in sizes {
            let mut rng = Rng::new((w * n) as u64);
            let template: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut bufs = template.clone();
            let r_serial = bench(&format!("serial/w{w}/n{n}"), 2, iters, || {
                bufs.clone_from(&template);
                ring_allreduce(std::hint::black_box(&mut bufs));
            });
            let r_pooled = bench(&format!("pooled/w{w}/n{n}"), 2, iters, || {
                bufs.clone_from(&template);
                ring_allreduce_pooled(std::hint::black_box(&mut bufs), &pool);
            });
            let r_spawn = bench(&format!("pooled_spawn/w{w}/n{n}"), 2, iters, || {
                bufs.clone_from(&template);
                ring_allreduce_pooled(std::hint::black_box(&mut bufs), &spawn_pool);
            });
            // algorithm bandwidth: 2(w-1)/w * n * 4 bytes moved per worker
            let bytes = 2.0 * (w as f64 - 1.0) / w as f64 * n as f64 * 4.0;
            t.row(&[
                w.to_string(),
                n.to_string(),
                format!("{:.3}", r_serial.mean_ms()),
                format!("{:.3}", r_pooled.mean_ms()),
                format!("{:.3}", r_spawn.mean_ms()),
                format!("{:.2}x", r_spawn.mean_ns / r_pooled.mean_ns),
                format!("{:.2}", bytes / (r_pooled.mean_ns * 1e-9) / 1e9),
            ]);
            rep.result(&r_serial);
            rep.result(&r_pooled);
            rep.result(&r_spawn);
            pairs.push((w, n, r_pooled.mean_ns, r_spawn.mean_ns));
        }
    }
    t.print();
    println!(
        "\n(pooled runs the same two-phase ring schedule with each step's \
         W chunk ops as one persistent-pool region; the spawn column pays \
         the legacy per-region thread spawn+join — 2(W-1) of them per \
         allreduce — which the persistent pool exists to remove.)"
    );

    if !quick {
        println!("\n=== α-β model: BERT-Large gradients (1.34 GB) on paper testbeds ===\n");
        let bytes = 334e6 * 4.0;
        let mut t2 = Table::new(&["scheme", "testbed", "modeled"]);
        t2.row(&[
            "flat ring (NIC shared by 8 GPUs)".into(),
            "192 x p3dn".into(),
            format!("{:.1} ms", flat_gpu_ring_time_s(192, 8, bytes, CommSpec::efa()) * 1e3),
        ]);
        t2.row(&[
            "hierarchical (NVLink + EFA)".into(),
            "192 x p3dn".into(),
            format!(
                "{:.1} ms",
                hierarchical_allreduce_time_s(
                    192,
                    8,
                    bytes,
                    CommSpec::nvlink(),
                    CommSpec::efa()
                ) * 1e3
            ),
        ]);
        t2.row(&[
            "flat ring (ICI)".into(),
            "1024 TPUv3".into(),
            format!("{:.1} ms", allreduce_time_s(1024, bytes, CommSpec::tpu_ici()) * 1e3),
        ]);
        t2.print();
    }

    rep.write().expect("writing BENCH_allreduce.json");

    // acceptance: on the largest swept message the persistent pool must
    // beat the per-call-spawn baseline (the 2(W-1) spawn+joins per
    // allreduce are pure overhead); small messages are allowed to tie —
    // they fall back to the serial schedule below POOLED_MIN_ELEMS.
    if avail >= 2 {
        let &(w, n, pooled_ns, spawn_ns) = pairs.last().unwrap();
        assert!(
            pooled_ns < spawn_ns,
            "persistent-pool allreduce ({:.3} ms) must beat the spawn baseline \
             ({:.3} ms) at w={w}, n={n}",
            pooled_ns / 1e6,
            spawn_ns / 1e6
        );
        println!(
            "\npersistent pool beats per-call spawn on the w={w}, n={n} allreduce: \
             {:.3} vs {:.3} ms",
            pooled_ns / 1e6,
            spawn_ns / 1e6
        );
    } else {
        println!("\n[pool-vs-spawn assertion skipped: single core]");
    }
}
