//! Collective bench: in-process ring-allreduce throughput across worker
//! counts and message sizes, against the α-β cost model's predictions for
//! the paper's real testbeds.

use lans::collective::cost::{
    allreduce_time_s, flat_gpu_ring_time_s, hierarchical_allreduce_time_s, CommSpec,
};
use lans::util::bench::{bench, Table};
use lans::util::rng::Rng;

fn main() {
    println!("=== in-process ring allreduce (sum) ===\n");
    let mut t = Table::new(&["workers", "floats", "mean ms", "GB/s (algo)"]);
    for &w in &[2usize, 4, 8] {
        for &n in &[1usize << 16, 1 << 20, 1 << 22] {
            let mut rng = Rng::new((w * n) as u64);
            let template: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut bufs = template.clone();
            let r = bench(&format!("ring w={w} n={n}"), 2, 10, || {
                bufs.clone_from(&template);
                lans::collective::ring_allreduce(std::hint::black_box(&mut bufs));
            });
            // algorithm bandwidth: 2(w-1)/w * n * 4 bytes moved per worker
            let bytes = 2.0 * (w as f64 - 1.0) / w as f64 * n as f64 * 4.0;
            t.row(&[
                w.to_string(),
                n.to_string(),
                format!("{:.3}", r.mean_ms()),
                format!("{:.2}", bytes / (r.mean_ns * 1e-9) / 1e9),
            ]);
        }
    }
    t.print();

    println!("\n=== α-β model: BERT-Large gradients (1.34 GB) on paper testbeds ===\n");
    let bytes = 334e6 * 4.0;
    let mut t2 = Table::new(&["scheme", "testbed", "modeled"]);
    t2.row(&[
        "flat ring (NIC shared by 8 GPUs)".into(),
        "192 x p3dn".into(),
        format!("{:.1} ms", flat_gpu_ring_time_s(192, 8, bytes, CommSpec::efa()) * 1e3),
    ]);
    t2.row(&[
        "hierarchical (NVLink + EFA)".into(),
        "192 x p3dn".into(),
        format!(
            "{:.1} ms",
            hierarchical_allreduce_time_s(192, 8, bytes, CommSpec::nvlink(), CommSpec::efa())
                * 1e3
        ),
    ]);
    t2.row(&[
        "flat ring (ICI)".into(),
        "1024 TPUv3".into(),
        format!("{:.1} ms", allreduce_time_s(1024, bytes, CommSpec::tpu_ici()) * 1e3),
    ]);
    t2.print();
}
