//! Bucketed-pipeline bench: the step-DAG scheduler (DESIGN.md §9) against
//! the phase-synchronous sharded step it generalizes.
//!
//! Three arms over the same reduce-scattered gradients:
//!   1. phase-sync — full-vector `hierarchical_reduce_scatter`, then the
//!      fused `step_scattered` (the pre-DAG trainer path),
//!   2. DAG serial — `sharded_bucketed_step` with `overlap = false`
//!      (same stages, caller-thread schedule),
//!   3. DAG overlapped — `overlap = true`: reduce-scatter of bucket k runs
//!      concurrently with the stitch of bucket k−1 on the worker pool.
//!
//! The contract under test is the tentpole's: all three arms are
//! *bit-identical* (asserted here at tens-of-millions-params scale, and
//! property-tested in `rust/tests/proptests.rs`), the overlapped schedule
//! is strictly faster than the serial one on ≥ 4 threads, and every
//! bucket's executed wire bytes equal the analytic
//! `hierarchical_phase_wire_bytes_range` prediction on both fabric tiers.
//!
//! `--quick` (CI smoke): fewer reps, one bucket count, smaller model,
//! same assertions.  Numbers land in `BENCH_overlap_step.json`.

use lans::cluster::pipelined_overlap_time_s;
use lans::collective::{
    hierarchical_phase_wire_bytes, hierarchical_phase_wire_bytes_range,
    hierarchical_reduce_scatter, hierarchical_reduce_scatter_views,
};
use lans::coordinator::sharded_bucketed_step;
use lans::optim::{BlockTable, Hyper, ShardPlan, ShardedOptimizer};
use lans::precision::DType;
use lans::topology::{TierPrecision, Topology, WireBytes};
use lans::trace;
use lans::util::bench::{quick_mode, BenchResult, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;
use lans::util::stats::percentile;

const W: usize = 4;
const LR: f32 = 0.001;

/// A prefix of the bert-base block table totalling at least `min_total`
/// params — bench-sized real layer shapes without bert-base's full 4·W
/// buffer footprint.
fn prefix_table(min_total: usize) -> BlockTable {
    let full = BlockTable::bert_base();
    let mut blocks: Vec<Block> = Vec::new();
    for b in full.blocks {
        let done = b.offset >= min_total;
        if done {
            break;
        }
        blocks.push(b);
    }
    let total = blocks.last().map_or(0, |b| b.offset + b.len);
    BlockTable { blocks, total }
}

/// Small table with blocks deliberately straddling the `NORM_SEG` grid, so
/// the wire-byte accounting is exercised on ragged bucket boundaries.
fn lumpy_table() -> BlockTable {
    let lens = [4096 * 3 + 7, 2048, 4096 * 5, 133, 9000, 4096 * 2, 77, 30000];
    let specs: Vec<(String, usize, bool)> =
        lens.iter().enumerate().map(|(i, &l)| (format!("lump{i}"), l, true)).collect();
    BlockTable::new(&specs)
}

fn fresh_bufs(rng: &mut Rng, n: usize) -> Vec<Vec<f32>> {
    (0..W).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
}

/// Time `step` over restored-from-master gradient buffers, excluding the
/// restore itself (the in-tree `bench` helper would fold the 4·n memcpy
/// into both arms and dilute the overlap signal).
fn timed_arm<F: FnMut(&mut [Vec<f32>])>(
    name: &str,
    master: &[Vec<f32>],
    scratch: &mut [Vec<f32>],
    warmup: usize,
    iters: usize,
    mut step: F,
) -> BenchResult {
    let mut samples = Vec::with_capacity(iters);
    for it in 0..warmup + iters {
        for (d, s) in scratch.iter_mut().zip(master) {
            d.copy_from_slice(s);
        }
        let t0 = std::time::Instant::now();
        step(scratch);
        let dt = t0.elapsed().as_nanos() as f64;
        if it >= warmup {
            samples.push(dt);
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
    }
}

/// Per-bucket executed wire bytes must equal the analytic range counter on
/// both tiers, and their sum the full-vector counter — for every topology
/// × wire-format combination the trainer accepts.
fn check_wire_accounting(rng: &mut Rng) {
    let table = lumpy_table();
    let n = table.total;
    let cuts = ShardPlan::bucket_starts(&table, 2 * 4096);
    assert!(cuts.len() > 3, "lumpy table should split into several buckets");
    let master = fresh_bufs(rng, n);
    let combos: &[(Topology, TierPrecision, &str)] = &[
        (Topology::flat(W), TierPrecision::fp32(), "flat fp32"),
        (Topology::grid(2, 2), TierPrecision::fp32(), "2x2 fp32"),
        (Topology::grid(2, 2), TierPrecision::half_inter(DType::Bf16), "2x2 bf16-inter"),
        (Topology::grid(2, 2), TierPrecision::uniform(DType::F16), "2x2 f16"),
    ];
    for (topo, prec, label) in combos {
        let mut bufs = master.clone();
        let mut executed_total = WireBytes::default();
        for b in cuts.windows(2) {
            let (lo, hi) = (b[0], b[1]);
            let mut views: Vec<&mut [f32]> =
                bufs.iter_mut().map(|v| &mut v[lo..hi]).collect();
            let executed = hierarchical_reduce_scatter_views(&mut views, n, lo, topo, *prec);
            let analytic = hierarchical_phase_wire_bytes_range(topo, n, lo, hi, *prec, false);
            assert_eq!(
                executed, analytic,
                "{label}: bucket [{lo}, {hi}) executed wire bytes != analytic"
            );
            executed_total += executed;
        }
        assert_eq!(
            executed_total,
            hierarchical_phase_wire_bytes(topo, n, *prec, false),
            "{label}: bucket sum != full-vector reduce-scatter accounting"
        );

        // and the bucketed DAG step must land on the same parameters as the
        // phase-synchronous path, per combo (full matrix in proptests)
        let scale = 1.0 / W as f32;
        let pool = ThreadPool::new(2);
        let x0: Vec<f32> = (0..n).map(|i| (i as f32).sin() * 0.01).collect();
        let mut x_phase = x0.clone();
        let mut so_phase =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W).unwrap();
        let mut phase_bufs = master.clone();
        hierarchical_reduce_scatter(&mut phase_bufs, topo, *prec);
        so_phase.step_scattered(&pool, &mut x_phase, &phase_bufs, scale, LR);
        for overlap in [false, true] {
            let mut x = x0.clone();
            let mut so =
                ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W)
                    .unwrap();
            let mut dag_bufs = master.clone();
            let (stats, wb) = sharded_bucketed_step(
                &mut so, &pool, &mut x, &mut dag_bufs, &cuts, scale, LR, false, topo,
                *prec, overlap,
            );
            assert!(stats.is_some(), "unprobed bucketed step never skips");
            assert_eq!(wb, executed_total, "{label}: step wire bytes (overlap={overlap})");
            assert_eq!(x, x_phase, "{label}: bucketed bits (overlap={overlap})");
        }
    }
    println!(
        "wire accounting: {} buckets x {} combos, executed == analytic on both tiers; \
         bucketed step bit-identical to phase-sync in every combo\n",
        cuts.len() - 1,
        combos.len()
    );
}

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("overlap_step");
    let mut rng = Rng::new(7);

    check_wire_accounting(&mut rng);

    let table = prefix_table(if quick { 12 << 20 } else { 48 << 20 });
    let n = table.total;
    let topo = Topology::grid(2, 2);
    let prec = TierPrecision::fp32();
    let scale = 1.0 / W as f32;
    let avail = ThreadPool::available();
    let pool = ThreadPool::new(avail);
    let (warmup, reps) = if quick { (1, 2) } else { (1, 5) };

    println!(
        "=== bucketed step DAG, {:.1}M params, W={W} on a 2x2 grid, pool={avail} \
         threads{} ===\n",
        n as f64 / 1e6,
        if quick { ", --quick" } else { "" }
    );

    let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.02).collect();
    let master = fresh_bufs(&mut rng, n);
    let mut scratch: Vec<Vec<f32>> = master.clone();

    // arm 1: the pre-DAG path — full-vector reduce-scatter, then the fused
    // scattered step (comm and update never overlap)
    let (r_phase, x_phase, wb_phase) = {
        let mut so =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W).unwrap();
        let mut x = x0.clone();
        let mut wb = WireBytes::default();
        let r = timed_arm("phase-sync RS + step_scattered", &master, &mut scratch, warmup, reps, |bufs| {
            wb = hierarchical_reduce_scatter(bufs, &topo, prec);
            so.step_scattered(&pool, std::hint::black_box(&mut x), bufs, scale, LR);
        });
        (r, x, wb)
    };
    rep.result(&r_phase);
    rep.metric("phase_sync_ms", r_phase.mean_ms());

    let bucket_counts: &[usize] = if quick { &[8] } else { &[4, 8, 16] };
    let mut t = Table::new(&["buckets", "DAG serial ms", "DAG overlap ms", "overlap speedup"]);
    let mut primary: Option<(BenchResult, BenchResult)> = None;
    for &want in bucket_counts {
        let cuts = ShardPlan::bucket_starts(&table, n / want);
        let b = cuts.len() - 1;

        // arm 2: same buckets, same stages, caller-thread schedule
        let (r_serial, x_serial, wb_serial) = {
            let mut so =
                ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W)
                    .unwrap();
            let mut x = x0.clone();
            let mut wb = WireBytes::default();
            let r = timed_arm(
                &format!("DAG serial (B={b})"),
                &master,
                &mut scratch,
                warmup,
                reps,
                |bufs| {
                    let (stats, w) = sharded_bucketed_step(
                        &mut so, &pool, std::hint::black_box(&mut x), bufs, &cuts, scale,
                        LR, false, &topo, prec, false,
                    );
                    assert!(stats.is_some());
                    wb = w;
                },
            );
            (r, x, wb)
        };

        // arm 3: the overlapped schedule — R_k alongside S_{k-1}
        let (r_overlap, x_overlap, wb_overlap) = {
            let mut so =
                ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W)
                    .unwrap();
            let mut x = x0.clone();
            let mut wb = WireBytes::default();
            let r = timed_arm(
                &format!("DAG overlapped (B={b})"),
                &master,
                &mut scratch,
                warmup,
                reps,
                |bufs| {
                    let (stats, w) = sharded_bucketed_step(
                        &mut so, &pool, std::hint::black_box(&mut x), bufs, &cuts, scale,
                        LR, false, &topo, prec, true,
                    );
                    assert!(stats.is_some());
                    wb = w;
                },
            );
            (r, x, wb)
        };

        // the DAG only reorders timing: bits and wire traffic are invariant
        assert_eq!(x_serial, x_phase, "B={b}: DAG serial diverged from phase-sync");
        assert_eq!(x_overlap, x_phase, "B={b}: DAG overlapped diverged from phase-sync");
        assert_eq!(wb_serial, wb_phase, "B={b}: DAG serial wire bytes");
        assert_eq!(wb_overlap, wb_phase, "B={b}: DAG overlapped wire bytes");

        t.row(&[
            b.to_string(),
            format!("{:.2}", r_serial.mean_ms()),
            format!("{:.2}", r_overlap.mean_ms()),
            format!("{:.2}x", r_serial.mean_ns / r_overlap.mean_ns),
        ]);
        rep.metric(&format!("dag_serial_ms_b{want}"), r_serial.mean_ms());
        rep.metric(&format!("dag_overlap_ms_b{want}"), r_overlap.mean_ms());
        rep.metric(
            &format!("overlap_speedup_b{want}"),
            r_serial.mean_ns / r_overlap.mean_ns,
        );
        rep.result(&r_serial);
        rep.result(&r_overlap);
        if want == 8 {
            primary = Some((r_serial, r_overlap));
        }
    }
    t.print();
    println!(
        "\n(phase-sync: {:.2} ms.  All arms bit-identical; wire bytes {:.1} MB intra + \
         {:.1} MB inter in every arm.)",
        r_phase.mean_ms(),
        wb_phase.intra as f64 / 1e6,
        wb_phase.inter as f64 / 1e6
    );
    rep.metric("wire_intra_mb", wb_phase.intra as f64 / 1e6);
    rep.metric("wire_inter_mb", wb_phase.inter as f64 / 1e6);
    rep.metric("threads", avail as f64);

    // --- traced calibration: the span timeline against the analytic model ---
    // A single-purpose process, so the global trace switch is safe to flip:
    // one serial and one overlapped bucketed step run with spans on, then the
    // StepTrace aggregates are checked against the wire-byte counters and the
    // `pipelined_overlap_time_s` prediction (informational).
    let cuts8 = ShardPlan::bucket_starts(&table, n / 8);
    let analytic_rs = hierarchical_phase_wire_bytes(&topo, n, prec, false);
    let mut run_traced = |overlap: bool| {
        let mut so =
            ShardedOptimizer::from_name("lans", table.clone(), Hyper::default(), W).unwrap();
        let mut x = x0.clone();
        for (d, s) in scratch.iter_mut().zip(&master) {
            d.copy_from_slice(s);
        }
        trace::enable();
        let t0 = std::time::Instant::now();
        let (stats, _) = sharded_bucketed_step(
            &mut so, &pool, &mut x, &mut scratch, &cuts8, scale, LR, false, &topo, prec,
            overlap,
        );
        let wall = t0.elapsed().as_secs_f64();
        trace::disable();
        assert!(stats.is_some());
        (trace::collect(1), wall)
    };
    let (st_serial, wall_serial) = run_traced(false);
    let (st_overlap, wall_overlap) = run_traced(true);
    for (st, label) in [(&st_serial, "serial"), (&st_overlap, "overlapped")] {
        // per-span wire-byte counters must reproduce the analytic
        // reduce-scatter volume exactly — the DAG's comm stages all enter
        // through hierarchical_reduce_scatter_views
        let span_bytes = st.detail_sum(trace::CAT_COMM, "hier_reduce_scatter_views");
        assert_eq!(
            span_bytes,
            analytic_rs.total(),
            "{label}: traced wire bytes != analytic reduce-scatter counter"
        );
        // stage spans (runs + queue-waits) must tile the scheduler's window:
        // the DAG keeps at least one stage in flight, so only scheduler
        // hand-off slack may be uncovered
        let cov = st.stage_coverage();
        assert!(cov > 0.80, "{label}: stage spans cover only {cov:.3} of their window");
    }
    let eff = st_overlap.overlap_efficiency();
    rep.metric("overlap_efficiency_b8", eff);
    let b8 = cuts8.len() - 1;
    println!("\n=== traced calibration (B={b8}) ===");
    println!(
        "serial:     wall {:7.2} ms  comm {:7.2} ms  compute {:7.2} ms  coverage {:.3}",
        wall_serial * 1e3,
        st_serial.comm_s() * 1e3,
        st_serial.compute_s() * 1e3,
        st_serial.stage_coverage()
    );
    println!(
        "overlapped: wall {:7.2} ms  comm {:7.2} ms  compute {:7.2} ms  overlap_eff {:.3}",
        wall_overlap * 1e3,
        st_overlap.comm_s() * 1e3,
        st_overlap.compute_s() * 1e3,
        eff
    );
    // feed the serial arm's measured phase times to the pipeline model and
    // compare its prediction with the overlapped wall time (informational:
    // the model assumes perfectly balanced buckets)
    let predicted = pipelined_overlap_time_s(st_serial.compute_s(), st_serial.comm_s(), b8);
    println!(
        "pipelined_overlap_time_s(measured C/M, B={b8}) = {:.2} ms vs measured {:.2} ms \
         ({:+.1}%)",
        predicted * 1e3,
        wall_overlap * 1e3,
        (wall_overlap - predicted) / predicted * 100.0
    );
    if avail >= 4 {
        assert!(
            eff > 0.0,
            "overlapped DAG on {avail} threads hid no communication under compute"
        );
    }

    // persist numbers before the acceptance assertions
    rep.write().expect("writing BENCH_overlap_step.json");

    // acceptance: with >= 4 threads the overlapped schedule must beat the
    // serial one — that is the whole point of the DAG.  (Two driver lanes
    // need at least a couple of cores to actually run concurrently.)
    let (r_serial, r_overlap) = primary.expect("primary bucket count (8) always measured");
    if avail >= 4 {
        assert!(
            r_overlap.mean_ns < r_serial.mean_ns,
            "overlapped DAG ({:.2} ms) must beat the serial schedule ({:.2} ms) on \
             {avail} threads",
            r_overlap.mean_ms(),
            r_serial.mean_ms()
        );
        println!(
            "\noverlap wins: {:.2} ms -> {:.2} ms ({:.2}x) at B=8 on {avail} threads",
            r_serial.mean_ms(),
            r_overlap.mean_ms(),
            r_serial.mean_ns / r_overlap.mean_ns
        );
    } else {
        println!("\n[{avail} threads — overlap speedup assertion skipped]");
    }
}
