//! Fig. 1 bench: regenerates the schedule series + AUC-gap numbers and
//! times the schedule evaluation itself (it sits on the trainer hot loop).

use lans::optim::Schedule;
use lans::util::bench::{bench, print_result, Table};

fn main() {
    let (t, tw, tc) = (3519u64, 1500u64, 963u64);
    let ideal = Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: tw, t_total: t };
    let small = Schedule::LinearWarmupDecay { eta: 0.007, t_warmup: tw, t_total: t };
    let ours = Schedule::WarmupConstDecay { eta: 0.007, t_warmup: tw, t_const: tc, t_total: t };

    println!("=== Fig. 1: schedules (T={t}, Tw={tw}, Tc={tc}) ===\n");
    let a = ideal.area_under_curve(t);
    let mut table = Table::new(&["schedule", "AUC", "gap vs eq8@0.01", "paper gap"]);
    table.row(&["eq8 eta=0.010".into(), format!("{a:.2}"), "-".into(), "-".into()]);
    table.row(&[
        "eq8 eta=0.007".into(),
        format!("{:.2}", small.area_under_curve(t)),
        format!("{:.2}", a - small.area_under_curve(t)),
        "5.28".into(),
    ]);
    table.row(&[
        "eq9 eta=0.007".into(),
        format!("{:.2}", ours.area_under_curve(t)),
        format!("{:.2}", a - ours.area_under_curve(t)),
        "1.91".into(),
    ]);
    table.print();

    // sanity: the reproduced gaps match the paper to the printed precision
    assert!((a - small.area_under_curve(t) - 5.28).abs() < 0.05);
    assert!((a - ours.area_under_curve(t) - 1.91).abs() < 0.05);
    println!("\ngaps match the paper ✔\n");

    println!("=== schedule evaluation cost (trainer hot loop) ===");
    let mut acc = 0.0f64;
    let r = bench("eq9 lr(t) x 4301 steps", 3, 50, || {
        for step in 1..=4301u64 {
            acc += ours.lr(step);
        }
    });
    print_result(&r);
    std::hint::black_box(acc);
}
