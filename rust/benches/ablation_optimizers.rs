//! Ablation bench (§3.1/§3.2 design choices): isolate the paper's two
//! modifications on a fast synthetic task —
//!
//!   lamb        = trust ratio only                       (Algorithm 1)
//!   lans        = trust ratio + block grad-norm + Nesterov (Algorithm 2)
//!   adamw       = neither
//!   adamw_bgn   = block grad-norm only                   (§4 finetune opt)
//!   msgd / nag  = §2.2's building blocks
//!
//! Task: noisy ill-conditioned least squares with heavy-tailed gradient
//! noise and occasional 100× gradient spikes — the failure mode blockwise
//! normalization is built for ("more robust to vanishing and exploding
//! gradients", §3.1).

use lans::optim::{from_ratios, make_optimizer, BlockTable, Hyper, Optimizer};
use lans::util::bench::Table;
use lans::util::rng::Rng;

struct Problem {
    dim: usize,
    xs: Vec<Vec<f32>>,
    ys: Vec<f32>,
}

impl Problem {
    fn new(n: usize, dim: usize, seed: u64) -> Problem {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        // ill-conditioned features: coordinate j scaled by 1.05^j
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                (0..dim)
                    .map(|j| rng.normal_f32() * 1.05f32.powi(j as i32))
                    .collect()
            })
            .collect();
        let ys = xs
            .iter()
            .map(|x| {
                x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>()
                    + 0.05 * rng.normal_f32()
            })
            .collect();
        Problem { dim, xs, ys }
    }

    fn grad(&self, w: &[f32], idx: &[usize], spike: f32) -> Vec<f32> {
        let mut g = vec![0.0f32; self.dim];
        for &i in idx {
            let e: f32 =
                self.xs[i].iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - self.ys[i];
            for (gj, xj) in g.iter_mut().zip(&self.xs[i]) {
                *gj += e * xj / idx.len() as f32;
            }
        }
        for gj in g.iter_mut() {
            *gj *= spike;
        }
        g
    }

    fn loss(&self, w: &[f32]) -> f64 {
        self.xs
            .iter()
            .zip(&self.ys)
            .map(|(x, y)| {
                let e = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - y;
                (e as f64).powi(2)
            })
            .sum::<f64>()
            / self.xs.len() as f64
    }
}

fn main() {
    let prob = Problem::new(1024, 48, 1);
    // two blocks of different scale — exercises the layer-wise machinery
    let table = BlockTable::new(&[("a".into(), 24, false), ("b".into(), 24, false)]);
    let steps = 600u64;
    let sched = from_ratios(0.08, steps, 0.4265, 0.2735); // Table-1 shape

    println!("=== §3.1/3.2 ablation: 600 steps, gradient spikes every 50 ===\n");
    let mut results: Vec<(&str, f64)> = Vec::new();
    for name in ["lans", "lamb", "adamw_bgn", "adamw", "nag", "msgd"] {
        let hp = Hyper { weight_decay: 0.0, ..Default::default() };
        let mut opt = make_optimizer(name, table.clone(), hp).unwrap();
        let _rng = Rng::new(7);
        // nonzero init: trust-ratio methods scale the step by phi(||x||),
        // so x = 0 is a fixed point (a real LAMB/LANS property)
        let mut w = vec![0.5f32; prob.dim];
        let mut shard = lans::data::make_shards(1024, 1, 3).remove(0);
        for step in 1..=steps {
            let idx = shard.next_batch(64);
            // 100x gradient spike every 50 steps (exploding-gradient event)
            let spike = if step % 50 == 0 { 100.0 } else { 1.0 };
            let g = prob.grad(&w, &idx, spike);
            let lr = sched.lr(step) as f32 * if name.ends_with("sgd") || name == "nag" { 0.01 } else { 1.0 };
            opt.step(&mut w, &g, lr);
        }
        results.push((name, prob.loss(&w)));
    }
    let lamb = results.iter().find(|(n, _)| *n == "lamb").unwrap().1;
    let mut t2 = Table::new(&[
        "optimizer", "grad-norm", "nesterov", "final mse", "ratio vs lamb",
    ]);
    for (n, l) in &results {
        let (gn, nes) = match *n {
            "lans" => ("yes", "yes"),
            "adamw_bgn" => ("yes", "no"),
            "nag" => ("no", "yes"),
            _ => ("no", "no"),
        };
        t2.row(&[
            n.to_string(),
            gn.into(),
            nes.into(),
            format!("{l:.4e}"),
            format!("{:.3}", l / lamb),
        ]);
    }
    t2.print();

    let lans = results.iter().find(|(n, _)| *n == "lans").unwrap().1;
    println!(
        "\nLANS vs LAMB under gradient spikes: {:.2}x lower final loss \
         (blockwise normalization absorbs the spikes; LAMB's v_t is polluted)",
        lamb / lans
    );
    assert!(lans <= lamb * 1.05, "LANS should not lose to LAMB here");
}
