//! §3.4 bench: minibatch-gradient variance, sampling with vs without
//! replacement, empirical vs the paper's closed forms, plus sampler
//! throughput (the data-pipeline cost of without-replacement sharding).

use lans::data::{make_shards, WithReplacementSampler};
use lans::util::bench::{bench, print_result, Table};
use lans::variance::{sweep, GradientPopulation};

fn main() {
    let n = 4096;
    let pop = GradientPopulation::synthetic(n, 16, 1);
    println!("=== §3.4: variance of the minibatch mean (n={n}) ===\n");
    let ks = [16, 64, 256, 1024, 2048, 4096];
    let mut t = Table::new(&[
        "k",
        "with-repl emp",
        "sigma^2/k",
        "wo-repl emp",
        "(n-k)/(k(n-1))s^2",
    ]);
    for row in sweep(&pop, &ks, 4000, 7) {
        t.row(&[
            row.k.to_string(),
            format!("{:.3e}", row.with_repl_empirical),
            format!("{:.3e}", row.with_repl_theory),
            format!("{:.3e}", row.without_repl_empirical),
            format!("{:.3e}", row.without_repl_theory),
        ]);
        // shape assertions: empirical within 20% of theory; wo <= with
        assert!(
            (row.with_repl_empirical - row.with_repl_theory).abs()
                / row.with_repl_theory
                < 0.2
        );
        assert!(
            row.without_repl_empirical
                <= row.with_repl_empirical * 1.05 + 1e-12
        );
    }
    t.print();
    println!("\nk = n row: without-replacement variance vanishes (exact pass) ✔\n");

    println!("=== sampler throughput ===");
    let mut shard = make_shards(1 << 20, 1, 3).remove(0);
    let r = bench("shard.next_batch(1024) from 1M", 10, 200, || {
        std::hint::black_box(shard.next_batch(1024));
    });
    print_result(&r);
    let mut wr = WithReplacementSampler::new(1 << 20, 3);
    let r = bench("with_replacement(1024) from 1M", 10, 200, || {
        std::hint::black_box(wr.next_batch(1024));
    });
    print_result(&r);
}
