//! §3.4 bench: minibatch-gradient variance, sampling with vs without
//! replacement, empirical vs the paper's closed forms, plus sampler
//! throughput (the data-pipeline cost of without-replacement sharding).
//!
//! `--quick` (CI smoke): fewer trials and a trimmed k-sweep — the trial
//! count stays high enough that the 20% empirical-vs-theory assertion
//! keeps real margin (the variance estimator's relative sd is
//! ~sqrt(2/trials) ≈ 3.7% at 1500 trials).  Numbers land in
//! `BENCH_variance_sampling.json` via `util::bench::Reporter`.

use lans::data::{make_shards, WithReplacementSampler};
use lans::util::bench::{bench, print_result, quick_mode, Reporter, Table};
use lans::variance::{sweep, GradientPopulation};

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("variance_sampling");

    let n = 4096;
    let trials = if quick { 1500 } else { 4000 };
    let pop = GradientPopulation::synthetic(n, 16, 1);
    println!(
        "=== §3.4: variance of the minibatch mean (n={n}, {trials} trials{}) ===\n",
        if quick { ", --quick" } else { "" }
    );
    let ks: &[usize] =
        if quick { &[16, 256, 4096] } else { &[16, 64, 256, 1024, 2048, 4096] };
    let mut t = Table::new(&[
        "k",
        "with-repl emp",
        "sigma^2/k",
        "wo-repl emp",
        "(n-k)/(k(n-1))s^2",
    ]);
    for row in sweep(&pop, ks, trials, 7) {
        t.row(&[
            row.k.to_string(),
            format!("{:.3e}", row.with_repl_empirical),
            format!("{:.3e}", row.with_repl_theory),
            format!("{:.3e}", row.without_repl_empirical),
            format!("{:.3e}", row.without_repl_theory),
        ]);
        rep.metric(&format!("with_repl_ratio_k{}", row.k),
                   row.with_repl_empirical / row.with_repl_theory);
        // shape assertions: empirical within 20% of theory; wo <= with
        assert!(
            (row.with_repl_empirical - row.with_repl_theory).abs()
                / row.with_repl_theory
                < 0.2
        );
        assert!(
            row.without_repl_empirical
                <= row.with_repl_empirical * 1.05 + 1e-12
        );
    }
    t.print();
    println!("\nk = n row: without-replacement variance vanishes (exact pass) ✔\n");

    println!("=== sampler throughput ===");
    let iters = if quick { 40 } else { 200 };
    let mut shard = make_shards(1 << 20, 1, 3).remove(0);
    let r = bench("shard.next_batch(1024) from 1M", 10, iters, || {
        std::hint::black_box(shard.next_batch(1024));
    });
    print_result(&r);
    rep.metric(
        "wo_repl_msamples_per_s",
        1024.0 / (r.mean_ns * 1e-9) / 1e6,
    );
    rep.result(&r);
    let mut wr = WithReplacementSampler::new(1 << 20, 3);
    let r = bench("with_replacement(1024) from 1M", 10, iters, || {
        std::hint::black_box(wr.next_batch(1024));
    });
    print_result(&r);
    rep.metric(
        "with_repl_msamples_per_s",
        1024.0 / (r.mean_ns * 1e-9) / 1e6,
    );
    rep.result(&r);

    rep.write().expect("writing BENCH_variance_sampling.json");
}
