//! Table 2 (time column) bench: the modeled wall-clock for both published
//! rows, plus sensitivity sweeps over node count that show where the
//! 54-minute number comes from, and the allreduce-vs-sharded collective
//! comparison (what `shard_optimizer = true` buys on the wire).

use lans::cluster::{pipelined_overlap_time_s, table2_runs, ClusterSpec, Phase, Run, BERT_LARGE};
use lans::collective::cost::{
    flat_gpu_ring_time_s, hierarchical_allreduce_shard_aware_time_s,
    hierarchical_allreduce_time_s, hierarchical_allreduce_time_tiered_s,
    tiered_ring_allreduce_wire_bytes,
};
use lans::collective::Collective;
use lans::coordinator::sharded_bucketed_step;
use lans::optim::{BlockTable, Hyper, ShardPlan, ShardedOptimizer};
use lans::precision::DType;
use lans::topology::{TierPrecision, Topology};
use lans::trace;
use lans::util::bench::Table;
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    println!("=== Table 2: modeled time-to-train (BERT-Large) ===\n");
    let paper = [76.2, 53.6];
    let mut t = Table::new(&["run", "steps", "modeled", "paper", "rel err"]);
    let mut modeled = Vec::new();
    for (run, p) in table2_runs().iter().zip(paper) {
        let m = run.total_minutes(&BERT_LARGE);
        modeled.push(m);
        t.row(&[
            run.label.to_string(),
            run.total_steps().to_string(),
            format!("{m:.1}m"),
            format!("{p:.1}m"),
            format!("{:+.1}%", (m - p) / p * 100.0),
        ]);
    }
    t.print();
    let ratio = modeled[1] / modeled[0];
    println!("\nLANS/LAMB ratio: modeled {ratio:.3} vs paper {:.3}\n", 53.6 / 76.2);

    println!("=== sensitivity: nodes sweep (LANS 96K/33K on p3dn) ===\n");
    let mut t2 = Table::new(&["nodes", "GPUs", "modeled time", "scaling eff"]);
    let mut base: Option<f64> = None;
    for nodes in [24, 48, 96, 192, 384] {
        let run = Run {
            label: "LANS",
            cluster: ClusterSpec::p3dn(nodes),
            phases: vec![
                Phase { steps: 3519, batch_seqs: 98304, seq: 128, slots: 20 },
                Phase { steps: 782, batch_seqs: 33792, seq: 512, slots: 80 },
            ],
        };
        let m = run.total_minutes(&BERT_LARGE);
        let b = *base.get_or_insert(m * nodes as f64);
        t2.row(&[
            nodes.to_string(),
            (nodes * 8).to_string(),
            format!("{m:.1}m"),
            format!("{:.1}%", b / (m * nodes as f64) * 100.0),
        ]);
    }
    t2.print();

    println!("\n=== collective: allreduce vs reduce-scatter+gather (sharded optimizer) ===\n");
    // the wire-side view of `shard_optimizer = true`.  Caveat: the
    // allreduce column prices a naive full-message inter-node ring (the
    // calibrated baseline), while the sharded column's inter-node phases
    // move only per-node shards — a shard-aware hierarchical allreduce
    // lands between the two, so read "saved" as an upper bound on the wire
    // side; the schedule-independent win is the per-device update row below
    let mut t3 = Table::new(&["cluster", "phase", "allreduce step", "sharded step", "saved"]);
    for run in table2_runs() {
        for (i, p) in run.phases.iter().enumerate() {
            let ar = run.cluster.step_time_with(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce);
            let sh = run.cluster.step_time_with(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::ReduceScatterGather);
            t3.row(&[
                run.label.to_string(),
                format!("{}", i + 1),
                format!("{ar:.3}s"),
                format!("{sh:.3}s"),
                format!("{:.1}%", (1.0 - sh / ar) * 100.0),
            ]);
        }
    }
    t3.print();
    let c = ClusterSpec::p3dn(192);
    println!(
        "\nper-device update: {:.1} ms replicated -> {:.3} ms sharded over {} GPUs",
        c.optimizer_update_time_s(&BERT_LARGE, false) * 1e3,
        c.optimizer_update_time_s(&BERT_LARGE, true) * 1e3,
        c.devices(),
    );

    println!("\n=== wire precision: fp32 vs fp16 gradient exchange (grad_dtype) ===\n");
    // the paper's run moves gradients in fp16: half the bytes on every
    // hop, so exactly half the β (bandwidth) term of the collective — the
    // α latency, compute and (fp32-master) update terms are unchanged
    let mut t4 = Table::new(&[
        "cluster", "phase", "fp32 step", "fp16 step", "beta term saved",
    ]);
    for run in table2_runs() {
        for (i, p) in run.phases.iter().enumerate() {
            let f32s = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 4.0);
            let f16s = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 2.0);
            let base = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 0.0);
            let (b32, b16) = (f32s - base, f16s - base);
            t4.row(&[
                run.label.to_string(),
                format!("{}", i + 1),
                format!("{f32s:.3}s"),
                format!("{f16s:.3}s"),
                format!("{:.1}%", (1.0 - b16 / b32) * 100.0),
            ]);
            assert!(
                (b16 - b32 / 2.0).abs() <= 1e-9 * b32,
                "fp16 wire must model exactly half the beta term \
                 ({b16} vs {b32}/2)"
            );
        }
    }
    t4.print();
    println!("\nfp16 wire: exactly half the modeled β term per phase ✔");

    println!("\n=== hierarchical executed: two-tier ring vs flat on 192 x 8 (BERT-Large) ===\n");
    // the executed-collective column (`collective::hierarchical`): a
    // node-contiguous ring crosses each NIC once per cycle, so per-NIC
    // traffic — and its α-β price — drops by gpus_per_node vs the
    // node-oblivious flat ring; the leader-based schedules price below it
    let c = ClusterSpec::p3dn(192);
    let (nodes, gpus) = (c.nodes, c.devices_per_node);
    let elems = (BERT_LARGE.param_bytes_f32() / 4.0) as usize;
    let flat_wire =
        tiered_ring_allreduce_wire_bytes(nodes * gpus, 1, elems, DType::F32, DType::F32);
    let hier_wire = tiered_ring_allreduce_wire_bytes(nodes, gpus, elems, DType::F32, DType::F32);
    let hier_wire_f16 =
        tiered_ring_allreduce_wire_bytes(nodes, gpus, elems, DType::F32, DType::F16);
    let bytes = BERT_LARGE.param_bytes_f32();
    let mut t5 = Table::new(&["schedule", "inter GB/NIC", "modeled comm s"]);
    for (label, inter_bytes, secs) in [
        (
            "flat ring (8 GPUs share each NIC)",
            flat_wire.1 as f64 / nodes as f64,
            flat_gpu_ring_time_s(nodes, gpus, bytes, c.inter),
        ),
        (
            "two-tier ring (executed, fp32)",
            hier_wire.1 as f64 / nodes as f64,
            hierarchical_allreduce_time_s(nodes, gpus, bytes, c.intra, c.inter),
        ),
        (
            "two-tier ring (executed, f16 inter)",
            hier_wire_f16.1 as f64 / nodes as f64,
            hierarchical_allreduce_time_tiered_s(
                nodes, gpus, bytes, bytes / 2.0, c.intra, c.inter,
            ),
        ),
        (
            "leader hierarchical, shard-aware (model)",
            2.0 * (nodes as f64 - 1.0) / nodes as f64 * bytes / gpus as f64,
            hierarchical_allreduce_shard_aware_time_s(nodes, gpus, bytes, c.intra, c.inter),
        ),
    ] {
        t5.row(&[label.to_string(), format!("{:.2}", inter_bytes / 1e9), format!("{secs:.3}")]);
    }
    t5.print();
    // executed invariant at paper scale: the tiered ring cuts per-NIC
    // inter bytes by the fan-in factor (exactly G with equal chunks; the
    // 1536-way grid of a 340M-param vector is within rounding of it)
    let shrink = flat_wire.1 as f64 / hier_wire.1 as f64;
    assert!(
        (shrink - gpus as f64).abs() < 0.01,
        "executed inter shrink {shrink} vs gpus_per_node {gpus}"
    );
    assert_eq!(hier_wire.0 + hier_wire.1, flat_wire.1, "volume conserved across tiers");
    println!(
        "\ntwo-tier ring: {shrink:.2}x less inter-node traffic than the flat ring \
         (executed counters; the f16 inter tier halves it again) ✔"
    );

    println!("\n=== sensitivity: what if LAMB could use LANS's hardware? ===\n");
    // isolate algorithm speedup (fewer steps) from hardware differences
    let lamb_on_gpu = Run {
        label: "LAMB steps on 1536 V100",
        cluster: ClusterSpec::p3dn(192),
        phases: vec![
            Phase { steps: 7038, batch_seqs: 65536, seq: 128, slots: 20 },
            Phase { steps: 1561, batch_seqs: 32768, seq: 512, slots: 80 },
        ],
    };
    let lans_run = &table2_runs()[1];
    let a = lamb_on_gpu.total_minutes(&BERT_LARGE);
    let b = lans_run.total_minutes(&BERT_LARGE);
    println!("LAMB schedule on p3dn-192:  {a:.1}m");
    println!("LANS schedule on p3dn-192:  {b:.1}m");
    println!(
        "algorithmic speedup (same hardware): {:.2}x — the paper's \
         contribution isolated from the TPU→GPU change",
        a / b
    );

    println!("\n=== bucketed overlap: modeled step time vs bucket count (LANS p1) ===\n");
    // `step_time_bucketed` at paper scale: the comm term hides behind
    // compute as the bucket count grows (DESIGN.md §9's pipeline model)
    let lans_run = &table2_runs()[1];
    let p = &lans_run.phases[0];
    let mut t6 = Table::new(&["buckets", "modeled step", "vs B=1"]);
    let base = lans_run.cluster.step_time_bucketed(
        &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::ReduceScatterGather, 4.0, 4.0, 1,
    );
    for buckets in [1usize, 4, 8, 32] {
        let s = lans_run.cluster.step_time_bucketed(
            &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::ReduceScatterGather, 4.0,
            4.0, buckets,
        );
        t6.row(&[
            buckets.to_string(),
            format!("{s:.3}s"),
            format!("{:.1}%", (1.0 - s / base) * 100.0),
        ]);
    }
    t6.print();

    println!("\n=== executed calibration: traced bucketed step vs the pipeline model ===\n");
    // a small in-process bucketed step with the step-trace subsystem on:
    // measured comm/compute phase times from the spans are fed to
    // `pipelined_overlap_time_s`, whose prediction is compared with the
    // measured overlapped wall time (informational — the model assumes
    // perfectly balanced buckets and zero scheduler slack)
    let lens = [1usize << 16, 1 << 18, 3 << 16, 1 << 17];
    let specs: Vec<(String, usize, bool)> =
        lens.iter().enumerate().map(|(i, &l)| (format!("blk{i}"), l, true)).collect();
    let btable = BlockTable::new(&specs);
    let workers = 4;
    let topo_x = Topology::grid(2, 2);
    let prec = TierPrecision::fp32();
    let pool = ThreadPool::new(ThreadPool::available());
    let cuts = ShardPlan::bucket_starts(&btable, btable.total / 8);
    let nb = cuts.len() - 1;
    let mut rng = Rng::new(11);
    let master: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..btable.total).map(|_| rng.normal_f32()).collect())
        .collect();
    let mut traced_step = |overlap: bool| {
        let mut so =
            ShardedOptimizer::from_name("lans", btable.clone(), Hyper::default(), workers)
                .unwrap();
        let mut x = vec![0.01f32; btable.total];
        let mut bufs = master.clone();
        trace::enable();
        let t0 = std::time::Instant::now();
        let (stats, _) = sharded_bucketed_step(
            &mut so, &pool, &mut x, &mut bufs, &cuts, 0.25, 1e-3, false, &topo_x, prec,
            overlap,
        );
        let wall = t0.elapsed().as_secs_f64();
        trace::disable();
        assert!(stats.is_some(), "unprobed bucketed step never skips");
        (trace::collect(0), wall)
    };
    let (st_serial, wall_serial) = traced_step(false);
    let (st_overlap, wall_overlap) = traced_step(true);
    let predicted =
        pipelined_overlap_time_s(st_serial.compute_s(), st_serial.comm_s(), nb);
    println!(
        "serial:     wall {:7.3} ms  comm {:7.3} ms  compute {:7.3} ms",
        wall_serial * 1e3,
        st_serial.comm_s() * 1e3,
        st_serial.compute_s() * 1e3
    );
    println!(
        "overlapped: wall {:7.3} ms  overlap_eff {:.3}",
        wall_overlap * 1e3,
        st_overlap.overlap_efficiency()
    );
    println!(
        "pipelined_overlap_time_s(measured C/M, B={nb}) = {:.3} ms vs measured \
         {:.3} ms ({:+.1}%)",
        predicted * 1e3,
        wall_overlap * 1e3,
        (wall_overlap - predicted) / predicted * 100.0
    );
}
