//! Table 2 (time column) bench: the modeled wall-clock for both published
//! rows, plus sensitivity sweeps over node count that show where the
//! 54-minute number comes from, and the allreduce-vs-sharded collective
//! comparison (what `shard_optimizer = true` buys on the wire).

use lans::cluster::{table2_runs, ClusterSpec, Phase, Run, BERT_LARGE};
use lans::collective::cost::{
    flat_gpu_ring_time_s, hierarchical_allreduce_shard_aware_time_s,
    hierarchical_allreduce_time_s, hierarchical_allreduce_time_tiered_s,
    tiered_ring_allreduce_wire_bytes,
};
use lans::collective::Collective;
use lans::precision::DType;
use lans::util::bench::Table;

fn main() {
    println!("=== Table 2: modeled time-to-train (BERT-Large) ===\n");
    let paper = [76.2, 53.6];
    let mut t = Table::new(&["run", "steps", "modeled", "paper", "rel err"]);
    let mut modeled = Vec::new();
    for (run, p) in table2_runs().iter().zip(paper) {
        let m = run.total_minutes(&BERT_LARGE);
        modeled.push(m);
        t.row(&[
            run.label.to_string(),
            run.total_steps().to_string(),
            format!("{m:.1}m"),
            format!("{p:.1}m"),
            format!("{:+.1}%", (m - p) / p * 100.0),
        ]);
    }
    t.print();
    let ratio = modeled[1] / modeled[0];
    println!("\nLANS/LAMB ratio: modeled {ratio:.3} vs paper {:.3}\n", 53.6 / 76.2);

    println!("=== sensitivity: nodes sweep (LANS 96K/33K on p3dn) ===\n");
    let mut t2 = Table::new(&["nodes", "GPUs", "modeled time", "scaling eff"]);
    let mut base: Option<f64> = None;
    for nodes in [24, 48, 96, 192, 384] {
        let run = Run {
            label: "LANS",
            cluster: ClusterSpec::p3dn(nodes),
            phases: vec![
                Phase { steps: 3519, batch_seqs: 98304, seq: 128, slots: 20 },
                Phase { steps: 782, batch_seqs: 33792, seq: 512, slots: 80 },
            ],
        };
        let m = run.total_minutes(&BERT_LARGE);
        let b = *base.get_or_insert(m * nodes as f64);
        t2.row(&[
            nodes.to_string(),
            (nodes * 8).to_string(),
            format!("{m:.1}m"),
            format!("{:.1}%", b / (m * nodes as f64) * 100.0),
        ]);
    }
    t2.print();

    println!("\n=== collective: allreduce vs reduce-scatter+gather (sharded optimizer) ===\n");
    // the wire-side view of `shard_optimizer = true`.  Caveat: the
    // allreduce column prices a naive full-message inter-node ring (the
    // calibrated baseline), while the sharded column's inter-node phases
    // move only per-node shards — a shard-aware hierarchical allreduce
    // lands between the two, so read "saved" as an upper bound on the wire
    // side; the schedule-independent win is the per-device update row below
    let mut t3 = Table::new(&["cluster", "phase", "allreduce step", "sharded step", "saved"]);
    for run in table2_runs() {
        for (i, p) in run.phases.iter().enumerate() {
            let ar = run.cluster.step_time_with(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce);
            let sh = run.cluster.step_time_with(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::ReduceScatterGather);
            t3.row(&[
                run.label.to_string(),
                format!("{}", i + 1),
                format!("{ar:.3}s"),
                format!("{sh:.3}s"),
                format!("{:.1}%", (1.0 - sh / ar) * 100.0),
            ]);
        }
    }
    t3.print();
    let c = ClusterSpec::p3dn(192);
    println!(
        "\nper-device update: {:.1} ms replicated -> {:.3} ms sharded over {} GPUs",
        c.optimizer_update_time_s(&BERT_LARGE, false) * 1e3,
        c.optimizer_update_time_s(&BERT_LARGE, true) * 1e3,
        c.devices(),
    );

    println!("\n=== wire precision: fp32 vs fp16 gradient exchange (grad_dtype) ===\n");
    // the paper's run moves gradients in fp16: half the bytes on every
    // hop, so exactly half the β (bandwidth) term of the collective — the
    // α latency, compute and (fp32-master) update terms are unchanged
    let mut t4 = Table::new(&[
        "cluster", "phase", "fp32 step", "fp16 step", "beta term saved",
    ]);
    for run in table2_runs() {
        for (i, p) in run.phases.iter().enumerate() {
            let f32s = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 4.0);
            let f16s = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 2.0);
            let base = run.cluster.step_time_with_wire(
                &BERT_LARGE, p.batch_seqs, p.seq, p.slots, Collective::AllReduce, 0.0);
            let (b32, b16) = (f32s - base, f16s - base);
            t4.row(&[
                run.label.to_string(),
                format!("{}", i + 1),
                format!("{f32s:.3}s"),
                format!("{f16s:.3}s"),
                format!("{:.1}%", (1.0 - b16 / b32) * 100.0),
            ]);
            assert!(
                (b16 - b32 / 2.0).abs() <= 1e-9 * b32,
                "fp16 wire must model exactly half the beta term \
                 ({b16} vs {b32}/2)"
            );
        }
    }
    t4.print();
    println!("\nfp16 wire: exactly half the modeled β term per phase ✔");

    println!("\n=== hierarchical executed: two-tier ring vs flat on 192 x 8 (BERT-Large) ===\n");
    // the executed-collective column (`collective::hierarchical`): a
    // node-contiguous ring crosses each NIC once per cycle, so per-NIC
    // traffic — and its α-β price — drops by gpus_per_node vs the
    // node-oblivious flat ring; the leader-based schedules price below it
    let c = ClusterSpec::p3dn(192);
    let (nodes, gpus) = (c.nodes, c.devices_per_node);
    let elems = (BERT_LARGE.param_bytes_f32() / 4.0) as usize;
    let flat_wire =
        tiered_ring_allreduce_wire_bytes(nodes * gpus, 1, elems, DType::F32, DType::F32);
    let hier_wire = tiered_ring_allreduce_wire_bytes(nodes, gpus, elems, DType::F32, DType::F32);
    let hier_wire_f16 =
        tiered_ring_allreduce_wire_bytes(nodes, gpus, elems, DType::F32, DType::F16);
    let bytes = BERT_LARGE.param_bytes_f32();
    let mut t5 = Table::new(&["schedule", "inter GB/NIC", "modeled comm s"]);
    for (label, inter_bytes, secs) in [
        (
            "flat ring (8 GPUs share each NIC)",
            flat_wire.1 as f64 / nodes as f64,
            flat_gpu_ring_time_s(nodes, gpus, bytes, c.inter),
        ),
        (
            "two-tier ring (executed, fp32)",
            hier_wire.1 as f64 / nodes as f64,
            hierarchical_allreduce_time_s(nodes, gpus, bytes, c.intra, c.inter),
        ),
        (
            "two-tier ring (executed, f16 inter)",
            hier_wire_f16.1 as f64 / nodes as f64,
            hierarchical_allreduce_time_tiered_s(
                nodes, gpus, bytes, bytes / 2.0, c.intra, c.inter,
            ),
        ),
        (
            "leader hierarchical, shard-aware (model)",
            2.0 * (nodes as f64 - 1.0) / nodes as f64 * bytes / gpus as f64,
            hierarchical_allreduce_shard_aware_time_s(nodes, gpus, bytes, c.intra, c.inter),
        ),
    ] {
        t5.row(&[label.to_string(), format!("{:.2}", inter_bytes / 1e9), format!("{secs:.3}")]);
    }
    t5.print();
    // executed invariant at paper scale: the tiered ring cuts per-NIC
    // inter bytes by the fan-in factor (exactly G with equal chunks; the
    // 1536-way grid of a 340M-param vector is within rounding of it)
    let shrink = flat_wire.1 as f64 / hier_wire.1 as f64;
    assert!(
        (shrink - gpus as f64).abs() < 0.01,
        "executed inter shrink {shrink} vs gpus_per_node {gpus}"
    );
    assert_eq!(hier_wire.0 + hier_wire.1, flat_wire.1, "volume conserved across tiers");
    println!(
        "\ntwo-tier ring: {shrink:.2}x less inter-node traffic than the flat ring \
         (executed counters; the f16 inter tier halves it again) ✔"
    );

    println!("\n=== sensitivity: what if LAMB could use LANS's hardware? ===\n");
    // isolate algorithm speedup (fewer steps) from hardware differences
    let lamb_on_gpu = Run {
        label: "LAMB steps on 1536 V100",
        cluster: ClusterSpec::p3dn(192),
        phases: vec![
            Phase { steps: 7038, batch_seqs: 65536, seq: 128, slots: 20 },
            Phase { steps: 1561, batch_seqs: 32768, seq: 512, slots: 80 },
        ],
    };
    let lans_run = &table2_runs()[1];
    let a = lamb_on_gpu.total_minutes(&BERT_LARGE);
    let b = lans_run.total_minutes(&BERT_LARGE);
    println!("LAMB schedule on p3dn-192:  {a:.1}m");
    println!("LANS schedule on p3dn-192:  {b:.1}m");
    println!(
        "algorithmic speedup (same hardware): {:.2}x — the paper's \
         contribution isolated from the TPU→GPU change",
        a / b
    );
}
