//! Data-pipeline bench: corpus generation, MLM masking and batch-building
//! throughput — the L3 work that must stay off the critical path.

use lans::data::{Masker, SequenceSet, SyntheticCorpus};
use lans::util::bench::{bench, print_result};
use lans::util::rng::Rng;

fn main() {
    println!("=== corpus generation ===");
    let corpus = SyntheticCorpus::new(8192, 1);
    let r = bench("markov-zipf generate 1M tokens", 1, 10, || {
        std::hint::black_box(corpus.generate(1 << 20, 7));
    });
    print_result(&r);
    println!(
        "  -> {:.1} Mtok/s",
        (1 << 20) as f64 / (r.mean_ns * 1e-9) / 1e6
    );

    println!("\n=== MLM masking + batch building ===");
    let toks = corpus.generate(128 * 4096, 2);
    let seqs = SequenceSet::new(toks, 128);
    let masker = Masker::new(20, &corpus.vocab);
    let mut rng = Rng::new(3);
    let idx: Vec<usize> = (0..32).collect();
    let r = bench("make_batch b=32 s=128 slots=20", 5, 100, || {
        std::hint::black_box(masker.make_batch(&seqs, &idx, &mut rng));
    });
    print_result(&r);
    let tok_rate = (32 * 128) as f64 / (r.mean_ns * 1e-9);
    println!("  -> {:.2} Mtok/s masked", tok_rate / 1e6);
    // a 96K-sequence global batch at seq 128 needs 12.6M tokens/step;
    // report how many masker threads the paper-scale pipeline would need
    // at a 1 s step time
    println!(
        "  -> paper-scale 96K batch needs {:.1} masker-threads at 1 s/step",
        (96.0 * 1024.0 * 128.0) / tok_rate
    );
}
