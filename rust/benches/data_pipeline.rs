//! Data-pipeline bench: corpus generation, MLM masking and batch-building
//! throughput — the L3 work that must stay off the critical path.
//!
//! `--quick` (CI smoke): fewer iterations and a smaller corpus, same
//! shape.  Numbers land in `BENCH_data_pipeline.json` via the shared
//! `util::bench::Reporter` so the throughput trajectory accumulates
//! across PRs.

use lans::data::{Masker, SequenceSet, SyntheticCorpus};
use lans::util::bench::{bench, print_result, quick_mode, Reporter};
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("data_pipeline");

    println!(
        "=== corpus generation{} ===",
        if quick { " (--quick)" } else { "" }
    );
    let gen_tokens = if quick { 1 << 18 } else { 1 << 20 };
    let gen_iters = if quick { 3 } else { 10 };
    let corpus = SyntheticCorpus::new(8192, 1);
    let r = bench(
        &format!("markov-zipf generate {gen_tokens} tokens"),
        1,
        gen_iters,
        || {
            std::hint::black_box(corpus.generate(gen_tokens, 7));
        },
    );
    print_result(&r);
    let gen_mtok_s = gen_tokens as f64 / (r.mean_ns * 1e-9) / 1e6;
    println!("  -> {gen_mtok_s:.1} Mtok/s");
    rep.result(&r);
    rep.metric("generate_mtok_per_s", gen_mtok_s);

    println!("\n=== MLM masking + batch building ===");
    let mask_iters = if quick { 20 } else { 100 };
    let toks = corpus.generate(128 * 4096, 2);
    let seqs = SequenceSet::new(toks, 128);
    let masker = Masker::new(20, &corpus.vocab);
    let mut rng = Rng::new(3);
    let idx: Vec<usize> = (0..32).collect();
    let r = bench("make_batch b=32 s=128 slots=20", 5, mask_iters, || {
        std::hint::black_box(masker.make_batch(&seqs, &idx, &mut rng));
    });
    print_result(&r);
    let tok_rate = (32 * 128) as f64 / (r.mean_ns * 1e-9);
    println!("  -> {:.2} Mtok/s masked", tok_rate / 1e6);
    rep.result(&r);
    rep.metric("mask_mtok_per_s", tok_rate / 1e6);
    // a 96K-sequence global batch at seq 128 needs 12.6M tokens/step;
    // report how many masker threads the paper-scale pipeline would need
    // at a 1 s step time
    let masker_threads = (96.0 * 1024.0 * 128.0) / tok_rate;
    println!(
        "  -> paper-scale 96K batch needs {masker_threads:.1} masker-threads at 1 s/step"
    );
    rep.metric("paper_scale_masker_threads", masker_threads);

    rep.write().expect("writing BENCH_data_pipeline.json");
}
