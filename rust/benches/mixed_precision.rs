//! Mixed-precision bench: half-conversion throughput and fp32-vs-fp16
//! wire allreduce — the executed side of the paper's "gradients cross EFA
//! in half precision" lever, with the α-β model pricing what the halved
//! bytes buy at paper scale.
//!
//! Acceptance (runs under `--quick` in CI):
//!   * the fp16/bf16 wire allreduce moves exactly half the bytes of the
//!     fp32 one (executed byte counters vs the analytic schedule);
//!   * the modeled β (bandwidth) term of the collective halves exactly
//!     when the wire goes 4 → 2 bytes/elem (`step_time_with_wire`);
//!   * the half-wire result is bit-identical serial vs pooled.
//!
//! Numbers land in `BENCH_mixed_precision.json` via `util::bench::Reporter`.

use lans::cluster::{ClusterSpec, BERT_LARGE};
use lans::collective::{
    ring_allreduce, ring_allreduce_half, ring_allreduce_half_pooled,
    ring_allreduce_wire_bytes, Collective,
};
use lans::precision::{DType, HalfVec};
use lans::simd::{self, Backend};
use lans::util::bench::{bench, quick_mode, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("mixed_precision");
    let iters = if quick { 3 } else { 10 };
    let avail = ThreadPool::available();
    let pool = ThreadPool::new(avail);

    // ---- conversion throughput -------------------------------------------
    println!(
        "=== f32 <-> f16/bf16 conversion throughput{} ===\n",
        if quick { " (--quick)" } else { "" }
    );
    let n_conv = if quick { 1 << 18 } else { 1 << 22 };
    let mut rng = Rng::new(7);
    let data: Vec<f32> = (0..n_conv).map(|_| rng.normal_f32()).collect();
    let mut t = Table::new(&["direction", "ms", "Melem/s"]);
    let melems = |r: &lans::util::bench::BenchResult| {
        n_conv as f64 / (r.mean_ns * 1e-9) / 1e6
    };

    let r = bench("f32->f16 pack", 1, iters, || {
        std::hint::black_box(HalfVec::from_f32(DType::F16, &data));
    });
    t.row(&["f32 -> f16".into(), format!("{:.3}", r.mean_ms()), format!("{:.1}", melems(&r))]);
    rep.metric("f16_pack_melems_per_s", melems(&r));
    rep.result(&r);

    let r = bench("f32->bf16 pack", 1, iters, || {
        std::hint::black_box(HalfVec::from_f32(DType::Bf16, &data));
    });
    t.row(&["f32 -> bf16".into(), format!("{:.3}", r.mean_ms()), format!("{:.1}", melems(&r))]);
    rep.metric("bf16_pack_melems_per_s", melems(&r));
    rep.result(&r);

    let packed16 = HalfVec::from_f32(DType::F16, &data);
    let mut out = vec![0.0f32; n_conv];
    let r = bench("f16->f32 unpack", 1, iters, || {
        packed16.to_f32_into(std::hint::black_box(&mut out));
    });
    t.row(&["f16 -> f32".into(), format!("{:.3}", r.mean_ms()), format!("{:.1}", melems(&r))]);
    rep.result(&r);

    let packed_bf = HalfVec::from_f32(DType::Bf16, &data);
    let r = bench("bf16->f32 unpack", 1, iters, || {
        packed_bf.to_f32_into(std::hint::black_box(&mut out));
    });
    t.row(&["bf16 -> f32".into(), format!("{:.3}", r.mean_ms()), format!("{:.1}", melems(&r))]);
    rep.result(&r);
    t.print();

    // ---- SIMD vs portable-scalar conversion kernels ----------------------
    // Direct calls: the dispatched entry points (whatever backend()
    // detected) against the canonical portable module in the same process.
    // `simd_active` guards the speedup-floor gate in BENCH_baseline/ —
    // a scalar-only runner (or LANS_FORCE_SCALAR=1) reports 0 and the
    // gate skips instead of failing.
    let backend = simd::backend();
    println!(
        "\n=== SIMD vs scalar conversion kernels (dispatch backend: {}) ===\n",
        backend.name()
    );
    let mut ts = Table::new(&["kernel", "simd GB/s", "scalar GB/s", "speedup"]);
    let mut bits = vec![0u16; n_conv];
    // bytes touched per element: 4 (f32 side) + 2 (half side)
    let gbs = |r: &lans::util::bench::BenchResult| {
        6.0 * n_conv as f64 / (r.mean_ns * 1e-9) / 1e9
    };
    let mut speedup = |rep: &mut Reporter,
                       ts: &mut Table,
                       name: &str,
                       key: &str,
                       run: &mut dyn FnMut(bool)| {
        let rs = bench(&format!("{name} (simd)"), 1, iters, || run(true));
        let rp = bench(&format!("{name} (scalar)"), 1, iters, || run(false));
        let ratio = rp.mean_ns / rs.mean_ns;
        ts.row(&[
            name.into(),
            format!("{:.2}", gbs(&rs)),
            format!("{:.2}", gbs(&rp)),
            format!("{ratio:.2}x"),
        ]);
        rep.metric(key, ratio);
        rep.result(&rs);
        rep.result(&rp);
    };
    speedup(&mut rep, &mut ts, "f32->f16 narrow", "f16_narrow_speedup", &mut |s| {
        if s {
            simd::narrow_f16(std::hint::black_box(&data), &mut bits);
        } else {
            simd::portable::narrow_f16(std::hint::black_box(&data), &mut bits);
        }
    });
    simd::narrow_f16(&data, &mut bits);
    speedup(&mut rep, &mut ts, "f16->f32 widen", "f16_widen_speedup", &mut |s| {
        if s {
            simd::widen_f16(std::hint::black_box(&bits), &mut out);
        } else {
            simd::portable::widen_f16(std::hint::black_box(&bits), &mut out);
        }
    });
    speedup(&mut rep, &mut ts, "f32->bf16 narrow", "bf16_narrow_speedup", &mut |s| {
        if s {
            simd::narrow_bf16(std::hint::black_box(&data), &mut bits);
        } else {
            simd::portable::narrow_bf16(std::hint::black_box(&data), &mut bits);
        }
    });
    let mut acc = vec![0.0f32; n_conv];
    speedup(&mut rep, &mut ts, "fused hop (q+dq+add)", "f16_hop_speedup", &mut |s| {
        if s {
            simd::accum_quantized_f16(std::hint::black_box(&data), &mut acc);
        } else {
            simd::portable::accum_quantized_f16(std::hint::black_box(&data), &mut acc);
        }
    });
    ts.print();
    rep.metric("simd_active", if backend == Backend::Scalar { 0.0 } else { 1.0 });

    // ---- fp32 vs half wire allreduce -------------------------------------
    println!("\n=== wire allreduce: fp32 vs fp16/bf16 chunks (W workers, N floats) ===\n");
    let mut t2 = Table::new(&[
        "workers",
        "floats",
        "f32 serial ms",
        "f16 serial ms",
        "f16 pooled ms",
        "bf16 pooled ms",
        "f32 wire MB",
        "f16 wire MB",
    ]);
    let cases: &[(usize, usize)] =
        if quick { &[(4, 1 << 18)] } else { &[(4, 1 << 18), (4, 1 << 20), (8, 1 << 20)] };
    for &(w, n) in cases {
        let mut rng = Rng::new((w * n) as u64);
        let template: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut bufs = template.clone();

        let r32 = bench(&format!("f32/w{w}/n{n}"), 1, iters, || {
            bufs.clone_from(&template);
            ring_allreduce(std::hint::black_box(&mut bufs));
        });
        let r16 = bench(&format!("f16/w{w}/n{n}"), 1, iters, || {
            bufs.clone_from(&template);
            ring_allreduce_half(std::hint::black_box(&mut bufs), DType::F16);
        });
        let r16p = bench(&format!("f16_pooled/w{w}/n{n}"), 1, iters, || {
            bufs.clone_from(&template);
            ring_allreduce_half_pooled(std::hint::black_box(&mut bufs), DType::F16, &pool);
        });
        let rbfp = bench(&format!("bf16_pooled/w{w}/n{n}"), 1, iters, || {
            bufs.clone_from(&template);
            ring_allreduce_half_pooled(std::hint::black_box(&mut bufs), DType::Bf16, &pool);
        });
        let b32 = ring_allreduce_wire_bytes(w, n, DType::F32);
        let b16 = ring_allreduce_wire_bytes(w, n, DType::F16);
        t2.row(&[
            w.to_string(),
            n.to_string(),
            format!("{:.3}", r32.mean_ms()),
            format!("{:.3}", r16.mean_ms()),
            format!("{:.3}", r16p.mean_ms()),
            format!("{:.3}", rbfp.mean_ms()),
            format!("{:.1}", b32 as f64 / 1e6),
            format!("{:.1}", b16 as f64 / 1e6),
        ]);
        for r in [&r32, &r16, &r16p, &rbfp] {
            rep.result(r);
        }

        // --- acceptance: half the bytes, executed == analytic, exact bits
        let mut serial = template.clone();
        let mut pooled = template.clone();
        let exec_serial = ring_allreduce_half(&mut serial, DType::F16);
        let exec_pooled = ring_allreduce_half_pooled(&mut pooled, DType::F16, &pool);
        assert_eq!(serial, pooled, "w={w} n={n}: serial vs pooled half bits");
        assert_eq!(exec_serial, b16, "executed wire bytes vs analytic");
        assert_eq!(exec_pooled, b16);
        assert_eq!(b16 * 2, b32, "fp16 wire must move half the fp32 bytes");
    }
    t2.print();
    println!(
        "\n(the in-process half path pays conversion compute for the byte \
         saving a real NIC would pocket; the α-β model below prices the \
         wire side at paper scale)"
    );
    rep.metric("wire_bytes_ratio_f16_over_f32", 0.5);

    // ---- modeled step time: the β term halves ----------------------------
    println!("\n=== α-β model: fp32 vs fp16 wire on the paper's testbed ===\n");
    let c = ClusterSpec::p3dn(192);
    let (batch, seq, slots) = (98304, 128, 20);
    let mut t3 = Table::new(&["collective", "fp32 step", "fp16 step", "comm saved"]);
    for coll in [Collective::AllReduce, Collective::ReduceScatterGather] {
        let t32 = c.step_time_with_wire(&BERT_LARGE, batch, seq, slots, coll, 4.0);
        let t16 = c.step_time_with_wire(&BERT_LARGE, batch, seq, slots, coll, 2.0);
        let base = c.step_time_with_wire(&BERT_LARGE, batch, seq, slots, coll, 0.0);
        let (beta32, beta16) = (t32 - base, t16 - base);
        t3.row(&[
            format!("{coll:?}"),
            format!("{t32:.3}s"),
            format!("{t16:.3}s"),
            format!("{:.1}%", (1.0 - beta16 / beta32) * 100.0),
        ]);
        // exact linearity: half the bytes is exactly half the β term
        assert!(
            (beta16 - beta32 / 2.0).abs() <= 1e-9 * beta32,
            "{coll:?}: β16 = {beta16} vs β32/2 = {}",
            beta32 / 2.0
        );
        if coll == Collective::AllReduce {
            rep.metric("model_beta_s_fp32_allreduce", beta32);
            rep.metric("model_beta_s_fp16_allreduce", beta16);
        }
    }
    t3.print();

    rep.write().expect("writing BENCH_mixed_precision.json");
    println!("\nfp16 wire: half the bytes, exactly half the modeled β term ✔");
}
