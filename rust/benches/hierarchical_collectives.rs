//! Hierarchical-collectives bench: the executed two-tier ring vs the
//! node-oblivious flat ring over a `nodes × gpus_per_node` sweep — wire
//! traffic split by tier, exact-bit parity with the flat path at fp32, and
//! the α-β model pricing what the tiered schedule buys at paper scale.
//!
//! Acceptance (runs under `--quick` in CI):
//!   * fp32/fp32 `hierarchical_allreduce` is exact-bit equal to the flat
//!     `ring_allreduce` at every swept topology, serial and pooled;
//!   * executed intra/inter wire bytes equal the analytic
//!     `cost::tiered_ring_*_wire_bytes` terms;
//!   * at ≥ 2 nodes the inter-node bytes shrink by ≥ gpus_per_node× vs the
//!     flat ring (exactly gpus_per_node× at equal chunks);
//!   * a bf16 inter tier halves the inter bytes again, bit-identical
//!     serial vs pooled.
//!
//! Numbers land in `BENCH_hierarchical_collectives.json` via `Reporter`.

use lans::cluster::BERT_LARGE;
use lans::collective::cost::{
    flat_gpu_ring_time_s, hierarchical_allreduce_shard_aware_time_s,
    hierarchical_allreduce_time_s,
};
use lans::collective::{
    hierarchical_allreduce, hierarchical_allreduce_pooled, hierarchical_allreduce_wire_bytes,
    ring_allreduce,
};
use lans::precision::DType;
use lans::topology::{TierLinks, TierPrecision, Topology};
use lans::util::bench::{bench, quick_mode, Reporter, Table};
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let mut rep = Reporter::new("hierarchical_collectives");
    let iters = if quick { 3 } else { 10 };
    let pool = ThreadPool::new(ThreadPool::available());
    let n: usize = if quick { 1 << 16 } else { 1 << 18 }; // divisible by every W below

    println!(
        "=== two-tier ring vs flat ring (N = {n} floats{}) ===\n",
        if quick { ", --quick" } else { "" }
    );
    let grids: &[(usize, usize)] =
        if quick { &[(2, 2), (2, 4)] } else { &[(1, 4), (2, 2), (2, 4), (4, 2), (4, 4), (4, 8)] };

    let mut t = Table::new(&[
        "topology",
        "W",
        "flat ms",
        "hier ms",
        "hier pooled ms",
        "bf16-inter ms",
        "flat inter MB",
        "hier inter MB",
        "shrink",
    ]);
    for &(nodes, gpus) in grids {
        let w = nodes * gpus;
        assert_eq!(n % w, 0, "sweep sizes keep chunks equal");
        let topo = Topology::grid(nodes, gpus);
        let flat_topo = Topology::flat(w);
        let mut rng = Rng::new((nodes * 37 + gpus) as u64);
        let template: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut bufs = template.clone();

        let r_flat = bench(&format!("flat/{nodes}x{gpus}"), 1, iters, || {
            bufs.clone_from(&template);
            ring_allreduce(std::hint::black_box(&mut bufs));
        });
        let r_hier = bench(&format!("hier/{nodes}x{gpus}"), 1, iters, || {
            bufs.clone_from(&template);
            hierarchical_allreduce(
                std::hint::black_box(&mut bufs),
                &topo,
                TierPrecision::fp32(),
            );
        });
        let r_hier_p = bench(&format!("hier_pooled/{nodes}x{gpus}"), 1, iters, || {
            bufs.clone_from(&template);
            hierarchical_allreduce_pooled(
                std::hint::black_box(&mut bufs),
                &topo,
                TierPrecision::fp32(),
                &pool,
            );
        });
        let r_bf16 = bench(&format!("hier_bf16/{nodes}x{gpus}"), 1, iters, || {
            bufs.clone_from(&template);
            hierarchical_allreduce_pooled(
                std::hint::black_box(&mut bufs),
                &topo,
                TierPrecision::half_inter(DType::Bf16),
                &pool,
            );
        });

        // --- acceptance: exact-bit parity + byte accounting ---------------
        let mut reference = template.clone();
        ring_allreduce(&mut reference);
        let mut serial = template.clone();
        let mut pooled = template.clone();
        let wb_serial = hierarchical_allreduce(&mut serial, &topo, TierPrecision::fp32());
        let wb_pooled =
            hierarchical_allreduce_pooled(&mut pooled, &topo, TierPrecision::fp32(), &pool);
        assert_eq!(serial, reference, "{topo}: fp32 hier != flat ring bits");
        assert_eq!(pooled, reference, "{topo}: fp32 pooled hier != flat ring bits");
        let analytic = hierarchical_allreduce_wire_bytes(&topo, n, TierPrecision::fp32());
        assert_eq!(wb_serial, analytic, "{topo}: executed != analytic bytes");
        assert_eq!(wb_pooled, analytic, "{topo}: pooled executed != analytic bytes");

        let mut flat_bufs = template.clone();
        let wb_flat =
            hierarchical_allreduce(&mut flat_bufs, &flat_topo, TierPrecision::fp32());
        assert_eq!(flat_bufs, reference, "flat({w}) must be the flat ring");
        if nodes >= 2 {
            assert!(
                wb_flat.inter >= gpus as u64 * analytic.inter,
                "{topo}: inter bytes must shrink >= {gpus}x \
                 (flat {} vs hier {})",
                wb_flat.inter,
                analytic.inter
            );
            // at equal chunks the shrink is exact
            assert_eq!(wb_flat.inter, gpus as u64 * analytic.inter, "{topo}");
        }

        // bf16 inter tier: bit-identical serial vs pooled, half the inter
        // bytes of the fp32 tiered ring, intra bytes unchanged
        let prec_bf = TierPrecision::half_inter(DType::Bf16);
        let mut bf_serial = template.clone();
        let mut bf_pooled = template.clone();
        let wb_bf_s = hierarchical_allreduce(&mut bf_serial, &topo, prec_bf);
        let wb_bf_p = hierarchical_allreduce_pooled(&mut bf_pooled, &topo, prec_bf, &pool);
        assert_eq!(bf_serial, bf_pooled, "{topo}: bf16 serial vs pooled bits");
        assert_eq!(wb_bf_s, wb_bf_p);
        assert_eq!(wb_bf_s, hierarchical_allreduce_wire_bytes(&topo, n, prec_bf));
        if nodes >= 2 {
            assert_eq!(wb_bf_s.inter * 2, analytic.inter, "{topo}: bf16 halves inter");
        }
        assert_eq!(wb_bf_s.intra, analytic.intra, "{topo}: intra tier stays fp32");

        let shrink = if analytic.inter > 0 {
            wb_flat.inter as f64 / analytic.inter as f64
        } else {
            f64::INFINITY
        };
        t.row(&[
            topo.to_string(),
            w.to_string(),
            format!("{:.3}", r_flat.mean_ms()),
            format!("{:.3}", r_hier.mean_ms()),
            format!("{:.3}", r_hier_p.mean_ms()),
            format!("{:.3}", r_bf16.mean_ms()),
            format!("{:.1}", wb_flat.inter as f64 / 1e6),
            format!("{:.1}", analytic.inter as f64 / 1e6),
            format!("{shrink:.1}x"),
        ]);
        for r in [&r_flat, &r_hier, &r_hier_p, &r_bf16] {
            rep.result(r);
        }
        if nodes >= 2 {
            rep.metric(&format!("inter_shrink_{nodes}x{gpus}"), shrink);
        }
    }
    t.print();
    println!(
        "\n(in-process the tiers only relabel which link a hop uses; the \
         byte split is what a real NIC pockets — the α-β model below \
         prices it at paper scale)"
    );

    // ---- α-β model: the paper's 192×8 testbed ----------------------------
    println!("\n=== α-β model: BERT-Large allreduce on 192 x 8 V100 (EFA inter) ===\n");
    let links = TierLinks::default();
    let bytes = BERT_LARGE.param_bytes_f32();
    let (nodes, gpus) = (192usize, 8usize);
    let flat_s = flat_gpu_ring_time_s(nodes, gpus, bytes, links.inter);
    let naive_s = hierarchical_allreduce_time_s(nodes, gpus, bytes, links.intra, links.inter);
    let aware_s =
        hierarchical_allreduce_shard_aware_time_s(nodes, gpus, bytes, links.intra, links.inter);
    let mut t2 = Table::new(&["schedule", "modeled s", "vs flat"]);
    for (label, s) in [
        ("flat ring (NIC shared by 8 GPUs)", flat_s),
        ("hierarchical, naive full-message inter", naive_s),
        ("hierarchical, shard-aware inter", aware_s),
    ] {
        t2.row(&[label.to_string(), format!("{s:.3}"), format!("{:.1}x", flat_s / s)]);
    }
    t2.print();
    assert!(naive_s < flat_s, "hierarchical must beat the shared-NIC flat ring");
    assert!(aware_s < naive_s, "shard-aware must beat the naive inter ring");
    rep.metric("model_flat_s", flat_s);
    rep.metric("model_hier_naive_s", naive_s);
    rep.metric("model_hier_shard_aware_s", aware_s);

    rep.write().expect("writing BENCH_hierarchical_collectives.json");
    println!("\ntwo-tier ring: flat bits, 1/gpus_per_node the inter-node bytes ✔");
}
