//! Table 2 (convergence columns) at laptop scale: the batch-scaling ladder.
//!
//! Protocol (the paper's, §3.3/§4, scaled down):
//!   * fixed token budget across rungs — batch k× larger ⇒ k× fewer steps
//!   * learning rate follows the sqrt rule: eta = sqrt(k) · eta_ref
//!   * "target quality" = the reference (small-batch) run's final eval loss
//!     (the stand-in for F1 ≥ 90.5)
//!
//! Expected shape (paper's Table 2):
//!   LAMB @ mid rung  (64K analogue)  → reaches target
//!   LAMB @ big rung  (96K analogue)  → fails / clearly degrades
//!   LANS @ big rung  (96K analogue)  → reaches target in the fewest steps
//!
//! Runs real bert-tiny training through the AOT fwd/bwd artifact with the
//! paper's stage-1 schedule shape on every rung.  Set LANS_FAST=1 to run a
//! quarter-budget smoke version.

use std::path::PathBuf;

use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::{sqrt_scaled_lr, Hyper};
use lans::precision::{DType, LossScale};
use lans::runtime::Engine;
use lans::topology::Topology;
use lans::util::bench::Table;

fn main() {
    let meta = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let fast = std::env::var("LANS_FAST").is_ok();
    let scale = if fast { 4 } else { 1 };

    let engine = Engine::cpu().expect("pjrt engine");
    let data = DataConfig {
        source: "synthetic".into(),
        vocab: 2048,
        corpus_tokens: 64 * 1500,
        seed: 7,
    };

    let eta_ref = 0.05; // reference LR at the base batch
    let base_batch = 16usize;
    let base_steps = 240u64 / scale as u64;

    // (label, batch multiplier, optimizer)
    let ladder: &[(&str, usize, &str)] = &[
        ("reference  (32K analogue)", 1, "lamb"),
        ("LAMB  2x   (64K analogue)", 2, "lamb"),
        ("LAMB  4x   (96K analogue)", 4, "lamb"),
        ("LANS  4x   (96K analogue)", 4, "lans"),
    ];

    let mut rows = Vec::new();
    for (label, mult, opt) in ladder {
        let batch = base_batch * mult;
        let steps = base_steps / *mult as u64;
        let eta = sqrt_scaled_lr(eta_ref, base_batch, batch);
        let cfg = TrainConfig {
            meta_path: meta.clone(),
            optimizer: opt.to_string(),
            backend: OptBackend::Native,
            workers: 4,
            threads: 0, // auto: block-parallel update path
            shard_optimizer: false,
            resume_opt_state: false,
            topology: Topology::flat(4),
            grad_dtype: DType::F32,
            intra_dtype: DType::F32,
            loss_scale: LossScale::Off,
            bucket_mb: 0,
            overlap: true,
            relaxed_collectives: false,
            global_batch: batch,
            steps,
            seed: 1,
            eval_every: 0,
            eval_batches: 6,
            hyper: Hyper::default(),
            schedule: TrainConfig::paper_stage1_schedule(eta, steps),
            data: data.clone(),
            checkpoint: None,
            resume_from: None,
            curve_out: Some(
                format!("target/table2_{}_{}x.tsv", opt, mult).into(),
            ),
            trace: None,
            metrics: MetricsConfig::default(),
            stop_on_divergence: false,
            flight: FlightConfig::default(),
            inject_failure: None,
        };
        let mut tr = Trainer::with_engine(cfg, engine.clone()).expect("trainer");
        eprintln!("running {label}: batch {batch}, {steps} steps, eta {eta:.4} …");
        let rep = tr.run().expect("train");
        let eval = rep.final_eval_loss.unwrap_or(f64::INFINITY);
        rows.push((label.to_string(), *mult, *opt, steps, eta, eval, rep.status));
    }

    let target = rows[0].5; // reference eval loss = the quality bar
    // "comparable quality" bar: within 0.05 nats of the reference eval loss
    // (the F1-90.5 analogue)
    let tol = 0.05;
    println!("\n=== Table 2 (convergence), laptop scale ===");
    println!("target quality: eval loss <= {:.4} + {tol} (reference run)\n", target);
    let mut t = Table::new(&[
        "run", "batch", "steps", "eta (sqrt rule)", "eval loss", "reaches target?",
    ]);
    for (label, mult, _opt, steps, eta, eval, status) in &rows {
        let reached = *eval <= target + tol
            && matches!(status, TrainStatus::Completed);
        t.row(&[
            label.clone(),
            format!("{}x", mult),
            steps.to_string(),
            format!("{eta:.4}"),
            format!("{eval:.4}"),
            if reached { "yes".into() } else { "NO".into() },
        ]);
    }
    t.print();

    let lamb_big = rows[2].5;
    let lans_big = rows[3].5;
    println!(
        "\nat the 96K-analogue rung: LANS eval {lans_big:.4} vs LAMB eval \
         {lamb_big:.4} (paper: LANS 90.60 F1, LAMB diverges)"
    );
    assert!(
        lans_big < lamb_big,
        "shape violated: LANS must beat LAMB at the largest (batch, lr)"
    );
    println!("ordering matches the paper ✔");
}
