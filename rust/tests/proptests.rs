//! Property-based tests over coordinator/optimizer/data invariants.
//!
//! proptest is unavailable offline; `for_cases` drives each property over
//! many seeded random cases (shrinking is traded for a printed failing seed,
//! which reproduces deterministically).

use lans::collective::{
    hierarchical_allreduce, hierarchical_allreduce_pooled, hierarchical_allreduce_wire_bytes,
    hierarchical_phase_wire_bytes, hierarchical_reduce_scatter,
    hierarchical_reduce_scatter_pooled, ring_all_gather, ring_all_gather_half,
    ring_all_gather_half_pooled, ring_all_gather_pooled, ring_allreduce, ring_allreduce_half,
    ring_allreduce_half_pooled, ring_allreduce_pooled, ring_reduce_scatter,
    ring_reduce_scatter_half, ring_reduce_scatter_half_pooled, ring_reduce_scatter_pooled,
};
use lans::coordinator::{replicated_bucketed_step, sharded_bucketed_step};
use lans::data::{make_shards, WithReplacementSampler};
use lans::optim::schedule::{from_ratios, sqrt_scaled_lr, Schedule};
use lans::optim::{
    make_optimizer, scatter_to_plan, BlockTable, Hyper, Optimizer, ParallelExecutor, ShardPlan,
    ShardedOptimizer,
};
use lans::precision::half::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
};
use lans::precision::DType;
use lans::simd::{self, AdamK};
use lans::topology::{TierPrecision, Topology};
use lans::util::json::Json;
use lans::util::pool::ThreadPool;
use lans::util::rng::Rng;

/// Run `f` for `cases` seeded cases; panics carry the failing seed.
fn for_cases(cases: u64, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBA5E ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(seed, &mut rng)
        }));
        if let Err(e) = result {
            eprintln!(">>> property failed at case seed = {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------------------
// schedule properties
// ---------------------------------------------------------------------------

#[test]
fn prop_schedule_bounds_and_peak() {
    for_cases(200, |_, rng| {
        let t_total = 50 + rng.below(5000);
        let rw = rng.next_f64() * 0.5;
        let rc = rng.next_f64() * (0.99 - rw);
        let eta = 1e-4 + rng.next_f64() * 0.1;
        let s = from_ratios(eta, t_total, rw, rc);
        let mut peak: f64 = 0.0;
        for t in 1..=t_total {
            let lr = s.lr(t);
            assert!(lr >= -1e-15 && lr <= eta * (1.0 + 1e-9),
                    "lr {lr} outside [0, {eta}] at t={t}");
            peak = peak.max(lr);
        }
        // the peak is achieved (warmup ends somewhere inside the run)
        assert!(peak > eta * 0.9, "peak {peak} never approaches eta {eta}");
    });
}

#[test]
fn prop_eq9_auc_dominates_eq8_at_same_eta() {
    // the whole point of eq. 9: more area under the curve at the same peak
    for_cases(100, |_, rng| {
        let t_total = 100 + rng.below(3000);
        let tw = 1 + rng.below(t_total / 2);
        let tc = rng.below(t_total - tw);
        let eta = 0.01;
        let eq8 = Schedule::LinearWarmupDecay { eta, t_warmup: tw, t_total };
        let eq9 = Schedule::WarmupConstDecay { eta, t_warmup: tw, t_const: tc, t_total };
        assert!(
            eq9.area_under_curve(t_total) >= eq8.area_under_curve(t_total) - 1e-9
        );
    });
}

#[test]
fn prop_sqrt_scaling_monotone() {
    for_cases(100, |_, rng| {
        let base = 1 + rng.below_usize(1 << 14);
        let k1 = base * (1 + rng.below_usize(8));
        let k2 = k1 * (1 + rng.below_usize(8));
        let lr0 = 0.001;
        let l1 = sqrt_scaled_lr(lr0, base, k1);
        let l2 = sqrt_scaled_lr(lr0, base, k2);
        assert!(l2 >= l1 - 1e-12);
        // exact law
        assert!((l1 / lr0 - ((k1 as f64) / (base as f64)).sqrt()).abs() < 1e-12);
    });
}

// ---------------------------------------------------------------------------
// sharding properties
// ---------------------------------------------------------------------------

#[test]
fn prop_shards_are_disjoint_partition() {
    for_cases(100, |seed, rng| {
        let workers = 1 + rng.below_usize(12);
        let n = workers + rng.below_usize(2000);
        let shards = make_shards(n, workers, seed);
        let mut seen = vec![false; n];
        let mut total = 0;
        for mut s in shards {
            let len = s.len();
            total += len;
            // draw a full epoch and check coverage of the shard
            let mut got = std::collections::HashSet::new();
            let bs = 1 + rng.below_usize(len);
            while s.epoch() == 0 {
                for i in s.next_batch(bs.min(len)) {
                    assert!(i < n);
                    got.insert(i);
                }
                if got.len() == len {
                    break;
                }
            }
            for i in got {
                assert!(!seen[i], "sample {i} in two shards");
                seen[i] = true;
            }
        }
        assert_eq!(total, n);
    });
}

#[test]
fn prop_epoch_coverage_without_replacement() {
    // within one epoch every shard element appears exactly once
    for_cases(60, |seed, rng| {
        let n = 8 + rng.below_usize(256);
        let mut shard = make_shards(n, 1, seed).remove(0);
        let bs = 1 + rng.below_usize(n.min(16));
        let full_batches = n / bs;
        let mut counts = vec![0usize; n];
        for _ in 0..full_batches {
            for i in shard.next_batch(bs) {
                counts[i] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c <= 1));
        assert_eq!(counts.iter().sum::<usize>(), full_batches * bs);
    });
}

#[test]
fn prop_with_replacement_has_collisions_wo_has_none() {
    for_cases(40, |seed, rng| {
        let n = 32 + rng.below_usize(128);
        let mut wr = WithReplacementSampler::new(n, seed);
        // birthday bound: k = n samples with replacement collide w.h.p.
        let batch = wr.next_batch(n);
        let uniq: std::collections::HashSet<_> = batch.iter().collect();
        // not a hard guarantee per-case, but overwhelmingly likely for n≥32:
        // P(no collision) = n!/n^n < e^{-n/3}
        assert!(uniq.len() < n, "n={n}: with-replacement drew a permutation");
        let _ = rng;
    });
}

// ---------------------------------------------------------------------------
// allreduce properties
// ---------------------------------------------------------------------------

#[test]
fn prop_allreduce_matches_reference_sum() {
    for_cases(100, |_, rng| {
        let w = 1 + rng.below_usize(9);
        let n = rng.below_usize(300);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let reference: Vec<f64> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i] as f64).sum())
            .collect();
        ring_allreduce(&mut bufs);
        for b in &bufs {
            for (got, want) in b.iter().zip(&reference) {
                assert!(
                    ((*got as f64) - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "{got} vs {want} (w={w}, n={n})"
                );
            }
        }
    });
}

#[test]
fn prop_pooled_allreduce_bit_identical_to_serial() {
    // n straddles POOLED_MIN_ELEMS (4096): below it the serial fallback is
    // exercised, above it the chunk-parallel path proper
    for_cases(60, |_, rng| {
        let w = 1 + rng.below_usize(9);
        let n = rng.below_usize(12_000);
        let threads = 1 + rng.below_usize(8);
        let template: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut serial = template.clone();
        let mut pooled = template;
        ring_allreduce(&mut serial);
        ring_allreduce_pooled(&mut pooled, &ThreadPool::new(threads));
        assert_eq!(serial, pooled, "w={w} n={n} threads={threads}");
    });
}

#[test]
fn prop_reduce_scatter_then_all_gather_is_allreduce_bit_for_bit() {
    // the identity the sharded-optimizer path rests on, for both the
    // serial and the pooled halves, across worker counts and sizes that
    // straddle POOLED_MIN_ELEMS
    for_cases(60, |_, rng| {
        let w = 1 + rng.below_usize(9);
        let n = rng.below_usize(12_000);
        let threads = 1 + rng.below_usize(8);
        let template: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut reference = template.clone();
        ring_allreduce(&mut reference);

        let mut serial = template.clone();
        ring_reduce_scatter(&mut serial);
        ring_all_gather(&mut serial);
        assert_eq!(serial, reference, "serial halves (w={w} n={n})");

        let pool = ThreadPool::new(threads);
        let mut pooled = template;
        ring_reduce_scatter_pooled(&mut pooled, &pool);
        ring_all_gather_pooled(&mut pooled, &pool);
        assert_eq!(pooled, reference, "pooled halves (w={w} n={n} threads={threads})");
    });
}

// ---------------------------------------------------------------------------
// topology / hierarchical-collective properties
// ---------------------------------------------------------------------------

/// All `nodes × gpus` factorizations of `w`.
fn factorizations(w: usize) -> Vec<Topology> {
    (1..=w).filter(|d| w % d == 0).map(|d| Topology::grid(d, w / d)).collect()
}

#[test]
fn prop_hierarchical_fp32_exact_bit_equals_flat_ring() {
    // the tentpole contract: with both tiers fp32, the executed two-tier
    // ring is the flat ring bit for bit — for every W in {1,2,4,8}, every
    // nodes×gpus factorization, serial and pooled, and the reduce-scatter
    // half on its own (the postcondition the sharded optimizer consumes);
    // executed wire bytes always equal the analytic cost terms
    for_cases(15, |_, rng| {
        let n = rng.below_usize(9000);
        let threads = 1 + rng.below_usize(8);
        let pool = ThreadPool::new(threads);
        for w in [1usize, 2, 4, 8] {
            let template: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut reference = template.clone();
            ring_allreduce(&mut reference);
            let mut rs_reference = template.clone();
            ring_reduce_scatter(&mut rs_reference);

            for topo in factorizations(w) {
                let prec = TierPrecision::fp32();
                let mut serial = template.clone();
                let mut pooled = template.clone();
                let ws = hierarchical_allreduce(&mut serial, &topo, prec);
                let wp = hierarchical_allreduce_pooled(&mut pooled, &topo, prec, &pool);
                assert_eq!(serial, reference, "{topo} n={n}: serial != flat ring");
                assert_eq!(pooled, reference, "{topo} n={n}: pooled != flat ring");
                let analytic = hierarchical_allreduce_wire_bytes(&topo, n, prec);
                assert_eq!(ws, analytic, "{topo} n={n}: serial bytes");
                assert_eq!(wp, analytic, "{topo} n={n}: pooled bytes");

                let mut rs = template.clone();
                hierarchical_reduce_scatter(&mut rs, &topo, prec);
                assert_eq!(rs, rs_reference, "{topo} n={n}: reduce-scatter bits");
            }
        }
    });
}

#[test]
fn prop_hierarchical_half_inter_replicas_bit_identical() {
    // with an f16/bf16 inter tier the result is still a deterministic
    // function of the inputs: serial == pooled == a re-run, every replica
    // ends with the same bits, and the executed intra/inter byte split
    // matches the analytic terms (intra stays at 4 bytes/elem, inter
    // drops to 2)
    for_cases(10, |_, rng| {
        let n = rng.below_usize(9000);
        let threads = 2 + rng.below_usize(7);
        let pool = ThreadPool::new(threads);
        for wire in [DType::F16, DType::Bf16] {
            for w in [2usize, 4, 8] {
                let template: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                    .collect();
                for topo in factorizations(w) {
                    let prec = TierPrecision::half_inter(wire);
                    let mut serial = template.clone();
                    let mut again = template.clone();
                    let mut pooled = template.clone();
                    let ws = hierarchical_allreduce(&mut serial, &topo, prec);
                    let wa = hierarchical_allreduce(&mut again, &topo, prec);
                    let wp = hierarchical_allreduce_pooled(&mut pooled, &topo, prec, &pool);
                    assert_eq!(serial, again, "{} {topo}: not deterministic", wire.name());
                    assert_eq!(serial, pooled, "{} {topo}: pooled diverged", wire.name());
                    assert_eq!(ws, wa);
                    assert_eq!(ws, wp, "{} {topo}: byte counts diverged", wire.name());
                    for b in &serial[1..] {
                        assert_eq!(&serial[0], b, "{} {topo}: replicas disagree", wire.name());
                    }
                    assert_eq!(
                        ws,
                        hierarchical_allreduce_wire_bytes(&topo, n, prec),
                        "{} {topo}: executed != analytic",
                        wire.name()
                    );
                    // single-node grids never touch the inter tier; multi-
                    // node grids must, unless there is nothing to move
                    if topo.nodes == 1 || n == 0 {
                        assert_eq!(ws.inter, 0, "{topo}");
                    } else {
                        assert!(ws.inter > 0, "{topo}");
                    }
                    // the reduce-scatter half alone reports the same split
                    // the phase-level analytic predicts
                    let mut rs = template.clone();
                    let wr = hierarchical_reduce_scatter_pooled(&mut rs, &topo, prec, &pool);
                    assert_eq!(wr, hierarchical_phase_wire_bytes(&topo, n, prec, false));
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// mixed-precision properties
// ---------------------------------------------------------------------------

#[test]
fn prop_f32_path_exact_bit_unchanged_through_precision_entry_points() {
    // acceptance (a): with the subsystem present, routing through the
    // precision-aware wire entry points at DType::F32 is the legacy f32
    // path, bit for bit
    for_cases(40, |_, rng| {
        let w = 1 + rng.below_usize(9);
        let n = rng.below_usize(9000);
        let threads = 1 + rng.below_usize(8);
        let pool = ThreadPool::new(threads);
        let template: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();

        let mut legacy = template.clone();
        let mut wire = template.clone();
        ring_reduce_scatter(&mut legacy);
        ring_reduce_scatter_half(&mut wire, DType::F32);
        assert_eq!(legacy, wire, "rs (w={w} n={n})");
        ring_all_gather(&mut legacy);
        ring_all_gather_half(&mut wire, DType::F32);
        assert_eq!(legacy, wire, "ag (w={w} n={n})");

        let mut legacy = template.clone();
        let mut wire = template;
        ring_allreduce_pooled(&mut legacy, &pool);
        ring_allreduce_half_pooled(&mut wire, DType::F32, &pool);
        assert_eq!(legacy, wire, "allreduce pooled (w={w} n={n} threads={threads})");
    });
}

#[test]
fn prop_half_wire_bit_identical_across_w_and_serial_vs_pooled() {
    // acceptance (b): for every W in 1..=8 and both half formats, the
    // pooled schedule produces exactly the serial schedule's bits, and
    // the serial schedule is deterministic (re-running it reproduces
    // itself) — the half path is a well-defined function of its inputs,
    // independent of execution schedule
    for_cases(12, |_, rng| {
        let n = rng.below_usize(9000);
        let threads = 2 + rng.below_usize(7);
        let pool = ThreadPool::new(threads);
        for wire in [DType::F16, DType::Bf16] {
            for w in 1..=8usize {
                let template: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                    .collect();

                let mut serial = template.clone();
                let mut again = template.clone();
                let mut pooled = template.clone();
                ring_reduce_scatter_half(&mut serial, wire);
                ring_reduce_scatter_half(&mut again, wire);
                ring_reduce_scatter_half_pooled(&mut pooled, wire, &pool);
                assert_eq!(serial, again, "{} rs determinism w={w}", wire.name());
                assert_eq!(serial, pooled, "{} rs pooled w={w} n={n}", wire.name());

                ring_all_gather_half(&mut serial, wire);
                ring_all_gather_half_pooled(&mut pooled, wire, &pool);
                assert_eq!(serial, pooled, "{} ag pooled w={w} n={n}", wire.name());

                let mut serial = template.clone();
                let mut pooled = template;
                ring_allreduce_half(&mut serial, wire);
                ring_allreduce_half_pooled(&mut pooled, wire, &pool);
                assert_eq!(serial, pooled, "{} allreduce w={w} n={n}", wire.name());
                // replicas agree — the replicated trainer's requirement
                for b in &serial[1..] {
                    assert_eq!(&serial[0], b, "{} replicas w={w}", wire.name());
                }
            }
        }
    });
}

#[test]
fn prop_half_conversion_deterministic_monotone_bounded() {
    // satellite: f32 -> half -> f32 is deterministic (idempotent: a value
    // already on the half grid maps to itself), monotone (rounding never
    // reorders), and error-bounded in the format's normal range
    for_cases(120, |_, rng| {
        for wire in [DType::F16, DType::Bf16] {
            let mut xs: Vec<f32> = (0..64)
                .map(|_| {
                    let mag = 10f32.powi(rng.below(12) as i32 - 6);
                    rng.normal_f32() * mag
                })
                .collect();
            for &x in xs.iter() {
                let q = wire.round_trip(x);
                // determinism + idempotence
                assert_eq!(q.to_bits(), wire.round_trip(x).to_bits());
                assert_eq!(q.to_bits(), wire.round_trip(q).to_bits(), "{x}");
                // bounded relative error in the normal range (eps/2 with
                // round-to-nearest: 2^-12 for f16's 10-bit, 2^-9 for
                // bf16's 7-bit mantissa; allow the full eps for slack)
                let (lo, hi, eps) = match wire {
                    DType::F16 => (6.2e-5f32, 6.5e4f32, 2.0f32.powi(-11)),
                    DType::Bf16 => (1.2e-38, 3.3e38, 2.0f32.powi(-8)),
                    DType::F32 => unreachable!(),
                };
                if x.abs() > lo && x.abs() < hi {
                    assert!(
                        (q - x).abs() <= eps * x.abs(),
                        "{}: {x} -> {q}",
                        wire.name()
                    );
                }
            }
            // monotone: sort the inputs, the images must be sorted too
            xs.sort_by(f32::total_cmp);
            let quantized: Vec<f32> = xs.iter().map(|&x| wire.round_trip(x)).collect();
            for pair in quantized.windows(2) {
                assert!(
                    pair[0] <= pair[1],
                    "{}: rounding reordered {} > {}",
                    wire.name(),
                    pair[0],
                    pair[1]
                );
            }
        }
    });
}

/// Pick a random power-of-two loss scale 2^k, k in [1, 20].
fn random_pow2(rng: &mut Rng) -> f32 {
    2.0f32.powi(1 + rng.below(20) as i32)
}

#[test]
fn prop_scaled_step_without_overflow_matches_unscaled_exactly() {
    // acceptance (c): gradients scaled by a power of two, unscaled inside
    // step_scaled, walk exactly the unscaled serial trajectory — params
    // and stats bit for bit, every optimizer
    for_cases(30, |_, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let pool = ThreadPool::new(1 + rng.below_usize(8));
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd"] {
            let hp = Hyper::default();
            let mut plain = make_optimizer(name, table.clone(), hp).unwrap();
            let mut scaled = make_optimizer(name, table.clone(), hp).unwrap();
            let mut xp = x0.clone();
            let mut xs = x0.clone();
            for k in 0..3 {
                let g: Vec<f32> =
                    (0..table.total).map(|_| rng.normal_f32()).collect();
                let s = random_pow2(rng);
                let mut gs: Vec<f32> = g.iter().map(|&v| v * s).collect();
                let lr = 0.005 + 0.004 * k as f32;
                // reference: the parallel step on the raw gradient (the
                // serial == parallel identity is covered elsewhere)
                let st_p = plain.step_parallel(&pool, &mut xp, &g, lr);
                let st_s = scaled
                    .step_scaled(&pool, &mut xs, &mut gs, lr, 1.0 / s)
                    .expect("no overflow in finite gradients");
                assert_eq!(st_p.grad_norm, st_s.grad_norm, "{name} s={s}");
                assert_eq!(st_p.mean_trust_ratio, st_s.mean_trust_ratio, "{name}");
                assert_eq!(st_p.max_abs_param, st_s.max_abs_param, "{name}");
                // the in-place unscale reproduced the raw gradient exactly
                assert_eq!(g, gs, "{name}: unscale was not exact (s={s})");
            }
            assert_eq!(xp, xs, "{name}: scaled trajectory diverged");
        }
    });
}

#[test]
fn prop_overflow_skips_step_and_leaves_state_untouched() {
    // acceptance (d): an inf/nan gradient makes step_scaled return None
    // with parameters, moments and the step clock untouched — the
    // optimizer continues afterwards exactly as if the bad step never
    // happened
    for_cases(30, |seed, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(4000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let pool = ThreadPool::new(1 + rng.below_usize(8));
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb", "adamw"] {
            let hp = Hyper::default();
            let mut clean = make_optimizer(name, table.clone(), hp).unwrap();
            let mut poked = make_optimizer(name, table.clone(), hp).unwrap();
            let mut xc = x0.clone();
            let mut xk = x0.clone();
            // one good step on both, so moments are non-trivial
            let g0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
            clean.step_parallel(&pool, &mut xc, &g0, 0.01);
            let mut g0s: Vec<f32> = g0.iter().map(|&v| v * 4.0).collect();
            poked.step_scaled(&pool, &mut xk, &mut g0s, 0.01, 0.25).unwrap();
            assert_eq!(xc, xk, "{name}: setup step diverged");

            // the poisoned step: inf or nan at a random position
            let mut bad: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
            let poison = if seed % 2 == 0 { f32::INFINITY } else { f32::NAN };
            bad[rng.below_usize(table.total)] = poison;
            let before = xk.clone();
            assert!(
                poked.step_scaled(&pool, &mut xk, &mut bad, 0.01, 0.5).is_none(),
                "{name}: overflow not detected"
            );
            assert_eq!(before, xk, "{name}: skipped step touched params");

            // continue on clean gradients: bit-identical to the optimizer
            // that never saw the poisoned step (moments + clock untouched)
            let g1: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
            let sc = clean.step_parallel(&pool, &mut xc, &g1, 0.02);
            let mut g1s: Vec<f32> = g1.iter().map(|&v| v * 8.0).collect();
            let sk = poked.step_scaled(&pool, &mut xk, &mut g1s, 0.02, 0.125).unwrap();
            assert_eq!(sc.grad_norm, sk.grad_norm, "{name}");
            assert_eq!(xc, xk, "{name}: post-skip trajectory diverged");
        }
    });
}

#[test]
fn prop_sharded_scaled_step_matches_replicated_and_skips_on_overflow() {
    // the ZeRO-1 side of (c)+(d): step_scattered_scaled with the loss
    // scale folded into the stitch factor matches the replicated
    // trajectory exactly, and a poisoned worker buffer skips the step
    // with all shard state untouched
    for_cases(20, |_, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(9000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 1 + rng.below_usize(6);
        let pool = ThreadPool::new(2 + rng.below_usize(6));
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb"] {
            let hp = Hyper::default();
            let mut rep = make_optimizer(name, table.clone(), hp).unwrap();
            let mut sh = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xr = x0.clone();
            let mut xs = x0.clone();
            for k in 0..2 {
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                    .collect();
                let s = random_pow2(rng);
                let inv = 1.0 / (w as f32);
                let lr = 0.005 + 0.004 * k as f32;

                // replicated reference on the unscaled buffers
                let mut r = bufs.clone();
                ring_allreduce(&mut r);
                let mut grad = std::mem::take(&mut r[0]);
                for g in grad.iter_mut() {
                    *g *= inv;
                }
                let s_rep = rep.step(&mut xr, &grad, lr);

                // sharded on the loss-scaled buffers, unscale in the stitch
                let mut b: Vec<Vec<f32>> = bufs
                    .iter()
                    .map(|buf| buf.iter().map(|&v| v * s).collect())
                    .collect();
                ring_reduce_scatter(&mut b);
                let s_sh = sh
                    .step_scattered_scaled(&pool, &mut xs, &b, inv * (1.0 / s), lr)
                    .expect("no overflow in finite gradients");
                assert_eq!(s_rep.grad_norm, s_sh.grad_norm, "{name} w={w}");
                assert_eq!(s_rep.mean_trust_ratio, s_sh.mean_trust_ratio, "{name}");
                assert_eq!(s_rep.max_abs_param, s_sh.max_abs_param, "{name}");
            }
            assert_eq!(xr, xs, "{name} w={w}: scaled sharded trajectory diverged");

            // poison one worker's buffer: the step must skip cleanly...
            let mut bad: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            bad[rng.below_usize(w)][rng.below_usize(table.total)] = f32::INFINITY;
            ring_reduce_scatter(&mut bad);
            let before = xs.clone();
            let t_before = sh.steps_taken();
            assert!(
                sh.step_scattered_scaled(&pool, &mut xs, &bad, 1.0 / w as f32, 0.01)
                    .is_none(),
                "{name}: poisoned buffer not detected"
            );
            assert_eq!(before, xs, "{name}: skipped sharded step touched params");
            assert_eq!(t_before, sh.steps_taken(), "{name}: skip advanced the clock");

            // ...and the next clean step continues the joint trajectory
            let bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            let inv = 1.0 / w as f32;
            let mut r = bufs.clone();
            ring_allreduce(&mut r);
            let mut grad = std::mem::take(&mut r[0]);
            for g in grad.iter_mut() {
                *g *= inv;
            }
            rep.step(&mut xr, &grad, 0.02);
            let mut b = bufs;
            ring_reduce_scatter(&mut b);
            sh.step_scattered_scaled(&pool, &mut xs, &b, inv, 0.02).unwrap();
            assert_eq!(xr, xs, "{name}: post-skip sharded trajectory diverged");
        }
    });
}

// ---------------------------------------------------------------------------
// optimizer properties
// ---------------------------------------------------------------------------

fn random_table(rng: &mut Rng) -> BlockTable {
    let nblocks = 1 + rng.below_usize(5);
    let specs: Vec<(String, usize, bool)> = (0..nblocks)
        .map(|i| (format!("b{i}"), 1 + rng.below_usize(64), rng.next_f64() < 0.5))
        .collect();
    BlockTable::new(&specs)
}

#[test]
fn prop_lans_step_norm_bounded() {
    // ‖Δx‖ per block ≤ lr·‖x‖ (+ tiny slack), the trust-ratio guarantee
    for_cases(120, |_, rng| {
        let table = random_table(rng);
        let hp = Hyper { weight_decay: 0.0, ..Default::default() };
        let mut opt = make_optimizer("lans", table.clone(), hp).unwrap();
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let lr = 0.001 + rng.next_f32() * 0.3;
        let mut x = x0.clone();
        opt.step(&mut x, &g, lr);
        for b in &table.blocks {
            let r = b.offset..b.offset + b.len;
            let dx: f64 = x[r.clone()]
                .iter()
                .zip(&x0[r.clone()])
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let xn: f64 =
                x0[r].iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            assert!(
                dx <= (lr as f64) * xn * 1.01 + 1e-9,
                "block {}: ‖Δx‖={dx} > lr·‖x‖={}",
                b.name,
                lr as f64 * xn
            );
        }
    });
}

#[test]
fn prop_lans_gradient_scale_invariance() {
    for_cases(80, |_, rng| {
        let table = random_table(rng);
        let hp = Hyper::default();
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let scale = 10f32.powi(rng.below(6) as i32 - 2);
        let gs: Vec<f32> = g.iter().map(|&v| v * scale).collect();
        let mut o1 = make_optimizer("lans", table.clone(), hp).unwrap();
        let mut o2 = make_optimizer("lans", table.clone(), hp).unwrap();
        let mut x1 = x0.clone();
        let mut x2 = x0;
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &gs, 0.01);
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b} (scale {scale})");
        }
    });
}

#[test]
fn prop_plan_parallel_step_bit_identical_to_serial() {
    // the plan-granularity executor contract: across random block tables
    // (including blocks that straddle the 4K reduction segment), thread
    // counts and step counts, the parallel LANS/LAMB/AdamW step is
    // *bit-identical* to the serial step — both paths run the same
    // segment kernels and combine partials in the same (global segment)
    // order, for any cut on the NORM_SEG grid.
    for_cases(40, |_, rng| {
        let nblocks = 1 + rng.below_usize(5);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| {
                (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5)
            })
            .collect();
        let table = BlockTable::new(&specs);
        let threads = 2 + rng.below_usize(7);
        let steps = 1 + rng.below_usize(4);
        // drive step_parallel directly: these tables sit below the
        // executor's PARALLEL_MIN_ELEMS auto-fallback, and the property is
        // about the parallel kernels themselves
        let pool = ThreadPool::new(threads);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb", "adamw", "adamw_bgn"] {
            let hp = Hyper::default();
            let mut o_ser = make_optimizer(name, table.clone(), hp).unwrap();
            let mut o_par = make_optimizer(name, table.clone(), hp).unwrap();
            let mut xs = x0.clone();
            let mut xp = x0.clone();
            for k in 0..steps {
                let g: Vec<f32> =
                    (0..table.total).map(|_| rng.normal_f32()).collect();
                let lr = 0.001 + 0.01 * k as f32;
                let s_ser = o_ser.step(&mut xs, &g, lr);
                let s_par = o_par.step_parallel(&pool, &mut xp, &g, lr);
                assert_eq!(
                    s_ser.mean_trust_ratio, s_par.mean_trust_ratio,
                    "{name}: trust mismatch"
                );
                assert_eq!(s_ser.grad_norm, s_par.grad_norm, "{name}: grad norm mismatch");
                assert_eq!(
                    s_ser.max_abs_param, s_par.max_abs_param,
                    "{name}: max abs param mismatch"
                );
            }
            assert_eq!(
                xs, xp,
                "{name} (threads={threads}, steps={steps}): params diverged"
            );
        }
    });
}

#[test]
fn prop_long_lived_pool_bit_identical_to_fresh_pools() {
    // pool-reuse contract: ONE persistent pool driving many interleaved
    // parallel regions — optimizer steps and ring collectives, across
    // many unrelated cases — produces exactly the bits of a fresh pool
    // per operation.  Guards against region-state leakage between uses
    // (stale cursors, generation mixups, result-slot reuse).
    let shared = ThreadPool::new(4);
    for_cases(25, |_, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 2 + rng.below_usize(4);
        let hp = Hyper::default();
        let mut o_shared = make_optimizer("lans", table.clone(), hp).unwrap();
        let mut o_fresh = make_optimizer("lans", table.clone(), hp).unwrap();
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let mut xs = x0.clone();
        let mut xf = x0;
        for k in 0..3 {
            // a collective on both pools...
            let bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut a = bufs.clone();
            let mut b = bufs;
            ring_allreduce_pooled(&mut a, &shared);
            ring_allreduce_pooled(&mut b, &ThreadPool::new(4));
            assert_eq!(a, b, "allreduce diverged on the long-lived pool");
            // ...then an optimizer step on both, interleaved
            let mut grad = std::mem::take(&mut a[0]);
            let inv = 1.0 / w as f32;
            for g in grad.iter_mut() {
                *g *= inv;
            }
            let lr = 0.01 + 0.002 * k as f32;
            o_shared.step_parallel(&shared, &mut xs, &grad, lr);
            o_fresh.step_parallel(&ThreadPool::new(4), &mut xf, &grad, lr);
            assert_eq!(xs, xf, "optimizer step diverged on the long-lived pool");
        }
    });
}

// ---------------------------------------------------------------------------
// sharded-optimizer properties
// ---------------------------------------------------------------------------

#[test]
fn prop_shard_plan_is_aligned_partition() {
    // boundaries are monotone, cover [0, n), and cut only on the
    // block-local NORM_SEG grid; fragments tile every shard range
    for_cases(80, |_, rng| {
        let nblocks = 1 + rng.below_usize(6);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(12_000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 1 + rng.below_usize(12);
        let plan = ShardPlan::build(&table, w);
        assert_eq!(plan.workers(), w);
        assert_eq!(plan.total(), table.total);
        assert!(plan.starts.windows(2).all(|p| p[0] <= p[1]));
        let mut cursor = 0;
        for s in 0..w {
            for f in plan.fragments(s) {
                let b = &table.blocks[f.block];
                assert_eq!((f.start - b.offset) % ShardPlan::ALIGN, 0, "misaligned cut");
                assert_eq!(f.start, cursor, "fragments must tile in order");
                cursor += f.len;
            }
        }
        assert_eq!(cursor, table.total);
    });
}

#[test]
fn prop_sharded_pipeline_matches_replicated_bit_for_bit() {
    // the full ZeRO-1 step — reduce-scatter, stitch, sharded update —
    // against allreduce + replicated serial update, from the same
    // per-worker gradient buffers: identical trajectories and stats,
    // across random block tables (straddling NORM_SEG), worker counts,
    // steps, and all three sharded execution modes (serial / pooled /
    // pipelined step_scattered, which fuses the stitch with phase A)
    for_cases(30, |seed, rng| {
        let nblocks = 1 + rng.below_usize(5);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(9000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 1 + rng.below_usize(6);
        let steps = 1 + rng.below_usize(3);
        let pool = ThreadPool::new(2 + rng.below_usize(6));
        let mode = seed % 3; // 0 = serial, 1 = pooled, 2 = pipelined
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb"] {
            let hp = Hyper::default();
            let mut rep = make_optimizer(name, table.clone(), hp).unwrap();
            let mut sh = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xr = x0.clone();
            let mut xs = x0.clone();
            for k in 0..steps {
                // per-worker gradient buffers, as the trainer's workers
                // would produce them
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                    .collect();
                let scale = 1.0 / (w as f32 * 3.0); // arbitrary mean factor
                let lr = 0.005 + 0.004 * k as f32;

                // replicated: allreduce, scale, serial step
                let mut r = bufs.clone();
                ring_allreduce(&mut r);
                let mut grad = std::mem::take(&mut r[0]);
                for g in grad.iter_mut() {
                    *g *= scale;
                }
                let s_rep = rep.step(&mut xr, &grad, lr);

                // sharded: reduce-scatter, then one of the three modes
                let mut b = bufs;
                ring_reduce_scatter(&mut b);
                let s_sh = match mode {
                    0 => {
                        let sg = scatter_to_plan(&b, sh.plan(), scale);
                        sh.step(&mut xs, &sg, lr)
                    }
                    1 => {
                        let sg = scatter_to_plan(&b, sh.plan(), scale);
                        sh.step_pooled(&pool, &mut xs, &sg, lr)
                    }
                    _ => sh.step_scattered(&pool, &mut xs, &b, scale, lr),
                };

                assert_eq!(s_rep.grad_norm, s_sh.grad_norm, "{name} w={w} mode={mode}");
                assert_eq!(
                    s_rep.mean_trust_ratio, s_sh.mean_trust_ratio,
                    "{name} w={w} mode={mode}"
                );
                assert_eq!(
                    s_rep.max_abs_param, s_sh.max_abs_param,
                    "{name} w={w} mode={mode}"
                );
            }
            assert_eq!(
                xr, xs,
                "{name} (w={w}, steps={steps}, mode={mode}): trajectory diverged"
            );
        }
    });
}

#[test]
fn prop_sharded_state_reshards_to_any_worker_count() {
    // save at W=w0, restore at W=w1, continue: identical to the replicated
    // serial run over the same gradient stream
    for_cases(20, |_, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(9000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let (w0, w1) = (1 + rng.below_usize(8), 1 + rng.below_usize(8));
        let hp = Hyper::default();
        let gs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();

        // replicated reference over all 4 steps
        let mut rep = make_optimizer("lans", table.clone(), hp).unwrap();
        let mut xr = x0.clone();
        for g in &gs {
            rep.step(&mut xr, g, 0.01);
        }

        // sharded: 2 steps at w0, state roundtrip, 2 more at w1
        let mut a = ShardedOptimizer::from_name("lans", table.clone(), hp, w0).unwrap();
        let mut xs = x0;
        for g in &gs[..2] {
            let sg = a.plan().split(g);
            a.step(&mut xs, &sg, 0.01);
        }
        let (state, step) = (a.export_state(), a.steps_taken());
        let mut b = ShardedOptimizer::from_name("lans", table.clone(), hp, w1).unwrap();
        b.import_state(step, &state).unwrap();
        for g in &gs[2..] {
            let sg = b.plan().split(g);
            b.step(&mut xs, &sg, 0.01);
        }
        assert_eq!(xr, xs, "w0={w0} -> w1={w1}: resharded trajectory diverged");
    });
}

#[test]
fn prop_zero_gradient_keeps_params_finite() {
    for_cases(40, |_, rng| {
        let table = random_table(rng);
        for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd", "nag"] {
            let mut opt =
                make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut x: Vec<f32> =
                (0..table.total).map(|_| rng.normal_f32()).collect();
            let g = vec![0.0f32; table.total];
            for _ in 0..3 {
                opt.step(&mut x, &g, 0.01);
            }
            assert!(
                x.iter().all(|v| v.is_finite()),
                "{name} produced non-finite params on zero gradient"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// simd kernel properties (DESIGN.md §11)
// ---------------------------------------------------------------------------
//
// Each property runs the *dispatched* entry points against the scalar
// reference in the same process.  On an AVX2/NEON runner that is a real
// vector-vs-scalar differential; under LANS_FORCE_SCALAR=1 it degenerates
// to scalar-vs-scalar — which is why CI runs the suite once per backend.

/// An f32 from the half-conversion "interesting" set: normals across many
/// magnitudes, the f16-subnormal and overflow ranges, ±0, ±inf, and NaNs
/// with payloads.
fn interesting_f32(rng: &mut Rng) -> f32 {
    match rng.below(10) {
        0 => 0.0,
        1 => -0.0,
        2 => f32::INFINITY,
        3 => f32::NEG_INFINITY,
        4 => {
            let sign = (rng.below(2) as u32) << 31;
            f32::from_bits(0x7FC0_1234 | sign)
        }
        5 => f32::from_bits(rng.below(1 << 23) as u32), // f32-subnormal / tiny
        6 => rng.normal_f32() * 1e-6,                   // f16-subnormal range
        7 => rng.normal_f32() * 7e4,                    // f16 overflow boundary
        _ => rng.normal_f32() * 10f32.powi(rng.below(8) as i32 - 4),
    }
}

#[test]
fn prop_simd_narrow_and_widen_match_scalar_any_length_and_offset() {
    // satellite: SIMD f32→half == scalar per element for every
    // lane-remainder length and unaligned slice offset, on data covering
    // all rounding/class branches; widening back is bit-exact including
    // NaN payloads
    for_cases(120, |_, rng| {
        let pad = rng.below_usize(8); // shifts 32-byte alignment of the slice
        let n = rng.below_usize(530); // every remainder mod 8 across cases
        let src: Vec<f32> = (0..pad + n).map(|_| interesting_f32(rng)).collect();
        let s = &src[pad..];
        for wire in [DType::F16, DType::Bf16] {
            let (narrow, widen): (fn(f32) -> u16, fn(u16) -> f32) = match wire {
                DType::F16 => (f32_to_f16_bits, f16_bits_to_f32),
                _ => (f32_to_bf16_bits, bf16_bits_to_f32),
            };
            let mut bits = vec![0u16; n];
            match wire {
                DType::F16 => simd::narrow_f16(s, &mut bits),
                _ => simd::narrow_bf16(s, &mut bits),
            }
            for (i, (&b, &x)) in bits.iter().zip(s).enumerate() {
                assert_eq!(b, narrow(x), "{} narrow[{i}] of {x:?}", wire.name());
            }
            let mut back = vec![0.0f32; n];
            match wire {
                DType::F16 => simd::widen_f16(&bits, &mut back),
                _ => simd::widen_bf16(&bits, &mut back),
            }
            for (i, (&f, &b)) in back.iter().zip(&bits).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    widen(b).to_bits(),
                    "{} widen[{i}] of {b:#06x}",
                    wire.name()
                );
            }
        }
    });
}

#[test]
fn prop_simd_fused_hop_kernels_match_their_composition() {
    // the collectives' per-hop kernels (quantize+dequantize+accumulate,
    // widen+accumulate, in-place round-trip) are bit-identical to the
    // three-step composition they replace
    for_cases(80, |_, rng| {
        let n = rng.below_usize(530);
        let src: Vec<f32> = (0..n).map(|_| interesting_f32(rng)).collect();
        let dst0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        for wire in [DType::F16, DType::Bf16] {
            let mut bits = vec![0u16; n];
            let mut wide = vec![0.0f32; n];
            let mut want = dst0.clone();
            let mut got_q = dst0.clone();
            let mut got_w = dst0.clone();
            let mut rt = src.clone();
            match wire {
                DType::F16 => {
                    simd::narrow_f16(&src, &mut bits);
                    simd::widen_f16(&bits, &mut wide);
                    simd::accum_quantized_f16(&src, &mut got_q);
                    simd::accum_widened_f16(&bits, &mut got_w);
                    simd::round_f16(&mut rt);
                }
                _ => {
                    simd::narrow_bf16(&src, &mut bits);
                    simd::widen_bf16(&bits, &mut wide);
                    simd::accum_quantized_bf16(&src, &mut got_q);
                    simd::accum_widened_bf16(&bits, &mut got_w);
                    simd::round_bf16(&mut rt);
                }
            }
            for (d, w) in want.iter_mut().zip(&wide) {
                *d += *w;
            }
            for i in 0..n {
                assert_eq!(got_q[i].to_bits(), want[i].to_bits(), "{} q[{i}]", wire.name());
                assert_eq!(got_w[i].to_bits(), want[i].to_bits(), "{} w[{i}]", wire.name());
                assert_eq!(rt[i].to_bits(), wide[i].to_bits(), "{} rt[{i}]", wire.name());
            }
        }
    });
}

#[test]
fn prop_simd_reductions_and_sweeps_match_portable_bitwise() {
    // the optimizer's segment kernels: the dispatched backend reproduces
    // the canonical portable lane-grid fold bit for bit — sums, updated
    // moments, cached directions and max-|param| alike — at every
    // remainder length (n mod 8 sweeps all tail shapes across cases)
    for_cases(60, |_, rng| {
        let n = rng.below_usize(5000);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

        assert_eq!(
            simd::sum_sq(&g).to_bits(),
            simd::portable::sum_sq(&g).to_bits(),
            "sum_sq (n={n})"
        );

        let inv = 2.0f32.powi(rng.below(8) as i32 - 4);
        let mut gd = g.clone();
        let mut gp = g.clone();
        let sd = simd::unscale_sum_sq(&mut gd, inv);
        let sp = simd::portable::unscale_sum_sq(&mut gp, inv);
        assert_eq!(sd.to_bits(), sp.to_bits(), "unscale_sum_sq (n={n})");
        assert_eq!(gd, gp, "unscaled gradient bytes (n={n})");

        let k = AdamK {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            inv_bc1: 1.0 / (1.0 - 0.9f32.powi(3)),
            inv_bc2: 1.0 / (1.0 - 0.999f32.powi(3)),
            lr: 0.01,
            wd: 0.01,
            inv_gnorm: 0.5,
        };
        let m0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
        let v0: Vec<f32> = (0..n).map(|_| rng.normal_f32().abs() * 0.01).collect();

        // LANS moment/direction sweep + apply
        let (mut md, mut vd) = (m0.clone(), v0.clone());
        let (mut mp, mut vp) = (m0.clone(), v0.clone());
        let (mut rd, mut cd) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut rp, mut cp) = (vec![0.0f32; n], vec![0.0f32; n]);
        let a = simd::lans_segment(&k, &x, &g, &mut md, &mut vd, &mut rd, &mut cd);
        let b = simd::portable::lans_segment(&k, &x, &g, &mut mp, &mut vp, &mut rp, &mut cp);
        assert_eq!(
            (a.0.to_bits(), a.1.to_bits(), a.2.to_bits()),
            (b.0.to_bits(), b.1.to_bits(), b.2.to_bits()),
            "lans_segment partials (n={n})"
        );
        assert_eq!(md, mp, "lans m");
        assert_eq!(vd, vp, "lans v");
        assert_eq!(rd, rp, "lans r");
        assert_eq!(cd, cp, "lans c");
        let (mut xd, mut xp) = (x.clone(), x.clone());
        let ad = simd::lans_apply(0.01, 0.02, &mut xd, &rd, &cd);
        let ap = simd::portable::lans_apply(0.01, 0.02, &mut xp, &rp, &cp);
        assert_eq!(ad.to_bits(), ap.to_bits(), "lans_apply max");
        assert_eq!(xd, xp, "lans_apply params");

        // LAMB sweep + apply
        let (mut md, mut vd) = (m0.clone(), v0.clone());
        let (mut mp, mut vp) = (m0.clone(), v0.clone());
        let (mut ud, mut up) = (vec![0.0f32; n], vec![0.0f32; n]);
        let a = simd::lamb_segment(&k, &x, &g, &mut md, &mut vd, &mut ud);
        let b = simd::portable::lamb_segment(&k, &x, &g, &mut mp, &mut vp, &mut up);
        assert_eq!(
            (a.0.to_bits(), a.1.to_bits(), a.2.to_bits()),
            (b.0.to_bits(), b.1.to_bits(), b.2.to_bits()),
            "lamb_segment partials (n={n})"
        );
        assert_eq!(md, mp, "lamb m");
        assert_eq!(vd, vp, "lamb v");
        assert_eq!(ud, up, "lamb u");
        let (mut xd, mut xp) = (x.clone(), x.clone());
        let ad = simd::axpy_max(0.003, &mut xd, &ud);
        let ap = simd::portable::axpy_max(0.003, &mut xp, &up);
        assert_eq!(ad.to_bits(), ap.to_bits(), "axpy_max max");
        assert_eq!(xd, xp, "axpy_max params");

        // AdamW fused sweep
        let (mut md, mut vd) = (m0.clone(), v0.clone());
        let (mut mp, mut vp) = (m0, v0);
        let (mut xd, mut xp) = (x.clone(), x.clone());
        let ad = simd::adamw_segment(&k, &mut xd, &g, &mut md, &mut vd);
        let ap = simd::portable::adamw_segment(&k, &mut xp, &g, &mut mp, &mut vp);
        assert_eq!(ad.to_bits(), ap.to_bits(), "adamw max");
        assert_eq!(md, mp, "adamw m");
        assert_eq!(vd, vp, "adamw v");
        assert_eq!(xd, xp, "adamw params");
    });
}

// ---------------------------------------------------------------------------
// json parser properties
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_numbers() {
    for_cases(200, |_, rng| {
        let x = (rng.normal() * 1e3 * 10f64.powi(rng.below(6) as i32 - 3)) as f64;
        let s = format!("{x:?}");
        let v = Json::parse(&s).unwrap();
        let back = v.as_f64().unwrap();
        let rel = (back - x).abs() / x.abs().max(1e-300);
        assert!(rel < 1e-12, "{x} -> {back}");
    });
}

#[test]
fn prop_json_never_panics_on_garbage() {
    for_cases(300, |_, rng| {
        let len = rng.below_usize(64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789truefalsenul\\"[rng.below_usize(31)])
            .collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = Json::parse(&s); // must return, not panic
    });
}

// ---------------------------------------------------------------------------
// bucketed step-DAG properties (DESIGN.md §9)
// ---------------------------------------------------------------------------

/// A random wire-precision config: mostly fp32, plus half inter tiers and
/// a uniform half wire — the trainer's full precision surface.
fn random_prec(rng: &mut Rng) -> TierPrecision {
    match rng.below(6) {
        0 | 1 => TierPrecision::fp32(),
        2 => TierPrecision::half_inter(DType::Bf16),
        3 => TierPrecision::half_inter(DType::F16),
        4 => TierPrecision::uniform(DType::Bf16),
        _ => TierPrecision::uniform(DType::F16),
    }
}

/// A random bucket grid for `table`: sometimes the single-bucket
/// degenerate cut, otherwise a small target so several NORM_SEG grid
/// points become cuts.
fn random_cuts(rng: &mut Rng, table: &BlockTable) -> Vec<usize> {
    let target = if rng.next_f64() < 0.25 {
        0
    } else {
        1 + rng.below_usize(2 * ShardPlan::ALIGN)
    };
    ShardPlan::bucket_starts(table, target)
}

#[test]
fn prop_bucketed_sharded_step_exact_bit_equals_phase_sync() {
    // the tentpole contract, ZeRO-1 side: the bucketed step DAG — comm of
    // bucket k overlapped with the stitch of bucket k-1 — walks exactly
    // the phase-synchronous trajectory (params, stats, executed wire
    // bytes), across optimizers × topologies × wire precisions × bucket
    // grids, probed and unprobed, overlap on and off
    for_cases(10, |seed, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(9000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let n = table.total;
        let w = [1usize, 2, 4, 8][rng.below_usize(4)];
        let topos = factorizations(w);
        let topo = topos[rng.below_usize(topos.len())];
        let prec = random_prec(rng);
        let pool = ThreadPool::new(2 + rng.below_usize(6));
        let cuts = random_cuts(rng, &table);
        let probe = seed % 2 == 1;
        let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb"] {
            let hp = Hyper::default();
            let mut o_ref = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut o_ser = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut o_ovl = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut x_ref = x0.clone();
            let mut x_ser = x0.clone();
            let mut x_ovl = x0.clone();
            for k in 0..2u32 {
                // loss-scaled worker buffers when probing (small powers of
                // two so a half wire rarely saturates; when it does, both
                // paths must skip identically)
                let ls = if probe { 2.0f32.powi(1 + rng.below(5) as i32) } else { 1.0 };
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..n).map(|_| rng.normal_f32() * ls).collect())
                    .collect();
                let scale = 1.0 / (w as f32 * ls);
                let lr = 0.005 + 0.004 * k as f32;

                // phase-synchronous reference: tiered reduce-scatter, then
                // the fused scattered step (probed or not)
                let mut r = bufs.clone();
                hierarchical_reduce_scatter(&mut r, &topo, prec);
                let s_ref = if probe {
                    o_ref.step_scattered_scaled(&pool, &mut x_ref, &r, scale, lr)
                } else {
                    Some(o_ref.step_scattered(&pool, &mut x_ref, &r, scale, lr))
                };

                let analytic = hierarchical_phase_wire_bytes(&topo, n, prec, false);
                for (arm, o, x, overlap) in [
                    ("serial", &mut o_ser, &mut x_ser, false),
                    ("overlap", &mut o_ovl, &mut x_ovl, true),
                ] {
                    let mut b = bufs.clone();
                    let (s_b, wb) = sharded_bucketed_step(
                        o, &pool, x, &mut b, &cuts, scale, lr, probe, &topo, prec, overlap,
                    );
                    assert_eq!(wb, analytic, "{name}/{arm} {topo}: wire bytes");
                    match (&s_ref, &s_b) {
                        (Some(a), Some(bs)) => {
                            assert_eq!(a.grad_norm, bs.grad_norm, "{name}/{arm} {topo}");
                            assert_eq!(
                                a.mean_trust_ratio, bs.mean_trust_ratio,
                                "{name}/{arm} {topo}"
                            );
                            assert_eq!(a.max_abs_param, bs.max_abs_param, "{name}/{arm} {topo}");
                        }
                        (None, None) => {}
                        _ => panic!("{name}/{arm} {topo}: skip decision diverged"),
                    }
                    assert_eq!(
                        &x_ref, &*x,
                        "{name}/{arm} (w={w}, {topo}, buckets={}): params diverged",
                        cuts.len() - 1
                    );
                }
            }
        }
    });
}

#[test]
fn prop_bucketed_replicated_step_exact_bit_equals_phase_sync() {
    // the tentpole contract, replicated side: per-bucket allreduce
    // overlapped with the previous bucket's unscale/probe sweep, one
    // prefolded step at the end — bit-identical to tiered allreduce + the
    // trainer's replicated update, for optimizers that feed the probe's
    // grad² into the step (lans, adamw, adamw_bgn) and ones that discard
    // it (lamb)
    for_cases(10, |seed, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(9000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let n = table.total;
        let w = [1usize, 2, 4, 8][rng.below_usize(4)];
        let topos = factorizations(w);
        let topo = topos[rng.below_usize(topos.len())];
        let prec = random_prec(rng);
        let exec = ParallelExecutor::new(2 + rng.below_usize(6));
        let cuts = random_cuts(rng, &table);
        let probe = seed % 2 == 1;
        let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

        for name in ["lans", "lamb", "adamw", "adamw_bgn"] {
            let hp = Hyper::default();
            let mut o_ref = make_optimizer(name, table.clone(), hp).unwrap();
            let mut o_ser = make_optimizer(name, table.clone(), hp).unwrap();
            let mut o_ovl = make_optimizer(name, table.clone(), hp).unwrap();
            let mut x_ref = x0.clone();
            let mut x_ser = x0.clone();
            let mut x_ovl = x0.clone();
            for k in 0..2u32 {
                let ls = if probe { 2.0f32.powi(1 + rng.below(5) as i32) } else { 1.0 };
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..n).map(|_| rng.normal_f32() * ls).collect())
                    .collect();
                let scale = 1.0 / (w as f32 * ls);
                let lr = 0.005 + 0.004 * k as f32;

                // phase-synchronous reference: tiered allreduce, then the
                // trainer's replicated update — the probed step_scaled, or
                // the executor step on the scaled mean gradient
                let mut r = bufs.clone();
                hierarchical_allreduce(&mut r, &topo, prec);
                let mut grad = std::mem::take(&mut r[0]);
                let s_ref = if probe {
                    o_ref.step_scaled(exec.pool(), &mut x_ref, &mut grad, lr, scale)
                } else {
                    for g in grad.iter_mut() {
                        *g *= scale;
                    }
                    Some(exec.step(o_ref.as_mut(), &mut x_ref, &grad, lr))
                };

                let analytic = hierarchical_allreduce_wire_bytes(&topo, n, prec);
                for (arm, o, x, overlap) in [
                    ("serial", &mut o_ser, &mut x_ser, false),
                    ("overlap", &mut o_ovl, &mut x_ovl, true),
                ] {
                    let mut b = bufs.clone();
                    let (s_b, wb) = replicated_bucketed_step(
                        o.as_mut(),
                        &exec,
                        x,
                        &mut b,
                        &cuts,
                        scale,
                        lr,
                        probe,
                        &topo,
                        prec,
                        overlap,
                    );
                    assert_eq!(wb, analytic, "{name}/{arm} {topo}: wire bytes");
                    match (&s_ref, &s_b) {
                        (Some(a), Some(bs)) => {
                            assert_eq!(a.grad_norm, bs.grad_norm, "{name}/{arm} {topo}");
                            assert_eq!(
                                a.mean_trust_ratio, bs.mean_trust_ratio,
                                "{name}/{arm} {topo}"
                            );
                            assert_eq!(a.max_abs_param, bs.max_abs_param, "{name}/{arm} {topo}");
                        }
                        (None, None) => {}
                        _ => panic!("{name}/{arm} {topo}: skip decision diverged"),
                    }
                    assert_eq!(
                        &x_ref, &*x,
                        "{name}/{arm} (w={w}, {topo}, buckets={}): params diverged",
                        cuts.len() - 1
                    );
                }
            }
        }
    });
}

#[test]
fn prop_bucketed_step_skips_on_overflow_and_leaves_state_untouched() {
    // the DAG pipeline's probe: a poisoned worker buffer turns the whole
    // bucketed step into a skip — params, moments and the step clock all
    // untouched, buckets already communicated leave no trace — and the
    // next clean step continues exactly the never-poisoned trajectory
    for_cases(12, |seed, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let n = table.total;
        let w = 1 + rng.below_usize(6);
        let topo = Topology::flat(w);
        let prec = TierPrecision::fp32();
        let pool = ThreadPool::new(2 + rng.below_usize(6));
        let exec = ParallelExecutor::new(2 + rng.below_usize(6));
        let overlap = seed % 2 == 0;
        let cuts = random_cuts(rng, &table);
        let scale = 1.0 / (w as f32 * 2.0);
        let poison = if seed % 2 == 0 { f32::INFINITY } else { f32::NAN };
        let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let fresh = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32() * 2.0).collect())
                .collect()
        };

        // ZeRO-1 pipeline
        let hp = Hyper::default();
        let mut clean = ShardedOptimizer::from_name("lans", table.clone(), hp, w).unwrap();
        let mut poked = ShardedOptimizer::from_name("lans", table.clone(), hp, w).unwrap();
        let mut xc = x0.clone();
        let mut xk = x0.clone();
        let bufs = fresh(rng);
        let mut b = bufs.clone();
        sharded_bucketed_step(
            &mut clean, &pool, &mut xc, &mut b, &cuts, scale, 0.01, true, &topo, prec, overlap,
        )
        .0
        .expect("clean setup step skipped");
        let mut b = bufs;
        sharded_bucketed_step(
            &mut poked, &pool, &mut xk, &mut b, &cuts, scale, 0.01, true, &topo, prec, overlap,
        )
        .0
        .expect("clean setup step skipped");
        assert_eq!(xc, xk, "sharded setup step diverged");

        let mut bad = fresh(rng);
        bad[rng.below_usize(w)][rng.below_usize(n)] = poison;
        let before = xk.clone();
        let t_before = poked.steps_taken();
        let (st, _) = sharded_bucketed_step(
            &mut poked, &pool, &mut xk, &mut bad, &cuts, scale, 0.01, true, &topo, prec, overlap,
        );
        assert!(st.is_none(), "sharded: poisoned buffer not detected");
        assert_eq!(before, xk, "sharded: skipped step touched params");
        assert_eq!(t_before, poked.steps_taken(), "sharded: skip advanced the clock");

        let bufs = fresh(rng);
        let mut b = bufs.clone();
        let sc = sharded_bucketed_step(
            &mut clean, &pool, &mut xc, &mut b, &cuts, scale, 0.02, true, &topo, prec, overlap,
        )
        .0
        .unwrap();
        let mut b = bufs;
        let sk = sharded_bucketed_step(
            &mut poked, &pool, &mut xk, &mut b, &cuts, scale, 0.02, true, &topo, prec, overlap,
        )
        .0
        .unwrap();
        assert_eq!(sc.grad_norm, sk.grad_norm, "sharded post-skip stats");
        assert_eq!(xc, xk, "sharded: post-skip trajectory diverged");

        // replicated pipeline — an optimizer that consumes the probe's
        // grad² (lans) and one that discards it (lamb)
        for name in ["lans", "lamb"] {
            let mut clean = make_optimizer(name, table.clone(), hp).unwrap();
            let mut poked = make_optimizer(name, table.clone(), hp).unwrap();
            let mut xc = x0.clone();
            let mut xk = x0.clone();
            let bufs = fresh(rng);
            let mut b = bufs.clone();
            replicated_bucketed_step(
                clean.as_mut(), &exec, &mut xc, &mut b, &cuts, scale, 0.01, true, &topo, prec,
                overlap,
            )
            .0
            .expect("clean setup step skipped");
            let mut b = bufs;
            replicated_bucketed_step(
                poked.as_mut(), &exec, &mut xk, &mut b, &cuts, scale, 0.01, true, &topo, prec,
                overlap,
            )
            .0
            .expect("clean setup step skipped");
            assert_eq!(xc, xk, "{name}: replicated setup step diverged");

            let mut bad = fresh(rng);
            bad[rng.below_usize(w)][rng.below_usize(n)] = poison;
            let before = xk.clone();
            let (st, _) = replicated_bucketed_step(
                poked.as_mut(), &exec, &mut xk, &mut bad, &cuts, scale, 0.01, true, &topo, prec,
                overlap,
            );
            assert!(st.is_none(), "{name}: poisoned buffer not detected");
            assert_eq!(before, xk, "{name}: skipped step touched params");

            let bufs = fresh(rng);
            let mut b = bufs.clone();
            let sc = replicated_bucketed_step(
                clean.as_mut(), &exec, &mut xc, &mut b, &cuts, scale, 0.02, true, &topo, prec,
                overlap,
            )
            .0
            .unwrap();
            let mut b = bufs;
            let sk = replicated_bucketed_step(
                poked.as_mut(), &exec, &mut xk, &mut b, &cuts, scale, 0.02, true, &topo, prec,
                overlap,
            )
            .0
            .unwrap();
            assert_eq!(sc.grad_norm, sk.grad_norm, "{name}: post-skip stats");
            assert_eq!(xc, xk, "{name}: post-skip trajectory diverged");
        }
    });
}

// ---------------------------------------------------------------------------
// run-health telemetry properties (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// The registry is process-global, so the three tests below serialize on
/// this lock; everything else in this binary leaves the registry disabled,
/// which is exactly the state these tests restore on exit.
static METRICS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn prop_metrics_registry_toggle_is_bit_invisible() {
    // the overhead contract's strong half: arming the registry must not
    // change a single bit of training state.  Same seeds, same tables,
    // same pools — one leg with the registry observing trust ratios,
    // block norms, wire bytes and pool busy-time, one leg with the seams
    // compiled down to a relaxed load.  Params, step stats and collective
    // outputs must agree exactly.
    let _g = METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for_cases(15, |_, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 2 + rng.below_usize(4);
        let pool = ThreadPool::new(4);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();

        let run_leg = |observed: bool| -> (Vec<f32>, Vec<Vec<f32>>, Vec<(f64, f64)>) {
            lans::metrics::registry::reset();
            if observed {
                lans::metrics::registry::enable();
            } else {
                lans::metrics::registry::disable();
            }
            let mut opt = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
            let mut x = x0.clone();
            let mut stats = Vec::new();
            for g in &grads {
                let s = opt.step_parallel(&pool, &mut x, g, 0.003);
                stats.push((s.grad_norm, s.mean_trust_ratio));
            }
            let mut b = bufs.clone();
            hierarchical_allreduce_pooled(
                &mut b,
                &Topology::flat(w),
                TierPrecision::fp32(),
                &pool,
            );
            lans::metrics::registry::disable();
            (x, b, stats)
        };

        let (x_off, b_off, s_off) = run_leg(false);
        let (x_on, b_on, s_on) = run_leg(true);
        assert_eq!(x_off, x_on, "arming the registry changed the parameter bits");
        assert_eq!(b_off, b_on, "arming the registry changed the collective bits");
        assert_eq!(s_off, s_on, "arming the registry changed the step stats");

        // and the observed leg actually observed (disable() froze, not
        // cleared, its counts): the optimizer seam fed the trust-ratio
        // histogram, the collective seam counted calls
        let snap = lans::metrics::registry::snapshot();
        assert!(
            snap.histogram("optim.trust_ratio").unwrap().count > 0,
            "enabled leg recorded no trust ratios"
        );
        assert!(
            snap.counter("collective.calls") > 0,
            "enabled leg counted no collectives"
        );
        lans::metrics::registry::reset();
    });
}

#[test]
fn prop_health_clean_runs_raise_no_verdicts() {
    // zero-false-positive contract: across random but *healthy* trainer
    // shapes — window size, base step time, bounded jitter, steadily
    // improving loss — the monitor must
    // stay silent.  A detector that cries wolf on clean runs is worse
    // than no detector (it would gate CI, ROADMAP item 4).
    let _g = METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for_cases(60, |seed, rng| {
        let window = 8 + rng.below_usize(56);
        let base = 0.002 + rng.next_f64() * 0.05;
        // jitter stays well under the straggler gate (z > 8 AND 1.5x median)
        let jitter = 0.02 + rng.next_f64() * 0.15;
        let steps = 100 + rng.below(300);
        let mut mon = lans::metrics::health::HealthMonitor::new(
            lans::metrics::health::HealthConfig { window, ..Default::default() },
        );
        let mut loss = 8.0 + rng.next_f64() * 4.0;
        for t in 1..=steps {
            let wobble = 1.0 + jitter * (rng.next_f64() - 0.5);
            let wall = base * wobble;
            let comm = wall * 0.3;
            let compute = wall * 0.6;
            loss *= 0.995;
            mon.observe_step(t, wall, comm, compute, loss, false, loss * 10.0);
        }
        assert!(
            mon.verdicts().is_empty(),
            "clean run (seed {seed}, window {window}, base {base:.4}s, \
             jitter {jitter:.2}) raised {:?}",
            mon.verdicts()
        );
        assert!(mon.healthy());
    });
}

#[test]
fn prop_health_seeded_faults_are_flagged() {
    // the detection half: the same clean-run generator with ONE seeded
    // fault — a straggler spike or a loss-scale thrash burst at a random
    // step — must produce exactly the matching verdict kind.
    let _g = METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for_cases(60, |seed, rng| {
        let window = 8 + rng.below_usize(24);
        let base = 0.005 + rng.next_f64() * 0.02;
        let steps = 150 + rng.below(150);
        let inject_thrash = rng.next_f64() < 0.5;
        let fault_at = (window as u64 * 2) + 5 + rng.below(steps / 2);
        let mut mon = lans::metrics::health::HealthMonitor::new(
            lans::metrics::health::HealthConfig { window, ..Default::default() },
        );
        let mut loss = 10.0;
        for t in 1..=steps {
            let wobble = 1.0 + 0.05 * (rng.next_f64() - 0.5);
            let mut wall = base * wobble;
            let mut backoff = false;
            if inject_thrash {
                // a burst of scale backoffs inside one window
                backoff = t >= fault_at && t < fault_at + 5;
            } else if t == fault_at {
                // one step 20x the median: an unambiguous straggler
                wall = base * 20.0;
            }
            loss *= 0.997;
            mon.observe_step(t, wall, wall * 0.3, wall * 0.6, loss, backoff, loss * 10.0);
        }
        let want = if inject_thrash { "loss_scale_thrash" } else { "straggler" };
        assert!(
            mon.verdicts().iter().any(|v| v.kind == want),
            "seeded {want} at step {fault_at} (seed {seed}) not flagged; \
             verdicts: {:?}",
            mon.verdicts()
        );
        assert!(!mon.healthy(), "fault flagged but run still called healthy");
    });
}

// ---------------------------------------------------------------------------
// flight recorder properties (DESIGN.md §13)
// ---------------------------------------------------------------------------

#[test]
fn prop_flight_ring_retains_exactly_last_k() {
    // the bounded-memory contract: however many frames a run pushes, the
    // ring holds exactly min(pushed, K) frames and they are precisely the
    // *last* K steps, in order.  K=0 is clamped to 1 so a misconfigured
    // cap can never make a sealed bundle frameless.
    use lans::obs::{FlightFrame, FlightRing};
    for_cases(200, |seed, rng| {
        let cap = rng.below_usize(64); // includes the degenerate 0
        let pushes = rng.below_usize(200);
        let first_step = 1 + rng.below(1000);
        let mut ring = FlightRing::new(cap);
        let eff_cap = cap.max(1);
        assert_eq!(ring.cap(), eff_cap);
        for i in 0..pushes {
            ring.push(FlightFrame::partial(first_step + i as u64, None));
        }
        assert_eq!(
            ring.len(),
            pushes.min(eff_cap),
            "seed {seed}: cap {cap}, {pushes} pushes"
        );
        let want: Vec<u64> = (0..pushes as u64)
            .map(|i| first_step + i)
            .skip(pushes.saturating_sub(eff_cap))
            .collect();
        assert_eq!(ring.steps(), want, "seed {seed}: ring must keep the LAST K steps");
        assert_eq!(ring.last_step(), want.last().copied());
        assert_eq!(ring.is_empty(), pushes == 0);
    });
}

#[test]
fn prop_flight_recorder_toggle_is_bit_invisible() {
    // the flight recorder's half of the overhead contract, mirroring
    // `prop_metrics_registry_toggle_is_bit_invisible`: arming the recorder
    // — frames pushed every step, a bundle sealed at the end — must not
    // change a single bit of parameters, collective outputs or step stats
    // versus the disarmed run, because the recorder only *observes* state
    // the trainer already computed.
    let _g = METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for_cases(15, |seed, rng| {
        let nblocks = 1 + rng.below_usize(4);
        let specs: Vec<(String, usize, bool)> = (0..nblocks)
            .map(|i| (format!("b{i}"), 1 + rng.below_usize(6000), rng.next_f64() < 0.5))
            .collect();
        let table = BlockTable::new(&specs);
        let w = 2 + rng.below_usize(4);
        let pool = ThreadPool::new(4);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();
        let bundle = std::env::temp_dir().join(format!("lans_flight_prop_{seed}.json"));
        let _ = std::fs::remove_file(&bundle);

        let run_leg = |armed: bool| -> (Vec<f32>, Vec<Vec<f32>>, Vec<(f64, f64)>) {
            lans::obs::flight::disarm(); // leave no state from prior legs/tests
            if armed {
                lans::obs::flight::arm(lans::obs::SealMeta {
                    bundle: Some(bundle.clone()),
                    config_echo: vec![("seed".into(), format!("{seed}"))],
                    cap: 8,
                });
            }
            let mut opt = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
            let mut x = x0.clone();
            let mut stats = Vec::new();
            for (t, g) in grads.iter().enumerate() {
                let s = opt.step_parallel(&pool, &mut x, g, 0.003);
                stats.push((s.grad_norm, s.mean_trust_ratio));
                if lans::obs::flight::enabled() {
                    lans::obs::flight::push_frame(lans::obs::FlightFrame::partial(
                        1 + t as u64,
                        None,
                    ));
                }
            }
            let mut b = bufs.clone();
            hierarchical_allreduce_pooled(
                &mut b,
                &Topology::flat(w),
                TierPrecision::fp32(),
                &pool,
            );
            if armed {
                let sealed = lans::obs::flight::trigger(lans::obs::Trigger {
                    kind: "health_verdict",
                    step: grads.len() as u64,
                    message: "proptest seal".into(),
                    culprit: None,
                });
                assert!(sealed.is_some(), "armed leg with bundle path must seal");
                lans::obs::flight::disarm();
            }
            (x, b, stats)
        };

        let (x_off, b_off, s_off) = run_leg(false);
        let (x_on, b_on, s_on) = run_leg(true);
        assert_eq!(x_off, x_on, "arming the flight recorder changed the parameter bits");
        assert_eq!(b_off, b_on, "arming the flight recorder changed the collective bits");
        assert_eq!(s_off, s_on, "arming the flight recorder changed the step stats");

        // and the armed leg actually sealed a valid, versioned bundle
        let bj = Json::parse(&std::fs::read_to_string(&bundle).unwrap()).unwrap();
        assert_eq!(bj.expect("schema").as_str(), Some(lans::obs::BUNDLE_SCHEMA));
        assert_eq!(
            bj.expect("frames").as_arr().unwrap().len(),
            grads.len().min(8),
            "sealed bundle frame count vs ring cap"
        );
        let _ = std::fs::remove_file(&bundle);
    });
}
