//! Cross-module integration tests that do not need the AOT artifacts:
//! sharding × masking × allreduce × optimizer over a synthetic linear
//! model, schedule × config wiring, checkpoint round-trips through the
//! block table.

use lans::collective::{ring_allreduce, ring_allreduce_avg};
use lans::config::{Document, TrainConfig};
use lans::data::{make_shards, Masker, SequenceSet, SyntheticCorpus};
use lans::optim::{from_ratios, make_optimizer, BlockTable, Hyper, Optimizer, Schedule};
use lans::util::rng::Rng;
use std::path::Path;

/// Least-squares "model": params w (d), samples (a_i, b_i), grad = aᵀ(aw−b).
/// Small enough to run thousands of steps, real enough that optimizer
/// dynamics (divergence at high lr, convergence at low) show up.
struct LinearProblem {
    d: usize,
    xs: Vec<Vec<f32>>,
    ys: Vec<f32>,
}

impl LinearProblem {
    fn new(n: usize, d: usize, seed: u64) -> (LinearProblem, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w_true: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32()).collect())
            .collect();
        let ys: Vec<f32> = xs
            .iter()
            .map(|x| {
                x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f32>()
                    + 0.01 * rng.normal_f32()
            })
            .collect();
        (LinearProblem { d, xs, ys }, w_true)
    }

    fn grad(&self, w: &[f32], idx: &[usize]) -> Vec<f32> {
        let mut g = vec![0.0f32; self.d];
        for &i in idx {
            let pred: f32 = self.xs[i].iter().zip(w).map(|(a, b)| a * b).sum();
            let err = pred - self.ys[i];
            for (gj, xj) in g.iter_mut().zip(&self.xs[i]) {
                *gj += err * xj;
            }
        }
        for gj in g.iter_mut() {
            *gj /= idx.len() as f32;
        }
        g
    }

    fn loss(&self, w: &[f32]) -> f32 {
        let mut s = 0.0;
        for (x, y) in self.xs.iter().zip(&self.ys) {
            let pred: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum();
            s += (pred - y) * (pred - y);
        }
        s / self.xs.len() as f32
    }
}

/// Full mini data-parallel pipeline: shards → per-worker grads →
/// ring allreduce → one optimizer.  Asserts the sharded run equals a
/// single-worker run over the union batch (synchronous DDP equivalence).
#[test]
fn sharded_allreduce_equals_single_worker() {
    let (prob, _) = LinearProblem::new(64, 16, 1);
    let table = BlockTable::new(&[("w".into(), 16, true)]);
    let hp = Hyper::default();

    // 4 workers, 4 samples each
    let mut shards = make_shards(64, 4, 2);
    let per_worker: Vec<Vec<usize>> =
        shards.iter_mut().map(|s| s.next_batch(4)).collect();
    let union: Vec<usize> = per_worker.iter().flatten().copied().collect();

    let w0: Vec<f32> = (0..16).map(|i| 0.1 * i as f32).collect();

    // path A: distributed
    let mut bufs: Vec<Vec<f32>> =
        per_worker.iter().map(|idx| prob.grad(&w0, idx)).collect();
    ring_allreduce_avg(&mut bufs);
    let mut opt_a = make_optimizer("lans", table.clone(), hp).unwrap();
    let mut wa = w0.clone();
    opt_a.step(&mut wa, &bufs[0], 0.01);

    // path B: single worker over the union batch
    let g = prob.grad(&w0, &union);
    let mut opt_b = make_optimizer("lans", table, hp).unwrap();
    let mut wb = w0.clone();
    opt_b.step(&mut wb, &g, 0.01);

    for (a, b) in wa.iter().zip(&wb) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn optimizers_converge_on_linear_problem() {
    let (prob, _) = LinearProblem::new(128, 8, 3);
    let table = BlockTable::new(&[("w".into(), 8, false)]);
    for name in ["lans", "lamb", "adamw", "adamw_bgn"] {
        let mut opt = make_optimizer(name, table.clone(),
            Hyper { weight_decay: 0.0, ..Default::default() }).unwrap();
        let mut w = vec![0.5f32; 8];
        let mut shard = make_shards(128, 1, 4).remove(0);
        let sched = from_ratios(0.05, 300, 0.1, 0.3);
        let l0 = prob.loss(&w);
        for t in 1..=300 {
            let idx = shard.next_batch(16);
            let g = prob.grad(&w, &idx);
            opt.step(&mut w, &g, sched.lr(t) as f32);
        }
        let l1 = prob.loss(&w);
        assert!(l1 < 0.05 * l0, "{name}: loss {l0} -> {l1}");
    }
}

/// The layer-wise adaptation property the paper builds on (and You et al.'s
/// motivation): per step, LANS moves each block by at most lr·‖x‖ —
/// *relative* movement is bounded by lr regardless of gradient magnitude —
/// while AdamW's per-coordinate movement is ~lr in *absolute* terms, which
/// for a small-norm block (e.g. a LayerNorm scale ≈ 0.02·√d) is a huge
/// relative jump.  This is what lets trust-ratio methods take large
/// learning rates on heterogeneous-norm models without blowing up small
/// blocks.
#[test]
fn lans_bounds_relative_movement_where_adamw_does_not() {
    let mut rng = Rng::new(5);
    let d = 64;
    let table = BlockTable::new(&[("w".into(), d, false)]);
    let hp = Hyper { weight_decay: 0.0, ..Default::default() };
    let lr = 0.5; // large-batch-scale LR

    // tiny-norm block, big gradient — the dangerous configuration
    let x0: Vec<f32> = (0..d).map(|_| 0.02 * rng.normal_f32()).collect();
    let g: Vec<f32> = (0..d).map(|_| 5.0 * rng.normal_f32()).collect();
    let xnorm: f32 = x0.iter().map(|v| v * v).sum::<f32>().sqrt();

    let rel_move = |name: &str| -> f32 {
        let mut opt = make_optimizer(name, table.clone(), hp).unwrap();
        let mut x = x0.clone();
        opt.step(&mut x, &g, lr);
        let dx: f32 = x
            .iter()
            .zip(&x0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        dx / xnorm
    };

    let lans_rel = rel_move("lans");
    let adamw_rel = rel_move("adamw");
    assert!(lans_rel <= lr * 1.01, "LANS relative move {lans_rel} > lr {lr}");
    assert!(
        adamw_rel > 5.0 * lans_rel,
        "adamw rel {adamw_rel} vs lans rel {lans_rel}"
    );
}

#[test]
fn end_to_end_masking_pipeline_shapes() {
    let corpus = SyntheticCorpus::new(512, 1);
    let toks = corpus.generate(64 * 50, 2);
    let seqs = SequenceSet::new(toks, 64);
    let masker = Masker::new(10, &corpus.vocab);
    let mut shards = make_shards(seqs.len(), 3, 3);
    let mut rng = Rng::new(4);
    for s in shards.iter_mut() {
        let idx = s.next_batch(4);
        let b = masker.make_batch(&seqs, &idx, &mut rng);
        assert_eq!(b.tokens.len(), 4 * 64);
        assert_eq!(b.positions.len(), 4 * 10);
        // all slot weights in {0,1}, at least one live slot per sequence
        for row in 0..4 {
            let live: f32 = b.weights[row * 10..(row + 1) * 10].iter().sum();
            assert!(live >= 1.0);
        }
    }
}

#[test]
fn allreduce_then_schedule_smoke() {
    // schedule from config doc drives an allreduce'd toy update loop
    let doc = Document::parse(
        r#"
        [model]
        meta = "artifacts/bert-tiny_s64_b4.meta.json"
        [train]
        steps = 50
        [schedule]
        kind = "warmup_const_decay"
        eta = 0.1
        ratio_warmup = 0.2
        ratio_const = 0.4
        "#,
    )
    .unwrap();
    let cfg = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
    match cfg.schedule {
        Schedule::WarmupConstDecay { t_warmup, t_const, t_total, .. } => {
            assert_eq!((t_warmup, t_const, t_total), (10, 20, 50));
        }
        _ => panic!("bad schedule"),
    }
    // lr curve feeds a 2-worker allreduce loop without NaNs
    let mut v = vec![vec![1.0f32; 8], vec![2.0f32; 8]];
    for t in 1..=50 {
        let lr = cfg.schedule.lr(t) as f32;
        for b in v.iter_mut() {
            for x in b.iter_mut() {
                *x *= 1.0 - lr * 0.1;
            }
        }
        ring_allreduce(&mut v);
        for b in v.iter_mut() {
            for x in b.iter_mut() {
                *x /= 2.0;
            }
        }
    }
    assert!(v[0][0].is_finite());
}
