//! End-to-end runtime integration: load the bert-tiny AOT artifacts, run
//! fwd/bwd and optimizer steps through PJRT, and cross-check the Pallas
//! LANS kernel against the pure-rust implementation.
//!
//! Requires `make artifacts` (skips with a notice if artifacts are absent,
//! so unit-test runs stay hermetic).

use std::path::PathBuf;
use std::sync::Arc;

use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{DataSource, TrainStatus, Trainer};
use lans::optim::{make_optimizer, BlockTable, Hyper, Optimizer, Schedule};
use lans::precision::{DType, LossScale};
use lans::runtime::{Engine, ModelRuntime};
use lans::topology::Topology;
use lans::util::rng::Rng;

fn meta_path() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/bert-tiny_s64_b4.meta.json");
    p.exists().then_some(p)
}

fn skip() {
    eprintln!("SKIP: artifacts not built (run `make artifacts`)");
}

fn data_cfg() -> DataConfig {
    DataConfig { source: "synthetic".into(), vocab: 2048, corpus_tokens: 64 * 400, seed: 7 }
}

#[test]
fn fwd_bwd_produces_finite_loss_and_grads() {
    let Some(meta) = meta_path() else { return skip() };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let params = rt.init_params(1);

    let ds = DataSource::build(&data_cfg(), rt.meta.seq, rt.meta.mlm_slots).unwrap();
    let mut rng = Rng::new(3);
    let idx: Vec<usize> = (0..rt.meta.batch).collect();
    let batch = ds.masker.make_batch(&ds.seqs, &idx, &mut rng);

    let (loss, grads) = rt.fwd_bwd(&params, &batch).unwrap();
    // random init ⇒ loss ≈ ln(vocab) = ln(2048) ≈ 7.62
    assert!(loss.is_finite());
    assert!((6.5..9.0).contains(&loss), "loss {loss}");
    assert_eq!(grads.len(), rt.meta.params.len());
    let gsum: f64 = grads
        .iter()
        .flat_map(|g| g.data.iter())
        .map(|&x| (x as f64).abs())
        .sum();
    assert!(gsum.is_finite() && gsum > 0.0, "gradients all zero?");
    for (g, p) in grads.iter().zip(&rt.meta.params) {
        assert_eq!(g.shape, p.shape, "grad shape mismatch for {}", p.name);
    }
}

#[test]
fn hlo_lans_matches_native_lans() {
    // The decisive L1↔L3 consistency check: the AOT Pallas LANS artifact and
    // the pure-rust LANS produce the same trajectory over several steps.
    let Some(meta) = meta_path() else { return skip() };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    rt.load_optimizer("lans").unwrap();

    let table = BlockTable::from_meta(&rt.meta);
    let mut rng = Rng::new(9);

    // HLO path state
    let mut params_hlo = rt.init_params(5);
    let mut state = rt.zero_opt_state();
    // native path state
    let mut flat = table.flatten(&params_hlo);
    let mut native = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();

    for step in 0..3 {
        // synthetic gradient, same for both paths
        let grads: Vec<_> = rt
            .meta
            .params
            .iter()
            .map(|p| {
                let data: Vec<f32> = (0..p.size).map(|_| rng.normal_f32()).collect();
                lans::runtime::TensorF32::new(p.shape.clone(), data)
            })
            .collect();
        let gflat = table.flatten(&grads);

        rt.opt_step("lans", &mut params_hlo, &mut state, &grads, 0.01).unwrap();
        native.step(&mut flat, &gflat, 0.01);

        let hlo_flat = table.flatten(&params_hlo);
        let mut max_err = 0.0f32;
        for (a, b) in hlo_flat.iter().zip(&flat) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 5e-5,
            "step {step}: HLO vs native diverged, max |Δ| = {max_err}"
        );
    }
}

#[test]
fn hlo_lamb_and_adamw_match_native() {
    let Some(meta) = meta_path() else { return skip() };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let table = BlockTable::from_meta(&rt.meta);

    for opt_name in ["lamb", "adamw", "adamw_bgn"] {
        rt.load_optimizer(opt_name).unwrap();
        let mut rng = Rng::new(11);
        let mut params = rt.init_params(6);
        let mut state = rt.zero_opt_state();
        let mut flat = table.flatten(&params);
        let mut native =
            make_optimizer(opt_name, table.clone(), Hyper::default()).unwrap();

        let grads: Vec<_> = rt
            .meta
            .params
            .iter()
            .map(|p| {
                let data: Vec<f32> = (0..p.size).map(|_| rng.normal_f32()).collect();
                lans::runtime::TensorF32::new(p.shape.clone(), data)
            })
            .collect();
        let gflat = table.flatten(&grads);

        rt.opt_step(opt_name, &mut params, &mut state, &grads, 0.005).unwrap();
        native.step(&mut flat, &gflat, 0.005);

        let hlo_flat = table.flatten(&params);
        let max_err = hlo_flat
            .iter()
            .zip(&flat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 5e-5, "{opt_name}: max |Δ| = {max_err}");
    }
}

#[test]
fn trainer_loss_decreases_small_run() {
    // 30 steps of real training (2 workers × accumulation) must cut the
    // MLM loss on the synthetic Markov corpus.
    let Some(meta) = meta_path() else { return skip() };
    let cfg = TrainConfig {
        meta_path: meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 2,
        threads: 1,
        shard_optimizer: false,
        resume_opt_state: false,
        topology: Topology::flat(2),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 16,
        steps: 30,
        seed: 1,
        eval_every: 0,
        eval_batches: 2,
        hyper: Hyper::default(),
        schedule: Schedule::Constant { eta: 0.02 },
        data: data_cfg(),
        checkpoint: None,
        resume_from: None,
        curve_out: None,
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };
    let mut tr = Trainer::new(cfg).unwrap();
    assert_eq!(tr.effective_batch(), 16);
    let report = tr.run().unwrap();
    assert_eq!(report.status, TrainStatus::Completed);
    let first = report.recorder.records.first().unwrap().loss;
    let last = report.recorder.ema_loss().unwrap();
    assert!(
        last < first - 0.5,
        "loss did not improve: {first:.3} -> {last:.3}"
    );
    assert!(report.final_eval_loss.unwrap().is_finite());
}

#[test]
fn trainer_on_declared_topology_keeps_bits_and_accounts_wire() {
    // the full-system topology contract: a 2x2 grid walks the flat run's
    // exact trajectory at fp32, and the executed wire bytes (split
    // intra/inter) equal the analytic per-step terms × steps — for both
    // the sharded (reduce-scatter only) and replicated (allreduce) paths
    use lans::collective::{hierarchical_allreduce_wire_bytes, hierarchical_phase_wire_bytes};
    use lans::topology::TierPrecision;

    let Some(meta) = meta_path() else { return skip() };
    let engine = Engine::cpu().unwrap();
    let mk = |topology: Topology, shard: bool, inter: DType| TrainConfig {
        meta_path: meta.clone(),
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 4,
        threads: 0,
        shard_optimizer: shard,
        resume_opt_state: false,
        topology,
        grad_dtype: inter,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 16,
        steps: 8,
        seed: 3,
        eval_every: 0,
        eval_batches: 1,
        hyper: Hyper::default(),
        schedule: Schedule::Constant { eta: 0.01 },
        data: data_cfg(),
        checkpoint: None,
        resume_from: None,
        curve_out: None,
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };
    let grid = Topology::grid(2, 2);

    for shard in [true, false] {
        let r_flat = Trainer::with_engine(mk(Topology::flat(4), shard, DType::F32), engine.clone())
            .unwrap()
            .run()
            .unwrap();
        let r_grid = Trainer::with_engine(mk(grid, shard, DType::F32), engine.clone())
            .unwrap()
            .run()
            .unwrap();
        for (a, b) in r_flat.params.iter().zip(&r_grid.params) {
            assert_eq!(a.data, b.data, "shard={shard}: topology changed the fp32 bits");
        }
        // byte accounting: per step the sharded path pays one tiered
        // reduce-scatter, the replicated path the full allreduce
        let n = r_grid.params.iter().map(|t| t.data.len()).sum::<usize>();
        let prec = TierPrecision::fp32();
        let per_step = if shard {
            hierarchical_phase_wire_bytes(&grid, n, prec, false)
        } else {
            hierarchical_allreduce_wire_bytes(&grid, n, prec)
        };
        assert_eq!(r_grid.wire.intra, per_step.intra * 8, "shard={shard}: intra bytes");
        assert_eq!(r_grid.wire.inter, per_step.inter * 8, "shard={shard}: inter bytes");
        assert!(r_grid.wire.inter > 0 && r_grid.wire.intra > 0, "both tiers executed");
        // flat puts everything on the inter tier
        assert_eq!(r_flat.wire.intra, 0, "shard={shard}");
    }

    // bf16 inter tier end-to-end on the sharded path: completes, improves,
    // and the split still matches the model (inter now 2 bytes/elem)
    let rep = Trainer::with_engine(mk(grid, true, DType::Bf16), engine).unwrap().run().unwrap();
    assert_eq!(rep.status, TrainStatus::Completed);
    let n = rep.params.iter().map(|t| t.data.len()).sum::<usize>();
    let per_step =
        hierarchical_phase_wire_bytes(&grid, n, TierPrecision::half_inter(DType::Bf16), false);
    assert_eq!(rep.wire.intra, per_step.intra * 8);
    assert_eq!(rep.wire.inter, per_step.inter * 8);
    let first = rep.recorder.records.first().unwrap().loss;
    let last = rep.recorder.ema_loss().unwrap();
    assert!(last < first, "bf16 inter wire should still learn: {first} -> {last}");
}

#[test]
fn eval_loss_runs() {
    let Some(meta) = meta_path() else { return skip() };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine.clone(), &meta).unwrap();
    let params = rt.init_params(2);
    let ds = Arc::new(
        DataSource::build(&data_cfg(), rt.meta.seq, rt.meta.mlm_slots).unwrap(),
    );
    let batch = ds.eval_batch(rt.meta.batch, 0, 3);
    let l = rt.eval_loss(&params, &batch).unwrap();
    assert!((6.0..9.5).contains(&(l as f64)), "eval loss {l}");
    // engine can host several executables at once
    assert!(engine.loaded_count().unwrap() >= 2);
}
