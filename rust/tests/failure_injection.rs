//! Failure injection: every user-facing error path must fail loudly with a
//! useful message, never panic or silently mis-train.

use std::path::{Path, PathBuf};

use lans::checkpoint::Checkpoint;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::Trainer;
use lans::optim::{BlockTable, Hyper, Schedule, ShardedOptimizer};
use lans::precision::{DType, LossScale};
use lans::runtime::{Engine, ModelMeta, ModelRuntime, TensorF32};
use lans::topology::Topology;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn meta_path() -> Option<PathBuf> {
    let p = artifacts_dir().join("bert-tiny_s64_b4.meta.json");
    p.exists().then_some(p)
}

fn base_cfg(meta: PathBuf) -> TrainConfig {
    TrainConfig {
        meta_path: meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 2,
        threads: 1,
        shard_optimizer: false,
        resume_opt_state: false,
        topology: Topology::flat(2),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 16,
        steps: 2,
        seed: 1,
        eval_every: 0,
        eval_batches: 1,
        hyper: Hyper::default(),
        schedule: Schedule::Constant { eta: 0.01 },
        data: DataConfig {
            source: "synthetic".into(),
            vocab: 2048,
            corpus_tokens: 64 * 200,
            seed: 7,
        },
        checkpoint: None,
        resume_from: None,
        curve_out: None,
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    }
}

#[test]
fn missing_meta_file_errors() {
    let engine = Engine::cpu().unwrap();
    let Err(e) = ModelRuntime::load(engine, Path::new("/nonexistent/meta.json"))
    else {
        panic!("expected error")
    };
    let err = format!("{e:#}");
    assert!(err.contains("meta.json"), "unhelpful error: {err}");
}

#[test]
fn corrupt_meta_json_errors() {
    let dir = std::env::temp_dir().join("lans_fi_meta");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.meta.json");
    std::fs::write(&p, "{ this is not json").unwrap();
    let engine = Engine::cpu().unwrap();
    assert!(ModelRuntime::load(engine, &p).is_err());
}

#[test]
fn meta_pointing_at_missing_artifact_errors() {
    let dir = std::env::temp_dir().join("lans_fi_art");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("x.meta.json");
    std::fs::write(
        &p,
        r#"{"tag": "x", "config": {"name": "x", "num_layers": 1, "hidden": 8,
            "num_heads": 2, "intermediate": 16, "vocab_size": 32,
            "max_seq_len": 16}, "batch": 1, "seq": 8, "mlm_slots": 2,
            "params": [{"name": "w", "shape": [2], "size": 2, "decay": true}],
            "param_count": 2,
            "artifacts": {"fwd_bwd": "does_not_exist.hlo.txt"}}"#,
    )
    .unwrap();
    let engine = Engine::cpu().unwrap();
    let Err(e) = ModelRuntime::load(engine, &p) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("does_not_exist"), "unhelpful: {err}");
}

#[test]
fn malformed_hlo_text_errors() {
    let Some(meta) = meta_path() else { return };
    // copy the meta but point fwd_bwd at a garbage HLO file
    let dir = std::env::temp_dir().join("lans_fi_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let text = std::fs::read_to_string(&meta).unwrap();
    let bad_hlo = dir.join("garbage.hlo.txt");
    std::fs::write(&bad_hlo, "HloModule definitely not valid !!!").unwrap();
    let patched = text.replace(
        "fwd_bwd_bert-tiny_s64_b4.hlo.txt",
        "garbage.hlo.txt",
    );
    let p = dir.join("patched.meta.json");
    std::fs::write(&p, patched).unwrap();
    // the other artifacts resolve relative to the patched meta's dir, so
    // loading must fail on the garbage file (or on missing eval) — either
    // way: an error, not a panic
    let engine = Engine::cpu().unwrap();
    assert!(ModelRuntime::load(engine, &p).is_err());
}

#[test]
fn indivisible_global_batch_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.global_batch = 17; // not divisible by workers(2) x micro(4)
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("divisible"), "unhelpful: {err}");
}

#[test]
fn oversized_data_vocab_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.data.vocab = 1 << 16; // model vocab is 2048
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("vocab"), "unhelpful: {err}");
}

#[test]
fn corpus_too_small_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.data.corpus_tokens = 64; // one sequence
    assert!(Trainer::new(cfg).is_err());
}

#[test]
fn wrong_batch_geometry_rejected_by_runtime() {
    let Some(meta) = meta_path() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let params = rt.init_params(1);
    // batch with the wrong sequence length
    let bad = lans::data::MlmBatch {
        tokens: vec![5; rt.meta.batch * 32], // seq 32, artifact wants 64
        positions: vec![0; rt.meta.batch * rt.meta.mlm_slots],
        target_ids: vec![5; rt.meta.batch * rt.meta.mlm_slots],
        weights: vec![1.0; rt.meta.batch * rt.meta.mlm_slots],
        batch: rt.meta.batch,
        seq: 32,
        slots: rt.meta.mlm_slots,
    };
    let Err(e) = rt.fwd_bwd(&params, &bad) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("geometry"), "unhelpful: {err}");
}

#[test]
fn wrong_param_count_rejected_by_runtime() {
    let Some(meta) = meta_path() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let mut params = rt.init_params(1);
    params.pop();
    let ds = lans::coordinator::DataSource::build(
        &base_cfg(meta).data, rt.meta.seq, rt.meta.mlm_slots).unwrap();
    let mut rng = lans::util::rng::Rng::new(1);
    let batch = ds.masker.make_batch(&ds.seqs, &[0, 1, 2, 3], &mut rng);
    assert!(rt.fwd_bwd(&params, &batch).is_err());
}

#[test]
fn resume_from_mismatched_checkpoint_errors() {
    let Some(meta) = meta_path() else { return };
    let dir = std::env::temp_dir().join("lans_fi_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("wrong.ckpt");
    Checkpoint::new(
        1,
        vec![("not/a/real/param".into(), TensorF32::new(vec![2], vec![0.0, 1.0]))],
    )
    .save(&p)
    .unwrap();
    let mut cfg = base_cfg(meta);
    cfg.resume_from = Some(p);
    let Err(e) = Trainer::new(cfg).unwrap().run() else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("missing tensor"), "unhelpful: {err}");
}

#[test]
fn checkpoint_save_creates_missing_parent_dirs() {
    let root = std::env::temp_dir().join("lans_fi_ckpt_dirs");
    let _ = std::fs::remove_dir_all(&root);
    let p = root.join("phase1/seed42/step.ckpt");
    Checkpoint::new(7, vec![("w".into(), TensorF32::new(vec![2], vec![0.5, -0.5]))])
        .save(&p)
        .unwrap();
    assert_eq!(Checkpoint::load(&p).unwrap().step, 7);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn checkpoint_load_missing_file_is_contextual() {
    let Err(e) = Checkpoint::load(Path::new("/nonexistent/run/final.ckpt")) else {
        panic!("expected error")
    };
    let err = format!("{e:#}");
    assert!(err.contains("final.ckpt"), "unhelpful: {err}");
    assert!(err.to_lowercase().contains("checkpoint"), "unhelpful: {err}");
}

#[test]
fn checkpoint_save_behind_file_is_contextual() {
    let base = std::env::temp_dir().join("lans_fi_ckpt_parent_file");
    std::fs::write(&base, b"i am a file").unwrap();
    let Err(e) = Checkpoint::new(0, vec![]).save(&base.join("x.ckpt"))
    else {
        panic!("expected error")
    };
    let err = format!("{e:#}");
    assert!(err.contains("lans_fi_ckpt_parent_file"), "unhelpful: {err}");
    std::fs::remove_file(&base).ok();
}

// --------------------------------------------------------------------------
// sharded-optimizer shard-mismatch coverage
// --------------------------------------------------------------------------

fn toy_table() -> BlockTable {
    BlockTable::new(&[("w".into(), 6000, true), ("b".into(), 40, false)])
}

#[test]
fn sharded_state_with_wrong_total_names_both_counts() {
    let hp = Hyper::default();
    let donor =
        ShardedOptimizer::from_name("lans", BlockTable::new(&[("w".into(), 128, true)]), hp, 2)
            .unwrap();
    let mut target = ShardedOptimizer::from_name("lans", toy_table(), hp, 4).unwrap();
    let err = format!("{:#}", target.import_state(3, &donor.export_state()).unwrap_err());
    assert!(err.contains("128") && err.contains("6040"), "unhelpful: {err}");
}

#[test]
fn sharded_state_with_missing_shard_tensor_is_contextual() {
    let hp = Hyper::default();
    let donor = ShardedOptimizer::from_name("lans", toy_table(), hp, 3).unwrap();
    let mut state = donor.export_state();
    // drop shard 1's v tensor
    state.retain(|(name, _)| name != "optshard:v:1");
    let mut target = ShardedOptimizer::from_name("lans", toy_table(), hp, 3).unwrap();
    let err = format!("{:#}", target.import_state(1, &state).unwrap_err());
    assert!(
        err.contains("shard 1") && err.contains("missing"),
        "unhelpful: {err}"
    );
}

#[test]
fn sharded_state_absent_from_checkpoint_is_contextual() {
    let mut target = ShardedOptimizer::from_name("lans", toy_table(), Hyper::default(), 2).unwrap();
    let params_only = vec![("w".to_string(), TensorF32::new(vec![2], vec![0.0, 1.0]))];
    let err = format!("{:#}", target.import_state(1, &params_only).unwrap_err());
    assert!(err.contains("no sharded optimizer state"), "unhelpful: {err}");
}

#[test]
fn sharded_restore_from_missing_file_names_the_path() {
    let mut so = ShardedOptimizer::from_name("lamb", toy_table(), Hyper::default(), 2).unwrap();
    let err = format!(
        "{:#}",
        so.restore_state(Path::new("/nonexistent/run/opt.ckpt")).unwrap_err()
    );
    assert!(err.contains("opt.ckpt"), "unhelpful: {err}");
}

#[test]
fn shard_optimizer_on_hlo_backend_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.backend = OptBackend::Hlo;
    cfg.shard_optimizer = true;
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("native"), "unhelpful: {err}");
}

#[test]
fn shard_optimizer_with_elementwise_optimizer_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.optimizer = "adamw".into();
    cfg.shard_optimizer = true;
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("lans|lamb"), "unhelpful: {err}");
}

#[test]
fn half_wire_on_hlo_backend_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.backend = OptBackend::Hlo;
    cfg.grad_dtype = DType::F16;
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("native"), "unhelpful: {err}");
}

#[test]
fn loss_scale_on_hlo_backend_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.backend = OptBackend::Hlo;
    cfg.loss_scale = LossScale::Dynamic { init: 65536.0 };
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("native"), "unhelpful: {err}");
}

#[test]
fn topology_worker_mismatch_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    // 2x2 describes 4 ranks, but the config runs 2 workers
    cfg.topology = Topology::grid(2, 2);
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(
        err.contains("topology") && err.contains('4') && err.contains('2'),
        "unhelpful: {err}"
    );
}

#[test]
fn mismatched_half_tier_precisions_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.topology = Topology::grid(2, 1);
    cfg.grad_dtype = DType::Bf16;
    cfg.intra_dtype = DType::F16; // a second distinct half format
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("intra"), "unhelpful: {err}");
}

#[test]
fn half_intra_tier_on_hlo_backend_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.backend = OptBackend::Hlo;
    cfg.grad_dtype = DType::F16;
    cfg.intra_dtype = DType::F16;
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("native"), "unhelpful: {err}");
}

#[test]
fn resume_opt_state_without_shard_optimizer_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.resume_opt_state = true;
    let Err(e) = Trainer::new(cfg) else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("shard_optimizer"), "unhelpful: {err}");
}

#[test]
fn resume_opt_state_from_params_only_checkpoint_errors() {
    let Some(meta) = meta_path() else { return };
    // a valid params-only checkpoint (no optshard:* tensors)
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(engine, &meta).unwrap();
    let params = rt.init_params(5);
    let dir = std::env::temp_dir().join("lans_fi_shard_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("params_only.ckpt");
    Checkpoint::new(
        1,
        rt.meta
            .params
            .iter()
            .zip(&params)
            .map(|(s, t)| (s.name.clone(), t.clone()))
            .collect(),
    )
    .save(&p)
    .unwrap();

    let mut cfg = base_cfg(meta);
    cfg.shard_optimizer = true;
    cfg.resume_opt_state = true;
    cfg.resume_from = Some(p);
    let Err(e) = Trainer::new(cfg).unwrap().run() else { panic!("expected error") };
    let err = format!("{e:#}");
    assert!(err.contains("no sharded optimizer state"), "unhelpful: {err}");
}

#[test]
fn unknown_optimizer_rejected() {
    let Some(meta) = meta_path() else { return };
    let mut cfg = base_cfg(meta);
    cfg.optimizer = "adagradzilla".into();
    // native backend: factory returns None -> error at run start
    let mut tr = Trainer::new(cfg).unwrap();
    assert!(tr.run().is_err());
}

#[test]
fn meta_struct_rejects_inconsistent_sizes() {
    // direct ModelMeta check (no engine needed)
    let bad = r#"{"tag": "x", "config": {"name": "x", "num_layers": 1,
        "hidden": 8, "num_heads": 2, "intermediate": 16, "vocab_size": 32,
        "max_seq_len": 16}, "batch": 1, "seq": 8, "mlm_slots": 2,
        "params": [{"name": "w", "shape": [3], "size": 2, "decay": true}],
        "param_count": 2, "artifacts": {}}"#;
    let j = lans::util::json::Json::parse(bad).unwrap();
    assert!(ModelMeta::from_json(&j, Path::new(".")).is_err());
}
