//! Checkpointing: binary save/restore of params + optimizer moments + step.
//!
//! Format (little-endian):
//!   magic "LANSCKPT" | version u32 | step u64 | n_tensors u32 |
//!   per tensor: name_len u32, name bytes, rank u32, dims u64…, data f32… |
//!   crc32 of everything after the magic
//!
//! Format versions: **v1** is the original params+moments layout; **v2**
//! (current) declares that auxiliary subsystem state may ride along as
//! extra named tensors (`optshard:*` sharded moments, `lossscale:state`
//! dynamic loss-scaler state).  The binary layout is unchanged, so v1
//! files load under v2 rules; files from a *newer* format fail with a
//! contextual error naming the path and the supported range instead of
//! mis-parsing.
//!
//! The two-phase pretraining flow depends on this: phase 2 (seq 512) resumes
//! from the phase-1 checkpoint, exactly as the paper's 3519+782-step split.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::TensorF32;

const MAGIC: &[u8; 8] = b"LANSCKPT";

/// The format version this build writes.
pub const FORMAT_VERSION: u32 = 2;
/// The oldest format version this build still reads.
pub const MIN_FORMAT_VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Format version: [`FORMAT_VERSION`] for checkpoints built in-process
    /// ([`Checkpoint::new`]); whatever the file declared after a load.
    /// Saving always writes the current [`FORMAT_VERSION`].
    pub version: u32,
    pub step: u64,
    /// named tensors: params first, then moments ("m:<name>", "v:<name>"),
    /// then any auxiliary subsystem state (v2)
    pub tensors: Vec<(String, TensorF32)>,
}

/// crc32 (IEEE) — small in-tree implementation (no external crates).
fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

impl Checkpoint {
    /// A checkpoint at the current [`FORMAT_VERSION`].
    pub fn new(step: u64, tensors: Vec<(String, TensorF32)>) -> Checkpoint {
        Checkpoint { version: FORMAT_VERSION, step, tensors }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        // create missing parent directories, and fail with the offending
        // directory in the message (not a bare io error) if that's impossible
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(|| {
                    format!("creating checkpoint directory {}", dir.display())
                })?;
            }
        }
        let mut body: Vec<u8> = Vec::new();
        body.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        body.extend_from_slice(&self.step.to_le_bytes());
        body.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            body.extend_from_slice(&(name.len() as u32).to_le_bytes());
            body.extend_from_slice(name.as_bytes());
            body.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        let crc = crc32(&body);
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {}", path.display()))?;
        f.write_all(MAGIC)
            .and_then(|()| f.write_all(&body))
            .and_then(|()| f.write_all(&crc.to_le_bytes()))
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut raw = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?
            .read_to_end(&mut raw)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        if raw.len() < MAGIC.len() + 4 || &raw[..8] != MAGIC {
            bail!("{}: not a LANS checkpoint", path.display());
        }
        let body = &raw[8..raw.len() - 4];
        let stored_crc = u32::from_le_bytes(raw[raw.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
        }

        let mut cur = body;
        let mut take = |n: usize| -> Result<&[u8]> {
            if cur.len() < n {
                return Err(anyhow!("truncated checkpoint"));
            }
            let (a, b) = cur.split_at(n);
            cur = b;
            Ok(a)
        };
        let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
            bail!(
                "{}: unsupported checkpoint format version {version} (this \
                 build reads {MIN_FORMAT_VERSION}..={FORMAT_VERSION}); was it \
                 written by a newer build?",
                path.display()
            );
        }
        let step = u64::from_le_bytes(take(8)?.try_into().unwrap());
        let n_tensors = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(name_len)?.to_vec())
                .map_err(|_| anyhow!("bad tensor name"))?;
            let rank = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize);
            }
            let n: usize = shape.iter().product();
            let bytes = take(n * 4)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push((name, TensorF32::new(shape, data)));
        }
        if !cur.is_empty() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { version, step, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint::new(
            42,
            vec![
                ("w".into(), TensorF32::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0])),
                ("m:w".into(), TensorF32::new(vec![4], vec![0.1; 4])),
            ],
        )
    }

    /// Rewrite a saved checkpoint's version field (offset 8..12, right
    /// after the magic) and refresh the trailing crc so only the version
    /// check can object.
    fn patch_version(path: &Path, version: u32) {
        let mut raw = std::fs::read(path).unwrap();
        raw[8..12].copy_from_slice(&version.to_le_bytes());
        let body_end = raw.len() - 4;
        let crc = crc32(&raw[8..body_end]);
        raw[body_end..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(path, &raw).unwrap();
    }

    #[test]
    fn roundtrip() {
        let p = std::env::temp_dir().join("lans_test_ckpt.bin");
        let c = sample();
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.version, FORMAT_VERSION);
        assert_eq!(back.step, 42);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].1, c.tensors[0].1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_files_still_load() {
        // the pre-versioned-aux-state format: same layout, version 1
        let p = std::env::temp_dir().join("lans_test_ckpt_v1.bin");
        sample().save(&p).unwrap();
        patch_version(&p, 1);
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.version, 1);
        assert_eq!(back.step, 42);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unknown_version_fails_with_context() {
        let p = std::env::temp_dir().join("lans_test_ckpt_v99.bin");
        sample().save(&p).unwrap();
        patch_version(&p, 99);
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("version 99"), "unhelpful: {err}");
        assert!(err.contains("lans_test_ckpt_v99.bin"), "unhelpful: {err}");
        assert!(
            err.contains(&format!("{MIN_FORMAT_VERSION}..={FORMAT_VERSION}")),
            "unhelpful: {err}"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn version_zero_rejected() {
        let p = std::env::temp_dir().join("lans_test_ckpt_v0.bin");
        sample().save(&p).unwrap();
        patch_version(&p, 0);
        let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
        assert!(err.contains("version 0"), "unhelpful: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_creates_missing_parent_dirs() {
        let root = std::env::temp_dir().join("lans_test_ckpt_nested");
        let _ = std::fs::remove_dir_all(&root);
        let p = root.join("a/b/c").join("ckpt.bin");
        sample().save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.step, 42);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn load_missing_file_names_the_path() {
        let err = format!(
            "{:#}",
            Checkpoint::load(Path::new("/nonexistent/dir/x.ckpt")).unwrap_err()
        );
        assert!(err.contains("x.ckpt"), "unhelpful: {err}");
    }

    #[test]
    fn save_behind_a_file_names_the_directory() {
        let base = std::env::temp_dir().join("lans_test_ckpt_parent_is_file");
        std::fs::write(&base, b"not a directory").unwrap();
        let p = base.join("ckpt.bin");
        let err = format!("{:#}", sample().save(&p).unwrap_err());
        assert!(
            err.contains("lans_test_ckpt_parent_is_file"),
            "unhelpful: {err}"
        );
        std::fs::remove_file(&base).ok();
    }

    #[test]
    fn detects_corruption() {
        let p = std::env::temp_dir().join("lans_test_ckpt_corrupt.bin");
        sample().save(&p).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(&p, &raw).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = std::env::temp_dir().join("lans_test_not_ckpt.bin");
        std::fs::write(&p, b"hello world, definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
