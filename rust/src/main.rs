//! `lans` — launcher CLI for the LANS reproduction.
//!
//! Subcommands:
//!   train --config <file.toml> [--steps N] [--optimizer NAME] [--workers N]
//!   schedule                      reproduce Fig. 1 (series + AUC gaps)
//!   time-model                    reproduce Table 2's time column
//!   variance [--n N] [--trials T] reproduce the §3.4 variance comparison
//!   info --meta <meta.json>       inspect an artifact bundle

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lans::cluster::{table2_runs, BERT_LARGE};
use lans::config::TrainConfig;
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::Schedule;
use lans::runtime::ModelMeta;
use lans::util::bench::Table;
use lans::variance::{sweep, GradientPopulation};

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", argv[i]))?;
            let v = argv
                .get(i + 1)
                .with_context(|| format!("--{k} needs a value"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(String::as_str)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("train") => cmd_train(&Args::parse(&argv[1..])?),
        Some("schedule") => cmd_schedule(),
        Some("time-model") => cmd_time_model(),
        Some("variance") => cmd_variance(&Args::parse(&argv[1..])?),
        Some("info") => cmd_info(&Args::parse(&argv[1..])?),
        _ => {
            eprintln!(
                "usage: lans <train|schedule|time-model|variance|info> [--flags]\n\
                 see README.md for examples"
            );
            bail!("missing or unknown subcommand");
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg_path = args.get("config").context("train needs --config <file>")?;
    let mut cfg = TrainConfig::from_file(Path::new(cfg_path))?;
    // flag overrides
    if let Some(s) = args.get("steps") {
        cfg.steps = s.parse()?;
    }
    if let Some(o) = args.get("optimizer") {
        cfg.optimizer = o.to_string();
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse()?;
    }
    if let Some(g) = args.get("global-batch") {
        cfg.global_batch = g.parse()?;
    }
    if let Some(c) = args.get("curve-out") {
        cfg.curve_out = Some(PathBuf::from(c));
    }

    let mut trainer = Trainer::new(cfg.clone())?;
    println!(
        "training {} | optimizer={} workers={} effective_batch={} steps={}",
        trainer.meta().tag,
        cfg.optimizer,
        cfg.workers,
        trainer.effective_batch(),
        cfg.steps
    );
    let report = trainer.run()?;
    match report.status {
        TrainStatus::Completed => {
            println!(
                "completed {} steps | final loss {:.4} | eval {:.4} | {:.0} tok/s",
                report.steps_run,
                report.recorder.last_loss().unwrap_or(f64::NAN),
                report.final_eval_loss.unwrap_or(f64::NAN),
                report.recorder.tokens_per_second()
            );
        }
        TrainStatus::Diverged { at_step } => {
            println!("DIVERGED at step {at_step} (ema loss blew past ceiling)");
        }
    }
    Ok(())
}

fn cmd_schedule() -> Result<()> {
    // Fig. 1 parameters
    let (t, tw, tc) = (3519u64, 1500u64, 963u64);
    let ideal = Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: tw, t_total: t };
    let small = Schedule::LinearWarmupDecay { eta: 0.007, t_warmup: tw, t_total: t };
    let ours = Schedule::WarmupConstDecay { eta: 0.007, t_warmup: tw, t_const: tc, t_total: t };

    println!("# Fig. 1 — learning-rate schedules (T={t}, Tw={tw}, Tc={tc})");
    println!("step\teq8_eta0.01\teq8_eta0.007\teq9_eta0.007");
    for step in (1..=t).step_by(100) {
        println!(
            "{step}\t{:.6}\t{:.6}\t{:.6}",
            ideal.lr(step),
            small.lr(step),
            ours.lr(step)
        );
    }
    let a_ideal = ideal.area_under_curve(t);
    let gap8 = a_ideal - small.area_under_curve(t);
    let gap9 = a_ideal - ours.area_under_curve(t);
    println!("\nAUC gap eq8(0.01)-eq8(0.007) = {gap8:.2}   (paper: 5.28)");
    println!("AUC gap eq8(0.01)-eq9(0.007) = {gap9:.2}   (paper: 1.91)");
    Ok(())
}

fn cmd_time_model() -> Result<()> {
    println!("# Table 2 — modeled time-to-train (see DESIGN.md §5)");
    let mut table = Table::new(&["run", "batch", "steps", "testbed", "modeled", "paper"]);
    let paper = ["76.2m", "53.6m"];
    for (run, p) in table2_runs().iter().zip(paper) {
        table.row(&[
            run.label.to_string(),
            format!(
                "{}K/{}K",
                run.phases[0].batch_seqs / 1024,
                run.phases[1].batch_seqs / 1024
            ),
            run.total_steps().to_string(),
            run.cluster.name.to_string(),
            format!("{:.1}m", run.total_minutes(&BERT_LARGE)),
            p.to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_variance(args: &Args) -> Result<()> {
    let n: usize = args.get("n").unwrap_or("4096").parse()?;
    let trials: usize = args.get("trials").unwrap_or("2000").parse()?;
    let pop = GradientPopulation::synthetic(n, 16, 1);
    let ks: Vec<usize> = [16, 64, 256, 1024, n / 2, n]
        .into_iter()
        .filter(|&k| k <= n)
        .collect();
    println!("# §3.4 — minibatch-mean gradient variance, n={n} ({trials} trials)");
    let mut table = Table::new(&[
        "k", "with-repl (emp)", "sigma^2/k", "without-repl (emp)", "(n-k)/(k(n-1)) sigma^2",
    ]);
    for row in sweep(&pop, &ks, trials, 7) {
        table.row(&[
            row.k.to_string(),
            format!("{:.3e}", row.with_repl_empirical),
            format!("{:.3e}", row.with_repl_theory),
            format!("{:.3e}", row.without_repl_empirical),
            format!("{:.3e}", row.without_repl_theory),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let meta_path = args.get("meta").context("info needs --meta <meta.json>")?;
    let meta = ModelMeta::load(Path::new(meta_path))?;
    println!("tag          {}", meta.tag);
    println!("config       {} (L={}, H={}, A={}, I={}, V={})",
        meta.config_name, meta.num_layers, meta.hidden, meta.num_heads,
        meta.intermediate, meta.vocab_size);
    println!("geometry     batch={} seq={} mlm_slots={}", meta.batch, meta.seq, meta.mlm_slots);
    println!("params       {} tensors, {} total", meta.params.len(), meta.param_count);
    println!("artifacts:");
    for (role, file) in &meta.artifacts {
        println!("  {role:<12} {file}");
    }
    Ok(())
}
