//! §3.4 reproduction: mini-batch gradient variance under sampling with vs
//! without replacement.
//!
//! The paper's argument: with replacement the variance of the mini-batch
//! mean is bounded by O(σ²/k); without replacement it is
//! O((n−k)/(k(n−1)) · σ²) — which *vanishes* at k = n, while the
//! with-replacement bound only vanishes as k → ∞.  This module measures
//! both empirically on a synthetic per-sample gradient population and
//! compares against the closed forms (exact for the mean estimator, not
//! just bounds, when σ² is the population variance).

use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// A synthetic population of per-sample "gradients" (d-dimensional), with a
/// known population mean and variance.
pub struct GradientPopulation {
    pub dim: usize,
    samples: Vec<Vec<f32>>, // n × d
    mean: Vec<f64>,
    /// population variance averaged over coordinates: (1/d)·Σ_j σ²_j
    pub sigma2: f64,
}

impl GradientPopulation {
    pub fn synthetic(n: usize, dim: usize, seed: u64) -> GradientPopulation {
        let mut rng = Rng::new(seed);
        // heavy-ish tails: mixture of two normals, like gradient noise
        let samples: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let scale = if rng.next_f64() < 0.1 { 4.0 } else { 1.0 };
                (0..dim).map(|_| (rng.normal() * scale) as f32).collect()
            })
            .collect();
        let mut mean = vec![0.0f64; dim];
        for s in &samples {
            for (m, &x) in mean.iter_mut().zip(s) {
                *m += x as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut sigma2 = 0.0;
        for s in &samples {
            for (j, &x) in s.iter().enumerate() {
                let d = x as f64 - mean[j];
                sigma2 += d * d;
            }
        }
        sigma2 /= (n * dim) as f64;
        GradientPopulation { dim, samples, mean, sigma2 }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Squared error of the mini-batch mean vs the population mean,
    /// averaged over coordinates.
    fn batch_mse(&self, idx: &[usize]) -> f64 {
        let k = idx.len() as f64;
        let mut mse = 0.0;
        for j in 0..self.dim {
            let mut s = 0.0;
            for &i in idx {
                s += self.samples[i][j] as f64;
            }
            let d = s / k - self.mean[j];
            mse += d * d;
        }
        mse / self.dim as f64
    }

    /// Monte-Carlo estimate of E‖mean_batch − mean_pop‖²/d for batch size k.
    pub fn empirical_variance(
        &self,
        k: usize,
        trials: usize,
        with_replacement: bool,
        seed: u64,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let mut w = Welford::default();
        for _ in 0..trials {
            let idx = if with_replacement {
                rng.sample_with_replacement(self.len(), k)
            } else {
                rng.sample_without_replacement(self.len(), k)
            };
            w.push(self.batch_mse(&idx));
        }
        w.mean()
    }

    /// Closed form, with replacement: σ²/k.
    pub fn theory_with_replacement(&self, k: usize) -> f64 {
        self.sigma2 / k as f64
    }

    /// Closed form, without replacement: (n−k)/(k(n−1)) · σ².
    pub fn theory_without_replacement(&self, k: usize) -> f64 {
        let n = self.len() as f64;
        let kf = k as f64;
        (n - kf) / (kf * (n - 1.0)) * self.sigma2
    }
}

/// One row of the variance-sweep table (the §3.4 bench output).
#[derive(Debug, Clone)]
pub struct VarianceRow {
    pub k: usize,
    pub with_repl_empirical: f64,
    pub with_repl_theory: f64,
    pub without_repl_empirical: f64,
    pub without_repl_theory: f64,
}

pub fn sweep(
    pop: &GradientPopulation,
    ks: &[usize],
    trials: usize,
    seed: u64,
) -> Vec<VarianceRow> {
    ks.iter()
        .map(|&k| VarianceRow {
            k,
            with_repl_empirical: pop.empirical_variance(k, trials, true, seed ^ k as u64),
            with_repl_theory: pop.theory_with_replacement(k),
            without_repl_empirical: pop.empirical_variance(
                k,
                trials,
                false,
                seed ^ (k as u64) << 1,
            ),
            without_repl_theory: pop.theory_without_replacement(k),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_matches_theory() {
        let pop = GradientPopulation::synthetic(512, 8, 1);
        for k in [8, 64, 256] {
            let e_wr = pop.empirical_variance(k, 3000, true, 2);
            let t_wr = pop.theory_with_replacement(k);
            assert!(
                (e_wr - t_wr).abs() / t_wr < 0.15,
                "with repl k={k}: {e_wr} vs {t_wr}"
            );
            let e_wo = pop.empirical_variance(k, 3000, false, 3);
            let t_wo = pop.theory_without_replacement(k);
            assert!(
                (e_wo - t_wo).abs() / t_wo.max(1e-12) < 0.15,
                "without repl k={k}: {e_wo} vs {t_wo}"
            );
        }
    }

    #[test]
    fn full_batch_without_replacement_is_exact() {
        let pop = GradientPopulation::synthetic(128, 4, 5);
        let v = pop.empirical_variance(128, 50, false, 6);
        assert!(v < 1e-12, "k=n must be exact, got {v}");
        // with replacement at k=n stays strictly positive
        let v_wr = pop.empirical_variance(128, 200, true, 7);
        assert!(v_wr > pop.sigma2 / 128.0 * 0.5);
    }

    #[test]
    fn without_beats_with_everywhere() {
        let pop = GradientPopulation::synthetic(256, 4, 9);
        for k in [16, 64, 192, 256] {
            assert!(
                pop.theory_without_replacement(k) <= pop.theory_with_replacement(k) + 1e-15,
                "k={k}"
            );
        }
    }
}
