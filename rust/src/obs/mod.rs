//! Forensic observability: the flight recorder and postmortem bundles.
//!
//! The third observability layer (DESIGN.md §13).  `trace/` answers *where
//! the time went* while you watch; `metrics/` answers *is the run healthy*
//! at the end; this module answers *what happened in the last K steps
//! before it went wrong* — after the process is already dead.
//!
//! [`flight`] keeps a bounded ring of per-step [`flight::FlightFrame`]s
//! (recorder row, span timeline, fresh health verdicts, registry counter
//! deltas, loss-scaler and step-clock state).  On a trigger — a Warn
//! health verdict, a loss-scale skip burst, an injected worker failure, or
//! a poisoned pool region / panicked DAG stage — [`postmortem`] seals the
//! retained window into a versioned JSON bundle on disk, pre-attributed to
//! the slowest (lane, stage) by interval math over the retained spans.
//! `lans-inspect postmortem` renders the bundle; `tools/check_postmortem.py`
//! validates it in CI.
//!
//! Overhead contract (same as the other two layers): disarmed, every seam
//! is one relaxed atomic load and a predictable branch — no allocation, no
//! locks, no clock reads.  Armed, the recorder only *observes* (clones of
//! already-computed state); training bits are identical either way, which
//! `prop_flight_recorder_toggle_is_bit_invisible` enforces.

pub mod flight;
pub mod postmortem;

pub use flight::{Culprit, FlightFrame, FlightRing, SealMeta, Trigger};
pub use postmortem::{slowest_stage, BUNDLE_SCHEMA};
