//! Postmortem bundles: seal the flight ring to a versioned JSON file and
//! pre-attribute the culprit (lane, stage) by interval math.
//!
//! The bundle is the crash-dump counterpart of the metrics `RunReport`:
//! written once, by whichever thread raised the trigger, with everything
//! an operator needs to answer "which stage, on which lane, at which
//! step" without the process that died.  Serialization is the same
//! hand-rolled strict JSON as `metrics/export` (no serde): every f64 goes
//! through `num()` (non-finite → `null`), every string through `esc()`.
//! `tools/check_postmortem.py` is the schema's keeper.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::flight::{Culprit, FlightFrame, FlightRing, SealMeta, Trigger};
use crate::metrics::export::{create_with_parents, esc, num, verdict_json};
use crate::trace::{self, StepTrace};

/// Version tag; bump on any breaking change to the bundle layout.
pub const BUNDLE_SCHEMA: &str = "lans-postmortem-v1";

/// The slowest (lane, stage) of a step: group the step's `sched` / `comm`
/// / `compute` spans by (lane, label), take each group's union measure
/// (nested and repeated spans count once), and return the largest.  This
/// is what upgrades a straggler verdict from "a step was slow" to "the
/// reduce-scatter on lans-pool-3 held the step".
pub fn slowest_stage(st: &StepTrace) -> Option<Culprit> {
    let mut groups: Vec<(&str, &'static str, Vec<(f64, f64)>)> = Vec::new();
    for lane in &st.lanes {
        for s in &lane.spans {
            if s.cat != trace::CAT_SCHED
                && s.cat != trace::CAT_COMM
                && s.cat != trace::CAT_COMPUTE
            {
                continue;
            }
            let iv = (s.start_s, s.end_s());
            match groups
                .iter_mut()
                .find(|(l, lab, _)| *l == lane.name && *lab == s.label)
            {
                Some((_, _, ivs)) => ivs.push(iv),
                None => groups.push((&lane.name, s.label, vec![iv])),
            }
        }
    }
    groups
        .into_iter()
        .map(|(lane, label, ivs)| {
            let dur = trace::measure(&trace::merge(ivs));
            (lane.to_string(), label, dur)
        })
        .max_by(|a, b| a.2.total_cmp(&b.2))
        .map(|(lane, stage, dur_s)| Culprit { lane, stage: stage.to_string(), dur_s })
}

fn culprit_json(c: &Culprit) -> String {
    format!(
        "{{\"lane\": \"{}\", \"stage\": \"{}\", \"dur_s\": {}}}",
        esc(&c.lane),
        esc(&c.stage),
        num(c.dur_s)
    )
}

fn spans_json(st: &StepTrace) -> String {
    let mut out = String::from("[");
    let mut first = true;
    for lane in &st.lanes {
        for s in &lane.spans {
            if !first {
                out.push_str(", ");
            }
            first = false;
            out.push_str(&format!(
                "{{\"lane\": \"{}\", \"cat\": \"{}\", \"label\": \"{}\", \
                 \"start_s\": {}, \"dur_s\": {}, \"detail\": {}}}",
                esc(&lane.name),
                esc(s.cat),
                esc(s.label),
                num(s.start_s),
                num(s.dur_s),
                s.detail
            ));
        }
    }
    out.push(']');
    out
}

fn frame_json(f: &FlightFrame) -> String {
    let record = match &f.record {
        Some(r) => format!(
            "{{\"lr\": {}, \"loss\": {}, \"loss_ema\": {}, \"grad_norm\": {}, \
             \"trust_ratio\": {}, \"tokens\": {}, \"wall_s\": {}, \"comm_s\": {}, \
             \"compute_s\": {}, \"overlap_eff\": {}, \"skipped\": {}, \"note\": \"{}\"}}",
            num(r.lr),
            num(r.loss),
            num(r.loss_ema),
            num(r.grad_norm),
            num(r.trust_ratio),
            r.tokens,
            num(r.wall_s),
            num(r.comm_s),
            num(r.compute_s),
            num(r.overlap_eff),
            r.skipped,
            esc(&r.note)
        ),
        None => "null".to_string(),
    };
    let deltas = f
        .counter_deltas
        .iter()
        .map(|(n, v)| format!("\"{}\": {v}", esc(n)))
        .collect::<Vec<_>>()
        .join(", ");
    let verdicts = f.verdicts.iter().map(verdict_json).collect::<Vec<_>>().join(", ");
    let spans = match &f.trace {
        Some(st) => spans_json(st),
        None => "null".to_string(),
    };
    format!(
        "{{\"step\": {}, \"partial\": {}, \"applied_steps\": {}, \"loss_scale\": {}, \
         \"scaler_overflows\": {}, \"record\": {record}, \"counter_deltas\": {{{deltas}}}, \
         \"verdicts\": [{verdicts}], \"spans\": {spans}}}",
        f.step,
        f.record.is_none(),
        f.applied_steps,
        num(f.loss_scale),
        f.scaler_overflows
    )
}

/// Render the whole bundle.  Split from [`write_bundle`] for tests.
pub fn bundle_json(meta: &SealMeta, ring: &FlightRing, trig: &Trigger) -> String {
    // pre-attribution: an explicit culprit from the trigger wins; a timing
    // trigger without one falls back to interval math over the newest
    // retained timeline
    let culprit = trig.culprit.clone().or_else(|| {
        ring.frames()
            .filter_map(|f| f.trace.as_ref())
            .next_back()
            .and_then(slowest_stage)
    });
    let config = meta
        .config_echo
        .iter()
        .map(|(k, v)| format!("    \"{}\": \"{}\"", esc(k), esc(v)))
        .collect::<Vec<_>>()
        .join(",\n");
    let frames = ring
        .frames()
        .map(|f| format!("    {}", frame_json(f)))
        .collect::<Vec<_>>()
        .join(",\n");
    let verdicts = ring
        .frames()
        .flat_map(|f| f.verdicts.iter())
        .map(|v| format!("    {}", verdict_json(v)))
        .collect::<Vec<_>>()
        .join(",\n");
    let snap = crate::metrics::registry::snapshot();
    let counters = snap
        .counters
        .iter()
        .map(|(n, v)| format!("\"{}\": {v}", esc(n)))
        .collect::<Vec<_>>()
        .join(", ");
    let gauges = snap
        .gauges
        .iter()
        .map(|(n, v)| format!("\"{}\": {}", esc(n), num(*v)))
        .collect::<Vec<_>>()
        .join(", ");
    let scaler = ring
        .frames()
        .next_back()
        .map(|f| {
            format!(
                "{{\"loss_scale\": {}, \"overflows\": {}}}",
                num(f.loss_scale),
                f.scaler_overflows
            )
        })
        .unwrap_or_else(|| "null".to_string());
    format!(
        "{{\n  \"schema\": \"{BUNDLE_SCHEMA}\",\n  \"trigger\": {{\"kind\": \"{}\", \
         \"step\": {}, \"message\": \"{}\"}},\n  \"culprit\": {},\n  \"config\": {{\n{}\n  }},\n  \
         \"flight_steps\": {},\n  \"frames\": [\n{}\n  ],\n  \"verdicts\": [\n{}\n  ],\n  \
         \"registry\": {{\"counters\": {{{counters}}}, \"gauges\": {{{gauges}}}}},\n  \
         \"scaler\": {scaler}\n}}\n",
        esc(trig.kind),
        trig.step,
        esc(&trig.message),
        culprit.as_ref().map(culprit_json).unwrap_or_else(|| "null".to_string()),
        config,
        ring.cap(),
        frames,
        verdicts,
    )
}

/// Seal the retained window to `path` (parents created on demand).
pub(crate) fn write_bundle(
    path: &Path,
    meta: &SealMeta,
    ring: &FlightRing,
    trig: &Trigger,
) -> Result<()> {
    let mut f = create_with_parents(path)?;
    f.write_all(bundle_json(meta, ring, trig).as_bytes())
        .with_context(|| format!("writing postmortem bundle {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Lane, TraceSpan};

    fn span(cat: &'static str, label: &'static str, start: f64, dur: f64) -> TraceSpan {
        TraceSpan { cat, label, start_s: start, dur_s: dur, detail: 0 }
    }

    #[test]
    fn slowest_stage_unions_per_lane_label() {
        let st = StepTrace {
            step: 7,
            lanes: vec![
                Lane {
                    name: "coordinator".into(),
                    spans: vec![
                        span(trace::CAT_COMM, "reduce_scatter", 0.0, 0.004),
                        // overlapping re-entry must union, not sum
                        span(trace::CAT_COMM, "reduce_scatter", 0.002, 0.003),
                        span(trace::CAT_STEP, "train_step", 0.0, 0.020),
                    ],
                },
                Lane {
                    name: "lans-pool-1".into(),
                    spans: vec![span(trace::CAT_COMPUTE, "optim_step", 0.001, 0.009)],
                },
            ],
        };
        let c = slowest_stage(&st).expect("culprit");
        assert_eq!(c.lane, "lans-pool-1");
        assert_eq!(c.stage, "optim_step");
        assert!((c.dur_s - 0.009).abs() < 1e-12);
        // the step-category wrapper must not win: it is not a stage
        assert_ne!(c.stage, "train_step");
    }

    #[test]
    fn slowest_stage_empty_trace_is_none() {
        assert!(slowest_stage(&StepTrace { step: 0, lanes: Vec::new() }).is_none());
    }

    #[test]
    fn bundle_json_is_valid_and_versioned() {
        let meta = SealMeta {
            bundle: None,
            config_echo: vec![("seed".into(), "42".into()), ("opt".into(), "lans".into())],
            cap: 4,
        };
        let mut ring = FlightRing::new(4);
        let mut f = FlightFrame::partial(3, None);
        f.loss_scale = 1024.0;
        ring.push(f);
        let trig = Trigger {
            kind: "worker_failure",
            step: 3,
            message: "worker 1 failed: \"injected\"".into(),
            culprit: Some(Culprit {
                lane: "worker-1".into(),
                stage: "worker_grads".into(),
                dur_s: 0.0,
            }),
        };
        let s = bundle_json(&meta, &ring, &trig);
        let j = crate::util::json::Json::parse(&s).expect("bundle parses");
        assert_eq!(j.expect("schema").as_str(), Some(BUNDLE_SCHEMA));
        assert_eq!(j.expect("trigger").expect("kind").as_str(), Some("worker_failure"));
        assert_eq!(j.expect("culprit").expect("lane").as_str(), Some("worker-1"));
        assert_eq!(j.expect("config").expect("seed").as_str(), Some("42"));
        let frames = j.expect("frames").as_arr().expect("frames array");
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].expect("step").as_usize(), Some(3));
        assert_eq!(frames[0].expect("partial").as_bool(), Some(true));
    }
}
