//! The flight recorder: a bounded ring of per-step forensic frames plus
//! the global arming/trigger state machine.
//!
//! The ring itself ([`FlightRing`]) is a plain struct so retention can be
//! property-tested without touching process-global state.  The global half
//! mirrors `trace/` and `metrics/registry`: one relaxed [`AtomicBool`] is
//! the only thing a disarmed seam ever touches, and everything mutable
//! lives behind a single [`Mutex`].
//!
//! Sealing is one-shot: the *first* trigger wins, later triggers are
//! no-ops.  The seal metadata (bundle path, config echo) is registered at
//! arm time, so a trigger raised from a panicking pool thread
//! ([`note_panic`]) can seal a bundle without any trainer cooperation —
//! the whole point of a flight recorder is surviving the crash.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::metrics::health::Verdict;
use crate::metrics::log as mlog;
use crate::metrics::recorder::StepRecord;
use crate::trace::StepTrace;

/// Skipped frames in the retained window that count as a burst.
pub const SKIP_BURST: usize = 3;

/// One retained step: everything the other observability layers computed
/// for it, cloned into the ring.
#[derive(Debug, Clone)]
pub struct FlightFrame {
    pub step: u64,
    /// the recorder row; `None` for a partial frame (the step died before
    /// the recorder saw it — worker failure mid-step)
    pub record: Option<StepRecord>,
    /// the step's span timeline (partial for a dying step)
    pub trace: Option<StepTrace>,
    /// health verdicts raised *this* step
    pub verdicts: Vec<Verdict>,
    /// registry counter increments since the previous frame
    pub counter_deltas: Vec<(&'static str, u64)>,
    /// loss scale in effect (1.0 when scaling is off)
    pub loss_scale: f64,
    /// cumulative scaler overflow count (0 when scaling is off)
    pub scaler_overflows: u64,
    /// optimizer step clock: steps actually applied (skips excluded)
    pub applied_steps: u64,
}

impl FlightFrame {
    /// A frame for a step that died before the recorder saw it.
    pub fn partial(step: u64, trace: Option<StepTrace>) -> FlightFrame {
        FlightFrame {
            step,
            record: None,
            trace,
            verdicts: Vec::new(),
            counter_deltas: Vec::new(),
            loss_scale: 1.0,
            scaler_overflows: 0,
            applied_steps: 0,
        }
    }
}

/// Fixed-capacity ring retaining exactly the last `cap` pushed frames.
#[derive(Debug)]
pub struct FlightRing {
    cap: usize,
    frames: VecDeque<FlightFrame>,
}

impl FlightRing {
    pub fn new(cap: usize) -> FlightRing {
        let cap = cap.max(1);
        FlightRing { cap, frames: VecDeque::with_capacity(cap) }
    }

    pub fn push(&mut self, f: FlightFrame) {
        if self.frames.len() == self.cap {
            self.frames.pop_front();
        }
        self.frames.push_back(f);
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    pub fn frames(&self) -> impl DoubleEndedIterator<Item = &FlightFrame> {
        self.frames.iter()
    }

    pub fn last_step(&self) -> Option<u64> {
        self.frames.back().map(|f| f.step)
    }

    /// Retained step indices, oldest first.
    pub fn steps(&self) -> Vec<u64> {
        self.frames.iter().map(|f| f.step).collect()
    }

    /// Skipped (overflow) frames in the retained window.
    pub fn skipped_frames(&self) -> usize {
        self.frames
            .iter()
            .filter(|f| f.record.as_ref().is_some_and(|r| r.skipped))
            .count()
    }
}

/// Where the culprit pre-attribution points: one (lane, stage) pair and,
/// when it came from interval math, how long that stage held the lane.
#[derive(Debug, Clone)]
pub struct Culprit {
    pub lane: String,
    pub stage: String,
    pub dur_s: f64,
}

/// What sealed the bundle.  `kind` is one of `health_verdict` |
/// `skip_burst` | `worker_failure` | `pool_poison`.
#[derive(Debug, Clone)]
pub struct Trigger {
    pub kind: &'static str,
    pub step: u64,
    pub message: String,
    pub culprit: Option<Culprit>,
}

/// Registered at arm time so any thread can seal without the trainer.
#[derive(Debug, Clone)]
pub struct SealMeta {
    /// bundle destination; `None` keeps the ring without sealing to disk
    pub bundle: Option<PathBuf>,
    /// run configuration echo, landed verbatim in the bundle
    pub config_echo: Vec<(String, String)>,
    /// ring capacity K
    pub cap: usize,
}

struct FlightState {
    ring: FlightRing,
    meta: SealMeta,
    /// previous frame's counter values, for delta computation
    last_counters: Vec<(&'static str, u64)>,
    /// the trigger that sealed this run, if any (first wins)
    sealed: Option<Trigger>,
    /// where the bundle actually landed
    last_bundle: Option<PathBuf>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<FlightState>> = Mutex::new(None);

/// The one disarmed-path cost: a relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the recorder: reset the ring to `meta.cap` frames and register the
/// seal metadata.  Re-arming discards any previous state.
pub fn arm(meta: SealMeta) {
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    *g = Some(FlightState {
        ring: FlightRing::new(meta.cap),
        last_counters: Vec::new(),
        sealed: None,
        last_bundle: None,
        meta,
    });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Disarm and drop all state; returns the sealed bundle path, if any.
pub fn disarm() -> Option<PathBuf> {
    ENABLED.store(false, Ordering::Relaxed);
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    g.take().and_then(|s| s.last_bundle)
}

/// The trigger that sealed the armed run, if any.
pub fn sealed_trigger() -> Option<Trigger> {
    let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    g.as_ref().and_then(|s| s.sealed.clone())
}

/// Push one step's frame.  Counter deltas are computed here against the
/// previous frame's registry snapshot (zeros when the registry is off).
pub fn push_frame(mut frame: FlightFrame) {
    if !enabled() {
        return;
    }
    let snap = crate::metrics::registry::snapshot();
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let Some(st) = g.as_mut() else { return };
    frame.counter_deltas = snap
        .counters
        .iter()
        .map(|&(name, v)| {
            let prev = st
                .last_counters
                .iter()
                .find(|(n, _)| *n == name)
                .map_or(0, |&(_, p)| p);
            (name, v.saturating_sub(prev))
        })
        .collect();
    st.last_counters = snap.counters;
    st.ring.push(frame);
}

/// Raise a trigger.  The first trigger per armed run wins: it is recorded,
/// and if a bundle path was registered the retained window is sealed to
/// disk.  Returns the bundle path when a bundle was just written.
pub fn trigger(t: Trigger) -> Option<PathBuf> {
    if !enabled() {
        return None;
    }
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let st = g.as_mut()?;
    if st.sealed.is_some() {
        return None;
    }
    st.sealed = Some(t.clone());
    let path = st.meta.bundle.clone()?;
    match super::postmortem::write_bundle(&path, &st.meta, &st.ring, &t) {
        Ok(()) => {
            st.last_bundle = Some(path.clone());
            Some(path)
        }
        Err(e) => {
            mlog::warn("flight", &format!("failed to seal postmortem bundle: {e:#}"));
            None
        }
    }
}

/// Skip-burst trigger: call after pushing a skipped frame.  Fires when at
/// least [`SKIP_BURST`] retained frames are skips.
pub fn check_skip_burst(step: u64) -> Option<PathBuf> {
    if !enabled() {
        return None;
    }
    let n = {
        let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
        g.as_ref().map_or(0, |s| s.ring.skipped_frames())
    };
    if n < SKIP_BURST {
        return None;
    }
    trigger(Trigger {
        kind: "skip_burst",
        step,
        message: format!(
            "{n} skipped steps within the retained window — the loss scaler \
             is burning batches, not settling"
        ),
        culprit: Some(Culprit {
            lane: "coordinator".to_string(),
            stage: "loss_scale".to_string(),
            dur_s: 0.0,
        }),
    })
}

/// Worker-failure trigger: seals before the trainer surfaces the error, so
/// the bundle names the failed lane even though the run is about to bail.
pub fn worker_failure(step: u64, worker: usize, err: &str) -> Option<PathBuf> {
    trigger(Trigger {
        kind: "worker_failure",
        step,
        message: format!("worker {worker} failed at step {step}: {err}"),
        culprit: Some(Culprit {
            lane: format!("worker-{worker}"),
            stage: "worker_grads".to_string(),
            dur_s: 0.0,
        }),
    })
}

/// Panic hook for the pool / DAG scheduler: called from the thread that
/// detected a poisoned region or a panicked stage, *before* the panic is
/// re-raised.  Must stay cheap and lock-light — it runs on an unwinding
/// path.  `origin` is "pool" or "dag"; `stage` is the panicking stage's
/// label ("pool_region" when the pool cannot know).
pub fn note_panic(origin: &'static str, stage: &'static str) {
    if !enabled() {
        return;
    }
    let step = {
        let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
        g.as_ref().and_then(|s| s.ring.last_step()).unwrap_or(0)
    };
    trigger(Trigger {
        kind: "pool_poison",
        step,
        message: format!("{origin}: stage '{stage}' panicked and poisoned the region"),
        culprit: Some(Culprit {
            lane: origin.to_string(),
            stage: stage.to_string(),
            dur_s: 0.0,
        }),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(step: u64, skipped: bool) -> FlightFrame {
        FlightFrame {
            step,
            record: Some(StepRecord {
                step,
                lr: 1e-3,
                loss: 1.0,
                loss_ema: 1.0,
                grad_norm: 1.0,
                trust_ratio: 1.0,
                tokens: 256,
                wall_s: step as f64 * 0.01,
                loss_scale: 1.0,
                skipped,
                comm_s: 0.0,
                compute_s: 0.0,
                overlap_eff: 0.0,
                note: String::new(),
            }),
            trace: None,
            verdicts: Vec::new(),
            counter_deltas: Vec::new(),
            loss_scale: 1.0,
            scaler_overflows: 0,
            applied_steps: step,
        }
    }

    #[test]
    fn ring_retains_exactly_last_k() {
        let mut r = FlightRing::new(4);
        for t in 1..=10 {
            r.push(frame(t, false));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.steps(), vec![7, 8, 9, 10]);
        assert_eq!(r.last_step(), Some(10));
    }

    #[test]
    fn ring_cap_floor_is_one() {
        let mut r = FlightRing::new(0);
        r.push(frame(1, false));
        r.push(frame(2, false));
        assert_eq!(r.steps(), vec![2]);
    }

    #[test]
    fn skipped_frames_counts_only_skips() {
        let mut r = FlightRing::new(8);
        for t in 1..=6 {
            r.push(frame(t, t % 2 == 0));
        }
        assert_eq!(r.skipped_frames(), 3);
        // eviction forgets old skips
        let mut r = FlightRing::new(2);
        r.push(frame(1, true));
        r.push(frame(2, false));
        r.push(frame(3, false));
        assert_eq!(r.skipped_frames(), 0);
    }

    #[test]
    fn first_trigger_wins_and_disarm_clears() {
        // serialize against other global-state tests via the metrics lock
        let _g = mlog::test_lock();
        arm(SealMeta { bundle: None, config_echo: Vec::new(), cap: 4 });
        assert!(enabled());
        push_frame(frame(1, false));
        trigger(Trigger { kind: "skip_burst", step: 1, message: "first".into(), culprit: None });
        trigger(Trigger {
            kind: "worker_failure",
            step: 2,
            message: "second".into(),
            culprit: None,
        });
        let t = sealed_trigger().expect("first trigger recorded");
        assert_eq!(t.kind, "skip_burst");
        assert_eq!(t.message, "first");
        assert_eq!(disarm(), None, "no bundle path registered");
        assert!(!enabled());
        assert!(sealed_trigger().is_none());
    }

    #[test]
    fn disarmed_seams_are_inert() {
        let _g = mlog::test_lock();
        let _ = disarm();
        push_frame(frame(1, false));
        assert!(trigger(Trigger {
            kind: "pool_poison",
            step: 0,
            message: "ignored".into(),
            culprit: None
        })
        .is_none());
        assert!(check_skip_burst(1).is_none());
        note_panic("pool", "pool_region");
        assert!(sealed_trigger().is_none());
    }
}
