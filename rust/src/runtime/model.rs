//! High-level model runtime: couples a `ModelMeta` with the engine and
//! exposes typed train/eval/optimizer-step entry points over the canonical
//! parameter order.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::data::MlmBatch;
use crate::util::rng::Rng;

use super::engine::Engine;
use super::meta::ModelMeta;
use super::tensor::{HostTensor, TensorF32, TensorI32};

/// Optimizer state (first/second moments), canonical order.
#[derive(Debug, Clone)]
pub struct OptState {
    pub m: Vec<TensorF32>,
    pub v: Vec<TensorF32>,
    /// 1-based step counter fed to the bias corrections.
    pub step: u64,
}

/// Cheap to clone: workers hold clones (the meta is shared, the engine is a
/// channel handle to the single device thread).
#[derive(Clone)]
pub struct ModelRuntime {
    pub meta: std::sync::Arc<ModelMeta>,
    engine: Engine,
}

impl ModelRuntime {
    /// Load meta + the fwd_bwd/eval artifacts; optimizer artifacts are
    /// loaded on demand via [`ModelRuntime::load_optimizer`].
    pub fn load(engine: Engine, meta_path: &Path) -> Result<ModelRuntime> {
        let meta = std::sync::Arc::new(ModelMeta::load(meta_path)?);
        let rt = ModelRuntime { meta, engine };
        rt.engine
            .load(&rt.key("fwd_bwd"), rt.meta.artifact_path("fwd_bwd")?)?;
        if rt.meta.artifacts.contains_key("eval") {
            rt.engine
                .load(&rt.key("eval"), rt.meta.artifact_path("eval")?)?;
        }
        Ok(rt)
    }

    fn key(&self, role: &str) -> String {
        format!("{}::{}", self.meta.tag, role)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Compile the `opt_<name>` artifact (idempotent per engine key).
    pub fn load_optimizer(&self, name: &str) -> Result<()> {
        let role = format!("opt_{name}");
        self.engine
            .load(&self.key(&role), self.meta.artifact_path(&role)?)
    }

    /// BERT-style initialisation: truncated-normal(0.02) for kernels and
    /// embeddings, zeros for biases, ones for LayerNorm scales.
    pub fn init_params(&self, seed: u64) -> Vec<TensorF32> {
        let mut rng = Rng::new(seed);
        self.meta
            .params
            .iter()
            .map(|p| {
                let data: Vec<f32> = if p.name.ends_with("ln_scale") {
                    vec![1.0; p.size]
                } else if p.name.ends_with("_bias") || p.name.ends_with("ln_bias") {
                    vec![0.0; p.size]
                } else {
                    (0..p.size)
                        .map(|_| {
                            let z = rng.normal_f32().clamp(-2.0, 2.0);
                            z * 0.02
                        })
                        .collect()
                };
                TensorF32::new(p.shape.clone(), data)
            })
            .collect()
    }

    pub fn zero_opt_state(&self) -> OptState {
        let zeros: Vec<TensorF32> = self
            .meta
            .params
            .iter()
            .map(|p| TensorF32::zeros(p.shape.clone()))
            .collect();
        OptState { m: zeros.clone(), v: zeros, step: 0 }
    }

    fn batch_tensors(&self, batch: &MlmBatch) -> Result<Vec<HostTensor>> {
        let (b, s, p) = (self.meta.batch, self.meta.seq, self.meta.mlm_slots);
        if batch.tokens.len() != b * s || batch.positions.len() != b * p {
            bail!(
                "batch geometry mismatch: artifact wants b={b} s={s} slots={p}, \
                 got tokens={} positions={}",
                batch.tokens.len(),
                batch.positions.len()
            );
        }
        Ok(vec![
            TensorI32::new(vec![b, s], batch.tokens.clone()).into(),
            TensorI32::new(vec![b, p], batch.positions.clone()).into(),
            TensorI32::new(vec![b, p], batch.target_ids.clone()).into(),
            TensorF32::new(vec![b, p], batch.weights.clone()).into(),
        ])
    }

    fn check_params(&self, params: &[TensorF32]) -> Result<()> {
        if params.len() != self.meta.params.len() {
            bail!(
                "expected {} param tensors, got {}",
                self.meta.params.len(),
                params.len()
            );
        }
        Ok(())
    }

    /// One microbatch forward+backward: returns (loss, grads).
    pub fn fwd_bwd(
        &self,
        params: &[TensorF32],
        batch: &MlmBatch,
    ) -> Result<(f32, Vec<TensorF32>)> {
        self.check_params(params)?;
        let mut inputs: Vec<HostTensor> =
            params.iter().cloned().map(HostTensor::from).collect();
        inputs.extend(self.batch_tensors(batch)?);
        let mut out = self.engine.run(&self.key("fwd_bwd"), inputs)?;
        if out.len() != 1 + self.meta.params.len() {
            bail!(
                "fwd_bwd returned {} outputs, expected {}",
                out.len(),
                1 + self.meta.params.len()
            );
        }
        let grads = out
            .split_off(1)
            .into_iter()
            .map(HostTensor::into_f32)
            .collect::<Result<Vec<_>>>()?;
        let loss = out[0].as_f32()?.data[0];
        Ok((loss, grads))
    }

    /// Forward-only loss on a held-out batch.
    pub fn eval_loss(&self, params: &[TensorF32], batch: &MlmBatch) -> Result<f32> {
        self.check_params(params)?;
        let mut inputs: Vec<HostTensor> =
            params.iter().cloned().map(HostTensor::from).collect();
        inputs.extend(self.batch_tensors(batch)?);
        let out = self.engine.run(&self.key("eval"), inputs)?;
        Ok(out
            .first()
            .ok_or_else(|| anyhow!("eval returned no outputs"))?
            .as_f32()?
            .data[0])
    }

    /// One optimizer step through the AOT `opt_<name>` artifact.
    /// Mutates `params` and `state` in place; `state.step` is incremented
    /// *before* the update (the kernels expect the 1-based t).
    pub fn opt_step(
        &self,
        name: &str,
        params: &mut [TensorF32],
        state: &mut OptState,
        grads: &[TensorF32],
        lr: f32,
    ) -> Result<()> {
        self.check_params(params)?;
        state.step += 1;
        let n = params.len();
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(4 * n + 2);
        inputs.extend(params.iter().cloned().map(HostTensor::from));
        inputs.extend(state.m.iter().cloned().map(HostTensor::from));
        inputs.extend(state.v.iter().cloned().map(HostTensor::from));
        inputs.extend(grads.iter().cloned().map(HostTensor::from));
        inputs.push(TensorF32::scalar1(lr).into());
        inputs.push(TensorF32::scalar1(state.step as f32).into());

        let out = self
            .engine
            .run(&self.key(&format!("opt_{name}")), inputs)?;
        if out.len() != 3 * n {
            bail!("opt step returned {} outputs, expected {}", out.len(), 3 * n);
        }
        let mut it = out.into_iter();
        for i in 0..n {
            params[i] = it.next().unwrap().into_f32()?;
        }
        for i in 0..n {
            state.m[i] = it.next().unwrap().into_f32()?;
        }
        for i in 0..n {
            state.v[i] = it.next().unwrap().into_f32()?;
        }
        Ok(())
    }
}
