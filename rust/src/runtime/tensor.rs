//! Host-side tensors: the Send-able currency between coordinator threads
//! and the PJRT device thread.
//!
//! PJRT objects (`PjRtClient` is `Rc`-based) are confined to the device
//! thread (`runtime::engine`); everything that crosses a channel is a
//! `HostTensor`.  Only f32 and i32 appear in the BERT artifacts.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, Shape};

#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(TensorF32),
    I32(TensorI32),
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar1(x: f32) -> Self {
        TensorF32 { shape: vec![1], data: vec![x] }
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        TensorI32 { shape, data }
    }
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(t) => &t.shape,
            HostTensor::I32(t) => &t.shape,
        }
    }

    pub fn numel(&self) -> usize {
        numel(self.shape())
    }

    pub fn as_f32(&self) -> Result<&TensorF32> {
        match self {
            HostTensor::F32(t) => Ok(t),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<TensorF32> {
        match self {
            HostTensor::F32(t) => Ok(t),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Convert to an XLA literal (device-thread side).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(t) => Literal::vec1(&t.data),
            HostTensor::I32(t) => Literal::vec1(&t.data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert back from an XLA literal (device-thread side).
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.shape().context("literal shape")?;
        let ashape = match shape {
            Shape::Array(a) => a,
            other => bail!("expected array literal, got {other:?}"),
        };
        let dims: Vec<usize> = ashape.dims().iter().map(|&d| d as usize).collect();
        match ashape.element_type() {
            ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(HostTensor::F32(TensorF32::new(dims, data)))
            }
            ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(HostTensor::I32(TensorI32::new(dims, data)))
            }
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

impl From<TensorF32> for HostTensor {
    fn from(t: TensorF32) -> Self {
        HostTensor::F32(t)
    }
}

impl From<TensorI32> for HostTensor {
    fn from(t: TensorI32) -> Self {
        HostTensor::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = TensorF32::zeros(vec![2, 3]);
        assert_eq!(HostTensor::from(t).numel(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        TensorF32::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = TensorF32::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let ht = HostTensor::from(t.clone());
        let lit = ht.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, ht);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = TensorI32::new(vec![4], vec![1, -2, 3, -4]);
        let ht = HostTensor::from(t);
        let back = HostTensor::from_literal(&ht.to_literal().unwrap()).unwrap();
        assert_eq!(back, ht);
    }
}
