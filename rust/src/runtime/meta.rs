//! Parser for the `<tag>.meta.json` files written by `python/compile/aot.py`.
//!
//! The meta file is the contract between the compile path and the runtime:
//! canonical parameter order (names, shapes, weight-decay flags), batch
//! geometry, and the artifact-file names for each executable.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    /// Whether weight decay applies (false for biases / LayerNorm params).
    pub decay: bool,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub tag: String,
    pub config_name: String,
    pub num_layers: usize,
    pub hidden: usize,
    pub num_heads: usize,
    pub intermediate: usize,
    pub vocab_size: usize,
    pub max_seq_len: usize,
    pub batch: usize,
    pub seq: usize,
    pub mlm_slots: usize,
    pub params: Vec<ParamSpec>,
    pub param_count: usize,
    /// artifact role ("fwd_bwd", "eval", "opt_lans", …) → file name
    pub artifacts: BTreeMap<String, String>,
    /// directory the meta file was loaded from (artifact paths are relative)
    pub dir: PathBuf,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&j, path.parent().unwrap_or(Path::new(".")))
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<ModelMeta> {
        let need_str = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("meta missing string {k:?}"))?
                .to_string())
        };
        let need_usize = |v: &Json, k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta missing number {k:?}"))
        };

        let cfg = j.get("config").ok_or_else(|| anyhow!("meta missing config"))?;
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta missing params"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                let shape: Vec<usize> = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?;
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    size: need_usize(p, "size")?,
                    decay: p
                        .get("decay")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| anyhow!("param missing decay"))?,
                    shape,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let artifacts = match j.get("artifacts") {
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        v.as_str()
                            .ok_or_else(|| anyhow!("artifact path not a string"))?
                            .to_string(),
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => BTreeMap::new(),
        };

        // sanity: declared sizes match shapes
        for p in &params {
            let n: usize = p.shape.iter().product();
            if n != p.size {
                return Err(anyhow!("param {}: size {} != shape product {n}",
                                   p.name, p.size));
            }
        }

        Ok(ModelMeta {
            tag: need_str("tag")?,
            config_name: cfg
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("config missing name"))?
                .to_string(),
            num_layers: need_usize(cfg, "num_layers")?,
            hidden: need_usize(cfg, "hidden")?,
            num_heads: need_usize(cfg, "num_heads")?,
            intermediate: need_usize(cfg, "intermediate")?,
            vocab_size: need_usize(cfg, "vocab_size")?,
            max_seq_len: need_usize(cfg, "max_seq_len")?,
            batch: need_usize(j, "batch")?,
            seq: need_usize(j, "seq")?,
            mlm_slots: need_usize(j, "mlm_slots")?,
            param_count: need_usize(j, "param_count")?,
            params,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact_path(&self, role: &str) -> Result<PathBuf> {
        let name = self
            .artifacts
            .get(role)
            .ok_or_else(|| anyhow!("meta {} has no artifact {role:?}; have {:?}",
                                   self.tag, self.artifacts.keys()))?;
        Ok(self.dir.join(name))
    }

    /// Block table for the pure-rust optimizers: (name, size, decay).
    pub fn blocks(&self) -> Vec<(String, usize, bool)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.size, p.decay))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "tag": "bert-x_s8_b2",
          "config": {"name": "bert-x", "num_layers": 1, "hidden": 8,
                     "num_heads": 2, "intermediate": 16, "vocab_size": 32,
                     "max_seq_len": 16, "type_vocab": 2,
                     "layernorm_eps": 1e-12},
          "batch": 2, "seq": 8, "mlm_slots": 2,
          "params": [{"name": "w", "shape": [4, 2], "size": 8, "decay": true},
                     {"name": "b", "shape": [2], "size": 2, "decay": false}],
          "param_count": 10,
          "hyper": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-6,
                    "weight_decay": 0.01},
          "artifacts": {"fwd_bwd": "fwd_bwd_x.hlo.txt"}
        }"#
    }

    #[test]
    fn parses_meta() {
        let j = Json::parse(sample()).unwrap();
        let m = ModelMeta::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.config_name, "bert-x");
        assert_eq!(m.params.len(), 2);
        assert!(m.params[0].decay);
        assert!(!m.params[1].decay);
        assert_eq!(m.artifact_path("fwd_bwd").unwrap(),
                   PathBuf::from("/tmp/a/fwd_bwd_x.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn rejects_size_mismatch() {
        let bad = sample().replace("\"size\": 8", "\"size\": 9");
        let j = Json::parse(&bad).unwrap();
        assert!(ModelMeta::from_json(&j, Path::new(".")).is_err());
    }
}
