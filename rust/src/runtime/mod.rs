//! Runtime layer: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `*.meta.json`) and executes them on a PJRT CPU client from a dedicated
//! device thread.  Adapted from /opt/xla-example/load_hlo.

pub mod engine;
pub mod meta;
pub mod model;
pub mod tensor;

pub use engine::Engine;
pub use meta::{ModelMeta, ParamSpec};
pub use model::ModelRuntime;
pub use tensor::{HostTensor, TensorF32, TensorI32};
