//! PJRT execution engine: a dedicated device thread owning all XLA state.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and must not cross threads,
//! while the coordinator is multi-threaded (data-pipeline workers, leader).
//! So the engine spawns one *device thread* that owns the client and every
//! compiled executable; coordinator threads talk to it through a channel
//! with `HostTensor` payloads.  This mirrors how real trainers serialize
//! access to an accelerator stream.
//!
//! Executables are loaded from HLO *text* (`HloModuleProto::from_text_file`)
//! — see DESIGN.md for why text, not serialized protos.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::thread;

use anyhow::{anyhow, Context, Result};

use super::tensor::HostTensor;

enum Req {
    Load {
        key: String,
        path: PathBuf,
        reply: SyncSender<Result<()>>,
    },
    Run {
        key: String,
        inputs: Vec<HostTensor>,
        reply: SyncSender<Result<Vec<HostTensor>>>,
    },
    /// Number of executables currently loaded (health/introspection).
    Stats { reply: SyncSender<usize> },
}

/// Clonable, Send handle to the device thread.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Req>,
}

impl Engine {
    /// Spawn the device thread with a PJRT CPU client.
    pub fn cpu() -> Result<Engine> {
        let (tx, rx) = std::sync::mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = sync_channel::<Result<String>>(1);
        thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || device_thread(rx, ready_tx))
            .context("spawning device thread")?;
        match ready_rx.recv().context("device thread died during init")? {
            Ok(_platform) => Ok(Engine { tx }),
            Err(e) => Err(e),
        }
    }

    /// Compile the HLO-text artifact at `path` and register it under `key`.
    pub fn load(&self, key: &str, path: PathBuf) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Req::Load { key: key.to_string(), path, reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    /// Execute the executable registered under `key`.
    ///
    /// The artifact was lowered with `return_tuple=True`, so the device
    /// thread unpacks the single tuple result into one `HostTensor` per
    /// output.
    pub fn run(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Req::Run { key: key.to_string(), inputs, reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn loaded_count(&self) -> Result<usize> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Req::Stats { reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))
    }
}

// the immediately-invoked closures are deliberate try-blocks: every error
// must be replied over the channel, never unwound through the device thread
#[allow(clippy::redundant_closure_call)]
fn device_thread(rx: Receiver<Req>, ready: SyncSender<Result<String>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(c.platform_name()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu: {e}")));
            return;
        }
    };

    let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Req::Load { key, path, reply } => {
                let r = (|| -> Result<()> {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
                    exes.insert(key, exe);
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Req::Run { key, inputs, reply } => {
                let r = (|| -> Result<Vec<HostTensor>> {
                    let exe = exes
                        .get(&key)
                        .ok_or_else(|| anyhow!("no executable {key:?} loaded"))?;
                    let lits = inputs
                        .iter()
                        .map(|t| t.to_literal())
                        .collect::<Result<Vec<_>>>()?;
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| anyhow!("executing {key:?}: {e}"))?;
                    let out = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("fetching result of {key:?}: {e}"))?;
                    let parts = out
                        .to_tuple()
                        .map_err(|e| anyhow!("untupling result of {key:?}: {e}"))?;
                    parts
                        .iter()
                        .map(HostTensor::from_literal)
                        .collect::<Result<Vec<_>>>()
                })();
                let _ = reply.send(r);
            }
            Req::Stats { reply } => {
                let _ = reply.send(exes.len());
            }
        }
    }
    // channel closed: drop executables, then the client
}
