//! Training metrics: loss-curve recording, throughput counters, TSV export.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::stats::Ema;

/// One recorded training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub lr: f64,
    pub loss: f64,
    pub loss_ema: f64,
    pub grad_norm: f64,
    pub trust_ratio: f64,
    pub tokens: u64,
    pub wall_s: f64,
    /// loss scale in effect this step (1.0 when loss scaling is off)
    pub loss_scale: f64,
    /// true when the update was skipped (gradient overflow under loss
    /// scaling) — the data was still consumed, the parameters untouched
    pub skipped: bool,
    /// wall time with communication in flight this step (union of the
    /// step's `comm` trace spans); 0.0 when tracing is off
    pub comm_s: f64,
    /// wall time with optimizer arithmetic in flight; 0.0 when tracing
    /// is off
    pub compute_s: f64,
    /// hidden-comm fraction: how much of `comm_s` was simultaneously
    /// covered by compute ([`trace::StepTrace::overlap_efficiency`]);
    /// 0.0 when tracing is off or the phases ran back-to-back
    ///
    /// [`trace::StepTrace::overlap_efficiency`]:
    /// crate::trace::StepTrace::overlap_efficiency
    pub overlap_eff: f64,
    /// skip diagnostic ("overflow at loss scale 2^15, scale -> 16384");
    /// empty for applied steps.  Lands in the TSV `note` column so a run's
    /// skip history survives in the curve file, not just on stderr.
    pub note: String,
}

/// Loss-curve recorder with EMA smoothing and divergence detection.
pub struct Recorder {
    pub records: Vec<StepRecord>,
    ema: Ema,
    start: Instant,
    tokens_seen: u64,
    skipped: u64,
    /// loss above this, or non-finite, counts as diverged
    pub divergence_ceiling: f64,
    initial_loss: Option<f64>,
}

impl Recorder {
    pub fn new(ema_alpha: f64) -> Recorder {
        Recorder {
            records: Vec::new(),
            ema: Ema::new(ema_alpha),
            start: Instant::now(),
            tokens_seen: 0,
            skipped: 0,
            divergence_ceiling: f64::INFINITY,
            initial_loss: None,
        }
    }

    pub fn push(
        &mut self,
        step: u64,
        lr: f64,
        loss: f64,
        grad_norm: f64,
        trust_ratio: f64,
        tokens: u64,
    ) -> &StepRecord {
        self.push_scaled(step, lr, loss, grad_norm, trust_ratio, tokens, 1.0)
    }

    /// [`push`](Recorder::push) with the loss scale in effect recorded.
    #[allow(clippy::too_many_arguments)]
    pub fn push_scaled(
        &mut self,
        step: u64,
        lr: f64,
        loss: f64,
        grad_norm: f64,
        trust_ratio: f64,
        tokens: u64,
        loss_scale: f64,
    ) -> &StepRecord {
        self.push_record(step, lr, loss, grad_norm, trust_ratio, tokens, loss_scale, false)
    }

    /// Record a *skipped* step: the gradient overflowed under loss scaling
    /// and the update was dropped.  The batch was still consumed (tokens
    /// advance), grad norm / trust ratio are not meaningful (NaN).  The
    /// `note` diagnostic is persisted on the record (and in the TSV) so
    /// skip forensics do not depend on captured stderr.
    pub fn push_skipped(
        &mut self,
        step: u64,
        lr: f64,
        loss: f64,
        tokens: u64,
        loss_scale: f64,
        note: &str,
    ) -> &StepRecord {
        self.skipped += 1;
        let r =
            self.push_record(step, lr, loss, f64::NAN, f64::NAN, tokens, loss_scale, true);
        r.note = note.to_string();
        &*r
    }

    /// Updates skipped so far (overflow under loss scaling).
    pub fn skipped_steps(&self) -> u64 {
        self.skipped
    }

    #[allow(clippy::too_many_arguments)]
    fn push_record(
        &mut self,
        step: u64,
        lr: f64,
        loss: f64,
        grad_norm: f64,
        trust_ratio: f64,
        tokens: u64,
        loss_scale: f64,
        skipped: bool,
    ) -> &mut StepRecord {
        self.tokens_seen += tokens;
        if self.initial_loss.is_none() {
            self.initial_loss = Some(loss);
            // default ceiling: 3x the initial loss (a diverged MLM run blows
            // far past this; a healthy one never revisits it).  Only a
            // positive, finite first loss defines a meaningful ceiling —
            // for a zero/negative one, loss×3 sits at or below the loss
            // itself and would flag a healthy run as diverged, so the
            // ceiling stays at the explicit-opt-in infinity.
            if self.divergence_ceiling.is_infinite() && loss.is_finite() && loss > 0.0 {
                self.divergence_ceiling = loss * 3.0;
            }
        }
        let ema = self.ema.push(loss);
        self.records.push(StepRecord {
            step,
            lr,
            loss,
            loss_ema: ema,
            grad_norm,
            trust_ratio,
            tokens: self.tokens_seen,
            wall_s: self.start.elapsed().as_secs_f64(),
            loss_scale,
            skipped,
            comm_s: 0.0,
            compute_s: 0.0,
            overlap_eff: 0.0,
            note: String::new(),
        });
        self.records.last_mut().unwrap()
    }

    /// Attach the traced per-step timing aggregates to the most recent
    /// record (the trainer collects the step's trace right after pushing
    /// it).  No-op before the first push.
    pub fn set_step_timing(&mut self, comm_s: f64, compute_s: f64, overlap_eff: f64) {
        if let Some(r) = self.records.last_mut() {
            r.comm_s = comm_s;
            r.compute_s = compute_s;
            r.overlap_eff = overlap_eff;
        }
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    pub fn ema_loss(&self) -> Option<f64> {
        self.ema.value()
    }

    /// True once the smoothed loss is non-finite or past the ceiling.
    pub fn diverged(&self) -> bool {
        match self.ema.value() {
            Some(v) => !v.is_finite() || v > self.divergence_ceiling,
            None => false,
        }
    }

    pub fn tokens_per_second(&self) -> f64 {
        let el = self.start.elapsed().as_secs_f64();
        if el > 0.0 {
            self.tokens_seen as f64 / el
        } else {
            0.0
        }
    }

    /// Write the curve as TSV (step, lr, loss, ema, grad_norm, trust, tokens,
    /// wall seconds, loss scale, skipped flag, traced comm/compute seconds,
    /// overlap efficiency, skip note) — consumed by EXPERIMENTS.md plots.
    pub fn write_tsv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).with_context(|| {
                format!("creating parent directory {} for the curve TSV", dir.display())
            })?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(
            f,
            "step\tlr\tloss\tloss_ema\tgrad_norm\ttrust_ratio\ttokens\twall_s\
             \tloss_scale\tskipped\tcomm_s\tcompute_s\toverlap_eff\tnote"
        )?;
        for r in &self.records {
            // the note is free text: keep the row parseable
            let note = r.note.replace(['\t', '\n'], " ");
            writeln!(
                f,
                "{}\t{:.6e}\t{:.6}\t{:.6}\t{:.6}\t{:.6}\t{}\t{:.3}\t{}\t{}\t{:.6}\t{:.6}\t{:.4}\t{}",
                r.step,
                r.lr,
                r.loss,
                r.loss_ema,
                r.grad_norm,
                r.trust_ratio,
                r.tokens,
                r.wall_s,
                r.loss_scale,
                r.skipped as u8,
                r.comm_s,
                r.compute_s,
                r.overlap_eff,
                note
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_smooths() {
        let mut r = Recorder::new(0.5);
        r.push(1, 0.01, 10.0, 1.0, 1.0, 100);
        r.push(2, 0.01, 8.0, 1.0, 1.0, 100);
        assert_eq!(r.records.len(), 2);
        assert!((r.ema_loss().unwrap() - 9.0).abs() < 1e-9);
        assert_eq!(r.records[1].tokens, 200);
        assert!(!r.diverged());
    }

    #[test]
    fn detects_divergence() {
        let mut r = Recorder::new(0.9);
        r.push(1, 0.01, 5.0, 1.0, 1.0, 1);
        for s in 2..10 {
            r.push(s, 0.01, 100.0, 1.0, 1.0, 1);
        }
        assert!(r.diverged());
        let mut r2 = Recorder::new(0.9);
        r2.push(1, 0.01, 5.0, 1.0, 1.0, 1);
        r2.push(2, 0.01, f64::NAN, 1.0, 1.0, 1);
        assert!(r2.diverged());
    }

    #[test]
    fn tsv_roundtrip() {
        let mut r = Recorder::new(0.5);
        r.push(1, 0.01, 3.0, 0.5, 1.0, 64);
        let p = std::env::temp_dir().join("lans_test_metrics.tsv");
        r.write_tsv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.starts_with("step\t"));
        let header = body.lines().next().unwrap();
        assert!(
            header.ends_with("skipped\tcomm_s\tcompute_s\toverlap_eff\tnote"),
            "header: {header}"
        );
        assert_eq!(body.lines().count(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn step_timing_lands_in_the_tsv() {
        let mut r = Recorder::new(0.5);
        r.push(1, 0.01, 3.0, 0.5, 1.0, 64);
        r.set_step_timing(0.25, 0.5, 0.75);
        assert_eq!(r.records[0].comm_s, 0.25);
        assert_eq!(r.records[0].compute_s, 0.5);
        assert_eq!(r.records[0].overlap_eff, 0.75);
        let p = std::env::temp_dir().join("lans_test_metrics_timing.tsv");
        r.write_tsv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        let row = body.lines().nth(1).unwrap();
        let cells: Vec<&str> = row.split('\t').collect();
        assert_eq!(cells.len(), 14, "row: {row}");
        assert_eq!(cells[10], "0.250000");
        assert_eq!(cells[11], "0.500000");
        assert_eq!(cells[12], "0.7500");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn tsv_parent_dir_failure_is_a_contextual_error() {
        // a *file* where the parent directory should go: create_dir_all
        // fails, and the error must surface (it used to be swallowed by
        // `.ok()` and resurface as a confusing File::create failure)
        let blocker = std::env::temp_dir().join("lans_test_metrics_blocker");
        std::fs::write(&blocker, b"not a directory").unwrap();
        let mut r = Recorder::new(0.5);
        r.push(1, 0.01, 3.0, 0.5, 1.0, 64);
        let err = r.write_tsv(&blocker.join("sub").join("curve.tsv")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("creating parent directory"), "unhelpful error: {msg}");
        std::fs::remove_file(&blocker).ok();
    }

    #[test]
    fn skip_notes_land_in_the_tsv() {
        let mut r = Recorder::new(0.5);
        r.push_scaled(1, 0.01, 5.0, 1.0, 1.0, 64, 65536.0);
        r.push_skipped(2, 0.01, 5.1, 64, 65536.0, "overflow\tat scale 65536");
        assert_eq!(r.records[1].note, "overflow\tat scale 65536");
        assert!(r.records[0].note.is_empty());
        let p = std::env::temp_dir().join("lans_test_metrics_note.tsv");
        r.write_tsv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        let skipped_row = body.lines().nth(2).unwrap();
        // tabs inside the note are flattened so the column count is stable
        assert_eq!(skipped_row.split('\t').count(), 14, "row: {skipped_row}");
        assert!(skipped_row.ends_with("overflow at scale 65536"), "row: {skipped_row}");
        let applied_row = body.lines().nth(1).unwrap();
        assert_eq!(applied_row.split('\t').count(), 14, "row: {applied_row}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn skipped_steps_are_counted_and_flagged() {
        let mut r = Recorder::new(0.5);
        r.push_scaled(1, 0.01, 5.0, 1.0, 1.0, 64, 65536.0);
        r.push_skipped(2, 0.01, 5.1, 64, 65536.0, "overflow");
        r.push_scaled(3, 0.01, 4.9, 1.0, 1.0, 64, 32768.0);
        assert_eq!(r.skipped_steps(), 1);
        assert!(!r.records[0].skipped);
        assert!(r.records[1].skipped);
        assert!(r.records[1].grad_norm.is_nan());
        assert_eq!(r.records[1].loss_scale, 65536.0);
        assert_eq!(r.records[2].loss_scale, 32768.0);
        // skipped batches still consume data
        assert_eq!(r.records[2].tokens, 192);
        // plain push records unit scale
        r.push(4, 0.01, 4.8, 1.0, 1.0, 64);
        assert_eq!(r.records[3].loss_scale, 1.0);
        assert!(!r.diverged());
    }

    #[test]
    fn non_positive_initial_loss_never_auto_diverges() {
        // regression: initial_loss * 3.0 put the ceiling at or below a
        // loss ≤ 0, flagging a healthy (e.g. reward-style) run as
        // diverged on its own first value
        let mut neg = Recorder::new(0.9);
        neg.push(1, 0.01, -2.0, 1.0, 1.0, 1);
        assert!(neg.divergence_ceiling.is_infinite(), "ceiling must stay opt-in");
        assert!(!neg.diverged());
        neg.push(2, 0.01, -1.5, 1.0, 1.0, 1);
        assert!(!neg.diverged(), "improving negative-loss run flagged as diverged");

        let mut zero = Recorder::new(0.9);
        zero.push(1, 0.01, 0.0, 1.0, 1.0, 1);
        assert!(zero.divergence_ceiling.is_infinite());
        assert!(!zero.diverged());

        // a NaN first loss must not poison the ceiling either — NaN
        // comparisons would make `diverged` silently always-false
        let mut nan = Recorder::new(0.9);
        nan.push(1, 0.01, f64::NAN, 1.0, 1.0, 1);
        assert!(nan.divergence_ceiling.is_infinite());
        assert!(nan.diverged(), "non-finite EMA is still divergence");

        // positive first loss keeps the historical 3x auto-ceiling
        let mut pos = Recorder::new(0.9);
        pos.push(1, 0.01, 5.0, 1.0, 1.0, 1);
        assert_eq!(pos.divergence_ceiling, 15.0);

        // an explicit ceiling set before the first push is never clobbered
        let mut explicit = Recorder::new(0.9);
        explicit.divergence_ceiling = 100.0;
        explicit.push(1, 0.01, 5.0, 1.0, 1.0, 1);
        assert_eq!(explicit.divergence_ceiling, 100.0);
    }

    #[test]
    fn wall_and_tokens_are_monotone_across_mixed_pushes() {
        let mut r = Recorder::new(0.5);
        r.push(1, 0.01, 5.0, 1.0, 1.0, 64);
        r.push_skipped(2, 0.01, 5.1, 64, 65536.0, "overflow");
        r.push_scaled(3, 0.01, 4.9, 1.0, 1.0, 64, 32768.0);
        r.push_skipped(4, 0.01, 4.8, 64, 32768.0, "overflow");
        r.push(5, 0.01, 4.7, 1.0, 1.0, 64);
        assert_eq!(r.records.len(), 5);
        for w in r.records.windows(2) {
            assert!(
                w[1].wall_s >= w[0].wall_s,
                "wall clock went backwards: {} -> {}",
                w[0].wall_s,
                w[1].wall_s
            );
            assert!(
                w[1].tokens >= w[0].tokens,
                "token counter went backwards: {} -> {}",
                w[0].tokens,
                w[1].tokens
            );
        }
        // skipped batches still consume data: strictly increasing here
        let toks: Vec<u64> = r.records.iter().map(|r| r.tokens).collect();
        assert_eq!(toks, vec![64, 128, 192, 256, 320]);
    }
}
