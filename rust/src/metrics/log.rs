//! Leveled, rate-limited diagnostic sink for the trainer.
//!
//! Replaces the trainer's raw `eprintln!` calls: messages carry a level and
//! a label, the level knob (`[metrics] log_level` → quiet/normal/verbose)
//! decides what reaches stderr, each label is rate-limited so a pathological
//! run (hundreds of skipped steps) cannot flood the terminal, and tests can
//! capture the stream instead of scraping stderr.  This is operator I/O, not
//! hot-path instrumentation — a mutex on the emit path is fine; the trainer
//! logs a handful of lines per run.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    /// nothing reaches stderr (records still land in the Recorder/TSV)
    Quiet = 0,
    /// skip/divergence diagnostics and eval lines (the default)
    Normal = 1,
    /// everything, including per-step chatter from future callers
    Verbose = 2,
}

impl LogLevel {
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s {
            "quiet" => Some(LogLevel::Quiet),
            "normal" => Some(LogLevel::Normal),
            "verbose" => Some(LogLevel::Verbose),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            LogLevel::Quiet => "quiet",
            LogLevel::Normal => "normal",
            LogLevel::Verbose => "verbose",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Normal as u8);

pub fn set_level(l: LogLevel) {
    LEVEL.store(l as u8, Ordering::SeqCst);
}

pub fn level() -> LogLevel {
    match LEVEL.load(Ordering::SeqCst) {
        0 => LogLevel::Quiet,
        2 => LogLevel::Verbose,
        _ => LogLevel::Normal,
    }
}

/// Max lines per label per run before suppression kicks in.
const LABEL_LIMIT: u64 = 50;

struct SinkState {
    /// (label, emitted-count) — labels are a small fixed set, linear scan
    counts: Vec<(&'static str, u64)>,
    /// when Some, lines are captured here instead of reaching stderr
    capture: Option<Vec<String>>,
}

static SINK: Mutex<SinkState> = Mutex::new(SinkState { counts: Vec::new(), capture: None });

/// Reset rate-limit counters (call at run start so limits are per-run).
pub fn reset_rate_limits() {
    let mut s = SINK.lock().unwrap_or_else(|e| e.into_inner());
    s.counts.clear();
}

/// End-of-run accounting for the rate limiter: one summary line per label
/// that overran [`LABEL_LIMIT`], saying how many lines were dropped after
/// the suppression notice.  The trainer calls this at sink drain/seal so a
/// pathological run (hundreds of skipped steps) leaves an audit trail
/// instead of vanishing silently.  Summary lines bypass the per-label
/// limit (they ARE the accounting) but still respect the level knob.
pub fn drain_suppression_summary() {
    if level() < LogLevel::Normal {
        return;
    }
    let mut s = SINK.lock().unwrap_or_else(|e| e.into_inner());
    let overruns: Vec<(&'static str, u64)> = s
        .counts
        .iter()
        .filter(|&&(_, c)| c > LABEL_LIMIT + 1)
        .map(|&(l, c)| (l, c - (LABEL_LIMIT + 1)))
        .collect();
    for (label, dropped) in overruns {
        let line = format!(
            "[log] label '{label}': suppressed {dropped} line{} this run \
             (limit {LABEL_LIMIT}/run)",
            if dropped == 1 { "" } else { "s" }
        );
        match &mut s.capture {
            Some(buf) => buf.push(line),
            None => eprintln!("{line}"),
        }
    }
}

/// Begin capturing emitted lines (tests); ends with [`capture_end`].
pub fn capture_begin() {
    let mut s = SINK.lock().unwrap_or_else(|e| e.into_inner());
    s.capture = Some(Vec::new());
}

/// Stop capturing and return everything emitted since [`capture_begin`].
pub fn capture_end() -> Vec<String> {
    let mut s = SINK.lock().unwrap_or_else(|e| e.into_inner());
    s.capture.take().unwrap_or_default()
}

fn emit(min: LogLevel, label: &'static str, msg: &str) {
    if level() < min {
        return;
    }
    let mut s = SINK.lock().unwrap_or_else(|e| e.into_inner());
    let count = match s.counts.iter_mut().find(|(l, _)| *l == label) {
        Some((_, c)) => {
            *c += 1;
            *c
        }
        None => {
            s.counts.push((label, 1));
            1
        }
    };
    let line = match count.cmp(&(LABEL_LIMIT + 1)) {
        std::cmp::Ordering::Less => format!("[{label}] {msg}"),
        std::cmp::Ordering::Equal => format!(
            "[{label}] {msg}\n[{label}] further '{label}' messages suppressed \
             (limit {LABEL_LIMIT}/run; the full history is in the curve TSV/JSONL)"
        ),
        std::cmp::Ordering::Greater => return,
    };
    match &mut s.capture {
        Some(buf) => buf.push(line),
        None => eprintln!("{line}"),
    }
}

/// Normal-level diagnostic (skip notes, eval lines).
pub fn info(label: &'static str, msg: &str) {
    emit(LogLevel::Normal, label, msg);
}

/// Verbose-only chatter.
pub fn verbose(label: &'static str, msg: &str) {
    emit(LogLevel::Verbose, label, msg);
}

/// Warnings follow the same knob as info: quiet mode silences everything
/// (the data still lands in the recorder), so an operator who opted out of
/// terminal output is never second-guessed.
pub fn warn(label: &'static str, msg: &str) {
    emit(LogLevel::Normal, label, msg);
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_gate_emission() {
        let _g = test_lock();
        reset_rate_limits();
        set_level(LogLevel::Quiet);
        capture_begin();
        info("skip", "dropped");
        warn("skip", "dropped");
        verbose("chat", "dropped");
        assert!(capture_end().is_empty());

        reset_rate_limits();
        set_level(LogLevel::Normal);
        capture_begin();
        info("skip", "kept");
        verbose("chat", "dropped");
        let lines = capture_end();
        assert_eq!(lines, vec!["[skip] kept".to_string()]);

        reset_rate_limits();
        set_level(LogLevel::Verbose);
        capture_begin();
        verbose("chat", "kept");
        let lines = capture_end();
        assert_eq!(lines, vec!["[chat] kept".to_string()]);
        set_level(LogLevel::Normal);
    }

    #[test]
    fn rate_limit_is_per_label_and_announced() {
        let _g = test_lock();
        set_level(LogLevel::Normal);
        reset_rate_limits();
        capture_begin();
        for i in 0..(LABEL_LIMIT + 10) {
            info("skip", &format!("overflow {i}"));
        }
        info("eval", "other label unaffected");
        let lines = capture_end();
        // LIMIT plain lines + 1 suppression notice + the other label
        assert_eq!(lines.len() as u64, LABEL_LIMIT + 2);
        assert!(lines[LABEL_LIMIT as usize].contains("suppressed"));
        assert_eq!(lines.last().unwrap(), "[eval] other label unaffected");
        // a new run re-arms the limit
        reset_rate_limits();
        capture_begin();
        info("skip", "fresh run");
        assert_eq!(capture_end(), vec!["[skip] fresh run".to_string()]);
    }

    #[test]
    fn drain_summary_accounts_for_dropped_lines() {
        let _g = test_lock();
        set_level(LogLevel::Normal);
        reset_rate_limits();
        capture_begin();
        // 'skip' overruns by 9 dropped lines; 'eval' stays under the limit
        for i in 0..(LABEL_LIMIT + 10) {
            info("skip", &format!("overflow {i}"));
        }
        info("eval", "fine");
        drain_suppression_summary();
        let lines = capture_end();
        let summary: Vec<&String> =
            lines.iter().filter(|l| l.starts_with("[log]")).collect();
        assert_eq!(summary.len(), 1, "exactly one overrunning label: {lines:?}");
        assert_eq!(
            summary[0],
            &format!("[log] label 'skip': suppressed 9 lines this run (limit {LABEL_LIMIT}/run)")
        );

        // a clean run emits no summary at all
        reset_rate_limits();
        capture_begin();
        info("skip", "one line");
        drain_suppression_summary();
        assert_eq!(capture_end(), vec!["[skip] one line".to_string()]);

        // quiet mode silences the accounting like everything else
        reset_rate_limits();
        capture_begin();
        for i in 0..(LABEL_LIMIT + 5) {
            info("skip", &format!("overflow {i}"));
        }
        set_level(LogLevel::Quiet);
        drain_suppression_summary();
        set_level(LogLevel::Normal);
        assert!(!capture_end().iter().any(|l| l.starts_with("[log]")));
    }

    #[test]
    fn log_level_parses() {
        assert_eq!(LogLevel::parse("quiet"), Some(LogLevel::Quiet));
        assert_eq!(LogLevel::parse("normal"), Some(LogLevel::Normal));
        assert_eq!(LogLevel::parse("verbose"), Some(LogLevel::Verbose));
        assert_eq!(LogLevel::parse("loud"), None);
        assert_eq!(LogLevel::Verbose.as_str(), "verbose");
        assert!(LogLevel::Quiet < LogLevel::Normal && LogLevel::Normal < LogLevel::Verbose);
    }
}
