//! Process-wide metrics registry: counters, gauges, log2-bucketed histograms.
//!
//! Same hard overhead contract as [`crate::trace`]: the registry ships in
//! every binary and is **off by default**.  Disabled, each instrumentation
//! seam costs exactly one relaxed atomic load and a predictable branch — no
//! clock reads, no allocation, no locks — and training output is
//! bit-identical whether the seam exists or not (the registry only ever
//! *observes* values the hot path already computed).  Enabled, updates are
//! lock-free atomics: counters `fetch_add`, gauges store f64 bits, histogram
//! observations bump one of 64 power-of-two buckets chosen straight from the
//! value's exponent bits (no float `log2` on the hot path).
//!
//! Metrics are **statically declared** (`static` items below, enumerated in
//! one registry list) rather than looked up in a dynamic map: a map would
//! need a lock or hash on every update, which the contract forbids.  Adding
//! a metric means adding a static and one line to the registry list —
//! `snapshot()` and `reset()` then cover it automatically.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// One relaxed load: the only cost an instrumentation seam pays when the
/// registry is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the registry on (idempotent).  Callers normally also [`reset`] at
/// run start so one process can host several isolated runs.
pub fn enable() {
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Zero every registered metric.  Not atomic as a whole — call it between
/// runs, not while workers are mid-step.
pub fn reset() {
    for c in COUNTERS {
        c.v.store(0, Ordering::SeqCst);
    }
    for g in GAUGES {
        g.bits.store(0.0f64.to_bits(), Ordering::SeqCst);
        g.set_flag.store(false, Ordering::SeqCst);
    }
    for h in HISTOGRAMS {
        h.count.store(0, Ordering::SeqCst);
        h.sum_bits.store(0.0f64.to_bits(), Ordering::SeqCst);
        for b in &h.buckets {
            b.store(0, Ordering::SeqCst);
        }
    }
}

/// Monotone event/byte counter.
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Counter {
        Counter { name, v: AtomicU64::new(0) }
    }

    /// Hot-path add: one relaxed load when disabled.
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::SeqCst)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Last-write-wins f64 gauge (stored as bits in an atomic).
pub struct Gauge {
    name: &'static str,
    bits: AtomicU64,
    /// distinguishes "never set" from "set to 0.0" in snapshots
    set_flag: AtomicBool,
}

impl Gauge {
    pub const fn new(name: &'static str) -> Gauge {
        Gauge {
            name,
            bits: AtomicU64::new(0),
            set_flag: AtomicBool::new(false),
        }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if enabled() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
            self.set_flag.store(true, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> Option<f64> {
        if self.set_flag.load(Ordering::SeqCst) {
            Some(f64::from_bits(self.bits.load(Ordering::SeqCst)))
        } else {
            None
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Number of histogram buckets.  Bucket 0 catches non-positive and NaN
/// observations; buckets 1..=63 cover powers of two from 2^-32 up — wide
/// enough for microseconds-as-integers, byte counts, trust ratios, and
/// gradient norms alike.
pub const HIST_BUCKETS: usize = 64;

/// Exponent offset: bucket `i` (for `i >= 1`) holds values in
/// `[2^(i - EXP_OFFSET), 2^(i + 1 - EXP_OFFSET))`.
const EXP_OFFSET: i32 = 33;

/// Log2-bucketed histogram: count, sum, and 64 power-of-two buckets.
pub struct Histogram {
    name: &'static str,
    count: AtomicU64,
    /// running sum of the *finite* observations, f64 bits, CAS-updated
    sum_bits: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Bucket index from the IEEE-754 exponent field — no float `log2` on the
/// hot path.  Non-positive and NaN land in bucket 0; +inf clamps to the top
/// bucket; subnormals clamp to bucket 1.
#[inline]
fn bucket_index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let biased = ((v.to_bits() >> 52) & 0x7ff) as i32;
    if biased == 0 {
        return 1; // subnormal: below every bucket boundary
    }
    let e = biased - 1023; // floor(log2(v)), or 1024 for +inf
    (e + EXP_OFFSET).clamp(1, HIST_BUCKETS as i32 - 1) as usize
}

/// Lower edge of bucket `i` (0.0 for the catch-all bucket 0).
fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        (2.0f64).powi(i as i32 - EXP_OFFSET)
    }
}

fn f64_fetch_add(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + x).to_bits();
        match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

impl Histogram {
    pub const fn new(name: &'static str) -> Histogram {
        // `const` item so the array-repeat initializer is allowed to copy it
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0x0), // 0.0f64.to_bits()
            buckets: [ZERO; HIST_BUCKETS],
        }
    }

    /// Hot-path observation: one relaxed load when disabled; two relaxed
    /// `fetch_add`s plus a CAS loop on the sum when enabled.
    #[inline]
    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        if v.is_finite() {
            f64_fetch_add(&self.sum_bits, v);
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            name: self.name,
            count: self.count.load(Ordering::SeqCst),
            sum: f64::from_bits(self.sum_bits.load(Ordering::SeqCst)),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::SeqCst)).collect(),
        }
    }
}

/// Owned copy of a histogram's state, safe to merge/summarize offline.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub name: &'static str,
    pub count: u64,
    pub sum: f64,
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn empty(name: &'static str) -> HistogramSnapshot {
        HistogramSnapshot { name, count: 0, sum: 0.0, buckets: vec![0; HIST_BUCKETS] }
    }

    /// Merge another snapshot in (counts and sums add bucket-wise).
    /// Associative and commutative — shard-local histograms can be combined
    /// in any grouping and agree with a single global histogram.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
    }

    /// Approximate percentile (`p` in [0, 100]): walks the buckets to the
    /// one holding the rank and returns its geometric midpoint.  Resolution
    /// is the bucket width (a factor of 2); exact percentiles over raw
    /// series live in `util::stats::percentile`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0 * (self.count as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen > rank {
                if i == 0 {
                    return 0.0;
                }
                let lo = bucket_lo(i);
                return lo * std::f64::consts::SQRT_2; // sqrt(lo * 2lo)
            }
        }
        bucket_lo(self.buckets.len() - 1) * std::f64::consts::SQRT_2
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

// ---------------------------------------------------------------------------
// The registry: every metric the seams feed, declared once, listed once.
// ---------------------------------------------------------------------------

/// Per-block LANS/LAMB trust ratio, observed where the coefficient is
/// computed (`optim::native::lans_coef`/`lamb_coef` — the single home every
/// serial/parallel/sharded path funnels through).
pub static TRUST_RATIO: Histogram = Histogram::new("optim.trust_ratio");
/// Per-block gradient L2 norm, same seam as [`TRUST_RATIO`].
pub static BLOCK_GRAD_NORM: Histogram = Histogram::new("optim.block_grad_norm");
/// DAG stage queue-wait (ready → launched), microseconds.
pub static QUEUE_WAIT_US: Histogram = Histogram::new("dag.queue_wait_us");

/// Intra-node (NVLink-tier) wire bytes from the hierarchical collectives.
pub static WIRE_INTRA_BYTES: Counter = Counter::new("wire.intra_bytes");
/// Inter-node (network-tier) wire bytes from the hierarchical collectives.
pub static WIRE_INTER_BYTES: Counter = Counter::new("wire.inter_bytes");
/// Top-level collective invocations (compositions count once per tiered
/// primitive they execute, never double).
pub static COLLECTIVE_CALLS: Counter = Counter::new("collective.calls");
/// Pool regions opened (dispatch→close cycles).
pub static POOL_REGIONS: Counter = Counter::new("pool.regions");
/// Microseconds pool workers spent busy (per-worker busy spans summed).
pub static POOL_BUSY_US: Counter = Counter::new("pool.busy_us");
/// Microseconds of open pool-region wall time (dispatch→close).  Utilization
/// = busy / (region * workers).
pub static POOL_REGION_US: Counter = Counter::new("pool.region_us");
/// Loss-scale backoffs (overflow → scale halved).
pub static SCALER_BACKOFFS: Counter = Counter::new("scaler.backoffs");
/// Loss-scale growths (clean interval → scale doubled).
pub static SCALER_GROWTHS: Counter = Counter::new("scaler.growths");

/// Current loss scale.
pub static SCALER_SCALE: Gauge = Gauge::new("scaler.scale");

static COUNTERS: &[&Counter] = &[
    &WIRE_INTRA_BYTES,
    &WIRE_INTER_BYTES,
    &COLLECTIVE_CALLS,
    &POOL_REGIONS,
    &POOL_BUSY_US,
    &POOL_REGION_US,
    &SCALER_BACKOFFS,
    &SCALER_GROWTHS,
];

static GAUGES: &[&Gauge] = &[&SCALER_SCALE];

static HISTOGRAMS: &[&Histogram] = &[&TRUST_RATIO, &BLOCK_GRAD_NORM, &QUEUE_WAIT_US];

/// Owned copy of the whole registry at one moment.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub counters: Vec<(&'static str, u64)>,
    /// gauges that were actually set during the run
    pub gauges: Vec<(&'static str, f64)>,
    pub histograms: Vec<HistogramSnapshot>,
}

pub fn snapshot() -> Snapshot {
    Snapshot {
        counters: COUNTERS.iter().map(|c| (c.name, c.get())).collect(),
        gauges: GAUGES.iter().filter_map(|g| g.get().map(|v| (g.name, v))).collect(),
        histograms: HISTOGRAMS.iter().map(|h| h.snapshot()).collect(),
    }
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| *n == name).map_or(0, |(_, v)| *v)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|h| h.name == name)
    }
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_observes_nothing() {
        let _g = test_lock();
        disable();
        reset();
        TRUST_RATIO.observe(1.0);
        WIRE_INTRA_BYTES.add(100);
        SCALER_SCALE.set(2.0);
        let s = snapshot();
        assert_eq!(s.counter("wire.intra_bytes"), 0);
        assert!(s.gauges.is_empty());
        assert_eq!(s.histogram("optim.trust_ratio").unwrap().count, 0);
    }

    #[test]
    fn enabled_registry_counts_and_buckets() {
        let _g = test_lock();
        reset();
        enable();
        WIRE_INTRA_BYTES.add(100);
        WIRE_INTRA_BYTES.add(28);
        SCALER_SCALE.set(65536.0);
        for v in [0.5, 0.5, 1.0, 2.0] {
            TRUST_RATIO.observe(v);
        }
        let s = snapshot();
        disable();
        assert_eq!(s.counter("wire.intra_bytes"), 128);
        assert_eq!(s.gauges, vec![("scaler.scale", 65536.0)]);
        let h = s.histogram("optim.trust_ratio").unwrap();
        assert_eq!(h.count, 4);
        assert!((h.sum - 4.0).abs() < 1e-12);
        // 0.5 and 1.0 and 2.0 land in distinct adjacent buckets
        let nonzero: Vec<usize> =
            (0..h.buckets.len()).filter(|&i| h.buckets[i] > 0).collect();
        assert_eq!(nonzero.len(), 3);
        assert_eq!(nonzero[1], nonzero[0] + 1);
        assert_eq!(nonzero[2], nonzero[1] + 1);
        assert_eq!(h.buckets[nonzero[0]], 2);
        reset();
    }

    #[test]
    fn bucket_index_covers_edge_values() {
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(f64::NEG_INFINITY), 0);
        assert_eq!(bucket_index(f64::INFINITY), HIST_BUCKETS - 1);
        assert_eq!(bucket_index(f64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_index(f64::MIN_POSITIVE / 2.0), 1, "subnormal clamps low");
        assert_eq!(bucket_index(1e-300), 1);
        // exact powers of two sit at bucket lower edges
        assert_eq!(bucket_index(1.0), (EXP_OFFSET) as usize);
        assert_eq!(bucket_index(2.0), (EXP_OFFSET + 1) as usize);
        assert_eq!(bucket_index(1.999_999), (EXP_OFFSET) as usize);
        // and bucket_lo inverts the mapping on the covered range
        for i in 2..HIST_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_lo(i)), i);
            assert_eq!(bucket_index(bucket_lo(i) * 1.5), i);
        }
    }

    #[test]
    fn histogram_percentiles_are_bucket_resolution() {
        let _g = test_lock();
        reset();
        enable();
        for _ in 0..90 {
            QUEUE_WAIT_US.observe(100.0);
        }
        for _ in 0..10 {
            QUEUE_WAIT_US.observe(10_000.0);
        }
        let h = QUEUE_WAIT_US.snapshot();
        disable();
        reset();
        // p50 within a factor of 2 of 100, p99 within a factor of 2 of 10k
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 >= 50.0 && p50 <= 200.0, "p50 = {p50}");
        assert!(p99 >= 5_000.0 && p99 <= 20_000.0, "p99 = {p99}");
        assert!(h.percentile(0.0) <= p50);
        // empty histogram: percentile defined as 0
        assert_eq!(HistogramSnapshot::empty("x").percentile(50.0), 0.0);
    }

    #[test]
    fn merge_is_associative_and_matches_global() {
        let mk = |vals: &[f64]| {
            let mut s = HistogramSnapshot::empty("m");
            for &v in vals {
                s.count += 1;
                s.buckets[bucket_index(v)] += 1;
                if v.is_finite() {
                    s.sum += v;
                }
            }
            s
        };
        let (a, b, c) = (mk(&[0.1, 1.0]), mk(&[2.0, 4.0, 8.0]), mk(&[1e6]));
        // (a + b) + c == a + (b + c)
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
        // and both equal the single global histogram over all values
        let global = mk(&[0.1, 1.0, 2.0, 4.0, 8.0, 1e6]);
        assert_eq!(ab_c, global);
        assert_eq!(ab_c.count, 6);
    }

    #[test]
    fn reset_isolates_runs() {
        let _g = test_lock();
        reset();
        enable();
        POOL_REGIONS.add(5);
        SCALER_SCALE.set(1.0);
        TRUST_RATIO.observe(1.0);
        reset();
        let s = snapshot();
        disable();
        assert_eq!(s.counter("pool.regions"), 0);
        assert!(s.gauges.is_empty(), "reset must clear the gauge set-flag");
        assert_eq!(s.histogram("optim.trust_ratio").unwrap().count, 0);
    }
}
