//! Run-level telemetry: loss-curve recording, a counters/gauges/histograms
//! registry, anomaly detection, leveled logging, and end-of-run reports.
//!
//! Layout (DESIGN.md §12):
//!
//! - [`recorder`] — the per-step loss-curve [`Recorder`] (EMA smoothing,
//!   divergence ceiling, TSV export).  Always on; it is the trainer's own
//!   bookkeeping, not an instrumentation seam.
//! - [`registry`] — process-wide counters, gauges, and log2-bucketed
//!   histograms fed from hot-path seams (optimizer coefficients, collective
//!   wire bytes, pool/DAG lanes, loss-scaler events).  Same hard overhead
//!   contract as [`crate::trace`]: disabled (the default) costs one relaxed
//!   atomic load per seam, same binary, bit-identical runs either way.
//! - [`health`] — rolling robust statistics (median/MAD z-scores) over the
//!   step time series; flags stragglers, step-time regressions, loss-scale
//!   thrash, loss plateaus, and divergence early-warning as [`health::Verdict`]s.
//! - [`export`] — per-step JSONL time-series and the end-of-run
//!   [`export::RunReport`] (JSON + human-readable summary), validated in CI
//!   by `tools/check_metrics.py`.
//! - [`log`] — a leveled, rate-limited stderr sink for trainer diagnostics
//!   (quiet/normal/verbose), capturable in tests.

pub mod export;
pub mod health;
pub mod log;
pub mod recorder;
pub mod registry;

pub use recorder::{Recorder, StepRecord};
