//! Run-health anomaly detection over the per-step time series.
//!
//! The [`HealthMonitor`] consumes one observation per training step (wall /
//! comm / compute seconds, smoothed loss, loss-scale events) and flags:
//!
//! - **stragglers** — a single step whose wall/comm/compute time is a
//!   robust-z outlier against the trailing window (median/MAD, DESIGN.md §12);
//! - **step-time regressions** — a sustained shift: a later window's median
//!   step time exceeding a ratio of the first full window's median;
//! - **loss-scale thrash** — more than `k` backoffs inside one trailing
//!   window (the scaler is oscillating instead of settling);
//! - **loss plateaus** — the smoothed loss has not improved for a long
//!   stretch (informational, not a failure);
//! - **divergence early-warning** — the smoothed loss climbed past a
//!   fraction of the recorder's divergence ceiling *before* the run is
//!   formally diverged.
//!
//! Thresholds are deliberately conservative: the acceptance bar is zero
//! false positives on clean runs (proptested across trainer configs), so
//! every detector demands both a large robust z **and** a material absolute
//! ratio before it speaks.  The verdict list feeds the end-of-run report
//! and is the seed of the ROADMAP item 4 regression gate.

use std::collections::VecDeque;

use crate::util::stats::{robust_z, RollingWindow};

/// Detector thresholds.  Defaults are tuned to stay silent on healthy runs.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// trailing window length (steps) for robust statistics
    pub window: usize,
    /// robust z-score a single step must exceed to be a straggler
    pub straggler_z: f64,
    /// ...and the minimum ratio vs the trailing median (guards against
    /// flagging microsecond jitter on near-constant series)
    pub straggler_ratio: f64,
    /// a window median above `regression_ratio`× the baseline window's
    /// median is a step-time regression
    pub regression_ratio: f64,
    /// more than this many backoffs inside one window is thrash
    pub thrash_backoffs: u64,
    /// steps without smoothed-loss improvement before a plateau verdict
    pub plateau_window: usize,
    /// smoothed loss above this fraction of the divergence ceiling warns
    pub divergence_warn_frac: f64,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            window: 32,
            straggler_z: 8.0,
            straggler_ratio: 1.5,
            regression_ratio: 2.0,
            thrash_backoffs: 3,
            plateau_window: 200,
            divergence_warn_frac: 0.5,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// worth a look, not a failure (plateau)
    Info,
    /// the run is unhealthy (straggler, regression, thrash, divergence risk)
    Warn,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
        }
    }
}

/// One health finding, self-describing enough for the JSON report.
#[derive(Debug, Clone)]
pub struct Verdict {
    /// `straggler` | `step_time_regression` | `loss_scale_thrash` |
    /// `loss_plateau` | `divergence_warning`
    pub kind: &'static str,
    pub severity: Severity,
    /// training step at which the detector fired
    pub step: u64,
    /// the measured value that tripped the detector
    pub value: f64,
    /// the threshold it tripped against
    pub threshold: f64,
    pub message: String,
    /// where the blame points: lane + offending stage label when flight
    /// data was available (`obs::postmortem::slowest_stage`), the step
    /// index otherwise.  Always non-empty.
    pub detail: String,
}

/// Rolling anomaly detector; feed it once per recorded step.
pub struct HealthMonitor {
    cfg: HealthConfig,
    wall: RollingWindow,
    comm: RollingWindow,
    compute: RollingWindow,
    /// steps at which a loss-scale backoff happened (pruned to the window)
    backoff_steps: VecDeque<u64>,
    /// median of the first full wall window — the regression baseline
    baseline_wall_median: Option<f64>,
    /// last step a straggler fired per lane (wall/comm/compute) — one
    /// verdict per incident, re-armed after a full window refresh
    last_straggler: [Option<u64>; 3],
    best_ema: Option<f64>,
    steps_since_best: usize,
    steps_seen: u64,
    regression_flagged: bool,
    plateau_flagged: bool,
    divergence_flagged: bool,
    verdicts: Vec<Verdict>,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig) -> HealthMonitor {
        let w = cfg.window.max(4);
        HealthMonitor {
            wall: RollingWindow::new(w),
            comm: RollingWindow::new(w),
            compute: RollingWindow::new(w),
            backoff_steps: VecDeque::new(),
            baseline_wall_median: None,
            last_straggler: [None; 3],
            best_ema: None,
            steps_since_best: 0,
            steps_seen: 0,
            regression_flagged: false,
            plateau_flagged: false,
            divergence_flagged: false,
            cfg,
        }
    }

    /// One observation per training step.  `wall_s` is this step's wall
    /// time (the caller diffs the recorder's cumulative clock); `comm_s` /
    /// `compute_s` may be 0.0 when tracing is off; `backoff` marks a
    /// loss-scale halving this step; `divergence_ceiling` is the recorder's
    /// (possibly infinite) ceiling.
    #[allow(clippy::too_many_arguments)]
    pub fn observe_step(
        &mut self,
        step: u64,
        wall_s: f64,
        comm_s: f64,
        compute_s: f64,
        loss_ema: f64,
        backoff: bool,
        divergence_ceiling: f64,
    ) {
        self.steps_seen += 1;

        self.check_straggler(step, 0, "wall", wall_s, self.wall.values());
        self.check_straggler(step, 1, "comm", comm_s, self.comm.values());
        self.check_straggler(step, 2, "compute", compute_s, self.compute.values());

        // regression: first full window fixes the baseline; later full
        // windows compare their median against it (flag once)
        if self.wall.is_full() {
            let med = self.wall.median();
            match self.baseline_wall_median {
                None => self.baseline_wall_median = Some(med),
                Some(base) => {
                    let threshold = self.cfg.regression_ratio * base;
                    if !self.regression_flagged && base > 0.0 && med > threshold {
                        self.regression_flagged = true;
                        self.verdicts.push(Verdict {
                            kind: "step_time_regression",
                            severity: Severity::Warn,
                            step,
                            value: med,
                            threshold,
                            message: format!(
                                "median step time {:.3e}s is {:.2}x the baseline \
                                 window's {:.3e}s",
                                med,
                                med / base,
                                base
                            ),
                            detail: format!("step {step}"),
                        });
                    }
                }
            }
        }

        self.wall.push(wall_s);
        self.comm.push(comm_s);
        self.compute.push(compute_s);

        // loss-scale thrash: count backoffs inside the trailing window
        if backoff {
            self.backoff_steps.push_back(step);
        }
        let horizon = step.saturating_sub(self.cfg.window as u64);
        while self.backoff_steps.front().is_some_and(|&s| s < horizon) {
            self.backoff_steps.pop_front();
        }
        if self.backoff_steps.len() as u64 > self.cfg.thrash_backoffs {
            let n = self.backoff_steps.len();
            self.verdicts.push(Verdict {
                kind: "loss_scale_thrash",
                severity: Severity::Warn,
                step,
                value: n as f64,
                threshold: self.cfg.thrash_backoffs as f64,
                message: format!(
                    "{n} loss-scale backoffs within {} steps — the scaler is \
                     oscillating, not settling",
                    self.cfg.window
                ),
                detail: format!("step {step}"),
            });
            // re-arm instead of firing every subsequent step
            self.backoff_steps.clear();
        }

        // plateau: smoothed loss has not made a new low for plateau_window
        if loss_ema.is_finite() {
            match self.best_ema {
                Some(best) if loss_ema < best => {
                    self.best_ema = Some(loss_ema);
                    self.steps_since_best = 0;
                }
                Some(_) => self.steps_since_best += 1,
                None => self.best_ema = Some(loss_ema),
            }
            if !self.plateau_flagged && self.steps_since_best >= self.cfg.plateau_window {
                self.plateau_flagged = true;
                self.verdicts.push(Verdict {
                    kind: "loss_plateau",
                    severity: Severity::Info,
                    step,
                    value: self.steps_since_best as f64,
                    threshold: self.cfg.plateau_window as f64,
                    message: format!(
                        "smoothed loss has not improved on {:.6} for {} steps",
                        self.best_ema.unwrap_or(f64::NAN),
                        self.steps_since_best
                    ),
                    detail: format!("step {step}"),
                });
            }
        }

        // divergence early-warning: smoothed loss climbing toward the
        // ceiling (only meaningful when the recorder fixed a finite one)
        if divergence_ceiling.is_finite() {
            let threshold = self.cfg.divergence_warn_frac * divergence_ceiling;
            if !self.divergence_flagged && loss_ema.is_finite() && loss_ema > threshold {
                self.divergence_flagged = true;
                self.verdicts.push(Verdict {
                    kind: "divergence_warning",
                    severity: Severity::Warn,
                    step,
                    value: loss_ema,
                    threshold,
                    message: format!(
                        "smoothed loss {loss_ema:.6} is past {:.0}% of the \
                         divergence ceiling {divergence_ceiling:.6}",
                        self.cfg.divergence_warn_frac * 100.0
                    ),
                    detail: format!("step {step}"),
                });
            }
        }
    }

    fn check_straggler(
        &mut self,
        step: u64,
        lane_idx: usize,
        lane: &'static str,
        x: f64,
        vals: Vec<f64>,
    ) {
        // need a populated window before an "outlier" means anything
        if vals.len() < 8 || !(x > 0.0) {
            return;
        }
        // one verdict per incident: a regime change would otherwise flag
        // every step until the trailing median catches up
        if self.last_straggler[lane_idx]
            .is_some_and(|last| step < last + self.cfg.window as u64)
        {
            return;
        }
        let med = crate::util::stats::median(&vals);
        if med <= 0.0 {
            return;
        }
        let mad = crate::util::stats::mad(&vals, med);
        // floor the MAD at 5% of the median: a near-constant series must
        // not turn scheduler jitter into a verdict
        let z = robust_z(x, med, mad, 0.05 * med);
        if z > self.cfg.straggler_z && x > self.cfg.straggler_ratio * med {
            self.last_straggler[lane_idx] = Some(step);
            let kind = match lane {
                "comm" => "straggler_comm",
                "compute" => "straggler_compute",
                _ => "straggler",
            };
            self.verdicts.push(Verdict {
                kind,
                severity: Severity::Warn,
                step,
                value: x,
                threshold: med,
                message: format!(
                    "step {step} {lane} time {x:.3e}s vs trailing median {med:.3e}s \
                     (robust z = {z:.1})"
                ),
                detail: format!("step {step}"),
            });
        }
    }

    pub fn verdicts(&self) -> &[Verdict] {
        &self.verdicts
    }

    /// Upgrade a verdict's attribution after the fact.  The trainer calls
    /// this on freshly-raised straggler verdicts when the flight recorder
    /// has the step's span timeline: `detail` then names the slowest
    /// (lane, stage) instead of just the step index.
    pub fn set_detail(&mut self, idx: usize, detail: String) {
        if let Some(v) = self.verdicts.get_mut(idx) {
            v.detail = detail;
        }
    }

    /// Healthy ⇔ no warn-severity verdicts (info verdicts don't fail a run).
    pub fn healthy(&self) -> bool {
        self.verdicts.iter().all(|v| v.severity != Severity::Warn)
    }

    pub fn steps_seen(&self) -> u64 {
        self.steps_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_clean(mon: &mut HealthMonitor, steps: u64) {
        for t in 1..=steps {
            // mild deterministic jitter around 10ms, loss decaying from 8
            let jitter = 1.0 + 0.04 * ((t % 7) as f64 - 3.0) / 3.0;
            let wall = 0.010 * jitter;
            let loss = 8.0 * (-(t as f64) / 400.0).exp();
            mon.observe_step(t, wall, wall * 0.4, wall * 0.5, loss, false, 24.0);
        }
    }

    #[test]
    fn clean_run_is_healthy() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        feed_clean(&mut mon, 500);
        assert!(mon.healthy(), "false positives on a clean run: {:?}", mon.verdicts());
        assert!(mon.verdicts().is_empty());
        assert_eq!(mon.steps_seen(), 500);
    }

    #[test]
    fn injected_straggler_is_flagged_once_at_the_right_step() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        for t in 1..=100u64 {
            let wall = if t == 60 { 0.200 } else { 0.010 };
            mon.observe_step(t, wall, 0.0, 0.0, 5.0, false, f64::INFINITY);
        }
        let stragglers: Vec<_> =
            mon.verdicts().iter().filter(|v| v.kind == "straggler").collect();
        assert_eq!(stragglers.len(), 1, "{:?}", mon.verdicts());
        assert_eq!(stragglers[0].step, 60);
        assert_eq!(stragglers[0].severity, Severity::Warn);
        assert!(!mon.healthy());
    }

    #[test]
    fn comm_straggler_uses_its_own_lane() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        for t in 1..=60u64 {
            let comm = if t == 40 { 0.080 } else { 0.004 };
            mon.observe_step(t, 0.010, comm, 0.005, 5.0, false, f64::INFINITY);
        }
        assert!(mon.verdicts().iter().any(|v| v.kind == "straggler_comm"));
        assert!(!mon.verdicts().iter().any(|v| v.kind == "straggler"));
    }

    #[test]
    fn sustained_slowdown_is_a_regression_flagged_once() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        for t in 1..=200u64 {
            // step time 2.5x after step 100 and staying there
            let wall = if t <= 100 { 0.010 } else { 0.025 };
            mon.observe_step(t, wall, 0.0, 0.0, 5.0, false, f64::INFINITY);
        }
        let regs: Vec<_> = mon
            .verdicts()
            .iter()
            .filter(|v| v.kind == "step_time_regression")
            .collect();
        assert_eq!(regs.len(), 1, "flag once, not per-step: {:?}", mon.verdicts());
        assert!(regs[0].step > 100);
        // the regime-change onset may read as one straggler, never a storm
        let stragglers = mon.verdicts().iter().filter(|v| v.kind == "straggler").count();
        assert!(stragglers <= 1, "straggler storm: {:?}", mon.verdicts());
        assert!(!mon.healthy());
    }

    #[test]
    fn loss_scale_thrash_is_flagged_and_rearmed() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        // 6 backoffs inside one 32-step window: one thrash verdict (>3),
        // then the counter re-arms
        for t in 1..=40u64 {
            let backoff = t % 5 == 0 && t <= 30;
            mon.observe_step(t, 0.010, 0.0, 0.0, 5.0, backoff, f64::INFINITY);
        }
        let thrash: Vec<_> =
            mon.verdicts().iter().filter(|v| v.kind == "loss_scale_thrash").collect();
        assert_eq!(thrash.len(), 1, "{:?}", mon.verdicts());
        assert!(!mon.healthy());

        // sparse backoffs (normal scale walk-down) stay silent
        let mut calm = HealthMonitor::new(HealthConfig::default());
        for t in 1..=300u64 {
            calm.observe_step(t, 0.010, 0.0, 0.0, 5.0, t % 100 == 0, f64::INFINITY);
        }
        assert!(calm.healthy(), "{:?}", calm.verdicts());
    }

    #[test]
    fn plateau_is_info_severity_and_flagged_once() {
        let cfg = HealthConfig { plateau_window: 50, ..HealthConfig::default() };
        let mut mon = HealthMonitor::new(cfg);
        for t in 1..=200u64 {
            mon.observe_step(t, 0.010, 0.0, 0.0, 5.0, false, f64::INFINITY);
        }
        let plateaus: Vec<_> =
            mon.verdicts().iter().filter(|v| v.kind == "loss_plateau").collect();
        assert_eq!(plateaus.len(), 1);
        assert_eq!(plateaus[0].severity, Severity::Info);
        assert!(mon.healthy(), "info verdicts must not fail the run");
    }

    #[test]
    fn divergence_warning_fires_before_the_ceiling() {
        let mut mon = HealthMonitor::new(HealthConfig::default());
        let ceiling = 15.0; // recorder default for first loss 5.0
        for t in 1..=50u64 {
            let ema = 5.0 + 0.1 * t as f64; // climbing toward 10 > 7.5
            mon.observe_step(t, 0.010, 0.0, 0.0, ema, false, ceiling);
        }
        let divs: Vec<_> =
            mon.verdicts().iter().filter(|v| v.kind == "divergence_warning").collect();
        assert_eq!(divs.len(), 1);
        assert!(divs[0].value > 7.5 && divs[0].value < ceiling);
        assert!(!mon.healthy());

        // infinite ceiling (opt-out): never warns no matter the loss
        let mut free = HealthMonitor::new(HealthConfig::default());
        for t in 1..=50u64 {
            free.observe_step(t, 0.010, 0.0, 0.0, 1e12, false, f64::INFINITY);
        }
        assert!(free.verdicts().iter().all(|v| v.kind != "divergence_warning"));
    }

    #[test]
    fn nan_ema_does_not_poison_the_plateau_tracker() {
        let mut mon = HealthMonitor::new(HealthConfig {
            plateau_window: 20,
            ..HealthConfig::default()
        });
        for t in 1..=60u64 {
            let ema = if t % 2 == 0 { f64::NAN } else { 6.0 - 0.05 * t as f64 };
            mon.observe_step(t, 0.010, 0.0, 0.0, ema, false, f64::INFINITY);
        }
        // improving on the finite samples: no plateau
        assert!(mon.verdicts().iter().all(|v| v.kind != "loss_plateau"));
    }
}
