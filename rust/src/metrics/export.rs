//! Metrics export: per-step JSONL time-series and the end-of-run report.
//!
//! Two machine-readable artifacts (both validated by `tools/check_metrics.py`
//! in CI) plus one human-readable summary:
//!
//! - **JSONL** (`[metrics] jsonl` knob): one JSON object per recorded step,
//!   mirroring [`StepRecord`].  Non-finite numbers serialize as `null` so
//!   every line is strict JSON.
//! - **report** (`[metrics] report` knob): a single `lans-metrics-report-v1`
//!   JSON document — run totals, exact step/comm/compute time percentiles
//!   (over the raw series, via [`crate::util::stats::percentile`]),
//!   registry counters/gauges/histograms (approximate p50/p90/p99 at bucket
//!   resolution), health verdicts, and the measured-vs-model step-time
//!   delta when the caller supplies a `cluster::timemodel` prediction.
//! - [`render_summary`]: the same report as indented text for the terminal.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::metrics::health::{HealthMonitor, Verdict};
use crate::metrics::recorder::{Recorder, StepRecord};
use crate::metrics::registry::Snapshot;
use crate::util::stats;

pub const REPORT_SCHEMA: &str = "lans-metrics-report-v1";

/// Exact percentile summary over one raw per-step time series.
#[derive(Debug, Clone, Default)]
pub struct TimeSummary {
    pub samples: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl TimeSummary {
    pub fn from_series(xs: &[f64]) -> TimeSummary {
        if xs.is_empty() {
            return TimeSummary::default();
        }
        TimeSummary {
            samples: xs.len() as u64,
            mean_s: stats::mean(xs),
            p50_s: stats::percentile(xs, 50.0),
            p90_s: stats::percentile(xs, 90.0),
            p99_s: stats::percentile(xs, 99.0),
            max_s: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// The end-of-run report: everything the run knows about itself.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub steps: u64,
    pub skipped_steps: u64,
    pub tokens: u64,
    pub tokens_per_second: f64,
    pub final_loss: Option<f64>,
    pub final_loss_ema: Option<f64>,
    pub diverged: bool,
    pub step_time: TimeSummary,
    pub comm_time: TimeSummary,
    pub compute_time: TimeSummary,
    /// registry state at run end (counters / gauges / histograms)
    pub snapshot: Snapshot,
    pub healthy: bool,
    pub verdicts: Vec<Verdict>,
    /// caller-supplied `cluster::timemodel` step-time prediction (seconds)
    pub model_step_time_s: Option<f64>,
}

impl RunReport {
    /// Median measured step time — the number the model delta compares to.
    pub fn measured_step_time_s(&self) -> f64 {
        self.step_time.p50_s
    }

    /// (measured − model) / model, when a model prediction was supplied and
    /// at least one step ran.
    pub fn model_delta_frac(&self) -> Option<f64> {
        let model = self.model_step_time_s?;
        if model <= 0.0 || self.step_time.samples == 0 {
            return None;
        }
        Some((self.measured_step_time_s() - model) / model)
    }
}

/// Per-step wall time: the recorder's `wall_s` is cumulative (elapsed since
/// run start), so step `i`'s own time is the delta from step `i - 1`.
pub fn step_wall_deltas(rec: &Recorder) -> Vec<f64> {
    let mut out = Vec::with_capacity(rec.records.len());
    let mut prev = 0.0;
    for r in &rec.records {
        out.push((r.wall_s - prev).max(0.0));
        prev = r.wall_s;
    }
    out
}

/// Assemble the report from the run's three sources of truth.
pub fn build_report(
    rec: &Recorder,
    snapshot: Snapshot,
    health: &HealthMonitor,
    model_step_time_s: Option<f64>,
) -> RunReport {
    let comm: Vec<f64> = rec.records.iter().map(|r| r.comm_s).collect();
    let compute: Vec<f64> = rec.records.iter().map(|r| r.compute_s).collect();
    RunReport {
        steps: rec.records.len() as u64,
        skipped_steps: rec.skipped_steps(),
        tokens: rec.records.last().map_or(0, |r| r.tokens),
        tokens_per_second: rec.tokens_per_second(),
        final_loss: rec.last_loss(),
        final_loss_ema: rec.ema_loss(),
        diverged: rec.diverged(),
        step_time: TimeSummary::from_series(&step_wall_deltas(rec)),
        comm_time: TimeSummary::from_series(&comm),
        compute_time: TimeSummary::from_series(&compute),
        snapshot,
        healthy: health.healthy(),
        verdicts: health.verdicts().to_vec(),
        model_step_time_s,
    }
}

// ---------------------------------------------------------------------------
// JSON rendering.  `util::json` is a parser only and `util::bench`'s writer
// helpers are private to the Reporter, so the (small) escaping/number logic
// lives here too: JSON output must be strict, so non-finite f64s become null.
// ---------------------------------------------------------------------------

pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// f64 → strict-JSON number, or `null` for NaN/inf (skipped steps record
/// NaN grad norms by design).
pub(crate) fn num(v: f64) -> String {
    if v.is_finite() {
        // `{}` prints integral f64s without a dot; that is still valid JSON
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn jsonl_line(r: &StepRecord) -> String {
    format!(
        "{{\"step\":{},\"lr\":{},\"loss\":{},\"loss_ema\":{},\"grad_norm\":{},\
         \"trust_ratio\":{},\"tokens\":{},\"wall_s\":{},\"loss_scale\":{},\
         \"skipped\":{},\"comm_s\":{},\"compute_s\":{},\"overlap_eff\":{},\
         \"note\":\"{}\"}}",
        r.step,
        num(r.lr),
        num(r.loss),
        num(r.loss_ema),
        num(r.grad_norm),
        num(r.trust_ratio),
        r.tokens,
        num(r.wall_s),
        num(r.loss_scale),
        r.skipped,
        num(r.comm_s),
        num(r.compute_s),
        num(r.overlap_eff),
        esc(&r.note)
    )
}

pub(crate) fn create_with_parents(path: &Path) -> Result<std::fs::File> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating parent directory {}", dir.display()))?;
    }
    std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))
}

/// Write the per-step time series as JSONL (one object per recorded step;
/// an empty run writes an empty file, which the checker accepts).
pub fn write_jsonl(path: &Path, rec: &Recorder) -> Result<()> {
    let mut f = create_with_parents(path)?;
    for r in &rec.records {
        writeln!(f, "{}", jsonl_line(r))?;
    }
    Ok(())
}

fn time_summary_json(t: &TimeSummary) -> String {
    format!(
        "{{\"samples\":{},\"mean_s\":{},\"p50_s\":{},\"p90_s\":{},\"p99_s\":{},\"max_s\":{}}}",
        t.samples,
        num(t.mean_s),
        num(t.p50_s),
        num(t.p90_s),
        num(t.p99_s),
        num(if t.samples == 0 { 0.0 } else { t.max_s })
    )
}

pub(crate) fn verdict_json(v: &Verdict) -> String {
    format!(
        "{{\"kind\":\"{}\",\"severity\":\"{}\",\"step\":{},\"value\":{},\
         \"threshold\":{},\"message\":\"{}\",\"detail\":\"{}\"}}",
        esc(v.kind),
        v.severity.as_str(),
        v.step,
        num(v.value),
        num(v.threshold),
        esc(&v.message),
        esc(&v.detail)
    )
}

/// Serialize the report as one `lans-metrics-report-v1` JSON document.
pub fn report_json(rep: &RunReport) -> String {
    let mut s = String::with_capacity(4096);
    s.push_str(&format!(
        "{{\n  \"schema\": \"{REPORT_SCHEMA}\",\n  \"steps\": {},\n  \
         \"skipped_steps\": {},\n  \"tokens\": {},\n  \"tokens_per_second\": {},\n",
        rep.steps,
        rep.skipped_steps,
        rep.tokens,
        num(rep.tokens_per_second)
    ));
    s.push_str(&format!(
        "  \"final_loss\": {},\n  \"final_loss_ema\": {},\n  \"diverged\": {},\n",
        rep.final_loss.map_or("null".into(), num),
        rep.final_loss_ema.map_or("null".into(), num),
        rep.diverged
    ));
    s.push_str(&format!("  \"step_time\": {},\n", time_summary_json(&rep.step_time)));
    s.push_str(&format!("  \"comm_time\": {},\n", time_summary_json(&rep.comm_time)));
    s.push_str(&format!(
        "  \"compute_time\": {},\n",
        time_summary_json(&rep.compute_time)
    ));

    s.push_str("  \"counters\": {");
    for (i, (name, v)) in rep.snapshot.counters.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\n    \"{}\": {}", esc(name), v));
    }
    s.push_str("\n  },\n  \"gauges\": {");
    for (i, (name, v)) in rep.snapshot.gauges.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\n    \"{}\": {}", esc(name), num(*v)));
    }
    s.push_str("\n  },\n  \"histograms\": {");
    for (i, h) in rep.snapshot.histograms.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        // sparse [bucket-index, count] pairs: 64 mostly-zero buckets would
        // drown the report
        let buckets: Vec<String> = h
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(idx, &n)| format!("[{idx},{n}]"))
            .collect();
        s.push_str(&format!(
            "\n    \"{}\": {{\"count\":{},\"sum\":{},\"mean\":{},\"p50\":{},\
             \"p90\":{},\"p99\":{},\"buckets\":[{}]}}",
            esc(h.name),
            h.count,
            num(h.sum),
            num(h.mean()),
            num(h.percentile(50.0)),
            num(h.percentile(90.0)),
            num(h.percentile(99.0)),
            buckets.join(",")
        ));
    }
    s.push_str("\n  },\n");

    s.push_str(&format!(
        "  \"health\": {{\"healthy\": {}, \"verdicts\": [",
        rep.healthy
    ));
    for (i, v) in rep.verdicts.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    ");
        s.push_str(&verdict_json(v));
    }
    if !rep.verdicts.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]},\n");

    match rep.model_step_time_s {
        Some(model) => s.push_str(&format!(
            "  \"model\": {{\"model_step_time_s\": {}, \"measured_step_time_s\": {}, \
             \"delta_frac\": {}}}\n",
            num(model),
            num(rep.measured_step_time_s()),
            rep.model_delta_frac().map_or("null".into(), num)
        )),
        None => s.push_str("  \"model\": null\n"),
    }
    s.push('}');
    s
}

/// Write the report JSON to disk.
pub fn write_report(path: &Path, rep: &RunReport) -> Result<()> {
    let mut f = create_with_parents(path)?;
    writeln!(f, "{}", report_json(rep))?;
    Ok(())
}

/// Human-readable report for the terminal.
pub fn render_summary(rep: &RunReport) -> String {
    let ms = |s: f64| format!("{:.2}ms", s * 1e3);
    let mut out = String::new();
    out.push_str(&format!(
        "run-health report — {} steps ({} skipped), {} tokens, {:.0} tok/s\n",
        rep.steps, rep.skipped_steps, rep.tokens, rep.tokens_per_second
    ));
    if let (Some(l), Some(e)) = (rep.final_loss, rep.final_loss_ema) {
        out.push_str(&format!(
            "  final loss {l:.6} (ema {e:.6}){}\n",
            if rep.diverged { "  [DIVERGED]" } else { "" }
        ));
    }
    for (label, t) in [
        ("step", &rep.step_time),
        ("comm", &rep.comm_time),
        ("compute", &rep.compute_time),
    ] {
        if t.samples > 0 {
            out.push_str(&format!(
                "  {label:<8} p50 {}  p90 {}  p99 {}  max {}\n",
                ms(t.p50_s),
                ms(t.p90_s),
                ms(t.p99_s),
                ms(t.max_s)
            ));
        }
    }
    for (name, v) in &rep.snapshot.counters {
        if *v > 0 {
            out.push_str(&format!("  {name} = {v}\n"));
        }
    }
    for (name, v) in &rep.snapshot.gauges {
        out.push_str(&format!("  {name} = {v}\n"));
    }
    for h in &rep.snapshot.histograms {
        if h.count > 0 {
            out.push_str(&format!(
                "  {} n={} mean={:.4e} p50~{:.4e} p99~{:.4e}\n",
                h.name,
                h.count,
                h.mean(),
                h.percentile(50.0),
                h.percentile(99.0)
            ));
        }
    }
    if let (Some(model), Some(delta)) = (rep.model_step_time_s, rep.model_delta_frac()) {
        out.push_str(&format!(
            "  model step time {} vs measured {} ({:+.1}%)\n",
            ms(model),
            ms(rep.measured_step_time_s()),
            delta * 100.0
        ));
    }
    out.push_str(&format!(
        "  health: {}",
        if rep.healthy { "HEALTHY" } else { "UNHEALTHY" }
    ));
    if rep.verdicts.is_empty() {
        out.push_str(" (no verdicts)\n");
    } else {
        out.push('\n');
        for v in &rep.verdicts {
            out.push_str(&format!(
                "    [{}] {} @ step {}: {} ({})\n",
                v.severity.as_str(),
                v.kind,
                v.step,
                v.message,
                v.detail
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::health::HealthConfig;
    use crate::metrics::registry;
    use crate::util::json::Json;

    fn empty_snapshot() -> Snapshot {
        // build through the registry while disabled: all zeros
        let _g = registry::test_lock();
        registry::disable();
        registry::reset();
        registry::snapshot()
    }

    fn quiet_health() -> HealthMonitor {
        HealthMonitor::new(HealthConfig::default())
    }

    #[test]
    fn empty_run_exports_cleanly() {
        let rec = Recorder::new(0.5);
        let rep = build_report(&rec, empty_snapshot(), &quiet_health(), None);
        assert_eq!(rep.steps, 0);
        assert_eq!(rep.step_time.samples, 0);
        assert_eq!(rep.step_time.p99_s, 0.0);
        assert!(rep.healthy);
        assert!(rep.final_loss.is_none());
        assert!(rep.model_delta_frac().is_none());

        let dir = std::env::temp_dir();
        let jl = dir.join("lans_test_export_empty.jsonl");
        let rp = dir.join("lans_test_export_empty.json");
        write_jsonl(&jl, &rec).unwrap();
        write_report(&rp, &rep).unwrap();
        assert_eq!(std::fs::read_to_string(&jl).unwrap(), "");
        let parsed = Json::parse(&std::fs::read_to_string(&rp).unwrap()).unwrap();
        assert_eq!(parsed.expect("schema").as_str(), Some(REPORT_SCHEMA));
        assert_eq!(parsed.expect("steps").as_usize(), Some(0));
        assert_eq!(parsed.expect("model"), &Json::Null);
        assert_eq!(parsed.expect("final_loss"), &Json::Null);
        std::fs::remove_file(&jl).ok();
        std::fs::remove_file(&rp).ok();
    }

    #[test]
    fn single_step_percentiles_collapse_to_the_value() {
        let mut rec = Recorder::new(0.5);
        rec.push(1, 1e-3, 4.0, 1.0, 1.0, 64);
        rec.set_step_timing(0.25, 0.5, 0.1);
        let rep = build_report(&rec, empty_snapshot(), &quiet_health(), None);
        assert_eq!(rep.step_time.samples, 1);
        assert_eq!(rep.comm_time.p50_s, 0.25);
        assert_eq!(rep.comm_time.p90_s, 0.25);
        assert_eq!(rep.comm_time.p99_s, 0.25);
        assert_eq!(rep.comm_time.max_s, 0.25);
        assert_eq!(rep.compute_time.p99_s, 0.5);
        // one step: its wall delta is the whole series
        assert_eq!(rep.step_time.p50_s, rep.step_time.max_s);
    }

    #[test]
    fn jsonl_round_trips_through_util_json() {
        let mut rec = Recorder::new(0.5);
        rec.push_scaled(1, 1e-3, 4.0, 2.0, 0.9, 64, 65536.0);
        rec.push_skipped(2, 1e-3, 4.1, 64, 65536.0, "overflow, scale -> 32768 \"half\"");
        rec.push_scaled(3, 1e-3, 3.9, 1.5, 0.8, 64, 32768.0);
        let p = std::env::temp_dir().join("lans_test_export_roundtrip.jsonl");
        write_jsonl(&p, &rec).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}\n{line}"));
            assert_eq!(j.expect("step").as_usize(), Some(i + 1));
            assert!(j.expect("loss").as_f64().is_some());
        }
        // skipped line: NaN grad norm serialized as null, note escaped
        let skipped = Json::parse(lines[1]).unwrap();
        assert_eq!(skipped.expect("skipped").as_bool(), Some(true));
        assert_eq!(skipped.expect("grad_norm"), &Json::Null);
        assert_eq!(skipped.expect("trust_ratio"), &Json::Null);
        assert_eq!(
            skipped.expect("note").as_str(),
            Some("overflow, scale -> 32768 \"half\"")
        );
        // applied line keeps real numbers
        let applied = Json::parse(lines[2]).unwrap();
        assert_eq!(applied.expect("grad_norm").as_f64(), Some(1.5));
        assert_eq!(applied.expect("loss_scale").as_f64(), Some(32768.0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn report_json_parses_and_orders_percentiles() {
        let mut rec = Recorder::new(0.5);
        for t in 1..=20u64 {
            rec.push(t, 1e-3, 5.0 - 0.1 * t as f64, 1.0, 1.0, 64);
            rec.set_step_timing(0.002 * t as f64, 0.003, 0.5);
        }
        let mut health = quiet_health();
        // force one verdict so the verdict array is exercised
        for t in 1..=100u64 {
            let wall = if t == 60 { 0.5 } else { 0.01 };
            health.observe_step(t, wall, 0.0, 0.0, 5.0, false, f64::INFINITY);
        }
        assert!(!health.healthy());
        let rep = build_report(&rec, empty_snapshot(), &health, Some(0.010));
        let doc = report_json(&rep);
        let j = Json::parse(&doc).unwrap_or_else(|e| panic!("{e}\n{doc}"));
        let ct = j.expect("comm_time");
        let (p50, p90, p99) = (
            ct.expect("p50_s").as_f64().unwrap(),
            ct.expect("p90_s").as_f64().unwrap(),
            ct.expect("p99_s").as_f64().unwrap(),
        );
        assert!(p50 <= p90 && p90 <= p99, "percentiles out of order: {p50} {p90} {p99}");
        let health_j = j.expect("health");
        assert_eq!(health_j.expect("healthy").as_bool(), Some(false));
        let verdicts = health_j.expect("verdicts").as_arr().unwrap();
        assert!(!verdicts.is_empty());
        assert_eq!(verdicts[0].expect("severity").as_str(), Some("warn"));
        let model = j.expect("model");
        assert_eq!(model.expect("model_step_time_s").as_f64(), Some(0.010));
        assert!(model.expect("delta_frac").as_f64().is_some());
        // the human rendering mentions the verdict and the model delta
        let text = render_summary(&rep);
        assert!(text.contains("UNHEALTHY"), "{text}");
        assert!(text.contains("straggler"), "{text}");
        assert!(text.contains("model step time"), "{text}");
    }

    #[test]
    fn step_wall_deltas_diff_the_cumulative_clock() {
        let mut rec = Recorder::new(0.5);
        rec.push(1, 1e-3, 5.0, 1.0, 1.0, 64);
        rec.push(2, 1e-3, 4.9, 1.0, 1.0, 64);
        rec.push(3, 1e-3, 4.8, 1.0, 1.0, 64);
        // overwrite the wall clocks with known values
        rec.records[0].wall_s = 1.0;
        rec.records[1].wall_s = 1.5;
        rec.records[2].wall_s = 3.5;
        assert_eq!(step_wall_deltas(&rec), vec![1.0, 0.5, 2.0]);
    }
}
