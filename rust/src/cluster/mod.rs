//! Cluster time model: FLOP accounting + testbed specs that regenerate the
//! wall-clock column of Table 2 (the substitution for the paper's 192-node
//! GPU cluster — DESIGN.md §5).

pub mod flops;
pub mod timemodel;

pub use flops::{BertDims, BERT_BASE, BERT_LARGE};
pub use timemodel::{
    pipelined_overlap_time_s, table2_runs, ClusterSpec, Phase, Run, UPDATE_WORDS_PER_PARAM,
};
