//! Cluster time model: regenerates Table 2's time column.
//!
//! Per training step:
//!     T_step = T_compute + (1 − overlap) · T_comm + T_update
//!     T_compute = batch_seqs · train_flops_per_seq / (devices · peak · eff)
//!     T_comm   = the chosen collective over the gradient/parameter bytes
//!                (hierarchical allreduce, or reduce-scatter + all-gather
//!                for the sharded-optimizer path)
//!     T_update = optimizer HBM traffic (~12 words/param for the fused
//!                3-pass LANS) / HBM bandwidth — over all params when
//!                replicated, over params/devices when sharded (ZeRO-1)
//!
//! `overlap` models backward/communication overlap (NCCL/EFA pipelines hide
//! most of the allreduce behind the backward pass; the paper enables EFA for
//! exactly this reason).  Constants are documented per testbed; DESIGN.md §5
//! explains the substitution and EXPERIMENTS.md compares model vs paper.

use crate::collective::cost::{
    hierarchical_all_gather_time_tiered_s, hierarchical_allreduce_time_tiered_s,
    hierarchical_reduce_scatter_time_tiered_s, Collective, CommSpec,
};

use super::flops::BertDims;

/// Words of HBM traffic per parameter per optimizer step for the fused
/// 3-pass LANS/LAMB update (9 reads + 3 writes — see the traffic model in
/// `benches/optimizer_step.rs`).
pub const UPDATE_WORDS_PER_PARAM: f64 = 12.0;

/// A modeled testbed.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub nodes: usize,
    pub devices_per_node: usize,
    /// peak mixed-precision FLOP/s per device
    pub peak_flops: f64,
    /// sustained fraction of peak on BERT training
    pub efficiency: f64,
    pub intra: CommSpec,
    pub inter: CommSpec,
    /// fraction of allreduce hidden behind backward
    pub overlap: f64,
    /// per-device HBM bandwidth (B/s) — prices the memory-bound optimizer
    /// update, the term the sharded path divides by the device count
    pub hbm_bytes_per_s: f64,
}

impl ClusterSpec {
    /// 192 × AWS P3dn.24xlarge: 8 × V100-32GB per node, 100 Gb/s EFA.
    pub fn p3dn(nodes: usize) -> ClusterSpec {
        ClusterSpec {
            name: "p3dn.24xlarge (V100, EFA)",
            nodes,
            devices_per_node: 8,
            peak_flops: 125e12, // V100 tensor-core fp16
            // Sustained fraction of peak, calibrated once against the
            // paper's published 53.6 m endpoint (≈21% of tensor-core peak —
            // consistent with 2019-era mixed-precision BERT at 1536 GPUs).
            // The LAMB/LANS *ratio* is model-predicted, not calibrated.
            efficiency: 0.21,
            intra: CommSpec::nvlink(),
            inter: CommSpec::efa(),
            overlap: 0.7,
            hbm_bytes_per_s: 900e9, // V100 HBM2
        }
    }

    /// TPUv3 pod slice with `chips` chips (LAMB's 1024-TPU testbed).
    pub fn tpu_v3(chips: usize) -> ClusterSpec {
        ClusterSpec {
            name: "TPUv3 pod",
            nodes: chips,
            devices_per_node: 1,
            peak_flops: 123e12, // bf16 per chip
            // calibrated against LAMB's published 76.2 m (≈30% of MXU peak)
            efficiency: 0.30,
            intra: CommSpec::tpu_ici(),
            inter: CommSpec::tpu_ici(),
            overlap: 0.7,
            hbm_bytes_per_s: 900e9, // TPUv3 HBM
        }
    }

    pub fn devices(&self) -> usize {
        self.nodes * self.devices_per_node
    }

    /// Seconds the memory-bound optimizer update takes on one device:
    /// [`UPDATE_WORDS_PER_PARAM`] words over all params when replicated,
    /// over `params / devices` when the optimizer is sharded (ZeRO-1).
    pub fn optimizer_update_time_s(&self, dims: &BertDims, sharded: bool) -> f64 {
        let t = UPDATE_WORDS_PER_PARAM * dims.param_bytes_f32() / self.hbm_bytes_per_s;
        if sharded {
            t / self.devices() as f64
        } else {
            t
        }
    }

    /// Seconds for one synchronous data-parallel step under the chosen
    /// collective schedule, at the default f32 wire width.
    pub fn step_time_with(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
        collective: Collective,
    ) -> f64 {
        self.step_time_with_wire(dims, batch_seqs, seq, slots, collective, 4.0)
    }

    /// [`step_time_with`](Self::step_time_with) at an explicit wire width
    /// (`bytes_per_elem`: 4.0 = fp32, 2.0 = fp16/bf16), applied to both
    /// tiers.  Halving the wire bytes halves exactly the β (bandwidth)
    /// term of the collective; the α (latency) term and the
    /// compute/update terms are unchanged — the optimizer update stays a
    /// full-precision pass over the fp32 master copy, as in the paper's
    /// mixed-precision recipe.
    pub fn step_time_with_wire(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
        collective: Collective,
        bytes_per_elem: f64,
    ) -> f64 {
        self.step_time_with_tier_wire(
            dims,
            batch_seqs,
            seq,
            slots,
            collective,
            bytes_per_elem,
            bytes_per_elem,
        )
    }

    /// [`step_time_with_wire`](Self::step_time_with_wire) at *per-tier*
    /// wire widths: `intra_bytes_per_elem` prices the intra-node (NVLink)
    /// phases and `inter_bytes_per_elem` the inter-node (NIC) phases, so a
    /// mixed fp32-intra / f16-inter topology (`intra_dtype = "f32"`,
    /// `grad_dtype = "f16"`) halves only the scarce tier's β term.  Equal
    /// widths reproduce the single-width price exactly (regression-pinned
    /// in the tests below).
    #[allow(clippy::too_many_arguments)]
    pub fn step_time_with_tier_wire(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
        collective: Collective,
        intra_bytes_per_elem: f64,
        inter_bytes_per_elem: f64,
    ) -> f64 {
        let (t_compute, t_comm, sharded) = self.compute_and_comm_s(
            dims,
            batch_seqs,
            seq,
            slots,
            collective,
            intra_bytes_per_elem,
            inter_bytes_per_elem,
        );
        t_compute
            + (1.0 - self.overlap) * t_comm
            + self.optimizer_update_time_s(dims, sharded)
    }

    /// The raw `(T_compute, T_comm, sharded)` triple behind the step-time
    /// entry points — one home for the collective dispatch so the scalar
    /// `overlap` model and the bucketed pipeline model price the same
    /// terms.
    #[allow(clippy::too_many_arguments)]
    fn compute_and_comm_s(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
        collective: Collective,
        intra_bytes_per_elem: f64,
        inter_bytes_per_elem: f64,
    ) -> (f64, f64, bool) {
        let flops = dims.train_flops_per_seq(seq, slots) * batch_seqs as f64;
        let t_compute =
            flops / (self.devices() as f64 * self.peak_flops * self.efficiency);
        let intra_bytes = dims.param_bytes(intra_bytes_per_elem);
        let inter_bytes = dims.param_bytes(inter_bytes_per_elem);
        let (t_comm, sharded) = match collective {
            Collective::AllReduce => (
                hierarchical_allreduce_time_tiered_s(
                    self.nodes,
                    self.devices_per_node,
                    intra_bytes,
                    inter_bytes,
                    self.intra,
                    self.inter,
                ),
                false,
            ),
            // sharded: reduce-scatter the gradient bytes, all-gather the
            // updated parameter bytes (same total volume, but each
            // inter-node phase moves only the per-node shard)
            Collective::ReduceScatterGather => (
                hierarchical_reduce_scatter_time_tiered_s(
                    self.nodes,
                    self.devices_per_node,
                    intra_bytes,
                    inter_bytes,
                    self.intra,
                    self.inter,
                ) + hierarchical_all_gather_time_tiered_s(
                    self.nodes,
                    self.devices_per_node,
                    intra_bytes,
                    inter_bytes,
                    self.intra,
                    self.inter,
                ),
                true,
            ),
        };
        (t_compute, t_comm, sharded)
    }

    /// Seconds for one step under the *bucketed* gradient pipeline
    /// (DESIGN.md §9): comm and compute are cut into `buckets` equal
    /// pieces, bucket `k`'s wire transfer overlapping bucket `k-1`'s
    /// digest, replacing the scalar `overlap` fraction with the explicit
    /// pipeline schedule [`pipelined_overlap_time_s`].  One bucket prices
    /// the fully synchronous step (`T_compute + T_comm`); infinitely many
    /// approach `max(T_compute, T_comm)` — comm fully hidden when compute
    /// dominates.  The optimizer update stays un-overlapped (it needs the
    /// whole folded gradient).
    #[allow(clippy::too_many_arguments)]
    pub fn step_time_bucketed(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
        collective: Collective,
        intra_bytes_per_elem: f64,
        inter_bytes_per_elem: f64,
        buckets: usize,
    ) -> f64 {
        let (t_compute, t_comm, sharded) = self.compute_and_comm_s(
            dims,
            batch_seqs,
            seq,
            slots,
            collective,
            intra_bytes_per_elem,
            inter_bytes_per_elem,
        );
        pipelined_overlap_time_s(t_compute, t_comm, buckets)
            + self.optimizer_update_time_s(dims, sharded)
    }

    /// Seconds for one step on the classic allreduce + replicated-update
    /// path (the historical default).
    pub fn step_time_s(
        &self,
        dims: &BertDims,
        batch_seqs: usize,
        seq: usize,
        slots: usize,
    ) -> f64 {
        self.step_time_with(dims, batch_seqs, seq, slots, Collective::AllReduce)
    }
}

/// Wall time of a `buckets`-deep two-stage pipeline whose total stage
/// costs are `t_compute` and `t_comm`: the first bucket's comm and the
/// last bucket's compute cannot overlap anything, every other slot is
/// paced by the slower stage —
///
///     T(B) = M/B + C/B + (B-1)/B · max(C, M)
///
/// `B = 1` degenerates to the synchronous `C + M`; `B → ∞` approaches
/// `max(C, M)`.  Monotone non-increasing in `B` — more buckets never
/// model a slower step (real bucket-count overheads are the `overlap_step`
/// bench's job, not the model's).
pub fn pipelined_overlap_time_s(t_compute: f64, t_comm: f64, buckets: usize) -> f64 {
    let b = buckets.max(1) as f64;
    t_compute / b + t_comm / b + (b - 1.0) / b * t_compute.max(t_comm)
}

/// One pretraining phase (the paper's seq-128 / seq-512 split).
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub steps: u64,
    pub batch_seqs: usize,
    pub seq: usize,
    pub slots: usize,
}

/// A Table-2 row: a named run = cluster + phases.
#[derive(Debug, Clone)]
pub struct Run {
    pub label: &'static str,
    pub cluster: ClusterSpec,
    pub phases: Vec<Phase>,
}

impl Run {
    pub fn total_steps(&self) -> u64 {
        self.phases.iter().map(|p| p.steps).sum()
    }

    pub fn total_minutes(&self, dims: &BertDims) -> f64 {
        self.phases
            .iter()
            .map(|p| {
                p.steps as f64 * self.cluster.step_time_s(dims, p.batch_seqs, p.seq, p.slots)
            })
            .sum::<f64>()
            / 60.0
    }
}

/// The paper's Table 2 runs.
///
/// * LAMB 64K/32K on 1024 TPUs, 8599 steps (7038 @ seq128 + 1561 @ seq512 —
///   the standard LAMB mixed-batch split that Table 2 cites from You et al.)
/// * LANS 96K/33K on 1536 V100s, 4301 steps (3519 + 782, paper §4)
pub fn table2_runs() -> Vec<Run> {
    vec![
        Run {
            label: "LAMB 64K/32K (1024 TPUv3)",
            cluster: ClusterSpec::tpu_v3(1024),
            phases: vec![
                Phase { steps: 7038, batch_seqs: 65536, seq: 128, slots: 20 },
                Phase { steps: 1561, batch_seqs: 32768, seq: 512, slots: 80 },
            ],
        },
        Run {
            label: "LANS 96K/33K (1536 V100)",
            cluster: ClusterSpec::p3dn(192),
            phases: vec![
                Phase { steps: 3519, batch_seqs: 98304, seq: 128, slots: 20 },
                Phase { steps: 782, batch_seqs: 33792, seq: 512, slots: 80 },
            ],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::flops::BERT_LARGE;

    #[test]
    fn table2_step_counts() {
        let runs = table2_runs();
        assert_eq!(runs[0].total_steps(), 8599);
        assert_eq!(runs[1].total_steps(), 4301);
    }

    #[test]
    fn table2_time_shape() {
        // paper: LAMB 76.2 m vs LANS 53.6 m (ratio 0.703).  The model should
        // land in the right ballpark (±40% absolute) and preserve the
        // ordering and rough ratio.
        let runs = table2_runs();
        let lamb = runs[0].total_minutes(&BERT_LARGE);
        let lans = runs[1].total_minutes(&BERT_LARGE);
        assert!(lans < lamb, "LANS ({lans:.1}m) must beat LAMB ({lamb:.1}m)");
        assert!((45.0..110.0).contains(&lamb), "LAMB modeled {lamb:.1}m vs 76.2m");
        assert!((30.0..80.0).contains(&lans), "LANS modeled {lans:.1}m vs 53.6m");
        let ratio = lans / lamb;
        assert!((0.5..0.9).contains(&ratio), "ratio {ratio:.2} vs paper 0.70");
    }

    #[test]
    fn sharded_collective_is_never_slower() {
        // reduce-scatter+gather moves less inter-node data and divides the
        // update by the device count — the modeled step must not regress
        for (c, batch, seq, slots) in
            [(ClusterSpec::p3dn(192), 98304, 128, 20), (ClusterSpec::tpu_v3(1024), 65536, 128, 20)]
        {
            let ar = c.step_time_with(&BERT_LARGE, batch, seq, slots, Collective::AllReduce);
            let rsg = c.step_time_with(
                &BERT_LARGE, batch, seq, slots, Collective::ReduceScatterGather);
            assert!(rsg < ar, "{}: sharded {rsg} vs allreduce {ar}", c.name);
        }
    }

    #[test]
    fn sharded_update_term_divides_by_devices() {
        let c = ClusterSpec::p3dn(192);
        let rep = c.optimizer_update_time_s(&BERT_LARGE, false);
        let sh = c.optimizer_update_time_s(&BERT_LARGE, true);
        assert!((rep / sh - c.devices() as f64).abs() < 1e-6);
    }

    #[test]
    fn fp16_wire_halves_the_beta_term() {
        // wire width scales only the bandwidth term: with bytes/elem = 0
        // isolating α + compute + update, the fp16 surplus must be exactly
        // half the fp32 surplus (the cost model is linear in bytes)
        let c = ClusterSpec::p3dn(192);
        let (b, s, sl) = (98304, 128, 20);
        for coll in [Collective::AllReduce, Collective::ReduceScatterGather] {
            let t32 = c.step_time_with_wire(&BERT_LARGE, b, s, sl, coll, 4.0);
            let t16 = c.step_time_with_wire(&BERT_LARGE, b, s, sl, coll, 2.0);
            let base = c.step_time_with_wire(&BERT_LARGE, b, s, sl, coll, 0.0);
            let beta32 = t32 - base;
            let beta16 = t16 - base;
            assert!(beta32 > 0.0, "{coll:?}");
            assert!(
                (beta16 - beta32 / 2.0).abs() <= 1e-9 * beta32,
                "{coll:?}: beta16 {beta16} vs half of {beta32}"
            );
            assert!(t16 < t32, "{coll:?}");
        }
        // and the default-width entry point is the 4-byte wire
        let via_default =
            c.step_time_with(&BERT_LARGE, b, s, sl, Collective::AllReduce);
        let via_wire =
            c.step_time_with_wire(&BERT_LARGE, b, s, sl, Collective::AllReduce, 4.0);
        assert_eq!(via_default, via_wire);
    }

    #[test]
    fn tier_wire_endpoints_pin_to_single_width_prices() {
        // the per-tier generalization must not move the uniform endpoints:
        // (4,4) == the old fp32 price, (2,2) == the old fp16 price, and a
        // mixed fp32-intra/f16-inter run lands strictly between
        let c = ClusterSpec::p3dn(192);
        let (b, s, sl) = (98304, 128, 20);
        for coll in [Collective::AllReduce, Collective::ReduceScatterGather] {
            let t32 = c.step_time_with_wire(&BERT_LARGE, b, s, sl, coll, 4.0);
            let t16 = c.step_time_with_wire(&BERT_LARGE, b, s, sl, coll, 2.0);
            assert_eq!(
                t32,
                c.step_time_with_tier_wire(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0),
                "{coll:?} fp32 endpoint moved"
            );
            assert_eq!(
                t16,
                c.step_time_with_tier_wire(&BERT_LARGE, b, s, sl, coll, 2.0, 2.0),
                "{coll:?} fp16 endpoint moved"
            );
            let mixed = c.step_time_with_tier_wire(&BERT_LARGE, b, s, sl, coll, 4.0, 2.0);
            assert!(t16 < mixed && mixed < t32, "{coll:?}: {t16} < {mixed} < {t32}");
            // on the naive allreduce the inter β term dominates, so
            // halving only the scarce tier keeps most of the uniform-fp16
            // saving (the sharded collective moves only shards inter-node,
            // so its saving concentrates intra — no such claim there)
            if coll == Collective::AllReduce {
                let saved_mixed = t32 - mixed;
                let saved_all = t32 - t16;
                assert!(
                    saved_mixed > 0.5 * saved_all,
                    "inter-only saving {saved_mixed} vs full {saved_all}"
                );
            }
        }
    }

    #[test]
    fn pipelined_time_endpoints_and_monotonicity() {
        let (c, m) = (3.0, 1.25);
        // B = 1 is the synchronous step, exactly
        assert_eq!(pipelined_overlap_time_s(c, m, 1), c + m);
        assert_eq!(pipelined_overlap_time_s(c, m, 0), c + m, "0 clamps to 1");
        // monotone non-increasing, and approaching max(C, M) from above
        let mut prev = f64::INFINITY;
        for b in 1..=64 {
            let t = pipelined_overlap_time_s(c, m, b);
            assert!(t <= prev + 1e-12, "B={b}: {t} > {prev}");
            assert!(t >= c.max(m) - 1e-12, "B={b}: below the pipeline floor");
            prev = t;
        }
        let deep = pipelined_overlap_time_s(c, m, 1 << 20);
        assert!((deep - c.max(m)).abs() < 1e-4, "B→∞ must approach max(C,M)");
        // symmetric in which stage dominates
        assert_eq!(
            pipelined_overlap_time_s(c, m, 8),
            pipelined_overlap_time_s(m, c, 8)
        );
    }

    #[test]
    fn bucketed_step_time_brackets_the_scalar_overlap_model() {
        // one bucket = the overlap-0 scalar model; deep pipelines beat it
        // and never beat the overlap-1 (compute + update) floor
        let c = ClusterSpec::p3dn(192);
        let (b, s, sl) = (98304, 128, 20);
        for coll in [Collective::AllReduce, Collective::ReduceScatterGather] {
            let mut sync = c.clone();
            sync.overlap = 0.0;
            let t_sync = sync.step_time_with_tier_wire(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0);
            let one = c.step_time_bucketed(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0, 1);
            assert!((one - t_sync).abs() <= 1e-12 * t_sync, "{coll:?}: {one} vs {t_sync}");

            let mut hidden = c.clone();
            hidden.overlap = 1.0;
            let floor = hidden.step_time_with_tier_wire(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0);
            let mut prev = f64::INFINITY;
            for nb in [1usize, 2, 4, 8, 32, 128] {
                let t = c.step_time_bucketed(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0, nb);
                assert!(t <= prev + 1e-12, "{coll:?} B={nb} regressed");
                assert!(t >= floor - 1e-12, "{coll:?} B={nb} beat the comm-free floor");
                prev = t;
            }
            // deep pipeline limit: recover C, M, update from the two
            // scalar-model endpoints and check T(B→∞) → max(C, M) + update
            let update =
                c.optimizer_update_time_s(&BERT_LARGE, coll == Collective::ReduceScatterGather);
            let comp = floor - update;
            let comm = one - floor;
            let deep = c.step_time_bucketed(&BERT_LARGE, b, s, sl, coll, 4.0, 4.0, 4096);
            let want = comp.max(comm) + update;
            assert!(
                (deep - want).abs() <= comp.min(comm) / 4096.0 + 1e-9 * want,
                "{coll:?}: deep {deep} vs limit {want}"
            );
        }
    }

    #[test]
    fn comm_fraction_is_minor_with_overlap() {
        // with EFA + overlap the paper's step is compute-bound; check comm
        // contributes <30% of step time at 96K/seq128
        let c = ClusterSpec::p3dn(192);
        let full = c.step_time_s(&BERT_LARGE, 98304, 128, 20);
        let mut no_comm = c.clone();
        no_comm.overlap = 1.0;
        let compute_only = no_comm.step_time_s(&BERT_LARGE, 98304, 128, 20);
        assert!((full - compute_only) / full < 0.3);
    }
}
