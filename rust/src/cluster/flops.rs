//! BERT FLOP and parameter accounting for the cluster time model.
//!
//! Uses the true architecture dimensions (BERT-Large: L=24, H=1024, I=4096,
//! V=30522) so the Table-2 time reproduction prices the paper's actual
//! workload, independent of the laptop-scale configs we *train*.

/// Architecture dimensions (mirrors python/compile/configs.py presets).
#[derive(Debug, Clone, Copy)]
pub struct BertDims {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub intermediate: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

pub const BERT_LARGE: BertDims = BertDims {
    layers: 24,
    hidden: 1024,
    heads: 16,
    intermediate: 4096,
    vocab: 30522,
    max_seq: 512,
};

pub const BERT_BASE: BertDims = BertDims {
    layers: 12,
    hidden: 768,
    heads: 12,
    intermediate: 3072,
    vocab: 30522,
    max_seq: 512,
};

impl BertDims {
    /// Total trainable parameters (matches configs.param_specs: embeddings,
    /// encoder, MLM head with tied output embedding).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let i = self.intermediate as u64;
        let v = self.vocab as u64;
        let s = self.max_seq as u64;
        let emb = v * h + s * h + 2 * h;
        let per_layer = 4 * (h * h + h)      // qkv+out proj
            + 2 * (2 * h)                    // 2 layernorms
            + h * i + i + i * h + h; // ffn
        let mlm = h * h + h + 2 * h + v;
        emb + self.layers as u64 * per_layer + mlm
    }

    pub fn param_bytes_f32(&self) -> f64 {
        self.param_bytes(4.0)
    }

    /// Parameter-vector bytes at an arbitrary wire element width — 2.0
    /// prices the fp16/bf16 gradient exchange of the paper's mixed-
    /// precision run, 4.0 the fp32 baseline.
    pub fn param_bytes(&self, bytes_per_elem: f64) -> f64 {
        self.param_count() as f64 * bytes_per_elem
    }

    /// Forward FLOPs for one sequence of length `seq` with `slots` MLM
    /// prediction positions (matmul flops = 2mnk; elementwise ignored).
    pub fn fwd_flops_per_seq(&self, seq: usize, slots: usize) -> f64 {
        let s = seq as f64;
        let p = slots as f64;
        let h = self.hidden as f64;
        let i = self.intermediate as f64;
        let v = self.vocab as f64;
        let per_layer = 4.0 * 2.0 * s * h * h   // q,k,v,out projections
            + 2.0 * 2.0 * s * s * h             // scores + context
            + 2.0 * 2.0 * s * h * i; // ffn in+out
        let mlm = 2.0 * p * h * h + 2.0 * p * h * v;
        self.layers as f64 * per_layer + mlm
    }

    /// Training FLOPs ≈ 3× forward (activation + weight gradient matmuls).
    pub fn train_flops_per_seq(&self, seq: usize, slots: usize) -> f64 {
        3.0 * self.fwd_flops_per_seq(seq, slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_large_param_count() {
        // published BERT-Large: ~340M (334M without pooler/NSP head)
        let p = BERT_LARGE.param_count();
        assert!((3.3e8..3.6e8).contains(&(p as f64)), "params = {p}");
    }

    #[test]
    fn bert_base_param_count() {
        let p = BERT_BASE.param_count();
        assert!((1.0e8..1.2e8).contains(&(p as f64)), "params = {p}");
    }

    #[test]
    fn flops_scale_superlinearly_with_seq() {
        // attention is quadratic in seq: 512 ≥ 4x the flops of 128
        let f128 = BERT_LARGE.fwd_flops_per_seq(128, 20);
        let f512 = BERT_LARGE.fwd_flops_per_seq(512, 76);
        assert!(f512 / f128 > 4.0, "ratio {}", f512 / f128);
        // sanity magnitude: ~100 GFLOP fwd per seq128 for BERT-Large
        assert!((5e10..5e11).contains(&f128), "f128 = {f128}");
    }
}
