//! Pure-rust reference optimizers over the flat-parameter/block-table view.
//!
//! These serve three roles:
//!  1. correctness cross-check against the AOT Pallas kernels (the
//!     integration test asserts LANS-native == LANS-HLO to float tolerance);
//!  2. the fast in-process update path for laptop-scale convergence
//!     experiments (no literal marshalling);
//!  3. the baselines the paper compares against (LAMB, AdamW, momentum SGD,
//!     NAG) in the ablation benches.
//!
//! Algorithms follow the paper text exactly — see
//! `python/compile/kernels/ref.py` for the line-by-line correspondence.

use crate::util::stats::Welford;

use super::blocks::BlockTable;

/// Numerical floor for block norms (matches kernels/common.py NORM_EPS).
pub const NORM_EPS: f32 = 1e-16;

/// Adam-family hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-6, weight_decay: 0.01 }
    }
}

/// Per-step diagnostics (divergence detection, trust-ratio telemetry).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// mean over blocks of phi(‖x‖)/‖update‖ trust ratios
    pub mean_trust_ratio: f64,
    /// max |param| after the step
    pub max_abs_param: f32,
    /// global gradient l2 norm (pre-normalization)
    pub grad_norm: f64,
}

pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// One update; `t` is maintained internally (1-based).
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats;

    fn blocks(&self) -> &BlockTable;
}

fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

// ---------------------------------------------------------------- LANS ----

/// Algorithm 2 — the paper's optimizer.
pub struct Lans {
    hp: Hyper,
    table: BlockTable,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    // cached full directions r̂+wd·x / ĉ+wd·x between the reduce and apply
    // passes — trades 2n scratch writes for recomputing 2 rsqrt-loops
    // (§Perf iteration 2: 700 → 389 ms at bert-base scale)
    r_full: Vec<f32>,
    c_full: Vec<f32>,
}

impl Lans {
    pub fn new(table: BlockTable, hp: Hyper) -> Lans {
        let n = table.total;
        Lans {
            hp,
            table,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            r_full: vec![0.0; n],
            c_full: vec![0.0; n],
        }
    }
}

/// Work item for the within-block parallel pass: disjoint mutable chunk
/// views over the six arrays (x, g, m, v, r_full, c_full).
struct LansChunk<'a> {
    x: &'a mut [f32],
    g: &'a [f32],
    m: &'a mut [f32],
    v: &'a mut [f32],
    rf: &'a mut [f32],
    cf: &'a mut [f32],
}

/// §Perf iteration 4: parallelize the per-block passes across CPU cores
/// (the rust analogue of apex multi-tensor-apply's thread blocks).  Reduce
/// pass returns per-chunk partial sums; apply pass is embarrassingly
/// parallel.  Correctness is untouched: f64 partial sums are combined in
/// chunk order, and chunking is deterministic.
fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Optimizer for Lans {
    fn name(&self) -> &'static str {
        "lans"
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let t = self.t as i32;
        let hp = self.hp;
        let bc1 = 1.0 - hp.beta1.powi(t);
        let bc2 = 1.0 - hp.beta2.powi(t);
        let mut stats = StepStats { grad_norm: l2(grads) as f64, ..Default::default() };
        let mut trust = Welford::default();

        // §Perf iteration 1: hoist 1/bc out of the loops and fold the
        // normalized-gradient pass into the moment pass (1605 → 700 ms at
        // bert-base scale); iteration 3: slice-zip loops so LLVM drops the
        // bounds checks and vectorizes (389 → 242 ms).
        let inv_bc1 = 1.0 / bc1;
        let inv_bc2 = 1.0 / bc2;
        let nthreads = num_threads();
        for b in &self.table.blocks {
            let r = b.offset..b.offset + b.len;
            let (x, g) = (&mut params[r.clone()], &grads[r.clone()]);
            let m = &mut self.m[r.clone()];
            let v = &mut self.v[r.clone()];
            let rf_s = &mut self.r_full[r.clone()];
            let cf_s = &mut self.c_full[r.clone()];
            let wd = if b.decay { hp.weight_decay } else { 0.0 };

            // eq. (4): block gradient normalization (folded into pass 1)
            let inv_gnorm = 1.0 / l2(g).max(NORM_EPS);

            // chunk the block across threads (≥64K elements per thread so
            // tiny blocks stay serial)
            let cs = (b.len / nthreads + 1).max(1 << 16);
            let chunks: Vec<LansChunk> = x
                .chunks_mut(cs)
                .zip(g.chunks(cs))
                .zip(m.chunks_mut(cs))
                .zip(v.chunks_mut(cs))
                .zip(rf_s.chunks_mut(cs).zip(cf_s.chunks_mut(cs)))
                .map(|((((x, g), m), v), (rf, cf))| LansChunk { x, g, m, v, rf, cf })
                .collect();

            // pass 1 — moments, full directions, and the three reductions
            // accumulate in f32 within 4K sub-chunks (vectorizable), combine
            // in f64 across sub-chunks — same accuracy class as pairwise
            // summation, lets LLVM keep the lane loop in f32
            const SUB: usize = 4096;
            let pass1 = |c: &mut LansChunk| -> (f64, f64, f64) {
                let (mut sx, mut sr, mut sc) = (0.0f64, 0.0f64, 0.0f64);
                let n = c.x.len();
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + SUB).min(n);
                    let (mut fx, mut fr, mut fc) = (0.0f32, 0.0f32, 0.0f32);
                    for ((((xi, gi), mi), vi), (rfi, cfi)) in c.x[lo..hi]
                        .iter()
                        .zip(c.g[lo..hi].iter())
                        .zip(c.m[lo..hi].iter_mut())
                        .zip(c.v[lo..hi].iter_mut())
                        .zip(c.rf[lo..hi].iter_mut().zip(c.cf[lo..hi].iter_mut()))
                    {
                        let gt = gi * inv_gnorm;
                        let mn = hp.beta1 * *mi + (1.0 - hp.beta1) * gt;
                        let vn = hp.beta2 * *vi + (1.0 - hp.beta2) * gt * gt;
                        *mi = mn;
                        *vi = vn;
                        let inv_denom = 1.0 / ((vn * inv_bc2).sqrt() + hp.eps);
                        let rf = mn * inv_bc1 * inv_denom + wd * xi;
                        let cf = gt * inv_denom + wd * xi;
                        *rfi = rf;
                        *cfi = cf;
                        fx += xi * xi;
                        fr += rf * rf;
                        fc += cf * cf;
                    }
                    sx += fx as f64;
                    sr += fr as f64;
                    sc += fc as f64;
                    lo = hi;
                }
                (sx, sr, sc)
            };
            let mut chunks = chunks;
            let partials: Vec<(f64, f64, f64)> = if chunks.len() == 1 {
                vec![pass1(&mut chunks[0])]
            } else {
                std::thread::scope(|s| {
                    let handles: Vec<_> = chunks
                        .iter_mut()
                        .map(|c| s.spawn(|| pass1(c)))
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
            };
            let (mut sum_x2, mut sum_r2, mut sum_c2) = (0.0f64, 0.0f64, 0.0f64);
            for (sx, sr, sc) in partials {
                sum_x2 += sx;
                sum_r2 += sr;
                sum_c2 += sc;
            }

            let x_norm = sum_x2.sqrt() as f32;
            let r_norm = (sum_r2.sqrt() as f32).max(NORM_EPS);
            let c_norm = (sum_c2.sqrt() as f32).max(NORM_EPS);
            let coef_r = lr * x_norm * hp.beta1 / r_norm;
            let coef_c = lr * x_norm * (1.0 - hp.beta1) / c_norm;
            trust.push((x_norm / r_norm) as f64);

            // pass 2 — apply from the cached directions (parallel)
            let pass2 = |c: &mut LansChunk| -> f32 {
                let mut max_abs = 0.0f32;
                for (xi, (rfi, cfi)) in
                    c.x.iter_mut().zip(c.rf.iter().zip(c.cf.iter()))
                {
                    *xi -= coef_r * rfi + coef_c * cfi;
                    max_abs = max_abs.max(xi.abs());
                }
                max_abs
            };
            let maxes: Vec<f32> = if chunks.len() == 1 {
                vec![pass2(&mut chunks[0])]
            } else {
                std::thread::scope(|s| {
                    let handles: Vec<_> = chunks
                        .iter_mut()
                        .map(|c| s.spawn(|| pass2(c)))
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
            };
            for ma in maxes {
                stats.max_abs_param = stats.max_abs_param.max(ma);
            }
        }
        stats.mean_trust_ratio = trust.mean();
        stats
    }
}

// ---------------------------------------------------------------- LAMB ----

/// Algorithm 1 — You et al.'s baseline.
pub struct Lamb {
    hp: Hyper,
    table: BlockTable,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    /// cached update direction between the reduce and apply passes (§Perf)
    u_full: Vec<f32>,
}

impl Lamb {
    pub fn new(table: BlockTable, hp: Hyper) -> Lamb {
        let n = table.total;
        Lamb { hp, table, m: vec![0.0; n], v: vec![0.0; n], t: 0, u_full: vec![0.0; n] }
    }
}

impl Optimizer for Lamb {
    fn name(&self) -> &'static str {
        "lamb"
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let t = self.t as i32;
        let hp = self.hp;
        let bc1 = 1.0 - hp.beta1.powi(t);
        let bc2 = 1.0 - hp.beta2.powi(t);
        let mut stats = StepStats { grad_norm: l2(grads) as f64, ..Default::default() };
        let mut trust = Welford::default();

        let inv_bc1 = 1.0 / bc1;
        let inv_bc2 = 1.0 / bc2;
        for b in &self.table.blocks {
            let r = b.offset..b.offset + b.len;
            let (x, g) = (&mut params[r.clone()], &grads[r.clone()]);
            let m = &mut self.m[r.clone()];
            let v = &mut self.v[r.clone()];
            let u_s = &mut self.u_full[r.clone()];
            let wd = if b.decay { hp.weight_decay } else { 0.0 };

            let mut sum_x2 = 0.0f64;
            let mut sum_u2 = 0.0f64;
            for ((((xi, gi), mi), vi), ui) in x
                .iter()
                .zip(g.iter())
                .zip(m.iter_mut())
                .zip(v.iter_mut())
                .zip(u_s.iter_mut())
            {
                let mn = hp.beta1 * *mi + (1.0 - hp.beta1) * gi;
                let vn = hp.beta2 * *vi + (1.0 - hp.beta2) * gi * gi;
                *mi = mn;
                *vi = vn;
                let u = mn * inv_bc1 / ((vn * inv_bc2).sqrt() + hp.eps) + wd * xi;
                *ui = u;
                sum_x2 += (*xi as f64) * (*xi as f64);
                sum_u2 += (u as f64) * (u as f64);
            }
            let x_norm = sum_x2.sqrt() as f32;
            let u_norm = (sum_u2.sqrt() as f32).max(NORM_EPS);
            let coef = lr * x_norm / u_norm;
            trust.push((x_norm / u_norm) as f64);

            let mut max_abs = 0.0f32;
            for (xi, ui) in x.iter_mut().zip(u_s.iter()) {
                *xi -= coef * ui;
                max_abs = max_abs.max(xi.abs());
            }
            stats.max_abs_param = stats.max_abs_param.max(max_abs);
        }
        stats.mean_trust_ratio = trust.mean();
        stats
    }
}

// --------------------------------------------------------------- AdamW ----

/// AdamW, optionally with the paper's blockwise gradient normalization.
pub struct AdamW {
    hp: Hyper,
    table: BlockTable,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    pub block_grad_norm: bool,
}

impl AdamW {
    pub fn new(table: BlockTable, hp: Hyper, block_grad_norm: bool) -> AdamW {
        let n = table.total;
        AdamW { hp, table, m: vec![0.0; n], v: vec![0.0; n], t: 0, block_grad_norm }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        if self.block_grad_norm {
            "adamw_bgn"
        } else {
            "adamw"
        }
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let t = self.t as i32;
        let hp = self.hp;
        let bc1 = 1.0 - hp.beta1.powi(t);
        let bc2 = 1.0 - hp.beta2.powi(t);
        let mut stats = StepStats { grad_norm: l2(grads) as f64, ..Default::default() };

        for b in &self.table.blocks {
            let r = b.offset..b.offset + b.len;
            let (x, g) = (&mut params[r.clone()], &grads[r.clone()]);
            let m = &mut self.m[r.clone()];
            let v = &mut self.v[r.clone()];
            let wd = if b.decay { hp.weight_decay } else { 0.0 };
            let inv_gnorm = if self.block_grad_norm {
                1.0 / l2(g).max(NORM_EPS)
            } else {
                1.0
            };

            let inv_bc1 = 1.0 / bc1;
            let inv_bc2 = 1.0 / bc2;
            let mut max_abs = 0.0f32;
            for (((xi, gi), mi), vi) in
                x.iter_mut().zip(g.iter()).zip(m.iter_mut()).zip(v.iter_mut())
            {
                let gn = gi * inv_gnorm;
                let mn = hp.beta1 * *mi + (1.0 - hp.beta1) * gn;
                let vn = hp.beta2 * *vi + (1.0 - hp.beta2) * gn * gn;
                *mi = mn;
                *vi = vn;
                let upd = mn * inv_bc1 / ((vn * inv_bc2).sqrt() + hp.eps) + wd * *xi;
                *xi -= lr * upd;
                max_abs = max_abs.max(xi.abs());
            }
            stats.max_abs_param = stats.max_abs_param.max(max_abs);
        }
        stats.mean_trust_ratio = 1.0;
        stats
    }
}

// ------------------------------------------------------- momentum SGD -----

/// Classic momentum (eq. 2–3) and Nesterov (NAG) — §2.2's building blocks,
/// used by the ablation benches.
pub struct MomentumSgd {
    table: BlockTable,
    m: Vec<f32>,
    pub mu: f32,
    pub nesterov: bool,
}

impl MomentumSgd {
    pub fn new(table: BlockTable, mu: f32, nesterov: bool) -> MomentumSgd {
        let n = table.total;
        MomentumSgd { table, m: vec![0.0; n], mu, nesterov }
    }
}

impl Optimizer for MomentumSgd {
    fn name(&self) -> &'static str {
        if self.nesterov {
            "nag"
        } else {
            "msgd"
        }
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        let mut stats = StepStats { grad_norm: l2(grads) as f64, ..Default::default() };
        let mut max_abs = 0.0f32;
        for i in 0..params.len() {
            // m_t = mu m_{t-1} + g_t
            self.m[i] = self.mu * self.m[i] + grads[i];
            let d = if self.nesterov {
                // x_{t+1} = x_t - lr (mu m_t + g_t)
                self.mu * self.m[i] + grads[i]
            } else {
                self.m[i]
            };
            params[i] -= lr * d;
            max_abs = max_abs.max(params[i].abs());
        }
        stats.max_abs_param = max_abs;
        stats.mean_trust_ratio = 1.0;
        stats
    }
}

/// Factory by name (CLI / config entry point).
pub fn make_optimizer(name: &str, table: BlockTable, hp: Hyper) -> Option<Box<dyn Optimizer>> {
    match name {
        "lans" => Some(Box::new(Lans::new(table, hp))),
        "lamb" => Some(Box::new(Lamb::new(table, hp))),
        "adamw" => Some(Box::new(AdamW::new(table, hp, false))),
        "adamw_bgn" => Some(Box::new(AdamW::new(table, hp, true))),
        "msgd" => Some(Box::new(MomentumSgd::new(table, 0.9, false))),
        "nag" => Some(Box::new(MomentumSgd::new(table, 0.9, true))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn table() -> BlockTable {
        BlockTable::new(&[("w".into(), 64, true), ("b".into(), 8, false)])
    }

    fn randvec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn lans_update_is_scale_invariant_in_gradient() {
        // blockwise normalization ⇒ multiplying g by any positive scalar per
        // block must not change the update at t=1
        let t = table();
        let mut rng = Rng::new(1);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let g_scaled: Vec<f32> = g.iter().map(|&v| v * 1000.0).collect();

        let mut o1 = Lans::new(t.clone(), Hyper::default());
        let mut o2 = Lans::new(t.clone(), Hyper::default());
        let mut x1 = x0.clone();
        let mut x2 = x0.clone();
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g_scaled, 0.01);
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn lamb_is_not_gradient_scale_invariant() {
        let t = table();
        let mut rng = Rng::new(2);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let g_scaled: Vec<f32> = g.iter().map(|&v| v * 1000.0).collect();
        let mut o1 = Lamb::new(t.clone(), Hyper::default());
        let mut o2 = Lamb::new(t.clone(), Hyper::default());
        let mut x1 = x0.clone();
        let mut x2 = x0.clone();
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g_scaled, 0.01);
        let diff: f32 = x1.iter().zip(&x2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "LAMB should depend on gradient scale via v_t");
    }

    #[test]
    fn update_norm_bounded_by_lr_times_xnorm() {
        // ‖Δx‖ per block ≤ lr·φ(‖x‖)·(β1 + (1-β1)) · (1+wd·...) ≈ lr·‖x‖:
        // the trust-ratio property the paper relies on for stability
        let t = table();
        let mut rng = Rng::new(3);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let mut o = Lans::new(t.clone(), Hyper { weight_decay: 0.0, ..Default::default() });
        let mut x = x0.clone();
        o.step(&mut x, &g, 0.01);
        for b in &t.blocks {
            let r = b.offset..b.offset + b.len;
            let dx: f32 = x[r.clone()]
                .iter()
                .zip(&x0[r.clone()])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let xn: f32 = x0[r].iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!(dx <= 0.01 * xn * 1.001 + 1e-7, "block {}: {dx} vs {}", b.name, 0.01 * xn);
        }
    }

    #[test]
    fn adamw_plain_reduces_simple_quadratic() {
        // minimize 0.5*x^2 — loss must drop monotonically-ish
        let t = BlockTable::new(&[("x".into(), 4, false)]);
        let mut o = AdamW::new(t, Hyper { weight_decay: 0.0, ..Default::default() }, false);
        let mut x = vec![1.0f32, -2.0, 3.0, -4.0];
        let f = |x: &[f32]| x.iter().map(|v| 0.5 * v * v).sum::<f32>();
        let f0 = f(&x);
        for _ in 0..200 {
            let g: Vec<f32> = x.to_vec();
            o.step(&mut x, &g, 0.05);
        }
        assert!(f(&x) < 0.05 * f0, "f went {f0} -> {}", f(&x));
    }

    #[test]
    fn nag_differs_from_classic() {
        let t = table();
        let mut rng = Rng::new(4);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let mut o1 = MomentumSgd::new(t.clone(), 0.9, false);
        let mut o2 = MomentumSgd::new(t.clone(), 0.9, true);
        let mut x1 = x0.clone();
        let mut x2 = x0;
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g, 0.01);
        assert_ne!(x1, x2);
    }

    #[test]
    fn factory_names() {
        let t = table();
        for n in ["lans", "lamb", "adamw", "adamw_bgn", "msgd", "nag"] {
            assert!(make_optimizer(n, t.clone(), Hyper::default()).is_some());
        }
        assert!(make_optimizer("sgdx", t, Hyper::default()).is_none());
    }
}
