//! Pure-rust reference optimizers over the flat-parameter/block-table view.
//!
//! These serve three roles:
//!  1. correctness cross-check against the AOT Pallas kernels (the
//!     integration test asserts LANS-native == LANS-HLO to float tolerance);
//!  2. the fast in-process update path for laptop-scale convergence
//!     experiments (no literal marshalling);
//!  3. the baselines the paper compares against (LAMB, AdamW, momentum SGD,
//!     NAG) in the ablation benches.
//!
//! Algorithms follow the paper text exactly — see
//! `python/compile/kernels/ref.py` for the line-by-line correspondence.
//!
//! Structure: each optimizer's update is factored into *per-block kernels*
//! (`lans_pass1_block`/`lans_pass2_block`, `lamb_pass1_block`/
//! `lamb_apply_block`, `adamw_block`).  The serial `Optimizer::step` loops
//! over blocks calling those kernels; `optim::parallel` runs the same
//! segment loops plan-concurrently on a [`ThreadPool`], so the two paths
//! are arithmetically identical by construction (the property tests
//! assert it).
//!
//! Canonical reduction order: every cross-element LANS/LAMB reduction
//! (block gradient norm, ‖x‖/‖r‖/‖c‖/‖u‖ — and AdamW's block grad²)
//! accumulates within [`NORM_SEG`]-element sub-chunks of a *block-local*
//! grid and combines the sub-chunk partials in f64, in order.  Within a
//! sub-chunk the fold runs on [`crate::simd`]'s 8-lane grid (element `i`
//! into lane `i % 8`, lanes combined sequentially at segment end) — the
//! order every backend of the runtime-dispatched kernels reproduces
//! bit-exactly.  The segment loops live in `grad_sq_segments` /
//! `lans_update_segments` / `lamb_update_segments` and are shared verbatim
//! by the serial path, the plan-granularity replicated path
//! (`optim::parallel`) and the sharded path (`optim::sharded`) — both of
//! which cut the flat vector only on the segment grid, which is what makes
//! all three bit-identical.

use crate::simd::{self, AdamK};
use crate::util::pool::ThreadPool;
use crate::util::stats::Welford;

use super::blocks::BlockTable;

/// Numerical floor for block norms (matches kernels/common.py NORM_EPS).
pub const NORM_EPS: f32 = 1e-16;

/// Width of the canonical norm-reduction segment.  Reductions accumulate
/// within `NORM_SEG`-element sub-chunks (f32 for the x/r/c norms — keeps
/// the lane loop vectorizable — and f64 for gradient norms) and combine
/// across sub-chunks in f64, in order, on a grid that restarts at every
/// block offset.  `optim::sharded::ShardPlan` aligns its shard boundaries
/// to this grid.
pub const NORM_SEG: usize = 4096;

/// Per-segment f64 partials of Σ g² over the block-local segment grid,
/// emitted in order via `sink`.  `g` must start on a segment boundary
/// (offset a multiple of [`NORM_SEG`] within its block).
pub(crate) fn grad_sq_segments(g: &[f32], mut sink: impl FnMut(f64)) {
    let mut lo = 0;
    while lo < g.len() {
        let hi = (lo + NORM_SEG).min(g.len());
        sink(simd::sum_sq(&g[lo..hi]));
        lo = hi;
    }
}

/// Per-segment f64 partials of Σ g² with the loss-scale unscale fused
/// into the same sweep: every element is multiplied by `inv_scale` in
/// place and the *unscaled* value is squared — one gradient pass serves
/// both the overflow probe and eq. 4's block norms.  Same segment grid
/// and fold order as [`grad_sq_segments`], so when `inv_scale` is the
/// exact inverse of a power-of-two loss scale the emitted partials are
/// bit-identical to the unscaled sweep's (the scale→unscale round trip is
/// exact in IEEE arithmetic).
pub(crate) fn unscale_grad_sq_segments(
    g: &mut [f32],
    inv_scale: f32,
    mut sink: impl FnMut(f64),
) {
    let mut lo = 0;
    while lo < g.len() {
        let hi = (lo + NORM_SEG).min(g.len());
        sink(simd::unscale_sum_sq(&mut g[lo..hi], inv_scale));
        lo = hi;
    }
}

/// Adam-family hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-6, weight_decay: 0.01 }
    }
}

/// Per-step diagnostics (divergence detection, trust-ratio telemetry).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// mean over blocks of phi(‖x‖)/‖update‖ trust ratios
    pub mean_trust_ratio: f64,
    /// max |param| after the step
    pub max_abs_param: f32,
    /// global gradient l2 norm (pre-normalization)
    pub grad_norm: f64,
}

pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// One update; `t` is maintained internally (1-based).
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats;

    /// Plan-sharded parallel update on `pool`.  The default falls back to
    /// the serial [`Optimizer::step`]; LANS/LAMB/AdamW override it with a
    /// plan-granularity concurrent path (the flat vector cut on the
    /// block-local [`NORM_SEG`] grid) that produces identical arithmetic
    /// (same segment kernels, same reduction order).
    fn step_parallel(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        let _ = pool;
        self.step(params, grads, lr)
    }

    /// Loss-scale-aware step: multiplies `grads` by `inv_scale` in place
    /// (the unscale, fused into the grad² sweep — see
    /// [`unscale_grad_sq_segments`]) and *skips* the update when the
    /// unscaled gradient contains inf/nan — parameters, moments and the
    /// bias-correction clock all untouched — returning `None` so the
    /// caller can back off the loss scale.  When `inv_scale` undoes an
    /// exact power-of-two scaling and no overflow occurs, the taken step
    /// is bit-identical to [`step_parallel`](Optimizer::step_parallel) on
    /// the unscaled gradient (property-tested in `tests/proptests.rs`).
    fn step_scaled(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &mut [f32],
        lr: f32,
        inv_scale: f32,
    ) -> Option<StepStats> {
        super::parallel::unscale_probe_pooled(pool, self.blocks(), grads, inv_scale)?;
        Some(self.step_parallel(pool, params, grads, lr))
    }

    /// Step with the per-block grad² already folded by the caller (the
    /// bucketed/overlapped replicated path computes it during its
    /// per-bucket unscale stages, in the canonical segment order).  The
    /// default discards it and runs [`step_parallel`] — exactly what the
    /// default [`step_scaled`](Optimizer::step_scaled) does with its
    /// probe's fold, so optimizers without an override (LAMB, SGD) stay
    /// bit-identical to the phase-synchronous path.  LANS and AdamW
    /// override it to feed the fold into their engines, mirroring their
    /// `step_scaled` overrides.
    fn step_prefolded(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        block_g2: Vec<f64>,
    ) -> StepStats {
        let _ = block_g2;
        self.step_parallel(pool, params, grads, lr)
    }

    fn blocks(&self) -> &BlockTable;
}

fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Per-step constants shared by every Adam-family block kernel: the bias
/// corrections are hoisted out of the element loops (§Perf iteration 1).
#[derive(Clone, Copy)]
pub(crate) struct AdamCtx {
    pub hp: Hyper,
    pub inv_bc1: f32,
    pub inv_bc2: f32,
    pub lr: f32,
}

impl AdamCtx {
    pub(crate) fn new(hp: Hyper, t: i32, lr: f32) -> AdamCtx {
        AdamCtx {
            hp,
            inv_bc1: 1.0 / (1.0 - hp.beta1.powi(t)),
            inv_bc2: 1.0 / (1.0 - hp.beta2.powi(t)),
            lr,
        }
    }

    /// Bundle the per-block factors with the per-step constants into the
    /// flat kernel-constant struct the [`crate::simd`] sweeps take.
    pub(crate) fn kernel(&self, wd: f32, inv_gnorm: f32) -> AdamK {
        AdamK {
            beta1: self.hp.beta1,
            beta2: self.hp.beta2,
            eps: self.hp.eps,
            inv_bc1: self.inv_bc1,
            inv_bc2: self.inv_bc2,
            lr: self.lr,
            wd,
            inv_gnorm,
        }
    }
}

// ---------------------------------------------------------------- LANS ----

/// Algorithm 2 — the paper's optimizer.
pub struct Lans {
    pub(crate) hp: Hyper,
    pub(crate) table: BlockTable,
    pub(crate) m: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) t: u64,
    // cached full directions r̂+wd·x / ĉ+wd·x between the reduce and apply
    // passes — trades 2n scratch writes for recomputing 2 rsqrt-loops
    // (§Perf iteration 2: 700 → 389 ms at bert-base scale)
    pub(crate) r_full: Vec<f32>,
    pub(crate) c_full: Vec<f32>,
}

impl Lans {
    pub fn new(table: BlockTable, hp: Hyper) -> Lans {
        let n = table.total;
        Lans {
            hp,
            table,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            r_full: vec![0.0; n],
            c_full: vec![0.0; n],
        }
    }
}

/// One block's mutable state for the LANS two-pass update: gradient view,
/// moments, cached directions, and the block's weight-decay factor.  The
/// slices are disjoint per block, which is what makes the parallel path
/// safe.
pub(crate) struct LansBlockMut<'a> {
    pub g: &'a [f32],
    pub m: &'a mut [f32],
    pub v: &'a mut [f32],
    pub rf: &'a mut [f32],
    pub cf: &'a mut [f32],
    pub wd: f32,
}

/// Pass-1 outputs for one block: the two apply coefficients, the trust
/// ratio, and the block's contribution to the global gradient norm.
pub(crate) struct LansCoef {
    pub coef_r: f32,
    pub coef_c: f32,
    pub trust: f64,
    pub grad_sq: f64,
}

/// LANS moment/direction update over a segment-aligned range of one block:
/// eq. (4) gradient normalization (via the precomputed `inv_gnorm`), moment
/// updates, cached full directions, and the (Σx², Σr², Σc²) partial of every
/// segment emitted in order via `sink`.
///
/// Reductions accumulate in f32 on [`crate::simd`]'s lane grid within
/// [`NORM_SEG`] sub-chunks and the caller combines the partials in f64 —
/// same accuracy class as pairwise summation, and the dispatched kernel
/// holds the grid in registers (§Perf iteration 3, vectorized by PR 8).
/// The serial path folds the partials directly; the sharded path collects
/// them per shard and folds after the exchange — same values, same order,
/// so the two are bit-identical.
pub(crate) fn lans_update_segments(
    cx: &AdamCtx,
    x: &[f32],
    b: &mut LansBlockMut<'_>,
    inv_gnorm: f32,
    mut sink: impl FnMut(f64, f64, f64),
) {
    let k = cx.kernel(b.wd, inv_gnorm);
    let n = x.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + NORM_SEG).min(n);
        let (fx, fr, fc) = simd::lans_segment(
            &k,
            &x[lo..hi],
            &b.g[lo..hi],
            &mut b.m[lo..hi],
            &mut b.v[lo..hi],
            &mut b.rf[lo..hi],
            &mut b.cf[lo..hi],
        );
        sink(fx, fr, fc);
        lo = hi;
    }
}

/// Block gradient norm → eq. (4) normalization factor.
pub(crate) fn lans_inv_gnorm(grad_sq: f64) -> f32 {
    1.0 / (grad_sq.sqrt() as f32).max(NORM_EPS)
}

/// Apply coefficients from the combined block norms — shared by every path
/// so the trust-ratio arithmetic has exactly one home.  That single home is
/// also the metrics seam: every serial/parallel/sharded step funnels each
/// block through here exactly once, so observing the per-block trust ratio
/// and gradient norm costs one relaxed load when the registry is off and
/// never perturbs the update arithmetic.
pub(crate) fn lans_coef(cx: &AdamCtx, sx: f64, sr: f64, sc: f64, grad_sq: f64) -> LansCoef {
    let hp = cx.hp;
    let x_norm = sx.sqrt() as f32;
    let r_norm = (sr.sqrt() as f32).max(NORM_EPS);
    let c_norm = (sc.sqrt() as f32).max(NORM_EPS);
    let trust = (x_norm / r_norm) as f64;
    if crate::metrics::registry::enabled() {
        crate::metrics::registry::TRUST_RATIO.observe(trust);
        crate::metrics::registry::BLOCK_GRAD_NORM.observe(grad_sq.sqrt());
    }
    LansCoef {
        coef_r: cx.lr * x_norm * hp.beta1 / r_norm,
        coef_c: cx.lr * x_norm * (1.0 - hp.beta1) / c_norm,
        trust,
        grad_sq,
    }
}

/// LANS pass 1 for one whole block: the composition of the canonical
/// segment reductions above.
pub(crate) fn lans_pass1_block(cx: &AdamCtx, x: &[f32], b: &mut LansBlockMut<'_>) -> LansCoef {
    let mut grad_sq = 0.0f64;
    grad_sq_segments(b.g, |p| grad_sq += p);
    let inv_gnorm = lans_inv_gnorm(grad_sq);
    let (mut sx, mut sr, mut sc) = (0.0f64, 0.0f64, 0.0f64);
    lans_update_segments(cx, x, b, inv_gnorm, |px, pr, pc| {
        sx += px;
        sr += pr;
        sc += pc;
    });
    lans_coef(cx, sx, sr, sc, grad_sq)
}

/// LANS pass 2 for one block: apply from the cached directions.  Returns
/// the block's max |param| after the step.
pub(crate) fn lans_pass2_block(
    coef_r: f32,
    coef_c: f32,
    x: &mut [f32],
    rf: &[f32],
    cf: &[f32],
) -> f32 {
    simd::lans_apply(coef_r, coef_c, x, rf, cf)
}

impl Optimizer for Lans {
    fn name(&self) -> &'static str {
        "lans"
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        let mut stats = StepStats::default();
        let mut trust = Welford::default();
        let mut grad_sq = 0.0f64;
        for blk in &self.table.blocks {
            let r = blk.offset..blk.offset + blk.len;
            let mut b = LansBlockMut {
                g: &grads[r.clone()],
                m: &mut self.m[r.clone()],
                v: &mut self.v[r.clone()],
                rf: &mut self.r_full[r.clone()],
                cf: &mut self.c_full[r.clone()],
                wd: if blk.decay { self.hp.weight_decay } else { 0.0 },
            };
            let c = lans_pass1_block(&cx, &params[r.clone()], &mut b);
            grad_sq += c.grad_sq;
            trust.push(c.trust);
            let ma = lans_pass2_block(c.coef_r, c.coef_c, &mut params[r], b.rf, b.cf);
            stats.max_abs_param = stats.max_abs_param.max(ma);
        }
        stats.grad_norm = grad_sq.sqrt();
        stats.mean_trust_ratio = trust.mean();
        stats
    }

    fn step_parallel(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        super::parallel::lans_step_parallel(self, pool, params, grads, lr)
    }

    /// LANS reuses the probe's block grad² as phase A of the segmented
    /// engine — the unscale sweep and eq. 4's norm pass are one gradient
    /// read, not two.
    fn step_scaled(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &mut [f32],
        lr: f32,
        inv_scale: f32,
    ) -> Option<StepStats> {
        let g2 = super::parallel::unscale_probe_pooled(pool, &self.table, grads, inv_scale)?;
        Some(super::parallel::lans_step_with_g2(self, pool, params, grads, lr, g2))
    }

    fn step_prefolded(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        block_g2: Vec<f64>,
    ) -> StepStats {
        super::parallel::lans_step_with_g2(self, pool, params, grads, lr, block_g2)
    }
}

// ---------------------------------------------------------------- LAMB ----

/// Algorithm 1 — You et al.'s baseline.
pub struct Lamb {
    pub(crate) hp: Hyper,
    pub(crate) table: BlockTable,
    pub(crate) m: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) t: u64,
    /// cached update direction between the reduce and apply passes (§Perf)
    pub(crate) u_full: Vec<f32>,
}

impl Lamb {
    pub fn new(table: BlockTable, hp: Hyper) -> Lamb {
        let n = table.total;
        Lamb { hp, table, m: vec![0.0; n], v: vec![0.0; n], t: 0, u_full: vec![0.0; n] }
    }
}

/// Pass-1 outputs for one LAMB block.
pub(crate) struct LambCoef {
    pub coef: f32,
    pub trust: f64,
    pub grad_sq: f64,
}

/// LAMB moment/direction update over a segment-aligned range of one block,
/// emitting the (Σx², Σu², Σg²) partial of every [`NORM_SEG`] segment in
/// order via `sink`.  Accumulation is per-element f64 on [`crate::simd`]'s
/// lane grid within a segment (LAMB's norms are not pre-normalized, so the
/// f64 lanes stay) and the caller combines partials in f64 — the canonical
/// order shared by the serial, block-parallel and sharded paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lamb_update_segments(
    cx: &AdamCtx,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
    wd: f32,
    mut sink: impl FnMut(f64, f64, f64),
) {
    let k = cx.kernel(wd, 1.0);
    let n = x.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + NORM_SEG).min(n);
        let (sx2, su2, sg2) = simd::lamb_segment(
            &k,
            &x[lo..hi],
            &g[lo..hi],
            &mut m[lo..hi],
            &mut v[lo..hi],
            &mut u[lo..hi],
        );
        sink(sx2, su2, sg2);
        lo = hi;
    }
}

/// Apply coefficient from the combined block norms.  Like [`lans_coef`],
/// the single home every path shares — and therefore the per-block
/// trust-ratio/grad-norm metrics seam.
pub(crate) fn lamb_coef(cx: &AdamCtx, sx2: f64, su2: f64, grad_sq: f64) -> LambCoef {
    let x_norm = sx2.sqrt() as f32;
    let u_norm = (su2.sqrt() as f32).max(NORM_EPS);
    let trust = (x_norm / u_norm) as f64;
    if crate::metrics::registry::enabled() {
        crate::metrics::registry::TRUST_RATIO.observe(trust);
        crate::metrics::registry::BLOCK_GRAD_NORM.observe(grad_sq.sqrt());
    }
    LambCoef { coef: cx.lr * x_norm / u_norm, trust, grad_sq }
}

/// LAMB pass 1 for one whole block: moments, cached update direction,
/// norms — the composition of the canonical segment reduction.
pub(crate) fn lamb_pass1_block(
    cx: &AdamCtx,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
    wd: f32,
) -> LambCoef {
    let (mut sx2, mut su2, mut sg2) = (0.0f64, 0.0f64, 0.0f64);
    lamb_update_segments(cx, x, g, m, v, u, wd, |px, pu, pg| {
        sx2 += px;
        su2 += pu;
        sg2 += pg;
    });
    lamb_coef(cx, sx2, su2, sg2)
}

/// LAMB apply for one block; returns the block's max |param|.
pub(crate) fn lamb_apply_block(coef: f32, x: &mut [f32], u: &[f32]) -> f32 {
    simd::axpy_max(coef, x, u)
}

impl Optimizer for Lamb {
    fn name(&self) -> &'static str {
        "lamb"
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        let mut stats = StepStats::default();
        let mut trust = Welford::default();
        let mut grad_sq = 0.0f64;
        for blk in &self.table.blocks {
            let r = blk.offset..blk.offset + blk.len;
            let wd = if blk.decay { self.hp.weight_decay } else { 0.0 };
            let c = lamb_pass1_block(
                &cx,
                &params[r.clone()],
                &grads[r.clone()],
                &mut self.m[r.clone()],
                &mut self.v[r.clone()],
                &mut self.u_full[r.clone()],
                wd,
            );
            grad_sq += c.grad_sq;
            trust.push(c.trust);
            let ma = lamb_apply_block(c.coef, &mut params[r.clone()], &self.u_full[r]);
            stats.max_abs_param = stats.max_abs_param.max(ma);
        }
        stats.grad_norm = grad_sq.sqrt();
        stats.mean_trust_ratio = trust.mean();
        stats
    }

    fn step_parallel(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        super::parallel::lamb_step_parallel(self, pool, params, grads, lr)
    }
}

// --------------------------------------------------------------- AdamW ----

/// AdamW, optionally with the paper's blockwise gradient normalization.
pub struct AdamW {
    pub(crate) hp: Hyper,
    pub(crate) table: BlockTable,
    pub(crate) m: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) t: u64,
    pub block_grad_norm: bool,
}

impl AdamW {
    pub fn new(table: BlockTable, hp: Hyper, block_grad_norm: bool) -> AdamW {
        let n = table.total;
        AdamW { hp, table, m: vec![0.0; n], v: vec![0.0; n], t: 0, block_grad_norm }
    }
}

/// AdamW element-wise update over any range of one block, given the
/// block's precomputed eq. 4 normalization factor (`1.0` when blockwise
/// gradient normalization is off).  Returns the range's max |param|.
/// There is no cross-element reduction here, so any cut of a block —
/// including the plan-granularity executor's mid-block chunks — produces
/// identical bits.
pub(crate) fn adamw_apply(
    cx: &AdamCtx,
    inv_gnorm: f32,
    wd: f32,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) -> f32 {
    let k = cx.kernel(wd, inv_gnorm);
    simd::adamw_segment(&k, x, g, m, v)
}

/// AdamW single-pass block update; returns (max |param|, block grad²).
/// The block grad² uses the canonical segmented fold ([`grad_sq_segments`])
/// so the serial path and the plan-granularity parallel path are
/// bit-identical.
pub(crate) fn adamw_block(
    cx: &AdamCtx,
    block_grad_norm: bool,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    wd: f32,
) -> (f32, f64) {
    let mut grad_sq = 0.0f64;
    grad_sq_segments(g, |p| grad_sq += p);
    let inv_gnorm = if block_grad_norm { lans_inv_gnorm(grad_sq) } else { 1.0 };
    (adamw_apply(cx, inv_gnorm, wd, x, g, m, v), grad_sq)
}

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        if self.block_grad_norm {
            "adamw_bgn"
        } else {
            "adamw"
        }
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        let mut stats = StepStats::default();
        let mut grad_sq = 0.0f64;
        for blk in &self.table.blocks {
            let r = blk.offset..blk.offset + blk.len;
            let wd = if blk.decay { self.hp.weight_decay } else { 0.0 };
            let (ma, gs) = adamw_block(
                &cx,
                self.block_grad_norm,
                &mut params[r.clone()],
                &grads[r.clone()],
                &mut self.m[r.clone()],
                &mut self.v[r],
                wd,
            );
            stats.max_abs_param = stats.max_abs_param.max(ma);
            grad_sq += gs;
        }
        stats.grad_norm = grad_sq.sqrt();
        stats.mean_trust_ratio = 1.0;
        stats
    }

    fn step_parallel(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        super::parallel::adamw_step_parallel(self, pool, params, grads, lr)
    }

    /// AdamW reuses the probe's block grad² (eq. 4 normalization for the
    /// bgn variant, the grad-norm stat otherwise) instead of re-sweeping
    /// the gradient.
    fn step_scaled(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &mut [f32],
        lr: f32,
        inv_scale: f32,
    ) -> Option<StepStats> {
        let g2 = super::parallel::unscale_probe_pooled(pool, &self.table, grads, inv_scale)?;
        Some(super::parallel::adamw_step_parallel_g2(
            self,
            pool,
            params,
            grads,
            lr,
            Some(g2),
        ))
    }

    fn step_prefolded(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
        block_g2: Vec<f64>,
    ) -> StepStats {
        super::parallel::adamw_step_parallel_g2(self, pool, params, grads, lr, Some(block_g2))
    }
}

// ------------------------------------------------------- momentum SGD -----

/// Classic momentum (eq. 2–3) and Nesterov (NAG) — §2.2's building blocks,
/// used by the ablation benches.  Stays serial: its update is a single
/// bandwidth-bound pass with no per-block reductions to shard.
pub struct MomentumSgd {
    table: BlockTable,
    m: Vec<f32>,
    pub mu: f32,
    pub nesterov: bool,
}

impl MomentumSgd {
    pub fn new(table: BlockTable, mu: f32, nesterov: bool) -> MomentumSgd {
        let n = table.total;
        MomentumSgd { table, m: vec![0.0; n], mu, nesterov }
    }
}

impl Optimizer for MomentumSgd {
    fn name(&self) -> &'static str {
        if self.nesterov {
            "nag"
        } else {
            "msgd"
        }
    }

    fn blocks(&self) -> &BlockTable {
        &self.table
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) -> StepStats {
        let mut stats = StepStats { grad_norm: l2(grads) as f64, ..Default::default() };
        let mut max_abs = 0.0f32;
        for ((xi, gi), mi) in params.iter_mut().zip(grads.iter()).zip(self.m.iter_mut()) {
            // m_t = mu m_{t-1} + g_t
            *mi = self.mu * *mi + gi;
            let d = if self.nesterov {
                // x_{t+1} = x_t - lr (mu m_t + g_t)
                self.mu * *mi + gi
            } else {
                *mi
            };
            *xi -= lr * d;
            max_abs = max_abs.max(xi.abs());
        }
        stats.max_abs_param = max_abs;
        stats.mean_trust_ratio = 1.0;
        stats
    }
}

/// Factory by name (CLI / config entry point).
pub fn make_optimizer(name: &str, table: BlockTable, hp: Hyper) -> Option<Box<dyn Optimizer>> {
    match name {
        "lans" => Some(Box::new(Lans::new(table, hp))),
        "lamb" => Some(Box::new(Lamb::new(table, hp))),
        "adamw" => Some(Box::new(AdamW::new(table, hp, false))),
        "adamw_bgn" => Some(Box::new(AdamW::new(table, hp, true))),
        "msgd" => Some(Box::new(MomentumSgd::new(table, 0.9, false))),
        "nag" => Some(Box::new(MomentumSgd::new(table, 0.9, true))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn table() -> BlockTable {
        BlockTable::new(&[("w".into(), 64, true), ("b".into(), 8, false)])
    }

    fn randvec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn lans_update_is_scale_invariant_in_gradient() {
        // blockwise normalization ⇒ multiplying g by any positive scalar per
        // block must not change the update at t=1
        let t = table();
        let mut rng = Rng::new(1);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let g_scaled: Vec<f32> = g.iter().map(|&v| v * 1000.0).collect();

        let mut o1 = Lans::new(t.clone(), Hyper::default());
        let mut o2 = Lans::new(t.clone(), Hyper::default());
        let mut x1 = x0.clone();
        let mut x2 = x0.clone();
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g_scaled, 0.01);
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn lamb_is_not_gradient_scale_invariant() {
        let t = table();
        let mut rng = Rng::new(2);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let g_scaled: Vec<f32> = g.iter().map(|&v| v * 1000.0).collect();
        let mut o1 = Lamb::new(t.clone(), Hyper::default());
        let mut o2 = Lamb::new(t.clone(), Hyper::default());
        let mut x1 = x0.clone();
        let mut x2 = x0.clone();
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g_scaled, 0.01);
        let diff: f32 = x1.iter().zip(&x2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "LAMB should depend on gradient scale via v_t");
    }

    #[test]
    fn update_norm_bounded_by_lr_times_xnorm() {
        // ‖Δx‖ per block ≤ lr·φ(‖x‖)·(β1 + (1-β1)) · (1+wd·...) ≈ lr·‖x‖:
        // the trust-ratio property the paper relies on for stability
        let t = table();
        let mut rng = Rng::new(3);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let mut o = Lans::new(t.clone(), Hyper { weight_decay: 0.0, ..Default::default() });
        let mut x = x0.clone();
        o.step(&mut x, &g, 0.01);
        for b in &t.blocks {
            let r = b.offset..b.offset + b.len;
            let dx: f32 = x[r.clone()]
                .iter()
                .zip(&x0[r.clone()])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let xn: f32 = x0[r].iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!(dx <= 0.01 * xn * 1.001 + 1e-7, "block {}: {dx} vs {}", b.name, 0.01 * xn);
        }
    }

    #[test]
    fn adamw_plain_reduces_simple_quadratic() {
        // minimize 0.5*x^2 — loss must drop monotonically-ish
        let t = BlockTable::new(&[("x".into(), 4, false)]);
        let mut o = AdamW::new(t, Hyper { weight_decay: 0.0, ..Default::default() }, false);
        let mut x = vec![1.0f32, -2.0, 3.0, -4.0];
        let f = |x: &[f32]| x.iter().map(|v| 0.5 * v * v).sum::<f32>();
        let f0 = f(&x);
        for _ in 0..200 {
            let g: Vec<f32> = x.to_vec();
            o.step(&mut x, &g, 0.05);
        }
        assert!(f(&x) < 0.05 * f0, "f went {f0} -> {}", f(&x));
    }

    #[test]
    fn nag_differs_from_classic() {
        let t = table();
        let mut rng = Rng::new(4);
        let x0 = randvec(t.total, &mut rng);
        let g = randvec(t.total, &mut rng);
        let mut o1 = MomentumSgd::new(t.clone(), 0.9, false);
        let mut o2 = MomentumSgd::new(t.clone(), 0.9, true);
        let mut x1 = x0.clone();
        let mut x2 = x0;
        o1.step(&mut x1, &g, 0.01);
        o2.step(&mut x2, &g, 0.01);
        assert_ne!(x1, x2);
    }

    #[test]
    fn factory_names() {
        let t = table();
        for n in ["lans", "lamb", "adamw", "adamw_bgn", "msgd", "nag"] {
            assert!(make_optimizer(n, t.clone(), Hyper::default()).is_some());
        }
        assert!(make_optimizer("sgdx", t, Hyper::default()).is_none());
    }
}
