//! Sharded-optimizer subsystem (ZeRO-1 style): partitioned LANS/LAMB state
//! + the reduce-scatter / shard-update / all-gather step.
//!
//! Every worker in the replicated path allreduces the full gradient and
//! runs the full optimizer update over all parameters — per-worker update
//! compute and moment memory are both O(n) regardless of scale.  This
//! module partitions both across the `W` data-parallel workers (the
//! multi-node cost lever of Lin et al., 2020, applied to the blockwise
//! updates of You et al., 2019): gradients are ring-reduce-scattered, each
//! worker updates only its owned shard holding moments only for that shard
//! (O(n/W) each), and the updated parameters are all-gathered.
//!
//! **Bit-identity.**  The sharded trajectory is bit-for-bit identical to
//! the replicated one (property-tested in `tests/proptests.rs`), by three
//! constructions:
//!
//! 1. Gradients are reduce-scattered on the ring's own chunk grid — the
//!    summation order per element is exactly `ring_allreduce`'s — and
//!    [`scatter_to_plan`] restitches the owned ranges from the chunk
//!    owners (pure copies + the same mean scaling).
//! 2. [`ShardPlan`] cuts the flat vector only on the block-local
//!    [`NORM_SEG`] grid, so every norm-reduction segment is computed whole
//!    by exactly one worker, with the same kernels
//!    (`optim::native::*_update_segments`) the serial path runs.
//! 3. Block norms combine from per-segment partials in global segment
//!    order — per-shard partial vectors concatenated in shard order — the
//!    two-phase hierarchical reduction the serial kernels also use.
//!
//! In-process, "communication" is slice copies and the parameter
//! all-gather is a no-op (workers share one flat vector); the *schedule*
//! is the real one and `collective::cost` prices it for the time model.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::collective::reduce_scatter::{chunk_owner, ring_chunk_starts};
use crate::runtime::tensor::TensorF32;
use crate::trace;
use crate::util::pool::{policy, ThreadPool};
use crate::util::stats::Welford;

use super::blocks::BlockTable;
use super::native::{
    grad_sq_segments, lamb_apply_block, lamb_coef, lamb_update_segments, lans_coef,
    lans_inv_gnorm, lans_pass2_block, lans_update_segments, AdamCtx, Hyper, LansBlockMut,
    StepStats, NORM_SEG,
};

/// A contiguous piece of one block owned by one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fragment {
    /// index into `BlockTable::blocks`
    pub block: usize,
    /// global offset in the flat vector
    pub start: usize,
    pub len: usize,
}

/// Deterministic fixed-width partition of the flat parameter vector across
/// `W` shards, cutting *through* blocks: the ideal boundaries `s·n/W` are
/// snapped to the nearest block-local [`NORM_SEG`] grid point (block starts
/// and ends are always grid points), which keeps every norm-reduction
/// segment wholly inside one shard — the alignment bit-identity rests on.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// shard boundaries in the flat vector; `starts.len() == workers + 1`
    pub starts: Vec<usize>,
    frags: Vec<Vec<Fragment>>,
}

impl ShardPlan {
    /// Shard boundaries snap to multiples of this width within each block
    /// (= [`NORM_SEG`], the canonical norm-reduction segment).
    pub const ALIGN: usize = NORM_SEG;

    pub fn build(table: &BlockTable, workers: usize) -> ShardPlan {
        assert!(workers > 0, "no workers");
        let n = table.total;
        let points = Self::grid_points(table);

        let mut starts = Vec::with_capacity(workers + 1);
        starts.push(0usize);
        for s in 1..workers {
            let ideal = s * n / workers;
            // nearest candidate; ties to the lower one — deterministic
            let i = points.partition_point(|&p| p < ideal);
            let lower = if i > 0 { Some(points[i - 1]) } else { None };
            let upper = points.get(i).copied();
            let cut = match (lower, upper) {
                (Some(a), Some(b)) => {
                    if ideal - a <= b - ideal {
                        a
                    } else {
                        b
                    }
                }
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => 0,
            };
            let prev = *starts.last().unwrap();
            starts.push(cut.max(prev));
        }
        starts.push(n);

        let frags = (0..workers)
            .map(|s| Self::fragments_for(table, starts[s], starts[s + 1]))
            .collect();
        ShardPlan { starts, frags }
    }

    /// Candidate cut points of the block-local [`Self::ALIGN`] grid —
    /// block starts, in-block grid multiples and block ends — shared by
    /// [`Self::build`] and [`Self::bucket_starts`] so shard and bucket
    /// boundaries snap to one grid and no norm segment is ever split.
    fn grid_points(table: &BlockTable) -> Vec<usize> {
        let mut points: Vec<usize> = vec![0];
        for b in &table.blocks {
            let end = b.offset + b.len;
            let mut p = b.offset + Self::ALIGN;
            while p < end {
                points.push(p);
                p += Self::ALIGN;
            }
            if end > *points.last().unwrap() {
                points.push(end);
            }
        }
        points
    }

    /// Bucket boundaries for the DAG-overlapped step: a partition of
    /// `[0, total)` on the same block-local [`Self::ALIGN`] grid shard
    /// boundaries use, greedily cutting at the first grid point at least
    /// `target_elems` past the previous cut (the last bucket takes the
    /// remainder).  `target_elems == 0` — overlap off — or at least the
    /// table yields the single full-vector bucket.
    pub fn bucket_starts(table: &BlockTable, target_elems: usize) -> Vec<usize> {
        let n = table.total;
        if target_elems == 0 || target_elems >= n {
            return vec![0, n];
        }
        let mut out = vec![0usize];
        for p in Self::grid_points(table) {
            if p < n && p - out.last().unwrap() >= target_elems {
                out.push(p);
            }
        }
        out.push(n);
        out
    }

    /// The degenerate block-granularity plan: one shard per block — the
    /// work grid the pre-plan `ParallelExecutor` used.  Its speedup is
    /// capped by the largest block (BERT's word embedding is ~20% of all
    /// parameters); kept only so the `optimizer_step` bench can measure
    /// what the balanced grid removes.
    pub fn per_block(table: &BlockTable) -> ShardPlan {
        let mut starts = Vec::with_capacity(table.blocks.len() + 1);
        starts.push(0usize);
        for b in &table.blocks {
            starts.push(b.offset + b.len);
        }
        let frags = (0..table.blocks.len())
            .map(|s| Self::fragments_for(table, starts[s], starts[s + 1]))
            .collect();
        ShardPlan { starts, frags }
    }

    fn fragments_for(table: &BlockTable, lo: usize, hi: usize) -> Vec<Fragment> {
        let mut out = Vec::new();
        for (bi, b) in table.blocks.iter().enumerate() {
            let s = lo.max(b.offset);
            let e = hi.min(b.offset + b.len);
            if s < e {
                debug_assert_eq!((s - b.offset) % Self::ALIGN, 0);
                out.push(Fragment { block: bi, start: s, len: e - s });
            }
        }
        out
    }

    pub fn workers(&self) -> usize {
        self.starts.len() - 1
    }

    pub fn total(&self) -> usize {
        *self.starts.last().unwrap()
    }

    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        self.starts[s]..self.starts[s + 1]
    }

    pub fn len_of(&self, s: usize) -> usize {
        self.starts[s + 1] - self.starts[s]
    }

    pub fn fragments(&self, s: usize) -> &[Fragment] {
        &self.frags[s]
    }

    /// Slice a full flat vector into per-shard owned copies (tests/benches).
    pub fn split(&self, full: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(full.len(), self.total());
        (0..self.workers()).map(|s| full[self.range(s)].to_vec()).collect()
    }
}

/// Split a flat vector into per-shard disjoint mutable slices on `plan`
/// boundaries (a chain of `split_at_mut` — shards tile the vector in
/// order).  The plan-granularity replicated executor builds its task
/// slices with this.
pub(crate) fn split_at_plan<'a>(plan: &ShardPlan, mut data: &'a mut [f32]) -> Vec<&'a mut [f32]> {
    assert_eq!(data.len(), plan.total(), "flat vector does not match plan");
    let w = plan.workers();
    let mut out = Vec::with_capacity(w);
    for s in 0..w {
        let (head, tail) = data.split_at_mut(plan.len_of(s));
        out.push(head);
        data = tail;
    }
    out
}

/// Assemble each shard's owned slice of the *mean* gradient from
/// reduce-scattered per-worker buffers: chunk `c` of the default ring grid
/// holds its full sum at worker [`chunk_owner`]`(c, w)`; every plan range
/// is stitched from the owning chunks and scaled by `scale`.  Because the
/// chunk sums are exactly what `ring_all_gather` would have broadcast, the
/// result is bit-identical to `ring_allreduce` + element-wise scaling.
pub fn scatter_to_plan(bufs: &[Vec<f32>], plan: &ShardPlan, scale: f32) -> Vec<Vec<f32>> {
    let w = bufs.len();
    assert_eq!(w, plan.workers(), "buffer count != plan worker count");
    let n = plan.total();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    let ring = ring_chunk_starts(w, n);
    (0..w)
        .map(|s| {
            let (lo, hi) = (plan.starts[s], plan.starts[s + 1]);
            let mut out = vec![0.0f32; hi - lo];
            stitch_range(bufs, &ring, lo, hi, scale, &mut out);
            out
        })
        .collect()
}

/// Stitch `[lo, hi)` of the mean gradient from reduce-scattered buffers
/// into `out`: each ring chunk's piece is copied from its [`chunk_owner`]
/// and scaled.  The one home for the stitch arithmetic — [`scatter_to_plan`]
/// and the pipelined [`ShardedOptimizer::step_scattered`] both use it, so
/// the two paths cannot drift.
pub(crate) fn stitch_range(
    bufs: &[Vec<f32>],
    ring: &[usize],
    lo: usize,
    hi: usize,
    scale: f32,
    out: &mut [f32],
) {
    let views: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    stitch_range_views(&views, 0, ring, lo, hi, scale, out);
}

/// [`stitch_range`] reading from per-worker bucket views instead of whole
/// buffers: `views[i]` is worker `i`'s slice of the global element range
/// `[view_lo, ...)`, and the stitched range `[lo, hi)` must fall inside
/// it.  The DAG-overlapped step hands each bucket's pre-carved views to
/// its stitch stage so communication of another bucket can run
/// concurrently on the same underlying buffers.
pub(crate) fn stitch_range_views(
    views: &[&[f32]],
    view_lo: usize,
    ring: &[usize],
    lo: usize,
    hi: usize,
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), hi - lo);
    let w = views.len();
    let mut cursor = 0usize;
    for c in 0..w {
        let (clo, chi) = (ring[c].max(lo), ring[c + 1].min(hi));
        if clo < chi {
            let owner = chunk_owner(c, w);
            for (o, &x) in out[cursor..cursor + (chi - clo)]
                .iter_mut()
                .zip(&views[owner][clo - view_lo..chi - view_lo])
            {
                *o = x * scale;
            }
            cursor += chi - clo;
        }
    }
    debug_assert_eq!(cursor, hi - lo, "ring chunks must cover the stitched range");
}

/// Which update rule a segmented step runs.  AdamW/SGD are element-wise
/// and gain nothing from norm sharding — the plan-granularity replicated
/// executor covers AdamW with a simpler two-phase path of its own.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Algo {
    Lans,
    Lamb,
}

/// One worker's slice of optimizer state: first/second moments plus the
/// cached update directions, all of length `plan.len_of(s)` — the O(n/W)
/// per-worker footprint that is the point of the subsystem.
struct ShardState {
    m: Vec<f32>,
    v: Vec<f32>,
    /// cached r̂+wd·x (LANS) / update direction u (LAMB)
    dir_a: Vec<f32>,
    /// cached ĉ+wd·x (LANS; unused by LAMB)
    dir_b: Vec<f32>,
    /// stitched mean-gradient scratch for the pipelined
    /// [`ShardedOptimizer::step_scattered`] path (empty until first use;
    /// never persisted)
    grad: Vec<f32>,
}

/// Per-block apply coefficients after the norm combine.
struct BlockCoef {
    a: f32,
    b: f32,
    trust: f64,
    grad_sq: f64,
}

/// One executor task: a contiguous, segment-aligned chunk of the flat
/// vector with every per-element array the update needs, plus the
/// fragments mapping it back onto blocks.  Two callers build these:
/// the sharded step (one task per worker shard, state owned per shard)
/// and the plan-granularity replicated executor in `optim::parallel`
/// (one task per plan chunk, state sliced from the full vectors) — both
/// then run the same [`segmented_step`] engine, which is what makes
/// replicated == parallel == sharded bit-identical by construction.
pub(crate) struct SegTask<'a> {
    pub x: &'a mut [f32],
    pub g: &'a [f32],
    pub m: &'a mut [f32],
    pub v: &'a mut [f32],
    /// cached r̂+wd·x (LANS) / update direction u (LAMB)
    pub dir_a: &'a mut [f32],
    /// cached ĉ+wd·x (LANS; unused and may be empty for LAMB)
    pub dir_b: &'a mut [f32],
    pub frags: &'a [Fragment],
    /// global offset of the task's first element
    pub base: usize,
    /// accumulated wall time across phases (the `sharded_step` bench
    /// reads the per-shard values)
    pub secs: f64,
}

/// One [`SegTask`] per worker shard, splitting `params` on the plan and
/// borrowing each shard's state fields.  `shard_grads` selects the
/// gradient source: `Some` for externally stitched per-shard slices (the
/// two-stage path), `None` for each shard's own `grad` scratch (the
/// pipelined path) — the only difference between the two call sites.
fn build_shard_tasks<'a>(
    plan: &'a ShardPlan,
    shards: &'a mut [ShardState],
    params: &'a mut [f32],
    shard_grads: Option<&'a [Vec<f32>]>,
) -> Vec<SegTask<'a>> {
    let mut tasks = Vec::with_capacity(shards.len());
    let mut rest = params;
    for (s, st) in shards.iter_mut().enumerate() {
        let (x, tail) = rest.split_at_mut(plan.len_of(s));
        rest = tail;
        let ShardState { m, v, dir_a, dir_b, grad } = st;
        let g: &[f32] = match shard_grads {
            Some(gs) => &gs[s],
            None => grad.as_slice(),
        };
        tasks.push(SegTask {
            x,
            g,
            m: m.as_mut_slice(),
            v: v.as_mut_slice(),
            dir_a: dir_a.as_mut_slice(),
            dir_b: dir_b.as_mut_slice(),
            frags: plan.fragments(s),
            base: plan.starts[s],
            secs: 0.0,
        });
    }
    tasks
}

/// Per-fragment grad² segment partials for one chunk, emitted in fragment
/// then segment order.  `g` is the chunk's gradient slice, `base` its
/// global offset.  The one home for this sweep — phase A, the pipelined
/// stitch, and both AdamW branches all call it, so the fold the
/// bit-identity contract depends on cannot fork.
pub(crate) fn frag_grad_sq_parts(
    g: &[f32],
    base: usize,
    frags: &[Fragment],
) -> Vec<(usize, Vec<f64>)> {
    let mut out = Vec::with_capacity(frags.len());
    for f in frags {
        let lo = f.start - base;
        let mut ps = Vec::new();
        grad_sq_segments(&g[lo..lo + f.len], |p| ps.push(p));
        out.push((f.block, ps));
    }
    out
}

/// Combine per-chunk partial lists into per-block grad² sums, in task
/// order = global segment order — the serial kernels' own f64 fold.
pub(crate) fn combine_block_g2(nb: usize, parts: &[Vec<(usize, Vec<f64>)>]) -> Vec<f64> {
    let mut g2 = vec![0.0f64; nb];
    for chunk_out in parts {
        for (b, ps) in chunk_out {
            for p in ps {
                g2[*b] += p;
            }
        }
    }
    g2
}

/// The three-phase segmented LANS/LAMB step over disjoint plan chunks:
/// (A) grad² segment partials → block gradient norms (skipped when the
/// caller pre-folded them, or for LAMB, whose grad² falls out of phase
/// B); (B) moments + cached directions + ‖x‖/‖r‖/‖c‖ segment partials →
/// per-block coefficients; (C) apply.  Each phase is one pool region;
/// partials combine in task order = global segment order — the serial
/// kernels' own hierarchical fold — so the result is bit-identical to
/// the serial `Optimizer::step` for any chunk grid cut on the
/// block-local [`NORM_SEG`](super::native::NORM_SEG) boundaries.
pub(crate) fn segmented_step(
    algo: Algo,
    cx: &AdamCtx,
    hp: Hyper,
    table: &BlockTable,
    pool: &ThreadPool,
    tasks: &mut [SegTask<'_>],
    precomputed_g2: Option<Vec<f64>>,
) -> StepStats {
    let nb = table.blocks.len();

    // --- phase A (LANS): per-chunk grad² segment partials → block
    //     gradient norms (eq. 4 needs them before the moment pass) ---
    let block_g2: Vec<f64> = match (algo, precomputed_g2) {
        (_, Some(g2)) => {
            debug_assert_eq!(g2.len(), nb);
            g2
        }
        (Algo::Lamb, None) => vec![0.0f64; nb],
        (Algo::Lans, None) => {
            let _sp = trace::span(trace::CAT_COMPUTE, "optim_grad_sq");
            let parts = pool.map_mut(&mut *tasks, |t| {
                let t0 = Instant::now();
                let out = frag_grad_sq_parts(t.g, t.base, t.frags);
                t.secs += t0.elapsed().as_secs_f64();
                out
            });
            combine_block_g2(nb, &parts)
        }
    };
    let inv_gnorm: Vec<f32> = block_g2.iter().map(|&g2| lans_inv_gnorm(g2)).collect();

    // --- phase B: moments + cached directions + norm partials ---
    let sp_b = trace::span(trace::CAT_COMPUTE, "optim_moments");
    let parts = pool.map_mut(&mut *tasks, |t| {
        let t0 = Instant::now();
        let mut out: Vec<(usize, Vec<(f64, f64, f64)>)> = Vec::with_capacity(t.frags.len());
        for f in t.frags {
            let lo = f.start - t.base;
            let hi = lo + f.len;
            let wd = if table.blocks[f.block].decay { hp.weight_decay } else { 0.0 };
            let mut ps = Vec::new();
            match algo {
                Algo::Lans => {
                    let mut blk = LansBlockMut {
                        g: &t.g[lo..hi],
                        m: &mut t.m[lo..hi],
                        v: &mut t.v[lo..hi],
                        rf: &mut t.dir_a[lo..hi],
                        cf: &mut t.dir_b[lo..hi],
                        wd,
                    };
                    lans_update_segments(
                        cx,
                        &t.x[lo..hi],
                        &mut blk,
                        inv_gnorm[f.block],
                        |px, pr, pc| ps.push((px, pr, pc)),
                    );
                }
                Algo::Lamb => lamb_update_segments(
                    cx,
                    &t.x[lo..hi],
                    &t.g[lo..hi],
                    &mut t.m[lo..hi],
                    &mut t.v[lo..hi],
                    &mut t.dir_a[lo..hi],
                    wd,
                    |px, pu, pg| ps.push((px, pu, pg)),
                ),
            }
            out.push((f.block, ps));
        }
        t.secs += t0.elapsed().as_secs_f64();
        out
    });

    // combine the three norm partials per block, in segment order
    let mut sums = vec![(0.0f64, 0.0f64, 0.0f64); nb];
    for chunk_out in &parts {
        for (b, ps) in chunk_out {
            let acc = &mut sums[*b];
            for (p0, p1, p2) in ps {
                acc.0 += p0;
                acc.1 += p1;
                acc.2 += p2;
            }
        }
    }
    let coefs: Vec<BlockCoef> = sums
        .iter()
        .enumerate()
        .map(|(b, &(s0, s1, s2))| match algo {
            Algo::Lans => {
                let c = lans_coef(cx, s0, s1, s2, block_g2[b]);
                BlockCoef { a: c.coef_r, b: c.coef_c, trust: c.trust, grad_sq: c.grad_sq }
            }
            Algo::Lamb => {
                let c = lamb_coef(cx, s0, s1, s2);
                BlockCoef { a: c.coef, b: 0.0, trust: c.trust, grad_sq: c.grad_sq }
            }
        })
        .collect();
    drop(sp_b);

    // --- phase C: apply from the cached directions ---
    let sp_c = trace::span(trace::CAT_COMPUTE, "optim_apply");
    let maxes = pool.map_mut(&mut *tasks, |t| {
        let t0 = Instant::now();
        let mut mx = 0.0f32;
        for f in t.frags {
            let lo = f.start - t.base;
            let hi = lo + f.len;
            let c = &coefs[f.block];
            let ma = match algo {
                Algo::Lans => lans_pass2_block(
                    c.a,
                    c.b,
                    &mut t.x[lo..hi],
                    &t.dir_a[lo..hi],
                    &t.dir_b[lo..hi],
                ),
                Algo::Lamb => lamb_apply_block(c.a, &mut t.x[lo..hi], &t.dir_a[lo..hi]),
            };
            mx = mx.max(ma);
        }
        t.secs += t0.elapsed().as_secs_f64();
        mx
    });
    drop(sp_c);

    // stats fold in block order — the serial loop's order
    let mut trust = Welford::default();
    let mut grad_sq = 0.0f64;
    for c in &coefs {
        trust.push(c.trust);
        grad_sq += c.grad_sq;
    }
    StepStats {
        mean_trust_ratio: trust.mean(),
        max_abs_param: maxes.iter().copied().fold(0.0f32, f32::max),
        grad_norm: grad_sq.sqrt(),
    }
}

/// Partitioned LANS/LAMB over all `W` in-process shards.  [`step`] runs the
/// full W-shard update (each shard touching only its own moments and
/// parameter range) and is bit-identical to the replicated serial
/// `Optimizer::step` on the same mean gradient.
///
/// [`step`]: ShardedOptimizer::step
pub struct ShardedOptimizer {
    algo: Algo,
    hp: Hyper,
    table: BlockTable,
    plan: ShardPlan,
    shards: Vec<ShardState>,
    t: u64,
}

impl ShardedOptimizer {
    /// Factory by optimizer name; `None` for algorithms without a sharded
    /// update (adamw/msgd/nag — element-wise, nothing to shard).
    pub fn from_name(
        name: &str,
        table: BlockTable,
        hp: Hyper,
        workers: usize,
    ) -> Option<ShardedOptimizer> {
        let algo = match name {
            "lans" => Algo::Lans,
            "lamb" => Algo::Lamb,
            _ => return None,
        };
        let plan = ShardPlan::build(&table, workers);
        let shards = (0..workers)
            .map(|s| {
                let n = plan.len_of(s);
                ShardState {
                    m: vec![0.0; n],
                    v: vec![0.0; n],
                    dir_a: vec![0.0; n],
                    dir_b: if algo == Algo::Lans { vec![0.0; n] } else { Vec::new() },
                    grad: Vec::new(),
                }
            })
            .collect();
        Some(ShardedOptimizer { algo, hp, table, plan, shards, t: 0 })
    }

    pub fn name(&self) -> &'static str {
        match self.algo {
            Algo::Lans => "lans",
            Algo::Lamb => "lamb",
        }
    }

    pub fn workers(&self) -> usize {
        self.plan.workers()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn blocks(&self) -> &BlockTable {
        &self.table
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// One update at learning rate `lr`.  `shard_grads[s]` is the *mean*
    /// gradient over shard `s`'s plan range (see [`scatter_to_plan`]);
    /// `params` is the replicated flat vector every in-process worker
    /// shares (a wire implementation would all-gather the owned ranges
    /// after this returns).
    pub fn step(&mut self, params: &mut [f32], shard_grads: &[Vec<f32>], lr: f32) -> StepStats {
        self.step_impl(&ThreadPool::new(1), params, shard_grads, lr).0
    }

    /// [`step`](Self::step) with the per-shard phases run concurrently on
    /// `pool` (shards touch disjoint state by construction; the norm
    /// combines are the barriers).  Falls back to the serial path for
    /// width-1 pools or when per-shard work is below
    /// [`POOLED_MIN_ELEMS`](crate::util::pool::policy::POOLED_MIN_ELEMS)
    /// (region overhead would dominate), mirroring the pooled
    /// collectives.  Bit-identical either way.
    pub fn step_pooled(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        shard_grads: &[Vec<f32>],
        lr: f32,
    ) -> StepStats {
        let w = self.plan.workers().max(1);
        let per_shard = self.table.total / w;
        if pool.threads() <= 1 || w < 2 || per_shard < policy::POOLED_MIN_ELEMS {
            return self.step(params, shard_grads, lr);
        }
        self.step_impl(pool, params, shard_grads, lr).0
    }

    /// Serial [`step`](Self::step) that also reports each shard's own wall
    /// time in seconds — what one worker of a W-wide deployment would
    /// spend updating (the `sharded_step` bench plots the max).
    pub fn step_timed(
        &mut self,
        params: &mut [f32],
        shard_grads: &[Vec<f32>],
        lr: f32,
    ) -> (StepStats, Vec<f64>) {
        self.step_impl(&ThreadPool::new(1), params, shard_grads, lr)
    }

    fn step_impl(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        shard_grads: &[Vec<f32>],
        lr: f32,
    ) -> (StepStats, Vec<f64>) {
        let w = self.plan.workers();
        assert_eq!(shard_grads.len(), w, "need one gradient slice per shard");
        assert_eq!(params.len(), self.table.total, "params do not match block table");
        for s in 0..w {
            assert_eq!(shard_grads[s].len(), self.plan.len_of(s), "shard {s} grad length");
        }
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        let mut tasks =
            build_shard_tasks(&self.plan, &mut self.shards, params, Some(shard_grads));
        let stats =
            segmented_step(self.algo, &cx, self.hp, &self.table, pool, &mut tasks, None);
        let timings = tasks.iter().map(|t| t.secs).collect();
        (stats, timings)
    }

    /// The pipelined ZeRO-1 step the trainer runs: takes the
    /// *reduce-scattered* per-worker buffers directly (chunk `c`'s
    /// gradient sum sitting at its [`chunk_owner`]) and fuses the
    /// [`scatter_to_plan`] stitch with phase A into one pool region —
    /// each shard's task stitches its owned mean-gradient range into a
    /// per-shard scratch buffer and folds the grad² segment partials
    /// while the data is cache-hot, instead of a serial full-vector
    /// stitch on the caller followed by a separate phase-A region
    /// barriered on the full scatter.  Bit-identical to
    /// `scatter_to_plan` + [`step_pooled`](Self::step_pooled): the
    /// stitch shares its arithmetic via `stitch_range` and the partial
    /// folds are unchanged (property-tested in `tests/proptests.rs`).
    pub fn step_scattered(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        bufs: &[Vec<f32>],
        scale: f32,
        lr: f32,
    ) -> StepStats {
        self.step_scattered_impl(pool, params, bufs, scale, lr, false)
            .expect("unprobed step_scattered never skips")
    }

    /// Loss-scale-aware [`step_scattered`](Self::step_scattered): `scale`
    /// folds the mean factor *and* the loss-scale unscale (both exact for
    /// power-of-two loss scales), and the fused stitch region doubles as
    /// the overflow probe — the grad² segment partials it already emits
    /// are checked for inf/nan before any shard state is touched.  On
    /// overflow the step is skipped (moments, parameters and the
    /// bias-correction clock untouched) and `None` is returned so the
    /// trainer can back off the loss scale.
    pub fn step_scattered_scaled(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        bufs: &[Vec<f32>],
        scale: f32,
        lr: f32,
    ) -> Option<StepStats> {
        self.step_scattered_impl(pool, params, bufs, scale, lr, true)
    }

    fn step_scattered_impl(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        bufs: &[Vec<f32>],
        scale: f32,
        lr: f32,
        probe: bool,
    ) -> Option<StepStats> {
        let w = self.plan.workers();
        assert_eq!(bufs.len(), w, "need one reduce-scattered buffer per shard");
        let n = self.table.total;
        assert_eq!(params.len(), n, "params do not match block table");
        assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
        let algo = self.algo;
        let table = &self.table;
        let plan = &self.plan;
        let ring = ring_chunk_starts(w, n);

        // below the policy floor (or width-1 pools) regions degrade to
        // serial caller loops; route through a width-1 pool so small work
        // never pays region wakeups — results identical either way
        let serial = ThreadPool::new(1);
        let eff = if pool.threads() <= 1 || w < 2 || n / w < policy::POOLED_MIN_ELEMS {
            &serial
        } else {
            pool
        };

        // --- fused stitch + phase A: one region over shards ---
        struct StitchTask<'a> {
            grad: &'a mut Vec<f32>,
            frags: &'a [Fragment],
            lo: usize,
            hi: usize,
        }
        // LANS needs the block grad² for eq. 4; the probe needs it for
        // overflow detection (LAMB included — its moments would otherwise
        // already be polluted by the time phase B surfaces the inf)
        let needs_g2 = probe || algo == Algo::Lans;
        let mut stitch: Vec<StitchTask<'_>> = self
            .shards
            .iter_mut()
            .enumerate()
            .map(|(s, st)| StitchTask {
                grad: &mut st.grad,
                frags: plan.fragments(s),
                lo: plan.starts[s],
                hi: plan.starts[s + 1],
            })
            .collect();
        let sp = trace::span(trace::CAT_COMPUTE, "stitch_probe");
        let parts = eff.map_mut(&mut stitch, |t| {
            t.grad.resize(t.hi - t.lo, 0.0);
            stitch_range(bufs, &ring, t.lo, t.hi, scale, t.grad);
            if !needs_g2 {
                return Vec::new();
            }
            frag_grad_sq_parts(t.grad, t.lo, t.frags)
        });
        drop(stitch);
        drop(sp);
        let g2 = if needs_g2 {
            Some(combine_block_g2(table.blocks.len(), &parts))
        } else {
            None
        };
        if probe {
            let finite =
                g2.as_ref().is_some_and(|v| v.iter().all(|x| x.is_finite()));
            if !finite {
                return None;
            }
        }

        // the step clock advances only once the step is certain to run
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        // LAMB's coefficients never read block grad² — hand the engine
        // exactly what the unprobed path would (None), keeping the two
        // call sites bit-identical by construction
        let precomputed = if algo == Algo::Lans { g2 } else { None };

        // --- phases B/C on the stitched scratch gradients ---
        let mut tasks = build_shard_tasks(&self.plan, &mut self.shards, params, None);
        Some(segmented_step(algo, &cx, self.hp, table, eff, &mut tasks, precomputed))
    }

    /// Whether the bucketed step's stitch stages must emit grad² partials:
    /// LANS reads them in phase A, and a probed (loss-scaled) step needs
    /// them for overflow detection — mirrors
    /// [`step_scattered`](Self::step_scattered)'s fused region.
    pub(crate) fn bucketed_needs_g2(&self, probe: bool) -> bool {
        probe || self.algo == Algo::Lans
    }

    /// Size every shard's stitched-gradient scratch for a bucketed step
    /// (the per-bucket [`Self::stitch_bucket`] calls then fill disjoint
    /// ranges of it).
    pub(crate) fn begin_bucketed(&mut self) {
        let plan = &self.plan;
        for (s, st) in self.shards.iter_mut().enumerate() {
            st.grad.resize(plan.len_of(s), 0.0);
        }
    }

    /// Stitch bucket `[lo, hi)` of the mean gradient into every shard's
    /// scratch (at the shard-local offset) from the bucket's
    /// reduce-scattered per-worker views, and return each shard's grad²
    /// segment partials for its bucket-clipped fragments (empty unless
    /// `needs_g2`).  Bucket cuts sit on the [`ShardPlan::ALIGN`] grid, so
    /// every clipped fragment still starts on a segment boundary inside
    /// its block: concatenating one shard's partials over buckets in
    /// order reproduces [`frag_grad_sq_parts`] over its full fragment
    /// list exactly — the fold [`Self::apply_bucketed`] relies on.
    pub(crate) fn stitch_bucket(
        &mut self,
        views: &[&[f32]],
        ring: &[usize],
        lo: usize,
        hi: usize,
        scale: f32,
        needs_g2: bool,
    ) -> Vec<Vec<(usize, Vec<f64>)>> {
        let _sp = trace::span_detail(trace::CAT_COMPUTE, "stitch_bucket", lo as u64);
        let plan = &self.plan;
        self.shards
            .iter_mut()
            .enumerate()
            .map(|(s, st)| {
                let (plo, phi) = (plan.starts[s].max(lo), plan.starts[s + 1].min(hi));
                if plo >= phi {
                    return Vec::new();
                }
                let base = plan.starts[s];
                debug_assert_eq!(st.grad.len(), plan.len_of(s), "begin_bucketed not called");
                stitch_range_views(
                    views,
                    lo,
                    ring,
                    plo,
                    phi,
                    scale,
                    &mut st.grad[plo - base..phi - base],
                );
                if !needs_g2 {
                    return Vec::new();
                }
                let clipped: Vec<Fragment> = plan
                    .fragments(s)
                    .iter()
                    .filter_map(|f| {
                        let flo = f.start.max(plo);
                        let fhi = (f.start + f.len).min(phi);
                        (flo < fhi)
                            .then_some(Fragment { block: f.block, start: flo, len: fhi - flo })
                    })
                    .collect();
                frag_grad_sq_parts(&st.grad, base, &clipped)
            })
            .collect()
    }

    /// Finish a bucketed step once every bucket is communicated and
    /// stitched: fold the per-bucket grad² partials in shard-major,
    /// bucket-minor order (= global segment order, the phase-synchronous
    /// fold), probe for overflow if requested (returning `None` *before*
    /// any shard state or the bias-correction clock is touched — buckets
    /// already communicated leave no trace in the moments), then run
    /// phases B/C on the assembled scratch gradients.  Bit-identical to
    /// [`step_scattered`](Self::step_scattered)/`_scaled` on the same
    /// buffers by construction.
    pub(crate) fn apply_bucketed(
        &mut self,
        pool: &ThreadPool,
        params: &mut [f32],
        lr: f32,
        probe: bool,
        parts_per_bucket: &[Vec<Vec<(usize, Vec<f64>)>>],
    ) -> Option<StepStats> {
        let w = self.plan.workers();
        let n = self.table.total;
        assert_eq!(params.len(), n, "params do not match block table");
        let algo = self.algo;
        let g2 = self.bucketed_needs_g2(probe).then(|| {
            let mut g2 = vec![0.0f64; self.table.blocks.len()];
            for s in 0..w {
                for bucket in parts_per_bucket {
                    for (b, ps) in &bucket[s] {
                        for p in ps {
                            g2[*b] += p;
                        }
                    }
                }
            }
            g2
        });
        if probe {
            let finite = g2.as_ref().is_some_and(|v| v.iter().all(|x| x.is_finite()));
            if !finite {
                return None;
            }
        }
        self.t += 1;
        let cx = AdamCtx::new(self.hp, self.t as i32, lr);
        let precomputed = if algo == Algo::Lans { g2 } else { None };
        let serial = ThreadPool::new(1);
        let eff = if pool.threads() <= 1 || w < 2 || n / w < policy::POOLED_MIN_ELEMS {
            &serial
        } else {
            pool
        };
        let mut tasks = build_shard_tasks(&self.plan, &mut self.shards, params, None);
        Some(segmented_step(algo, &cx, self.hp, &self.table, eff, &mut tasks, precomputed))
    }

    /// Serialize per-shard moments as named tensors (`optshard:m:<s>` /
    /// `optshard:v:<s>`) for embedding in a [`Checkpoint`].  Cached
    /// directions are scratch and are not persisted.
    pub fn export_state(&self) -> Vec<(String, TensorF32)> {
        let mut out = Vec::with_capacity(2 * self.shards.len());
        for (s, st) in self.shards.iter().enumerate() {
            out.push((
                format!("optshard:m:{s}"),
                TensorF32::new(vec![st.m.len()], st.m.clone()),
            ));
            out.push((
                format!("optshard:v:{s}"),
                TensorF32::new(vec![st.v.len()], st.v.clone()),
            ));
        }
        out
    }

    /// Restore moments from checkpoint tensors, resharding automatically:
    /// the saved shards (any worker count) are concatenated back into the
    /// flat moment vectors and re-sliced on *this* optimizer's plan, so a
    /// W=4 checkpoint restores into W=2 or W=8 with a bit-identical
    /// continued trajectory.  `step` becomes the bias-correction clock.
    pub fn import_state(&mut self, step: u64, tensors: &[(String, TensorF32)]) -> Result<()> {
        let mut ms: Vec<Option<&TensorF32>> = Vec::new();
        let mut vs: Vec<Option<&TensorF32>> = Vec::new();
        for (name, t) in tensors {
            let Some(rest) = name.strip_prefix("optshard:") else { continue };
            let Some((kind, idx)) = rest.split_once(':') else { continue };
            let idx: usize = idx
                .parse()
                .with_context(|| format!("bad shard index in tensor {name:?}"))?;
            let slot = match kind {
                "m" => &mut ms,
                "v" => &mut vs,
                _ => bail!("unknown sharded state tensor {name:?}"),
            };
            if slot.len() <= idx {
                slot.resize(idx + 1, None);
            }
            slot[idx] = Some(t);
        }
        if ms.is_empty() && vs.is_empty() {
            bail!("checkpoint has no sharded optimizer state (optshard:* tensors)");
        }
        if ms.len() != vs.len() {
            bail!(
                "sharded optimizer state is inconsistent: {} m-shards vs {} v-shards",
                ms.len(),
                vs.len()
            );
        }
        let concat = |parts: &[Option<&TensorF32>], kind: &str| -> Result<Vec<f32>> {
            let mut flat = Vec::new();
            for (i, &p) in parts.iter().enumerate() {
                let t = p.ok_or_else(|| {
                    anyhow::anyhow!(
                        "sharded optimizer state shard {i} is missing its {kind} tensor"
                    )
                })?;
                flat.extend_from_slice(&t.data);
            }
            Ok(flat)
        };
        let flat_m = concat(&ms, "m")?;
        let flat_v = concat(&vs, "v")?;
        if flat_m.len() != self.table.total || flat_v.len() != self.table.total {
            bail!(
                "sharded optimizer state has {} elements, the model's block table wants {}",
                flat_m.len(),
                self.table.total
            );
        }
        for (s, st) in self.shards.iter_mut().enumerate() {
            let r = self.plan.range(s);
            st.m.copy_from_slice(&flat_m[r.clone()]);
            st.v.copy_from_slice(&flat_v[r]);
            for d in st.dir_a.iter_mut() {
                *d = 0.0;
            }
            for d in st.dir_b.iter_mut() {
                *d = 0.0;
            }
        }
        self.t = step;
        Ok(())
    }

    /// Save the optimizer state alone as a checkpoint file.
    pub fn save_state(&self, path: &Path) -> Result<()> {
        Checkpoint::new(self.t, self.export_state())
            .save(path)
            .with_context(|| format!("saving sharded optimizer state to {}", path.display()))
    }

    /// Restore from a file written by [`save_state`](Self::save_state) (or
    /// a trainer checkpoint that embeds the state), resharding as needed.
    pub fn restore_state(&mut self, path: &Path) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        self.import_state(ck.step, &ck.tensors)
            .with_context(|| format!("restoring sharded optimizer state from {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{make_optimizer, Optimizer};
    use crate::util::rng::Rng;

    fn big_table() -> BlockTable {
        // straddles NORM_SEG several times + tiny no-decay blocks, like BERT
        BlockTable::new(&[
            ("emb".into(), 9000, true),
            ("k1".into(), 4096, true),
            ("b1".into(), 17, false),
            ("k2".into(), 6000, true),
            ("ln".into(), 1, false),
        ])
    }

    #[test]
    fn plan_boundaries_are_grid_aligned_and_cover() {
        let t = big_table();
        for w in [1, 2, 3, 4, 8, 32] {
            let plan = ShardPlan::build(&t, w);
            assert_eq!(plan.workers(), w);
            assert_eq!(plan.starts[0], 0);
            assert_eq!(plan.total(), t.total);
            assert!(plan.starts.windows(2).all(|p| p[0] <= p[1]));
            for s in 0..w {
                for f in plan.fragments(s) {
                    let b = &t.blocks[f.block];
                    assert_eq!((f.start - b.offset) % ShardPlan::ALIGN, 0);
                    assert!(f.start + f.len <= b.offset + b.len);
                }
            }
            // fragments tile [0, n)
            let mut covered = 0;
            let mut cursor = 0;
            for s in 0..w {
                for f in plan.fragments(s) {
                    assert_eq!(f.start, cursor, "w={w}");
                    cursor += f.len;
                    covered += f.len;
                }
            }
            assert_eq!(covered, t.total, "w={w}");
        }
    }

    #[test]
    fn plan_snaps_to_nearest_grid_point() {
        // one 10000-block: W=2 ideal cut 5000 → grid {0, 4096, 8192, 10000};
        // nearest is 4096
        let t = BlockTable::new(&[("w".into(), 10000, true)]);
        let plan = ShardPlan::build(&t, 2);
        assert_eq!(plan.starts, vec![0, 4096, 10000]);
    }

    #[test]
    fn more_workers_than_grid_points_leaves_empty_shards() {
        let t = BlockTable::new(&[("a".into(), 5, true), ("b".into(), 3, false)]);
        let plan = ShardPlan::build(&t, 6);
        assert_eq!(plan.total(), 8);
        let occupied: usize = (0..6).filter(|&s| plan.len_of(s) > 0).count();
        assert!(occupied <= 3); // only block boundaries are cut points
        let covered: usize = (0..6).map(|s| plan.len_of(s)).sum();
        assert_eq!(covered, 8);
    }

    #[test]
    fn sharded_step_matches_replicated_serial() {
        let table = big_table();
        let mut rng = Rng::new(11);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        for name in ["lans", "lamb"] {
            for w in [1, 2, 3, 5] {
                let hp = Hyper::default();
                let mut rep = make_optimizer(name, table.clone(), hp).unwrap();
                let mut sh = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
                let mut xr = x0.clone();
                let mut xs = x0.clone();
                for k in 0..3 {
                    let g: Vec<f32> =
                        (0..table.total).map(|_| rng.normal_f32()).collect();
                    let lr = 0.01 + 0.003 * k as f32;
                    let sr = rep.step(&mut xr, &g, lr);
                    let sg = sh.plan().split(&g);
                    let ss = sh.step(&mut xs, &sg, lr);
                    assert_eq!(sr.grad_norm, ss.grad_norm, "{name} w={w}");
                    assert_eq!(sr.mean_trust_ratio, ss.mean_trust_ratio, "{name} w={w}");
                    assert_eq!(sr.max_abs_param, ss.max_abs_param, "{name} w={w}");
                }
                assert_eq!(xr, xs, "{name} w={w}: params diverged");
            }
        }
    }

    #[test]
    fn per_block_plan_is_one_shard_per_block() {
        let t = big_table();
        let plan = ShardPlan::per_block(&t);
        assert_eq!(plan.workers(), t.blocks.len());
        assert_eq!(plan.total(), t.total);
        for (s, b) in t.blocks.iter().enumerate() {
            assert_eq!(plan.range(s), b.offset..b.offset + b.len);
            assert_eq!(plan.fragments(s).len(), 1);
            assert_eq!(plan.fragments(s)[0].block, s);
        }
    }

    #[test]
    fn scattered_step_matches_scatter_then_step() {
        // the pipelined path (fused stitch + phase A) against the
        // two-stage reference, from identical reduce-scattered buffers
        use crate::collective::reduce_scatter::ring_reduce_scatter;
        let table = big_table();
        let mut rng = Rng::new(21);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let pool = ThreadPool::new(4);
        for name in ["lans", "lamb"] {
            let w = 4;
            let hp = Hyper::default();
            let mut a = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut b = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xa = x0.clone();
            let mut xb = x0.clone();
            for k in 0..2 {
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                    .collect();
                let mut rs = bufs;
                ring_reduce_scatter(&mut rs);
                let scale = 1.0 / w as f32;
                let lr = 0.01 + 0.001 * k as f32;
                let sg = scatter_to_plan(&rs, a.plan(), scale);
                let sa = a.step(&mut xa, &sg, lr);
                let sb = b.step_scattered(&pool, &mut xb, &rs, scale, lr);
                assert_eq!(sa.grad_norm, sb.grad_norm, "{name}");
                assert_eq!(sa.mean_trust_ratio, sb.mean_trust_ratio, "{name}");
                assert_eq!(sa.max_abs_param, sb.max_abs_param, "{name}");
            }
            assert_eq!(xa, xb, "{name}: pipelined trajectory diverged");
        }
    }

    #[test]
    fn scattered_step_accepts_hierarchical_reduce_scatter() {
        // the topology-aware (tiered-ring) reduce-scatter keeps the flat
        // ring's postcondition — same chunk owners, and at fp32 tiers the
        // same bits — so the pipelined ZeRO-1 step consumes its buffers
        // unchanged: flat and 2x2 trajectories are exact-bit equal, and a
        // half inter tier composes through the probed path with serial ==
        // pooled bit-identity
        use crate::collective::hierarchical::hierarchical_reduce_scatter;
        use crate::collective::reduce_scatter::ring_reduce_scatter;
        use crate::precision::DType;
        use crate::topology::{TierPrecision, Topology};

        let table = big_table();
        let mut rng = Rng::new(41);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let pool = ThreadPool::new(4);
        let (w, hp) = (4usize, Hyper::default());
        let topo = Topology::grid(2, 2);
        for name in ["lans", "lamb"] {
            let mut flat = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut hier = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xf = x0.clone();
            let mut xh = x0.clone();
            for k in 0..2 {
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                    .collect();
                let mut rs_flat = bufs.clone();
                let mut rs_hier = bufs;
                ring_reduce_scatter(&mut rs_flat);
                let wire =
                    hierarchical_reduce_scatter(&mut rs_hier, &topo, TierPrecision::fp32());
                assert_eq!(rs_flat, rs_hier, "{name}: fp32 tiers must not change bits");
                assert!(wire.inter > 0 && wire.intra > 0, "{name}: both tiers executed");
                let scale = 1.0 / w as f32;
                let lr = 0.01 + 0.002 * k as f32;
                let sf = flat.step_scattered(&pool, &mut xf, &rs_flat, scale, lr);
                let sh = hier.step_scattered(&pool, &mut xh, &rs_hier, scale, lr);
                assert_eq!(sf.grad_norm, sh.grad_norm, "{name}");
            }
            assert_eq!(xf, xh, "{name}: hierarchical-fed trajectory diverged");

            // bf16 inter tier: the sharded+mixed-precision composition the
            // trainer runs.  The tiered reduce-scatter is deterministic,
            // and two optimizers with identical state walk identical
            // trajectories on its buffers, serial pool vs wide pool.
            let bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            let prec = TierPrecision::half_inter(DType::Bf16);
            let mut rs_a = bufs.clone();
            let mut rs_b = bufs;
            hierarchical_reduce_scatter(&mut rs_a, &topo, prec);
            hierarchical_reduce_scatter(&mut rs_b, &topo, prec);
            assert_eq!(rs_a, rs_b, "{name}: half tier must be deterministic");
            // twin optimizer with hier's exact state (resharded import)
            let mut twin = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            twin.import_state(hier.steps_taken(), &hier.export_state()).unwrap();
            let mut xa = xh.clone();
            let mut xb = xh.clone();
            let serial = ThreadPool::new(1);
            let sa = hier
                .step_scattered_scaled(&serial, &mut xa, &rs_a, 1.0 / w as f32, 0.01)
                .expect("finite gradients");
            let sb = twin
                .step_scattered_scaled(&pool, &mut xb, &rs_b, 1.0 / w as f32, 0.01)
                .expect("finite gradients");
            assert_eq!(sa.grad_norm, sb.grad_norm, "{name}: bf16 serial vs pooled");
            assert_eq!(xa, xb, "{name}: bf16-fed step diverged serial vs pooled");
            assert!(xa.iter().all(|v| v.is_finite()), "{name}: non-finite params");
        }
    }

    #[test]
    fn scattered_scaled_matches_unprobed_and_skips_on_overflow() {
        use crate::collective::reduce_scatter::ring_reduce_scatter;
        let table = big_table();
        let mut rng = Rng::new(31);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let pool = ThreadPool::new(3);
        let (w, hp) = (3usize, Hyper::default());
        for name in ["lans", "lamb"] {
            let mut a = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut b = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xa = x0.clone();
            let mut xb = x0.clone();
            let bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut rs = bufs;
            ring_reduce_scatter(&mut rs);
            let scale = 1.0 / w as f32;
            // probe on finite gradients: identical to the unprobed step
            let sa = a.step_scattered(&pool, &mut xa, &rs, scale, 0.01);
            let sb = b.step_scattered_scaled(&pool, &mut xb, &rs, scale, 0.01).unwrap();
            assert_eq!(sa.grad_norm, sb.grad_norm, "{name}");
            assert_eq!(xa, xb, "{name}: probed step diverged");
            // poisoned buffer: skip, no state change, clock untouched.
            // position 17 sits in ring chunk 0, so the NaN must live in
            // that chunk's owner buffer — the only one the stitch reads
            let mut bad = rs.clone();
            bad[chunk_owner(0, w)][17] = f32::NAN;
            let t_before = b.steps_taken();
            assert!(b.step_scattered_scaled(&pool, &mut xb, &bad, scale, 0.01).is_none());
            assert_eq!(xa, xb, "{name}: skipped step touched params");
            assert_eq!(t_before, b.steps_taken(), "{name}: skip advanced the clock");
            // both continue identically afterwards
            let sa = a.step_scattered(&pool, &mut xa, &rs, scale, 0.02);
            let sb = b.step_scattered_scaled(&pool, &mut xb, &rs, scale, 0.02).unwrap();
            assert_eq!(sa.max_abs_param, sb.max_abs_param, "{name}");
            assert_eq!(xa, xb, "{name}: post-skip trajectory diverged");
        }
    }

    #[test]
    fn pooled_step_matches_serial() {
        let table = big_table();
        let mut rng = Rng::new(12);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let hp = Hyper::default();
        let pool = ThreadPool::new(4);
        let mut a = ShardedOptimizer::from_name("lans", table.clone(), hp, 4).unwrap();
        let mut b = ShardedOptimizer::from_name("lans", table.clone(), hp, 4).unwrap();
        let mut xa = x0.clone();
        let mut xb = x0;
        let grads = a.plan().split(&g);
        a.step(&mut xa, &grads, 0.01);
        b.step_pooled(&pool, &mut xb, &grads, 0.01);
        assert_eq!(xa, xb);
    }

    #[test]
    fn state_roundtrip_reshards() {
        let table = big_table();
        let mut rng = Rng::new(13);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let hp = Hyper::default();
        let gs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();

        // run W=4 for two steps, export
        let mut base = ShardedOptimizer::from_name("lans", table.clone(), hp, 4).unwrap();
        let mut xb = x0.clone();
        for g in &gs[..2] {
            let sg = base.plan().split(g);
            base.step(&mut xb, &sg, 0.01);
        }
        let state = base.export_state();
        let step = base.steps_taken();

        // import into W=2 and W=8, continue — must match the uninterrupted run
        for w in [2usize, 8] {
            let mut other = ShardedOptimizer::from_name("lans", table.clone(), hp, w).unwrap();
            other.import_state(step, &state).unwrap();
            let mut xo = xb.clone();
            let mut xc = xb.clone();
            let mut cont = base_clone(&table, hp, &state, step);
            for g in &gs[2..] {
                let sg = other.plan().split(g);
                other.step(&mut xo, &sg, 0.02);
                let sg2 = cont.plan().split(g);
                cont.step(&mut xc, &sg2, 0.02);
            }
            assert_eq!(xo, xc, "resharded W={w} trajectory diverged");
        }
    }

    /// A fresh W=4 optimizer restored from the same state — the
    /// uninterrupted-run stand-in (import is exercised on both sides).
    fn base_clone(
        table: &BlockTable,
        hp: Hyper,
        state: &[(String, TensorF32)],
        step: u64,
    ) -> ShardedOptimizer {
        let mut o = ShardedOptimizer::from_name("lans", table.clone(), hp, 4).unwrap();
        o.import_state(step, state).unwrap();
        o
    }

    #[test]
    fn import_rejects_wrong_total() {
        let table = big_table();
        let other = BlockTable::new(&[("w".into(), 64, true)]);
        let hp = Hyper::default();
        let small = ShardedOptimizer::from_name("lans", other, hp, 2).unwrap();
        let mut big = ShardedOptimizer::from_name("lans", table, hp, 2).unwrap();
        let err = big.import_state(1, &small.export_state()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("64") && msg.contains("elements"), "unhelpful: {msg}");
    }

    #[test]
    fn unsupported_algorithms_have_no_sharded_form() {
        let t = big_table();
        for name in ["adamw", "adamw_bgn", "msgd", "nag", "zilch"] {
            assert!(ShardedOptimizer::from_name(name, t.clone(), Hyper::default(), 2).is_none());
        }
    }

    #[test]
    fn bucket_starts_partition_on_the_grid() {
        let t = big_table();
        for target in [1usize, 100, 4096, 5000, 16384] {
            let cuts = ShardPlan::bucket_starts(&t, target);
            assert_eq!(*cuts.first().unwrap(), 0, "target={target}");
            assert_eq!(*cuts.last().unwrap(), t.total, "target={target}");
            assert!(cuts.windows(2).all(|p| p[0] < p[1]), "target={target}: {cuts:?}");
            // every interior cut is a grid point: aligned within its block
            for &c in &cuts[1..cuts.len() - 1] {
                let b = t
                    .blocks
                    .iter()
                    .find(|b| b.offset <= c && c <= b.offset + b.len)
                    .expect("cut outside all blocks");
                assert!(
                    (c - b.offset) % ShardPlan::ALIGN == 0 || c == b.offset + b.len,
                    "cut {c} off-grid"
                );
            }
            // buckets meet the target except possibly the last
            for pair in cuts.windows(2).rev().skip(1) {
                assert!(pair[1] - pair[0] >= target, "target={target}: {cuts:?}");
            }
        }
        // degenerate targets: one full-vector bucket
        assert_eq!(ShardPlan::bucket_starts(&t, 0), vec![0, t.total]);
        assert_eq!(ShardPlan::bucket_starts(&t, t.total + 1), vec![0, t.total]);
    }

    #[test]
    fn bucketed_stitch_and_apply_match_step_scattered() {
        // the sharded half of the tentpole, composed serially (no DAG):
        // per-bucket range reduce-scatter + stitch_bucket, one
        // apply_bucketed — bitwise equal to the phase-synchronous
        // step_scattered_scaled, and the skip path leaves communicated
        // buckets' moments untouched
        use crate::collective::reduce_scatter::{
            ring_reduce_scatter, ring_reduce_scatter_range,
        };
        let table = big_table();
        let mut rng = Rng::new(71);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let pool = ThreadPool::new(4);
        let (w, hp) = (4usize, Hyper::default());
        let cuts = ShardPlan::bucket_starts(&table, 4096);
        assert!(cuts.len() > 3, "want several buckets: {cuts:?}");
        for name in ["lans", "lamb"] {
            let mut sync = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut buck = ShardedOptimizer::from_name(name, table.clone(), hp, w).unwrap();
            let mut xs = x0.clone();
            let mut xb = x0.clone();
            let ring = ring_chunk_starts(w, table.total);
            for k in 0..2 {
                let bufs: Vec<Vec<f32>> = (0..w)
                    .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                    .collect();
                let scale = 1.0 / w as f32;
                let lr = 0.01 + 0.002 * k as f32;

                let mut rs_sync = bufs.clone();
                ring_reduce_scatter(&mut rs_sync);
                let ss = sync
                    .step_scattered_scaled(&pool, &mut xs, &rs_sync, scale, lr)
                    .unwrap();

                let mut rs_buck = bufs;
                buck.begin_bucketed();
                let needs_g2 = buck.bucketed_needs_g2(true);
                let mut parts = Vec::new();
                for b in cuts.windows(2) {
                    ring_reduce_scatter_range(&mut rs_buck, b[0], b[1]);
                    let views: Vec<&[f32]> =
                        rs_buck.iter().map(|v| &v[b[0]..b[1]]).collect();
                    parts.push(buck.stitch_bucket(&views, &ring, b[0], b[1], scale, needs_g2));
                }
                let sb = buck.apply_bucketed(&pool, &mut xb, lr, true, &parts).unwrap();
                assert_eq!(ss.grad_norm, sb.grad_norm, "{name} k={k}");
                assert_eq!(ss.mean_trust_ratio, sb.mean_trust_ratio, "{name} k={k}");
                assert_eq!(xs, xb, "{name} k={k}: bucketed trajectory diverged");
            }

            // overflow in the *last* bucket, detected after every other
            // bucket has already been communicated and stitched: the probe
            // still skips before any moment or the clock is touched
            let mut bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
            .collect();
            let last = table.total - 1;
            // the poisoned element must sit where the stitch reads it: the
            // owner of the last ring chunk
            bufs[chunk_owner(w - 1, w)][last] = f32::INFINITY;
            buck.begin_bucketed();
            let mut parts = Vec::new();
            for b in cuts.windows(2) {
                ring_reduce_scatter_range(&mut bufs, b[0], b[1]);
                let views: Vec<&[f32]> = bufs.iter().map(|v| &v[b[0]..b[1]]).collect();
                parts.push(buck.stitch_bucket(&views, &ring, b[0], b[1], 0.25, true));
            }
            let t_before = buck.steps_taken();
            assert!(
                buck.apply_bucketed(&pool, &mut xb, 0.01, true, &parts).is_none(),
                "{name}: overflow must skip"
            );
            assert_eq!(t_before, buck.steps_taken(), "{name}: skip advanced the clock");
            assert_eq!(xs, xb, "{name}: skipped bucketed step touched params");
            // both walk on identically after the skip
            let bufs: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..table.total).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut rs_sync = bufs.clone();
            ring_reduce_scatter(&mut rs_sync);
            let scale = 1.0 / w as f32;
            sync.step_scattered_scaled(&pool, &mut xs, &rs_sync, scale, 0.02).unwrap();
            let mut rs_buck = bufs;
            buck.begin_bucketed();
            let mut parts = Vec::new();
            for b in cuts.windows(2) {
                ring_reduce_scatter_range(&mut rs_buck, b[0], b[1]);
                let views: Vec<&[f32]> = rs_buck.iter().map(|v| &v[b[0]..b[1]]).collect();
                parts.push(buck.stitch_bucket(&views, &ring, b[0], b[1], scale, true));
            }
            buck.apply_bucketed(&pool, &mut xb, 0.02, true, &parts).unwrap();
            assert_eq!(xs, xb, "{name}: post-skip trajectory diverged");
        }
    }
}
