//! Learning-rate schedules — the paper's second contribution.
//!
//! * [`Schedule::LinearWarmupDecay`] — eq. (8), the LAMB schedule.
//! * [`Schedule::WarmupConstDecay`]  — eq. (9): warmup → *constant
//!   transient* → decay.  The constant stage is what lets batch sizes past
//!   the linear-scaling limit keep making progress once η has hit the
//!   1/L ceiling (paper §3.3).
//! * [`Schedule::PolyDecay`] — the poly-decay generalisation used by BERT
//!   reference code (power=1 ⇒ eq. 8).
//!
//! `area_under_curve` reproduces Fig. 1's quantitative claim: with
//! T=3519, Tw=1500, Tc=963 the AUC gap between eq. 8 @ η=0.01 and
//! eq. 8 @ η=0.007 is 5.28, and eq. 9 @ η=0.007 shrinks it to 1.91.
//! Bit-parity with the jax closed forms is asserted in
//! `python/tests/test_schedule.py`.

/// Step-indexed learning-rate schedule (t is 1-based, as in the paper).
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Constant {
        eta: f64,
    },
    /// eq. (8)
    LinearWarmupDecay {
        eta: f64,
        t_warmup: u64,
        t_total: u64,
    },
    /// eq. (9)
    WarmupConstDecay {
        eta: f64,
        t_warmup: u64,
        t_const: u64,
        t_total: u64,
    },
    PolyDecay {
        eta: f64,
        t_warmup: u64,
        t_total: u64,
        power: f64,
    },
}

impl Schedule {
    /// Learning rate at 1-based step `t`.
    ///
    /// `t_warmup == 0` means "no warmup" — the post-warmup branch applies
    /// from the first step (the naive ratio would be the `0/0 → NaN` every
    /// downstream consumer of the rate would silently propagate).  The
    /// decay ratios divide by a zero span only when `t` is already past
    /// `t_total`, where `.max(0.0)`/`.clamp` pin the rate to a finite 0.
    pub fn lr(&self, t: u64) -> f64 {
        let tf = t as f64;
        match *self {
            Schedule::Constant { eta } => eta,
            Schedule::LinearWarmupDecay { eta, t_warmup, t_total } => {
                if t_warmup > 0 && t <= t_warmup {
                    eta * tf / t_warmup as f64
                } else {
                    (eta * (t_total as f64 - tf)
                        / (t_total - t_warmup) as f64)
                        .max(0.0)
                }
            }
            Schedule::WarmupConstDecay { eta, t_warmup, t_const, t_total } => {
                if t_warmup > 0 && t <= t_warmup {
                    eta * tf / t_warmup as f64
                } else if t <= t_warmup + t_const {
                    eta
                } else {
                    (eta * (t_total as f64 - tf)
                        / (t_total - t_warmup - t_const) as f64)
                        .max(0.0)
                }
            }
            Schedule::PolyDecay { eta, t_warmup, t_total, power } => {
                if t_warmup > 0 && t <= t_warmup {
                    eta * tf / t_warmup as f64
                } else {
                    let frac = ((t_total as f64 - tf)
                        / (t_total - t_warmup) as f64)
                        .clamp(0.0, 1.0);
                    eta * frac.powf(power)
                }
            }
        }
    }

    /// Peak learning rate.
    pub fn eta(&self) -> f64 {
        match *self {
            Schedule::Constant { eta }
            | Schedule::LinearWarmupDecay { eta, .. }
            | Schedule::WarmupConstDecay { eta, .. }
            | Schedule::PolyDecay { eta, .. } => eta,
        }
    }

    /// The full LR curve over steps 1..=t_total.
    pub fn curve(&self, t_total: u64) -> Vec<f64> {
        (1..=t_total).map(|t| self.lr(t)).collect()
    }

    /// Exact area under the schedule over t ∈ [1, t_total] (sum of per-step
    /// rates — the discrete analogue Fig. 1's numbers are computed with).
    pub fn area_under_curve(&self, t_total: u64) -> f64 {
        (1..=t_total).map(|t| self.lr(t)).sum()
    }
}

/// The paper's ratio-based parameterisation (§4, Table 1):
/// `ratio_warmup = T_warmup / T_stage`, `ratio_const = T_const / T_stage`.
pub fn from_ratios(
    eta: f64,
    t_total: u64,
    ratio_warmup: f64,
    ratio_const: f64,
) -> Schedule {
    assert!(ratio_warmup >= 0.0 && ratio_const >= 0.0);
    assert!(ratio_warmup + ratio_const <= 1.0 + 1e-9);
    let t_warmup = (t_total as f64 * ratio_warmup).round() as u64;
    let t_const = (t_total as f64 * ratio_const).round() as u64;
    if t_const == 0 {
        Schedule::LinearWarmupDecay { eta, t_warmup, t_total }
    } else {
        Schedule::WarmupConstDecay { eta, t_warmup, t_const, t_total }
    }
}

/// Square-root LR scaling rule (paper §3.3, from You et al.):
/// η = sqrt(k) · η̃ for mini-batch size k and reference rate η̃.
pub fn sqrt_scaled_lr(reference_lr: f64, reference_batch: usize, batch: usize) -> f64 {
    reference_lr * ((batch as f64) / (reference_batch as f64)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fig. 1 parameters
    const T: u64 = 3519;
    const TW: u64 = 1500;
    const TC: u64 = 963;

    #[test]
    fn eq8_shape() {
        let s = Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: TW, t_total: T };
        assert!((s.lr(TW) - 0.01).abs() < 1e-12);
        assert!(s.lr(1) < 1e-4);
        assert!((s.lr(T)).abs() < 1e-9);
        // monotone up then down
        assert!(s.lr(700) < s.lr(1400));
        assert!(s.lr(2000) > s.lr(3000));
    }

    #[test]
    fn eq9_constant_stage() {
        let s = Schedule::WarmupConstDecay {
            eta: 0.007,
            t_warmup: TW,
            t_const: TC,
            t_total: T,
        };
        for t in [TW, TW + 1, TW + TC / 2, TW + TC] {
            assert!((s.lr(t) - 0.007).abs() < 1e-12, "t={t}");
        }
        assert!(s.lr(TW + TC + 100) < 0.007);
        assert!((s.lr(T)).abs() < 1e-9);
    }

    #[test]
    fn fig1_auc_gaps() {
        // the paper: gap(eq8@0.01, eq8@0.007) = 5.28; gap(eq8@0.01, eq9@0.007) = 1.91
        let ideal = Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: TW, t_total: T };
        let small = Schedule::LinearWarmupDecay { eta: 0.007, t_warmup: TW, t_total: T };
        let ours = Schedule::WarmupConstDecay {
            eta: 0.007,
            t_warmup: TW,
            t_const: TC,
            t_total: T,
        };
        let gap8 = ideal.area_under_curve(T) - small.area_under_curve(T);
        let gap9 = ideal.area_under_curve(T) - ours.area_under_curve(T);
        assert!((gap8 - 5.28).abs() < 0.05, "gap8 = {gap8}");
        assert!((gap9 - 1.91).abs() < 0.05, "gap9 = {gap9}");
    }

    #[test]
    fn ratios_table1_stage1() {
        // Table 1 stage 1: eta=0.00675, warmup 42.65%, const 27.35% of 3519
        let s = from_ratios(0.00675, 3519, 0.4265, 0.2735);
        match s {
            Schedule::WarmupConstDecay { t_warmup, t_const, .. } => {
                assert_eq!(t_warmup, 1501); // 3519*0.4265 = 1500.8
                assert_eq!(t_const, 962);
                // warmup+const = 70% of stage (paper's constraint)
                let frac = (t_warmup + t_const) as f64 / 3519.0;
                assert!((frac - 0.70).abs() < 0.001);
            }
            _ => panic!("expected WarmupConstDecay"),
        }
    }

    #[test]
    fn sqrt_scaling() {
        // 32K -> 128K is 4x batch => 2x lr
        let lr = sqrt_scaled_lr(0.005, 32768, 131072);
        assert!((lr - 0.01).abs() < 1e-12);
    }

    #[test]
    fn zero_const_falls_back_to_eq8() {
        let s = from_ratios(0.01, 1000, 0.1, 0.0);
        assert!(matches!(s, Schedule::LinearWarmupDecay { .. }));
    }

    #[test]
    fn zero_warmup_never_nans() {
        // t_warmup = 0 used to hit 0/0 at t = 0 in every warmup branch
        let eta = 0.01;
        let schedules = [
            Schedule::LinearWarmupDecay { eta, t_warmup: 0, t_total: 100 },
            Schedule::WarmupConstDecay { eta, t_warmup: 0, t_const: 30, t_total: 100 },
            Schedule::PolyDecay { eta, t_warmup: 0, t_total: 100, power: 2.0 },
        ];
        for s in &schedules {
            for t in [0u64, 1, 50, 100, 101] {
                let lr = s.lr(t);
                assert!(lr.is_finite(), "{s:?} at t={t}: lr = {lr}");
                assert!(
                    (0.0..=eta * (1.0 + 1e-12)).contains(&lr),
                    "{s:?} at t={t}: lr = {lr} outside [0, eta]"
                );
            }
            // no warmup ⇒ the run starts at (or decaying from) full rate
            assert!(s.lr(1) > eta * 0.9, "{s:?}: lr(1) = {}", s.lr(1));
        }
        // with no warmup and a const stage, the rate is exactly eta at t=0/1
        assert_eq!(schedules[1].lr(0), eta);
        assert_eq!(schedules[1].lr(1), eta);
    }

    #[test]
    fn t_zero_is_finite_with_warmup() {
        // t = 0 is below the 1-based domain but must still be well-defined
        for s in [
            Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: 10, t_total: 100 },
            Schedule::WarmupConstDecay {
                eta: 0.01,
                t_warmup: 10,
                t_const: 20,
                t_total: 100,
            },
            Schedule::PolyDecay { eta: 0.01, t_warmup: 10, t_total: 100, power: 1.0 },
        ] {
            assert_eq!(s.lr(0), 0.0, "{s:?}");
        }
        assert_eq!(Schedule::Constant { eta: 0.01 }.lr(0), 0.01);
    }

    #[test]
    fn t_total_endpoint_across_variants() {
        let (t_total, eta) = (100u64, 0.01);
        // eq. 8 / eq. 9 / poly decay all reach (or clamp to) 0 at t_total
        let lwd = Schedule::LinearWarmupDecay { eta, t_warmup: 10, t_total };
        assert!(lwd.lr(t_total).abs() < 1e-15);
        let wcd =
            Schedule::WarmupConstDecay { eta, t_warmup: 10, t_const: 20, t_total };
        assert!(wcd.lr(t_total).abs() < 1e-15);
        let poly = Schedule::PolyDecay { eta, t_warmup: 10, t_total, power: 2.0 };
        assert!(poly.lr(t_total).abs() < 1e-15);
        // past the end: clamped to 0, never negative or non-finite
        for s in [&lwd, &wcd, &poly] {
            let lr = s.lr(t_total + 10);
            assert_eq!(lr, 0.0, "{s:?} past t_total");
        }
        // degenerate all-warmup schedule: finite everywhere, peaks at eta
        let all_warm = Schedule::LinearWarmupDecay { eta, t_warmup: t_total, t_total };
        assert_eq!(all_warm.lr(t_total), eta);
        assert_eq!(all_warm.lr(t_total + 1), 0.0);
    }
}
