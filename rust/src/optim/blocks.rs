//! Block table: the flat-vector view of the model parameters.
//!
//! The paper's algorithms are defined per *block* (one parameter tensor =
//! one G_b).  The pure-rust optimizers and the allreduce path work on a
//! single contiguous `Vec<f32>` holding all parameters; `BlockTable` maps
//! block index → (offset, len, decay flag) within that vector.

use crate::runtime::meta::ModelMeta;
use crate::runtime::tensor::TensorF32;

#[derive(Debug, Clone)]
pub struct Block {
    pub name: String,
    pub offset: usize,
    pub len: usize,
    /// whether weight decay applies (false for bias / LayerNorm blocks)
    pub decay: bool,
}

#[derive(Debug, Clone)]
pub struct BlockTable {
    pub blocks: Vec<Block>,
    pub total: usize,
}

impl BlockTable {
    pub fn new(specs: &[(String, usize, bool)]) -> BlockTable {
        let mut blocks = Vec::with_capacity(specs.len());
        let mut offset = 0;
        for (name, len, decay) in specs {
            blocks.push(Block { name: name.clone(), offset, len: *len, decay: *decay });
            offset += len;
        }
        BlockTable { blocks, total: offset }
    }

    pub fn from_meta(meta: &ModelMeta) -> BlockTable {
        Self::new(&meta.blocks())
    }

    /// A bert-base-shaped table (≈110M params, 196 blocks) without needing
    /// artifacts — the standard subject of the optimizer micro-benchmarks
    /// (`optimizer_step`, `sharded_step`).
    pub fn bert_base() -> BlockTable {
        let (h, i, v, s) = (768usize, 3072usize, 30522usize, 512usize);
        let mut specs: Vec<(String, usize, bool)> = vec![
            ("emb/word".into(), v * h, true),
            ("emb/pos".into(), s * h, true),
            ("emb/ln_s".into(), h, false),
            ("emb/ln_b".into(), h, false),
        ];
        for l in 0..12 {
            for (name, len, decay) in [
                ("q_k", h * h, true),
                ("q_b", h, false),
                ("k_k", h * h, true),
                ("k_b", h, false),
                ("v_k", h * h, true),
                ("v_b", h, false),
                ("o_k", h * h, true),
                ("o_b", h, false),
                ("ln1s", h, false),
                ("ln1b", h, false),
                ("f_in", h * i, true),
                ("f_inb", i, false),
                ("f_out", i * h, true),
                ("f_outb", h, false),
                ("ln2s", h, false),
                ("ln2b", h, false),
            ] {
                specs.push((format!("l{l}/{name}"), len, decay));
            }
        }
        Self::new(&specs)
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Flatten per-tensor params into one contiguous vector.
    pub fn flatten(&self, tensors: &[TensorF32]) -> Vec<f32> {
        assert_eq!(tensors.len(), self.blocks.len());
        let mut out = Vec::with_capacity(self.total);
        for (b, t) in self.blocks.iter().zip(tensors) {
            assert_eq!(t.data.len(), b.len, "block {} length mismatch", b.name);
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Scatter a flat vector back into per-tensor storage (shapes preserved).
    pub fn unflatten_into(&self, flat: &[f32], tensors: &mut [TensorF32]) {
        assert_eq!(flat.len(), self.total);
        assert_eq!(tensors.len(), self.blocks.len());
        for (b, t) in self.blocks.iter().zip(tensors.iter_mut()) {
            t.data.copy_from_slice(&flat[b.offset..b.offset + b.len]);
        }
    }

    pub fn slice<'a>(&self, flat: &'a [f32], idx: usize) -> &'a [f32] {
        let b = &self.blocks[idx];
        &flat[b.offset..b.offset + b.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> BlockTable {
        BlockTable::new(&[
            ("w".into(), 6, true),
            ("b".into(), 2, false),
        ])
    }

    #[test]
    fn offsets() {
        let t = table();
        assert_eq!(t.total, 8);
        assert_eq!(t.blocks[1].offset, 6);
        assert!(!t.blocks[1].decay);
    }

    #[test]
    fn flatten_roundtrip() {
        let t = table();
        let tensors = vec![
            TensorF32::new(vec![2, 3], (0..6).map(|i| i as f32).collect()),
            TensorF32::new(vec![2], vec![10.0, 11.0]),
        ];
        let flat = t.flatten(&tensors);
        assert_eq!(flat, vec![0., 1., 2., 3., 4., 5., 10., 11.]);
        let mut back = vec![
            TensorF32::zeros(vec![2, 3]),
            TensorF32::zeros(vec![2]),
        ];
        t.unflatten_into(&flat, &mut back);
        assert_eq!(back, tensors);
    }
}
