//! Optimizers and learning-rate schedules — the paper's algorithmic core,
//! plus the block-sharded [`ParallelExecutor`] that runs them on all cores
//! and the ZeRO-1-style [`ShardedOptimizer`] that partitions state across
//! data-parallel workers.

pub mod blocks;
pub mod native;
pub mod parallel;
pub mod schedule;
pub mod sharded;

pub use blocks::{Block, BlockTable};
pub use native::{
    make_optimizer, AdamW, Hyper, Lamb, Lans, MomentumSgd, Optimizer, StepStats, NORM_EPS,
    NORM_SEG,
};
pub use parallel::{lans_step_on_plan, lamb_step_on_plan, ParallelExecutor};
pub use schedule::{from_ratios, sqrt_scaled_lr, Schedule};
pub use sharded::{scatter_to_plan, Fragment, ShardPlan, ShardedOptimizer};
