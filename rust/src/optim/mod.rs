//! Optimizers and learning-rate schedules — the paper's algorithmic core.

pub mod blocks;
pub mod native;
pub mod schedule;

pub use blocks::{Block, BlockTable};
pub use native::{make_optimizer, AdamW, Hyper, Lamb, Lans, MomentumSgd, Optimizer, StepStats};
pub use schedule::{from_ratios, sqrt_scaled_lr, Schedule};
