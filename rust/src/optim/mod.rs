//! Optimizers and learning-rate schedules — the paper's algorithmic core,
//! plus the block-sharded [`ParallelExecutor`] that runs them on all cores.

pub mod blocks;
pub mod native;
pub mod parallel;
pub mod schedule;

pub use blocks::{Block, BlockTable};
pub use native::{make_optimizer, AdamW, Hyper, Lamb, Lans, MomentumSgd, Optimizer, StepStats};
pub use parallel::ParallelExecutor;
pub use schedule::{from_ratios, sqrt_scaled_lr, Schedule};
