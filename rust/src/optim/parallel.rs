//! Block-sharded parallel optimizer stepping — the `ParallelExecutor`
//! subsystem.
//!
//! LAMB/LANS are defined per *block* (one parameter tensor = one G_b), and
//! every per-block quantity — gradient norm, moments, trust ratio, apply —
//! is independent across blocks.  The executor exploits exactly that: it
//! shards the flat parameter/gradient/moment vectors on [`BlockTable`]
//! boundaries into disjoint mutable slices and runs the per-block kernels
//! from [`super::native`] concurrently on a [`ThreadPool`], in two parallel
//! phases per step:
//!
//!   1. **norms/moments** — `*_pass1_block` per block (moment updates, the
//!      ‖x‖/‖r‖/‖c‖ reductions, the block's apply coefficients);
//!   2. **apply** — `*_pass2/apply_block` per block from the cached
//!      directions.
//!
//! Because the parallel path runs the *same* kernels in the same per-block
//! order for every reduction that crosses blocks (grad-norm sum, trust-mean
//! push), its results are arithmetically identical to the serial path —
//! `tests/proptests.rs` asserts serial == parallel across random block
//! tables, thread counts and step counts.  This is the rust analogue of
//! apex `multi_tensor_apply`: one launch over many tensors, work split by
//! block, with dynamic scheduling so BERT's ~20%-of-parameters embedding
//! block does not serialize the sweep.

use crate::util::pool::ThreadPool;
use crate::util::stats::Welford;

use super::blocks::BlockTable;
use super::native::{
    adamw_block, lamb_apply_block, lamb_pass1_block, lans_pass1_block, lans_pass2_block,
    AdamCtx, AdamW, Lamb, Lans, LansBlockMut, Optimizer, StepStats,
};

/// Below this many total parameters a step is cheaper serial than the
/// pool's per-call spawn cost (same floor the pre-executor within-block
/// chunking used).  [`ParallelExecutor::step`] falls back automatically;
/// results are identical either way.
pub const PARALLEL_MIN_ELEMS: usize = 1 << 16;

/// Executes optimizer steps block-parallel on an owned [`ThreadPool`].
///
/// Width 1 (or [`ParallelExecutor::serial`]) dispatches to the plain serial
/// [`Optimizer::step`], preserving the legacy path exactly; width 0 at
/// construction selects the machine's available parallelism.  Small models
/// (fewer than [`PARALLEL_MIN_ELEMS`] parameters) also take the serial
/// path: scoped-thread spawn cost would dominate the sharded compute.
pub struct ParallelExecutor {
    pool: ThreadPool,
}

impl ParallelExecutor {
    /// `threads == 0` selects available parallelism; `1` is fully serial.
    pub fn new(threads: usize) -> ParallelExecutor {
        ParallelExecutor { pool: ThreadPool::new(threads) }
    }

    /// An executor that always takes the serial path.
    pub fn serial() -> ParallelExecutor {
        ParallelExecutor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The underlying pool (shared with e.g. the chunk-parallel allreduce).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// One optimizer update at learning rate `lr`.
    pub fn step(
        &self,
        opt: &mut dyn Optimizer,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        if self.pool.threads() <= 1 || opt.blocks().total < PARALLEL_MIN_ELEMS {
            opt.step(params, grads, lr)
        } else {
            opt.step_parallel(&self.pool, params, grads, lr)
        }
    }
}

/// Split `data` into one mutable slice per block (blocks tile the flat
/// vector contiguously and in order, so this is a chain of `split_at_mut`).
fn split_blocks<'a>(table: &BlockTable, mut data: &'a mut [f32]) -> Vec<&'a mut [f32]> {
    assert_eq!(data.len(), table.total, "flat vector does not match block table");
    let mut out = Vec::with_capacity(table.blocks.len());
    for b in &table.blocks {
        let (head, tail) = data.split_at_mut(b.len);
        out.push(head);
        data = tail;
    }
    out
}

/// Fold per-block pass-1 outputs into [`StepStats`] fields in block order —
/// the same order the serial loop uses, so the cross-block reductions are
/// bit-identical.
fn fold_coefs(trusts: impl Iterator<Item = (f64, f64)>) -> (f64, f64) {
    let mut welford = Welford::default();
    let mut grad_sq = 0.0f64;
    for (trust, gs) in trusts {
        welford.push(trust);
        grad_sq += gs;
    }
    (welford.mean(), grad_sq)
}

pub(crate) fn lans_step_parallel(
    o: &mut Lans,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let hp = o.hp;
    let table = &o.table;

    struct Task<'a> {
        x: &'a mut [f32],
        blk: LansBlockMut<'a>,
        coef_r: f32,
        coef_c: f32,
    }

    let xs = split_blocks(table, params);
    let ms = split_blocks(table, &mut o.m);
    let vs = split_blocks(table, &mut o.v);
    let rfs = split_blocks(table, &mut o.r_full);
    let cfs = split_blocks(table, &mut o.c_full);
    let mut tasks: Vec<Task> = Vec::with_capacity(table.blocks.len());
    for (((((b, x), m), v), rf), cf) in
        table.blocks.iter().zip(xs).zip(ms).zip(vs).zip(rfs).zip(cfs)
    {
        tasks.push(Task {
            x,
            blk: LansBlockMut {
                g: &grads[b.offset..b.offset + b.len],
                m,
                v,
                rf,
                cf,
                wd: if b.decay { hp.weight_decay } else { 0.0 },
            },
            coef_r: 0.0,
            coef_c: 0.0,
        });
    }

    // phase 1 — per-block moments, norms and coefficients, block-parallel
    let coefs = pool.map_mut(&mut tasks, |t| lans_pass1_block(&cx, t.x, &mut t.blk));
    for (t, c) in tasks.iter_mut().zip(&coefs) {
        t.coef_r = c.coef_r;
        t.coef_c = c.coef_c;
    }

    // phase 2 — apply from the cached directions, block-parallel
    let maxes = pool.map_mut(&mut tasks, |t| {
        lans_pass2_block(t.coef_r, t.coef_c, t.x, t.blk.rf, t.blk.cf)
    });

    let (mean_trust, grad_sq) = fold_coefs(coefs.iter().map(|c| (c.trust, c.grad_sq)));
    StepStats {
        mean_trust_ratio: mean_trust,
        max_abs_param: maxes.into_iter().fold(0.0f32, f32::max),
        grad_norm: grad_sq.sqrt(),
    }
}

pub(crate) fn lamb_step_parallel(
    o: &mut Lamb,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let hp = o.hp;
    let table = &o.table;

    struct Task<'a> {
        x: &'a mut [f32],
        g: &'a [f32],
        m: &'a mut [f32],
        v: &'a mut [f32],
        u: &'a mut [f32],
        wd: f32,
        coef: f32,
    }

    let xs = split_blocks(table, params);
    let ms = split_blocks(table, &mut o.m);
    let vs = split_blocks(table, &mut o.v);
    let us = split_blocks(table, &mut o.u_full);
    let mut tasks: Vec<Task> = Vec::with_capacity(table.blocks.len());
    for ((((b, x), m), v), u) in table.blocks.iter().zip(xs).zip(ms).zip(vs).zip(us) {
        tasks.push(Task {
            x,
            g: &grads[b.offset..b.offset + b.len],
            m,
            v,
            u,
            wd: if b.decay { hp.weight_decay } else { 0.0 },
            coef: 0.0,
        });
    }

    let coefs = pool.map_mut(&mut tasks, |t| {
        lamb_pass1_block(&cx, t.x, t.g, t.m, t.v, t.u, t.wd)
    });
    for (t, c) in tasks.iter_mut().zip(&coefs) {
        t.coef = c.coef;
    }
    let maxes = pool.map_mut(&mut tasks, |t| lamb_apply_block(t.coef, t.x, t.u));

    let (mean_trust, grad_sq) = fold_coefs(coefs.iter().map(|c| (c.trust, c.grad_sq)));
    StepStats {
        mean_trust_ratio: mean_trust,
        max_abs_param: maxes.into_iter().fold(0.0f32, f32::max),
        grad_norm: grad_sq.sqrt(),
    }
}

pub(crate) fn adamw_step_parallel(
    o: &mut AdamW,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let hp = o.hp;
    let bgn = o.block_grad_norm;
    let table = &o.table;

    struct Task<'a> {
        x: &'a mut [f32],
        g: &'a [f32],
        m: &'a mut [f32],
        v: &'a mut [f32],
        wd: f32,
    }

    let xs = split_blocks(table, params);
    let ms = split_blocks(table, &mut o.m);
    let vs = split_blocks(table, &mut o.v);
    let mut tasks: Vec<Task> = Vec::with_capacity(table.blocks.len());
    for (((b, x), m), v) in table.blocks.iter().zip(xs).zip(ms).zip(vs) {
        tasks.push(Task {
            x,
            g: &grads[b.offset..b.offset + b.len],
            m,
            v,
            wd: if b.decay { hp.weight_decay } else { 0.0 },
        });
    }

    // AdamW has no cross-element reduction feeding the apply, so the whole
    // block update is one parallel phase.
    let outs = pool.map_mut(&mut tasks, |t| adamw_block(&cx, bgn, t.x, t.g, t.m, t.v, t.wd));

    let mut max_abs = 0.0f32;
    let mut grad_sq = 0.0f64;
    for (ma, gs) in outs {
        max_abs = max_abs.max(ma);
        grad_sq += gs;
    }
    StepStats {
        mean_trust_ratio: 1.0,
        max_abs_param: max_abs,
        grad_norm: grad_sq.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{make_optimizer, Hyper};
    use crate::util::rng::Rng;

    fn bumpy_table() -> BlockTable {
        // sizes straddle the pass-1 sub-chunk boundary (4096) and include a
        // dominant block, like BERT's word embedding
        BlockTable::new(&[
            ("emb".into(), 9000, true),
            ("k1".into(), 4096, true),
            ("b1".into(), 17, false),
            ("k2".into(), 1500, true),
            ("ln".into(), 1, false),
        ])
    }

    #[test]
    fn executor_serial_and_parallel_agree() {
        let table = bumpy_table();
        let mut rng = Rng::new(42);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        // drive step_parallel directly: the table is below the executor's
        // PARALLEL_MIN_ELEMS auto-fallback, and this test is about the
        // parallel kernels themselves
        let pool = ThreadPool::new(4);
        for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd"] {
            let mut o_serial = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut o_par = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut xs = x0.clone();
            let mut xp = x0.clone();
            for step in 0..3 {
                // identical gradient stream for both paths
                let g: Vec<f32> =
                    (0..table.total).map(|_| rng.normal_f32()).collect();
                let lr = 0.01 + 0.002 * step as f32;
                let s_ser = o_serial.step(&mut xs, &g, lr);
                let s_par = o_par.step_parallel(&pool, &mut xp, &g, lr);
                assert!(
                    (s_ser.mean_trust_ratio - s_par.mean_trust_ratio).abs() < 1e-12,
                    "{name}: trust mismatch"
                );
                assert!(
                    (s_ser.grad_norm - s_par.grad_norm).abs() < 1e-9,
                    "{name}: grad norm mismatch"
                );
            }
            for (a, b) in xs.iter().zip(&xp) {
                assert!((a - b).abs() < 1e-6, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn serial_executor_never_spawns_path() {
        let table = bumpy_table();
        let exec = ParallelExecutor::serial();
        assert_eq!(exec.threads(), 1);
        let mut opt = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut x = vec![0.1f32; table.total];
        let g = vec![0.01f32; table.total];
        let stats = exec.step(opt.as_mut(), &mut x, &g, 0.01);
        assert!(stats.grad_norm > 0.0);
    }

    #[test]
    fn split_blocks_is_a_partition() {
        let table = bumpy_table();
        let mut data: Vec<f32> = (0..table.total).map(|i| i as f32).collect();
        let parts = split_blocks(&table, &mut data);
        assert_eq!(parts.len(), table.blocks.len());
        for (b, p) in table.blocks.iter().zip(&parts) {
            assert_eq!(p.len(), b.len);
            assert_eq!(p.first().copied(), Some(b.offset as f32));
        }
    }
}
