//! Plan-granularity parallel optimizer stepping — the replicated-path
//! executor.
//!
//! The first executor sharded work on [`BlockTable`] boundaries (one task
//! per parameter tensor, the rust analogue of apex `multi_tensor_apply`).
//! That ceiling is the largest block: BERT's word embedding is ~20% of all
//! parameters, so block granularity cannot speed the step up more than
//! ~5× no matter the thread count.  This executor instead cuts the flat
//! vector on the balanced [`ShardPlan`] grid from `optim::sharded` —
//! boundaries snapped to the block-local
//! [`NORM_SEG`](super::native::NORM_SEG) segment grid, oversubscribed
//! [`policy::PLAN_CHUNKS_PER_THREAD`]× per pool thread so dynamic
//! scheduling stays load-balanced — and runs the *same* three-phase
//! segmented engine ([`segmented_step`]) as the sharded optimizer:
//!
//!   1. **grad² partials** (LANS/AdamW) — per-segment block gradient
//!      norms;
//!   2. **moments/directions + norm partials** — combined per block in
//!      global segment order;
//!   3. **apply** — from the per-block coefficients.
//!
//! Because every cut sits on the segment grid and partials combine in
//! segment order — the serial kernels' own hierarchical fold — the
//! parallel path is *bit-identical* to the serial `Optimizer::step` (and
//! to the sharded path, which runs the same engine): `tests/proptests.rs`
//! asserts exact equality across random block tables, thread counts and
//! step counts.  [`ShardPlan::per_block`] preserves the old block
//! granularity purely as the baseline the `optimizer_step` bench measures
//! the ceiling against.

use crate::util::pool::{policy, ThreadPool};

pub use crate::util::pool::policy::PARALLEL_MIN_ELEMS;

use super::blocks::BlockTable;
use super::native::{
    adamw_apply, lans_inv_gnorm, unscale_grad_sq_segments, AdamCtx, AdamW, Lamb, Lans,
    Optimizer, StepStats,
};
use super::sharded::{
    combine_block_g2, frag_grad_sq_parts, segmented_step, split_at_plan, Algo, Fragment,
    SegTask, ShardPlan,
};

/// Executes optimizer steps plan-parallel on an owned [`ThreadPool`].
///
/// Width 1 (or [`ParallelExecutor::serial`]) dispatches to the plain serial
/// [`Optimizer::step`], preserving the legacy path exactly; width 0 at
/// construction selects the machine's available parallelism.  Small models
/// (fewer than [`PARALLEL_MIN_ELEMS`] parameters) also take the serial
/// path: region overhead would dominate the sharded compute.
pub struct ParallelExecutor {
    pool: ThreadPool,
}

impl ParallelExecutor {
    /// `threads == 0` selects available parallelism; `1` is fully serial.
    pub fn new(threads: usize) -> ParallelExecutor {
        ParallelExecutor { pool: ThreadPool::new(threads) }
    }

    /// An executor that always takes the serial path.
    pub fn serial() -> ParallelExecutor {
        ParallelExecutor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The underlying pool (shared with e.g. the chunk-parallel allreduce).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// One optimizer update at learning rate `lr`.
    pub fn step(
        &self,
        opt: &mut dyn Optimizer,
        params: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) -> StepStats {
        if self.pool.threads() <= 1 || opt.blocks().total < PARALLEL_MIN_ELEMS {
            opt.step(params, grads, lr)
        } else {
            opt.step_parallel(&self.pool, params, grads, lr)
        }
    }
}

/// The balanced work grid for a `threads`-wide pool (see
/// [`policy::plan_chunks`]).
fn balanced_plan(table: &BlockTable, threads: usize) -> ShardPlan {
    ShardPlan::build(table, policy::plan_chunks(threads))
}

/// Carve one [`SegTask`] per plan chunk out of the full flat vectors.
/// `dir_b` is `None` for LAMB (no second cached direction).
fn build_seg_tasks<'a>(
    plan: &'a ShardPlan,
    params: &'a mut [f32],
    grads: &'a [f32],
    m: &'a mut [f32],
    v: &'a mut [f32],
    dir_a: &'a mut [f32],
    dir_b: Option<&'a mut [f32]>,
) -> Vec<SegTask<'a>> {
    let w = plan.workers();
    let xs = split_at_plan(plan, params);
    let ms = split_at_plan(plan, m);
    let vs = split_at_plan(plan, v);
    let das = split_at_plan(plan, dir_a);
    let dbs: Vec<&'a mut [f32]> = match dir_b {
        Some(db) => split_at_plan(plan, db),
        None => (0..w).map(|_| <&mut [f32]>::default()).collect(),
    };
    let mut tasks = Vec::with_capacity(w);
    for (((((s, x), m), v), da), db) in
        (0..w).zip(xs).zip(ms).zip(vs).zip(das).zip(dbs)
    {
        tasks.push(SegTask {
            x,
            g: &grads[plan.range(s)],
            m,
            v,
            dir_a: da,
            dir_b: db,
            frags: plan.fragments(s),
            base: plan.starts[s],
            secs: 0.0,
        });
    }
    tasks
}

/// Fused unscale + overflow probe: one sweep multiplies the gradient by
/// `inv_scale` in place while folding the canonical per-block grad²
/// partials ([`unscale_grad_sq_segments`], block-local segment grid,
/// global segment order — the serial kernels' own fold).  Returns the
/// per-block grad² for reuse as the segmented engine's phase A, or `None`
/// when any block's sum is inf/nan — the fp16 overflow signal that turns
/// the step into a skip.  Pooled on the balanced plan grid when the work
/// is large enough; bit-identical either way.
pub(crate) fn unscale_probe_pooled(
    pool: &ThreadPool,
    table: &BlockTable,
    grads: &mut [f32],
    inv_scale: f32,
) -> Option<Vec<f64>> {
    let _sp = crate::trace::span(crate::trace::CAT_COMPUTE, "unscale_probe");
    let nb = table.blocks.len();
    let parts: Vec<Vec<(usize, Vec<f64>)>> =
        if pool.threads() <= 1 || table.total < policy::POOLED_MIN_ELEMS {
            table
                .blocks
                .iter()
                .enumerate()
                .map(|(bi, b)| {
                    let mut ps = Vec::new();
                    unscale_grad_sq_segments(
                        &mut grads[b.offset..b.offset + b.len],
                        inv_scale,
                        |p| ps.push(p),
                    );
                    vec![(bi, ps)]
                })
                .collect()
        } else {
            let plan = balanced_plan(table, pool.threads());
            struct ProbeTask<'a> {
                g: &'a mut [f32],
                frags: &'a [Fragment],
                base: usize,
            }
            let mut tasks: Vec<ProbeTask<'_>> = split_at_plan(&plan, grads)
                .into_iter()
                .enumerate()
                .map(|(s, g)| ProbeTask {
                    g,
                    frags: plan.fragments(s),
                    base: plan.starts[s],
                })
                .collect();
            pool.map_mut(&mut tasks, |t| {
                let mut out = Vec::with_capacity(t.frags.len());
                for f in t.frags {
                    let lo = f.start - t.base;
                    let mut ps = Vec::new();
                    unscale_grad_sq_segments(&mut t.g[lo..lo + f.len], inv_scale, |p| {
                        ps.push(p)
                    });
                    out.push((f.block, ps));
                }
                out
            })
        };
    let g2 = combine_block_g2(nb, &parts);
    g2.iter().all(|x| x.is_finite()).then_some(g2)
}

pub(crate) fn lans_step_parallel(
    o: &mut Lans,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    let plan = balanced_plan(&o.table, pool.threads());
    lans_step_on_plan_g2(o, pool, &plan, params, grads, lr, None)
}

/// One LANS step on an explicit work grid.  `step_parallel` uses the
/// balanced grid; the `optimizer_step` bench also drives the degenerate
/// [`ShardPlan::per_block`] grid through here to measure the old
/// largest-block ceiling.  Bit-identical to the serial step for any plan.
pub fn lans_step_on_plan(
    o: &mut Lans,
    pool: &ThreadPool,
    plan: &ShardPlan,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    lans_step_on_plan_g2(o, pool, plan, params, grads, lr, None)
}

/// LANS step with the probe's per-block grad² handed in as phase A — the
/// loss-scaled path ([`Optimizer::step_scaled`]) computed it during the
/// fused unscale sweep, so the engine must not re-read the gradient.
pub(crate) fn lans_step_with_g2(
    o: &mut Lans,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
    g2: Vec<f64>,
) -> StepStats {
    let plan = balanced_plan(&o.table, pool.threads());
    lans_step_on_plan_g2(o, pool, &plan, params, grads, lr, Some(g2))
}

fn lans_step_on_plan_g2(
    o: &mut Lans,
    pool: &ThreadPool,
    plan: &ShardPlan,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
    g2: Option<Vec<f64>>,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let mut tasks = build_seg_tasks(
        plan,
        params,
        grads,
        &mut o.m,
        &mut o.v,
        &mut o.r_full,
        Some(&mut o.c_full),
    );
    segmented_step(Algo::Lans, &cx, o.hp, &o.table, pool, &mut tasks, g2)
}

pub(crate) fn lamb_step_parallel(
    o: &mut Lamb,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    let plan = balanced_plan(&o.table, pool.threads());
    lamb_step_on_plan(o, pool, &plan, params, grads, lr)
}

/// One LAMB step on an explicit work grid (see [`lans_step_on_plan`]).
pub fn lamb_step_on_plan(
    o: &mut Lamb,
    pool: &ThreadPool,
    plan: &ShardPlan,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let mut tasks =
        build_seg_tasks(plan, params, grads, &mut o.m, &mut o.v, &mut o.u_full, None);
    segmented_step(Algo::Lamb, &cx, o.hp, &o.table, pool, &mut tasks, None)
}

pub(crate) fn adamw_step_parallel(
    o: &mut AdamW,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
) -> StepStats {
    adamw_step_parallel_g2(o, pool, params, grads, lr, None)
}

/// AdamW step with the probe's per-block grad² handed in
/// ([`Optimizer::step_scaled`] folded it during the fused unscale sweep):
/// the bgn variant skips its grad² region entirely, the plain variant
/// skips the partial emission inside its fused region — either way the
/// redundant gradient sweep is gone and the folded values are identical
/// by construction (same segment grid, same order).
pub(crate) fn adamw_step_parallel_g2(
    o: &mut AdamW,
    pool: &ThreadPool,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
    g2: Option<Vec<f64>>,
) -> StepStats {
    o.t += 1;
    let cx = AdamCtx::new(o.hp, o.t as i32, lr);
    let hp = o.hp;
    let bgn = o.block_grad_norm;
    let table = &o.table;
    let plan = balanced_plan(table, pool.threads());

    struct Task<'a> {
        x: &'a mut [f32],
        g: &'a [f32],
        m: &'a mut [f32],
        v: &'a mut [f32],
        frags: &'a [Fragment],
        base: usize,
    }
    let w = plan.workers();
    let xs = split_at_plan(&plan, params);
    let ms = split_at_plan(&plan, &mut o.m);
    let vs = split_at_plan(&plan, &mut o.v);
    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(w);
    for (((s, x), m), v) in (0..w).zip(xs).zip(ms).zip(vs) {
        tasks.push(Task {
            x,
            g: &grads[plan.range(s)],
            m,
            v,
            frags: plan.fragments(s),
            base: plan.starts[s],
        });
    }

    let nb = table.blocks.len();
    let (block_g2, maxes) = if bgn {
        // blockwise normalization needs every block's grad² before any
        // element updates: grad² partials (skipped when the scaled-step
        // probe already folded them), then apply
        let block_g2 = match g2 {
            Some(v) => v,
            None => {
                let parts =
                    pool.map_mut(&mut tasks, |t| frag_grad_sq_parts(t.g, t.base, t.frags));
                combine_block_g2(nb, &parts)
            }
        };
        let inv: Vec<f32> = block_g2.iter().map(|&g2| lans_inv_gnorm(g2)).collect();
        let maxes = pool.map_mut(&mut tasks, |t| {
            let mut mx = 0.0f32;
            for f in t.frags {
                let lo = f.start - t.base;
                let hi = lo + f.len;
                let wd = if table.blocks[f.block].decay { hp.weight_decay } else { 0.0 };
                let ma = adamw_apply(
                    &cx,
                    inv[f.block],
                    wd,
                    &mut t.x[lo..hi],
                    &t.g[lo..hi],
                    &mut t.m[lo..hi],
                    &mut t.v[lo..hi],
                );
                mx = mx.max(ma);
            }
            mx
        });
        (block_g2, maxes)
    } else if let Some(v) = g2 {
        // plain AdamW with the probe's grad² in hand: apply-only region
        let maxes = pool.map_mut(&mut tasks, |t| {
            let mut mx = 0.0f32;
            for f in t.frags {
                let lo = f.start - t.base;
                let hi = lo + f.len;
                let wd = if table.blocks[f.block].decay { hp.weight_decay } else { 0.0 };
                let ma = adamw_apply(
                    &cx,
                    1.0,
                    wd,
                    &mut t.x[lo..hi],
                    &t.g[lo..hi],
                    &mut t.m[lo..hi],
                    &mut t.v[lo..hi],
                );
                mx = mx.max(ma);
            }
            mx
        });
        (v, maxes)
    } else {
        // plain AdamW: nothing feeds forward, so one fused region does
        // the element-wise update and emits the grad² stat partials from
        // the same sweep of `g` (no second full-gradient read)
        let outs = pool.map_mut(&mut tasks, |t| {
            let out = frag_grad_sq_parts(t.g, t.base, t.frags);
            let mut mx = 0.0f32;
            for f in t.frags {
                let lo = f.start - t.base;
                let hi = lo + f.len;
                let wd = if table.blocks[f.block].decay { hp.weight_decay } else { 0.0 };
                let ma = adamw_apply(
                    &cx,
                    1.0,
                    wd,
                    &mut t.x[lo..hi],
                    &t.g[lo..hi],
                    &mut t.m[lo..hi],
                    &mut t.v[lo..hi],
                );
                mx = mx.max(ma);
            }
            (mx, out)
        });
        let (maxes, parts): (Vec<f32>, Vec<Vec<(usize, Vec<f64>)>>) =
            outs.into_iter().unzip();
        (combine_block_g2(nb, &parts), maxes)
    };

    StepStats {
        mean_trust_ratio: 1.0,
        max_abs_param: maxes.into_iter().fold(0.0f32, f32::max),
        grad_norm: block_g2.iter().sum::<f64>().sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{make_optimizer, Hyper};
    use crate::util::rng::Rng;

    fn bumpy_table() -> BlockTable {
        // sizes straddle the segment boundary (4096) and include a
        // dominant block, like BERT's word embedding
        BlockTable::new(&[
            ("emb".into(), 9000, true),
            ("k1".into(), 4096, true),
            ("b1".into(), 17, false),
            ("k2".into(), 1500, true),
            ("ln".into(), 1, false),
        ])
    }

    #[test]
    fn executor_serial_and_parallel_agree() {
        let table = bumpy_table();
        let mut rng = Rng::new(42);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        // drive step_parallel directly: the table is below the executor's
        // PARALLEL_MIN_ELEMS auto-fallback, and this test is about the
        // parallel kernels themselves
        let pool = ThreadPool::new(4);
        for name in ["lans", "lamb", "adamw", "adamw_bgn", "msgd"] {
            let mut o_serial = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut o_par = make_optimizer(name, table.clone(), Hyper::default()).unwrap();
            let mut xs = x0.clone();
            let mut xp = x0.clone();
            for step in 0..3 {
                // identical gradient stream for both paths
                let g: Vec<f32> =
                    (0..table.total).map(|_| rng.normal_f32()).collect();
                let lr = 0.01 + 0.002 * step as f32;
                let s_ser = o_serial.step(&mut xs, &g, lr);
                let s_par = o_par.step_parallel(&pool, &mut xp, &g, lr);
                // same segment kernels, same fold order ⇒ exact equality
                assert_eq!(
                    s_ser.mean_trust_ratio, s_par.mean_trust_ratio,
                    "{name}: trust mismatch"
                );
                assert_eq!(s_ser.grad_norm, s_par.grad_norm, "{name}: grad norm mismatch");
                assert_eq!(
                    s_ser.max_abs_param, s_par.max_abs_param,
                    "{name}: max abs mismatch"
                );
            }
            assert_eq!(xs, xp, "{name}: params diverged");
        }
    }

    #[test]
    fn per_block_grid_matches_balanced_grid() {
        // the bench baseline must still be *correct* — only slower
        let table = bumpy_table();
        let mut rng = Rng::new(7);
        let x0: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..table.total).map(|_| rng.normal_f32()).collect();
        let pool = ThreadPool::new(4);
        let hp = Hyper::default();
        let mut a = Lans::new(table.clone(), hp);
        let mut b = Lans::new(table.clone(), hp);
        let mut xa = x0.clone();
        let mut xb = x0;
        let block_plan = ShardPlan::per_block(&table);
        let balanced = ShardPlan::build(&table, 13);
        let sa = lans_step_on_plan(&mut a, &pool, &block_plan, &mut xa, &g, 0.01);
        let sb = lans_step_on_plan(&mut b, &pool, &balanced, &mut xb, &g, 0.01);
        assert_eq!(xa, xb);
        assert_eq!(sa.grad_norm, sb.grad_norm);
        assert_eq!(sa.mean_trust_ratio, sb.mean_trust_ratio);
    }

    #[test]
    fn serial_executor_never_spawns_path() {
        let table = bumpy_table();
        let exec = ParallelExecutor::serial();
        assert_eq!(exec.threads(), 1);
        let mut opt = make_optimizer("lans", table.clone(), Hyper::default()).unwrap();
        let mut x = vec![0.1f32; table.total];
        let g = vec![0.01f32; table.total];
        let stats = exec.step(opt.as_mut(), &mut x, &g, 0.01);
        assert!(stats.grad_norm > 0.0);
    }
}
