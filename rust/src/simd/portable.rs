//! Portable scalar backend — the **canonical definition** of every SIMD
//! kernel (DESIGN.md §11).  The AVX2/NEON modules must reproduce these
//! bits exactly; the differential tests in `simd::tests` and
//! `tests/proptests.rs` assert it.
//!
//! Conversions delegate per element to the scalar bit algorithms in
//! `precision::half` (their golden-vector tests are the ground truth).
//! Reductions implement the lane-grid fold: element `i` accumulates into
//! lane `i % LANES`, lanes combine sequentially at the end — see the
//! module docs on `simd` for why the canonical order is lane-strided.
//!
//! The `*_span` helpers run the elementwise body over a sub-range while
//! folding into caller-owned lane accumulators.  They are the single home
//! of the scalar arithmetic: the vector backends call them for tail
//! elements (tails start at a multiple of [`LANES`], so the lane a tail
//! element lands in is just its offset from the tail start), which keeps
//! the scalar and vector paths literally the same code wherever a loop
//! doesn't fill a register.

use crate::precision::half::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
};

use super::{fold_f32, fold_f64, fold_max, AdamK, LANES};

/// `maxps` semantics: strictly-greater replaces, so a NaN candidate never
/// wins.  Identical to `f32::max` on finite values.
#[inline]
pub(crate) fn max2(acc: f32, v: f32) -> f32 {
    if v > acc {
        v
    } else {
        acc
    }
}

// ------------------------------------------------------ conversions ------

pub fn narrow_f16(src: &[f32], out: &mut [u16]) {
    for (o, &x) in out.iter_mut().zip(src) {
        *o = f32_to_f16_bits(x);
    }
}

pub fn narrow_bf16(src: &[f32], out: &mut [u16]) {
    for (o, &x) in out.iter_mut().zip(src) {
        *o = f32_to_bf16_bits(x);
    }
}

pub fn widen_f16(bits: &[u16], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(bits) {
        *o = f16_bits_to_f32(b);
    }
}

pub fn widen_bf16(bits: &[u16], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(bits) {
        *o = bf16_bits_to_f32(b);
    }
}

pub fn accum_widened_f16(bits: &[u16], dst: &mut [f32]) {
    for (d, &b) in dst.iter_mut().zip(bits) {
        *d += f16_bits_to_f32(b);
    }
}

pub fn accum_widened_bf16(bits: &[u16], dst: &mut [f32]) {
    for (d, &b) in dst.iter_mut().zip(bits) {
        *d += bf16_bits_to_f32(b);
    }
}

pub fn accum_quantized_f16(src: &[f32], dst: &mut [f32]) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += f16_bits_to_f32(f32_to_f16_bits(x));
    }
}

pub fn accum_quantized_bf16(src: &[f32], dst: &mut [f32]) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += bf16_bits_to_f32(f32_to_bf16_bits(x));
    }
}

pub fn round_f16(seg: &mut [f32]) {
    for x in seg.iter_mut() {
        *x = f16_bits_to_f32(f32_to_f16_bits(*x));
    }
}

pub fn round_bf16(seg: &mut [f32]) {
    for x in seg.iter_mut() {
        *x = bf16_bits_to_f32(f32_to_bf16_bits(*x));
    }
}

// ------------------------------------------------------- reductions ------

/// Lane-grid Σ g² over `g`, folding into `acc` starting at lane
/// `lane0 % LANES` — the tail continuation the vector backends share.
#[inline]
pub(crate) fn sum_sq_span(g: &[f32], lane0: usize, acc: &mut [f64; LANES]) {
    for (i, &gi) in g.iter().enumerate() {
        let v = gi as f64;
        acc[(lane0 + i) % LANES] += v * v;
    }
}

pub fn sum_sq(g: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    sum_sq_span(g, 0, &mut acc);
    fold_f64(acc)
}

/// Fused unscale + Σ g² span (squares the *stored* unscaled f32 value,
/// exactly like the old fused scalar sweep).
#[inline]
pub(crate) fn unscale_sum_sq_span(
    g: &mut [f32],
    inv_scale: f32,
    lane0: usize,
    acc: &mut [f64; LANES],
) {
    for (i, gi) in g.iter_mut().enumerate() {
        *gi *= inv_scale;
        let v = *gi as f64;
        acc[(lane0 + i) % LANES] += v * v;
    }
}

pub fn unscale_sum_sq(g: &mut [f32], inv_scale: f32) -> f64 {
    let mut acc = [0.0f64; LANES];
    unscale_sum_sq_span(g, inv_scale, 0, &mut acc);
    fold_f64(acc)
}

// ------------------------------------------------- optimizer sweeps ------

/// LANS elementwise body + lane-grid norm accumulation over a sub-range.
/// The operation order transcribes `optim::native`'s historical scalar
/// loop exactly (two muls + add for each moment, `sqrt` then `+eps` then
/// one reciprocal shared by r and c).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn lans_span(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    rf: &mut [f32],
    cf: &mut [f32],
    lane0: usize,
    fx: &mut [f32; LANES],
    fr: &mut [f32; LANES],
    fc: &mut [f32; LANES],
) {
    for i in 0..x.len() {
        let j = (lane0 + i) % LANES;
        let xi = x[i];
        let gt = g[i] * k.inv_gnorm;
        let mn = k.beta1 * m[i] + (1.0 - k.beta1) * gt;
        let vn = k.beta2 * v[i] + (1.0 - k.beta2) * gt * gt;
        m[i] = mn;
        v[i] = vn;
        let inv_denom = 1.0 / ((vn * k.inv_bc2).sqrt() + k.eps);
        let r = mn * k.inv_bc1 * inv_denom + k.wd * xi;
        let c = gt * inv_denom + k.wd * xi;
        rf[i] = r;
        cf[i] = c;
        fx[j] += xi * xi;
        fr[j] += r * r;
        fc[j] += c * c;
    }
}

#[allow(clippy::too_many_arguments)]
pub fn lans_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    rf: &mut [f32],
    cf: &mut [f32],
) -> (f64, f64, f64) {
    let (mut fx, mut fr, mut fc) = ([0.0f32; LANES], [0.0f32; LANES], [0.0f32; LANES]);
    lans_span(k, x, g, m, v, rf, cf, 0, &mut fx, &mut fr, &mut fc);
    (fold_f32(fx) as f64, fold_f32(fr) as f64, fold_f32(fc) as f64)
}

/// LAMB elementwise body + per-element f64 lane accumulation.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn lamb_span(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
    lane0: usize,
    sx2: &mut [f64; LANES],
    su2: &mut [f64; LANES],
    sg2: &mut [f64; LANES],
) {
    for i in 0..x.len() {
        let j = (lane0 + i) % LANES;
        let gi = g[i];
        let xi = x[i];
        let mn = k.beta1 * m[i] + (1.0 - k.beta1) * gi;
        let vn = k.beta2 * v[i] + (1.0 - k.beta2) * gi * gi;
        m[i] = mn;
        v[i] = vn;
        let un = mn * k.inv_bc1 / ((vn * k.inv_bc2).sqrt() + k.eps) + k.wd * xi;
        u[i] = un;
        sg2[j] += (gi as f64) * (gi as f64);
        sx2[j] += (xi as f64) * (xi as f64);
        su2[j] += (un as f64) * (un as f64);
    }
}

pub fn lamb_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
) -> (f64, f64, f64) {
    let (mut sx2, mut su2, mut sg2) =
        ([0.0f64; LANES], [0.0f64; LANES], [0.0f64; LANES]);
    lamb_span(k, x, g, m, v, u, 0, &mut sx2, &mut su2, &mut sg2);
    (fold_f64(sx2), fold_f64(su2), fold_f64(sg2))
}

/// AdamW fused moment+apply body with the lane-grid max fold.
#[inline]
pub(crate) fn adamw_span(
    k: &AdamK,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lane0: usize,
    ma: &mut [f32; LANES],
) {
    for i in 0..x.len() {
        let j = (lane0 + i) % LANES;
        let gn = g[i] * k.inv_gnorm;
        let mn = k.beta1 * m[i] + (1.0 - k.beta1) * gn;
        let vn = k.beta2 * v[i] + (1.0 - k.beta2) * gn * gn;
        m[i] = mn;
        v[i] = vn;
        let upd = mn * k.inv_bc1 / ((vn * k.inv_bc2).sqrt() + k.eps) + k.wd * x[i];
        x[i] -= k.lr * upd;
        ma[j] = max2(ma[j], x[i].abs());
    }
}

pub fn adamw_segment(
    k: &AdamK,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) -> f32 {
    let mut ma = [0.0f32; LANES];
    adamw_span(k, x, g, m, v, 0, &mut ma);
    fold_max(ma)
}

/// LANS apply body with the lane-grid max fold.
#[inline]
pub(crate) fn lans_apply_span(
    coef_r: f32,
    coef_c: f32,
    x: &mut [f32],
    rf: &[f32],
    cf: &[f32],
    lane0: usize,
    ma: &mut [f32; LANES],
) {
    for i in 0..x.len() {
        let j = (lane0 + i) % LANES;
        x[i] -= coef_r * rf[i] + coef_c * cf[i];
        ma[j] = max2(ma[j], x[i].abs());
    }
}

pub fn lans_apply(coef_r: f32, coef_c: f32, x: &mut [f32], rf: &[f32], cf: &[f32]) -> f32 {
    let mut ma = [0.0f32; LANES];
    lans_apply_span(coef_r, coef_c, x, rf, cf, 0, &mut ma);
    fold_max(ma)
}

/// LAMB apply body with the lane-grid max fold.
#[inline]
pub(crate) fn axpy_max_span(
    coef: f32,
    x: &mut [f32],
    u: &[f32],
    lane0: usize,
    ma: &mut [f32; LANES],
) {
    for i in 0..x.len() {
        let j = (lane0 + i) % LANES;
        x[i] -= coef * u[i];
        ma[j] = max2(ma[j], x[i].abs());
    }
}

pub fn axpy_max(coef: f32, x: &mut [f32], u: &[f32]) -> f32 {
    let mut ma = [0.0f32; LANES];
    axpy_max_span(coef, x, u, 0, &mut ma);
    fold_max(ma)
}
