//! Runtime-dispatched SIMD kernels for the byte-level hot loops: batch
//! `f32 ↔ f16/bf16` wire conversion, the fused quantize-accumulate /
//! round-trip hop kernels, and the segmented grad²/moment/apply sweeps
//! behind `optim::native` (ROADMAP item: conversion and grad² at memory
//! bandwidth; DESIGN.md §11).
//!
//! Three backends, one contract:
//!
//! * [`portable`] — safe scalar Rust, and the **canonical definition** of
//!   every kernel.  The other backends must reproduce its bits exactly.
//! * `avx2` (x86_64) — 8-lane integer/float vectors.  Conversions are
//!   pure integer SIMD transcribing the scalar algorithms in
//!   `precision::half` branch-free (hardware `vcvtps2ph` would quiet
//!   signaling NaNs and break the exhaustive widen test, so it is *not*
//!   used).  Float kernels replicate the scalar operation order — no FMA,
//!   and `sqrt`/`div` are IEEE correctly rounded — so every elementwise
//!   result is bit-identical.
//! * `neon` (aarch64) — conversions and the grad² sweeps (the byte-level
//!   loops); the moment/apply sweeps fall back to [`portable`] there.
//!
//! The backend is detected once (`is_x86_feature_detected!("avx2")` on
//! x86_64; NEON is baseline on aarch64) and cached in an atomic, so
//! dispatch costs one relaxed load per *batch* call — never per element.
//! Setting `LANS_FORCE_SCALAR=1` in the environment forces [`portable`]
//! everywhere (the CI fallback leg runs the whole suite this way).
//!
//! ## The lane-grid reduction contract
//!
//! A sequential `acc += x[i]²` fold cannot be vectorized bit-identically,
//! so the *canonical in-segment fold order* is defined lane-strided: every
//! reduction keeps [`LANES`] = 8 accumulators, element `i` folds into lane
//! `i % 8`, and the lanes combine sequentially (lane 0 first) when the
//! segment ends.  [`portable`] implements exactly that with plain arrays;
//! AVX2 holds the same lanes in registers (two `__m256d` for f64 grids,
//! one `__m256` for f32 grids) and NEON in four `float64x2_t` — same
//! grid, same fold, same bits.  Cross-path bit-identity (serial ==
//! parallel == sharded == bucketed) is untouched because every path calls
//! these kernels through their single home in `optim::native`; the
//! SIMD == portable equality is what the exhaustive and lane-remainder
//! differential tests in this module pin.
//!
//! Max-folds (`|param|` after apply) use the same lane grid with
//! `if v > acc { acc = v }` semantics (what `maxps` computes) — identical
//! to the old sequential `f32::max` fold on the finite values the
//! optimizer produces.

pub mod portable;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

use std::sync::atomic::{AtomicU8, Ordering};

/// Width of the canonical reduction lane grid (elements `i` fold into
/// accumulator lane `i % LANES` within a segment).
pub const LANES: usize = 8;

/// Which kernel implementation the process dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Safe scalar Rust — the canonical reference (and the
    /// `LANS_FORCE_SCALAR=1` path).
    Scalar,
    /// x86_64 with AVX2 detected at runtime.
    Avx2,
    /// aarch64 (NEON is baseline); moment/apply sweeps still run
    /// [`portable`].
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

const B_UNKNOWN: u8 = 0;
const B_SCALAR: u8 = 1;
const B_AVX2: u8 = 2;
const B_NEON: u8 = 3;

static BACKEND: AtomicU8 = AtomicU8::new(B_UNKNOWN);

fn force_scalar_env() -> bool {
    std::env::var("LANS_FORCE_SCALAR")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

fn detect() -> u8 {
    if force_scalar_env() {
        return B_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        return B_AVX2;
    }
    #[cfg(target_arch = "aarch64")]
    return B_NEON;
    #[allow(unreachable_code)]
    B_SCALAR
}

/// The dispatched backend, detected once per process and cached.
#[inline]
pub fn backend() -> Backend {
    let mut b = BACKEND.load(Ordering::Relaxed);
    if b == B_UNKNOWN {
        b = detect();
        BACKEND.store(b, Ordering::Relaxed);
    }
    match b {
        B_AVX2 => Backend::Avx2,
        B_NEON => Backend::Neon,
        _ => Backend::Scalar,
    }
}

// ------------------------------------------------------------- folds ------

/// Sequential (lane 0 first) combine of an f64 lane grid — the one fold
/// order every backend shares.
#[inline]
pub(crate) fn fold_f64(acc: [f64; LANES]) -> f64 {
    let mut s = acc[0];
    for &a in &acc[1..] {
        s += a;
    }
    s
}

/// Sequential combine of an f32 lane grid.
#[inline]
pub(crate) fn fold_f32(acc: [f32; LANES]) -> f32 {
    let mut s = acc[0];
    for &a in &acc[1..] {
        s += a;
    }
    s
}

/// Sequential max-combine of an f32 lane grid (`maxps` semantics:
/// `if v > acc { acc = v }`).
#[inline]
pub(crate) fn fold_max(acc: [f32; LANES]) -> f32 {
    let mut s = acc[0];
    for &a in &acc[1..] {
        if a > s {
            s = a;
        }
    }
    s
}

// ------------------------------------------------- per-step constants ----

/// Per-segment constants of the Adam-family sweeps, hoisted once per step
/// (`optim::native::AdamCtx` plus the per-block factors).  One struct
/// serves LANS (`inv_gnorm`, `wd`), LAMB (`wd`) and AdamW (`inv_gnorm`,
/// `wd`, `lr`).
#[derive(Debug, Clone, Copy)]
pub struct AdamK {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub inv_bc1: f32,
    pub inv_bc2: f32,
    pub lr: f32,
    pub wd: f32,
    pub inv_gnorm: f32,
}

// ------------------------------------------------------ conversions ------

macro_rules! dispatch_conv {
    ($name:ident, $($arg:expr),*) => {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Backend::Avx2 is only returned when
            // is_x86_feature_detected!("avx2") held at detection.
            Backend::Avx2 => unsafe { avx2::$name($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is a baseline feature of every aarch64 target.
            Backend::Neon => unsafe { neon::$name($($arg),*) },
            _ => portable::$name($($arg),*),
        }
    };
}

/// Batch `f32 → f16` (round-to-nearest-even, overflow → ±inf) —
/// bit-identical to `precision::half::f32_to_f16_bits` per element.
#[inline]
pub fn narrow_f16(src: &[f32], out: &mut [u16]) {
    assert_eq!(src.len(), out.len(), "narrow_f16 length mismatch");
    dispatch_conv!(narrow_f16, src, out)
}

/// Batch `f32 → bf16` — bit-identical to
/// `precision::half::f32_to_bf16_bits` per element.
#[inline]
pub fn narrow_bf16(src: &[f32], out: &mut [u16]) {
    assert_eq!(src.len(), out.len(), "narrow_bf16 length mismatch");
    dispatch_conv!(narrow_bf16, src, out)
}

/// Batch `f16 → f32` widening (exact; NaN payloads preserved bit-exactly).
#[inline]
pub fn widen_f16(bits: &[u16], out: &mut [f32]) {
    assert_eq!(bits.len(), out.len(), "widen_f16 length mismatch");
    dispatch_conv!(widen_f16, bits, out)
}

/// Batch `bf16 → f32` widening (exact).
#[inline]
pub fn widen_bf16(bits: &[u16], out: &mut [f32]) {
    assert_eq!(bits.len(), out.len(), "widen_bf16 length mismatch");
    dispatch_conv!(widen_bf16, bits, out)
}

/// Fused ring-hop receive: `dst[i] += widen(bits[i])` — the batch form of
/// the `iter_f32` accumulate loop, no intermediate f32 buffer.
#[inline]
pub fn accum_widened_f16(bits: &[u16], dst: &mut [f32]) {
    assert_eq!(bits.len(), dst.len(), "accum_widened_f16 length mismatch");
    dispatch_conv!(accum_widened_f16, bits, dst)
}

/// Fused ring-hop receive for bf16 wires.
#[inline]
pub fn accum_widened_bf16(bits: &[u16], dst: &mut [f32]) {
    assert_eq!(bits.len(), dst.len(), "accum_widened_bf16 length mismatch");
    dispatch_conv!(accum_widened_bf16, bits, dst)
}

/// Fused in-process ring hop: `dst[i] += dq(q(src[i]))` at f16 — quantize
/// and widen stay in registers, so a hop allocates nothing.
#[inline]
pub fn accum_quantized_f16(src: &[f32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "accum_quantized_f16 length mismatch");
    dispatch_conv!(accum_quantized_f16, src, dst)
}

/// Fused in-process ring hop at bf16.
#[inline]
pub fn accum_quantized_bf16(src: &[f32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "accum_quantized_bf16 length mismatch");
    dispatch_conv!(accum_quantized_bf16, src, dst)
}

/// In-place `x[i] = dq(q(x[i]))` at f16 — the all-gather owner adoption.
#[inline]
pub fn round_f16(seg: &mut [f32]) {
    dispatch_conv!(round_f16, seg)
}

/// In-place round trip at bf16.
#[inline]
pub fn round_bf16(seg: &mut [f32]) {
    dispatch_conv!(round_bf16, seg)
}

// ------------------------------------------------------- reductions ------

/// Σ g² of one segment on the canonical lane grid, folded to f64.
#[inline]
pub fn sum_sq(g: &[f32]) -> f64 {
    dispatch_conv!(sum_sq, g)
}

/// Fused `g[i] *= inv_scale` + Σ g² of the *unscaled* values — one pass
/// serves the overflow probe and the block norms.
#[inline]
pub fn unscale_sum_sq(g: &mut [f32], inv_scale: f32) -> f64 {
    dispatch_conv!(unscale_sum_sq, g, inv_scale)
}

macro_rules! dispatch_x86 {
    ($name:ident, $($arg:expr),*) => {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Backend::Avx2 implies AVX2 was detected.
            Backend::Avx2 => unsafe { avx2::$name($($arg),*) },
            _ => portable::$name($($arg),*),
        }
    };
}

/// LANS moment/direction sweep of one segment (eq. 4 normalization,
/// moment update, cached r/c directions); returns the segment's
/// (Σx², Σr², Σc²) lane-grid partials folded to f64.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn lans_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    rf: &mut [f32],
    cf: &mut [f32],
) -> (f64, f64, f64) {
    dispatch_x86!(lans_segment, k, x, g, m, v, rf, cf)
}

/// LAMB moment/direction sweep of one segment; returns (Σx², Σu², Σg²)
/// accumulated per element in f64 on the lane grid.
#[inline]
pub fn lamb_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
) -> (f64, f64, f64) {
    dispatch_x86!(lamb_segment, k, x, g, m, v, u)
}

/// AdamW fused moment+apply sweep over any range; returns max |param|.
#[inline]
pub fn adamw_segment(
    k: &AdamK,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) -> f32 {
    dispatch_x86!(adamw_segment, k, x, g, m, v)
}

/// LANS apply: `x -= coef_r·rf + coef_c·cf`; returns max |param|.
#[inline]
pub fn lans_apply(coef_r: f32, coef_c: f32, x: &mut [f32], rf: &[f32], cf: &[f32]) -> f32 {
    dispatch_x86!(lans_apply, coef_r, coef_c, x, rf, cf)
}

/// LAMB apply: `x -= coef·u`; returns max |param|.
#[inline]
pub fn axpy_max(coef: f32, x: &mut [f32], u: &[f32]) -> f32 {
    dispatch_x86!(axpy_max, coef, x, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::half::{
        bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
    };
    use crate::util::rng::Rng;

    // The differential harness: run `f` against the live dispatched
    // backend AND (on x86_64 with AVX2) explicitly against the avx2
    // module, so the SIMD == portable assertions hold even when the
    // force-scalar knob redirects the dispatcher.

    fn interesting_f32(rng: &mut Rng) -> f32 {
        match rng.next_u64() % 10 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            4 => f32::NAN,
            5 => f32::from_bits(rng.next_u64() as u32), // arbitrary bits
            6 => (rng.next_u64() % 131072) as f32 - 65536.0, // f16 overflow edge
            7 => rng.normal_f32() * 1e-6,               // subnormal-ish after narrow
            8 => rng.normal_f32() * 1e38,
            _ => rng.normal_f32(),
        }
    }

    #[test]
    fn backend_is_cached_and_named() {
        let b = backend();
        assert_eq!(b, backend(), "detection must be stable");
        assert!(["scalar", "avx2", "neon"].contains(&b.name()));
    }

    #[test]
    fn exhaustive_widen_f16_matches_scalar_all_patterns() {
        // all 2^16 bit patterns in one batch call (main loop, no tail) …
        let bits: Vec<u16> = (0..=u16::MAX).collect();
        let mut out = vec![0.0f32; bits.len()];
        widen_f16(&bits, &mut out);
        for (h, o) in bits.iter().zip(&out) {
            assert_eq!(
                o.to_bits(),
                f16_bits_to_f32(*h).to_bits(),
                "f16 widen pattern {h:#06x}"
            );
        }
        // … and through the portable reference explicitly
        let mut port = vec![0.0f32; bits.len()];
        portable::widen_f16(&bits, &mut port);
        for (h, (a, b)) in bits.iter().zip(out.iter().zip(&port)) {
            assert_eq!(a.to_bits(), b.to_bits(), "f16 widen pattern {h:#06x}");
        }
    }

    #[test]
    fn exhaustive_widen_bf16_matches_scalar_all_patterns() {
        let bits: Vec<u16> = (0..=u16::MAX).collect();
        let mut out = vec![0.0f32; bits.len()];
        widen_bf16(&bits, &mut out);
        for (h, o) in bits.iter().zip(&out) {
            assert_eq!(
                o.to_bits(),
                bf16_bits_to_f32(*h).to_bits(),
                "bf16 widen pattern {h:#06x}"
            );
        }
    }

    #[test]
    fn exhaustive_narrow_roundtrip_all_half_patterns() {
        // every representable half value is a fixed point of the SIMD
        // narrow — covers all normal/subnormal/inf/nan narrow classes
        let bits: Vec<u16> = (0..=u16::MAX).collect();
        let mut wide = vec![0.0f32; bits.len()];
        let mut back = vec![0u16; bits.len()];
        widen_f16(&bits, &mut wide);
        narrow_f16(&wide, &mut back);
        for (h, b) in bits.iter().zip(&back) {
            assert_eq!(*b, f32_to_f16_bits(f16_bits_to_f32(*h)), "f16 {h:#06x}");
        }
        widen_bf16(&bits, &mut wide);
        narrow_bf16(&wide, &mut back);
        for (h, b) in bits.iter().zip(&back) {
            assert_eq!(*b, f32_to_bf16_bits(bf16_bits_to_f32(*h)), "bf16 {h:#06x}");
        }
    }

    #[test]
    fn narrow_matches_scalar_every_lane_remainder_and_offset() {
        // n mod LANES ∈ 0..LANES and unaligned slice offsets 0..LANES —
        // the tail path and misaligned loads must agree with the scalar
        let mut rng = Rng::new(0x51D0);
        let buf: Vec<f32> = (0..4 * LANES + LANES).map(|_| interesting_f32(&mut rng)).collect();
        for off in 0..LANES {
            for rem in 0..LANES {
                let n = 3 * LANES + rem;
                let src = &buf[off..off + n];
                let mut got = vec![0u16; n];
                narrow_f16(src, &mut got);
                for (i, (&x, &b)) in src.iter().zip(&got).enumerate() {
                    assert_eq!(b, f32_to_f16_bits(x), "f16 off={off} rem={rem} i={i}");
                }
                narrow_bf16(src, &mut got);
                for (i, (&x, &b)) in src.iter().zip(&got).enumerate() {
                    assert_eq!(b, f32_to_bf16_bits(x), "bf16 off={off} rem={rem} i={i}");
                }
            }
        }
    }

    #[test]
    fn fused_hop_kernels_match_their_composition() {
        let mut rng = Rng::new(0xACC0);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let src: Vec<f32> = (0..n).map(|_| interesting_f32(&mut rng)).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

            // accum_quantized == narrow → widen → add, elementwise
            let mut dst = base.clone();
            accum_quantized_f16(&src, &mut dst);
            for i in 0..n {
                let want = base[i] + f16_bits_to_f32(f32_to_f16_bits(src[i]));
                assert_eq!(dst[i].to_bits(), want.to_bits(), "aq f16 n={n} i={i}");
            }
            let mut dst = base.clone();
            accum_quantized_bf16(&src, &mut dst);
            for i in 0..n {
                let want = base[i] + bf16_bits_to_f32(f32_to_bf16_bits(src[i]));
                assert_eq!(dst[i].to_bits(), want.to_bits(), "aq bf16 n={n} i={i}");
            }

            // accum_widened == widen → add
            let bits: Vec<u16> = src.iter().map(|&x| f32_to_f16_bits(x)).collect();
            let mut dst = base.clone();
            accum_widened_f16(&bits, &mut dst);
            for i in 0..n {
                let want = base[i] + f16_bits_to_f32(bits[i]);
                assert_eq!(dst[i].to_bits(), want.to_bits(), "aw f16 n={n} i={i}");
            }

            // round == narrow → widen in place
            let mut seg = src.clone();
            round_f16(&mut seg);
            for i in 0..n {
                let want = f16_bits_to_f32(f32_to_f16_bits(src[i]));
                assert_eq!(seg[i].to_bits(), want.to_bits(), "round f16 n={n} i={i}");
            }
            let mut seg = src.clone();
            round_bf16(&mut seg);
            for i in 0..n {
                let want = bf16_bits_to_f32(f32_to_bf16_bits(src[i]));
                assert_eq!(seg[i].to_bits(), want.to_bits(), "round bf16 n={n} i={i}");
            }
        }
    }

    #[test]
    fn sum_sq_matches_portable_every_remainder() {
        let mut rng = Rng::new(0x5E6);
        let buf: Vec<f32> = (0..6 * LANES).map(|_| rng.normal_f32() * 3.0).collect();
        for off in 0..LANES {
            for n in 0..4 * LANES {
                let g = &buf[off..off + n];
                let got = sum_sq(g);
                let want = portable::sum_sq(g);
                assert_eq!(got.to_bits(), want.to_bits(), "off={off} n={n}");
            }
        }
    }

    #[test]
    fn unscale_sum_sq_matches_portable_and_unscales_in_place() {
        let mut rng = Rng::new(0xD15);
        for n in [0usize, 5, 8, 17, 4096, 4100] {
            let g0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let inv = 0.25f32; // exact power of two
            let mut a = g0.clone();
            let mut b = g0.clone();
            let sa = unscale_sum_sq(&mut a, inv);
            let sb = portable::unscale_sum_sq(&mut b, inv);
            assert_eq!(sa.to_bits(), sb.to_bits(), "n={n}");
            assert_eq!(a, b, "n={n}");
            for (x, x0) in a.iter().zip(&g0) {
                assert_eq!(x.to_bits(), (x0 * inv).to_bits());
            }
        }
    }

    fn test_k() -> AdamK {
        AdamK {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            inv_bc1: 1.0 / (1.0 - 0.9f32),
            inv_bc2: 1.0 / (1.0 - 0.999f32),
            lr: 0.01,
            wd: 0.01,
            inv_gnorm: 0.37,
        }
    }

    #[test]
    fn lans_segment_matches_portable_every_remainder() {
        let k = test_k();
        let mut rng = Rng::new(0x1A45);
        for n in [0usize, 1, 7, 8, 9, 31, 32, 100, 4096] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let m0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
            let v0: Vec<f32> = (0..n).map(|_| rng.normal_f32().abs() * 0.1).collect();
            let (mut m1, mut v1) = (m0.clone(), v0.clone());
            let (mut m2, mut v2) = (m0, v0);
            let (mut rf1, mut cf1) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut rf2, mut cf2) = (vec![0.0f32; n], vec![0.0f32; n]);
            let a = lans_segment(&k, &x, &g, &mut m1, &mut v1, &mut rf1, &mut cf1);
            let b = portable::lans_segment(&k, &x, &g, &mut m2, &mut v2, &mut rf2, &mut cf2);
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "n={n} sx");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "n={n} sr");
            assert_eq!(a.2.to_bits(), b.2.to_bits(), "n={n} sc");
            assert_eq!(m1, m2, "n={n}");
            assert_eq!(v1, v2, "n={n}");
            assert_eq!(rf1, rf2, "n={n}");
            assert_eq!(cf1, cf2, "n={n}");
        }
    }

    #[test]
    fn lamb_and_adamw_and_applies_match_portable() {
        let k = test_k();
        let mut rng = Rng::new(0x1A3B);
        for n in [0usize, 3, 8, 13, 64, 257, 4096] {
            let x0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let m0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
            let v0: Vec<f32> = (0..n).map(|_| rng.normal_f32().abs() * 0.1).collect();

            let (mut m1, mut v1, mut u1) = (m0.clone(), v0.clone(), vec![0.0f32; n]);
            let (mut m2, mut v2, mut u2) = (m0.clone(), v0.clone(), vec![0.0f32; n]);
            let a = lamb_segment(&k, &x0, &g, &mut m1, &mut v1, &mut u1);
            let b = portable::lamb_segment(&k, &x0, &g, &mut m2, &mut v2, &mut u2);
            assert_eq!(
                (a.0.to_bits(), a.1.to_bits(), a.2.to_bits()),
                (b.0.to_bits(), b.1.to_bits(), b.2.to_bits()),
                "lamb n={n}"
            );
            assert_eq!((m1, v1, u1), (m2, v2, u2.clone()), "lamb n={n}");

            let (mut xa, mut ma, mut va) = (x0.clone(), m0.clone(), v0.clone());
            let (mut xb, mut mb, mut vb) = (x0.clone(), m0, v0);
            let a = adamw_segment(&k, &mut xa, &g, &mut ma, &mut va);
            let b = portable::adamw_segment(&k, &mut xb, &g, &mut mb, &mut vb);
            assert_eq!(a.to_bits(), b.to_bits(), "adamw n={n}");
            assert_eq!((xa, ma, va), (xb, mb, vb), "adamw n={n}");

            let (mut xa, mut xb) = (x0.clone(), x0.clone());
            let a = lans_apply(0.01, 0.002, &mut xa, &g, &u2);
            let b = portable::lans_apply(0.01, 0.002, &mut xb, &g, &u2);
            assert_eq!(a.to_bits(), b.to_bits(), "lans_apply n={n}");
            assert_eq!(xa, xb, "lans_apply n={n}");

            let (mut xa, mut xb) = (x0.clone(), x0);
            let a = axpy_max(0.01, &mut xa, &u2);
            let b = portable::axpy_max(0.01, &mut xb, &u2);
            assert_eq!(a.to_bits(), b.to_bits(), "axpy n={n}");
            assert_eq!(xa, xb, "axpy n={n}");
        }
    }

    // ---- explicit AVX2-vs-portable differentials (run whenever the CPU
    // has AVX2, independent of the LANS_FORCE_SCALAR dispatcher state) ----

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_exhaustive_conversions_match_portable() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let bits: Vec<u16> = (0..=u16::MAX).collect();
        let (mut a, mut b) = (vec![0.0f32; bits.len()], vec![0.0f32; bits.len()]);
        unsafe { avx2::widen_f16(&bits, &mut a) };
        portable::widen_f16(&bits, &mut b);
        for (h, (x, y)) in bits.iter().zip(a.iter().zip(&b)) {
            assert_eq!(x.to_bits(), y.to_bits(), "avx2 f16 widen {h:#06x}");
        }
        unsafe { avx2::widen_bf16(&bits, &mut a) };
        portable::widen_bf16(&bits, &mut b);
        for (h, (x, y)) in bits.iter().zip(a.iter().zip(&b)) {
            assert_eq!(x.to_bits(), y.to_bits(), "avx2 bf16 widen {h:#06x}");
        }
        // narrow over every widened half value plus a dense f32 sweep
        // around the f16 subnormal/overflow boundaries
        let mut rng = Rng::new(7);
        let mut xs: Vec<f32> = Vec::with_capacity(1 << 17);
        unsafe { avx2::widen_f16(&bits, &mut a) };
        xs.extend_from_slice(&a);
        for _ in 0..(1 << 16) {
            xs.push(interesting_f32(&mut rng));
        }
        let (mut na, mut nb) = (vec![0u16; xs.len()], vec![0u16; xs.len()]);
        unsafe { avx2::narrow_f16(&xs, &mut na) };
        portable::narrow_f16(&xs, &mut nb);
        assert_eq!(na, nb, "avx2 f16 narrow");
        unsafe { avx2::narrow_bf16(&xs, &mut na) };
        portable::narrow_bf16(&xs, &mut nb);
        assert_eq!(na, nb, "avx2 bf16 narrow");
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_reductions_match_portable() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let k = test_k();
        let mut rng = Rng::new(0xAB2D);
        for n in [0usize, 1, 7, 8, 9, 100, 4095, 4096] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            assert_eq!(
                unsafe { avx2::sum_sq(&g) }.to_bits(),
                portable::sum_sq(&g).to_bits(),
                "sum_sq n={n}"
            );
            let (mut ga, mut gb) = (g.clone(), g.clone());
            let sa = unsafe { avx2::unscale_sum_sq(&mut ga, 0.5) };
            let sb = portable::unscale_sum_sq(&mut gb, 0.5);
            assert_eq!(sa.to_bits(), sb.to_bits(), "unscale n={n}");
            assert_eq!(ga, gb, "unscale n={n}");

            let m0: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
            let v0: Vec<f32> = (0..n).map(|_| rng.normal_f32().abs() * 0.1).collect();
            let (mut m1, mut v1) = (m0.clone(), v0.clone());
            let (mut m2, mut v2) = (m0, v0);
            let (mut rf1, mut cf1) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut rf2, mut cf2) = (vec![0.0f32; n], vec![0.0f32; n]);
            let a = unsafe { avx2::lans_segment(&k, &x, &g, &mut m1, &mut v1, &mut rf1, &mut cf1) };
            let b = portable::lans_segment(&k, &x, &g, &mut m2, &mut v2, &mut rf2, &mut cf2);
            assert_eq!(
                (a.0.to_bits(), a.1.to_bits(), a.2.to_bits()),
                (b.0.to_bits(), b.1.to_bits(), b.2.to_bits()),
                "lans n={n}"
            );
            assert_eq!((m1, v1, rf1, cf1), (m2, v2, rf2, cf2), "lans n={n}");
        }
    }
}
