//! NEON backend (aarch64).  Covers the byte-level loops — the ten batch
//! conversion kernels and the two grad² sweeps; the moment/apply sweeps
//! dispatch to [`portable`](super::portable) on aarch64 (see the policy
//! note in the `simd` module docs).
//!
//! The conversion algorithms are the same branch-free integer transcriptions
//! of `precision::half` as the AVX2 backend, on 4-lane `u32x4` vectors:
//! compute every class (normal / subnormal / inf / nan / zero), then
//! `vbslq` the right one in.  Variable shifts use `vshlq_u32` with negated
//! signed counts (USHL: negative = right shift; out-of-range counts yield
//! 0 on lanes that are blended away anyway).  RNE is the same branch-free
//! `(rem + odd) > half` comparison.
//!
//! The grad² sweeps keep the canonical 8-lane f64 grid in four
//! `float64x2_t` (lanes 0-1, 2-3, 4-5, 6-7) with separate mul/add — no
//! `vfmaq`, which would fuse the rounding — and tails fall through to the
//! shared `portable::*_span` helpers.
//!
//! Safety: NEON is a baseline feature of every aarch64 target; the
//! `#[target_feature]` + `unsafe fn` shape only mirrors the AVX2 module so
//! the dispatch macro treats both alike.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::portable;
use super::{fold_f64, LANES};

// --------------------------------------------------- register helpers ----

/// 4 × f32 → 4 × u16-valued u32 lanes, IEEE f16 narrow with RNE.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn narrow4_f16(x: float32x4_t) -> uint32x4_t {
    let bits = vreinterpretq_u32_f32(x);
    let sign = vshrq_n_u32::<16>(vandq_u32(bits, vdupq_n_u32(0x8000_0000)));
    let exp = vandq_u32(vshrq_n_u32::<23>(bits), vdupq_n_u32(0xFF));
    let man = vandq_u32(bits, vdupq_n_u32(0x007F_FFFF));
    let abs = vandq_u32(bits, vdupq_n_u32(0x7FFF_FFFF));

    // normal range (exp in [113, 142]): rebias, drop 13 bits with RNE
    // (subtracting the all-ones compare mask adds the round increment)
    let base = vorrq_u32(
        vshlq_n_u32::<10>(vsubq_u32(exp, vdupq_n_u32(112))),
        vshrq_n_u32::<13>(man),
    );
    let rem = vandq_u32(man, vdupq_n_u32(0x1FFF));
    let odd = vandq_u32(base, vdupq_n_u32(1));
    let round = vcgtq_u32(vaddq_u32(rem, odd), vdupq_n_u32(0x1000));
    let out_norm = vsubq_u32(base, round);

    // subnormal range (exp in [102, 112]): shift by 126 - exp ∈ [14, 24]
    // with RNE on the dropped bits; other lanes produce garbage that the
    // blends discard
    let full = vorrq_u32(man, vdupq_n_u32(0x0080_0000));
    let shift = vsubq_u32(vdupq_n_u32(126), exp);
    let shift_s = vreinterpretq_s32_u32(shift);
    let kept = vshlq_u32(full, vnegq_s32(shift_s));
    let low_mask = vsubq_u32(vshlq_u32(vdupq_n_u32(1), shift_s), vdupq_n_u32(1));
    let rem_s = vandq_u32(full, low_mask);
    let half = vshlq_u32(
        vdupq_n_u32(1),
        vreinterpretq_s32_u32(vsubq_u32(shift, vdupq_n_u32(1))),
    );
    let odd_s = vandq_u32(kept, vdupq_n_u32(1));
    let round_s = vcgtq_u32(vaddq_u32(rem_s, odd_s), half);
    let out_sub = vsubq_u32(kept, round_s);

    let out_nan = vorrq_u32(
        vdupq_n_u32(0x7E00),
        vandq_u32(vshrq_n_u32::<13>(man), vdupq_n_u32(0x01FF)),
    );

    let is_nan = vcgtq_u32(abs, vdupq_n_u32(0x7F80_0000));
    let lt_102 = vcltq_u32(exp, vdupq_n_u32(102));
    let lt_113 = vcltq_u32(exp, vdupq_n_u32(113));
    let lt_143 = vcltq_u32(exp, vdupq_n_u32(143));
    let is_norm = vbicq_u32(lt_143, lt_113);
    let is_sub = vbicq_u32(lt_113, lt_102);

    let mut r = vdupq_n_u32(0x7C00); // default: exp >= 143 overflows to inf
    r = vbslq_u32(is_norm, out_norm, r);
    r = vbslq_u32(is_sub, out_sub, r);
    r = vbicq_u32(r, lt_102); // exp < 102: underflow to signed zero
    r = vbslq_u32(is_nan, out_nan, r);
    vorrq_u32(sign, r)
}

/// 4 × u16-valued u32 lanes → 4 × f32 bit patterns, exact f16 widen.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen4_f16(v: uint32x4_t) -> uint32x4_t {
    let sign = vshlq_n_u32::<16>(vandq_u32(v, vdupq_n_u32(0x8000)));
    let em = vandq_u32(v, vdupq_n_u32(0x7FFF));
    let shifted = vshlq_n_u32::<13>(em);
    let norm = vaddq_u32(shifted, vdupq_n_u32(0x3800_0000));
    let infnan = vaddq_u32(shifted, vdupq_n_u32(0x7000_0000));
    // subnormals: man * 2^-24 exactly (convert is exact for man <= 1023)
    let man = vandq_u32(v, vdupq_n_u32(0x03FF));
    let subf = vmulq_f32(vcvtq_f32_u32(man), vdupq_n_f32(5.960_464_5e-8)); // 2^-24
    let sub_bits = vreinterpretq_u32_f32(subf);
    let is_infnan = vcgtq_u32(em, vdupq_n_u32(0x7BFF));
    let is_sub = vcltq_u32(em, vdupq_n_u32(0x0400));
    let mut r = vbslq_u32(is_infnan, infnan, norm);
    r = vbslq_u32(is_sub, sub_bits, r);
    vorrq_u32(sign, r)
}

/// 4 × f32 → 4 × u16-valued u32 lanes, bf16 narrow with RNE.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn narrow4_bf16(x: float32x4_t) -> uint32x4_t {
    let bits = vreinterpretq_u32_f32(x);
    let abs = vandq_u32(bits, vdupq_n_u32(0x7FFF_FFFF));
    let is_nan = vcgtq_u32(abs, vdupq_n_u32(0x7F80_0000));
    let lsb = vandq_u32(vshrq_n_u32::<16>(bits), vdupq_n_u32(1));
    let rounded =
        vshrq_n_u32::<16>(vaddq_u32(vaddq_u32(bits, vdupq_n_u32(0x7FFF)), lsb));
    let nan_out = vorrq_u32(vshrq_n_u32::<16>(bits), vdupq_n_u32(0x0040));
    vbslq_u32(is_nan, nan_out, rounded)
}

/// 4 × u16-valued u32 lanes → 4 × f32 bit patterns (bf16 widen).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen4_bf16(v: uint32x4_t) -> uint32x4_t {
    vshlq_n_u32::<16>(v)
}

#[inline]
#[target_feature(enable = "neon")]
unsafe fn load4_u16(p: *const u16) -> uint32x4_t {
    vmovl_u16(vld1_u16(p))
}

#[inline]
#[target_feature(enable = "neon")]
unsafe fn store4_u16(p: *mut u16, v: uint32x4_t) {
    vst1_u16(p, vmovn_u32(v));
}

// ------------------------------------------------------ conversions ------

macro_rules! conv_loops {
    ($narrow:ident, $widen:ident, $accw:ident, $accq:ident, $round:ident,
     $n4:ident, $w4:ident) => {
        #[target_feature(enable = "neon")]
        pub unsafe fn $narrow(src: &[f32], out: &mut [u16]) {
            let n = src.len();
            let mut i = 0;
            while i + 4 <= n {
                store4_u16(out.as_mut_ptr().add(i), $n4(vld1q_f32(src.as_ptr().add(i))));
                i += 4;
            }
            portable::$narrow(&src[i..], &mut out[i..]);
        }

        #[target_feature(enable = "neon")]
        pub unsafe fn $widen(bits: &[u16], out: &mut [f32]) {
            let n = bits.len();
            let mut i = 0;
            while i + 4 <= n {
                let w = vreinterpretq_f32_u32($w4(load4_u16(bits.as_ptr().add(i))));
                vst1q_f32(out.as_mut_ptr().add(i), w);
                i += 4;
            }
            portable::$widen(&bits[i..], &mut out[i..]);
        }

        #[target_feature(enable = "neon")]
        pub unsafe fn $accw(bits: &[u16], dst: &mut [f32]) {
            let n = bits.len();
            let mut i = 0;
            while i + 4 <= n {
                let q = vreinterpretq_f32_u32($w4(load4_u16(bits.as_ptr().add(i))));
                let d = vaddq_f32(vld1q_f32(dst.as_ptr().add(i)), q);
                vst1q_f32(dst.as_mut_ptr().add(i), d);
                i += 4;
            }
            portable::$accw(&bits[i..], &mut dst[i..]);
        }

        #[target_feature(enable = "neon")]
        pub unsafe fn $accq(src: &[f32], dst: &mut [f32]) {
            let n = src.len();
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(src.as_ptr().add(i));
                let q = vreinterpretq_f32_u32($w4($n4(x)));
                let d = vaddq_f32(vld1q_f32(dst.as_ptr().add(i)), q);
                vst1q_f32(dst.as_mut_ptr().add(i), d);
                i += 4;
            }
            portable::$accq(&src[i..], &mut dst[i..]);
        }

        #[target_feature(enable = "neon")]
        pub unsafe fn $round(seg: &mut [f32]) {
            let n = seg.len();
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(seg.as_ptr().add(i));
                let q = vreinterpretq_f32_u32($w4($n4(x)));
                vst1q_f32(seg.as_mut_ptr().add(i), q);
                i += 4;
            }
            portable::$round(&mut seg[i..]);
        }
    };
}

conv_loops!(
    narrow_f16,
    widen_f16,
    accum_widened_f16,
    accum_quantized_f16,
    round_f16,
    narrow4_f16,
    widen4_f16
);
conv_loops!(
    narrow_bf16,
    widen_bf16,
    accum_widened_bf16,
    accum_quantized_bf16,
    round_bf16,
    narrow4_bf16,
    widen4_bf16
);

// ------------------------------------------------------- reductions ------

/// The canonical 8-lane f64 grid as four 2-lane vectors: `(lanes 0-1,
/// 2-3, 4-5, 6-7)` from two consecutive f32x4 loads.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn sq_acc(
    acc: &mut [float64x2_t; 4],
    v0: float32x4_t,
    v1: float32x4_t,
) {
    let d0 = vcvt_f64_f32(vget_low_f32(v0));
    let d1 = vcvt_high_f64_f32(v0);
    let d2 = vcvt_f64_f32(vget_low_f32(v1));
    let d3 = vcvt_high_f64_f32(v1);
    acc[0] = vaddq_f64(acc[0], vmulq_f64(d0, d0));
    acc[1] = vaddq_f64(acc[1], vmulq_f64(d1, d1));
    acc[2] = vaddq_f64(acc[2], vmulq_f64(d2, d2));
    acc[3] = vaddq_f64(acc[3], vmulq_f64(d3, d3));
}

#[inline]
#[target_feature(enable = "neon")]
unsafe fn store_grid(acc: [float64x2_t; 4]) -> [f64; LANES] {
    let mut out = [0.0f64; LANES];
    for (j, a) in acc.iter().enumerate() {
        vst1q_f64(out.as_mut_ptr().add(2 * j), *a);
    }
    out
}

#[target_feature(enable = "neon")]
pub unsafe fn sum_sq(g: &[f32]) -> f64 {
    let n = g.len();
    let mut acc = [vdupq_n_f64(0.0); 4];
    let mut i = 0;
    while i + LANES <= n {
        sq_acc(
            &mut acc,
            vld1q_f32(g.as_ptr().add(i)),
            vld1q_f32(g.as_ptr().add(i + 4)),
        );
        i += LANES;
    }
    let mut grid = store_grid(acc);
    portable::sum_sq_span(&g[i..], 0, &mut grid);
    fold_f64(grid)
}

#[target_feature(enable = "neon")]
pub unsafe fn unscale_sum_sq(g: &mut [f32], inv_scale: f32) -> f64 {
    let n = g.len();
    let inv = vdupq_n_f32(inv_scale);
    let mut acc = [vdupq_n_f64(0.0); 4];
    let mut i = 0;
    while i + LANES <= n {
        // square the *stored* unscaled value, like the fused scalar sweep
        let v0 = vmulq_f32(vld1q_f32(g.as_ptr().add(i)), inv);
        let v1 = vmulq_f32(vld1q_f32(g.as_ptr().add(i + 4)), inv);
        vst1q_f32(g.as_mut_ptr().add(i), v0);
        vst1q_f32(g.as_mut_ptr().add(i + 4), v1);
        sq_acc(&mut acc, v0, v1);
        i += LANES;
    }
    let mut grid = store_grid(acc);
    portable::unscale_sum_sq_span(&mut g[i..], inv_scale, 0, &mut grid);
    fold_f64(grid)
}
