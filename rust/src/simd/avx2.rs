//! AVX2 backend (x86_64).  Every function is a bit-exact transcription of
//! the portable canonical kernels — see the module docs on `simd` for the
//! contract and `portable` for the reference arithmetic.
//!
//! Conversions are *pure integer* SIMD: the scalar branch ladder of
//! `precision::half` becomes unconditional computation of every class
//! (normal / subnormal / inf / nan / zero) followed by mask blends.  The
//! hardware F16C instructions are deliberately not used — `vcvtph2ps`
//! quiets signaling NaNs, while the scalar widen preserves the payload
//! bit-exactly, and the exhaustive 2^16 differential test would catch the
//! difference.  Round-to-nearest-even is computed branch-free:
//! `kept += (rem + (kept & 1)) > half` is equivalent to the scalar
//! `rem > half || (rem == half && odd)` ladder.
//!
//! Float kernels replicate the scalar operation order exactly (separate
//! mul/add — rustc emits no FMA without fast-math — and `vsqrtps` /
//! `vdivps` are IEEE correctly rounded), so elementwise results are
//! bit-identical.  Reductions keep the canonical 8-lane grid in registers
//! (f32 grids in one `__m256`, f64 grids as a lo/hi `__m256d` pair) and
//! tails fall through to the shared `portable::*_span` helpers — a tail
//! starts on a multiple of 8, so its lane offset is 0.
//!
//! Max folds use `cmp(GT) + blendv` rather than `vmaxps` so the NaN /
//! signed-zero semantics equal `portable::max2` exactly.
//!
//! Safety: every `fn` here is `#[target_feature(enable = "avx2")]` and
//! must only be called after AVX2 has been detected (`simd::backend()`
//! guarantees it for the dispatch wrappers).

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use super::portable;
use super::{fold_f32, fold_f64, fold_max, AdamK, LANES};

// --------------------------------------------------- register helpers ----

/// 8 × f32 → 8 × u16-valued i32 lanes, IEEE f16 narrow with RNE.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn narrow8_f16(x: __m256) -> __m256i {
    let bits = _mm256_castps_si256(x);
    let sign =
        _mm256_srli_epi32::<16>(_mm256_and_si256(bits, _mm256_set1_epi32(0x8000_0000u32 as i32)));
    let exp = _mm256_and_si256(_mm256_srli_epi32::<23>(bits), _mm256_set1_epi32(0xFF));
    let man = _mm256_and_si256(bits, _mm256_set1_epi32(0x007F_FFFF));
    let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7FFF_FFFF));

    // normal range (unbiased e in [-14, 15] ⇔ exp in [113, 142]):
    // out = ((e+15) << 10) | (man >> 13), then RNE on the dropped 13 bits;
    // the carry of a round-up past 0x7BFF lands on 0x7C00 = inf exactly
    let base = _mm256_or_si256(
        _mm256_slli_epi32::<10>(_mm256_sub_epi32(exp, _mm256_set1_epi32(112))),
        _mm256_srli_epi32::<13>(man),
    );
    let rem = _mm256_and_si256(man, _mm256_set1_epi32(0x1FFF));
    let odd = _mm256_and_si256(base, _mm256_set1_epi32(1));
    let round =
        _mm256_cmpgt_epi32(_mm256_add_epi32(rem, odd), _mm256_set1_epi32(0x1000));
    let out_norm = _mm256_sub_epi32(base, round); // mask is -1 ⇒ +1

    // subnormal range (e in [-25, -15] ⇔ exp in [102, 112]): shift the
    // explicit significand by 126 - exp ∈ [14, 24] with RNE on the low
    // bits.  Lanes outside the range produce garbage (variable shifts ≥ 32
    // yield 0) and are blended away.
    let full = _mm256_or_si256(man, _mm256_set1_epi32(0x0080_0000));
    let shift = _mm256_sub_epi32(_mm256_set1_epi32(126), exp);
    let kept = _mm256_srlv_epi32(full, shift);
    let low_mask =
        _mm256_sub_epi32(_mm256_sllv_epi32(_mm256_set1_epi32(1), shift), _mm256_set1_epi32(1));
    let rem_s = _mm256_and_si256(full, low_mask);
    let half =
        _mm256_sllv_epi32(_mm256_set1_epi32(1), _mm256_sub_epi32(shift, _mm256_set1_epi32(1)));
    let odd_s = _mm256_and_si256(kept, _mm256_set1_epi32(1));
    let round_s = _mm256_cmpgt_epi32(_mm256_add_epi32(rem_s, odd_s), half);
    let out_sub = _mm256_sub_epi32(kept, round_s);

    // nan: top payload bits, quiet bit forced (matches f32_to_f16_bits)
    let out_nan = _mm256_or_si256(
        _mm256_set1_epi32(0x7E00),
        _mm256_and_si256(_mm256_srli_epi32::<13>(man), _mm256_set1_epi32(0x01FF)),
    );

    // classify (all operands < 2^31, so signed compares are exact)
    let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7F80_0000));
    let lt_102 = _mm256_cmpgt_epi32(_mm256_set1_epi32(102), exp);
    let lt_113 = _mm256_cmpgt_epi32(_mm256_set1_epi32(113), exp);
    let lt_143 = _mm256_cmpgt_epi32(_mm256_set1_epi32(143), exp);
    let is_norm = _mm256_andnot_si256(lt_113, lt_143); // 113 <= exp < 143
    let is_sub = _mm256_andnot_si256(lt_102, lt_113); // 102 <= exp < 113

    // default inf (exp >= 143: finite overflow and real infinities)
    let mut r = _mm256_set1_epi32(0x7C00);
    r = _mm256_blendv_epi8(r, out_norm, is_norm);
    r = _mm256_blendv_epi8(r, out_sub, is_sub);
    r = _mm256_andnot_si256(lt_102, r); // exp < 102: underflow to zero
    r = _mm256_blendv_epi8(r, out_nan, is_nan);
    _mm256_or_si256(sign, r)
}

/// 8 × u16-valued i32 lanes → 8 × f32 bit patterns, exact f16 widen.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn widen8_f16(v: __m256i) -> __m256i {
    let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(v, _mm256_set1_epi32(0x8000)));
    let em = _mm256_and_si256(v, _mm256_set1_epi32(0x7FFF));
    let shifted = _mm256_slli_epi32::<13>(em);
    // normal: rebias +112 exponents; inf/nan: push the exponent to 255
    // keeping the payload (SNaN-ness preserved, same as the scalar widen)
    let norm = _mm256_add_epi32(shifted, _mm256_set1_epi32(0x3800_0000));
    let infnan = _mm256_add_epi32(shifted, _mm256_set1_epi32(0x7000_0000));
    // subnormal (em < 0x400, zero included): man * 2^-24 exactly — the
    // int→float convert is exact for man <= 1023 and the power-of-two
    // scale is exact, reproducing the scalar normalization loop
    let man = _mm256_and_si256(v, _mm256_set1_epi32(0x03FF));
    let subf = _mm256_mul_ps(_mm256_cvtepi32_ps(man), _mm256_set1_ps(5.960_464_5e-8)); // 2^-24
    let sub_bits = _mm256_castps_si256(subf);
    let is_infnan = _mm256_cmpgt_epi32(em, _mm256_set1_epi32(0x7BFF));
    let is_sub = _mm256_cmpgt_epi32(_mm256_set1_epi32(0x0400), em);
    let mut r = _mm256_blendv_epi8(norm, infnan, is_infnan);
    r = _mm256_blendv_epi8(r, sub_bits, is_sub);
    _mm256_or_si256(sign, r)
}

/// 8 × f32 → 8 × u16-valued i32 lanes, bf16 narrow with RNE.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn narrow8_bf16(x: __m256) -> __m256i {
    let bits = _mm256_castps_si256(x);
    let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7FFF_FFFF));
    let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7F80_0000));
    // RNE on the dropped 16 bits; wrap-around on NaN lanes is harmless
    // (they are blended away), matching the scalar's early NaN return
    let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(1));
    let rounded = _mm256_srli_epi32::<16>(_mm256_add_epi32(
        _mm256_add_epi32(bits, _mm256_set1_epi32(0x7FFF)),
        lsb,
    ));
    let nan_out =
        _mm256_or_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(0x0040));
    _mm256_blendv_epi8(rounded, nan_out, is_nan)
}

/// 8 × u16-valued i32 lanes → 8 × f32 bit patterns (bf16 is f32's top half).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn widen8_bf16(v: __m256i) -> __m256i {
    _mm256_slli_epi32::<16>(v)
}

/// Pack 8 u16-valued i32 lanes into 8 contiguous u16s (order preserved;
/// all values are <= 0xFFFF so the saturation never fires).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn pack8_u16(v: __m256i) -> __m128i {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256::<1>(v);
    _mm_packus_epi32(lo, hi)
}

/// Load 8 contiguous u16s as zero-extended i32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn load8_u16(p: *const u16) -> __m256i {
    _mm256_cvtepu16_epi32(_mm_loadu_si128(p as *const __m128i))
}

/// `portable::max2` in registers: strictly-greater replaces (NaN never
/// wins, ties keep the accumulator) — NOT `vmaxps`, whose NaN semantics
/// differ.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn max8(acc: __m256, v: __m256) -> __m256 {
    _mm256_blendv_ps(acc, v, _mm256_cmp_ps::<_CMP_GT_OQ>(v, acc))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn abs8(x: __m256) -> __m256 {
    _mm256_and_ps(x, _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF)))
}

// ------------------------------------------------------ conversions ------

macro_rules! conv_loops {
    ($narrow:ident, $widen:ident, $accw:ident, $accq:ident, $round:ident,
     $n8:ident, $w8:ident) => {
        #[target_feature(enable = "avx2")]
        pub unsafe fn $narrow(src: &[f32], out: &mut [u16]) {
            let n = src.len();
            let mut i = 0;
            while i + LANES <= n {
                let x = _mm256_loadu_ps(src.as_ptr().add(i));
                _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, pack8_u16($n8(x)));
                i += LANES;
            }
            portable::$narrow(&src[i..], &mut out[i..]);
        }

        #[target_feature(enable = "avx2")]
        pub unsafe fn $widen(bits: &[u16], out: &mut [f32]) {
            let n = bits.len();
            let mut i = 0;
            while i + LANES <= n {
                let v = load8_u16(bits.as_ptr().add(i));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_castsi256_ps($w8(v)));
                i += LANES;
            }
            portable::$widen(&bits[i..], &mut out[i..]);
        }

        #[target_feature(enable = "avx2")]
        pub unsafe fn $accw(bits: &[u16], dst: &mut [f32]) {
            let n = bits.len();
            let mut i = 0;
            while i + LANES <= n {
                let q = _mm256_castsi256_ps($w8(load8_u16(bits.as_ptr().add(i))));
                let d = _mm256_add_ps(_mm256_loadu_ps(dst.as_ptr().add(i)), q);
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), d);
                i += LANES;
            }
            portable::$accw(&bits[i..], &mut dst[i..]);
        }

        #[target_feature(enable = "avx2")]
        pub unsafe fn $accq(src: &[f32], dst: &mut [f32]) {
            let n = src.len();
            let mut i = 0;
            while i + LANES <= n {
                let x = _mm256_loadu_ps(src.as_ptr().add(i));
                let q = _mm256_castsi256_ps($w8($n8(x)));
                let d = _mm256_add_ps(_mm256_loadu_ps(dst.as_ptr().add(i)), q);
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), d);
                i += LANES;
            }
            portable::$accq(&src[i..], &mut dst[i..]);
        }

        #[target_feature(enable = "avx2")]
        pub unsafe fn $round(seg: &mut [f32]) {
            let n = seg.len();
            let mut i = 0;
            while i + LANES <= n {
                let x = _mm256_loadu_ps(seg.as_ptr().add(i));
                let q = _mm256_castsi256_ps($w8($n8(x)));
                _mm256_storeu_ps(seg.as_mut_ptr().add(i), q);
                i += LANES;
            }
            portable::$round(&mut seg[i..]);
        }
    };
}

// The five f16 slice kernels…
conv_loops!(
    narrow_f16,
    widen_f16,
    accum_widened_f16,
    accum_quantized_f16,
    round_f16,
    narrow8_f16,
    widen8_f16
);
// …and the five bf16 ones.
conv_loops!(
    narrow_bf16,
    widen_bf16,
    accum_widened_bf16,
    accum_quantized_bf16,
    round_bf16,
    narrow8_bf16,
    widen8_bf16
);

// ------------------------------------------------------- reductions ------

/// Convert an 8-lane f32 vector into the (lanes 0-3, lanes 4-7) f64 pair
/// of the canonical grid.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn to_pd_pair(v: __m256) -> (__m256d, __m256d) {
    (
        _mm256_cvtps_pd(_mm256_castps256_ps128(v)),
        _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v)),
    )
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn store_pd_grid(lo: __m256d, hi: __m256d) -> [f64; LANES] {
    let mut acc = [0.0f64; LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), lo);
    _mm256_storeu_pd(acc.as_mut_ptr().add(4), hi);
    acc
}

#[target_feature(enable = "avx2")]
pub unsafe fn sum_sq(g: &[f32]) -> f64 {
    let n = g.len();
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut i = 0;
    while i + LANES <= n {
        let (lo, hi) = to_pd_pair(_mm256_loadu_ps(g.as_ptr().add(i)));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
        i += LANES;
    }
    let mut acc = store_pd_grid(acc_lo, acc_hi);
    portable::sum_sq_span(&g[i..], 0, &mut acc);
    fold_f64(acc)
}

#[target_feature(enable = "avx2")]
pub unsafe fn unscale_sum_sq(g: &mut [f32], inv_scale: f32) -> f64 {
    let n = g.len();
    let inv = _mm256_set1_ps(inv_scale);
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut i = 0;
    while i + LANES <= n {
        // square the *stored* unscaled f32, exactly like the fused scalar
        let v = _mm256_mul_ps(_mm256_loadu_ps(g.as_ptr().add(i)), inv);
        _mm256_storeu_ps(g.as_mut_ptr().add(i), v);
        let (lo, hi) = to_pd_pair(v);
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
        i += LANES;
    }
    let mut acc = store_pd_grid(acc_lo, acc_hi);
    portable::unscale_sum_sq_span(&mut g[i..], inv_scale, 0, &mut acc);
    fold_f64(acc)
}

#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn lans_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    rf: &mut [f32],
    cf: &mut [f32],
) -> (f64, f64, f64) {
    let n = x.len();
    let b1 = _mm256_set1_ps(k.beta1);
    let omb1 = _mm256_set1_ps(1.0 - k.beta1);
    let b2 = _mm256_set1_ps(k.beta2);
    let omb2 = _mm256_set1_ps(1.0 - k.beta2);
    let eps = _mm256_set1_ps(k.eps);
    let ibc1 = _mm256_set1_ps(k.inv_bc1);
    let ibc2 = _mm256_set1_ps(k.inv_bc2);
    let wd = _mm256_set1_ps(k.wd);
    let ign = _mm256_set1_ps(k.inv_gnorm);
    let one = _mm256_set1_ps(1.0);
    let mut afx = _mm256_setzero_ps();
    let mut afr = _mm256_setzero_ps();
    let mut afc = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let vv = _mm256_loadu_ps(v.as_ptr().add(i));
        // same op order as the scalar: gt = g·ign; mn = β1·m + (1-β1)·gt;
        // vn = β2·v + ((1-β2)·gt)·gt  (left-assoc, matching Rust parsing)
        let gt = _mm256_mul_ps(gv, ign);
        let mn = _mm256_add_ps(_mm256_mul_ps(b1, mv), _mm256_mul_ps(omb1, gt));
        let vn =
            _mm256_add_ps(_mm256_mul_ps(b2, vv), _mm256_mul_ps(_mm256_mul_ps(omb2, gt), gt));
        _mm256_storeu_ps(m.as_mut_ptr().add(i), mn);
        _mm256_storeu_ps(v.as_mut_ptr().add(i), vn);
        let inv_denom =
            _mm256_div_ps(one, _mm256_add_ps(_mm256_sqrt_ps(_mm256_mul_ps(vn, ibc2)), eps));
        let wx = _mm256_mul_ps(wd, xv);
        let r = _mm256_add_ps(_mm256_mul_ps(_mm256_mul_ps(mn, ibc1), inv_denom), wx);
        let c = _mm256_add_ps(_mm256_mul_ps(gt, inv_denom), wx);
        _mm256_storeu_ps(rf.as_mut_ptr().add(i), r);
        _mm256_storeu_ps(cf.as_mut_ptr().add(i), c);
        afx = _mm256_add_ps(afx, _mm256_mul_ps(xv, xv));
        afr = _mm256_add_ps(afr, _mm256_mul_ps(r, r));
        afc = _mm256_add_ps(afc, _mm256_mul_ps(c, c));
        i += LANES;
    }
    let (mut fx, mut fr, mut fc) = ([0.0f32; LANES], [0.0f32; LANES], [0.0f32; LANES]);
    _mm256_storeu_ps(fx.as_mut_ptr(), afx);
    _mm256_storeu_ps(fr.as_mut_ptr(), afr);
    _mm256_storeu_ps(fc.as_mut_ptr(), afc);
    portable::lans_span(
        k,
        &x[i..],
        &g[i..],
        &mut m[i..],
        &mut v[i..],
        &mut rf[i..],
        &mut cf[i..],
        0,
        &mut fx,
        &mut fr,
        &mut fc,
    );
    (fold_f32(fx) as f64, fold_f32(fr) as f64, fold_f32(fc) as f64)
}

#[target_feature(enable = "avx2")]
pub unsafe fn lamb_segment(
    k: &AdamK,
    x: &[f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    u: &mut [f32],
) -> (f64, f64, f64) {
    let n = x.len();
    let b1 = _mm256_set1_ps(k.beta1);
    let omb1 = _mm256_set1_ps(1.0 - k.beta1);
    let b2 = _mm256_set1_ps(k.beta2);
    let omb2 = _mm256_set1_ps(1.0 - k.beta2);
    let eps = _mm256_set1_ps(k.eps);
    let ibc1 = _mm256_set1_ps(k.inv_bc1);
    let ibc2 = _mm256_set1_ps(k.inv_bc2);
    let wd = _mm256_set1_ps(k.wd);
    let (mut ax_lo, mut ax_hi) = (_mm256_setzero_pd(), _mm256_setzero_pd());
    let (mut au_lo, mut au_hi) = (_mm256_setzero_pd(), _mm256_setzero_pd());
    let (mut ag_lo, mut ag_hi) = (_mm256_setzero_pd(), _mm256_setzero_pd());
    let mut i = 0;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let vv = _mm256_loadu_ps(v.as_ptr().add(i));
        let mn = _mm256_add_ps(_mm256_mul_ps(b1, mv), _mm256_mul_ps(omb1, gv));
        let vn =
            _mm256_add_ps(_mm256_mul_ps(b2, vv), _mm256_mul_ps(_mm256_mul_ps(omb2, gv), gv));
        _mm256_storeu_ps(m.as_mut_ptr().add(i), mn);
        _mm256_storeu_ps(v.as_mut_ptr().add(i), vn);
        // un = (mn·ibc1) / (sqrt(vn·ibc2) + eps) + wd·x — a real divide,
        // matching the scalar (no reciprocal-multiply rewrite)
        let denom = _mm256_add_ps(_mm256_sqrt_ps(_mm256_mul_ps(vn, ibc2)), eps);
        let un = _mm256_add_ps(
            _mm256_div_ps(_mm256_mul_ps(mn, ibc1), denom),
            _mm256_mul_ps(wd, xv),
        );
        _mm256_storeu_ps(u.as_mut_ptr().add(i), un);
        let (glo, ghi) = to_pd_pair(gv);
        ag_lo = _mm256_add_pd(ag_lo, _mm256_mul_pd(glo, glo));
        ag_hi = _mm256_add_pd(ag_hi, _mm256_mul_pd(ghi, ghi));
        let (xlo, xhi) = to_pd_pair(xv);
        ax_lo = _mm256_add_pd(ax_lo, _mm256_mul_pd(xlo, xlo));
        ax_hi = _mm256_add_pd(ax_hi, _mm256_mul_pd(xhi, xhi));
        let (ulo, uhi) = to_pd_pair(un);
        au_lo = _mm256_add_pd(au_lo, _mm256_mul_pd(ulo, ulo));
        au_hi = _mm256_add_pd(au_hi, _mm256_mul_pd(uhi, uhi));
        i += LANES;
    }
    let mut sx2 = store_pd_grid(ax_lo, ax_hi);
    let mut su2 = store_pd_grid(au_lo, au_hi);
    let mut sg2 = store_pd_grid(ag_lo, ag_hi);
    portable::lamb_span(
        k,
        &x[i..],
        &g[i..],
        &mut m[i..],
        &mut v[i..],
        &mut u[i..],
        0,
        &mut sx2,
        &mut su2,
        &mut sg2,
    );
    (fold_f64(sx2), fold_f64(su2), fold_f64(sg2))
}

#[target_feature(enable = "avx2")]
pub unsafe fn adamw_segment(
    k: &AdamK,
    x: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) -> f32 {
    let n = x.len();
    let b1 = _mm256_set1_ps(k.beta1);
    let omb1 = _mm256_set1_ps(1.0 - k.beta1);
    let b2 = _mm256_set1_ps(k.beta2);
    let omb2 = _mm256_set1_ps(1.0 - k.beta2);
    let eps = _mm256_set1_ps(k.eps);
    let ibc1 = _mm256_set1_ps(k.inv_bc1);
    let ibc2 = _mm256_set1_ps(k.inv_bc2);
    let wd = _mm256_set1_ps(k.wd);
    let ign = _mm256_set1_ps(k.inv_gnorm);
    let lr = _mm256_set1_ps(k.lr);
    let mut amax = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let vv = _mm256_loadu_ps(v.as_ptr().add(i));
        let gn = _mm256_mul_ps(gv, ign);
        let mn = _mm256_add_ps(_mm256_mul_ps(b1, mv), _mm256_mul_ps(omb1, gn));
        let vn =
            _mm256_add_ps(_mm256_mul_ps(b2, vv), _mm256_mul_ps(_mm256_mul_ps(omb2, gn), gn));
        _mm256_storeu_ps(m.as_mut_ptr().add(i), mn);
        _mm256_storeu_ps(v.as_mut_ptr().add(i), vn);
        let denom = _mm256_add_ps(_mm256_sqrt_ps(_mm256_mul_ps(vn, ibc2)), eps);
        let upd = _mm256_add_ps(
            _mm256_div_ps(_mm256_mul_ps(mn, ibc1), denom),
            _mm256_mul_ps(wd, xv),
        );
        let xn = _mm256_sub_ps(xv, _mm256_mul_ps(lr, upd));
        _mm256_storeu_ps(x.as_mut_ptr().add(i), xn);
        amax = max8(amax, abs8(xn));
        i += LANES;
    }
    let mut ma = [0.0f32; LANES];
    _mm256_storeu_ps(ma.as_mut_ptr(), amax);
    portable::adamw_span(k, &mut x[i..], &g[i..], &mut m[i..], &mut v[i..], 0, &mut ma);
    fold_max(ma)
}

#[target_feature(enable = "avx2")]
pub unsafe fn lans_apply(
    coef_r: f32,
    coef_c: f32,
    x: &mut [f32],
    rf: &[f32],
    cf: &[f32],
) -> f32 {
    let n = x.len();
    let cr = _mm256_set1_ps(coef_r);
    let cc = _mm256_set1_ps(coef_c);
    let mut amax = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let rv = _mm256_loadu_ps(rf.as_ptr().add(i));
        let cv = _mm256_loadu_ps(cf.as_ptr().add(i));
        let xn = _mm256_sub_ps(
            xv,
            _mm256_add_ps(_mm256_mul_ps(cr, rv), _mm256_mul_ps(cc, cv)),
        );
        _mm256_storeu_ps(x.as_mut_ptr().add(i), xn);
        amax = max8(amax, abs8(xn));
        i += LANES;
    }
    let mut ma = [0.0f32; LANES];
    _mm256_storeu_ps(ma.as_mut_ptr(), amax);
    portable::lans_apply_span(coef_r, coef_c, &mut x[i..], &rf[i..], &cf[i..], 0, &mut ma);
    fold_max(ma)
}

#[target_feature(enable = "avx2")]
pub unsafe fn axpy_max(coef: f32, x: &mut [f32], u: &[f32]) -> f32 {
    let n = x.len();
    let cv = _mm256_set1_ps(coef);
    let mut amax = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let uv = _mm256_loadu_ps(u.as_ptr().add(i));
        let xn = _mm256_sub_ps(xv, _mm256_mul_ps(cv, uv));
        _mm256_storeu_ps(x.as_mut_ptr().add(i), xn);
        amax = max8(amax, abs8(xn));
        i += LANES;
    }
    let mut ma = [0.0f32; LANES];
    _mm256_storeu_ps(ma.as_mut_ptr(), amax);
    portable::axpy_max_span(coef, &mut x[i..], &u[i..], 0, &mut ma);
    fold_max(ma)
}
