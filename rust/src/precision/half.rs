//! Bit-level `f32 ↔ f16/bf16` conversion (no `half` crate offline) and the
//! packed [`HalfVec`] wire buffer.
//!
//! Both conversions implement IEEE 754 round-to-nearest-even on the
//! dropped mantissa bits, with the full special-value contract:
//!
//! * overflow (a finite f32 past the half format's range) rounds to ±inf
//!   — the signal dynamic loss scaling watches for;
//! * f16 subnormals are produced and consumed exactly (down to 2^-24);
//!   values below half the smallest subnormal underflow to signed zero;
//! * NaN stays NaN (quiet bit forced; payload truncated), infinities map
//!   to the format's infinities.
//!
//! The half→f32 direction is exact (every f16/bf16 value is representable
//! in f32), so `to_f32 ∘ from_f32` is idempotent — quantizing an
//! already-quantized value is the identity, which is what makes multi-hop
//! wire forwarding in `collective::half` loss-free after the first hop.
//!
//! Golden-vector tests below pin known bit patterns (normals, subnormals,
//! inf/nan, round-to-nearest-even ties); `tests/proptests.rs` adds the
//! determinism / monotonicity / bounded-error properties.
//!
//! The scalar functions here are the *bit reference*; every batch entry
//! point ([`HalfVec::from_f32`], [`HalfVec::to_f32_into`], and the fused
//! hop helpers [`quantize_accumulate`] / [`round_trip_slice`]) routes
//! through the runtime-dispatched kernels in [`crate::simd`], which are
//! differentially tested against these scalars (exhaustive 2^16 widen +
//! lane-remainder sweeps).  Batch calls record a `trace::CAT_CONVERT`
//! span whose detail counts converted bytes on the half side.

use super::DType;
use crate::{simd, trace};

/// Open a `convert` trace span for a batch conversion touching `n` half
/// elements (detail = bytes on the half side of the conversion).
#[inline]
fn convert_span(n: usize) -> trace::Span {
    trace::span_detail(trace::CAT_CONVERT, "wire_convert", 2 * n as u64)
}

// ------------------------------------------------------------------ f16 ----

/// f32 → IEEE binary16 bits, round-to-nearest-even, overflow → ±inf.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xFF) as i32;
    let man = x & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / nan: keep the top payload bits, force the quiet bit so a
        // payload that truncates to zero stays a NaN
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF)
        };
    }
    let e = exp - 127; // unbiased
    if e >= 16 {
        // >= 2^16 > 65504: past the largest half, round to inf
        return sign | 0x7C00;
    }
    if e >= -14 {
        // normal half range; rounding may carry into the exponent and
        // produce inf naturally (values in (65504, 65536))
        let mut out = (((e + 15) as u32) << 10) | (man >> 13);
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
            out += 1;
        }
        return sign | (out as u16);
    }
    if e < -25 {
        // below half the smallest subnormal (2^-25): underflow to ±0
        return sign;
    }
    // subnormal: shift the full significand (implicit bit made explicit)
    // so the result counts units of 2^-24, rounding to nearest even; a
    // round-up from 1023 lands on 0x0400 = the smallest normal, which is
    // exactly the adjacent representable value
    let full = man | 0x0080_0000;
    let shift = (-e - 1) as u32; // 14..=24
    let kept = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut m = kept;
    if rem > half || (rem == half && (m & 1) == 1) {
        m += 1;
    }
    sign | (m as u16)
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        // inf / nan
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: value = man * 2^-24; normalize so the leading
            // significand bit becomes f32's implicit bit
            let mut e = 113u32; // 127 - 14, decremented per shift below
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ----------------------------------------------------------------- bf16 ----

/// f32 → bfloat16 bits, round-to-nearest-even, overflow → ±inf.
pub fn f32_to_bf16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    if value.is_nan() {
        // force the quiet bit so a payload living in the dropped low bits
        // does not truncate the NaN into an infinity
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest even on the dropped 16 bits; the carry propagates
    // through exponent bits, turning a just-under-max value into inf
    let lsb = (bits >> 16) & 1;
    ((bits + 0x7FFF + lsb) >> 16) as u16
}

/// bfloat16 bits → f32 (exact — bf16 is f32's top half).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// -------------------------------------------------------------- HalfVec ----

/// A packed half-precision buffer — the wire format of the half
/// collectives.  Stores one `u16` per element (`dtype.bytes() == 2` of
/// wire traffic each), quantized once at construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HalfVec {
    dtype: DType,
    bits: Vec<u16>,
}

impl HalfVec {
    /// Quantize an f32 slice (round-to-nearest-even, overflow → inf).
    /// `dtype` must be a half format — an f32 "HalfVec" has no packed form.
    pub fn from_f32(dtype: DType, data: &[f32]) -> HalfVec {
        assert!(dtype.is_half(), "HalfVec needs a half dtype, got {}", dtype.name());
        let _sp = convert_span(data.len());
        let mut bits = vec![0u16; data.len()];
        match dtype {
            DType::F16 => simd::narrow_f16(data, &mut bits),
            DType::Bf16 => simd::narrow_bf16(data, &mut bits),
            DType::F32 => unreachable!(),
        }
        HalfVec { dtype, bits }
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Bytes this buffer would occupy on the wire.
    pub fn wire_bytes(&self) -> usize {
        self.bits.len() * self.dtype.bytes()
    }

    /// Raw packed bits (what a transport would memcpy).
    pub fn bits(&self) -> &[u16] {
        &self.bits
    }

    /// Element `i` widened back to f32 (exact).  Cold path: this
    /// dispatches on `dtype` *per element* — hot loops must use the batch
    /// [`to_f32_into`](Self::to_f32_into) / [`accum_into`](Self::accum_into)
    /// kernels instead.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self.dtype {
            DType::F16 => f16_bits_to_f32(self.bits[i]),
            DType::Bf16 => bf16_bits_to_f32(self.bits[i]),
            DType::F32 => unreachable!(),
        }
    }

    /// Dequantize the whole buffer into `out` (exact widening).
    pub fn to_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.bits.len(), "length mismatch");
        let _sp = convert_span(self.bits.len());
        match self.dtype {
            DType::F16 => simd::widen_f16(&self.bits, out),
            DType::Bf16 => simd::widen_bf16(&self.bits, out),
            DType::F32 => unreachable!(),
        }
    }

    /// Fused receive: `dst[i] += widen(self[i])` — the batch form of the
    /// old `iter_f32` accumulate loop, one pass and no f32 scratch.
    pub fn accum_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.bits.len(), "length mismatch");
        let _sp = convert_span(self.bits.len());
        match self.dtype {
            DType::F16 => simd::accum_widened_f16(&self.bits, dst),
            DType::Bf16 => simd::accum_widened_bf16(&self.bits, dst),
            DType::F32 => unreachable!(),
        }
    }

    /// Iterate the elements widened to f32.  Cold path — dispatches per
    /// element; hot loops use the batch kernels above.
    pub fn iter_f32(&self) -> impl Iterator<Item = f32> + '_ {
        let dtype = self.dtype;
        self.bits.iter().map(move |&b| match dtype {
            DType::F16 => f16_bits_to_f32(b),
            DType::Bf16 => bf16_bits_to_f32(b),
            DType::F32 => unreachable!(),
        })
    }
}

// ------------------------------------------------- fused hop helpers ----

/// One in-process ring hop at half precision: `dst[i] += dq(q(src[i]))`.
/// Exactly what constructing a [`HalfVec`] from `src` and accumulating it
/// into `dst` computes, but quantize and widen stay in registers — a hop
/// allocates nothing and reads/writes each slice once.  `dtype` must be a
/// half format.
pub fn quantize_accumulate(dtype: DType, src: &[f32], dst: &mut [f32]) {
    assert!(dtype.is_half(), "quantize_accumulate needs a half dtype");
    assert_eq!(src.len(), dst.len(), "length mismatch");
    let _sp = convert_span(src.len());
    match dtype {
        DType::F16 => simd::accum_quantized_f16(src, dst),
        DType::Bf16 => simd::accum_quantized_bf16(src, dst),
        DType::F32 => unreachable!(),
    }
}

/// In-place `x[i] = dq(q(x[i]))` over a slice — the owner-segment adoption
/// of the wire value in the all-gather phase.  Identity on `DType::F32`.
pub fn round_trip_slice(dtype: DType, seg: &mut [f32]) {
    match dtype {
        DType::F32 => {}
        DType::F16 => {
            let _sp = convert_span(seg.len());
            simd::round_f16(seg);
        }
        DType::Bf16 => {
            let _sp = convert_span(seg.len());
            simd::round_bf16(seg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- golden IEEE-754 vectors: f16 ------------------------------------

    #[test]
    fn f16_golden_normals() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3C00),
            (-1.0, 0xBC00),
            (2.0, 0x4000),
            (0.5, 0x3800),
            (-2.5, 0xC100),
            (0.1, 0x2E66),     // nearest f16 to f32(0.1)
            (65504.0, 0x7BFF), // largest finite f16
            (2.0f32.powi(-14), 0x0400), // smallest normal
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "f32_to_f16({x})");
        }
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn f16_golden_subnormals() {
        // 2^-24: the smallest f16 subnormal
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        // 2^-25 is exactly halfway between 0 and 2^-24: ties to even -> 0
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000);
        // 1.5 * 2^-25 rounds up to 2^-24
        assert_eq!(f32_to_f16_bits(1.5 * 2.0f32.powi(-25)), 0x0001);
        // largest subnormal: 1023 * 2^-24
        let largest_sub = 1023.0f32 * 2.0f32.powi(-24);
        assert_eq!(f32_to_f16_bits(largest_sub), 0x03FF);
        // below half the smallest subnormal: underflow to signed zero
        assert_eq!(f32_to_f16_bits(1.0e-9), 0x0000);
        assert_eq!(f32_to_f16_bits(-1.0e-9), 0x8000);
        // subnormals decode exactly
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x03FF), largest_sub);
        assert_eq!(f16_bits_to_f32(0x8001), -(2.0f32.powi(-24)));
    }

    #[test]
    fn f16_golden_inf_nan_overflow() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        // overflow -> inf: 65520 ties up into 65536 (unrepresentable),
        // 1e9 and f32::MAX are far past the range
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
        assert_eq!(f32_to_f16_bits(1.0e9), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::MAX), 0x7C00);
        assert_eq!(f32_to_f16_bits(-65520.0), 0xFC00);
        // just below the tie: rounds back down to the max finite
        assert_eq!(f32_to_f16_bits(65519.0), 0x7BFF);
        // NaN stays NaN, sign preserved, payload truncated but non-zero
        let n = f32_to_f16_bits(f32::NAN);
        assert_eq!(n & 0x7C00, 0x7C00);
        assert_ne!(n & 0x03FF, 0);
        assert!(f16_bits_to_f32(n).is_nan());
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 sits exactly between 0x3C00 (1.0) and 0x3C01: even wins
        assert_eq!(f32_to_f16_bits(1.000_488_281_25), 0x3C00);
        // 1 + 3*2^-11 sits between 0x3C01 and 0x3C02: even (0x3C02) wins
        assert_eq!(f32_to_f16_bits(1.001_464_843_75), 0x3C02);
        // just past the tie rounds up
        assert_eq!(f32_to_f16_bits(1.000_489), 0x3C01);
    }

    // ---- golden IEEE-754 vectors: bf16 -----------------------------------

    #[test]
    fn bf16_golden_normals() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3F80),
            (-2.0, 0xC000),
            (0.5, 0x3F00),
            (std::f32::consts::PI, 0x4049), // 0x40490FDB rounds down
            (0.1, 0x3DCD),                  // 0x3DCCCCCD rounds up
        ] {
            assert_eq!(f32_to_bf16_bits(x), bits, "f32_to_bf16({x})");
        }
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        // decode is the exact top half
        assert_eq!(bf16_bits_to_f32(0x3F80), 1.0);
        assert_eq!(bf16_bits_to_f32(0xC000), -2.0);
    }

    #[test]
    fn bf16_round_to_nearest_even_ties() {
        // 0x3F808000 is halfway between 0x3F80 and 0x3F81: even (0x3F80)
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8000)), 0x3F80);
        // 0x3F818000 is halfway between 0x3F81 and 0x3F82: even (0x3F82)
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F81_8000)), 0x3F82);
        // just past the tie rounds up
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8001)), 0x3F81);
    }

    #[test]
    fn bf16_golden_inf_nan_overflow_subnormal() {
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16_bits(f32::NEG_INFINITY), 0xFF80);
        // f32::MAX rounds up past the largest bf16 into inf
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16_bits(-f32::MAX), 0xFF80);
        // largest finite bf16 survives
        assert_eq!(f32_to_bf16_bits(bf16_bits_to_f32(0x7F7F)), 0x7F7F);
        let n = f32_to_bf16_bits(f32::NAN);
        assert!(bf16_bits_to_f32(n).is_nan());
        // f32 subnormals map onto bf16 subnormals exactly when the low 16
        // bits are zero; the smallest bf16 subnormal is 2^-133
        assert_eq!(bf16_bits_to_f32(0x0001), 2.0f32.powi(-133));
        assert_eq!(f32_to_bf16_bits(2.0f32.powi(-133)), 0x0001);
        assert_eq!(f32_to_bf16_bits(bf16_bits_to_f32(0x8001)), 0x8001);
    }

    // ---- roundtrip / HalfVec ---------------------------------------------

    #[test]
    fn every_f16_value_roundtrips_exactly() {
        // exhaustive: all 2^16 bit patterns survive f16 -> f32 -> f16
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(x), h, "pattern {h:#06x} ({x})");
            }
        }
    }

    #[test]
    fn every_bf16_value_roundtrips_exactly() {
        for b in 0..=u16::MAX {
            let x = bf16_bits_to_f32(b);
            if x.is_nan() {
                assert!(bf16_bits_to_f32(f32_to_bf16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_bf16_bits(x), b, "pattern {b:#06x} ({x})");
            }
        }
    }

    #[test]
    fn halfvec_packs_and_unpacks() {
        let data = [0.0f32, 1.0, -2.5, 0.1, 65504.0, 1.0e9];
        for dtype in [DType::F16, DType::Bf16] {
            let hv = HalfVec::from_f32(dtype, &data);
            assert_eq!(hv.len(), data.len());
            assert_eq!(hv.wire_bytes(), data.len() * 2);
            let mut back = vec![0.0f32; data.len()];
            hv.to_f32_into(&mut back);
            for (i, (&x, &b)) in data.iter().zip(&back).enumerate() {
                assert_eq!(b, dtype.round_trip(x), "{} elem {i}", dtype.name());
                assert_eq!(hv.get(i), b);
            }
            let collected: Vec<f32> = hv.iter_f32().collect();
            assert_eq!(collected, back);
        }
        // f16 saturates 1e9 to inf; bf16 keeps it finite
        assert_eq!(HalfVec::from_f32(DType::F16, &[1.0e9]).get(0), f32::INFINITY);
        assert!(HalfVec::from_f32(DType::Bf16, &[1.0e9]).get(0).is_finite());
    }

    #[test]
    #[should_panic(expected = "half dtype")]
    fn halfvec_rejects_f32() {
        let _ = HalfVec::from_f32(DType::F32, &[1.0]);
    }

    #[test]
    fn fused_helpers_match_halfvec_composition() {
        let src = [0.0f32, 1.0, -2.5, 0.1, 65504.0, 1.0e9, 1.5e-25, -0.0, 3.7];
        let base = [1.0f32, -0.5, 2.0, 0.25, -1.0, 0.125, 4.0, -8.0, 0.0];
        for dtype in [DType::F16, DType::Bf16] {
            let hv = HalfVec::from_f32(dtype, &src);

            // quantize_accumulate == from_f32 + accumulate, bitwise
            let mut fused = base;
            quantize_accumulate(dtype, &src, &mut fused);
            let mut composed = base;
            for (d, q) in composed.iter_mut().zip(hv.iter_f32()) {
                *d += q;
            }
            for (i, (a, b)) in fused.iter().zip(&composed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} qacc {i}", dtype.name());
            }

            // accum_into == iter_f32 accumulate, bitwise
            let mut fused = base;
            hv.accum_into(&mut fused);
            for (i, (a, b)) in fused.iter().zip(&composed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} accum {i}", dtype.name());
            }

            // round_trip_slice == per-element round_trip
            let mut seg = src;
            round_trip_slice(dtype, &mut seg);
            for (i, (a, &x)) in seg.iter().zip(&src).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    dtype.round_trip(x).to_bits(),
                    "{} round {i}",
                    dtype.name()
                );
            }
        }
        // F32 round trip is the identity on the slice form too
        let mut seg = [1.0f32, f32::INFINITY, 1e-42];
        round_trip_slice(DType::F32, &mut seg);
        assert_eq!(seg, [1.0f32, f32::INFINITY, 1e-42]);
    }
}
