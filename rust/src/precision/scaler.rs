//! Dynamic loss scaling — the state machine that keeps fp16 gradients
//! inside the format's narrow range (max 65504).
//!
//! The loss (hence every gradient) is multiplied by a power-of-two scale
//! before the backward/wire, and unscaled inside the optimizer's grad²
//! phase.  When the unscaled gradient contains inf/nan the step is
//! *skipped* (parameters, moments and the bias-correction clock all
//! untouched) and the scale backs off; after [`growth_interval`] clean
//! steps in a row it grows back.  Power-of-two scales make the
//! scale→unscale round trip bit-exact in IEEE arithmetic, which is what
//! lets the f32-wire loss-scaled trajectory match the unscaled one
//! exactly (property-tested in `tests/proptests.rs`).
//!
//! [`growth_interval`]: DynamicLossScaler::DEFAULT_GROWTH_INTERVAL

use anyhow::{bail, Result};

use crate::runtime::tensor::TensorF32;

/// The `TrainConfig::loss_scale` knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossScale {
    /// Unit scale — the historical fp32 path (no scaling, no skip logic).
    Off,
    /// Fixed power-of-two scale: overflowed steps are still skipped, but
    /// the scale never moves.
    Static(f32),
    /// Backoff-on-overflow / growth-after-quiet-interval, starting at
    /// `init` (rounded to the nearest power of two).
    Dynamic { init: f32 },
}

impl LossScale {
    pub fn enabled(&self) -> bool {
        !matches!(self, LossScale::Off)
    }

    /// Build the runtime scaler; `None` when scaling is off.
    pub fn build(&self) -> Option<DynamicLossScaler> {
        match *self {
            LossScale::Off => None,
            LossScale::Static(s) => Some(DynamicLossScaler::fixed(s)),
            LossScale::Dynamic { init } => Some(DynamicLossScaler::dynamic(init)),
        }
    }
}

/// Name of the checkpoint tensor the scaler state rides in.
pub const LOSS_SCALE_TENSOR: &str = "lossscale:state";

/// Power-of-two loss scale with apex/amp-style dynamics.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicLossScaler {
    scale: f32,
    good_steps: u64,
    growth_interval: u64,
    dynamic: bool,
    /// total overflowed (skipped) steps — telemetry
    overflows: u64,
}

impl DynamicLossScaler {
    /// amp's defaults: start at 2^16, try to double every 2000 clean steps.
    pub const DEFAULT_INIT: f32 = 65536.0;
    pub const DEFAULT_GROWTH_INTERVAL: u64 = 2000;
    /// Scale bounds, both powers of two.  Both keep `scale` and `1/scale`
    /// well inside the normal f32 range so scaling stays an exact
    /// exponent shift.  The floor sits *below* 1: the wire carries
    /// un-normalized gradient sums (the 1/micro-steps mean applies after
    /// the collective), so at large accumulation counts the scaler must
    /// be able to shrink gradients to fit the f16 range, not just grow
    /// them.
    pub const MIN_SCALE: f32 = 5.960_464_5e-8; // 2^-24
    pub const MAX_SCALE: f32 = 16_777_216.0; // 2^24

    /// Dynamic scaler starting at `init` (rounded to a power of two and
    /// clamped to the legal range).
    pub fn dynamic(init: f32) -> DynamicLossScaler {
        DynamicLossScaler {
            scale: round_pow2(init),
            good_steps: 0,
            growth_interval: Self::DEFAULT_GROWTH_INTERVAL,
            dynamic: true,
            overflows: 0,
        }
    }

    /// Fixed scaler: overflow still skips the step, but the scale is pinned.
    pub fn fixed(scale: f32) -> DynamicLossScaler {
        DynamicLossScaler { dynamic: false, ..Self::dynamic(scale) }
    }

    /// Override the growth interval (tests, aggressive schedules).
    pub fn with_growth_interval(mut self, interval: u64) -> DynamicLossScaler {
        self.growth_interval = interval.max(1);
        self
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// `1 / scale` — exact, since the scale is a power of two.
    pub fn inv_scale(&self) -> f32 {
        1.0 / self.scale
    }

    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    pub fn overflows(&self) -> u64 {
        self.overflows
    }

    /// Record one step's outcome: backoff ×1/2 on overflow, growth ×2
    /// after `growth_interval` consecutive clean steps (dynamic only; a
    /// fixed scaler only counts overflows).
    pub fn update(&mut self, overflow: bool) {
        use crate::metrics::registry;
        if overflow {
            self.overflows += 1;
            self.good_steps = 0;
            if self.dynamic {
                self.scale = (self.scale * 0.5).max(Self::MIN_SCALE);
                registry::SCALER_BACKOFFS.add(1);
                registry::SCALER_SCALE.set(self.scale as f64);
            }
            return;
        }
        if !self.dynamic {
            return;
        }
        self.good_steps += 1;
        if self.good_steps >= self.growth_interval {
            self.scale = (self.scale * 2.0).min(Self::MAX_SCALE);
            self.good_steps = 0;
            registry::SCALER_GROWTHS.add(1);
            registry::SCALER_SCALE.set(self.scale as f64);
        }
    }

    /// Serialize as the checkpoint tensor [`LOSS_SCALE_TENSOR`]:
    /// `[scale, good_steps, dynamic]` (the counters fit f32 exactly —
    /// `good_steps < growth_interval ≤ 2^24`).
    pub fn export_tensor(&self) -> (String, TensorF32) {
        (
            LOSS_SCALE_TENSOR.to_string(),
            TensorF32::new(
                vec![3],
                vec![
                    self.scale,
                    self.good_steps as f32,
                    if self.dynamic { 1.0 } else { 0.0 },
                ],
            ),
        )
    }

    /// Restore scale + quiet-step counter from a checkpoint tensor.  The
    /// `dynamic` flag stays whatever the current config says (the config
    /// owns the policy; the checkpoint owns the trajectory).  For a
    /// *fixed* scaler the configured scale IS the policy, so only the
    /// telemetry counter is restored and the pinned scale stands.
    pub fn import_tensor(&mut self, t: &TensorF32) -> Result<()> {
        if t.data.len() != 3 {
            bail!(
                "loss-scale state tensor has {} elements, expected 3 \
                 (scale, good_steps, dynamic)",
                t.data.len()
            );
        }
        let scale = t.data[0];
        if !scale.is_finite() || scale <= 0.0 {
            bail!("loss-scale state has non-positive scale {scale}");
        }
        if self.dynamic {
            self.scale = round_pow2(scale);
            self.good_steps = t.data[1] as u64;
        }
        Ok(())
    }
}

/// Nearest power of two (in log space), clamped to the legal scale range.
fn round_pow2(x: f32) -> f32 {
    assert!(x.is_finite() && x > 0.0, "loss scale must be positive, got {x}");
    let e = x.log2().round() as i32;
    2.0f32
        .powi(e)
        .clamp(DynamicLossScaler::MIN_SCALE, DynamicLossScaler::MAX_SCALE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_builds_the_right_scaler() {
        assert!(LossScale::Off.build().is_none());
        assert!(!LossScale::Off.enabled());
        let s = LossScale::Static(1024.0).build().unwrap();
        assert_eq!(s.scale(), 1024.0);
        assert!(!s.is_dynamic());
        let d = LossScale::Dynamic { init: 65536.0 }.build().unwrap();
        assert_eq!(d.scale(), 65536.0);
        assert!(d.is_dynamic());
    }

    #[test]
    fn init_rounds_to_power_of_two() {
        assert_eq!(DynamicLossScaler::dynamic(1000.0).scale(), 1024.0);
        assert_eq!(DynamicLossScaler::dynamic(1.5).scale(), 2.0);
        // sub-unit scales are legal (they *shrink* oversized wire sums)
        assert_eq!(DynamicLossScaler::dynamic(0.01).scale(), 0.0078125); // 2^-7
        // out-of-range inits clamp to the legal bounds
        assert_eq!(DynamicLossScaler::dynamic(1e30).scale(), DynamicLossScaler::MAX_SCALE);
        assert_eq!(DynamicLossScaler::dynamic(1e-30).scale(), DynamicLossScaler::MIN_SCALE);
        assert_eq!(DynamicLossScaler::MIN_SCALE, 2.0f32.powi(-24));
    }

    #[test]
    fn overflow_backs_off_growth_restores() {
        let mut s = DynamicLossScaler::dynamic(65536.0).with_growth_interval(3);
        s.update(true);
        assert_eq!(s.scale(), 32768.0);
        assert_eq!(s.overflows(), 1);
        // two clean steps: not enough to grow
        s.update(false);
        s.update(false);
        assert_eq!(s.scale(), 32768.0);
        // third clean step grows; counter resets
        s.update(false);
        assert_eq!(s.scale(), 65536.0);
        s.update(false);
        s.update(false);
        // an overflow resets the quiet counter too
        s.update(true);
        assert_eq!(s.scale(), 32768.0);
        s.update(false);
        s.update(false);
        assert_eq!(s.scale(), 32768.0);
    }

    #[test]
    fn scale_stays_power_of_two_and_bounded() {
        let mut s = DynamicLossScaler::dynamic(65536.0).with_growth_interval(1);
        for _ in 0..40 {
            s.update(false);
            assert!(s.scale() <= DynamicLossScaler::MAX_SCALE);
            assert_eq!(s.scale().log2().fract(), 0.0);
        }
        assert_eq!(s.scale(), DynamicLossScaler::MAX_SCALE);
        for _ in 0..60 {
            s.update(true);
            assert!(s.scale() >= DynamicLossScaler::MIN_SCALE);
        }
        assert_eq!(s.scale(), DynamicLossScaler::MIN_SCALE);
    }

    #[test]
    fn fixed_scale_never_moves() {
        let mut s = DynamicLossScaler::fixed(256.0).with_growth_interval(1);
        s.update(true);
        s.update(false);
        s.update(false);
        assert_eq!(s.scale(), 256.0);
        assert_eq!(s.overflows(), 1);
    }

    #[test]
    fn inv_scale_is_exact() {
        let s = DynamicLossScaler::dynamic(65536.0);
        assert_eq!(s.inv_scale() * s.scale(), 1.0);
        assert_eq!(s.inv_scale(), 2.0f32.powi(-16));
    }

    #[test]
    fn state_roundtrips_through_tensor() {
        let mut a = DynamicLossScaler::dynamic(65536.0).with_growth_interval(100);
        a.update(true);
        a.update(false);
        a.update(false);
        let (name, t) = a.export_tensor();
        assert_eq!(name, LOSS_SCALE_TENSOR);
        let mut b = DynamicLossScaler::dynamic(2.0).with_growth_interval(100);
        b.import_tensor(&t).unwrap();
        assert_eq!(b.scale(), a.scale());
        // continue in lockstep
        for ov in [false, true, false] {
            a.update(ov);
            b.update(ov);
            assert_eq!(a.scale(), b.scale());
        }
    }

    #[test]
    fn fixed_scaler_keeps_its_configured_scale_on_import() {
        // the user pinned the scale in the config: a checkpoint written by
        // an earlier dynamic run must not silently override it
        let mut dynamic = DynamicLossScaler::dynamic(65536.0);
        for _ in 0..6 {
            dynamic.update(true); // walk down to 2^10
        }
        let (_, state) = dynamic.export_tensor();
        let mut pinned = DynamicLossScaler::fixed(65536.0);
        pinned.import_tensor(&state).unwrap();
        assert_eq!(pinned.scale(), 65536.0);
        // a dynamic scaler does adopt the checkpointed trajectory
        let mut resumed = DynamicLossScaler::dynamic(2.0);
        resumed.import_tensor(&state).unwrap();
        assert_eq!(resumed.scale(), 1024.0);
    }

    #[test]
    fn import_rejects_garbage() {
        let mut s = DynamicLossScaler::dynamic(2.0);
        let bad_len = TensorF32::new(vec![2], vec![1.0, 0.0]);
        assert!(s.import_tensor(&bad_len).is_err());
        let bad_scale = TensorF32::new(vec![3], vec![-4.0, 0.0, 1.0]);
        assert!(s.import_tensor(&bad_scale).is_err());
    }
}
