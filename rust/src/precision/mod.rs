//! Mixed-precision subsystem: software half-precision formats, the packed
//! wire buffer, and dynamic loss scaling — the numerics layer behind the
//! paper's fp16 run (192 P3dn nodes move gradients over EFA in half
//! precision while the optimizer keeps fp32 master state).
//!
//! Three pieces, each consumed by a different layer of the stack:
//!
//! * [`half`] — bit-level `f32 ↔ f16/bf16` conversion (round-to-nearest-
//!   even, overflow → ±inf, full subnormal support) and the packed
//!   [`HalfVec`] buffer that is the wire format of the half-precision
//!   collectives (`collective::half`).
//! * [`DType`] — the element-type knob (`TrainConfig::grad_dtype`) that
//!   selects the gradient wire format.  `DType::F32` is the identity wire:
//!   routing through the precision-aware entry points with `F32` is
//!   exact-bit identical to the historical f32 path.
//! * [`scaler`] — [`DynamicLossScaler`]: power-of-two loss scales with
//!   backoff-on-overflow / growth-after-quiet-interval, plus the
//!   [`LossScale`] config knob.  The scaled gradient is unscaled inside
//!   the optimizer's grad² phase (`optim::native::step_scaled`), where
//!   inf/nan detection turns an overflowed step into a skip.
//!
//! Exact-bit boundary (DESIGN.md §7): master parameters and optimizer
//! moments are always f32; only the gradient *wire* carries half data.
//! Power-of-two scales make scale→unscale a bit-exact round trip, so with
//! an f32 wire the loss-scaled trajectory is identical to the unscaled
//! one (property-tested in `tests/proptests.rs`).

pub mod half;
pub mod scaler;

pub use half::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
    quantize_accumulate, round_trip_slice, HalfVec,
};
pub use scaler::{DynamicLossScaler, LossScale};

/// Element type of a wire buffer.  `F32` is the identity (historical)
/// format; the half formats quantize at the wire boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    /// IEEE 754 binary16: 5 exponent bits, 10 mantissa bits.  Narrow range
    /// (max 65504) — the format that needs loss scaling.
    F16,
    /// bfloat16: 8 exponent bits (f32's range), 7 mantissa bits.
    Bf16,
}

impl DType {
    /// Bytes one element occupies on the wire.
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::Bf16 => 2,
        }
    }

    pub fn is_half(&self) -> bool {
        !matches!(self, DType::F32)
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
        }
    }

    /// Parse a config-file spelling.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" | "fp32" | "float32" => Some(DType::F32),
            "f16" | "fp16" | "half" | "float16" => Some(DType::F16),
            "bf16" | "bfloat16" => Some(DType::Bf16),
            _ => None,
        }
    }

    /// One trip across the wire: quantize to this dtype and back to f32
    /// (round-to-nearest-even; overflow → ±inf).  Identity for `F32`.
    #[inline]
    pub fn round_trip(&self, x: f32) -> f32 {
        match self {
            DType::F32 => x,
            DType::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
            DType::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_and_names() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::Bf16.bytes(), 2);
        assert!(!DType::F32.is_half());
        assert!(DType::F16.is_half() && DType::Bf16.is_half());
        for d in [DType::F32, DType::F16, DType::Bf16] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("fp16"), Some(DType::F16));
        assert_eq!(DType::parse("bfloat16"), Some(DType::Bf16));
        assert_eq!(DType::parse("int8"), None);
    }

    #[test]
    fn f32_round_trip_is_identity() {
        for x in [0.0f32, -1.5, 3.0e38, f32::INFINITY, 1e-42] {
            assert_eq!(DType::F32.round_trip(x).to_bits(), x.to_bits());
        }
    }
}
