//! `lans-inspect` — forensic CLI over the run artifacts the trainer emits.
//!
//! Four subcommands, each reading one artifact kind:
//!
//! * `summary <report.json>` — render a metrics report (schema
//!   `lans-metrics-report-v1`) as a human-readable digest: throughput,
//!   loss, timing percentiles, health verdicts.
//! * `timeline <trace.json> [--step N] [--width W]` — ASCII view of a
//!   Chrome-trace export: one row per lane, spans drawn to scale so
//!   stragglers and overlap gaps are visible without opening a browser.
//! * `diff <baseline.json> <candidate.json> [--threshold PCT]` — compare
//!   two metrics reports; exits nonzero when the candidate regresses
//!   (p50 step time beyond the threshold, or healthy → unhealthy) so CI
//!   can gate on it.
//! * `postmortem <bundle.json>` — turn a flight-recorder bundle (schema
//!   `lans-postmortem-v1`) into a culprit report: what tripped, which
//!   lane/stage is implicated, and the last-K steps leading up to it.
//!
//! Everything is read via the crate's own strict JSON parser — no new
//! dependencies, and a malformed artifact fails loudly with its path.

use std::collections::HashMap;
use std::process::ExitCode;

use lans::util::json::Json;

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut flags = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{a}'"))?;
            let val = it
                .next()
                .ok_or_else(|| format!("flag --{key} is missing a value"))?;
            flags.insert(key.to_string(), val.clone());
        }
        Ok(Args { flags })
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: lans-inspect <command> ...
  summary    <report.json>                           digest of a metrics report
  timeline   <trace.json> [--step N] [--width W]     ASCII span timeline
  diff       <baseline.json> <candidate.json> [--threshold PCT]
                                                     compare two reports (exit 1 on regression)
  postmortem <bundle.json>                           culprit report from a flight bundle";

fn run(argv: &[String]) -> Result<(), String> {
    match argv.first().map(String::as_str) {
        Some("summary") => {
            let path = argv.get(1).ok_or("summary: missing <report.json>")?;
            cmd_summary(path)
        }
        Some("timeline") => {
            let path = argv.get(1).ok_or("timeline: missing <trace.json>")?;
            let args = Args::parse(&argv[2..])?;
            let step = match args.flags.get("step") {
                None => None,
                Some(v) => Some(
                    v.parse::<u64>()
                        .map_err(|_| format!("--step expects an integer, got '{v}'"))?,
                ),
            };
            let width = args.get_usize("width", 100)?;
            cmd_timeline(path, step, width.max(20))
        }
        Some("diff") => {
            let base = argv.get(1).ok_or("diff: missing <baseline.json>")?;
            let cand = argv.get(2).ok_or("diff: missing <candidate.json>")?;
            let args = Args::parse(&argv[3..])?;
            let threshold = args.get_f64("threshold", 20.0)?;
            cmd_diff(base, cand, threshold)
        }
        Some("postmortem") => {
            let path = argv.get(1).ok_or("postmortem: missing <bundle.json>")?;
            cmd_postmortem(path)
        }
        Some(other) => Err(format!("unknown command '{other}'\n{USAGE}")),
        None => Err(USAGE.to_string()),
    }
}

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: invalid JSON: {e:?}"))
}

/// Required key lookup with the artifact path in the error.
fn want<'a>(j: &'a Json, key: &str, path: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("{path}: missing key '{key}'"))
}

fn f64_of(j: &Json, key: &str, path: &str) -> Result<f64, String> {
    want(j, key, path)?
        .as_f64()
        .ok_or_else(|| format!("{path}: key '{key}' is not a number"))
}

fn str_of<'a>(j: &'a Json, key: &str, path: &str) -> Result<&'a str, String> {
    want(j, key, path)?
        .as_str()
        .ok_or_else(|| format!("{path}: key '{key}' is not a string"))
}

// ---------------------------------------------------------------- summary --

fn check_schema(j: &Json, expect: &str, path: &str) -> Result<(), String> {
    let got = str_of(j, "schema", path)?;
    if got != expect {
        return Err(format!("{path}: schema is '{got}', expected '{expect}'"));
    }
    Ok(())
}

fn timing_line(j: &Json, key: &str, path: &str) -> Result<Option<String>, String> {
    let Some(t) = j.get(key) else { return Ok(None) };
    if matches!(t, Json::Null) {
        return Ok(None);
    }
    let samples = f64_of(t, "samples", path)?;
    if samples == 0.0 {
        return Ok(None);
    }
    Ok(Some(format!(
        "  {key:<13} mean {:>9.6}s  p50 {:>9.6}s  p90 {:>9.6}s  p99 {:>9.6}s  max {:>9.6}s  ({} samples)",
        f64_of(t, "mean_s", path)?,
        f64_of(t, "p50_s", path)?,
        f64_of(t, "p90_s", path)?,
        f64_of(t, "p99_s", path)?,
        f64_of(t, "max_s", path)?,
        samples as u64,
    )))
}

fn cmd_summary(path: &str) -> Result<(), String> {
    let j = load(path)?;
    check_schema(&j, "lans-metrics-report-v1", path)?;

    let steps = f64_of(&j, "steps", path)? as u64;
    let skipped = f64_of(&j, "skipped_steps", path)? as u64;
    let tokens = f64_of(&j, "tokens", path)?;
    // null on zero-step runs (non-finite values serialize as null)
    let tps = want(&j, "tokens_per_second", path)?.as_f64().unwrap_or(f64::NAN);
    let loss = want(&j, "final_loss", path)?.as_f64().unwrap_or(f64::NAN);
    let ema = want(&j, "final_loss_ema", path)?.as_f64().unwrap_or(f64::NAN);
    let diverged = want(&j, "diverged", path)?.as_bool().unwrap_or(false);

    println!("run summary — {path}");
    println!(
        "  steps         {steps} ({skipped} skipped)  tokens {tokens:.0}  throughput {tps:.0} tok/s"
    );
    println!("  final loss    {loss:.6} (ema {ema:.6}){}", if diverged { "  DIVERGED" } else { "" });
    for key in ["step_time", "comm_time", "compute_time"] {
        if let Some(line) = timing_line(&j, key, path)? {
            println!("{line}");
        }
    }
    if let Some(m @ Json::Obj(_)) = j.get("model") {
        let model = f64_of(m, "model_step_time_s", path)?;
        let measured = f64_of(m, "measured_step_time_s", path)?;
        let delta = f64_of(m, "delta_frac", path)?;
        println!(
            "  perf model    predicted {model:.6}s  measured {measured:.6}s  delta {:+.1}%",
            delta * 100.0
        );
    }
    let health = want(&j, "health", path)?;
    let healthy = want(health, "healthy", path)?.as_bool().unwrap_or(false);
    let verdicts = want(health, "verdicts", path)?
        .as_arr()
        .ok_or_else(|| format!("{path}: health.verdicts is not an array"))?;
    println!("  health        {}", if healthy { "healthy" } else { "UNHEALTHY" });
    for v in verdicts {
        let sev = str_of(v, "severity", path)?;
        let kind = str_of(v, "kind", path)?;
        let step = f64_of(v, "step", path)? as u64;
        let msg = str_of(v, "message", path)?;
        let detail = v.get("detail").and_then(Json::as_str).unwrap_or("");
        if detail.is_empty() {
            println!("    [{sev}] {kind} @ step {step}: {msg}");
        } else {
            println!("    [{sev}] {kind} @ step {step}: {msg} ({detail})");
        }
    }
    Ok(())
}

// --------------------------------------------------------------- timeline --

struct TlSpan {
    name: String,
    cat: String,
    start_us: f64,
    dur_us: f64,
    step: u64,
}

/// Category → single glyph so dense rows stay legible.
fn cat_glyph(cat: &str) -> char {
    match cat {
        "sched" => 's',
        "wait" => '.',
        "comm" => 'c',
        "compute" => '#',
        "pool" => 'p',
        "convert" => 'v',
        "step" => '=',
        _ => '?',
    }
}

fn cmd_timeline(path: &str, step: Option<u64>, width: usize) -> Result<(), String> {
    let j = load(path)?;
    let events = want(&j, "traceEvents", path)?
        .as_arr()
        .ok_or_else(|| format!("{path}: traceEvents is not an array"))?;

    // tid → lane name from "M" metadata events
    let mut lane_names: HashMap<u64, String> = HashMap::new();
    let mut lanes: Vec<(u64, Vec<TlSpan>)> = Vec::new();
    for ev in events {
        let ph = str_of(ev, "ph", path)?;
        let tid = f64_of(ev, "tid", path)? as u64;
        if ph == "M" {
            if let Some(name) = ev.get("args").and_then(|a| a.get("name")).and_then(Json::as_str) {
                lane_names.insert(tid, name.to_string());
            }
            continue;
        }
        if ph != "X" {
            continue;
        }
        let ev_step = ev
            .get("args")
            .and_then(|a| a.get("step"))
            .and_then(Json::as_f64)
            .map(|s| s as u64);
        if let (Some(want_step), Some(got)) = (step, ev_step) {
            if got != want_step {
                continue;
            }
        }
        let span = TlSpan {
            name: str_of(ev, "name", path)?.to_string(),
            cat: ev.get("cat").and_then(Json::as_str).unwrap_or("?").to_string(),
            start_us: f64_of(ev, "ts", path)?,
            dur_us: f64_of(ev, "dur", path)?,
            step: ev_step.unwrap_or(0),
        };
        match lanes.iter_mut().find(|(t, _)| *t == tid) {
            Some((_, v)) => v.push(span),
            None => lanes.push((tid, vec![span])),
        }
    }
    if lanes.iter().all(|(_, v)| v.is_empty()) {
        return Err(match step {
            Some(s) => format!("{path}: no spans for step {s}"),
            None => format!("{path}: no spans in trace"),
        });
    }

    let t0 = lanes
        .iter()
        .flat_map(|(_, v)| v.iter())
        .map(|s| s.start_us)
        .fold(f64::INFINITY, f64::min);
    let t1 = lanes
        .iter()
        .flat_map(|(_, v)| v.iter())
        .map(|s| s.start_us + s.dur_us)
        .fold(f64::NEG_INFINITY, f64::max);
    let total_us = (t1 - t0).max(1e-9);
    let scale = width as f64 / total_us;

    let steps: Vec<u64> = {
        let mut v: Vec<u64> = lanes.iter().flat_map(|(_, s)| s.iter().map(|x| x.step)).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    println!("timeline — {path}");
    match step {
        Some(s) => println!("  step {s}, span {:.3} ms, 1 col = {:.1} µs", total_us / 1e3, 1.0 / scale),
        None => println!(
            "  steps {:?}, span {:.3} ms, 1 col = {:.1} µs",
            steps,
            total_us / 1e3,
            1.0 / scale
        ),
    }
    println!("  glyphs: s=sched .=wait c=comm #=compute p=pool v=convert ==step");

    lanes.sort_by_key(|(tid, _)| *tid);
    let name_w = lanes
        .iter()
        .map(|(tid, _)| lane_names.get(tid).map_or(6, String::len))
        .max()
        .unwrap_or(6)
        .max(6);
    for (tid, spans) in &lanes {
        let default_name = format!("tid {tid}");
        let name = lane_names.get(tid).cloned().unwrap_or(default_name);
        let mut row: Vec<char> = vec![' '; width];
        // draw big spans first so short ones stay visible on top
        let mut order: Vec<&TlSpan> = spans.iter().collect();
        order.sort_by(|a, b| b.dur_us.total_cmp(&a.dur_us));
        for s in order {
            let lo = (((s.start_us - t0) * scale) as usize).min(width - 1);
            let hi = ((((s.start_us + s.dur_us) - t0) * scale).ceil() as usize).clamp(lo + 1, width);
            let g = cat_glyph(&s.cat);
            for cell in &mut row[lo..hi] {
                *cell = g;
            }
        }
        println!("  {name:<name_w$} |{}|", row.iter().collect::<String>());
    }

    // per-lane busiest span, so the picture has numbers attached
    println!("  longest span per lane:");
    for (tid, spans) in &lanes {
        let default_name = format!("tid {tid}");
        let name = lane_names.get(tid).cloned().unwrap_or(default_name);
        if let Some(s) = spans.iter().max_by(|a, b| a.dur_us.total_cmp(&b.dur_us)) {
            println!(
                "    {name:<name_w$} {:<18} [{}] {:.3} ms @ step {}",
                s.name,
                s.cat,
                s.dur_us / 1e3,
                s.step
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- diff --

fn delta_pct(base: f64, cand: f64) -> f64 {
    if base == 0.0 {
        if cand == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (cand - base) / base * 100.0
    }
}

fn cmd_diff(base_path: &str, cand_path: &str, threshold: f64) -> Result<(), String> {
    let base = load(base_path)?;
    let cand = load(cand_path)?;
    check_schema(&base, "lans-metrics-report-v1", base_path)?;
    check_schema(&cand, "lans-metrics-report-v1", cand_path)?;

    println!("diff — baseline {base_path} vs candidate {cand_path}");
    let mut regressions: Vec<String> = Vec::new();

    // scalar rows: (label, key, lower-is-better)
    let scalar_rows = [
        ("tokens/s", "tokens_per_second", false),
        ("final loss", "final_loss", true),
        ("final loss ema", "final_loss_ema", true),
        ("skipped steps", "skipped_steps", true),
    ];
    for (label, key, _lower_better) in scalar_rows {
        // null (zero-step run) compares as NaN: printed, never a regression
        let b = want(&base, key, base_path)?.as_f64().unwrap_or(f64::NAN);
        let c = want(&cand, key, cand_path)?.as_f64().unwrap_or(f64::NAN);
        println!("  {label:<16} {b:>12.4} -> {c:>12.4}  ({:+.1}%)", delta_pct(b, c));
    }

    for key in ["step_time", "comm_time", "compute_time"] {
        let (Some(bt), Some(ct)) = (base.get(key), cand.get(key)) else { continue };
        if matches!(bt, Json::Null) || matches!(ct, Json::Null) {
            continue;
        }
        if f64_of(bt, "samples", base_path)? == 0.0 || f64_of(ct, "samples", cand_path)? == 0.0 {
            continue;
        }
        for q in ["p50_s", "p90_s", "p99_s"] {
            let b = f64_of(bt, q, base_path)?;
            let c = f64_of(ct, q, cand_path)?;
            let pct = delta_pct(b, c);
            println!("  {key}.{q:<8} {b:>12.6} -> {c:>12.6}  ({pct:+.1}%)");
            if key == "step_time" && q == "p50_s" && pct > threshold {
                regressions.push(format!(
                    "step_time.p50 regressed {pct:+.1}% (threshold +{threshold:.1}%)"
                ));
            }
        }
    }

    let healthy = |j: &Json, p: &str| -> Result<bool, String> {
        Ok(want(j, "health", p)?.get("healthy").and_then(Json::as_bool).unwrap_or(false))
    };
    let (bh, ch) = (healthy(&base, base_path)?, healthy(&cand, cand_path)?);
    println!(
        "  health           {:>12} -> {:>12}",
        if bh { "healthy" } else { "unhealthy" },
        if ch { "healthy" } else { "unhealthy" }
    );
    if bh && !ch {
        regressions.push("health regressed: baseline healthy, candidate unhealthy".to_string());
    }
    let bd = want(&base, "diverged", base_path)?.as_bool().unwrap_or(false);
    let cd = want(&cand, "diverged", cand_path)?.as_bool().unwrap_or(false);
    if !bd && cd {
        regressions.push("candidate diverged; baseline did not".to_string());
    }

    if regressions.is_empty() {
        println!("  verdict: OK (threshold +{threshold:.1}% on step_time.p50)");
        Ok(())
    } else {
        for r in &regressions {
            println!("  REGRESSION: {r}");
        }
        Err(format!("{} regression(s) detected", regressions.len()))
    }
}

// ------------------------------------------------------------- postmortem --

fn cmd_postmortem(path: &str) -> Result<(), String> {
    let j = load(path)?;
    check_schema(&j, "lans-postmortem-v1", path)?;

    let trig = want(&j, "trigger", path)?;
    let kind = str_of(trig, "kind", path)?;
    let t_step = f64_of(trig, "step", path)? as u64;
    let msg = str_of(trig, "message", path)?;

    println!("postmortem — {path}");
    println!("  trigger   {kind} @ step {t_step}");
    println!("            {msg}");

    match want(&j, "culprit", path)? {
        Json::Null => println!("  culprit   (none attributed)"),
        c => {
            let lane = str_of(c, "lane", path)?;
            let stage = str_of(c, "stage", path)?;
            let dur = f64_of(c, "dur_s", path)?;
            println!("  culprit   lane '{lane}', stage '{stage}' ({dur:.3e}s)");
        }
    }

    let flight_steps = f64_of(&j, "flight_steps", path)? as usize;
    let frames = want(&j, "frames", path)?
        .as_arr()
        .ok_or_else(|| format!("{path}: frames is not an array"))?;
    println!("  flight    {} of up to {flight_steps} frames retained", frames.len());
    println!("            step     loss       grad_norm  scale      applied  flags");
    for f in frames {
        let step = f64_of(f, "step", path)? as u64;
        let partial = want(f, "partial", path)?.as_bool().unwrap_or(false);
        let scale = f64_of(f, "loss_scale", path)?;
        let applied = f64_of(f, "applied_steps", path)? as u64;
        let (loss, gnorm, skipped) = match want(f, "record", path)? {
            Json::Null => (None, None, false),
            r => (
                r.get("loss").and_then(Json::as_f64),
                r.get("grad_norm").and_then(Json::as_f64),
                r.get("skipped").and_then(Json::as_bool).unwrap_or(false),
            ),
        };
        let fmt_opt = |v: Option<f64>| match v {
            Some(x) => format!("{x:<10.4}"),
            None => format!("{:<10}", "-"),
        };
        let mut flags = Vec::new();
        if partial {
            flags.push("partial");
        }
        if skipped {
            flags.push("skipped");
        }
        println!(
            "            {step:<8} {} {} {scale:<10.1} {applied:<8} {}",
            fmt_opt(loss),
            fmt_opt(gnorm),
            flags.join(",")
        );
    }

    let verdicts = want(&j, "verdicts", path)?
        .as_arr()
        .ok_or_else(|| format!("{path}: verdicts is not an array"))?;
    if verdicts.is_empty() {
        println!("  verdicts  (none in retained window)");
    } else {
        println!("  verdicts:");
        for v in verdicts {
            let sev = str_of(v, "severity", path)?;
            let vkind = str_of(v, "kind", path)?;
            let vstep = f64_of(v, "step", path)? as u64;
            let vmsg = str_of(v, "message", path)?;
            let detail = v.get("detail").and_then(Json::as_str).unwrap_or("");
            if detail.is_empty() {
                println!("    [{sev}] {vkind} @ step {vstep}: {vmsg}");
            } else {
                println!("    [{sev}] {vkind} @ step {vstep}: {vmsg} ({detail})");
            }
        }
    }

    if let Some(Json::Obj(cfg)) = j.get("config") {
        println!("  config echo:");
        for (k, v) in cfg {
            println!("    {k} = {}", v.as_str().unwrap_or("?"));
        }
    }
    Ok(())
}
