//! # lans — Accelerated Large-Batch BERT Pretraining
//!
//! A full-system reproduction of *"Accelerated Large Batch Optimization of
//! BERT Pretraining in 54 minutes"* (Zheng, Lin, Zha, Li; 2020): the LANS
//! optimizer, the warmup→constant→decay learning-rate schedule, sharded
//! without-replacement data sampling, and the distributed data-parallel
//! training harness they run in — as a three-layer rust + JAX + Pallas
//! stack (rust coordinator, AOT-lowered jax BERT, Pallas fused-optimizer
//! kernels), with Python never on the training hot path.
//!
//! See DESIGN.md for the architecture and the paper-experiment index, and
//! `examples/` for runnable entry points.

pub mod checkpoint;
pub mod cluster;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod precision;
pub mod runtime;
pub mod simd;
pub mod topology;
pub mod trace;
pub mod util;
pub mod variance;
