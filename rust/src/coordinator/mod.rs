//! L3 coordinator: leader/worker topology, gradient accumulation, the
//! synchronous data-parallel step loop, and the data-source plumbing.

pub mod dag;
pub mod source;
pub mod trainer;
pub mod worker;

pub use dag::{replicated_bucketed_step, sharded_bucketed_step, StepDag};
pub use source::DataSource;
pub use trainer::{TrainReport, TrainStatus, Trainer};
pub use worker::{WorkerCmd, WorkerHandle, WorkerReply};
