//! Step-DAG scheduler: the bucketed gradient pipeline.
//!
//! One training step is a small dependency graph over gradient *buckets*
//! cut on the shard plan's `NORM_SEG` grid ([`ShardPlan::bucket_starts`]):
//!
//! ```text
//!   R_0 ──► R_1 ──► R_2 ──► …        comm lane (one wire, in order)
//!    │       │       │
//!    ▼       ▼       ▼
//!   S_0 ──► S_1 ──► S_2 ──► …        compute lane (stitch/unscale)
//! ```
//!
//! `R_k` reduce-scatters (or allreduces) bucket `k`; `S_k` stitches /
//! unscales it and emits its grad² partials.  `S_k` depends on `R_k` *and*
//! `S_{k-1}`, so while the wire carries bucket `k`, the CPU digests bucket
//! `k-1` — the classic DDP overlap, executed here on the persistent
//! [`ThreadPool`] via a handful of driver tokens.
//!
//! Bit-identity contract (DESIGN.md §9): every per-element f32 reduction
//! runs the *full* ring schedule clipped to the bucket's range, so the
//! summation order per element is exactly the phase-synchronous ring's;
//! the per-block grad² f64 folds visit segments in the same global order
//! as the fused phase-synchronous step.  The bucketed step is therefore
//! exact-bit equal to the monolithic one for every optimizer × topology ×
//! wire-dtype combination — overlap changes *when* work runs, never what
//! it computes (stages mutate disjoint bucket views; the DAG edges order
//! every read-after-write).
//!
//! [`ShardPlan::bucket_starts`]: crate::optim::ShardPlan::bucket_starts

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::collective::{
    hierarchical_all_gather_views, hierarchical_reduce_scatter_views, ring_chunk_starts,
};
use crate::optim::native::unscale_grad_sq_segments;
use crate::optim::{Optimizer, ParallelExecutor, ShardedOptimizer, StepStats};
use crate::topology::{TierPrecision, Topology, WireBytes};
use crate::trace;
use crate::util::pool::ThreadPool;

// ------------------------------------------------------------ executor ----

struct Stage<'scope> {
    label: &'static str,
    deps: Vec<usize>,
    run: Option<Box<dyn FnOnce() + Send + 'scope>>,
}

/// A small single-shot dependency graph of stages.  Stage ids are
/// insertion order and dependencies must point backwards, so insertion
/// order is always a valid topological order — the serial execution path
/// (overlap off, width-1 pool, or a single stage) just runs the stages in
/// the order they were declared, and the overlapped path can never
/// deadlock on a cycle.
pub struct StepDag<'scope> {
    stages: Vec<Stage<'scope>>,
}

struct Sched {
    deps_left: Vec<usize>,
    ready: VecDeque<usize>,
    /// When tracing: the instant each stage entered `ready`, so the driver
    /// that claims it can emit a queue-wait span (`None` when disabled).
    ready_at: Vec<Option<Instant>>,
    done: usize,
    poisoned: bool,
}

impl<'scope> StepDag<'scope> {
    pub fn new() -> StepDag<'scope> {
        StepDag { stages: Vec::new() }
    }

    /// Declare a stage that runs after every stage in `deps`.  Returns its
    /// id for later stages to depend on.
    pub fn stage<F>(&mut self, label: &'static str, deps: &[usize], f: F) -> usize
    where
        F: FnOnce() + Send + 'scope,
    {
        let id = self.stages.len();
        for &d in deps {
            assert!(d < id, "stage {label:?} depends on not-yet-declared stage {d}");
        }
        self.stages.push(Stage { label, deps: deps.to_vec(), run: Some(Box::new(f)) });
        id
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Execute every stage, respecting the declared edges.
    ///
    /// With `overlap` off (or a width-1 pool, or fewer than two stages)
    /// the stages run serially in insertion order on the calling thread —
    /// the reference schedule.  Otherwise `min(threads, stages)` driver
    /// tokens go through [`ThreadPool::map_mut`] and greedily claim ready
    /// stages from a shared queue; dependents are released as their last
    /// dependency completes.  Results are identical either way — the DAG
    /// edges order every conflicting access, overlap only changes timing.
    ///
    /// A panicking stage poisons the schedule: no new stage starts, every
    /// driver drains out, and the first panic payload is re-raised on the
    /// caller once the pool region has closed (mirroring `map_mut`'s own
    /// containment).  Stage bodies run inside a pool region, so a nested
    /// `map_mut` from within a stage degrades to the serial path — keep
    /// stage bodies serial and save the pool for the post-DAG apply.
    pub fn run(mut self, pool: &ThreadPool, overlap: bool) {
        let total = self.stages.len();
        if total == 0 {
            return;
        }
        if !overlap || pool.threads() <= 1 || total <= 1 {
            for (id, st) in self.stages.iter_mut().enumerate() {
                let _run = trace::span_detail(trace::CAT_SCHED, st.label, id as u64);
                match st.run.take() {
                    // with the flight recorder armed, contain-attribute-
                    // re-raise so the postmortem names the exact stage; the
                    // disarmed path stays a plain call (one relaxed load)
                    Some(f) if crate::obs::flight::enabled() => {
                        if let Err(p) = catch_unwind(AssertUnwindSafe(f)) {
                            crate::obs::flight::note_panic("dag", st.label);
                            std::panic::resume_unwind(p);
                        }
                    }
                    Some(f) => f(),
                    None => panic!("stage {:?} ran twice", st.label),
                }
            }
            return;
        }

        let deps_left: Vec<usize> = self.stages.iter().map(|s| s.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
        for (id, s) in self.stages.iter().enumerate() {
            for &d in &s.deps {
                dependents[d].push(id);
            }
        }
        let ready: VecDeque<usize> = (0..total).filter(|&i| deps_left[i] == 0).collect();
        assert!(!ready.is_empty(), "no root stage");
        // queue-wait stamps feed both the step trace and the metrics
        // registry — populate them if either consumer is on
        let observing = trace::enabled() || crate::metrics::registry::enabled();
        let mut ready_at: Vec<Option<Instant>> = vec![None; total];
        if observing {
            let now = Instant::now();
            for &i in &ready {
                ready_at[i] = Some(now);
            }
        }
        let labels: Vec<&'static str> = self.stages.iter().map(|s| s.label).collect();
        let labels = &labels;
        let runs: Vec<Mutex<Option<Box<dyn FnOnce() + Send + 'scope>>>> = self
            .stages
            .iter_mut()
            .map(|s| Mutex::new(s.run.take()))
            .collect();
        let sched = Mutex::new(Sched { deps_left, ready, ready_at, done: 0, poisoned: false });
        let cv = Condvar::new();
        let payload: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);

        let width = pool.threads().min(total);
        let mut tokens: Vec<usize> = (0..width).collect();
        pool.map_mut(&mut tokens, |_| loop {
            // claim a ready stage, or wait for one to be released
            let claimed = {
                let mut s = sched.lock().unwrap();
                loop {
                    if s.poisoned || s.done == total {
                        break None;
                    }
                    if let Some(id) = s.ready.pop_front() {
                        break Some((id, s.ready_at[id].take()));
                    }
                    s = cv.wait(s).unwrap();
                }
            };
            let Some((id, queued_at)) = claimed else {
                cv.notify_all();
                return;
            };
            if let Some(t) = queued_at {
                // queue-wait: released-by-last-dependency → claimed-by-a-driver
                let now = Instant::now();
                if trace::enabled() {
                    trace::record_span(trace::CAT_WAIT, labels[id], t, now, id as u64);
                }
                crate::metrics::registry::QUEUE_WAIT_US
                    .observe(now.duration_since(t).as_micros() as f64);
            }
            let f = runs[id].lock().unwrap().take().expect("stage scheduled twice");
            let run_span = trace::span_detail(trace::CAT_SCHED, labels[id], id as u64);
            let outcome = catch_unwind(AssertUnwindSafe(f));
            drop(run_span);
            match outcome {
                Ok(()) => {
                    let mut s = sched.lock().unwrap();
                    s.done += 1;
                    let now = observing.then(Instant::now);
                    for &d in &dependents[id] {
                        s.deps_left[d] -= 1;
                        if s.deps_left[d] == 0 {
                            s.ready.push_back(d);
                            s.ready_at[d] = now;
                        }
                    }
                }
                Err(p) => {
                    // name the panicking stage for the flight recorder
                    // before the payload crosses back to the caller
                    crate::obs::flight::note_panic("dag", labels[id]);
                    let mut slot = payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                    sched.lock().unwrap().poisoned = true;
                }
            }
            cv.notify_all();
        });

        if let Some(p) = payload.into_inner().unwrap() {
            std::panic::resume_unwind(p);
        }
    }
}

impl Default for StepDag<'_> {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------- bucket view carve ----

/// Split every worker buffer into per-bucket `&mut` views: `result[k]` is
/// bucket `k`'s view of each worker, behind a lock so the comm stage
/// (mutating all workers' bucket `k`) and the compute stage (reading it
/// one DAG edge later) can hand the borrows across driver threads.  The
/// views of distinct buckets are disjoint slices of the same buffers —
/// the aliasing the phase-synchronous path never needed, carved here once
/// so the stages themselves stay safe code.
type BucketViews<'a> = Vec<Mutex<Option<Vec<&'a mut [f32]>>>>;

fn carve_buckets<'a>(bufs: &'a mut [Vec<f32>], cuts: &[usize]) -> BucketViews<'a> {
    let nb = cuts.len() - 1;
    let mut per_bucket: Vec<Vec<&'a mut [f32]>> =
        (0..nb).map(|_| Vec::with_capacity(bufs.len())).collect();
    for buf in bufs.iter_mut() {
        let mut rest: &mut [f32] = buf;
        for (k, w) in cuts.windows(2).enumerate() {
            let (head, tail) = rest.split_at_mut(w[1] - w[0]);
            per_bucket[k].push(head);
            rest = tail;
        }
        assert!(rest.is_empty(), "bucket cuts must cover the whole buffer");
    }
    per_bucket.into_iter().map(|v| Mutex::new(Some(v))).collect()
}

fn check_cuts(cuts: &[usize], n: usize) {
    assert!(
        cuts.len() >= 2 && cuts[0] == 0 && *cuts.last().unwrap() == n,
        "bucket cuts {cuts:?} must partition 0..{n}"
    );
    assert!(cuts.windows(2).all(|w| w[0] < w[1]), "bucket cuts must increase");
}

// ----------------------------------------------------- sharded pipeline ----

/// The bucketed ZeRO-1 step: per bucket, reduce-scatter on the wire
/// (tiered, per-tier precision) then stitch into the shards' scratch
/// gradients with the mean/unscale factor folded in — comm of bucket `k`
/// overlapped with the stitch of bucket `k-1` — and finally one
/// [`ShardedOptimizer::apply_bucketed`] for the probe and phases B/C.
///
/// Exact-bit equal to `hierarchical_reduce_scatter_pooled` +
/// [`ShardedOptimizer::step_scattered`]/`_scaled` on the same buffers:
/// each bucket runs the full ring schedule clipped to its range, and the
/// grad² fold order matches the fused phase-synchronous region.  Returns
/// `None` (step skipped, no state touched) iff `probe` finds a non-finite
/// grad² — buckets already communicated leave no trace in the moments.
#[allow(clippy::too_many_arguments)]
pub fn sharded_bucketed_step(
    so: &mut ShardedOptimizer,
    pool: &ThreadPool,
    params: &mut [f32],
    bufs: &mut [Vec<f32>],
    cuts: &[usize],
    scale: f32,
    lr: f32,
    probe: bool,
    topo: &Topology,
    prec: TierPrecision,
    overlap: bool,
) -> (Option<StepStats>, WireBytes) {
    let w = bufs.len();
    assert!(w > 0, "no worker buffers");
    let n = bufs[0].len();
    check_cuts(cuts, n);
    let nb = cuts.len() - 1;
    let topo = *topo;
    let ring = ring_chunk_starts(w, n);
    let needs_g2 = so.bucketed_needs_g2(probe);
    so.begin_bucketed();

    let slots = carve_buckets(bufs, cuts);
    let parts: Vec<Mutex<Vec<Vec<(usize, Vec<f64>)>>>> =
        (0..nb).map(|_| Mutex::new(Vec::new())).collect();
    let wire = Mutex::new(WireBytes::default());
    {
        let so_cell = Mutex::new(&mut *so);
        let (so_cell, ring, wire) = (&so_cell, &ring, &wire);
        let mut dag = StepDag::new();
        let mut prev_comm: Vec<usize> = Vec::new();
        let mut prev_stitch: Option<usize> = None;
        for k in 0..nb {
            let (lo, hi) = (cuts[k], cuts[k + 1]);
            let slot = &slots[k];
            let comm = dag.stage("reduce_scatter", &prev_comm, move || {
                let mut views = slot.lock().unwrap().take().expect("bucket views taken");
                let b = hierarchical_reduce_scatter_views(&mut views, n, lo, &topo, prec);
                *wire.lock().unwrap() += b;
                *slot.lock().unwrap() = Some(views);
            });
            let parts_k = &parts[k];
            let deps: Vec<usize> = prev_stitch.into_iter().chain([comm]).collect();
            let stitch = dag.stage("stitch", &deps, move || {
                let views = slot.lock().unwrap().take().expect("bucket views taken");
                let shared: Vec<&[f32]> = views.iter().map(|v| &**v).collect();
                let p = so_cell
                    .lock()
                    .unwrap()
                    .stitch_bucket(&shared, ring, lo, hi, scale, needs_g2);
                *parts_k.lock().unwrap() = p;
            });
            prev_comm = vec![comm];
            prev_stitch = Some(stitch);
        }
        dag.run(pool, overlap);
    }
    drop(slots);

    let parts: Vec<Vec<Vec<(usize, Vec<f64>)>>> =
        parts.into_iter().map(|m| m.into_inner().unwrap()).collect();
    let stats = so.apply_bucketed(pool, params, lr, probe, &parts);
    (stats, wire.into_inner().unwrap())
}

// -------------------------------------------------- replicated pipeline ----

/// The bucketed replicated step: per bucket, a full allreduce
/// (reduce-scatter + all-gather on the clipped ring schedule) overlapped
/// with the unscale / grad²-probe sweep of the previous bucket, then one
/// [`Optimizer::step_prefolded`] (probed path) or
/// [`ParallelExecutor::step`] (plain path) on the assembled mean
/// gradient in `bufs[0]`.
///
/// Exact-bit equal to `hierarchical_allreduce_pooled` + the trainer's
/// replicated update for every optimizer: the probed fold visits grad²
/// segments in the same global order as `unscale_probe_pooled` (bucket
/// cuts sit on the `NORM_SEG` grid, so no segment straddles a cut), and
/// optimizers that discard the fold get it discarded here too.  Returns
/// `None` iff `probe` finds a non-finite grad².
#[allow(clippy::too_many_arguments)]
pub fn replicated_bucketed_step(
    opt: &mut dyn Optimizer,
    exec: &ParallelExecutor,
    params: &mut [f32],
    bufs: &mut [Vec<f32>],
    cuts: &[usize],
    scale: f32,
    lr: f32,
    probe: bool,
    topo: &Topology,
    prec: TierPrecision,
    overlap: bool,
) -> (Option<StepStats>, WireBytes) {
    let w = bufs.len();
    assert!(w > 0, "no worker buffers");
    let n = bufs[0].len();
    check_cuts(cuts, n);
    let nb = cuts.len() - 1;
    let topo = *topo;
    // block geometry for the per-bucket probe sweep (cuts are grid points,
    // so every block piece starts on a NORM_SEG segment boundary)
    let blocks: Vec<(usize, usize)> =
        opt.blocks().blocks.iter().map(|b| (b.offset, b.len)).collect();
    let nblocks = blocks.len();

    let slots = carve_buckets(bufs, cuts);
    let parts: Vec<Mutex<Vec<(usize, Vec<f64>)>>> =
        (0..nb).map(|_| Mutex::new(Vec::new())).collect();
    let wire = Mutex::new(WireBytes::default());
    {
        let (blocks, wire) = (&blocks, &wire);
        let mut dag = StepDag::new();
        let mut prev_comm: Vec<usize> = Vec::new();
        let mut prev_sweep: Option<usize> = None;
        for k in 0..nb {
            let (lo, hi) = (cuts[k], cuts[k + 1]);
            let slot = &slots[k];
            let comm = dag.stage("allreduce", &prev_comm, move || {
                let mut views = slot.lock().unwrap().take().expect("bucket views taken");
                let b = hierarchical_reduce_scatter_views(&mut views, n, lo, &topo, prec)
                    + hierarchical_all_gather_views(&mut views, n, lo, &topo, prec);
                *wire.lock().unwrap() += b;
                *slot.lock().unwrap() = Some(views);
            });
            let parts_k = &parts[k];
            let deps: Vec<usize> = prev_sweep.into_iter().chain([comm]).collect();
            let sweep = dag.stage("unscale", &deps, move || {
                let _sp = trace::span_detail(trace::CAT_COMPUTE, "bucket_unscale", k as u64);
                let mut views = slot.lock().unwrap().take().expect("bucket views taken");
                let mine = &mut views[0];
                if probe {
                    let mut out = Vec::new();
                    for (bi, &(off, len)) in blocks.iter().enumerate() {
                        let (plo, phi) = (off.max(lo), (off + len).min(hi));
                        if plo >= phi {
                            continue;
                        }
                        let mut ps = Vec::new();
                        unscale_grad_sq_segments(&mut mine[plo - lo..phi - lo], scale, |p| {
                            ps.push(p)
                        });
                        out.push((bi, ps));
                    }
                    *parts_k.lock().unwrap() = out;
                } else {
                    for g in mine.iter_mut() {
                        *g *= scale;
                    }
                }
                *slot.lock().unwrap() = Some(views);
            });
            prev_comm = vec![comm];
            prev_sweep = Some(sweep);
        }
        dag.run(exec.pool(), overlap);
    }
    drop(slots);
    let wire = wire.into_inner().unwrap();

    if probe {
        // fold bucket-major: each block's segments land in increasing
        // global order, the exact `unscale_probe_pooled` fold
        let mut g2 = vec![0.0f64; nblocks];
        for bucket in &parts {
            for (bi, ps) in bucket.lock().unwrap().iter() {
                for p in ps {
                    g2[*bi] += p;
                }
            }
        }
        if !g2.iter().all(|x| x.is_finite()) {
            return (None, wire);
        }
        let grad = std::mem::take(&mut bufs[0]);
        let stats = opt.step_prefolded(exec.pool(), params, &grad, lr, g2);
        (Some(stats), wire)
    } else {
        let grad = std::mem::take(&mut bufs[0]);
        let stats = exec.step(opt, params, &grad, lr);
        (Some(stats), wire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_run_preserves_insertion_order() {
        let log = Mutex::new(Vec::new());
        let mut dag = StepDag::new();
        let a = dag.stage("a", &[], || log.lock().unwrap().push(0));
        let b = dag.stage("b", &[a], || log.lock().unwrap().push(1));
        dag.stage("c", &[a, b], || log.lock().unwrap().push(2));
        dag.run(&ThreadPool::new(1), true);
        assert_eq!(log.into_inner().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn overlapped_run_respects_every_edge() {
        // a diamond fan per "bucket": comm lane chained, compute depends
        // on its comm and the previous compute — the trainer's shape
        let pool = ThreadPool::new(4);
        for _ in 0..50 {
            let n = 6;
            let done: Vec<AtomicUsize> = (0..2 * n).map(|_| AtomicUsize::new(0)).collect();
            let order = Mutex::new(Vec::new());
            {
                let (done, order) = (&done, &order);
                let mut dag = StepDag::new();
                let mut prev_comm: Vec<usize> = Vec::new();
                let mut prev_compute: Option<usize> = None;
                for k in 0..n {
                    let comm = dag.stage("comm", &prev_comm, move || {
                        done[k].store(1, Ordering::SeqCst);
                        order.lock().unwrap().push(k);
                    });
                    let deps: Vec<usize> = prev_compute.into_iter().chain([comm]).collect();
                    let compute = dag.stage("compute", &deps, move || {
                        // our comm and the previous compute must be done
                        assert_eq!(done[k].load(Ordering::SeqCst), 1);
                        if k > 0 {
                            assert_eq!(done[n + k - 1].load(Ordering::SeqCst), 1);
                        }
                        done[n + k].store(1, Ordering::SeqCst);
                        order.lock().unwrap().push(n + k);
                    });
                    prev_comm = vec![comm];
                    prev_compute = Some(compute);
                }
                dag.run(&pool, true);
            }
            let ran = order.into_inner().unwrap();
            assert_eq!(ran.len(), 2 * n, "every stage ran exactly once");
        }
    }

    #[test]
    fn overlap_off_is_the_serial_schedule() {
        let log = Mutex::new(Vec::new());
        let mut dag = StepDag::new();
        for i in 0..5 {
            let deps: Vec<usize> = if i == 0 { vec![] } else { vec![i - 1] };
            let log = &log;
            dag.stage("s", &deps, move || log.lock().unwrap().push(i));
        }
        dag.run(&ThreadPool::new(8), false);
        assert_eq!(log.into_inner().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn panicking_stage_reaches_the_caller_and_blocks_dependents() {
        let pool = ThreadPool::new(4);
        let ran_dependent = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let ran = &ran_dependent;
            let mut dag = StepDag::new();
            let a = dag.stage("boom", &[], || panic!("stage-boom"));
            dag.stage("after", &[a], move || {
                ran.store(1, Ordering::SeqCst);
            });
            dag.run(&pool, true);
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_owned)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("stage-boom"), "payload lost: {msg:?}");
        assert_eq!(ran_dependent.load(Ordering::SeqCst), 0, "dependent must not run");
        // the pool must still be serviceable after the poisoned region
        let mut items: Vec<usize> = (0..8).collect();
        let out = pool.map_mut(&mut items, |x| *x + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }

    #[test]
    fn empty_dag_is_a_noop() {
        StepDag::new().run(&ThreadPool::new(4), true);
    }

    #[test]
    fn traced_run_emits_one_sched_span_per_stage() {
        // label-prefixed and tolerant of concurrent tests' spans: the trace
        // switch is process-global, so other lanes may be live while we are
        let _guard = trace::test_lock();
        trace::enable();
        let pool = ThreadPool::new(4);
        let mut dag = StepDag::new();
        let a = dag.stage("dagtr_a", &[], || {});
        let b = dag.stage("dagtr_b", &[a], || {});
        dag.stage("dagtr_c", &[a, b], || {});
        dag.run(&pool, true);
        trace::disable();
        let st = trace::collect(0);
        let mine: Vec<&trace::TraceSpan> = st
            .lanes
            .iter()
            .flat_map(|l| l.spans.iter())
            .filter(|s| s.label.starts_with("dagtr_"))
            .collect();
        let sched = mine.iter().filter(|s| s.cat == trace::CAT_SCHED).count();
        assert_eq!(sched, 3, "one sched span per stage");
        // released stages (b, c) must each carry a queue-wait span
        let waits = mine.iter().filter(|s| s.cat == trace::CAT_WAIT).count();
        assert!(waits >= 2, "released stages record queue-wait, got {waits}");
    }

    #[test]
    fn carve_buckets_covers_and_is_disjoint() {
        let mut bufs = vec![(0..10).map(|x| x as f32).collect::<Vec<f32>>(); 3];
        let cuts = [0usize, 4, 10];
        let slots = carve_buckets(&mut bufs, &cuts);
        assert_eq!(slots.len(), 2);
        {
            let mut b0 = slots[0].lock().unwrap().take().unwrap();
            let b1 = slots[1].lock().unwrap().take().unwrap();
            assert_eq!(b0.len(), 3);
            assert_eq!(b0[0], &[0.0, 1.0, 2.0, 3.0]);
            assert_eq!(b1[2], &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
            b0[1][0] = 99.0;
        }
        drop(slots);
        assert_eq!(bufs[1][0], 99.0);
    }
}
