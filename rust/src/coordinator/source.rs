//! Training data source: corpus + masker + per-worker shards + a held-out
//! eval shard, built from a [`DataConfig`].
//!
//! The last `EVAL_FRACTION` of sequences never enter any training shard —
//! that slice is the "dev set" the trainer's eval loop scores (the stand-in
//! for the paper's SQuAD check of pretraining quality, DESIGN.md §5).

use anyhow::{bail, Result};

use crate::config::DataConfig;
use crate::data::{
    make_shards, text_corpus, Masker, MlmBatch, SequenceSet, Shard, SyntheticCorpus, Vocab,
};
use crate::util::rng::Rng;

const EVAL_FRACTION: f64 = 0.05;

pub struct DataSource {
    pub seqs: SequenceSet,
    pub masker: Masker,
    pub vocab_size: usize,
    /// number of leading sequences available for training shards
    train_len: usize,
    eval_indices: Vec<usize>,
}

impl DataSource {
    pub fn build(cfg: &DataConfig, seq_len: usize, slots: usize) -> Result<DataSource> {
        let (vocab, tokens) = match cfg.source.as_str() {
            "synthetic" => {
                // The *language* (Markov transition table) is derived from
                // the seed's high bits, the document stream from the full
                // seed: seeds 0x700 and 0x701 generate different documents
                // of the SAME language.  This is what lets the finetune
                // example model a downstream task on the pretraining
                // distribution (fresh text, same statistics).
                let c = SyntheticCorpus::new(cfg.vocab, cfg.seed >> 8);
                let toks = c.generate(cfg.corpus_tokens, cfg.seed ^ 0xDA7A);
                (c.vocab, toks)
            }
            "text" => {
                let (v, t) = text_corpus(cfg.vocab, cfg.corpus_tokens);
                (v, t)
            }
            other => bail!("unknown data source {other:?} (synthetic|text)"),
        };
        Self::from_parts(vocab, tokens, seq_len, slots)
    }

    pub fn from_parts(
        vocab: Vocab,
        tokens: Vec<i32>,
        seq_len: usize,
        slots: usize,
    ) -> Result<DataSource> {
        let masker = Masker::new(slots, &vocab);
        let seqs = SequenceSet::new(tokens, seq_len);
        let n = seqs.len();
        let eval_n = ((n as f64 * EVAL_FRACTION) as usize).max(1).min(n / 2);
        let train_len = n - eval_n;
        if train_len == 0 {
            bail!("corpus too small: {n} sequences");
        }
        Ok(DataSource {
            seqs,
            masker,
            vocab_size: vocab.size,
            train_len,
            eval_indices: (train_len..n).collect(),
        })
    }

    /// Disjoint without-replacement shards over the training slice
    /// (paper §3.4).
    pub fn make_worker_shards(&self, workers: usize, seed: u64) -> Vec<Shard> {
        make_shards(self.train_len, workers, seed)
    }

    pub fn train_sequences(&self) -> usize {
        self.train_len
    }

    pub fn eval_sequences(&self) -> usize {
        self.eval_indices.len()
    }

    /// A deterministic eval batch (same masking per (seed, batch_idx) so the
    /// eval metric is comparable across steps and runs).
    pub fn eval_batch(&self, batch: usize, batch_idx: usize, seed: u64) -> MlmBatch {
        let mut rng = Rng::new(seed ^ 0xE7A1).fork(batch_idx as u64);
        let idx: Vec<usize> = (0..batch)
            .map(|i| self.eval_indices[(batch_idx * batch + i) % self.eval_indices.len()])
            .collect();
        self.masker.make_batch(&self.seqs, &idx, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig { source: "synthetic".into(), vocab: 256, corpus_tokens: 64 * 200, seed: 1 }
    }

    #[test]
    fn builds_and_splits() {
        let ds = DataSource::build(&cfg(), 64, 10).unwrap();
        assert!(ds.train_sequences() > 0);
        assert!(ds.eval_sequences() > 0);
        assert_eq!(ds.train_sequences() + ds.eval_sequences(), ds.seqs.len());
    }

    #[test]
    fn eval_never_overlaps_train_shards() {
        let ds = DataSource::build(&cfg(), 64, 10).unwrap();
        let mut shards = ds.make_worker_shards(3, 2);
        let eval_min = ds.train_sequences();
        for s in shards.iter_mut() {
            for _ in 0..5 {
                for i in s.next_batch(4) {
                    assert!(i < eval_min, "train shard leaked eval index {i}");
                }
            }
        }
    }

    #[test]
    fn eval_batch_is_deterministic() {
        let ds = DataSource::build(&cfg(), 64, 10).unwrap();
        let a = ds.eval_batch(4, 0, 9);
        let b = ds.eval_batch(4, 0, 9);
        assert_eq!(a, b);
        let c = ds.eval_batch(4, 1, 9);
        assert_ne!(a, c);
    }

    #[test]
    fn text_source_works() {
        let c = DataConfig { source: "text".into(), vocab: 512, corpus_tokens: 20_000, seed: 1 };
        let ds = DataSource::build(&c, 32, 5).unwrap();
        assert!(ds.train_sequences() > 10);
    }

    #[test]
    fn unknown_source_errors() {
        let c = DataConfig { source: "s3".into(), vocab: 256, corpus_tokens: 1000, seed: 1 };
        assert!(DataSource::build(&c, 32, 5).is_err());
    }
}
