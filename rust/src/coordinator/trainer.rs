//! The synchronous data-parallel trainer — the leader side of the paper's
//! training system.
//!
//! Per step: snapshot params → workers run their microbatches on disjoint
//! shards (§3.4) → ring-allreduce the per-worker gradient sums → mean →
//! LANS/LAMB/AdamW update (native rust or the AOT Pallas artifact) at the
//! scheduled learning rate (eq. 8/eq. 9) → metrics, divergence detection,
//! periodic eval, checkpointing.
//!
//! The *effective* mini-batch is `workers × micro_steps × micro_batch`
//! sequences — gradient accumulation is how the paper reaches 96K on fixed
//! per-GPU memory, and how we reach "large batch" at laptop scale.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::collective::{
    hierarchical_allreduce_pooled, hierarchical_reduce_scatter_pooled, leader_allreduce,
};
use crate::config::{OptBackend, TrainConfig};
use crate::metrics::export::{self, RunReport};
use crate::metrics::health::{HealthConfig, HealthMonitor, Severity};
use crate::metrics::{log as mlog, registry, Recorder};
use crate::obs::{flight, postmortem};
use crate::optim::{
    make_optimizer, BlockTable, Optimizer, ParallelExecutor, ShardPlan, ShardedOptimizer,
};
use crate::precision::scaler::LOSS_SCALE_TENSOR;
use crate::precision::DynamicLossScaler;
use crate::runtime::{Engine, ModelRuntime, TensorF32};
use crate::topology::{TierPrecision, WireBytes};
use crate::trace;

use super::dag::{replicated_bucketed_step, sharded_bucketed_step};
use super::source::DataSource;
use super::worker::{WorkerCmd, WorkerHandle, WorkerReply};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrainStatus {
    Completed,
    Diverged { at_step: u64 },
}

pub struct TrainReport {
    pub status: TrainStatus,
    pub recorder: Recorder,
    pub final_eval_loss: Option<f64>,
    pub steps_run: u64,
    /// final parameters (canonical order) for checkpoint-free callers
    pub params: Vec<TensorF32>,
    /// executed gradient-wire bytes over the whole run, split by topology
    /// tier (the sharded path pays the reduce-scatter; the replicated path
    /// the full allreduce) — `examples/multi_node.rs` and the e2e tests
    /// assert this equals the analytic `collective::cost` terms × steps
    pub wire: WireBytes,
    /// run-health report (DESIGN.md §12) — `Some` whenever any `[metrics]`
    /// knob was active for the run, `None` otherwise
    pub metrics: Option<RunReport>,
}

pub struct Trainer {
    cfg: TrainConfig,
    runtime: ModelRuntime,
    source: Arc<DataSource>,
    table: Arc<BlockTable>,
    micro_steps_per_worker: usize,
}

impl Trainer {
    /// Build the full topology: engine, runtime, data source.  Fails fast on
    /// inconsistent geometry (batch divisibility, vocab overflow).
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        let engine = Engine::cpu().context("starting PJRT engine")?;
        Self::with_engine(cfg, engine)
    }

    /// Reuse an existing engine (benches share one across trainers).
    pub fn with_engine(cfg: TrainConfig, engine: Engine) -> Result<Trainer> {
        let runtime = ModelRuntime::load(engine, &cfg.meta_path)
            .with_context(|| format!("loading {}", cfg.meta_path.display()))?;
        let meta = runtime.meta.clone();

        let denom = cfg.workers * meta.batch;
        if cfg.global_batch % denom != 0 {
            bail!(
                "global_batch {} not divisible by workers*micro_batch = {}×{}",
                cfg.global_batch, cfg.workers, meta.batch
            );
        }
        let micro_steps = cfg.global_batch / denom;

        let source =
            Arc::new(DataSource::build(&cfg.data, meta.seq, meta.mlm_slots)?);
        if source.vocab_size > meta.vocab_size {
            bail!(
                "data vocab {} exceeds model vocab {}",
                source.vocab_size, meta.vocab_size
            );
        }
        if source.train_sequences() < cfg.workers {
            bail!("corpus too small for {} workers", cfg.workers);
        }

        if cfg.backend == OptBackend::Hlo {
            runtime.load_optimizer(&cfg.optimizer).with_context(|| {
                format!("loading opt_{} artifact", cfg.optimizer)
            })?;
        }

        if cfg.shard_optimizer {
            if cfg.backend != OptBackend::Native {
                bail!("shard_optimizer requires the native backend");
            }
            if !matches!(cfg.optimizer.as_str(), "lans" | "lamb") {
                bail!(
                    "optimizer {:?} has no sharded update \
                     (shard_optimizer supports lans|lamb)",
                    cfg.optimizer
                );
            }
        }
        if cfg.resume_opt_state && (!cfg.shard_optimizer || cfg.resume_from.is_none()) {
            bail!(
                "resume_opt_state requires shard_optimizer = true and a \
                 resume_from checkpoint"
            );
        }
        if let Some(f) = &cfg.inject_failure {
            if f.worker >= cfg.workers {
                bail!(
                    "inject_failure names worker {} but the run has only {} \
                     workers (0..{})",
                    f.worker,
                    cfg.workers,
                    cfg.workers - 1
                );
            }
        }
        if (cfg.grad_dtype.is_half() || cfg.intra_dtype.is_half() || cfg.loss_scale.enabled())
            && cfg.backend != OptBackend::Native
        {
            bail!(
                "grad_dtype = {} / intra_dtype = {} / loss_scale require the \
                 native backend (the HLO optimizer artifacts have no \
                 half-wire or skip-step form)",
                cfg.grad_dtype.name(),
                cfg.intra_dtype.name()
            );
        }
        if cfg.topology.world() != cfg.workers {
            bail!(
                "topology {} describes {} ranks but workers = {}",
                cfg.topology,
                cfg.topology.world(),
                cfg.workers
            );
        }
        let tier_prec = TierPrecision { intra: cfg.intra_dtype, inter: cfg.grad_dtype };
        if let Err(e) = tier_prec.validate() {
            bail!("bad intra_dtype/grad_dtype combination: {e}");
        }
        if cfg.bucket_mb > 0 && cfg.backend != OptBackend::Native {
            bail!(
                "bucket_mb requires the native backend (the HLO optimizer \
                 artifacts have no bucketed step form)"
            );
        }
        if cfg.relaxed_collectives {
            if cfg.shard_optimizer {
                bail!(
                    "relaxed_collectives applies to the replicated path only \
                     (the sharded step consumes the ring reduce-scatter layout)"
                );
            }
            if cfg.bucket_mb > 0 {
                bail!("relaxed_collectives and bucket_mb are mutually exclusive");
            }
            if tier_prec.any_half() {
                bail!(
                    "relaxed_collectives is fp32-only (leader_allreduce has no \
                     half-wire form); clear grad_dtype/intra_dtype"
                );
            }
        }

        let table = Arc::new(BlockTable::from_meta(&runtime.meta));
        Ok(Trainer { cfg, runtime, source, table, micro_steps_per_worker: micro_steps })
    }

    pub fn meta(&self) -> &crate::runtime::ModelMeta {
        &self.runtime.meta
    }

    pub fn effective_batch(&self) -> usize {
        self.cfg.workers * self.micro_steps_per_worker * self.runtime.meta.batch
    }

    /// Run the configured number of steps (or stop early on divergence).
    pub fn run(&mut self) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let meta = self.runtime.meta.clone();
        let tokens_per_step = (self.effective_batch() * meta.seq) as u64;

        // workers with disjoint shards (paper §3.4)
        let shards = self.source.make_worker_shards(cfg.workers, cfg.seed);
        let workers: Vec<WorkerHandle> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                WorkerHandle::spawn(
                    i,
                    self.runtime.clone(),
                    self.source.clone(),
                    shard,
                    self.table.clone(),
                    cfg.seed,
                )
            })
            .collect::<Result<_>>()?;

        // leader state: fresh init, or warm-start from a checkpoint
        // (moments restart unless resume_opt_state re-imports them below).
        // The non-param tensors (per-shard optimizer moments) are kept
        // aside from the same single load instead of re-reading the file.
        let mut resume_state: Option<(u64, Vec<(String, TensorF32)>)> = None;
        // loss-scaler state embedded in the checkpoint (v2 aux tensor);
        // restored below iff this run has loss scaling enabled
        let mut resume_loss_scale: Option<TensorF32> = None;
        let mut params = match &cfg.resume_from {
            None => self.runtime.init_params(cfg.seed),
            Some(path) => {
                let ckpt = Checkpoint::load(path)?;
                let step = ckpt.step;
                let mut by_name: std::collections::HashMap<String, TensorF32> =
                    ckpt.tensors.into_iter().collect();
                resume_loss_scale = by_name.remove(LOSS_SCALE_TENSOR);
                let params = meta
                    .params
                    .iter()
                    .map(|spec| {
                        let mut t = by_name.remove(&spec.name).ok_or_else(|| {
                            anyhow::anyhow!(
                                "checkpoint missing tensor {:?}", spec.name
                            )
                        })?;
                        if t.data.len() != spec.size {
                            bail!(
                                "checkpoint tensor {} has {} elements, model \
                                 wants {}",
                                spec.name, t.data.len(), spec.size
                            );
                        }
                        // phase-2 reshape: position embeddings etc. keep
                        // identical sizes in our presets, so shapes must match
                        t.shape = spec.shape.clone();
                        Ok(t)
                    })
                    .collect::<Result<Vec<_>>>()?;
                if cfg.resume_opt_state {
                    resume_state = Some((step, by_name.into_iter().collect()));
                }
                params
            }
        };
        let mut opt_state = self.runtime.zero_opt_state();
        // ZeRO-1 path: partitioned moments + reduce-scatter/all-gather step
        let mut sharded_opt: Option<ShardedOptimizer> = if cfg.shard_optimizer {
            Some(
                ShardedOptimizer::from_name(
                    &cfg.optimizer,
                    (*self.table).clone(),
                    cfg.hyper,
                    cfg.workers,
                )
                .expect("optimizer validated lans|lamb in Trainer::with_engine"),
            )
        } else {
            None
        };
        if cfg.resume_opt_state {
            // validated at construction: sharded + resume_from are present
            let so = sharded_opt.as_mut().expect("resume_opt_state implies shard_optimizer");
            let (step, tensors) =
                resume_state.as_ref().expect("resume_opt_state implies resume_from");
            so.import_state(*step, tensors).with_context(|| {
                format!(
                    "restoring sharded optimizer state from {}",
                    cfg.resume_from.as_ref().unwrap().display()
                )
            })?;
        }
        let mut native_opt: Option<Box<dyn Optimizer>> = match cfg.backend {
            OptBackend::Native if !cfg.shard_optimizer => Some(
                make_optimizer(&cfg.optimizer, (*self.table).clone(), cfg.hyper)
                    .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", cfg.optimizer))?,
            ),
            _ => None,
        };
        let mut flat_params = match cfg.backend {
            OptBackend::Native => self.table.flatten(&params),
            OptBackend::Hlo => Vec::new(),
        };

        // one persistent pool for the whole run: plan-parallel optimizer
        // updates and chunk-parallel collectives share its parked workers
        // across every step (cfg.threads = 0 → available parallelism,
        // 1 → the exact serial path, nothing spawned)
        let exec = ParallelExecutor::new(cfg.threads);

        // the declared topology tiers the ring's hops (intra-node links
        // carry `intra_dtype`, the scarce inter-node links `grad_dtype`);
        // executed wire bytes accumulate per tier onto the report.  Mixed
        // precision: `scaled` routes the optimizer through the probe/skip
        // path — any loss scale, or a half tier whose quantization can
        // mint inf on its own.  With scaling off and all-f32 tiers the
        // legacy exact-bit path below runs unchanged (the tiered ring
        // keeps the flat ring's reduction order for every topology).
        let topo = cfg.topology;
        let prec = TierPrecision { intra: cfg.intra_dtype, inter: cfg.grad_dtype };
        let mut wire_bytes = WireBytes::default();
        // bucketed pipeline: fixed cuts on the NORM_SEG grid, computed once
        // (validated native-backend-only at construction).  The same cuts
        // drive every step so the DAG shape is stable across the run.
        let bucket_cuts: Option<Vec<usize>> = (cfg.bucket_mb > 0).then(|| {
            let target = cfg.bucket_mb * (1 << 20) / std::mem::size_of::<f32>();
            ShardPlan::bucket_starts(&self.table, target)
        });
        let mut scaler: Option<DynamicLossScaler> = cfg.loss_scale.build();
        if let (Some(sc), Some(t)) = (scaler.as_mut(), resume_loss_scale.as_ref()) {
            sc.import_tensor(t).with_context(|| {
                format!(
                    "restoring loss-scaler state from {}",
                    cfg.resume_from.as_ref().unwrap().display()
                )
            })?;
        }
        let scaled = scaler.is_some() || prec.any_half();

        let mut recorder = Recorder::new(0.9);
        let mut status = TrainStatus::Completed;
        let mut steps_run = 0;

        // step tracing: flip the global switch for the whole run, collect
        // each step's spans into a StepTrace (feeding the per-step TSV
        // aggregates), and write the Chrome-trace timeline at the end
        if cfg.trace.is_some() {
            trace::enable();
        }
        let mut step_traces: Vec<trace::StepTrace> = Vec::new();

        // flight recorder (DESIGN.md §13): arm the last-K ring and register
        // the seal metadata up front, so a trigger raised from a panicking
        // pool thread can write the bundle without the trainer's help.
        // Arming implies span collection (the ring retains timelines); the
        // Chrome trace file is still written only when `[train] trace`
        // asks for it.  The guard disarms on every exit path — including a
        // worker-failure bail, whose bundle is already on disk by then.
        let flight_on = cfg.flight.active();
        if flight_on {
            flight::arm(flight::SealMeta {
                bundle: cfg.flight.bundle.clone(),
                config_echo: config_echo(cfg),
                cap: cfg.flight.steps,
            });
            trace::enable();
        }
        struct FlightDisarm {
            armed: bool,
            owns_trace: bool,
        }
        impl Drop for FlightDisarm {
            fn drop(&mut self) {
                if self.armed {
                    flight::disarm();
                }
                if self.owns_trace {
                    // span collection was on only for the ring: switch it
                    // back off on every exit path, including a bail
                    trace::disable();
                }
            }
        }
        let _flight_guard = FlightDisarm {
            armed: flight_on,
            owns_trace: flight_on && cfg.trace.is_none(),
        };

        // run-health telemetry (DESIGN.md §12): arm the registry for the
        // whole run when any `[metrics]` knob is active.  Disabled, every
        // seam is one relaxed atomic load; enabled, the registry only
        // observes values the hot path already computed, so the training
        // trajectory is bit-identical either way (property-tested).
        let metrics_on = cfg.metrics.active();
        if metrics_on {
            registry::reset();
            registry::enable();
        }
        mlog::set_level(cfg.metrics.log_level);
        mlog::reset_rate_limits();
        let mut health = metrics_on.then(|| {
            HealthMonitor::new(HealthConfig {
                window: cfg.metrics.window,
                ..Default::default()
            })
        });
        let mut prev_wall = 0.0f64;

        for t in 1..=cfg.steps {
            let step_span = trace::span_detail(trace::CAT_STEP, "step", t);
            let lr = cfg.schedule.lr(t);
            let scale_s = scaler.as_ref().map_or(1.0, |s| s.scale());
            let snapshot = Arc::new(params.clone());
            for w in &workers {
                w.send(WorkerCmd::Step {
                    params: snapshot.clone(),
                    micro_steps: self.micro_steps_per_worker,
                    loss_scale: scale_s,
                });
            }
            let wait_grads = trace::span(trace::CAT_WAIT, "worker_grads");
            let replies: Vec<WorkerReply> =
                workers.iter().map(|w| w.recv()).collect::<Result<_>>()?;
            drop(wait_grads);
            let mut loss_sum = 0.0;
            let mut total_micros = 0usize;
            let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(replies.len());
            for r in replies {
                // chaos injection (ROADMAP item 2's failure model): treat
                // the designated worker's reply as a mid-step death
                let error = r.error.or_else(|| {
                    cfg.inject_failure
                        .filter(|f| f.step == t && f.worker == r.worker)
                        .map(|f| {
                            format!(
                                "worker {}: injected failure (inject_failure = \
                                 \"{}@{}\")",
                                r.worker, f.step, f.worker
                            )
                        })
                });
                if let Some(e) = error {
                    if flight_on {
                        // seal the bundle before surfacing the error: the
                        // ring holds the preceding steps plus a partial
                        // frame with whatever spans this step produced
                        let partial = trace::enabled().then(|| trace::collect(t));
                        flight::push_frame(flight::FlightFrame::partial(t, partial));
                        if let Some(p) = flight::worker_failure(t, r.worker, &e) {
                            mlog::warn(
                                "flight",
                                &format!("postmortem bundle sealed to {}", p.display()),
                            );
                        }
                    }
                    bail!("step {t}: {e}");
                }
                loss_sum += r.loss_sum;
                total_micros += r.micros;
                bufs.push(r.grad_flat);
            }

            let inv = 1.0 / total_micros as f32;
            let loss = loss_sum / total_micros as f64;

            // combine worker gradients and update.  `None` = the loss-
            // scaled gradient overflowed (inf/nan after unscale): the step
            // is skipped with params/moments/step-clock untouched.
            let outcome: Option<(f64, f64)> = if let Some(so) = sharded_opt.as_mut() {
                // pipelined ZeRO-1 step: reduce-scatter on the ring's own
                // chunk grid (summation order identical to the allreduce),
                // then hand the scattered buffers straight to the
                // optimizer — each shard's stitch of its owned
                // mean-gradient range is fused with the grad² phase in
                // one pool region instead of barriering on a full-vector
                // scatter.  The parameter all-gather stays a no-op
                // in-process (every worker reads the same f32 master flat
                // vector; the time model prices the wire version).
                // step_scattered self-falls-back to the serial path for
                // width-1 pools / small per-shard work; results are
                // identical either way.  The tiered reduce-scatter
                // quantizes each hop at its tier's wire format (f32
                // accumulation, 2-byte inter-node chunks under a half
                // `grad_dtype`); the stitch's mean factor then also folds
                // the loss-scale unscale — exact for power-of-two scales.
                if let Some(cuts) = &bucket_cuts {
                    // bucketed pipeline: reduce-scatter bucket k on the wire
                    // while stitching bucket k-1 — bit-identical to the
                    // phase-synchronous branch below (DESIGN.md §9)
                    let scale = if scaled { inv * (1.0 / scale_s) } else { inv };
                    let (stats, wb) = sharded_bucketed_step(
                        so,
                        exec.pool(),
                        &mut flat_params,
                        &mut bufs,
                        cuts,
                        scale,
                        lr as f32,
                        scaled,
                        &topo,
                        prec,
                        cfg.overlap,
                    );
                    wire_bytes += wb;
                    stats.map(|stats| {
                        self.table.unflatten_into(&flat_params, &mut params);
                        (stats.grad_norm, stats.mean_trust_ratio)
                    })
                } else {
                    wire_bytes += hierarchical_reduce_scatter_pooled(
                        &mut bufs,
                        &topo,
                        prec,
                        exec.pool(),
                    );
                    if scaled {
                        let inv_eff = inv * (1.0 / scale_s);
                        so.step_scattered_scaled(
                            exec.pool(),
                            &mut flat_params,
                            &bufs,
                            inv_eff,
                            lr as f32,
                        )
                        .map(|stats| {
                            self.table.unflatten_into(&flat_params, &mut params);
                            (stats.grad_norm, stats.mean_trust_ratio)
                        })
                    } else {
                        let stats = so.step_scattered(
                            exec.pool(),
                            &mut flat_params,
                            &bufs,
                            inv,
                            lr as f32,
                        );
                        self.table.unflatten_into(&flat_params, &mut params);
                        Some((stats.grad_norm, stats.mean_trust_ratio))
                    }
                }
            } else if let Some(cuts) = &bucket_cuts {
                // replicated bucketed pipeline (native backend, validated):
                // per-bucket allreduce overlapped with the unscale/probe
                // sweep, then one prefolded optimizer step on bufs[0]
                let scale = if scaled { inv * (1.0 / scale_s) } else { inv };
                let opt = native_opt.as_mut().unwrap();
                let (stats, wb) = replicated_bucketed_step(
                    opt.as_mut(),
                    &exec,
                    &mut flat_params,
                    &mut bufs,
                    cuts,
                    scale,
                    lr as f32,
                    scaled,
                    &topo,
                    prec,
                    cfg.overlap,
                );
                wire_bytes += wb;
                stats.map(|stats| {
                    self.table.unflatten_into(&flat_params, &mut params);
                    (stats.grad_norm, stats.mean_trust_ratio)
                })
            } else {
                // replicated path: tiered ring allreduce (sum), then mean.
                // relaxed_collectives swaps in the leader-based hierarchical
                // allreduce — fewer inter-node hops (the shard-aware cost
                // model's schedule), different f32 summation order, hence
                // the explicit opt-in (fp32-only, validated)
                wire_bytes += if cfg.relaxed_collectives {
                    leader_allreduce(&mut bufs, &topo)
                } else {
                    hierarchical_allreduce_pooled(&mut bufs, &topo, prec, exec.pool())
                };
                let mut grad = std::mem::take(&mut bufs[0]);
                match cfg.backend {
                    OptBackend::Native if scaled => {
                        // unscale (mean × 1/loss-scale, fused into the
                        // grad² probe) + skip-on-overflow step
                        let inv_eff = inv * (1.0 / scale_s);
                        let opt = native_opt.as_mut().unwrap();
                        opt.step_scaled(
                            exec.pool(),
                            &mut flat_params,
                            &mut grad,
                            lr as f32,
                            inv_eff,
                        )
                        .map(|stats| {
                            self.table.unflatten_into(&flat_params, &mut params);
                            (stats.grad_norm, stats.mean_trust_ratio)
                        })
                    }
                    OptBackend::Native => {
                        for g in grad.iter_mut() {
                            *g *= inv;
                        }
                        let opt = native_opt.as_mut().unwrap();
                        let stats =
                            exec.step(opt.as_mut(), &mut flat_params, &grad, lr as f32);
                        self.table.unflatten_into(&flat_params, &mut params);
                        Some((stats.grad_norm, stats.mean_trust_ratio))
                    }
                    OptBackend::Hlo => {
                        for g in grad.iter_mut() {
                            *g *= inv;
                        }
                        let gn = grad
                            .iter()
                            .map(|&x| (x as f64) * (x as f64))
                            .sum::<f64>()
                            .sqrt();
                        let mut grads_t: Vec<TensorF32> = meta
                            .params
                            .iter()
                            .map(|p| TensorF32::zeros(p.shape.clone()))
                            .collect();
                        self.table.unflatten_into(&grad, &mut grads_t);
                        self.runtime.opt_step(
                            &cfg.optimizer,
                            &mut params,
                            &mut opt_state,
                            &grads_t,
                            lr as f32,
                        )?;
                        Some((gn, 1.0))
                    }
                }
            };

            // a skipped step with a scaler attached is a loss-scale backoff
            // event — health.rs counts these per window to flag thrash
            let backoff = outcome.is_none() && scaler.is_some();
            match outcome {
                Some((grad_norm, trust)) => {
                    if let Some(sc) = scaler.as_mut() {
                        sc.update(false);
                    }
                    if scaled {
                        recorder.push_scaled(
                            t,
                            lr,
                            loss,
                            grad_norm,
                            trust,
                            tokens_per_step,
                            scale_s as f64,
                        );
                    } else {
                        recorder.push(t, lr, loss, grad_norm, trust, tokens_per_step);
                    }
                }
                None => {
                    // overflow: the batch is spent, the update is not.  The
                    // diagnostic rides on the record (and the TSV `note`
                    // column) so skip forensics survive without stderr.
                    let note = match scaler.as_mut() {
                        Some(sc) => {
                            sc.update(true);
                            format!(
                                "gradient overflow at loss scale {scale_s} — \
                                 step skipped, scale -> {}",
                                sc.scale()
                            )
                        }
                        None => format!(
                            "gradient overflow on the {} wire — step skipped \
                             (no loss scaler configured; consider loss_scale \
                             = \"dynamic\")",
                            cfg.grad_dtype.name()
                        ),
                    };
                    recorder.push_skipped(t, lr, loss, tokens_per_step, scale_s as f64, &note);
                    mlog::warn("skip", &format!("step {t:>6}  {note}"));
                }
            }
            steps_run = t;
            drop(step_span);
            // this step's timeline feeds up to three consumers: the TSV
            // aggregates (always), the Chrome trace file (cfg.trace), and
            // the flight ring (flight_on) — cloned only when both want it
            let mut step_trace: Option<trace::StepTrace> = None;
            if trace::enabled() {
                let st = trace::collect(t);
                recorder.set_step_timing(st.comm_s(), st.compute_s(), st.overlap_efficiency());
                if cfg.trace.is_some() && flight_on {
                    step_traces.push(st.clone());
                    step_trace = Some(st);
                } else if cfg.trace.is_some() {
                    step_traces.push(st);
                } else {
                    step_trace = Some(st);
                }
            }

            // feed the anomaly detector AFTER the trace collect so the
            // record carries this step's comm/compute split.  wall_s is a
            // cumulative clock — health wants per-step durations, so diff.
            let verdicts_before = health.as_ref().map_or(0, |h| h.verdicts().len());
            if let Some(h) = health.as_mut() {
                if let Some(r) = recorder.records.last() {
                    let wall = (r.wall_s - prev_wall).max(0.0);
                    prev_wall = r.wall_s;
                    h.observe_step(
                        t,
                        wall,
                        r.comm_s,
                        r.compute_s,
                        r.loss_ema,
                        backoff,
                        recorder.divergence_ceiling,
                    );
                }
            }

            if flight_on {
                // upgrade fresh straggler verdicts from "a step was slow"
                // to the slowest (lane, stage) by interval math over this
                // step's spans, and pick the first fresh Warn as a trigger
                let culprit = step_trace.as_ref().and_then(postmortem::slowest_stage);
                let mut warn_trigger: Option<flight::Trigger> = None;
                if let Some(h) = health.as_mut() {
                    for i in verdicts_before..h.verdicts().len() {
                        if h.verdicts()[i].kind.starts_with("straggler") {
                            if let Some(c) = culprit.as_ref() {
                                h.set_detail(
                                    i,
                                    format!(
                                        "{} — slowest stage '{}' ({:.3e}s)",
                                        c.lane, c.stage, c.dur_s
                                    ),
                                );
                            }
                        }
                        let v = &h.verdicts()[i];
                        if v.severity == Severity::Warn && warn_trigger.is_none() {
                            warn_trigger = Some(flight::Trigger {
                                kind: "health_verdict",
                                step: t,
                                message: v.message.clone(),
                                culprit: culprit.clone(),
                            });
                        }
                    }
                }
                // retain the frame BEFORE evaluating triggers, so a sealed
                // bundle includes the offending step itself
                let skipped_now =
                    recorder.records.last().is_some_and(|r| r.skipped);
                flight::push_frame(flight::FlightFrame {
                    step: t,
                    record: recorder.records.last().cloned(),
                    trace: step_trace,
                    verdicts: health
                        .as_ref()
                        .map_or(Vec::new(), |h| h.verdicts()[verdicts_before..].to_vec()),
                    counter_deltas: Vec::new(),
                    loss_scale: scale_s as f64,
                    scaler_overflows: scaler.as_ref().map_or(0, |s| s.overflows()),
                    applied_steps: t - recorder.skipped_steps(),
                });
                let sealed = if let Some(trig) = warn_trigger {
                    flight::trigger(trig)
                } else if skipped_now {
                    flight::check_skip_burst(t)
                } else {
                    None
                };
                if let Some(p) = sealed {
                    mlog::warn(
                        "flight",
                        &format!("postmortem bundle sealed to {}", p.display()),
                    );
                }
            }

            if cfg.stop_on_divergence && recorder.diverged() {
                status = TrainStatus::Diverged { at_step: t };
                break;
            }

            if cfg.eval_every > 0 && t % cfg.eval_every == 0 {
                let ev = self.eval(&params)?;
                mlog::info(
                    "eval",
                    &format!("step {t:>6}  lr {lr:.3e}  loss {loss:.4}  eval {ev:.4}"),
                );
            }
        }

        let final_eval_loss = if matches!(status, TrainStatus::Completed) {
            Some(self.eval(&params)?)
        } else {
            None
        };

        if let Some(path) = &cfg.checkpoint {
            let mut tensors: Vec<(String, TensorF32)> = meta
                .params
                .iter()
                .zip(&params)
                .map(|(s, t)| (s.name.clone(), t.clone()))
                .collect();
            // the sharded path also persists its partitioned moments so a
            // later run can continue exactly (resume_opt_state), under any
            // worker count — resharding happens on import
            if let Some(so) = &sharded_opt {
                tensors.extend(so.export_state());
            }
            // the loss-scaler state rides along too (v2 aux tensor), so a
            // resumed mixed-precision run keeps its calibrated scale
            // instead of re-walking the backoff ladder
            if let Some(sc) = &scaler {
                tensors.push(sc.export_tensor());
            }
            Checkpoint::new(steps_run, tensors).save(path)?;
        }
        if let Some(path) = &cfg.trace {
            trace::disable();
            trace::write_chrome_trace(path, &step_traces)
                .with_context(|| format!("writing Chrome trace to {}", path.display()))?;
        }
        if let Some(path) = &cfg.curve_out {
            recorder.write_tsv(path)?;
        }

        // end-of-run accounting for the rate-limited log sink: one summary
        // line per label that overran its limit, before the sink goes quiet
        mlog::drain_suppression_summary();

        // seal the telemetry run: snapshot before disabling so late worker
        // teardown can't race new observations into the report
        let metrics_report: Option<RunReport> = if metrics_on {
            let snap = registry::snapshot();
            registry::disable();
            let h = health.take().expect("armed with metrics_on");
            let rep = export::build_report(&recorder, snap, &h, cfg.metrics.model_step_time_s);
            if let Some(path) = &cfg.metrics.jsonl {
                export::write_jsonl(path, &recorder).with_context(|| {
                    format!("writing per-step metrics JSONL to {}", path.display())
                })?;
            }
            if let Some(path) = &cfg.metrics.report {
                export::write_report(path, &rep).with_context(|| {
                    format!("writing run-health report to {}", path.display())
                })?;
            }
            Some(rep)
        } else {
            None
        };

        Ok(TrainReport {
            status,
            recorder,
            final_eval_loss,
            steps_run,
            params,
            wire: wire_bytes,
            metrics: metrics_report,
        })
    }

    /// Mean eval loss over the held-out shard.
    pub fn eval(&self, params: &[TensorF32]) -> Result<f64> {
        let mut sum = 0.0;
        for i in 0..self.cfg.eval_batches {
            let batch =
                self.source
                    .eval_batch(self.runtime.meta.batch, i, self.cfg.seed);
            sum += self.runtime.eval_loss(params, &batch)? as f64;
        }
        Ok(sum / self.cfg.eval_batches as f64)
    }
}

/// The run-configuration echo landed in a postmortem bundle: enough to
/// reproduce the run's shape (and its RNG provenance, via the seeds)
/// without shipping the whole config file.
fn config_echo(cfg: &TrainConfig) -> Vec<(String, String)> {
    [
        ("optimizer", cfg.optimizer.clone()),
        ("backend", format!("{:?}", cfg.backend)),
        ("workers", cfg.workers.to_string()),
        ("threads", cfg.threads.to_string()),
        ("topology", format!("{:?}", cfg.topology)),
        ("grad_dtype", cfg.grad_dtype.name().to_string()),
        ("intra_dtype", cfg.intra_dtype.name().to_string()),
        ("loss_scale", format!("{:?}", cfg.loss_scale)),
        ("shard_optimizer", cfg.shard_optimizer.to_string()),
        ("bucket_mb", cfg.bucket_mb.to_string()),
        ("overlap", cfg.overlap.to_string()),
        ("global_batch", cfg.global_batch.to_string()),
        ("steps", cfg.steps.to_string()),
        ("seed", cfg.seed.to_string()),
        ("data_seed", cfg.data.seed.to_string()),
        ("flight_steps", cfg.flight.steps.to_string()),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect()
}
