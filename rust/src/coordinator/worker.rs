//! Data-parallel worker: owns a disjoint data shard, runs fwd/bwd on the
//! AOT artifact for its microbatches, accumulates a flat local gradient.
//!
//! Workers are OS threads (CPU-bound PJRT work; no async runtime needed).
//! Heavy compute serializes on the engine's device thread; batch building,
//! masking and gradient flattening run concurrently on the worker threads.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::data::Shard;
use crate::optim::BlockTable;
use crate::runtime::{ModelRuntime, TensorF32};
use crate::util::rng::Rng;

use super::source::DataSource;

pub enum WorkerCmd {
    /// Run `micro_steps` microbatches against the given parameter snapshot.
    /// `loss_scale` multiplies every gradient contribution during
    /// accumulation — modeling a loss-scaled backward pass (a real fp16
    /// run scales the loss so the backward emits scaled gradients; here
    /// the scaling fuses into the accumulation loop at zero extra cost).
    /// `1.0` is the exact historical path.
    Step {
        params: Arc<Vec<TensorF32>>,
        micro_steps: usize,
        loss_scale: f32,
    },
    Shutdown,
}

pub struct WorkerReply {
    pub worker: usize,
    /// sum over this worker's microbatch gradients, flat block layout
    pub grad_flat: Vec<f32>,
    pub loss_sum: f64,
    pub micros: usize,
    pub error: Option<String>,
}

pub struct WorkerHandle {
    pub id: usize,
    cmd_tx: Sender<WorkerCmd>,
    reply_rx: Receiver<WorkerReply>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    pub fn spawn(
        id: usize,
        runtime: ModelRuntime,
        source: Arc<DataSource>,
        shard: Shard,
        table: Arc<BlockTable>,
        seed: u64,
    ) -> Result<WorkerHandle> {
        let (cmd_tx, cmd_rx) = channel::<WorkerCmd>();
        let (reply_tx, reply_rx) = channel::<WorkerReply>();
        let join = std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || {
                worker_loop(id, runtime, source, shard, table, seed, cmd_rx, reply_tx)
            })?;
        Ok(WorkerHandle { id, cmd_tx, reply_rx, join: Some(join) })
    }

    pub fn send(&self, cmd: WorkerCmd) {
        let _ = self.cmd_tx.send(cmd);
    }

    pub fn recv(&self) -> Result<WorkerReply> {
        Ok(self.reply_rx.recv()?)
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.cmd_tx.send(WorkerCmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    runtime: ModelRuntime,
    source: Arc<DataSource>,
    mut shard: Shard,
    table: Arc<BlockTable>,
    seed: u64,
    cmd_rx: Receiver<WorkerCmd>,
    reply_tx: Sender<WorkerReply>,
) {
    let micro_batch = runtime.meta.batch;
    let mut rng = Rng::new(seed).fork(id as u64 + 101);
    let mut grad_flat = vec![0.0f32; table.total];

    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            WorkerCmd::Shutdown => break,
            WorkerCmd::Step { params, micro_steps, loss_scale } => {
                grad_flat.iter_mut().for_each(|x| *x = 0.0);
                let mut loss_sum = 0.0f64;
                let mut error = None;

                'micro: for _ in 0..micro_steps {
                    let idx = shard.next_batch(micro_batch);
                    let batch = source.masker.make_batch(&source.seqs, &idx, &mut rng);
                    match runtime.fwd_bwd(&params, &batch) {
                        Ok((loss, grads)) => {
                            // the *reported* loss stays unscaled — only the
                            // gradient carries the loss scale
                            loss_sum += loss as f64;
                            // accumulate into the flat layout, loss-scaled
                            // (×1.0 is bit-exact; a power-of-two scale
                            // commutes exactly with the f32 sums)
                            for (b, g) in table.blocks.iter().zip(&grads) {
                                let dst = &mut grad_flat[b.offset..b.offset + b.len];
                                for (d, s) in dst.iter_mut().zip(&g.data) {
                                    *d += s * loss_scale;
                                }
                            }
                        }
                        Err(e) => {
                            error = Some(format!("worker {id}: {e:#}"));
                            break 'micro;
                        }
                    }
                }

                let _ = reply_tx.send(WorkerReply {
                    worker: id,
                    grad_flat: grad_flat.clone(),
                    loss_sum,
                    micros: micro_steps,
                    error,
                });
            }
        }
    }
}
