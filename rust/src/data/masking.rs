//! BERT masked-LM batch construction: 15% dynamic masking with the 80/10/10
//! mask/random/keep split, padded to a fixed prediction-slot budget so every
//! batch matches the AOT artifact's static shapes.

use crate::util::rng::Rng;

use super::corpus::SequenceSet;
use super::vocab::{Vocab, FIRST_REGULAR, MASK};

/// One MLM training batch in artifact layout (row-major [batch, ...]).
#[derive(Debug, Clone, PartialEq)]
pub struct MlmBatch {
    /// (b*s) input ids after mask substitution
    pub tokens: Vec<i32>,
    /// (b*slots) positions of prediction slots within each sequence
    pub positions: Vec<i32>,
    /// (b*slots) original ids at those positions
    pub target_ids: Vec<i32>,
    /// (b*slots) 1.0 for live slots, 0.0 for padded slots
    pub weights: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub slots: usize,
}

/// Masking policy constants (Devlin et al.).
pub const MASK_FRACTION: f64 = 0.15;
pub const PROB_MASK_TOKEN: f64 = 0.8;
pub const PROB_RANDOM_TOKEN: f64 = 0.1; // remainder keeps the original

#[derive(Debug, Clone)]
pub struct Masker {
    pub slots: usize,
    vocab_size: usize,
}

impl Masker {
    pub fn new(slots: usize, vocab: &Vocab) -> Masker {
        Masker { slots, vocab_size: vocab.size }
    }

    /// Apply dynamic masking to one sequence; returns (masked tokens,
    /// positions, targets, weights), each padded/truncated to `slots`.
    pub fn mask_sequence(
        &self,
        seq: &[i32],
        rng: &mut Rng,
    ) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<f32>) {
        let s = seq.len();
        let budget = ((s as f64 * MASK_FRACTION).ceil() as usize)
            .min(self.slots)
            .max(1);

        // choose distinct positions among non-special tokens
        let candidates: Vec<usize> = (0..s)
            .filter(|&i| !Vocab::is_special(seq[i]))
            .collect();
        let k = budget.min(candidates.len());
        let mut picks = rng.sample_without_replacement(candidates.len(), k);
        picks.sort_unstable();

        let mut tokens = seq.to_vec();
        let mut positions = Vec::with_capacity(self.slots);
        let mut targets = Vec::with_capacity(self.slots);
        let mut weights = Vec::with_capacity(self.slots);

        for &pi in &picks {
            let pos = candidates[pi];
            let orig = seq[pos];
            let u = rng.next_f64();
            tokens[pos] = if u < PROB_MASK_TOKEN {
                MASK
            } else if u < PROB_MASK_TOKEN + PROB_RANDOM_TOKEN {
                FIRST_REGULAR
                    + rng.below_usize(self.vocab_size - FIRST_REGULAR as usize) as i32
            } else {
                orig
            };
            positions.push(pos as i32);
            targets.push(orig);
            weights.push(1.0);
        }
        while positions.len() < self.slots {
            positions.push(0);
            targets.push(0);
            weights.push(0.0);
        }
        (tokens, positions, targets, weights)
    }

    /// Build a full batch from sequence indices into a `SequenceSet`.
    pub fn make_batch(
        &self,
        seqs: &SequenceSet,
        indices: &[usize],
        rng: &mut Rng,
    ) -> MlmBatch {
        let b = indices.len();
        let s = seqs.seq_len;
        let mut tokens = Vec::with_capacity(b * s);
        let mut positions = Vec::with_capacity(b * self.slots);
        let mut target_ids = Vec::with_capacity(b * self.slots);
        let mut weights = Vec::with_capacity(b * self.slots);
        for &idx in indices {
            let (t, p, tg, w) = self.mask_sequence(seqs.get(idx), rng);
            tokens.extend(t);
            positions.extend(p);
            target_ids.extend(tg);
            weights.extend(w);
        }
        MlmBatch {
            tokens,
            positions,
            target_ids,
            weights,
            batch: b,
            seq: s,
            slots: self.slots,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;

    fn setup() -> (SyntheticCorpus, SequenceSet, Masker) {
        let c = SyntheticCorpus::new(256, 1);
        let toks = c.generate(64 * 32, 2);
        let seqs = SequenceSet::new(toks, 64);
        let masker = Masker::new(10, &c.vocab);
        (c, seqs, masker)
    }

    #[test]
    fn batch_geometry() {
        let (_c, seqs, masker) = setup();
        let mut rng = Rng::new(3);
        let b = masker.make_batch(&seqs, &[0, 1, 2, 3], &mut rng);
        assert_eq!(b.tokens.len(), 4 * 64);
        assert_eq!(b.positions.len(), 4 * 10);
        assert_eq!(b.weights.len(), 4 * 10);
    }

    #[test]
    fn mask_budget_respected() {
        let (_c, seqs, masker) = setup();
        let mut rng = Rng::new(4);
        let (_t, _p, _tg, w) = masker.mask_sequence(seqs.get(0), &mut rng);
        let live = w.iter().filter(|&&x| x > 0.0).count();
        // ceil(0.15*64) = 10 == slots
        assert_eq!(live, 10);
    }

    #[test]
    fn targets_are_originals() {
        let (_c, seqs, masker) = setup();
        let mut rng = Rng::new(5);
        let seq = seqs.get(0);
        let (_t, p, tg, w) = masker.mask_sequence(seq, &mut rng);
        for i in 0..p.len() {
            if w[i] > 0.0 {
                assert_eq!(tg[i], seq[p[i] as usize]);
            }
        }
    }

    #[test]
    fn masking_rate_split() {
        // over many sequences, ~80% of slots become [MASK]
        let (_c, seqs, masker) = setup();
        let mut rng = Rng::new(6);
        let (mut masked, mut total) = (0usize, 0usize);
        for i in 0..seqs.len() {
            let seq = seqs.get(i);
            let (t, p, _tg, w) = masker.mask_sequence(seq, &mut rng);
            for j in 0..p.len() {
                if w[j] > 0.0 {
                    total += 1;
                    if t[p[j] as usize] == MASK {
                        masked += 1;
                    }
                }
            }
        }
        let frac = masked as f64 / total as f64;
        assert!((frac - 0.8).abs() < 0.06, "mask fraction {frac}");
    }

    #[test]
    fn positions_distinct_within_sequence() {
        let (_c, seqs, masker) = setup();
        let mut rng = Rng::new(7);
        let (_t, p, _tg, w) = masker.mask_sequence(seqs.get(1), &mut rng);
        let live: Vec<i32> =
            p.iter().zip(&w).filter(|(_, &w)| w > 0.0).map(|(&p, _)| p).collect();
        let set: std::collections::HashSet<_> = live.iter().collect();
        assert_eq!(set.len(), live.len());
    }
}
