//! Data pipeline: corpus synthesis, vocab, MLM masking and distributed
//! sharding (paper §3.4).

pub mod corpus;
pub mod masking;
pub mod sharder;
pub mod vocab;

pub use corpus::{text_corpus, SequenceSet, SyntheticCorpus, Zipf};
pub use masking::{Masker, MlmBatch};
pub use sharder::{make_shards, Shard, WithReplacementSampler};
pub use vocab::Vocab;
