//! Corpus sources — the substitution for Wikipedia+BooksCorpus (DESIGN.md §5).
//!
//! Two generators:
//!
//! * [`SyntheticCorpus`] — a seeded first-order Markov chain over a Zipf
//!   unigram prior.  The chain gives MLM real *context* to learn (each token
//!   has a sparse set of likely successors), so loss curves show the same
//!   learnable-structure dynamics that drive the paper's convergence
//!   experiments, while staying fully deterministic and dependency-free.
//! * [`text_corpus`] — a small embedded public-domain text (Austen), for the
//!   quickstart and tests that want real word statistics.
//!
//! Both produce a flat token stream that [`SequenceSet`] windows into
//! fixed-length training sequences (BERT's packed-sequence pretraining
//! layout: documents concatenated, split every `seq_len`).

use crate::util::rng::Rng;

use super::vocab::{Vocab, FIRST_REGULAR};

/// First paragraphs of *Pride and Prejudice* (public domain) — enough real
/// text for word-statistics tests and the quickstart demo.
pub const EMBEDDED_TEXT: &str = "It is a truth universally acknowledged, that a single man in \
possession of a good fortune, must be in want of a wife. However little known the feelings or \
views of such a man may be on his first entering a neighbourhood, this truth is so well fixed \
in the minds of the surrounding families, that he is considered as the rightful property of \
some one or other of their daughters. My dear Mr. Bennet, said his lady to him one day, have \
you heard that Netherfield Park is let at last? Mr. Bennet replied that he had not. But it is, \
returned she; for Mrs. Long has just been here, and she told me all about it. Mr. Bennet made \
no answer. Do you not want to know who has taken it? cried his wife impatiently. You want to \
tell me, and I have no objection to hearing it. This was invitation enough. Why, my dear, you \
must know, Mrs. Long says that Netherfield is taken by a young man of large fortune from the \
north of England; that he came down on Monday in a chaise and four to see the place, and was \
so much delighted with it that he agreed with Mr. Morris immediately; that he is to take \
possession before Michaelmas, and some of his servants are to be in the house by the end of \
next week. What is his name? Bingley. Is he married or single? Oh! single, my dear, to be \
sure! A single man of large fortune; four or five thousand a year. What a fine thing for our \
girls! How so? how can it affect them? My dear Mr. Bennet, replied his wife, how can you be \
so tiresome! You must know that I am thinking of his marrying one of them. Is that his design \
in settling here? Design! nonsense, how can you talk so! But it is very likely that he may \
fall in love with one of them, and therefore you must visit him as soon as he comes. I see no \
occasion for that. You and the girls may go, or you may send them by themselves, which perhaps \
will be still better, for as you are as handsome as any of them, Mr. Bingley may like you the \
best of the party.";

/// Zipf sampler over `n` items with exponent `s` (inverse-CDF over
/// precomputed cumulative weights; O(log n) per sample).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Markov-over-Zipf synthetic corpus: each regular token has `fanout`
/// preferred successors that receive `locality` of the transition mass;
/// the rest falls back to the Zipf unigram prior.
pub struct SyntheticCorpus {
    pub vocab: Vocab,
    zipf: Zipf,
    successors: Vec<[i32; Self::FANOUT]>,
    locality: f64,
}

impl SyntheticCorpus {
    pub const FANOUT: usize = 8;

    pub fn new(vocab_size: usize, seed: u64) -> SyntheticCorpus {
        let vocab = Vocab::synthetic(vocab_size);
        let regular = vocab.regular_count();
        let zipf = Zipf::new(regular, 1.1);
        let mut rng = Rng::new(seed ^ 0x5EED_C09B_0515_D00D);
        let successors = (0..regular)
            .map(|_| {
                let mut succ = [0i32; Self::FANOUT];
                for s in succ.iter_mut() {
                    *s = FIRST_REGULAR + zipf.sample(&mut rng) as i32;
                }
                succ
            })
            .collect();
        SyntheticCorpus { vocab, zipf, successors, locality: 0.7 }
    }

    /// Generate a token stream of length `n` (regular ids only).
    pub fn generate(&self, n: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(n);
        let mut prev = FIRST_REGULAR + self.zipf.sample(&mut rng) as i32;
        for _ in 0..n {
            let next = if rng.next_f64() < self.locality {
                let succ = &self.successors[(prev - FIRST_REGULAR) as usize];
                succ[rng.below_usize(Self::FANOUT)]
            } else {
                FIRST_REGULAR + self.zipf.sample(&mut rng) as i32
            };
            out.push(next);
            prev = next;
        }
        out
    }
}

/// Tokenized embedded text, repeated until at least `min_tokens` long.
pub fn text_corpus(vocab_cap: usize, min_tokens: usize) -> (Vocab, Vec<i32>) {
    let vocab = Vocab::from_text(EMBEDDED_TEXT, vocab_cap);
    let base: Vec<i32> = super::vocab::tokenize(EMBEDDED_TEXT)
        .iter()
        .map(|w| vocab.encode(w))
        .collect();
    let mut tokens = Vec::with_capacity(min_tokens + base.len());
    while tokens.len() < min_tokens {
        tokens.extend_from_slice(&base);
    }
    (vocab, tokens)
}

/// Fixed-length sequence windows over a token stream.
#[derive(Debug, Clone)]
pub struct SequenceSet {
    pub seq_len: usize,
    tokens: Vec<i32>,
}

impl SequenceSet {
    pub fn new(tokens: Vec<i32>, seq_len: usize) -> SequenceSet {
        assert!(tokens.len() >= seq_len, "corpus shorter than one sequence");
        SequenceSet { seq_len, tokens }
    }

    /// Number of non-overlapping sequences.
    pub fn len(&self) -> usize {
        self.tokens.len() / self.seq_len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, idx: usize) -> &[i32] {
        let s = idx * self.seq_len;
        &self.tokens[s..s + self.seq_len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(1);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 should carry far more than 1% of mass
        assert!(head as f64 / n as f64 > 0.2, "head mass {head}");
    }

    #[test]
    fn synthetic_deterministic() {
        let c = SyntheticCorpus::new(512, 7);
        assert_eq!(c.generate(100, 3), c.generate(100, 3));
        assert_ne!(c.generate(100, 3), c.generate(100, 4));
    }

    #[test]
    fn synthetic_has_markov_structure() {
        // successor-following transitions should dominate: measure how often
        // a transition lands in the preferred-successor set
        let c = SyntheticCorpus::new(256, 7);
        let toks = c.generate(20_000, 11);
        let mut hits = 0;
        for w in toks.windows(2) {
            let succ = &c.successors[(w[0] - FIRST_REGULAR) as usize];
            if succ.contains(&w[1]) {
                hits += 1;
            }
        }
        let frac = hits as f64 / (toks.len() - 1) as f64;
        assert!(frac > 0.5, "markov locality too weak: {frac}");
    }

    #[test]
    fn sequences_window() {
        let s = SequenceSet::new((0..100).collect(), 16);
        assert_eq!(s.len(), 6);
        assert_eq!(s.get(1)[0], 16);
    }

    #[test]
    fn text_corpus_builds() {
        let (vocab, toks) = text_corpus(512, 5000);
        assert!(toks.len() >= 5000);
        assert!(vocab.size > 100);
        assert!(toks.iter().all(|&t| (t as usize) < vocab.size));
    }
}
