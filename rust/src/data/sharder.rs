//! Data sharding (paper §3.4).
//!
//! "To make sure that the mini-batch does not have redundant samples, we
//! only grant each worker access to a shard of the dataset.  Within each
//! shard, random shuffling is used to construct the mini-batch samples."
//!
//! [`Sharder`] implements exactly that: a disjoint contiguous shard per
//! worker, reshuffled per epoch — global sampling *without replacement*
//! within an epoch.  [`WithReplacementSampler`] is the baseline scheme the
//! paper's variance argument compares against (O(σ²/k) vs
//! O((n−k)/(k(n−1)) σ²)); the `variance` module measures both.

use crate::util::rng::Rng;

/// Per-worker shard: owns its index range, shuffles per epoch, yields
/// without-replacement batches.
#[derive(Debug, Clone)]
pub struct Shard {
    pub worker: usize,
    indices: Vec<usize>,
    cursor: usize,
    epoch: u64,
    rng: Rng,
}

impl Shard {
    fn new(worker: usize, mut indices: Vec<usize>, seed_rng: &Rng) -> Shard {
        let mut rng = seed_rng.fork(worker as u64 + 1);
        rng.shuffle(&mut indices);
        Shard { worker, indices, cursor: 0, epoch: 0, rng }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next `n` sample indices; reshuffles and bumps the epoch at the shard
    /// boundary (batches never mix epochs for exact without-replacement
    /// semantics within an epoch).
    pub fn next_batch(&mut self, n: usize) -> Vec<usize> {
        assert!(n <= self.indices.len(), "batch larger than shard");
        if self.cursor + n > self.indices.len() {
            self.rng.shuffle(&mut self.indices);
            self.cursor = 0;
            self.epoch += 1;
        }
        let out = self.indices[self.cursor..self.cursor + n].to_vec();
        self.cursor += n;
        out
    }
}

/// Split `num_samples` across `workers` disjoint contiguous shards
/// (the paper partitions the preprocessed dataset into 1536 shards the same
/// way).  Remainder samples go to the leading shards.
pub fn make_shards(num_samples: usize, workers: usize, seed: u64) -> Vec<Shard> {
    assert!(workers > 0);
    assert!(
        num_samples >= workers,
        "fewer samples ({num_samples}) than workers ({workers})"
    );
    let root = Rng::new(seed);
    let base = num_samples / workers;
    let extra = num_samples % workers;
    let mut start = 0;
    (0..workers)
        .map(|w| {
            let len = base + usize::from(w < extra);
            let shard = Shard::new(w, (start..start + len).collect(), &root);
            start += len;
            shard
        })
        .collect()
}

/// Uniform i.i.d. sampling with replacement over the whole dataset — the
/// baseline scheme in the paper's variance comparison.
#[derive(Debug, Clone)]
pub struct WithReplacementSampler {
    n: usize,
    rng: Rng,
}

impl WithReplacementSampler {
    pub fn new(num_samples: usize, seed: u64) -> Self {
        WithReplacementSampler { n: num_samples, rng: Rng::new(seed) }
    }

    pub fn next_batch(&mut self, k: usize) -> Vec<usize> {
        self.rng.sample_with_replacement(self.n, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let shards = make_shards(103, 4, 1);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // size balance within 1
        let sizes: Vec<usize> = shards.iter().map(Shard::len).collect();
        assert_eq!(sizes, vec![26, 26, 26, 25]);
    }

    #[test]
    fn epoch_has_no_duplicates() {
        let mut shards = make_shards(64, 2, 2);
        let s = &mut shards[0];
        let mut seen = HashSet::new();
        // one full epoch of batches
        for _ in 0..(s.len() / 8) {
            for i in s.next_batch(8) {
                assert!(seen.insert(i), "duplicate {i} within epoch");
            }
        }
        assert_eq!(seen.len(), s.len());
        assert_eq!(s.epoch(), 0);
        // next batch starts epoch 1
        s.next_batch(8);
        assert_eq!(s.epoch(), 1);
    }

    #[test]
    fn workers_never_share_samples() {
        let mut shards = make_shards(100, 4, 3);
        let mut per_worker: Vec<HashSet<usize>> = vec![HashSet::new(); 4];
        for (w, s) in shards.iter_mut().enumerate() {
            for _ in 0..3 {
                per_worker[w].extend(s.next_batch(5));
            }
        }
        for a in 0..4 {
            for b in (a + 1)..4 {
                assert!(per_worker[a].is_disjoint(&per_worker[b]));
            }
        }
    }

    #[test]
    fn reshuffle_changes_order() {
        let mut shards = make_shards(32, 1, 4);
        let s = &mut shards[0];
        let e0: Vec<usize> = (0..4).flat_map(|_| s.next_batch(8)).collect();
        let e1: Vec<usize> = (0..4).flat_map(|_| s.next_batch(8)).collect();
        assert_ne!(e0, e1, "epoch order should differ");
        let (mut a, mut b) = (e0, e1);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "same underlying set");
    }

    #[test]
    fn with_replacement_repeats_eventually() {
        let mut s = WithReplacementSampler::new(8, 5);
        let batch = s.next_batch(64);
        let uniq: HashSet<_> = batch.iter().collect();
        assert!(uniq.len() < 64);
    }
}
