//! Vocabulary with BERT-style special tokens.
//!
//! The synthetic corpus uses ids directly; the embedded text corpus builds a
//! word-level vocab by frequency.  Ids 0..5 are reserved specials in both
//! cases so masking logic is uniform.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const CLS: i32 = 2;
pub const SEP: i32 = 3;
pub const MASK: i32 = 4;
/// First id available for regular tokens.
pub const FIRST_REGULAR: i32 = 5;

#[derive(Debug, Clone)]
pub struct Vocab {
    pub size: usize,
    token_to_id: HashMap<String, i32>,
    id_to_token: Vec<String>,
}

impl Vocab {
    /// A purely-synthetic vocab of `size` ids (no strings beyond specials).
    pub fn synthetic(size: usize) -> Vocab {
        assert!(size > FIRST_REGULAR as usize);
        Vocab { size, token_to_id: HashMap::new(), id_to_token: Vec::new() }
    }

    /// Build a word-level vocab from text, capped at `max_size` ids
    /// (most-frequent first; ties broken lexicographically for determinism).
    pub fn from_text(text: &str, max_size: usize) -> Vocab {
        let mut counts: HashMap<String, usize> = HashMap::new();
        for w in tokenize(text) {
            *counts.entry(w).or_default() += 1;
        }
        let mut items: Vec<(String, usize)> = counts.into_iter().collect();
        items.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        items.truncate(max_size.saturating_sub(FIRST_REGULAR as usize));

        let mut token_to_id = HashMap::new();
        let mut id_to_token = Vec::new();
        for (i, (w, _)) in items.iter().enumerate() {
            token_to_id.insert(w.clone(), FIRST_REGULAR + i as i32);
            id_to_token.push(w.clone());
        }
        let size = FIRST_REGULAR as usize + id_to_token.len();
        Vocab { size, token_to_id, id_to_token }
    }

    pub fn encode(&self, word: &str) -> i32 {
        *self.token_to_id.get(word).unwrap_or(&UNK)
    }

    pub fn decode(&self, id: i32) -> &str {
        match id {
            PAD => "[PAD]",
            UNK => "[UNK]",
            CLS => "[CLS]",
            SEP => "[SEP]",
            MASK => "[MASK]",
            _ => {
                let idx = (id - FIRST_REGULAR) as usize;
                self.id_to_token.get(idx).map(String::as_str).unwrap_or("[?]")
            }
        }
    }

    /// Number of regular (non-special) ids.
    pub fn regular_count(&self) -> usize {
        self.size - FIRST_REGULAR as usize
    }

    pub fn is_special(id: i32) -> bool {
        id < FIRST_REGULAR
    }
}

/// Lower-case word tokenizer: alphanumeric runs and single punctuation marks.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_alphanumeric() || c == '\'' {
            cur.extend(c.to_lowercase());
        } else {
            if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
            if !c.is_whitespace() {
                out.push(c.to_string());
            }
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_splits_punct() {
        assert_eq!(
            tokenize("It is a truth, universally!"),
            vec!["it", "is", "a", "truth", ",", "universally", "!"]
        );
    }

    #[test]
    fn vocab_roundtrip() {
        let v = Vocab::from_text("a b b c c c", 100);
        // c most frequent -> first regular id
        assert_eq!(v.encode("c"), FIRST_REGULAR);
        assert_eq!(v.decode(v.encode("b")), "b");
        assert_eq!(v.encode("zzz"), UNK);
        assert_eq!(v.size, FIRST_REGULAR as usize + 3);
    }

    #[test]
    fn vocab_cap_respected() {
        let v = Vocab::from_text("a b c d e f g h", FIRST_REGULAR as usize + 3);
        assert_eq!(v.size, FIRST_REGULAR as usize + 3);
    }

    #[test]
    fn specials() {
        assert!(Vocab::is_special(MASK));
        assert!(!Vocab::is_special(FIRST_REGULAR));
    }
}
