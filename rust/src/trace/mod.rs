//! Step-trace subsystem: a low-overhead span/counter tracing layer over
//! the training step.
//!
//! Every instrumented seam — DAG stage execution, pool regions, the
//! tiered collectives, the segmented optimizer phases — opens a [`Span`]
//! guard.  When tracing is disabled (the default) the guard costs one
//! relaxed atomic load and a predictable branch: no `Instant::now`, no
//! allocation, no lock — the **overhead contract** (DESIGN.md §10) that
//! keeps the traced hot paths bit-identical *and* time-identical to the
//! untraced build.  When enabled, spans land in a thread-local buffer
//! (start/end [`Instant`] pairs + static category/label + a `u64` detail
//! such as wire bytes or a stage index) registered once per thread in a
//! global lane registry; [`collect`] drains every lane into a per-step
//! [`StepTrace`].
//!
//! Three consumers sit on top:
//!
//! 1. [`write_chrome_trace`] renders a run's `StepTrace`s as
//!    Chrome-trace/Perfetto JSON (`chrome://tracing`, `ui.perfetto.dev`)
//!    — one lane per pool worker plus the coordinator lane, validated in
//!    CI by `tools/check_trace.py` and round-tripped through
//!    [`util::json`](crate::util::json) in tests.
//! 2. The trainer appends per-step aggregates ([`StepTrace::comm_s`],
//!    [`StepTrace::compute_s`], [`StepTrace::overlap_efficiency`]) to the
//!    Recorder TSV.
//! 3. The `overlap_step` / `table2_time_model` benches calibrate the α-β
//!    cost model against measured phase times, and assert in `--quick`
//!    CI that traced wire-byte counters equal the analytic
//!    `cost::tiered_ring_phase_wire_bytes` values and that stage spans
//!    tile the step.
//!
//! Aggregates are computed on **interval unions**, never naive sums, so
//! nested spans (a pooled collective inside a DAG stage) are counted
//! once: `comm_s` is the measure of the union of all `comm` intervals
//! across lanes, and `overlap_efficiency` is the fraction of that union
//! covered by the `compute` union — the hidden-comm fraction.

use std::cell::RefCell;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One whole optimizer step (coordinator lane; detail = step number).
pub const CAT_STEP: &str = "step";
/// A DAG stage actually running (detail = stage index).
pub const CAT_SCHED: &str = "sched";
/// Time spent waiting: DAG queue-wait (ready → claimed) and the pool's
/// region close barrier.
pub const CAT_WAIT: &str = "wait";
/// Collective communication (detail = executed wire bytes).
pub const CAT_COMM: &str = "comm";
/// Optimizer arithmetic: grad², moments/coefficients, apply, stitch,
/// unscale/probe.
pub const CAT_COMPUTE: &str = "compute";
/// Pool region mechanics: dispatch, caller drain, per-worker busy time.
pub const CAT_POOL: &str = "pool";
/// Wire precision conversion (batch f32↔f16/bf16; detail = converted
/// bytes on the half side).  Deliberately its *own* category — these
/// spans nest inside `comm` spans, and charging them to `compute` would
/// corrupt `overlap_efficiency`'s comm∩compute measure.
pub const CAT_CONVERT: &str = "convert";

static ENABLED: AtomicBool = AtomicBool::new(false);

struct RawSpan {
    cat: &'static str,
    label: &'static str,
    start: Instant,
    end: Instant,
    detail: u64,
}

struct LaneBuf {
    name: String,
    spans: Vec<RawSpan>,
}

struct Global {
    /// Set once, at the first [`enable`], and kept for the process
    /// lifetime so timestamps stay monotonic across enable/disable
    /// cycles.
    origin: Option<Instant>,
    lanes: Vec<Arc<Mutex<LaneBuf>>>,
}

static GLOBAL: Mutex<Global> = Mutex::new(Global { origin: None, lanes: Vec::new() });

thread_local! {
    static LANE: RefCell<Option<Arc<Mutex<LaneBuf>>>> = RefCell::new(None);
}

/// Whether spans are currently being recorded.  One relaxed load — the
/// only cost the disabled hot path ever pays.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording spans.  Idempotent; the time origin is pinned at the
/// first call and shared by every later session.
pub fn enable() {
    let mut g = GLOBAL.lock().unwrap();
    if g.origin.is_none() {
        g.origin = Some(Instant::now());
    }
    drop(g);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording spans.  Buffered spans stay until the next [`collect`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// An RAII span guard: opened by [`span`]/[`span_detail`], recorded on
/// drop.  Disabled tracing makes both construction and drop a no-op.
#[must_use = "a span measures the scope it is bound to; bind it to a variable"]
pub struct Span {
    active: Option<(Instant, &'static str, &'static str, u64)>,
}

impl Span {
    /// Attach/overwrite the detail value (e.g. wire bytes known only
    /// after the traced call returns).
    #[inline]
    pub fn set_detail(&mut self, detail: u64) {
        if let Some(a) = &mut self.active {
            a.3 = detail;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((start, cat, label, detail)) = self.active.take() {
            record_span(cat, label, start, Instant::now(), detail);
        }
    }
}

/// Open a span with detail 0.
#[inline]
pub fn span(cat: &'static str, label: &'static str) -> Span {
    span_detail(cat, label, 0)
}

/// Open a span carrying a `u64` detail (bucket index, wire bytes, …).
#[inline]
pub fn span_detail(cat: &'static str, label: &'static str, detail: u64) -> Span {
    if !enabled() {
        return Span { active: None };
    }
    Span { active: Some((Instant::now(), cat, label, detail)) }
}

/// Record a span from explicit instants — for callers that measure a wait
/// whose start predates the recording scope (e.g. DAG queue-wait, whose
/// clock starts when the stage becomes ready on another thread).
pub fn record_span(
    cat: &'static str,
    label: &'static str,
    start: Instant,
    end: Instant,
    detail: u64,
) {
    if !enabled() {
        return;
    }
    LANE.with(|slot| {
        let mut slot = slot.borrow_mut();
        let arc = slot.get_or_insert_with(|| {
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| "anon".to_string());
            let arc = Arc::new(Mutex::new(LaneBuf { name, spans: Vec::new() }));
            GLOBAL.lock().unwrap().lanes.push(arc.clone());
            arc
        });
        arc.lock().unwrap().spans.push(RawSpan { cat, label, start, end, detail });
    });
}

/// One recorded span, times in seconds relative to the trace origin.
#[derive(Debug, Clone)]
pub struct TraceSpan {
    pub cat: &'static str,
    pub label: &'static str,
    pub start_s: f64,
    pub dur_s: f64,
    pub detail: u64,
}

impl TraceSpan {
    pub fn end_s(&self) -> f64 {
        self.start_s + self.dur_s
    }
}

/// One thread's timeline: the coordinator, or one `lans-pool-{i}` worker.
#[derive(Debug, Clone)]
pub struct Lane {
    pub name: String,
    pub spans: Vec<TraceSpan>,
}

/// Every lane's spans for one step, drained by [`collect`].
#[derive(Debug, Clone)]
pub struct StepTrace {
    pub step: u64,
    pub lanes: Vec<Lane>,
}

/// Sort key putting the coordinator (any non-pool thread) before the pool
/// workers, and the workers in index order.
fn lane_sort_key(name: &str) -> (u8, usize) {
    match name.strip_prefix("lans-pool-").and_then(|s| s.parse().ok()) {
        Some(i) => (1, i),
        None => (0, 0),
    }
}

/// Drain every lane's buffered spans into a [`StepTrace`].  Call between
/// steps, when no instrumented region is open (the trainer collects after
/// each step; benches after each timed iteration).
pub fn collect(step: u64) -> StepTrace {
    let g = GLOBAL.lock().unwrap();
    let origin = match g.origin {
        Some(o) => o,
        None => return StepTrace { step, lanes: Vec::new() },
    };
    let mut lanes = Vec::new();
    for arc in &g.lanes {
        let mut buf = arc.lock().unwrap();
        if buf.spans.is_empty() {
            continue;
        }
        let mut spans: Vec<TraceSpan> = buf
            .spans
            .drain(..)
            .map(|r| TraceSpan {
                cat: r.cat,
                label: r.label,
                start_s: r.start.saturating_duration_since(origin).as_secs_f64(),
                dur_s: r.end.saturating_duration_since(r.start).as_secs_f64(),
                detail: r.detail,
            })
            .collect();
        spans.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        lanes.push(Lane { name: buf.name.clone(), spans });
    }
    lanes.sort_by(|a, b| {
        (lane_sort_key(&a.name), a.name.as_str()).cmp(&(lane_sort_key(&b.name), b.name.as_str()))
    });
    StepTrace { step, lanes }
}

/// Merge sorted-or-not intervals into a disjoint ascending list.  Shared
/// with `obs::postmortem`'s culprit attribution.
pub(crate) fn merge(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

pub(crate) fn measure(iv: &[(f64, f64)]) -> f64 {
    iv.iter().map(|(s, e)| e - s).sum()
}

/// Measure of the intersection of two disjoint ascending interval lists.
fn intersect_measure(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            acc += hi - lo;
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    acc
}

impl StepTrace {
    fn intervals(&self, cat: &str) -> Vec<(f64, f64)> {
        let mut iv = Vec::new();
        for l in &self.lanes {
            for s in &l.spans {
                if s.cat == cat {
                    iv.push((s.start_s, s.end_s()));
                }
            }
        }
        iv
    }

    /// Wall time with communication in flight: the measure of the union
    /// of every `comm` span across all lanes (nested spans count once).
    pub fn comm_s(&self) -> f64 {
        measure(&merge(self.intervals(CAT_COMM)))
    }

    /// Wall time with optimizer arithmetic in flight (union measure of
    /// the `compute` category).
    pub fn compute_s(&self) -> f64 {
        measure(&merge(self.intervals(CAT_COMPUTE)))
    }

    /// Hidden-comm fraction: of the wall time communication was in
    /// flight, how much was simultaneously covered by compute.  1.0 means
    /// communication is fully hidden behind the optimizer; 0.0 means the
    /// phases ran back-to-back (overlap off, or a serial pool).
    pub fn overlap_efficiency(&self) -> f64 {
        let comm = merge(self.intervals(CAT_COMM));
        let total = measure(&comm);
        if total <= 0.0 {
            return 0.0;
        }
        let compute = merge(self.intervals(CAT_COMPUTE));
        intersect_measure(&comm, &compute) / total
    }

    /// Sum of the `detail` payloads over spans matching `cat`/`label` —
    /// e.g. executed wire bytes over the DAG's per-bucket comm spans,
    /// which the benches check against the analytic
    /// `cost::tiered_ring_phase_wire_bytes` values.
    pub fn detail_sum(&self, cat: &str, label: &str) -> u64 {
        self.lanes
            .iter()
            .flat_map(|l| &l.spans)
            .filter(|s| s.cat == cat && s.label == label)
            .map(|s| s.detail)
            .sum()
    }

    pub fn span_count(&self, cat: &str) -> usize {
        self.lanes.iter().flat_map(|l| &l.spans).filter(|s| s.cat == cat).count()
    }

    /// How completely the DAG stage spans (runs + queue-waits) tile their
    /// own window `[first ready/run start, last run end]`: the union
    /// measure over that window's length.  1.0 = no gaps; scheduler
    /// bookkeeping (mutex hops, condvar wakeups) keeps real runs slightly
    /// below it, which is the "scheduler slack" the bench assertions
    /// allow for.
    pub fn stage_coverage(&self) -> f64 {
        let mut iv = self.intervals(CAT_SCHED);
        iv.extend(self.intervals(CAT_WAIT));
        let merged = merge(iv);
        let (Some(first), Some(last)) = (merged.first(), merged.last()) else {
            return 1.0;
        };
        let window = last.1 - first.0;
        if window <= 0.0 {
            return 1.0;
        }
        measure(&merged) / window
    }

    /// The step span's duration when present, else the envelope of every
    /// recorded span.
    pub fn duration_s(&self) -> f64 {
        for l in &self.lanes {
            if let Some(s) = l.spans.iter().find(|s| s.cat == CAT_STEP) {
                return s.dur_s;
            }
        }
        let all: Vec<(f64, f64)> =
            self.lanes.iter().flat_map(|l| &l.spans).map(|s| (s.start_s, s.end_s())).collect();
        let merged = merge(all);
        match (merged.first(), merged.last()) {
            (Some(f), Some(l)) => l.1 - f.0,
            _ => 0.0,
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a run's step traces as Chrome-trace/Perfetto JSON ("X" complete
/// events, µs timestamps) and write them to `path` (parent directories
/// are created).  Lane → tid mapping is stable across steps: tid 0 is the
/// coordinator lane, pool workers follow in index order; each tid gets a
/// thread-name metadata ("M") event and its events are sorted by `ts` —
/// the schema `tools/check_trace.py` validates in CI.
pub fn write_chrome_trace(path: &Path, traces: &[StepTrace]) -> std::io::Result<()> {
    // stable lane-name → tid assignment across the whole run
    let mut names: Vec<String> = Vec::new();
    for t in traces {
        for l in &t.lanes {
            if !names.contains(&l.name) {
                names.push(l.name.clone());
            }
        }
    }
    names.sort_by(|a, b| {
        (lane_sort_key(a), a.as_str()).cmp(&(lane_sort_key(b), b.as_str()))
    });

    struct Ev {
        ts_us: f64,
        dur_us: f64,
        name: &'static str,
        cat: &'static str,
        step: u64,
        detail: u64,
    }
    let mut per_tid: Vec<Vec<Ev>> = (0..names.len()).map(|_| Vec::new()).collect();
    for t in traces {
        for l in &t.lanes {
            let tid = names.iter().position(|n| n == &l.name).unwrap();
            for s in &l.spans {
                per_tid[tid].push(Ev {
                    ts_us: s.start_s * 1e6,
                    dur_us: s.dur_s * 1e6,
                    name: s.label,
                    cat: s.cat,
                    step: t.step,
                    detail: s.detail,
                });
            }
        }
    }
    for evs in &mut per_tid {
        evs.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    }

    let mut out = String::new();
    out.push_str("{\n  \"displayTimeUnit\": \"ms\",\n  \"traceEvents\": [\n");
    let mut first = true;
    let mut push_event = |out: &mut String, body: String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str("    ");
        out.push_str(&body);
    };
    for (tid, name) in names.iter().enumerate() {
        push_event(
            &mut out,
            format!(
                "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": {}, \
                 \"args\": {{\"name\": \"{}\"}}}}",
                tid,
                json_escape(name)
            ),
        );
    }
    for (tid, evs) in per_tid.iter().enumerate() {
        for e in evs {
            push_event(
                &mut out,
                format!(
                    "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \
                     \"dur\": {:.3}, \"pid\": 0, \"tid\": {}, \
                     \"args\": {{\"step\": {}, \"detail\": {}}}}}",
                    json_escape(e.name),
                    json_escape(e.cat),
                    e.ts_us,
                    e.dur_us,
                    tid,
                    e.step,
                    e.detail
                ),
            );
        }
    }
    out.push_str("\n  ]\n}\n");

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

/// Serializes tests (here and in other modules) that flip the global
/// enable flag, so concurrently running tests don't interleave spans.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic() -> StepTrace {
        // comm [0,2)∪[3,4), compute [1,5): comm total 3, hidden [1,2)∪[3,4) = 2
        let spans = vec![
            TraceSpan { cat: CAT_STEP, label: "step", start_s: 0.0, dur_s: 5.0, detail: 7 },
            TraceSpan { cat: CAT_COMM, label: "rs", start_s: 0.0, dur_s: 2.0, detail: 100 },
            TraceSpan { cat: CAT_COMM, label: "rs", start_s: 3.0, dur_s: 1.0, detail: 50 },
            TraceSpan { cat: CAT_COMPUTE, label: "apply", start_s: 1.0, dur_s: 4.0, detail: 0 },
        ];
        StepTrace { step: 7, lanes: vec![Lane { name: "main".into(), spans }] }
    }

    #[test]
    fn union_aggregates_are_exact_on_synthetic_spans() {
        let t = synthetic();
        assert!((t.comm_s() - 3.0).abs() < 1e-12);
        assert!((t.compute_s() - 4.0).abs() < 1e-12);
        assert!((t.overlap_efficiency() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(t.detail_sum(CAT_COMM, "rs"), 150);
        assert!((t.duration_s() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn nested_spans_count_once() {
        // a pooled collective span nested inside a wider comm span must
        // not double the comm measure
        let spans = vec![
            TraceSpan { cat: CAT_COMM, label: "outer", start_s: 0.0, dur_s: 4.0, detail: 0 },
            TraceSpan { cat: CAT_COMM, label: "inner", start_s: 1.0, dur_s: 1.0, detail: 0 },
        ];
        let t = StepTrace { step: 0, lanes: vec![Lane { name: "main".into(), spans }] };
        assert!((t.comm_s() - 4.0).abs() < 1e-12);
        assert_eq!(t.overlap_efficiency(), 0.0);
    }

    #[test]
    fn stage_coverage_sees_gaps() {
        let spans = vec![
            TraceSpan { cat: CAT_SCHED, label: "a", start_s: 0.0, dur_s: 1.0, detail: 0 },
            TraceSpan { cat: CAT_SCHED, label: "b", start_s: 3.0, dur_s: 1.0, detail: 1 },
        ];
        let t = StepTrace { step: 0, lanes: vec![Lane { name: "main".into(), spans }] };
        assert!((t.stage_coverage() - 0.5).abs() < 1e-12);
        // waits filling the gap restore full coverage
        let mut t2 = t.clone();
        t2.lanes[0].spans.push(TraceSpan {
            cat: CAT_WAIT,
            label: "b",
            start_s: 1.0,
            dur_s: 2.0,
            detail: 1,
        });
        assert!((t2.stage_coverage() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_lock();
        disable();
        {
            let mut sp = span_detail(CAT_COMM, "noop", 3);
            sp.set_detail(9);
        }
        let t = collect(0);
        assert_eq!(t.detail_sum(CAT_COMM, "noop"), 0);
    }

    #[test]
    fn spans_round_trip_through_collect() {
        let _g = test_lock();
        enable();
        {
            let mut sp = span(CAT_COMM, "rt_comm");
            std::thread::sleep(std::time::Duration::from_millis(2));
            sp.set_detail(4096);
        }
        {
            let _sp = span_detail(CAT_COMPUTE, "rt_apply", 1);
        }
        disable();
        let t = collect(11);
        // other tests may contribute lanes while enabled; assert only on
        // the spans this thread emitted
        assert_eq!(t.detail_sum(CAT_COMM, "rt_comm"), 4096);
        let me: Vec<&TraceSpan> = t
            .lanes
            .iter()
            .flat_map(|l| &l.spans)
            .filter(|s| s.label.starts_with("rt_"))
            .collect();
        assert_eq!(me.len(), 2);
        assert!(me.iter().all(|s| s.dur_s >= 0.0 && s.start_s >= 0.0));
        let comm = me.iter().find(|s| s.label == "rt_comm").unwrap();
        assert!(comm.dur_s >= 0.002, "slept 2ms inside the span, got {}", comm.dur_s);
        // drained: a second collect starts empty
        assert_eq!(collect(12).detail_sum(CAT_COMM, "rt_comm"), 0);
    }

    #[test]
    fn chrome_trace_round_trips_through_util_json() {
        let dir = std::env::temp_dir().join("lans_trace_test");
        let path = dir.join("trace.json");
        let mut t = synthetic();
        t.lanes.push(Lane {
            name: "lans-pool-0".into(),
            spans: vec![TraceSpan {
                cat: CAT_POOL,
                label: "worker_busy",
                start_s: 0.5,
                dur_s: 0.25,
                detail: 0,
            }],
        });
        write_chrome_trace(&path, &[t]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::Json::parse(&text).expect("trace JSON must parse");
        let events = v.expect("traceEvents").as_arr().unwrap();
        // 2 thread-name metadata + 5 spans
        assert_eq!(events.len(), 7);
        let metas: Vec<_> =
            events.iter().filter(|e| e.expect("ph").as_str() == Some("M")).collect();
        assert_eq!(metas.len(), 2);
        assert_eq!(metas[0].expect("args").expect("name").as_str(), Some("main"));
        assert_eq!(metas[1].expect("args").expect("name").as_str(), Some("lans-pool-0"));
        let xs: Vec<_> =
            events.iter().filter(|e| e.expect("ph").as_str() == Some("X")).collect();
        assert_eq!(xs.len(), 5);
        for e in &xs {
            assert!(e.expect("ts").as_f64().unwrap() >= 0.0);
            assert!(e.expect("dur").as_f64().unwrap() >= 0.0);
            assert_eq!(e.expect("pid").as_usize(), Some(0));
            assert!(e.expect("tid").as_usize().is_some());
            assert!(e.expect("cat").as_str().is_some());
            assert!(e.expect("args").expect("step").as_usize().is_some());
        }
        // the step span landed on the coordinator tid with its detail
        let step_ev = xs
            .iter()
            .find(|e| e.expect("cat").as_str() == Some(CAT_STEP))
            .expect("step span present");
        assert_eq!(step_ev.expect("tid").as_usize(), Some(0));
        assert_eq!(step_ev.expect("args").expect("detail").as_usize(), Some(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lane_ordering_puts_coordinator_first() {
        assert!(lane_sort_key("main") < lane_sort_key("lans-pool-0"));
        assert!(lane_sort_key("lans-pool-1") < lane_sort_key("lans-pool-2"));
        assert!(lane_sort_key("lans-pool-9") < lane_sort_key("lans-pool-10"));
    }
}
