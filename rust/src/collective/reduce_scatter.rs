//! Reduce-scatter / all-gather primitives — the two halves of the classic
//! ring schedule, exposed separately so the sharded-optimizer path (ZeRO-1
//! style, `optim::sharded`) can stop after the reduce-scatter, update only
//! the owned shard, and gather parameters instead of gradients.
//!
//! [`ring_allreduce`](super::ring::ring_allreduce) is *composed* from these
//! primitives over the same chunk grid ([`ring_chunk_starts`]), so
//! `reduce_scatter ∘ all_gather ≡ ring_allreduce` holds bit-for-bit by
//! construction (and is still property-tested in `tests/proptests.rs`
//! to guard refactors).
//!
//! Ownership convention: after [`ring_reduce_scatter`], chunk `c` holds its
//! full sum in the buffer of worker [`chunk_owner`]`(c, w) = (c + w - 1) % w`
//! — exactly the worker the classic schedule parks the reduced chunk on
//! before the gather phase starts.
//!
//! The `*_at` variants take an explicit chunk partition (`starts`, length
//! `w + 1`): the sharded trainer gathers *parameters* on `ShardPlan`
//! boundaries (a pure copy phase — boundaries never change bits) while
//! gradients are always reduced on the default ring grid, keeping the
//! summation order identical to the replicated path's allreduce.

use crate::precision::DType;
use crate::trace;
use crate::util::pool::ThreadPool;

use super::half::ring_phase_wire_bytes;

// The serial-fallback floor lives in the shared `util::pool::policy`
// module (one home for every such threshold); re-exported here so the
// collective API keeps its historical path.
pub use crate::util::pool::policy::POOLED_MIN_ELEMS;

/// The ring's default chunk grid: chunk `c` covers
/// `[c * n / w, (c + 1) * n / w)`.
pub fn ring_chunk_starts(w: usize, n: usize) -> Vec<usize> {
    assert!(w > 0, "no workers");
    (0..=w).map(|c| c * n / w).collect()
}

/// Which worker owns chunk `c`'s full sum after the reduce-scatter phase.
pub fn chunk_owner(c: usize, w: usize) -> usize {
    (c + w - 1) % w
}

/// Validate a worker-buffer set and return `(workers, elements)` — shared
/// with the half-wire variants in [`super::half`] so the invariant has
/// one home.
pub(crate) fn check_bufs(bufs: &[Vec<f32>]) -> (usize, usize) {
    let w = bufs.len();
    assert!(w > 0, "no workers");
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    (w, n)
}

fn check_starts(starts: &[usize], w: usize, n: usize) {
    assert_eq!(starts.len(), w + 1, "starts must have w + 1 entries");
    assert_eq!(starts[0], 0, "starts must begin at 0");
    assert_eq!(starts[w], n, "starts must end at the buffer length");
    assert!(starts.windows(2).all(|p| p[0] <= p[1]), "starts must be sorted");
}

/// Reduce-scatter on the default ring grid: `w - 1` ring steps after which
/// chunk `c`'s element-wise sum lives in worker [`chunk_owner`]`(c, w)`'s
/// buffer (other workers hold partial sums there — do not read them).
pub fn ring_reduce_scatter(bufs: &mut [Vec<f32>]) {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_reduce_scatter",
        ring_phase_wire_bytes(w, n, DType::F32),
    );
    let starts = ring_chunk_starts(w, n);
    ring_reduce_scatter_at(bufs, &starts);
}

/// Reduce-scatter over an explicit chunk partition.
pub fn ring_reduce_scatter_at(bufs: &mut [Vec<f32>], starts: &[usize]) {
    let (w, n) = check_bufs(bufs);
    check_starts(starts, w, n);
    if w == 1 || n == 0 {
        return;
    }
    // After step s, worker (c + s + 1) mod w holds the partial sum of chunk
    // c over s + 2 workers; after w - 1 steps the full sum sits at the
    // chunk's owner.  Chunk c is reduced in worker order c, c+1, … (mod w)
    // regardless of w — deterministic, like a real wire ring.
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = split_two(bufs, src, dst);
            for i in lo..hi {
                b[i] += a[i];
            }
        }
    }
}

/// Reduce-scatter restricted to the element range `[lo, hi)` of the
/// *global* ring grid (the grid is still computed from the full buffer
/// length).  The full `w - 1`-step schedule runs with every chunk clipped
/// to the range, so each in-range element receives exactly the adds it
/// would under [`ring_reduce_scatter`], from the same sources, in the
/// same order — an element's summation order depends only on its
/// containing chunk, never on which other elements travel with it.
/// Running this once per bucket over a partition of `[0, n)` is therefore
/// bitwise identical to one full-vector reduce-scatter (the bucketed
/// trainer path's bit-identity contract; property-tested).  Elements
/// outside `[lo, hi)` are untouched.
pub fn ring_reduce_scatter_range(bufs: &mut [Vec<f32>], lo: usize, hi: usize) {
    let (w, n) = check_bufs(bufs);
    assert!(lo <= hi && hi <= n, "bad range {lo}..{hi} for n={n}");
    if w == 1 || lo == hi {
        return;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        for c in 0..w {
            let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
            if clo >= chi {
                continue;
            }
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (a, b) = split_two(bufs, src, dst);
            for i in clo..chi {
                b[i] += a[i];
            }
        }
    }
}

/// All-gather restricted to the element range `[lo, hi)` of the global
/// ring grid — the range analogue of [`ring_all_gather`]: pure copies of
/// the clipped owner chunks, circulated on the full schedule.  Running it
/// per bucket over a partition of `[0, n)` reproduces the full gather
/// exactly.
pub fn ring_all_gather_range(bufs: &mut [Vec<f32>], lo: usize, hi: usize) {
    let (w, n) = check_bufs(bufs);
    assert!(lo <= hi && hi <= n, "bad range {lo}..{hi} for n={n}");
    if w == 1 || lo == hi {
        return;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        for c in 0..w {
            let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
            if clo >= chi {
                continue;
            }
            let src = (c + w - 1 + s) % w;
            let dst = (c + w + s) % w;
            let (a, b) = split_two(bufs, src, dst);
            b[clo..chi].copy_from_slice(&a[clo..chi]);
        }
    }
}

/// All-gather on the default ring grid: assumes each chunk's final value
/// sits at its [`chunk_owner`] (the reduce-scatter postcondition) and
/// circulates it until every buffer holds every chunk.
pub fn ring_all_gather(bufs: &mut [Vec<f32>]) {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_all_gather",
        ring_phase_wire_bytes(w, n, DType::F32),
    );
    let starts = ring_chunk_starts(w, n);
    ring_all_gather_at(bufs, &starts);
}

/// All-gather over an explicit chunk partition.  Pure copies — the
/// partition affects scheduling only, never bits.
pub fn ring_all_gather_at(bufs: &mut [Vec<f32>], starts: &[usize]) {
    let (w, n) = check_bufs(bufs);
    check_starts(starts, w, n);
    if w == 1 || n == 0 {
        return;
    }
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + w - 1 + s) % w;
            let dst = (c + w + s) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = split_two(bufs, src, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
}

/// Chunk-parallel reduce-scatter: the same schedule as
/// [`ring_reduce_scatter`] with the `w` per-chunk sums of every ring step
/// run concurrently on `pool` (they touch disjoint buffer regions).
/// Bit-identical to the serial path; falls back to it for width-1 pools,
/// small buffers or degenerate inputs.
pub fn ring_reduce_scatter_pooled(bufs: &mut [Vec<f32>], pool: &ThreadPool) {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_reduce_scatter_pooled",
        ring_phase_wire_bytes(w, n, DType::F32),
    );
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        ring_reduce_scatter(bufs);
        return;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        let mut tasks = ring_step_tasks(bufs, &starts, s, true);
        pool.map_mut(&mut tasks, |t| {
            for (d, x) in t.dst.iter_mut().zip(t.src.iter()) {
                *d += *x;
            }
        });
    }
}

/// Chunk-parallel all-gather; see [`ring_reduce_scatter_pooled`].
pub fn ring_all_gather_pooled(bufs: &mut [Vec<f32>], pool: &ThreadPool) {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_all_gather_pooled",
        ring_phase_wire_bytes(w, n, DType::F32),
    );
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        ring_all_gather(bufs);
        return;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        let mut tasks = ring_step_tasks(bufs, &starts, s, false);
        pool.map_mut(&mut tasks, |t| t.dst.copy_from_slice(t.src));
    }
}

/// One parallel unit of a ring step: move/accumulate `src` into `dst`.
/// The slices of different tasks never overlap (distinct chunks of distinct
/// buffers), which is what makes the step safely chunk-parallel.
pub(crate) struct ChunkTask<'a> {
    pub(crate) src: &'a [f32],
    pub(crate) dst: &'a mut [f32],
}

/// Carve the per-chunk (src, dst) slice pairs for ring step `s`.
///
/// In the reduce-scatter phase buffer `b` sends (is read at) chunk
/// `(b - s) mod w` and receives (is written at) chunk `(b - s - 1) mod w`;
/// in the all-gather phase it sends chunk `(b + 1 - s) mod w` and receives
/// chunk `(b - s) mod w` — the chunk↔buffer mapping of the classic
/// schedule, reindexed per buffer so each buffer is borrowed exactly once.
pub(crate) fn ring_step_tasks<'a>(
    bufs: &'a mut [Vec<f32>],
    starts: &[usize],
    s: usize,
    reduce: bool,
) -> Vec<ChunkTask<'a>> {
    let w = bufs.len();
    let mut srcs: Vec<Option<&[f32]>> = (0..w).map(|_| None).collect();
    let mut dsts: Vec<Option<&mut [f32]>> = (0..w).map(|_| None).collect();
    for (b, buf) in bufs.iter_mut().enumerate() {
        let (c_read, c_write) = if reduce {
            ((b + w - s) % w, (b + w - s - 1) % w)
        } else {
            ((b + w + 1 - s) % w, (b + w - s) % w)
        };
        let (rd, wr) = carve(
            buf,
            starts[c_read]..starts[c_read + 1],
            starts[c_write]..starts[c_write + 1],
        );
        srcs[c_read] = Some(rd);
        dsts[c_write] = Some(wr);
    }
    srcs.into_iter()
        .zip(dsts)
        .map(|(src, dst)| ChunkTask {
            src: src.expect("ring chunk without a source"),
            dst: dst.expect("ring chunk without a destination"),
        })
        .collect()
}

/// Split one buffer into a shared slice over `read` and a mutable slice
/// over `write`.  The ranges are distinct chunks, so non-empty ranges never
/// overlap; empty ranges may sit anywhere.
fn carve<'a>(
    buf: &'a mut [f32],
    read: std::ops::Range<usize>,
    write: std::ops::Range<usize>,
) -> (&'a [f32], &'a mut [f32]) {
    if write.is_empty() {
        return (&buf[read], &mut []);
    }
    if read.is_empty() {
        return (&[], &mut buf[write]);
    }
    if read.start < write.start {
        let (lo, hi) = buf.split_at_mut(write.start);
        (&lo[read], &mut hi[..write.end - write.start])
    } else {
        let (lo, hi) = buf.split_at_mut(read.start);
        (&hi[..read.end - read.start], &mut lo[write])
    }
}

/// Borrow two distinct workers' buffers mutably.  Generic over the buffer
/// representation (`Vec<f32>` for whole buffers, `&mut [f32]` for the
/// bucket views the DAG-scheduled step pre-carves).
pub(crate) fn split_two<B: AsRef<[f32]> + AsMut<[f32]>>(
    bufs: &mut [B],
    src: usize,
    dst: usize,
) -> (&[f32], &mut [f32]) {
    assert_ne!(src, dst);
    if src < dst {
        let (l, r) = bufs.split_at_mut(dst);
        (l[src].as_ref(), r[0].as_mut())
    } else {
        let (l, r) = bufs.split_at_mut(src);
        (r[0].as_ref(), l[dst].as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::ring::ring_allreduce;
    use crate::util::rng::Rng;

    fn random_bufs(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn owner_chunks_hold_full_sums() {
        for (w, n) in [(2, 10), (3, 7), (4, 64), (8, 1000), (8, 3), (1, 5)] {
            let mut bufs = random_bufs(w, n, (w * 31 + n) as u64);
            let mut reference = bufs.clone();
            ring_allreduce(&mut reference);
            ring_reduce_scatter(&mut bufs);
            let starts = ring_chunk_starts(w, n);
            for c in 0..w {
                let o = chunk_owner(c, w);
                assert_eq!(
                    &bufs[o][starts[c]..starts[c + 1]],
                    &reference[0][starts[c]..starts[c + 1]],
                    "chunk {c} at owner {o} (w={w} n={n})"
                );
            }
        }
    }

    #[test]
    fn reduce_scatter_then_all_gather_is_allreduce() {
        for (w, n) in [(1, 8), (2, 10), (3, 7), (5, 3), (4, 4096), (8, 30011)] {
            let template = random_bufs(w, n, (w * 1009 + n) as u64);
            let mut composed = template.clone();
            let mut reference = template;
            ring_reduce_scatter(&mut composed);
            ring_all_gather(&mut composed);
            ring_allreduce(&mut reference);
            assert_eq!(composed, reference, "w={w} n={n}");
        }
    }

    #[test]
    fn pooled_halves_match_serial_bit_for_bit() {
        for (w, n, threads) in
            [(2, 10, 4), (8, 3, 4), (2, 5000, 4), (3, 4099, 2), (4, 65536, 8)]
        {
            let pool = ThreadPool::new(threads);
            let template = random_bufs(w, n, (w * 7 + n + threads) as u64);

            let mut serial = template.clone();
            let mut pooled = template.clone();
            ring_reduce_scatter(&mut serial);
            ring_reduce_scatter_pooled(&mut pooled, &pool);
            assert_eq!(serial, pooled, "reduce-scatter w={w} n={n}");

            ring_all_gather(&mut serial);
            ring_all_gather_pooled(&mut pooled, &pool);
            assert_eq!(serial, pooled, "all-gather w={w} n={n}");
        }
    }

    #[test]
    fn all_gather_on_custom_partition_moves_owner_chunks() {
        // gather on an uneven partition: seed each owner's chunk with a
        // sentinel and check every worker ends up with all sentinels
        let (w, n) = (4, 100);
        let starts = vec![0, 10, 15, 80, 100];
        let mut bufs = vec![vec![0.0f32; n]; w];
        for c in 0..w {
            let o = chunk_owner(c, w);
            for i in starts[c]..starts[c + 1] {
                bufs[o][i] = (c + 1) as f32;
            }
        }
        ring_all_gather_at(&mut bufs, &starts);
        for (wk, b) in bufs.iter().enumerate() {
            for c in 0..w {
                for i in starts[c]..starts[c + 1] {
                    assert_eq!(b[i], (c + 1) as f32, "worker {wk} chunk {c}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "starts")]
    fn bad_partition_rejected() {
        let mut bufs = vec![vec![0.0f32; 8]; 2];
        ring_reduce_scatter_at(&mut bufs, &[0, 9, 8]);
    }

    #[test]
    fn range_sweep_equals_full_reduce_scatter() {
        // reducing bucket by bucket over any partition of [0, n) must be
        // bitwise identical to one full-vector reduce-scatter
        for (w, n, cuts) in [
            (2, 10, vec![0, 4, 10]),
            (3, 4099, vec![0, 1, 4096, 4099]),
            (4, 64, vec![0, 64]),
            (8, 30011, vec![0, 5000, 5000, 16384, 30011]),
            (5, 17, vec![0, 3, 9, 12, 17]),
        ] {
            let template = random_bufs(w, n, (w * 131 + n) as u64);
            let mut full = template.clone();
            let mut bucketed = template;
            ring_reduce_scatter(&mut full);
            for b in cuts.windows(2) {
                ring_reduce_scatter_range(&mut bucketed, b[0], b[1]);
            }
            assert_eq!(full, bucketed, "w={w} n={n} cuts={cuts:?}");
        }
    }

    #[test]
    fn range_sweep_equals_full_all_gather() {
        for (w, n, cuts) in [
            (2, 10, vec![0, 7, 10]),
            (4, 4099, vec![0, 1024, 4099]),
            (8, 30011, vec![0, 11, 4096, 30011]),
        ] {
            let template = random_bufs(w, n, (w * 17 + n) as u64);
            let mut full = template.clone();
            let mut bucketed = template;
            ring_reduce_scatter(&mut full);
            bucketed.clone_from(&full);
            ring_all_gather(&mut full);
            for b in cuts.windows(2) {
                ring_all_gather_range(&mut bucketed, b[0], b[1]);
            }
            assert_eq!(full, bucketed, "w={w} n={n} cuts={cuts:?}");
        }
    }

    #[test]
    fn empty_and_degenerate_ranges_are_noops() {
        let template = random_bufs(3, 100, 9);
        let mut bufs = template.clone();
        ring_reduce_scatter_range(&mut bufs, 40, 40);
        ring_all_gather_range(&mut bufs, 0, 0);
        assert_eq!(bufs, template);
        let mut single = random_bufs(1, 50, 10);
        let copy = single.clone();
        ring_reduce_scatter_range(&mut single, 0, 50);
        assert_eq!(single, copy);
    }
}
