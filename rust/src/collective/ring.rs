//! Ring allreduce with real summation — the collective the trainer uses to
//! combine per-worker gradients.
//!
//! The implementation follows the classic two-phase schedule (Baidu ring):
//! `W-1` reduce-scatter steps followed by `W-1` all-gather steps over `W`
//! equal chunks.  Communication here is memory movement between worker
//! buffers (the workers are in-process), but the *schedule* is the real
//! one: each phase moves exactly the chunks a wire implementation would,
//! which is what the cost model (`collective::cost`) prices and what the
//! allreduce bench measures.
//!
//! Numerical note: chunk c of every worker is reduced in the same ring
//! order regardless of W, so results are deterministic; f32 accumulation
//! order differs from a naive sequential sum by design (as on real rings).

/// In-place ring allreduce (sum) across `bufs` (one buffer per worker).
/// All buffers must be the same length.  After return, every buffer holds
/// the element-wise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) {
    let w = bufs.len();
    assert!(w > 0, "no workers");
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    if w == 1 || n == 0 {
        return;
    }

    // chunk boundaries: chunk c covers [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Phase 1 — reduce-scatter: after step s, worker (c + s + 1) mod w holds
    // the partial sum of chunk c over s+2 workers.  After w-1 steps, worker
    // (c + w - 1) mod w owns the full sum of chunk c.
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // sum src's chunk into dst's chunk
            let (a, b) = split_two(bufs, src, dst);
            for i in lo..hi {
                b[i] += a[i];
            }
        }
    }

    // Phase 2 — all-gather: owner of each reduced chunk circulates it.
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + w - 1 + s) % w;
            let dst = (c + w + s) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = split_two(bufs, src, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
}

/// Allreduce then divide by the worker count (gradient averaging).
pub fn ring_allreduce_avg(bufs: &mut [Vec<f32>]) {
    let w = bufs.len() as f32;
    ring_allreduce(bufs);
    for b in bufs.iter_mut() {
        for x in b.iter_mut() {
            *x /= w;
        }
    }
}

/// Borrow two distinct workers' buffers mutably.
fn split_two(bufs: &mut [Vec<f32>], src: usize, dst: usize) -> (&[f32], &mut [f32]) {
    assert_ne!(src, dst);
    if src < dst {
        let (l, r) = bufs.split_at_mut(dst);
        (&l[src], &mut r[0])
    } else {
        let (l, r) = bufs.split_at_mut(src);
        (&r[0], &mut l[dst])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_sum(w: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum())
            .collect();
        ring_allreduce(&mut bufs);
        for b in &bufs {
            for (got, want) in b.iter().zip(&expect) {
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "{got} vs {want} (w={w} n={n})"
                );
            }
        }
    }

    #[test]
    fn sums_match_many_shapes() {
        for (w, n) in [(1, 8), (2, 10), (3, 7), (4, 64), (8, 1000), (5, 3)] {
            check_sum(w, n, (w * 1000 + n) as u64);
        }
    }

    #[test]
    fn n_smaller_than_workers() {
        // degenerate chunking: some chunks are empty
        check_sum(8, 3, 42);
    }

    #[test]
    fn avg_divides() {
        let mut bufs = vec![vec![2.0f32; 4], vec![4.0f32; 4]];
        ring_allreduce_avg(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![3.0f32; 4]);
        }
    }

    #[test]
    fn all_workers_agree() {
        let mut rng = Rng::new(9);
        let mut bufs: Vec<Vec<f32>> =
            (0..6).map(|_| (0..50).map(|_| rng.normal_f32()).collect()).collect();
        ring_allreduce(&mut bufs);
        for w in 1..6 {
            assert_eq!(bufs[0], bufs[w], "worker {w} disagrees");
        }
    }
}
